"""Repo-wide test session config.

Two jobs:

1. **JAX persistent compilation cache** — the tier-1 suite's wall time is
   dominated by XLA compiles of the model smoke tests; caching them under
   ``.jax_cache/`` (gitignored) makes every rerun start warm.  Set via
   environment variables (before jax initializes) so subprocess tests
   inherit the same cache.

2. **Suite runtime budget** — now that the network tests run in virtual
   time, the default suite has a wall-clock budget (satisfying the CI gate:
   fail if tier-1 exceeds it).  Enabled by exporting
   ``SUITE_BUDGET_S`` (CI sets 90); local runs are unaffected.
"""
import os
import time

import pytest

os.environ.setdefault(
    "JAX_COMPILATION_CACHE_DIR",
    os.path.join(os.path.dirname(os.path.abspath(__file__)), ".jax_cache"))
os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "0.1")

_SESSION_T0 = time.monotonic()


def pytest_sessionfinish(session, exitstatus):
    budget = os.environ.get("SUITE_BUDGET_S")
    if not budget:
        return
    elapsed = time.monotonic() - _SESSION_T0
    if elapsed > float(budget):
        session.exitstatus = 1
        tr = session.config.pluginmanager.get_plugin("terminalreporter")
        if tr is not None:
            tr.write_line(
                f"FAILED suite-runtime budget: {elapsed:.1f}s > {budget}s "
                "(virtual-time tests should not wait on the host clock — "
                "see EXPERIMENTS.md §virtual time)", red=True)
