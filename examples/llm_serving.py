"""LLM inference serving over the simulated kernel-bypass fabric.

Builds the disaggregated serving cluster the ``repro.serving`` package
models — clients → flexlb-style balancer → prefill replicas → (KV-cache
transfer) → decode replicas → clients — entirely on the Switch/Topology
layer's shared SimClock, with every byte a real frame on a wire:

1. steady state: requests complete, TTFT/TPOT are measured in virtual ns,
   and the balancer splits load exactly across the prefill replicas;
2. a continuous-batching saturation sweep: p99 TTFT fattens monotonically
   as the offered QPS crosses the prefill replicas' aggregate capacity;
3. decode-replica failover: kill one decode mid-run — requests pinned to it
   strand (visible on the failed node's counters), later requests route
   around it, and the run still quiesces deterministically.

    PYTHONPATH=src python examples/llm_serving.py
"""
import sys

sys.path.insert(0, "src")

from repro.exp import (LinkConfig, NodeConfig, PoolConfig, PortConfig,
                       StackConfig, SwitchConfig, TopologyConfig,
                       TrafficConfig, run_topology_experiment)
from repro.serving import RequestMixConfig, ServingConfig


def serving(**kw) -> ServingConfig:
    base = dict(
        mix=RequestMixConfig(prompt_mean_tokens=64, prompt_dist="fixed",
                             output_mean_tokens=4, output_dist="fixed"),
        qps=20_000.0, prefill_ns_per_token=200, prefill_overhead_ns=5_000,
        decode_ns_per_token=300, decode_overhead_ns=2_000,
        kv_bytes_per_token=256, kv_segment_bytes=1024,
        max_batch_tokens=2048, max_batch_requests=8)
    base.update(kw)
    return ServingConfig(**base)


def node(name: str, kind: str) -> NodeConfig:
    return NodeConfig(name=name,
                      pool=PoolConfig(n_slots=4096, slot_size=2048),
                      port=PortConfig(n_queues=2, ring_size=512,
                                      writeback_threshold=1),
                      stack=StackConfig(kind=kind, burst_size=32))


def topology(s: ServingConfig, n_clients: int = 2,
             duration_s: float = 0.002) -> TopologyConfig:
    return TopologyConfig(
        name="llm-serving",
        nodes=(node("lb", "balancer"), node("prefill0", "prefill"),
               node("prefill1", "prefill"), node("decode0", "decode"),
               node("decode1", "decode")),
        n_clients=n_clients,
        client_pool=PoolConfig(n_slots=4096, slot_size=2048),
        switch=SwitchConfig(egress_capacity=256,
                            link=LinkConfig(gbps=100.0, latency_ns=1000)),
        traffic=TrafficConfig(duration_s=duration_s, seed=7,
                              mode="open_loop", sim_time=True),
        serving=s)


def main():
    print("=== Steady state: 2 clients -> lb -> 2 prefill -> 2 decode ===")
    rep = run_topology_experiment(topology(serving()))
    print(f"  requests: {rep.received}/{rep.sent} completed")
    print(f"  ttft: p50={rep.extras['ttft_p50_ns']/1e3:.1f}us "
          f"p99={rep.extras['ttft_p99_ns']/1e3:.1f}us   "
          f"tpot: p50={rep.extras['tpot_p50_ns']/1e3:.1f}us")
    print(f"  balancer split: prefill0={int(rep.extras['n0_lb_prefill0_requests'])} "
          f"prefill1={int(rep.extras['n0_lb_prefill1_requests'])}")
    print(f"  kv segments: prefill0={int(rep.extras['n1_prefill_kv_segments'])} "
          f"prefill1={int(rep.extras['n2_prefill_kv_segments'])}")
    assert rep.received == rep.sent > 0
    assert rep.extras["ttft_count"] == rep.sent
    assert abs(rep.extras["n0_lb_prefill0_requests"]
               - rep.extras["n0_lb_prefill1_requests"]) <= 1

    print("\n=== Continuous-batching saturation: p99 TTFT vs offered QPS ===")
    print(f"  {'qps':>8} {'done':>6} {'ttft_p50':>9} {'ttft_p99':>9}")
    p99s = []
    for qps in (2_000.0, 8_000.0, 24_000.0):
        s = serving(qps=qps, prefill_ns_per_token=2_000)
        r = run_topology_experiment(topology(s, n_clients=1))
        p99s.append(r.extras["ttft_p99_ns"])
        print(f"  {qps:8.0f} {r.received:6d} "
              f"{r.extras['ttft_p50_ns']/1e3:8.1f}u "
              f"{r.extras['ttft_p99_ns']/1e3:8.1f}u")
        assert r.received == r.sent
    assert p99s[0] <= p99s[1] <= p99s[2]   # queueing, monotone across the knee
    assert p99s[2] > 3 * p99s[0]

    print("\n=== Decode failover: decode1 dies at t=0.5ms ===")
    s = serving(fail_node="decode1", fail_at_s=0.0005)
    r = run_topology_experiment(topology(s))
    lost = int(r.extras["n4_decode_failed_drops"]
               + r.extras["n4_decode_stranded_requests"])
    print(f"  requests: {r.received}/{r.sent} completed, "
          f"{lost} KV/requests lost at the failed replica")
    print(f"  healthy decode0 finished {int(r.extras['n3_decode_requests_done'])}, "
          f"decode1 finished {int(r.extras['n4_decode_requests_done'])} "
          f"before failing")
    assert lost > 0 and r.received < r.sent
    assert r.extras["n3_decode_requests_done"] > 0

    print("\n=== Determinism: same TopologyConfig + seed, twice ===")
    a = run_topology_experiment(topology(serving()))
    b = run_topology_experiment(topology(serving()))
    same = (a.summary() == b.summary()
            and a.latency.as_dict() == b.latency.as_dict())
    print(f"  run A: done={a.received} ttft_p99={a.extras['ttft_p99_ns']:.0f}ns")
    print(f"  run B: done={b.received} ttft_p99={b.extras['ttft_p99_ns']:.0f}ns")
    print(f"  bit-identical: {same}")
    assert same

    # the whole scenario is declarative: exact dict round-trip
    cfg = topology(serving(policy="weighted", prefill_weights=(3, 1)))
    assert TopologyConfig.from_dict(cfg.to_dict()) == cfg
    print("\nconfig round-trip OK")


if __name__ == "__main__":
    main()
