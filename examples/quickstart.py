"""Quickstart: the paper's kernel-bypass dataplane in 60 seconds.

Builds both network stacks, measures their max sustainable bandwidth with the
EtherLoadGen-analogue load generator, and shows the descriptor-writeback-
threshold fix (paper §3.1.4) in action.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import (BypassL2FwdServer, KernelStackServer, LoadGen,
                        PacketPool, Port, RxDescriptorRing, TrafficPattern,
                        find_max_sustainable_bandwidth)


def make(stack, nports=1):
    pool = PacketPool(16384, 1518)
    ports = [Port.make(pool, ring_size=1024) for _ in range(nports)]
    server = (BypassL2FwdServer(ports, burst_size=64) if stack == "bypass"
              else KernelStackServer(ports))
    return server, ports


def main():
    print("=== 1. Maximum sustainable bandwidth (EtherLoadGen ramp mode) ===")
    for stack in ("kernel", "bypass"):
        msb, _ = find_max_sustainable_bandwidth(lambda: make(stack),
                                                trial_s=0.1, refine_iters=3)
        print(f"  {stack:7s} stack: {msb:6.2f} Gbps")

    print("\n=== 2. Per-packet latency at a common offered load ===")
    for stack in ("kernel", "bypass"):
        server, ports = make(stack)
        rep = LoadGen(ports).run(
            server, TrafficPattern(rate_gbps=0.5, packet_size=1518),
            duration_s=0.2)
        print(f"  {stack:7s}: {rep.latency}")

    print("\n=== 3. Descriptor writeback threshold (paper §3.1.4) ===")
    for threshold in (None, 32):
        ring = RxDescriptorRing(256, writeback_threshold=threshold)
        pool = PacketPool(256, 256)
        visible_at = None
        for i in range(256):
            s = pool.alloc()
            pool.write_packet(s, seq=i, length=128, fill=0)
            ring.nic_deliver(s, 128)
            if visible_at is None and ring.poll(1):
                visible_at = i + 1
        name = "pathological (None)" if threshold is None else f"fixed ({threshold})"
        print(f"  threshold {name:20s}: first packet visible to the PMD "
              f"after {visible_at} deliveries")


if __name__ == "__main__":
    main()
