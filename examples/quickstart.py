"""Quickstart: the paper's kernel-bypass dataplane in 60 seconds.

Declares both network stacks as :class:`repro.exp.ExperimentConfig`, measures
their max sustainable bandwidth with the EtherLoadGen-analogue load generator
through the one-call :func:`repro.exp.run_experiment` entry point, and shows
the descriptor-writeback-threshold fix (paper §3.1.4) through the
``rte_ethdev``-style :class:`repro.core.EthDev` API.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import EthDev, PacketPool
from repro.exp import ExperimentConfig, StackConfig, TrafficConfig, run_experiment


def config(stack: str, **traffic) -> ExperimentConfig:
    return ExperimentConfig(name=f"quickstart-{stack}",
                            stack=StackConfig(kind=stack),
                            traffic=TrafficConfig(**traffic))


def main():
    # durations are VIRTUAL seconds (sim_time defaults on): a few ms of
    # simulated traffic measures exactly and runs in moments of host time
    print("=== 1. Maximum sustainable bandwidth (EtherLoadGen ramp mode) ===")
    for stack in ("kernel", "bypass"):
        rep = run_experiment(config(stack, mode="msb", trial_s=0.004,
                                    refine_iters=3))
        print(f"  {stack:7s} stack: {rep.extras['msb_gbps']:6.2f} Gbps")

    print("\n=== 2. Per-packet latency at a common offered load ===")
    for stack in ("kernel", "bypass"):
        rep = run_experiment(config(stack, mode="open_loop", rate_gbps=0.5,
                                    packet_size=1518, duration_s=0.02))
        print(f"  {stack:7s}: {rep.latency}")

    print("\n=== 3. Descriptor writeback threshold (paper §3.1.4) ===")
    for threshold in (None, 32):
        pool = PacketPool(256, 256)
        dev = EthDev.make(pool, ring_size=256, writeback_threshold=threshold)
        visible_at = None
        for i in range(256):
            s = pool.alloc()
            pool.write_packet(s, seq=i, length=128, fill=0)
            dev.deliver(s, 128)
            if visible_at is None and len(dev.rx_burst(0, 1)[0]):
                visible_at = i + 1
        name = "pathological (None)" if threshold is None else f"fixed ({threshold})"
        print(f"  threshold {name:20s}: first packet visible to the PMD "
              f"after {visible_at} deliveries")


if __name__ == "__main__":
    main()
