"""ECN marking + DCTCP-style rate adaptation taming an N:1 incast.

Runs the same 8-client incast twice — 24 Gbps offered into one 10 GbE
switch egress port:

1. **drop-tail**: the egress buffer fills and stays full; line rate is
   sustained only by discarding over half the offered frames at the wall.
2. **ECN + DCTCP**: the switch pipeline's AQM stage marks CE on the RED
   curve instead of dropping, the server echoes the mark home, and each
   client's rate controller (virtual-time windows, multiplicative decrease
   by alpha/2, additive fast-recovery increase, in-flight cap as the cwnd
   analogue) converges onto the fair share — >= 90% of line rate with the
   egress drop counter at zero.

The asserts at the bottom are the smoke contract CI runs: ECN must cut
egress drops at least 10x below drop-tail at the same offered load while
keeping >= 90% of line rate.

    PYTHONPATH=src python examples/dctcp_incast.py
"""
import sys

sys.path.insert(0, "src")

from repro.exp import (AqmConfig, LinkConfig, NodeConfig, PipelineConfig,
                       PoolConfig, SwitchConfig, TopologyConfig,
                       TrafficConfig, run_topology_experiment)

N_CLIENTS = 8
RATE_GBPS = 3.0        # per client: 24 Gbps offered into a 10 GbE egress
LINK_GBPS = 10.0
DURATION_S = 0.005


def topology(ecn: bool) -> TopologyConfig:
    pipeline = None
    if ecn:
        pipeline = PipelineConfig(aqm=AqmConfig(
            kind="ecn", min_thresh=8, max_thresh=24, max_p=0.1, seed=1))
    return TopologyConfig(
        name="dctcp-incast" if ecn else "droptail-incast",
        nodes=(NodeConfig(name="server", pool=PoolConfig(n_slots=16384)),),
        n_clients=N_CLIENTS,
        client_pool=PoolConfig(n_slots=16384),
        switch=SwitchConfig(egress_capacity=64,
                            link=LinkConfig(gbps=LINK_GBPS, latency_ns=1000),
                            pipeline=pipeline),
        traffic=TrafficConfig(mode="open_loop", rate_gbps=RATE_GBPS,
                              packet_size=1518, duration_s=DURATION_S,
                              seed=7, cc_mode="dctcp" if ecn else "fixed",
                              cc_window_ns=100_000, cc_increase_gbps=0.1,
                              cc_max_inflight=8))


def main():
    print(f"=== {N_CLIENTS}:1 incast, {N_CLIENTS * RATE_GBPS:g} Gbps offered "
          f"into one {LINK_GBPS:g} GbE egress ===")
    dt = run_topology_experiment(topology(ecn=False))
    dt_drops = int(dt.extras["sw_p0_egress_drops"])
    print(f"  drop-tail : {dt.achieved_gbps:5.2f}G achieved  "
          f"{dt_drops:6d} egress drops  drop% {dt.drop_pct:5.1f}  "
          f"p99 {dt.latency.p99_ns / 1e3:.1f}us")

    ec = run_topology_experiment(topology(ecn=True))
    ec_drops = int(ec.extras["sw_p0_egress_drops"])
    marked = int(ec.extras["sw_p0_ecn_marked"])
    print(f"  ecn+dctcp : {ec.achieved_gbps:5.2f}G achieved  "
          f"{ec_drops:6d} egress drops  marked {marked:5d}  "
          f"p99 {ec.latency.p99_ns / 1e3:.1f}us")
    rates = [ec.extras[f"g{g}_cc_final_rate_gbps"] for g in range(N_CLIENTS)]
    print("  final client rates:",
          " ".join(f"{r:.2f}" for r in rates),
          f"(sum {sum(rates):.2f}G, fair share "
          f"{LINK_GBPS / N_CLIENTS:.2f}G)")

    # the smoke contract: same offered load, >=10x fewer egress drops,
    # >=90% of line rate kept
    line_frac = ec.achieved_gbps / LINK_GBPS
    print(f"  line fraction {line_frac:.3f}  "
          f"drop reduction {dt_drops / max(1, ec_drops):.0f}x")
    assert ec_drops * 10 <= dt_drops, \
        f"ECN egress drops {ec_drops} not 10x below drop-tail {dt_drops}"
    assert line_frac >= 0.90, \
        f"ECN+DCTCP goodput {line_frac:.3f} below 90% of line rate"
    print("  OK: >=10x fewer egress drops at >=90% of line rate")


if __name__ == "__main__":
    main()
