"""L2Fwd — the paper's workload, end to end, with payload verification.

Reproduces the §4.2 correctness experiment ("we modify L2Fwd to print the
content of the packets ... we always receive the correct content") as a
checksum sweep over packet sizes and port counts, then runs the run-to-
completion and pipeline execution models side by side.

    PYTHONPATH=src python examples/l2fwd_forward.py
"""
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core import (BypassL2FwdServer, LoadGen, PacketPool, PipelineServer,
                        Port, TrafficPattern)


def main():
    print("=== L2Fwd payload integrity (paper §4.2) ===")
    for size in (64, 256, 1024, 1518):
        for nports in (1, 2, 4):
            pool = PacketPool(4096, 1518)
            ports = [Port.make(pool) for _ in range(nports)]
            server = BypassL2FwdServer(ports, burst_size=32)
            lg = LoadGen(ports, verify_integrity=True)
            rep = lg.run_closed_loop(server, n_packets=500, packet_size=size,
                                     rng=np.random.default_rng(size))
            ok = (rep.received == 500 and rep.extras["integrity_errors"] == 0)
            print(f"  size={size:5d} ports={nports}: rx={rep.received} "
                  f"integrity_errors={int(rep.extras['integrity_errors'])} "
                  f"{'OK' if ok else 'FAIL'}")
            assert ok

    print("\n=== Run-to-completion vs pipeline mode (paper §2) ===")
    pool = PacketPool(8192, 1518)
    ports = [Port.make(pool, ring_size=1024)]
    rtc = BypassL2FwdServer(ports, burst_size=64)
    rep = LoadGen(ports).run(rtc, TrafficPattern(rate_gbps=0.5,
                                                 packet_size=1518),
                             duration_s=0.2)
    print(f"  run-to-completion: {rep.achieved_gbps:.2f} Gbps, "
          f"p99={rep.latency.p99_ns/1e3:.0f}us")

    pool2 = PacketPool(8192, 1518)
    ports2 = [Port.make(pool2, ring_size=1024)]
    pipe = PipelineServer(ports2[0], burst_size=64)
    pipe.start()
    lg2 = LoadGen(ports2)

    class _PipeShim:  # loadgen drives polling; pipeline threads do the work
        def poll_once(self):
            time.sleep(0)
            return 0

    rep2 = lg2.run(_PipeShim(), TrafficPattern(rate_gbps=0.5,
                                               packet_size=1518),
                   duration_s=0.2)
    pipe.stop()
    print(f"  pipeline (3 threads): {rep2.achieved_gbps:.2f} Gbps, "
          f"rx={rep2.received} (GIL-serialized on this 1-core host; "
          f"see DESIGN.md)")


if __name__ == "__main__":
    main()
