"""L2Fwd — the paper's workload, end to end, with payload verification.

Reproduces the §4.2 correctness experiment ("we modify L2Fwd to print the
content of the packets ... we always receive the correct content") as a
checksum sweep over packet sizes and port counts, then runs the run-to-
completion and pipeline execution models side by side.  Every testbed is a
declarative :class:`repro.exp.ExperimentConfig`.

    PYTHONPATH=src python examples/l2fwd_forward.py
"""
import sys
import time

sys.path.insert(0, "src")

from repro.exp import (ExperimentConfig, PoolConfig, PortConfig, StackConfig,
                       TrafficConfig, Testbed, run_experiment, run_testbed)


def main():
    print("=== L2Fwd payload integrity (paper §4.2) ===")
    for size in (64, 256, 1024, 1518):
        for nports in (1, 2, 4):
            cfg = ExperimentConfig(
                name=f"l2fwd-integrity-{size}B-{nports}p",
                pool=PoolConfig(n_slots=4096),
                ports=tuple(PortConfig(ring_size=256) for _ in range(nports)),
                stack=StackConfig(kind="bypass", burst_size=32),
                traffic=TrafficConfig(mode="closed_loop", n_packets=500,
                                      packet_size=size, verify_integrity=True,
                                      payload_seed=size))
            rep = run_experiment(cfg)
            ok = (rep.received == 500 and rep.extras["integrity_errors"] == 0)
            print(f"  size={size:5d} ports={nports}: rx={rep.received} "
                  f"integrity_errors={int(rep.extras['integrity_errors'])} "
                  f"{'OK' if ok else 'FAIL'}")
            assert ok

    print("\n=== Run-to-completion vs pipeline mode (paper §2) ===")
    base = ExperimentConfig(
        name="l2fwd-rtc",
        pool=PoolConfig(n_slots=8192),
        ports=(PortConfig(ring_size=1024),),
        stack=StackConfig(kind="bypass", burst_size=64),
        traffic=TrafficConfig(mode="open_loop", rate_gbps=0.5,
                              packet_size=1518, duration_s=0.02))
    rep = run_experiment(base)
    print(f"  run-to-completion: {rep.achieved_gbps:.2f} Gbps, "
          f"p99={rep.latency.p99_ns/1e3:.0f}us")

    # threaded pipeline mode is inherently wall-clock (real threads do the
    # work), so this one testbed opts out of virtual time
    tb = Testbed.build(base.with_stack(kind="pipeline")
                           .with_traffic(sim_time=False))
    tb.server.start()  # the three stage lcores run in their own threads

    class _PipeShim:  # loadgen drives polling; pipeline threads do the work
        def poll_once(self):
            time.sleep(0)
            return 0

    from repro.core import TrafficPattern
    rep2 = tb.loadgen.run(_PipeShim(), TrafficPattern(rate_gbps=0.5,
                                                      packet_size=1518),
                          duration_s=0.2)
    tb.server.stop()
    print(f"  pipeline (3 threads): {rep2.achieved_gbps:.2f} Gbps, "
          f"rx={rep2.received} (GIL-serialized on this 1-core host; "
          f"see DESIGN.md)")


if __name__ == "__main__":
    main()
