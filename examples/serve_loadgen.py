"""Serve a small LM under load: prefill/decode with per-request latency stats.

The serving-side use of the paper's methodology: requests are "packets",
TTFT/per-token latencies are the timestamp-compared RTTs, and the generator
never drops — all queueing shows up as measured latency.

    PYTHONPATH=src python examples/serve_loadgen.py
"""
import subprocess
import sys

if __name__ == "__main__":
    sys.exit(subprocess.call(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "qwen3-1.7b",
         "--smoke", "--requests", "8", "--batch", "4", "--prompt-len", "64",
         "--gen-len", "16"],
        env={**__import__("os").environ, "PYTHONPATH": "src"}))
