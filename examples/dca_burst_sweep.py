"""Fig. 4 end-to-end: the DPDK burst size moves *measured* RTT percentiles.

The smallest demonstration of the sim-time DCA descriptor path: one bypass
server behind a 100 GbE link, 10 Gbps of offered load, and a
:class:`~repro.exp.DcaConfig` sweeping the L2Fwd processing burst over
{1, 32, 1024} at a fixed writeback threshold of 32.  Completions publish at
threshold crossings or when the writeback-timeout (ITR analogue) event fires
on the testbed's EventScheduler; the PMD accumulates a full burst of
written-back descriptors before forwarding, giving up after the same
timeout.  Forwarding in bursts of 32 overlaps DMA with processing; waiting
for 1024 packets floods the staging path — the paper's Fig. 4 asymmetry, now
visible in p50/p99 instead of a queue-occupancy proxy.

Used as the CI smoke for this subsystem: asserts the monotone relationship
(p99 at burst 1024 > p99 at burst 32) and bit-identical reports across two
runs of the same config + seed.

    PYTHONPATH=src python examples/dca_burst_sweep.py
"""
import sys

sys.path.insert(0, "src")

from repro.exp import (DcaConfig, ExperimentConfig, PortConfig, StackConfig,
                       TrafficConfig, run_experiment)

WRITEBACK_TIMEOUT_NS = 200_000


def config(burst: int) -> ExperimentConfig:
    return ExperimentConfig(
        name=f"dca-sweep-{burst}",
        ports=(PortConfig(n_queues=1, ring_size=2048),),
        stack=StackConfig(kind="bypass", n_lcores=1),
        traffic=TrafficConfig(mode="open_loop", rate_gbps=10.0,
                              packet_size=1518, duration_s=0.004, seed=3),
        dca=DcaConfig(burst_size=burst, writeback_threshold=32,
                      writeback_timeout_ns=WRITEBACK_TIMEOUT_NS))


def main():
    print("=== Fig. 4 in sim time: burst size vs measured RTT ===")
    print("(writeback_threshold=32, writeback_timeout=200us, 10 Gbps offered)")
    p99 = {}
    for burst in (1, 32, 1024):
        rep = run_experiment(config(burst))
        again = run_experiment(config(burst))
        assert rep.summary() == again.summary(), \
            f"burst={burst}: reports not bit-identical across runs"
        assert rep.received == rep.sent, \
            f"burst={burst}: {rep.sent - rep.received} packets stranded " \
            "(writeback/accumulation timeouts should have flushed them)"
        lat = rep.latency
        p99[burst] = lat.p99_ns
        print(f"  burst={burst:5d}  p50={lat.median_ns/1e3:7.1f}us  "
              f"p99={lat.p99_ns/1e3:7.1f}us  max={lat.max_ns/1e3:7.1f}us  "
              f"rx={rep.received}/{rep.sent}  "
              f"writebacks={rep.extras['p0q0_writebacks']:.0f} "
              f"(mean size {rep.extras['p0q0_wb_size_mean']:.1f}, "
              f"timeout flushes {rep.extras['p0q0_timeout_flushes']:.0f})")
    assert p99[1024] > p99[32], \
        f"expected p99(1024) > p99(32), got {p99[1024]} vs {p99[32]}"
    print("OK: burst 1024 p99 > burst 32 p99 (accumulate-then-forward "
          "floods the staging path); reports bit-identical per config+seed.")


if __name__ == "__main__":
    main()
