"""Multi-queue RSS NIC + per-lcore engines — the Fig. 3(a) core-scaling axis.

One port with 4 RX/TX queue pairs; Toeplitz RSS steers each of 256 synthetic
flows to a queue; 4 lcores each poll their own queue run-to-completion.  The
sequential round-robin scheduler makes the single-core measurement exactly
reproducible; per-queue stats and the RSS skew come out of the run report.

    PYTHONPATH=src python examples/multiqueue_rss.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import (BurstPlan, BypassL2FwdServer, LoadGen, PacketPool,
                        Port, QueueTelemetry, TrafficPattern)


def main():
    print("=== 1 port x 4 RSS queues x 4 lcores (closed loop) ===")
    pool = PacketPool(16384, 1518)
    ports = [Port.make(pool, ring_size=1024, n_queues=4)]
    server = BypassL2FwdServer(ports, burst_size=64, n_lcores=4)
    lg = LoadGen(ports, verify_integrity=True)
    rep = lg.run_closed_loop(server, n_packets=4000, packet_size=512,
                             rng=np.random.default_rng(0))
    print(f"  sent={rep.sent} rx={rep.received} drops={rep.dropped} "
          f"integrity_errors={int(rep.extras['integrity_errors'])}")
    for (pi, qi), st in sorted(server.per_queue_stats().items()):
        print(f"  port{pi} queue{qi}: rx={st.rx_packets} tx={st.tx_packets} "
              f"avg_burst={st.avg_burst:.1f}")
    agg = server.stats
    print(f"  aggregate: rx={agg.rx_packets} tx={agg.tx_packets} "
          f"(per-queue sums match: "
          f"{sum(s.rx_packets for s in server.per_queue_stats().values()) == agg.rx_packets})")
    print(f"  rss_imbalance={rep.extras['p0_rss_imbalance']:.3f} "
          f"(1.0 == perfectly balanced)")

    print("\n=== per-lcore BurstPlan (heterogeneous DCA depths) ===")
    pool2 = PacketPool(16384, 1518)
    ports2 = [Port.make(pool2, ring_size=1024, n_queues=4)]
    server2 = BypassL2FwdServer(ports2, n_lcores=4,
                                plan=BurstPlan(per_lcore=(8, 16, 32, 64)))
    print("  lcore bursts:", [lc.burst_size for lc in server2.lcores])
    # drive manually so queue occupancy can be sampled mid-run
    telem = QueueTelemetry()
    lg2 = LoadGen(ports2)
    import time
    for i in range(400):
        now = time.perf_counter_ns()
        lg2._send_burst(ports2[0], 32, 512, now)
        ports2[0].flush_rx()
        telem.sample(ports2)  # post-DMA, pre-processing: the DCA pressure point
        server2.poll_once()
        lg2._drain_port(ports2[0], time.perf_counter_ns())
    rep2 = lg2._report(offered_gbps=0.0)
    print(f"  rx={rep2.received} drops={rep2.dropped} "
          f"({telem.samples} occupancy samples)")
    for k, v in telem.summary(ports2).items():
        print(f"  {k}={v:.3f}")


if __name__ == "__main__":
    main()
