"""Multi-queue RSS NIC + per-lcore engines — the Fig. 3(a) core-scaling axis.

One port with 4 RX/TX queue pairs; Toeplitz RSS steers each of 256 synthetic
flows to a queue; 4 lcores each poll their own queue run-to-completion.  The
testbed is declared as an :class:`repro.exp.ExperimentConfig`; per-queue
counters come out both as the server's stats and as DPDK-named
``rx_q{N}_packets`` extended stats from the :class:`repro.core.EthDev`
facade.

    PYTHONPATH=src python examples/multiqueue_rss.py
"""
import sys

sys.path.insert(0, "src")

from repro.exp import (ExperimentConfig, PortConfig, StackConfig,
                       TrafficConfig, Testbed, run_testbed)


def main():
    print("=== 1 port x 4 RSS queues x 4 lcores (closed loop) ===")
    cfg = ExperimentConfig(
        name="multiqueue-rss",
        ports=(PortConfig(n_queues=4, ring_size=1024),),
        stack=StackConfig(kind="bypass", burst_size=64, n_lcores=4),
        traffic=TrafficConfig(mode="closed_loop", n_packets=4000,
                              packet_size=512, verify_integrity=True,
                              payload_seed=0))
    tb = Testbed.build(cfg)
    rep = run_testbed(tb)
    print(f"  sent={rep.sent} rx={rep.received} drops={rep.dropped} "
          f"integrity_errors={int(rep.extras['integrity_errors'])}")
    server = tb.server
    for (pi, qi), st in sorted(server.per_queue_stats().items()):
        print(f"  port{pi} queue{qi}: rx={st.rx_packets} tx={st.tx_packets} "
              f"avg_burst={st.avg_burst:.1f}")
    agg = server.stats
    print(f"  aggregate: rx={agg.rx_packets} tx={agg.tx_packets} "
          f"(per-queue sums match: "
          f"{sum(s.rx_packets for s in server.per_queue_stats().values()) == agg.rx_packets})")
    print(f"  rss_imbalance={rep.extras['p0_rss_imbalance']:.3f} "
          f"(1.0 == perfectly balanced)")
    dev = tb.devs[0]
    xs = dev.xstats()
    print("  ethdev xstats: "
          + " ".join(f"rx_q{q}_packets={xs[f'rx_q{q}_packets']}"
                     for q in range(4))
          + f" imissed={xs['imissed']}")

    print("\n=== per-lcore BurstPlan (heterogeneous DCA depths) ===")
    cfg2 = ExperimentConfig(
        name="multiqueue-burstplan",
        ports=(PortConfig(n_queues=4, ring_size=1024),),
        stack=StackConfig(kind="bypass", n_lcores=4,
                          per_lcore_bursts=(8, 16, 32, 64)))
    tb2 = Testbed.build(cfg2)
    server2, lg2, dev2 = tb2.server, tb2.loadgen, tb2.devs[0]
    print("  lcore bursts:", [lc.burst_size for lc in server2.lcores])
    # drive manually on the testbed's SimClock so queue occupancy can be
    # sampled mid-run; 20 us virtual per 32-packet round offers ~1.6 Mpps,
    # inside the 4 lcores' modeled service rate — fully deterministic
    for i in range(400):
        now = tb2.clock.advance(20_000)
        lg2._send_burst(dev2, 32, 512, now)
        dev2.flush_rx()
        tb2.telemetry.sample(tb2.devs)  # post-DMA, pre-processing: DCA pressure
        server2.poll_at(now)
        lg2._drain_port(dev2, tb2.clock.now_ns)
    rep2 = lg2._report(offered_gbps=0.0)
    print(f"  rx={rep2.received} drops={rep2.dropped} "
          f"({tb2.telemetry.samples} occupancy samples)")
    for k, v in tb2.telemetry.summary(tb2.devs).items():
        print(f"  {k}={v:.3f}")


if __name__ == "__main__":
    main()
