"""Multi-host topologies on one shared SimClock — the Switch/Topology layer.

Builds the smallest interesting fabric: one bypass server node and N load-
generator clients around an output-queued 10 GbE switch, everything driven
event-by-event in virtual time.  Shows the two scenarios the loopback
harness could never express:

1. client -> switch -> server -> switch -> client forward path (RTT floored
   by four wire crossings), and
2. an N:1 incast, where the switch egress port facing the server saturates:
   the RTT tail fattens with client count and every loss is a *switch*
   egress-buffer drop while the server NIC stays clean — loss attribution a
   single-NIC model cannot produce.

    PYTHONPATH=src python examples/incast_topology.py
"""
import sys

sys.path.insert(0, "src")

from repro.exp import (LinkConfig, NodeConfig, PoolConfig, PortConfig,
                       StackConfig, SwitchConfig, TopologyConfig,
                       TrafficConfig, run_topology_experiment)


def topology(n_clients: int, rate_gbps: float) -> TopologyConfig:
    return TopologyConfig(
        name=f"incast-{n_clients}",
        nodes=(NodeConfig(name="server", pool=PoolConfig(n_slots=16384),
                          port=PortConfig(ring_size=2048,
                                          writeback_threshold=1),
                          stack=StackConfig(kind="bypass", burst_size=64)),),
        n_clients=n_clients,
        switch=SwitchConfig(egress_capacity=32,
                            link=LinkConfig(gbps=10.0, latency_ns=1000)),
        traffic=TrafficConfig(mode="open_loop", rate_gbps=rate_gbps,
                              packet_size=1518, duration_s=0.0004, seed=7))


def main():
    print("=== Forward path: 1 client -> server over the switch ===")
    rep = run_topology_experiment(topology(1, rate_gbps=2.0))
    # one wire crossing: serialization (integer ns, like the Wire) + 1 us
    ser_lat_ns = int(round(1518 * 8 / 10.0)) + 1000
    print(f"  rx={rep.received}/{rep.sent}  min_rtt={rep.latency.min_ns/1e3:.1f}us "
          f"(floor: 4 crossings = {4*ser_lat_ns/1e3:.1f}us)  "
          f"p99={rep.latency.p99_ns/1e3:.1f}us")
    assert rep.dropped == 0
    assert rep.latency.min_ns >= 4 * ser_lat_ns

    print("\n=== N:1 incast, 3 Gbps per client into one 10 GbE egress ===")
    print(f"  {'clients':>7} {'offered':>8} {'achieved':>9} {'p99_rtt':>8} "
          f"{'sw_drops':>8} {'occ_high':>8} {'imissed':>8}")
    for n in (1, 2, 4, 8):
        rep = run_topology_experiment(topology(n, rate_gbps=3.0))
        print(f"  {n:7d} {rep.offered_gbps:7.1f}G {rep.achieved_gbps:8.2f}G "
              f"{rep.latency.p99_ns/1e3:7.1f}u "
              f"{int(rep.extras['sw_p0_egress_drops']):8d} "
              f"{int(rep.extras['sw_p0_occ_high']):8d} "
              f"{int(rep.extras['n0_imissed']):8d}")
        # every loss (if any) is a switch egress-buffer drop, never the NIC
        assert rep.extras["n0_imissed"] == 0.0
        assert rep.extras["n0_rx_nombuf"] == 0.0
        assert rep.extras["sw_p0_egress_drops"] == float(rep.dropped)

    print("\n=== Determinism: same TopologyConfig + seed, twice ===")
    a = run_topology_experiment(topology(4, rate_gbps=3.0))
    b = run_topology_experiment(topology(4, rate_gbps=3.0))
    same = (a.sent, a.received, a.dropped, a.latency.p99_ns) == \
           (b.sent, b.received, b.dropped, b.latency.p99_ns)
    print(f"  run A: rx={a.received} drops={a.dropped} p99={a.latency.p99_ns}ns")
    print(f"  run B: rx={b.received} drops={b.dropped} p99={b.latency.p99_ns}ns")
    print(f"  bit-identical: {same}")
    assert same


if __name__ == "__main__":
    main()
