"""End-to-end training driver: a ~100M-param qwen3-family model, trained for a
few hundred steps through the kernel-bypass dataplane, with checkpointing.

Default invocation is CPU-budget-friendly (a ~10M model, 120 steps); pass
``--full`` for the ~100M/300-step configuration described in EXPERIMENTS.md.

    PYTHONPATH=src python examples/train_e2e.py [--full] [--steps N]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.data.pipeline import DataConfig
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.runtime.trainer import TrainerConfig, TrainerRuntime


def model_100m() -> ModelConfig:
    # ~100M params: 12L, d=768, 12H (kv 4), ff 2304, vocab 32k (tied)
    return ModelConfig(
        arch_id="qwen3-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, d_ff=2304, vocab_size=32000, qk_norm=True,
        tie_embeddings=True, rope_theta=1e6,
        param_dtype="float32", compute_dtype="float32")


def model_10m() -> ModelConfig:
    return ModelConfig(
        arch_id="qwen3-10m", family="dense", n_layers=6, d_model=256,
        n_heads=8, n_kv_heads=4, d_ff=768, vocab_size=8192, qk_norm=True,
        tie_embeddings=True, rope_theta=1e6,
        param_dtype="float32", compute_dtype="float32")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="~100M params")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    ap.add_argument("--feed", choices=["bypass", "kernel"], default="bypass")
    args = ap.parse_args()

    cfg = model_100m() if args.full else model_10m()
    steps = args.steps or (300 if args.full else 120)
    seq = args.seq_len or (256 if args.full else 128)
    n_params = cfg.param_count()
    print(f"[e2e] {cfg.arch_id}: {n_params/1e6:.1f}M params, {steps} steps, "
          f"seq {seq}, batch {args.global_batch}, feed={args.feed}")

    dcfg = DataConfig(seq_len=seq, global_batch=args.global_batch, seed=0)
    tcfg = TrainerConfig(steps=steps, ckpt_every=max(50, steps // 4),
                         ckpt_dir=args.ckpt_dir, feed=args.feed,
                         feed_ports=2, log_every=max(1, steps // 20))
    opt = adamw.AdamWConfig(lr=6e-4, warmup_steps=max(10, steps // 20),
                            decay_steps=steps)
    rt = TrainerRuntime(cfg, dcfg, tcfg, opt)
    state = rt.run()
    losses = [m["loss"] for m in rt.metrics_log]
    print(f"[e2e] done at step {state.step}: loss {losses[0]:.3f} -> "
          f"{losses[-1]:.3f} "
          f"({'DECREASED OK' if losses[-1] < losses[0] else 'no decrease!'})")
    assert losses[-1] < losses[0], "loss must decrease over the run"


if __name__ == "__main__":
    main()
