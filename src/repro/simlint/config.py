"""simlint configuration: defaults, ``simlint.toml`` discovery and parsing.

Config may live in a standalone ``simlint.toml`` (a ``[simlint]`` table,
per-rule subtables like ``[simlint.sl001]``) or inside a pyproject-style
``[tool.simlint]`` table — both spellings parse to the same
:class:`SimlintConfig`.  Parsing prefers :mod:`tomllib` (Python >= 3.11)
and falls back to a minimal built-in TOML-subset reader (tables, strings,
booleans, integers, and possibly-multiline string arrays) so the linter
stays dependency-free on 3.10 CI runners.
"""
from __future__ import annotations

import os
import re
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

# the sim-path scope: the layers whose numbers feed RunReports.  launch/,
# runtime/, models/ etc. are training/deploy utilities where wall clocks are
# the point, so the default walk (and the exclude list below) leaves them out.
DEFAULT_PATHS = (
    "src/repro/core",
    "src/repro/exp",
    "src/repro/serving",
    "benchmarks",
)

DEFAULT_EXCLUDE = (
    "*/__pycache__/*",
    "src/repro/launch/*",
    "src/repro/runtime/*",
    "src/repro/models/*",
    "src/repro/data/*",
    "src/repro/checkpoint/*",
    "src/repro/kernels/*",
    "src/repro/simlint/*",
)

# counters the telemetry layer accumulates as int64 (SL004): attribute names
# used by ThroughputMeter, LoadGen flight stats, EthDev/SwitchPort counters
DEFAULT_INT64_COUNTERS = (
    "packets", "bytes", "sent", "received", "dropped",
    "tx_frames", "rx_frames", "tx_bytes", "rx_bytes",
    "egress_drops", "egress_enqueued", "unrouted",
    "ipackets", "opackets", "imissed", "rx_nombuf",
    "integrity_errors",
)

CONFIG_FILENAME = "simlint.toml"
BASELINE_FILENAME = "simlint_baseline.json"


@dataclass
class SimlintConfig:
    paths: Tuple[str, ...] = DEFAULT_PATHS
    exclude: Tuple[str, ...] = DEFAULT_EXCLUDE
    # SL001: file globs where wall-clock reads are expected wholesale
    sl001_allow: Tuple[str, ...] = ()
    # SL004: int64 counter attribute names
    sl004_counters: Tuple[str, ...] = DEFAULT_INT64_COUNTERS
    baseline: str = BASELINE_FILENAME
    # directory config values resolve against (where the config file lives)
    root: str = "."


# -- minimal TOML-subset parsing ----------------------------------------------

_TABLE_RE = re.compile(r"^\[\s*([A-Za-z0-9_.\-]+)\s*\]\s*$")
_KEY_RE = re.compile(r"^([A-Za-z0-9_\-]+)\s*=\s*(.*)$")


def _strip_comment(line: str) -> str:
    """Drop a trailing ``#`` comment (quote-aware for double quotes)."""
    out = []
    in_str = False
    for ch in line:
        if ch == '"':
            in_str = not in_str
        elif ch == "#" and not in_str:
            break
        out.append(ch)
    return "".join(out).rstrip()


def _parse_value(raw: str) -> Any:
    raw = raw.strip()
    if raw.startswith("["):
        inner = raw[1:-1] if raw.endswith("]") else raw[1:]
        return [_parse_value(tok) for tok in _split_array(inner)]
    if raw.startswith('"') and raw.endswith('"') and len(raw) >= 2:
        return raw[1:-1]
    if raw in ("true", "false"):
        return raw == "true"
    try:
        return int(raw)
    except ValueError:
        return raw


def _split_array(inner: str) -> List[str]:
    toks, cur, in_str = [], [], False
    for ch in inner:
        if ch == '"':
            in_str = not in_str
            cur.append(ch)
        elif ch == "," and not in_str:
            toks.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    toks.append("".join(cur))
    return [t.strip() for t in toks if t.strip()]


def _parse_toml_subset(text: str) -> Dict[str, Dict[str, Any]]:
    tables: Dict[str, Dict[str, Any]] = {}
    current = tables.setdefault("", {})
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        line = _strip_comment(lines[i]).strip()
        i += 1
        if not line:
            continue
        m = _TABLE_RE.match(line)
        if m:
            current = tables.setdefault(m.group(1), {})
            continue
        m = _KEY_RE.match(line)
        if not m:
            raise ValueError(f"simlint.toml: cannot parse line: {line!r}")
        key, raw = m.group(1), m.group(2).strip()
        # multiline array: accumulate until brackets balance
        while raw.count("[") > raw.count("]") and i < len(lines):
            raw += " " + _strip_comment(lines[i]).strip()
            i += 1
        current[key] = _parse_value(raw)
    return tables


def _load_tables(path: str) -> Dict[str, Dict[str, Any]]:
    with open(path, "rb") as fh:
        data = fh.read()
    try:
        import tomllib
        doc = tomllib.loads(data.decode("utf-8"))
        # flatten nested tables into dotted names, one level of values each
        flat: Dict[str, Dict[str, Any]] = {}

        def walk(prefix: str, tbl: Dict[str, Any]) -> None:
            plain = {k: v for k, v in tbl.items() if not isinstance(v, dict)}
            if plain or prefix:
                flat.setdefault(prefix, {}).update(plain)
            for k, v in tbl.items():
                if isinstance(v, dict):
                    walk(f"{prefix}.{k}" if prefix else k, v)

        walk("", doc)
        return flat
    except ModuleNotFoundError:
        return _parse_toml_subset(data.decode("utf-8"))


def _table(tables: Dict[str, Dict[str, Any]], *names: str) -> Dict[str, Any]:
    for name in names:
        if name in tables:
            return tables[name]
    return {}


def _tup(value: Any, default: Tuple[str, ...]) -> Tuple[str, ...]:
    if value is None:
        return default
    return tuple(str(v) for v in value)


def find_config(start: str = ".") -> Optional[str]:
    """Walk up from ``start`` looking for ``simlint.toml``."""
    d = os.path.abspath(start)
    while True:
        cand = os.path.join(d, CONFIG_FILENAME)
        if os.path.isfile(cand):
            return cand
        parent = os.path.dirname(d)
        if parent == d:
            return None
        d = parent


def load_config(path: Optional[str] = None,
                start: str = ".") -> SimlintConfig:
    """Load config from ``path`` (or discover ``simlint.toml`` upward from
    ``start``); missing file → pure defaults rooted at ``start``."""
    if path is None:
        path = find_config(start)
    if path is None:
        return SimlintConfig(root=os.path.abspath(start))
    tables = _load_tables(path)
    top = _table(tables, "simlint", "tool.simlint")
    sl001 = _table(tables, "simlint.sl001", "tool.simlint.sl001")
    sl004 = _table(tables, "simlint.sl004", "tool.simlint.sl004")
    return SimlintConfig(
        paths=_tup(top.get("paths"), DEFAULT_PATHS),
        exclude=_tup(top.get("exclude"), DEFAULT_EXCLUDE),
        sl001_allow=_tup(sl001.get("allow"), ()),
        sl004_counters=_tup(sl004.get("counters"), DEFAULT_INT64_COUNTERS),
        baseline=str(top.get("baseline", BASELINE_FILENAME)),
        root=os.path.dirname(os.path.abspath(path)),
    )
