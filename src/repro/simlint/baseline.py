"""Baseline file support: let accepted pre-existing findings ride while new
violations gate.

A baseline entry is ``{"path", "rule", "text"}`` where ``text`` is the
stripped source line — content-addressed rather than line-numbered, so
unrelated edits above a baselined finding don't invalidate it.  Matching is
multiset-style: N baseline entries for one (path, rule, text) absorb at most
N findings.
"""
from __future__ import annotations

import json
import os
from collections import Counter
from typing import Dict, List, Sequence, Tuple

from .rules import Finding

Key = Tuple[str, str, str]


def _line_text(root: str, f: Finding,
               cache: Dict[str, List[str]]) -> str:
    if f.path not in cache:
        try:
            with open(os.path.join(root, f.path), encoding="utf-8") as fh:
                cache[f.path] = fh.read().splitlines()
        except OSError:
            cache[f.path] = []
    lines = cache[f.path]
    return lines[f.line - 1].strip() if 0 < f.line <= len(lines) else ""


def finding_key(root: str, f: Finding,
                cache: Dict[str, List[str]]) -> Key:
    return (f.path, f.rule, _line_text(root, f, cache))


def load_baseline(path: str) -> Counter:
    """Baseline file → Counter of (path, rule, text) keys.  Missing file ==
    empty baseline."""
    if not os.path.isfile(path):
        return Counter()
    with open(path, encoding="utf-8") as fh:
        entries = json.load(fh)
    return Counter((e["path"], e["rule"], e.get("text", ""))
                   for e in entries)


def split_new(findings: Sequence[Finding], baseline: Counter,
              root: str = ".") -> Tuple[List[Finding], List[Finding]]:
    """(new, baselined) partition of ``findings`` against the baseline."""
    budget = Counter(baseline)
    cache: Dict[str, List[str]] = {}
    new: List[Finding] = []
    old: List[Finding] = []
    for f in findings:
        key = finding_key(root, f, cache)
        if budget[key] > 0:
            budget[key] -= 1
            old.append(f)
        else:
            new.append(f)
    return new, old


def write_baseline(path: str, findings: Sequence[Finding],
                   root: str = ".") -> int:
    """Write the current findings as the new baseline; returns the count."""
    cache: Dict[str, List[str]] = {}
    entries = [{"path": f.path, "rule": f.rule,
                "text": _line_text(root, f, cache)}
               for f in sorted(findings,
                               key=lambda f: (f.path, f.line, f.rule))]
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(entries, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return len(entries)
