"""AST checkers for the SL001–SL007 determinism rules.

One parse per file feeds every rule.  Imports are resolved to dotted names
(``np.random.default_rng`` → ``numpy.random.default_rng``) so aliases cannot
dodge a rule, and suppression comments (``# simlint: disable=SL001 -- why``)
are honored per physical line.
"""
from __future__ import annotations

import ast
import fnmatch
import os
import re
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .config import SimlintConfig
from .rules import Finding

_DISABLE_RE = re.compile(r"#\s*simlint:\s*disable=([A-Za-z0-9,\s]+)")

WALL_CLOCK_CALLS = {
    "time.time", "time.time_ns",
    "time.perf_counter", "time.perf_counter_ns",
    "time.monotonic", "time.monotonic_ns",
    "time.process_time", "time.process_time_ns",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "datetime.date.today",
}

# numpy.random attributes that are explicit-seed constructors, not draws from
# the legacy global state
_SEEDED_NP_RANDOM = {
    "default_rng", "Generator", "SeedSequence", "BitGenerator",
    "PCG64", "PCG64DXSM", "MT19937", "Philox", "SFC64",
}

_SCHED_TOKENS = ("EventScheduler", "DomainScheduler")
_MP_MODULES = ("multiprocessing", "concurrent.futures")


def parse_suppressions(lines: Sequence[str]) -> Dict[int, Set[str]]:
    """line number (1-based) → set of suppressed rule ids ("ALL" == any)."""
    out: Dict[int, Set[str]] = {}
    for i, line in enumerate(lines, start=1):
        m = _DISABLE_RE.search(line)
        if m:
            ids = {tok.strip().upper() for tok in m.group(1).split(",")
                   if tok.strip()}
            if ids:
                out[i] = ids
    return out


class _ImportTable(ast.NodeVisitor):
    """local name → fully dotted origin, from import statements."""

    def __init__(self) -> None:
        self.alias: Dict[str, str] = {}
        self.modules: Set[str] = set()

    def visit_Import(self, node: ast.Import) -> None:
        for a in node.names:
            self.modules.add(a.name)
            self.alias[a.asname or a.name.split(".")[0]] = \
                a.name if a.asname else a.name.split(".")[0]

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module is None or node.level:
            return  # relative imports stay repo-internal
        self.modules.add(node.module)
        for a in node.names:
            self.alias[a.asname or a.name] = f"{node.module}.{a.name}"


def _dotted(node: ast.AST, alias: Dict[str, str]) -> Optional[str]:
    if isinstance(node, ast.Name):
        return alias.get(node.id, node.id)
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value, alias)
        return None if base is None else f"{base}.{node.attr}"
    return None


def _is_set_expr(node: ast.AST, alias: Dict[str, str]) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        return _dotted(node.func, alias) in ("set", "frozenset")
    return False


def _is_set_annotation(node: ast.AST, alias: Dict[str, str]) -> bool:
    target = node.value if isinstance(node, ast.Subscript) else node
    d = _dotted(target, alias)
    return d in ("set", "frozenset", "Set", "FrozenSet", "typing.Set",
                 "typing.FrozenSet")


def _is_floaty(node: ast.AST, alias: Dict[str, str]) -> bool:
    """Does this expression smell like it produces a Python float?"""
    if isinstance(node, ast.Constant):
        return isinstance(node.value, float)
    if isinstance(node, ast.BinOp):
        if isinstance(node.op, ast.Div):
            return True
        return _is_floaty(node.left, alias) or _is_floaty(node.right, alias)
    if isinstance(node, ast.UnaryOp):
        return _is_floaty(node.operand, alias)
    if isinstance(node, ast.Call):
        d = _dotted(node.func, alias)
        return d in ("float", "numpy.mean", "numpy.average", "numpy.std",
                     "numpy.var", "numpy.float64", "numpy.float32")
    if isinstance(node, ast.IfExp):
        return _is_floaty(node.body, alias) or _is_floaty(node.orelse, alias)
    return False


def _dataclass_frozen(node: ast.ClassDef,
                      alias: Dict[str, str]) -> Optional[bool]:
    """None == not a dataclass; else whether frozen=True is declared."""
    for dec in node.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        if _dotted(target, alias) in ("dataclass", "dataclasses.dataclass"):
            if isinstance(dec, ast.Call):
                for kw in dec.keywords:
                    if kw.arg == "frozen":
                        return (isinstance(kw.value, ast.Constant)
                                and kw.value.value is True)
            return False
    return None


def _class_fields(node: ast.ClassDef) -> List[Tuple[str, ast.AnnAssign]]:
    """Dataclass fields: class-level annotated names, minus ClassVar."""
    out = []
    for stmt in node.body:
        if isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target,
                                                          ast.Name):
            ann = ast.unparse(stmt.annotation)
            if "ClassVar" in ann:
                continue
            out.append((stmt.target.id, stmt))
    return out


def _find_method(node: ast.ClassDef, name: str) -> Optional[ast.FunctionDef]:
    for stmt in node.body:
        if isinstance(stmt, ast.FunctionDef) and stmt.name == name:
            return stmt
    return None


class _Checker:
    def __init__(self, path: str, text: str, tree: ast.Module,
                 cfg: SimlintConfig):
        self.path = path
        self.text = text
        self.cfg = cfg
        self.tree = tree
        self.findings: List[Finding] = []
        imports = _ImportTable()
        imports.visit(tree)
        self.alias = imports.alias
        self.sched_adjacent = any(tok in text for tok in _SCHED_TOKENS)
        self.is_mp = any(
            m == mod or m.startswith(mod + ".")
            for m in imports.modules for mod in _MP_MODULES)
        self.sl001_allowed = any(
            fnmatch.fnmatch(path, pat) for pat in cfg.sl001_allow)
        self.set_names: Set[str] = self._collect_set_names()

    def _add(self, node: ast.AST, rule: str, message: str) -> None:
        self.findings.append(Finding(
            path=self.path, line=getattr(node, "lineno", 1),
            col=getattr(node, "col_offset", 0) + 1, rule=rule,
            message=message))

    def _collect_set_names(self) -> Set[str]:
        """Names (incl. ``self.x``) bound to set expressions anywhere in the
        file — a deliberately scope-blind approximation."""
        names: Set[str] = set()
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Assign) and _is_set_expr(node.value,
                                                             self.alias):
                for tgt in node.targets:
                    d = _dotted(tgt, self.alias)
                    if d:
                        names.add(d)
            elif isinstance(node, ast.AnnAssign) and node.target is not None:
                if _is_set_annotation(node.annotation, self.alias) or (
                        node.value is not None
                        and _is_set_expr(node.value, self.alias)):
                    d = _dotted(node.target, self.alias)
                    if d:
                        names.add(d)
        return names

    def run(self) -> List[Finding]:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call):
                self._check_call(node)
            elif isinstance(node, ast.For):
                self._check_iter(node.iter)
            elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                   ast.GeneratorExp)):
                for gen in node.generators:
                    self._check_iter(gen.iter)
            elif isinstance(node, ast.AugAssign):
                self._check_augassign(node)
            elif isinstance(node, ast.ClassDef):
                self._check_classdef(node)
            elif isinstance(node, ast.Subscript) and self.is_mp:
                if _dotted(node.value, self.alias) == "os.environ":
                    self._add(node, "SL007",
                              "os.environ read in an mp-worker code path")
        return self.findings

    # -- SL001 / SL002 / SL007 (calls) ----------------------------------------
    def _check_call(self, node: ast.Call) -> None:
        d = _dotted(node.func, self.alias)
        if d is None:
            return
        if d in WALL_CLOCK_CALLS and not self.sl001_allowed:
            self._add(node, "SL001", f"wall-clock call {d}() in a sim path")
        elif d.startswith("numpy.random."):
            tail = d[len("numpy.random."):]
            if "." in tail:
                return  # method on e.g. numpy.random.default_rng(...)
            if tail == "default_rng":
                if not node.args:
                    self._add(node, "SL002",
                              "default_rng() without an explicit seed "
                              "draws OS entropy")
            elif tail not in _SEEDED_NP_RANDOM:
                self._add(node, "SL002",
                          f"global-state RNG call numpy.random.{tail}()")
        elif d.startswith("random.") and d.count(".") == 1:
            tail = d[len("random."):]
            if tail in ("Random", "SystemRandom"):
                if tail == "SystemRandom" or not node.args:
                    self._add(node, "SL002",
                              f"random.{tail}() without an explicit seed")
            else:
                self._add(node, "SL002",
                          f"global-state RNG call random.{tail}()")
        elif self.is_mp:
            if d == "os.getpid":
                self._add(node, "SL007",
                          "os.getpid() in an mp-worker code path")
            elif d == "os.environ.get":
                self._add(node, "SL007",
                          "os.environ read in an mp-worker code path")
            elif d == "id":
                self._add(node, "SL007",
                          "id()-derived key in an mp-worker code path is "
                          "address-dependent across processes")

    # -- SL003 -----------------------------------------------------------------
    def _check_iter(self, it: ast.AST) -> None:
        if not self.sched_adjacent:
            return
        if _is_set_expr(it, self.alias):
            self._add(it, "SL003",
                      "iteration over a set literal/constructor in "
                      "scheduler-adjacent code")
            return
        d = _dotted(it, self.alias)
        if d is not None and d in self.set_names:
            self._add(it, "SL003",
                      f"iteration over set-typed {d!r} in scheduler-"
                      "adjacent code")

    # -- SL004 -----------------------------------------------------------------
    def _check_augassign(self, node: ast.AugAssign) -> None:
        if not isinstance(node.op, ast.Add):
            return
        if not isinstance(node.target, ast.Attribute):
            return
        attr = node.target.attr
        if attr not in self.cfg.sl004_counters:
            return
        if _is_floaty(node.value, self.alias):
            self._add(node, "SL004",
                      f"float accumulation into int64 counter .{attr}")

    # -- SL005 / SL006 ---------------------------------------------------------
    def _check_classdef(self, node: ast.ClassDef) -> None:
        if not node.name.endswith("Config"):
            return
        frozen = _dataclass_frozen(node, self.alias)
        if frozen is None:
            return  # not a dataclass — out of scope
        fields = _class_fields(node)
        if not frozen:
            self._add(node, "SL005",
                      f"config dataclass {node.name} is not frozen=True")
        for name, stmt in fields:
            if stmt.value is not None and isinstance(
                    stmt.value, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                                 ast.DictComp, ast.SetComp)):
                self._add(stmt, "SL005",
                          f"mutable default on config field {name!r} "
                          "(use field(default_factory=...))")
        self._check_roundtrip(node, [n for n, _ in fields])

    def _check_roundtrip(self, node: ast.ClassDef,
                         fields: List[str]) -> None:
        to_dict = _find_method(node, "to_dict")
        from_dict = _find_method(node, "from_dict")
        if to_dict is None and from_dict is None:
            return
        if to_dict is None or from_dict is None:
            have, miss = (("to_dict", "from_dict") if from_dict is None
                          else ("from_dict", "to_dict"))
            self._add(node, "SL006",
                      f"{node.name} defines {have} without {miss} — the "
                      "round-trip cannot close")
            return
        fset = set(fields)
        keys = self._explicit_dict_keys(to_dict)
        if keys is not None:
            missing = sorted(fset - keys)
            extra = sorted(keys - fset)
            if missing:
                self._add(to_dict, "SL006",
                          f"{node.name}.to_dict omits field(s) "
                          f"{', '.join(missing)}")
            if extra:
                self._add(to_dict, "SL006",
                          f"{node.name}.to_dict emits non-field key(s) "
                          f"{', '.join(extra)}")
        kwargs = self._explicit_ctor_kwargs(node, from_dict)
        if kwargs is not None:
            missing = sorted(fset - kwargs)
            if missing:
                self._add(from_dict, "SL006",
                          f"{node.name}.from_dict never passes field(s) "
                          f"{', '.join(missing)}")

    @staticmethod
    def _explicit_dict_keys(fn: ast.FunctionDef) -> Optional[Set[str]]:
        """Keys of a returned dict literal, or None when to_dict is generic
        (returns a helper call / builds the dict dynamically)."""
        for stmt in ast.walk(fn):
            if isinstance(stmt, ast.Return) and isinstance(stmt.value,
                                                           ast.Dict):
                keys: Set[str] = set()
                for k in stmt.value.keys:
                    if k is None:  # **spread — dynamic, trust it
                        return None
                    if not (isinstance(k, ast.Constant)
                            and isinstance(k.value, str)):
                        return None
                    keys.add(k.value)
                return keys
        return None

    @staticmethod
    def _explicit_ctor_kwargs(node: ast.ClassDef,
                              fn: ast.FunctionDef) -> Optional[Set[str]]:
        """Keyword names of an all-explicit cls(...) construction, or None
        when from_dict forwards dynamically (cls(**d))."""
        for stmt in ast.walk(fn):
            if not isinstance(stmt, ast.Call):
                continue
            callee = stmt.func
            name = callee.id if isinstance(callee, ast.Name) else None
            if name not in ("cls", node.name):
                continue
            if any(kw.arg is None for kw in stmt.keywords):
                return None  # cls(**d)
            if stmt.args:
                return None  # positional — give it the benefit of the doubt
            return {kw.arg for kw in stmt.keywords}
        return None


def lint_source(path: str, text: str,
                cfg: Optional[SimlintConfig] = None) -> List[Finding]:
    """Lint one file's source; returns unsuppressed findings in line order."""
    cfg = cfg or SimlintConfig()
    try:
        tree = ast.parse(text, filename=path)
    except SyntaxError as exc:
        return [Finding(path=path, line=exc.lineno or 1, col=1, rule="SL000",
                        message=f"syntax error: {exc.msg}")]
    findings = _Checker(path, text, tree, cfg).run()
    suppressed = parse_suppressions(text.splitlines())
    out = []
    for f in findings:
        ids = suppressed.get(f.line)
        if ids is not None and (f.rule in ids or "ALL" in ids):
            continue
        out.append(f)
    out.sort(key=lambda f: (f.line, f.col, f.rule))
    return out


def _norm(path: str, root: str) -> str:
    rel = os.path.relpath(os.path.abspath(path), root)
    return rel.replace(os.sep, "/")


def collect_files(paths: Sequence[str], cfg: SimlintConfig) -> List[str]:
    """Expand files/directories into the sorted list of lintable .py files,
    honoring the config's exclude globs (paths relative to ``cfg.root``)."""
    out: Set[str] = set()
    for p in paths:
        if os.path.isfile(p):
            out.add(_norm(p, cfg.root))
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames.sort()
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    out.add(_norm(os.path.join(dirpath, fn), cfg.root))
    def excluded(rel: str) -> bool:
        return any(fnmatch.fnmatch(rel, pat) for pat in cfg.exclude)
    return sorted(rel for rel in out if not excluded(rel))


def lint_paths(paths: Sequence[str],
               cfg: Optional[SimlintConfig] = None) -> List[Finding]:
    """Lint every file under ``paths``; findings carry root-relative paths."""
    cfg = cfg or SimlintConfig()
    findings: List[Finding] = []
    for rel in collect_files(paths, cfg):
        full = os.path.join(cfg.root, rel)
        with open(full, "r", encoding="utf-8") as fh:
            text = fh.read()
        findings.extend(lint_source(rel, text, cfg))
    return findings
