"""``python -m repro.simlint`` — the gating entry point.

Exit status: 0 when every finding is suppressed inline or absorbed by the
baseline; 1 when any new finding remains (printed with file:line:col, rule
id, and a fix hint); 2 on usage errors.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional

from .baseline import load_baseline, split_new, write_baseline
from .checker import lint_paths
from .config import load_config
from .rules import RULES


def _list_rules() -> str:
    lines = ["simlint determinism rules:"]
    for r in RULES.values():
        lines.append(f"  {r.id}  {r.title}")
        lines.append(f"         fix: {r.hint}")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.simlint",
        description="AST-based determinism linter for the sim core "
                    "(rules SL001-SL007).")
    ap.add_argument("paths", nargs="*",
                    help="files/directories to lint (default: the "
                         "configured sim-path scope)")
    ap.add_argument("--config", default=None,
                    help="path to simlint.toml (default: discovered "
                         "upward from the cwd)")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON path (default: from config, "
                         "simlint_baseline.json next to simlint.toml)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline: report every finding")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept all current findings into the baseline "
                         "file and exit 0")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule table and exit")
    ap.add_argument("--no-hints", action="store_true",
                    help="one line per finding (no fix hints)")
    args = ap.parse_args(argv)

    if args.list_rules:
        print(_list_rules())
        return 0

    cfg = load_config(args.config)
    paths = args.paths or [os.path.join(cfg.root, p) for p in cfg.paths]
    findings = lint_paths(paths, cfg)

    baseline_path = args.baseline or os.path.join(cfg.root, cfg.baseline)
    if args.write_baseline:
        n = write_baseline(baseline_path, findings, root=cfg.root)
        print(f"simlint: wrote {n} finding(s) to {baseline_path}")
        return 0

    if args.no_baseline:
        new, old = list(findings), []
    else:
        new, old = split_new(findings, load_baseline(baseline_path),
                             root=cfg.root)

    for f in new:
        print(f.render(with_hint=not args.no_hints))
    if new:
        print(f"simlint: {len(new)} new finding(s)"
              + (f" ({len(old)} baselined)" if old else ""))
        return 1
    tail = f" ({len(old)} baselined)" if old else ""
    print(f"simlint: clean{tail}")
    return 0


if __name__ == "__main__":  # pragma: no cover - exercised via __main__
    sys.exit(main())
