"""simlint — the repo's AST-based determinism linter.

Every headline guarantee this reproduction makes (bit-identical RunReports
across the event/epoch engines and across shared-clock/partitioned/
partitioned-mp execution) rests on source-level discipline: no wall-clock
reads in sim paths, no unseeded or global-state RNG, no iteration over
unordered containers in scheduler-adjacent code, int64 counter accumulation,
and frozen configs that round-trip exactly.  ``simlint`` turns those
invariants from test-suite folklore into gating, named, suppressible rules:

==== =======================================================================
id   what it catches
==== =======================================================================
SL001 wall-clock call (``time.time``/``perf_counter``/``monotonic``/
      ``datetime.now``) outside the wall-mode allowlist
SL002 RNG without an explicit seed/Generator (bare ``np.random.*``,
      ``random.*``, unseeded ``default_rng()``)
SL003 iteration over a ``set`` in files that touch
      ``EventScheduler``/``DomainScheduler`` (unordered → nondeterministic
      event order)
SL004 float accumulation into counters the telemetry layer declares int64
SL005 mutable default or missing ``frozen=True`` on a config dataclass
SL006 ``to_dict``/``from_dict`` field-coverage mismatch on a config
      dataclass
SL007 ``os.environ``/``os.getpid``/``id()``-keyed ordering inside
      mp-worker code paths
==== =======================================================================

Run it as ``python -m repro.simlint [paths...]``; configuration lives in
``simlint.toml`` (or a ``[tool.simlint]`` table), suppressions are inline
``# simlint: disable=SL00N -- reason`` comments, and ``simlint_baseline.json``
lets pre-existing accepted findings ride while new violations gate CI.
"""
from .baseline import load_baseline, split_new, write_baseline
from .checker import collect_files, lint_paths, lint_source
from .cli import main
from .config import SimlintConfig, load_config
from .rules import Finding, Rule, RULES

__all__ = [
    "Finding", "Rule", "RULES", "SimlintConfig", "load_config",
    "collect_files", "lint_paths", "lint_source",
    "load_baseline", "split_new", "write_baseline", "main",
]
