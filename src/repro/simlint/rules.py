"""Rule registry + the Finding record every checker emits.

A rule is pure metadata (id, title, one-line fix hint); the detection logic
lives in :mod:`repro.simlint.checker`.  Keeping the registry declarative
means ``--list-rules``, the docs table, and the per-finding hint all render
from one source of truth.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict


@dataclass(frozen=True)
class Rule:
    id: str
    title: str
    hint: str


RULES: Dict[str, Rule] = {r.id: r for r in (
    Rule("SL001",
         "wall-clock call in a sim path",
         "read virtual time from the SimClock; intentionally wall-clock "
         "code (wall-mode pacing, bench timing) gets "
         "`# simlint: disable=SL001 -- <why>`"),
    Rule("SL002",
         "RNG without an explicit seed",
         "use np.random.default_rng(seed) with a seed derived via "
         "repro.exp.seeding; never the global numpy/stdlib RNG state"),
    Rule("SL003",
         "iteration over an unordered set in scheduler-adjacent code",
         "iterate sorted(...) or an insertion-ordered dict/list so event "
         "order cannot depend on hash seeds"),
    Rule("SL004",
         "float accumulation into an int64 telemetry counter",
         "accumulate integers and convert once at the boundary "
         "(int(round(x))); float += drifts across platforms"),
    Rule("SL005",
         "config dataclass not frozen / mutable default",
         "declare @dataclass(frozen=True) and use "
         "field(default_factory=...) for container defaults"),
    Rule("SL006",
         "to_dict/from_dict field-coverage mismatch",
         "cover every dataclass field in the round-trip body, or use the "
         "generic _config_to_dict(self) / cls(**d) forms"),
    Rule("SL007",
         "process-identity-dependent value in an mp-worker path",
         "key by domain/trial index, not pid, id(), or environment reads "
         "that can differ across workers"),
)}


@dataclass
class Finding:
    """One violation: where, which rule, and what exactly."""

    path: str
    line: int
    col: int
    rule: str
    message: str

    @property
    def hint(self) -> str:
        return RULES[self.rule].hint

    def render(self, with_hint: bool = True) -> str:
        head = f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"
        if not with_hint:
            return head
        return f"{head}\n    hint: {self.hint}"
