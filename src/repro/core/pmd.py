"""Polling-mode driver (PMD) engine — the DPDK analogue.

Implements the two DPDK execution models from the paper (§2):

* **Run-to-completion**: "(1) retrieve RX packets through polling mode driver
  (PMD) RX API, (2) process packets on the same logical core, (3) send pending
  packets through PMD TX API."  → :meth:`BypassL2FwdServer.poll_once`.
* **Pipeline**: "lets cores pass packets between each other via a ring buffer"
  → :class:`PipelineServer` (stages linked by SPSC rings, one thread each).

Zero-copy discipline: a packet never leaves its arena slot between RX and TX —
processing operates on numpy views, and TX posts the same slot the NIC DMA'd
into.  Compare :mod:`repro.core.kernel_stack`, which copies twice and allocates
per packet.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from .descriptor import RxDescriptorRing, TxDescriptorRing
from .packet import PacketPool, swap_macs, swap_macs_vec
from .rings import SpscRing

ProcessFn = Callable[[np.ndarray], None]  # in-place packet transform
# in-place burst transform over (pool, slots, lengths)
BurstProcessFn = Callable[[PacketPool, np.ndarray, np.ndarray], None]


@dataclass
class Port:
    """One NIC port: RX + TX descriptor rings over a shared packet pool."""

    rx: RxDescriptorRing
    tx: TxDescriptorRing
    pool: PacketPool

    @staticmethod
    def make(
        pool: PacketPool,
        ring_size: int = 256,
        writeback_threshold: Optional[int] = 32,
    ) -> "Port":
        return Port(
            rx=RxDescriptorRing(ring_size, writeback_threshold=writeback_threshold),
            tx=TxDescriptorRing(ring_size),
            pool=pool,
        )


@dataclass
class ServerStats:
    rx_packets: int = 0
    tx_packets: int = 0
    rx_bytes: int = 0
    poll_iterations: int = 0
    empty_polls: int = 0
    burst_histogram: List[int] = field(default_factory=list)

    @property
    def avg_burst(self) -> float:
        return float(np.mean(self.burst_histogram)) if self.burst_histogram else 0.0


class BypassL2FwdServer:
    """Run-to-completion DPDK L2Fwd over N ports (the paper's workload).

    Each ``poll_once`` is one lcore loop iteration: rx_burst → process in place
    → tx_burst, per port.  ``burst_size`` is the DPDK burst knob that the DCA
    use-case (paper §5.2) sweeps.
    """

    def __init__(
        self,
        ports: Sequence[Port],
        burst_size: int = 32,
        process_fn: Optional[ProcessFn] = None,
        burst_process_fn: Optional[BurstProcessFn] = None,
    ):
        if burst_size <= 0:
            raise ValueError("burst_size must be positive")
        if process_fn is not None and burst_process_fn is not None:
            raise ValueError("pass either process_fn or burst_process_fn, not both")
        self.ports = list(ports)
        self.burst_size = burst_size
        self.process_fn = process_fn
        # default: vectorized L2Fwd header rewrite over the whole burst
        self.burst_process_fn = burst_process_fn if burst_process_fn is not None else (
            None if process_fn is not None else swap_macs_vec
        )
        self.stats = ServerStats()

    def poll_once(self) -> int:
        """One polling iteration across all ports. Returns packets forwarded."""
        total = 0
        for port in self.ports:
            slots, lengths = port.rx.poll_burst(self.burst_size)
            self.stats.poll_iterations += 1
            n = len(slots)
            if n == 0:
                self.stats.empty_polls += 1
                continue
            self.stats.burst_histogram.append(n)
            if self.burst_process_fn is not None:
                self.burst_process_fn(port.pool, slots, lengths)  # zero copy, amortized
            else:
                for slot, length in zip(slots, lengths):
                    self.process_fn(port.pool.view(int(slot), int(length)))
            posted = port.tx.post_burst_vec(slots, lengths)
            if posted < n:
                port.pool.free_burst([int(s) for s in slots[posted:]])  # TX full: drop
            self.stats.rx_packets += n
            self.stats.rx_bytes += int(lengths.sum())
            total += n
        self.stats.tx_packets = sum(p.tx.posted for p in self.ports)
        return total


class PipelineServer:
    """DPDK pipeline mode: RX core → worker core(s) → TX core, linked by rings.

    Threaded; demonstrates the mode on real rings.  On a 1-core host the GIL
    serializes the stages, so use run-to-completion for bandwidth numbers.
    """

    def __init__(
        self,
        port: Port,
        process_fn: Optional[ProcessFn] = None,
        stage_ring_capacity: int = 1024,
        burst_size: int = 32,
    ):
        self.port = port
        self.burst_size = burst_size
        self.process_fn = process_fn if process_fn is not None else swap_macs
        self.rx_to_work = SpscRing(stage_ring_capacity)
        self.work_to_tx = SpscRing(stage_ring_capacity)
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        self.stats = ServerStats()

    # each stage is a polling loop — no blocking anywhere
    def _rx_stage(self) -> None:
        while not self._stop.is_set():
            batch = self.port.rx.poll(self.burst_size)
            if batch:
                pushed = self.rx_to_work.push_burst(batch)
                for slot, _len in batch[pushed:]:
                    self.port.pool.free(slot)  # stage ring full → drop
            else:
                self.stats.empty_polls += 1

    def _work_stage(self) -> None:
        while not self._stop.is_set():
            batch = self.rx_to_work.pop_burst(self.burst_size)
            for slot, length in batch:
                self.process_fn(self.port.pool.view(slot, length))
                self.stats.rx_packets += 1
                self.stats.rx_bytes += length
            if batch:
                self.work_to_tx.push_burst(batch)

    def _tx_stage(self) -> None:
        while not self._stop.is_set():
            batch = self.work_to_tx.pop_burst(self.burst_size)
            for slot, length in batch:
                if not self.port.tx.post(slot, length):
                    self.port.pool.free(slot)

    def start(self) -> None:
        self._stop.clear()
        self._threads = [
            threading.Thread(target=fn, daemon=True, name=name)
            for fn, name in [
                (self._rx_stage, "pmd-rx"),
                (self._work_stage, "pmd-work"),
                (self._tx_stage, "pmd-tx"),
            ]
        ]
        for t in self._threads:
            t.start()

    def stop(self) -> None:
        self._stop.set()
        for t in self._threads:
            t.join(timeout=5)
        self._threads = []
