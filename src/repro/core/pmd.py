"""Polling-mode driver (PMD) engine — the DPDK analogue.

Implements the two DPDK execution models from the paper (§2), both on the
unified :class:`~repro.core.netstack.NetworkStack` lcore machinery:

* **Run-to-completion**: "(1) retrieve RX packets through polling mode driver
  (PMD) RX API, (2) process packets on the same logical core, (3) send pending
  packets through PMD TX API."  → :class:`BypassL2FwdServer`, one lcore per
  (port, queue) pair by default.
* **Pipeline**: "lets cores pass packets between each other via a ring buffer"
  → :class:`PipelineServer` (rx/work/tx stage lcores linked by SPSC rings;
  sequential ``poll_once`` or optional threads).

The NIC model is multi-queue: a :class:`Port` owns ``n_queues`` RX/TX
descriptor-ring pairs over the shared :class:`~repro.core.packet.PacketPool`,
and received frames are steered to a queue by Toeplitz RSS over the flow
fields in the frame header (:mod:`repro.core.rss`) — the mechanism that makes
bandwidth scale with cores in the paper's Fig. 3(a).

Zero-copy discipline: a packet never leaves its arena slot between RX and TX —
processing operates on numpy views, and TX posts the same slot the NIC DMA'd
into.  Compare :mod:`repro.core.kernel_stack`, which copies twice and allocates
per packet.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from .descriptor import RxDescriptorRing, TxDescriptorRing
from .netstack import Lcore, NetworkStack, ServerStats
from .packet import (PacketPool, read_flow_bytes, read_flow_bytes_vec,
                     swap_macs, swap_macs_vec)
from .rings import SpscRing
from .rss import RssIndirection

ProcessFn = Callable[[np.ndarray], None]  # in-place packet transform
# in-place burst transform over (pool, slots, lengths)
BurstProcessFn = Callable[[PacketPool, np.ndarray, np.ndarray], None]

_EMPTY_I64 = np.empty(0, dtype=np.int64)
_EMPTY_I32 = np.empty(0, dtype=np.int32)


class Port:
    """One NIC port: ``n_queues`` RX/TX descriptor-ring pairs + RSS steering
    over a shared packet pool.

    .. deprecated:: the public device API is :class:`repro.core.ethdev.EthDev`
       (the ``rte_ethdev``-faithful facade, which owns a ``Port`` as its
       internal engine).  Direct ``Port``/``Port.make`` construction remains
       supported for existing code and tests, but new scenarios should go
       through ``EthDev`` / ``repro.exp.ExperimentConfig``.
    """

    def __init__(
        self,
        pool: PacketPool,
        rx_queues: Sequence[RxDescriptorRing],
        tx_queues: Sequence[TxDescriptorRing],
        rss: Optional[RssIndirection] = None,
        link_gbps: float = 0.0,
        link_latency_ns: int = 0,
    ):
        if not rx_queues or len(rx_queues) != len(tx_queues):
            raise ValueError("need equal, nonzero RX and TX queue counts")
        if link_latency_ns < 0:
            raise ValueError("link_latency_ns must be >= 0")
        self.pool = pool
        self.rx_queues = list(rx_queues)
        self.tx_queues = list(tx_queues)
        self.rss = rss if rss is not None else RssIndirection(len(self.rx_queues))
        # wire parameters consumed by the virtual-time load generator:
        # serialization runs at link_gbps (<= 0 == ideal wire) and every frame
        # pays link_latency_ns of propagation each way
        self.link_gbps = float(link_gbps)
        self.link_latency_ns = int(link_latency_ns)

    @staticmethod
    def make(
        pool: PacketPool,
        ring_size: int = 256,
        writeback_threshold: Optional[int] = 32,
        n_queues: int = 1,
        rss: Optional[RssIndirection] = None,
        link_gbps: float = 0.0,
        link_latency_ns: int = 0,
    ) -> "Port":
        return Port(
            pool,
            rx_queues=[
                RxDescriptorRing(ring_size, writeback_threshold=writeback_threshold,
                                 queue_id=q)
                for q in range(n_queues)
            ],
            tx_queues=[TxDescriptorRing(ring_size, queue_id=q)
                       for q in range(n_queues)],
            rss=rss,
            link_gbps=link_gbps,
            link_latency_ns=link_latency_ns,
        )

    @property
    def n_queues(self) -> int:
        return len(self.rx_queues)

    # -- burst dataplane (the rte_ethdev contract; EthDev delegates here) ----
    def rx_burst(self, queue_id: int, nb_pkts: int) -> Tuple[np.ndarray, np.ndarray]:
        """``rte_eth_rx_burst`` semantics: harvest up to ``nb_pkts`` completed
        descriptors from one RX queue → (slots, lengths), zero copy."""
        return self.rx_queues[queue_id].poll_burst(nb_pkts)

    def tx_burst(self, queue_id: int, slots: np.ndarray,
                 lengths: np.ndarray) -> int:
        """``rte_eth_tx_burst`` semantics: post a burst on one TX queue;
        returns the number accepted (the rest is the caller's to free)."""
        return self.tx_queues[queue_id].post_burst_vec(slots, lengths)

    # -- legacy single-queue views (the seed-era API; queue 0) ---------------
    @property
    def rx(self) -> RxDescriptorRing:
        return self.rx_queues[0]

    @property
    def tx(self) -> TxDescriptorRing:
        return self.tx_queues[0]

    # -- NIC-side delivery (the RSS steering point) --------------------------
    def deliver(self, packet_slot: int, length: int) -> bool:
        """Steer one received frame to its RSS queue.  On ring overflow the
        frame is dropped at the NIC and its buffer recycled; returns False."""
        if self.n_queues == 1:
            q = 0
        else:
            # scalar path: a zero-copy flow-bytes view + table-lookup hash —
            # no per-frame numpy temporaries
            q = self.rss.steer_one(read_flow_bytes(self.pool, packet_slot))
        if not self.rx_queues[q].nic_deliver(packet_slot, length):
            self.pool.free(packet_slot)
            return False
        return True

    def deliver_burst(self, packet_slots: np.ndarray, lengths: np.ndarray) -> int:
        """RSS-steered burst delivery: one hash + one indirection lookup for
        the whole burst, then one ``nic_deliver_burst`` per touched queue.
        Dropped frames (per-queue ring overflow) are freed back to the pool.
        Returns the number accepted."""
        n = len(packet_slots)
        if n == 0:
            return 0
        if self.n_queues == 1:
            ring = self.rx_queues[0]
            accepted = ring.nic_deliver_burst(packet_slots, lengths)
            if accepted < n:
                self.pool.free_burst([int(s) for s in packet_slots[accepted:]])
            return accepted
        queues = self.rss.steer(read_flow_bytes_vec(self.pool, packet_slots))
        accepted = 0
        for q in range(self.n_queues):
            mask = queues == q
            if not mask.any():
                continue
            qslots = packet_slots[mask]
            qlens = lengths[mask]
            take = self.rx_queues[q].nic_deliver_burst(qslots, qlens)
            accepted += take
            if take < len(qslots):
                self.pool.free_burst([int(s) for s in qslots[take:]])
        return accepted

    def flush_rx(self) -> None:
        """Timeout-driven descriptor-cache writeback, all queues."""
        for ring in self.rx_queues:
            ring.flush()

    # -- wire-side TX drain (the loadgen pulls from every queue) -------------
    def drain_tx(self, max_n_per_queue: int) -> List[Tuple[int, int]]:
        out: List[Tuple[int, int]] = []
        for ring in self.tx_queues:
            out.extend(ring.drain(max_n_per_queue))
        return out

    def drain_tx_bursts(self, max_n_per_queue: int) -> Tuple[np.ndarray, np.ndarray]:
        """Vectorized drain across all TX queues → concatenated arrays."""
        slots_parts: List[np.ndarray] = []
        len_parts: List[np.ndarray] = []
        for ring in self.tx_queues:
            s, l = ring.drain_burst(max_n_per_queue)
            if len(s):
                slots_parts.append(s)
                len_parts.append(l)
        if not slots_parts:
            return _EMPTY_I64, _EMPTY_I32
        return np.concatenate(slots_parts), np.concatenate(len_parts)

    # -- aggregates / telemetry ----------------------------------------------
    @property
    def tx_pending(self) -> int:
        return sum(r.pending for r in self.tx_queues)

    @property
    def tx_posted(self) -> int:
        return sum(r.posted for r in self.tx_queues)

    @property
    def rx_delivered(self) -> int:
        return sum(r.delivered for r in self.rx_queues)

    @property
    def rx_dropped(self) -> int:
        return sum(r.dropped for r in self.rx_queues)

    def rx_queue_delivered(self) -> List[int]:
        return [r.delivered for r in self.rx_queues]

    def rx_queue_dropped(self) -> List[int]:
        return [r.dropped for r in self.rx_queues]

    def queue_occupancy(self) -> List[int]:
        """Per-RX-queue descriptor occupancy (the RSS-skew observable)."""
        return [r.in_flight for r in self.rx_queues]


class BypassL2FwdServer(NetworkStack):
    """Run-to-completion DPDK L2Fwd over N multi-queue ports.

    Each lcore quantum on a (port, queue) pair is one DPDK loop iteration:
    rx_burst → process in place → tx_burst on the same queue.  ``burst_size``
    is the DPDK burst knob the DCA use-case (paper §5.2) sweeps — pass a
    :class:`~repro.core.dca.BurstPlan` for per-lcore bursts.  ``n_lcores``
    defaults to one lcore per (port, queue) pair.

    **DCA accumulate mode** (:meth:`enable_dca_accumulate`, virtual time
    only): the paper's Fig. 4(b) variant "waits until [burst] packets are
    received and then starts the forwarding".  A queue whose written-back
    backlog is below the lcore's burst is left to accumulate; the wait is
    bounded by a give-up deadline (``wait_timeout_ns`` past the first
    observation of a partial backlog, surfaced to the event loop through
    ``next_free_ns``), so tail packets are forwarded even when the offered
    train ends mid-burst.  This is what makes the burst-size knob move
    measured end-to-end RTT percentiles instead of only queue-occupancy
    proxies.
    """

    def __init__(
        self,
        ports: Sequence[Port],
        burst_size: int = 32,
        process_fn: Optional[ProcessFn] = None,
        burst_process_fn: Optional[BurstProcessFn] = None,
        n_lcores: Optional[int] = None,
        plan: Optional[object] = None,
    ):
        if burst_size <= 0:
            raise ValueError("burst_size must be positive")
        if process_fn is not None and burst_process_fn is not None:
            raise ValueError("pass either process_fn or burst_process_fn, not both")
        super().__init__(ports, n_lcores=n_lcores, burst_size=burst_size, plan=plan)
        self.burst_size = burst_size
        self.process_fn = process_fn
        # default: vectorized L2Fwd header rewrite over the whole burst
        self.burst_process_fn = burst_process_fn if burst_process_fn is not None else (
            None if process_fn is not None else swap_macs_vec
        )

    def _service_queue(self, lcore: Lcore, port_idx: int, queue_idx: int,
                       qstats: ServerStats) -> int:
        port = self.ports[port_idx]
        if self._dca_wait_ns is not None and self.clock is not None:
            ring = port.rx_queues[queue_idx]
            avail = ring.done_count
            key = (port_idx, queue_idx)
            if avail == 0:
                qstats.poll_iterations += 1
                qstats.empty_polls += 1
                self._queue_deadline.pop(key, None)
                return 0
            if self._dca_accumulate_wait(key, avail, lcore.burst_size):
                qstats.poll_iterations += 1
                return 0
        # the DPDK loop iteration, verbatim: rx_burst → process → tx_burst
        slots, lengths = port.rx_burst(queue_idx, lcore.burst_size)
        qstats.poll_iterations += 1
        n = len(slots)
        if n == 0:
            qstats.empty_polls += 1
            return 0
        qstats.record_burst(n)
        if self.burst_process_fn is not None:
            self.burst_process_fn(port.pool, slots, lengths)  # zero copy, amortized
        else:
            for slot, length in zip(slots, lengths):
                self.process_fn(port.pool.view(int(slot), int(length)))
        posted = port.tx_burst(queue_idx, slots, lengths)
        if posted < n:
            port.pool.free_burst([int(s) for s in slots[posted:]])  # TX full: drop
        qstats.rx_packets += n
        qstats.rx_bytes += int(lengths.sum())
        qstats.tx_packets += posted
        if self.clock is not None:
            # virtual-time mode: real code no longer sets the pace, so the
            # PMD loop's work is charged explicitly (empty polls are free —
            # a spinning PMD would otherwise never let simulated time end)
            self.charge_ns(self.sim_cost.pmd_burst_ns(n))
        return n


class PipelineServer(NetworkStack):
    """DPDK pipeline mode: RX lcore → worker lcore → TX lcore, linked by rings.

    The three stages are stage-lcores on the NetworkStack scheduler: a
    sequential ``poll_once`` runs rx → work → tx deterministically (the
    1-core measurement mode), while ``start()`` runs each stage in its own
    thread (GIL-serialized on a 1-core host; see DESIGN.md).  Multi-queue
    aware: the RX stage polls every RX queue and frames return on the TX
    queue they arrived on.
    """

    _RX, _WORK, _TX = 0, 1, 2

    def __init__(
        self,
        port: Port,
        process_fn: Optional[ProcessFn] = None,
        stage_ring_capacity: int = 1024,
        burst_size: int = 32,
    ):
        super().__init__([port], n_lcores=1, burst_size=burst_size)
        # stage lcores replace the default queue-parallel layout
        all_queues = [(0, qi) for qi in range(port.n_queues)]
        self.lcores = [Lcore(self._RX, all_queues, burst_size),
                       Lcore(self._WORK, all_queues, burst_size),
                       Lcore(self._TX, all_queues, burst_size)]
        self.port = port
        self.burst_size = burst_size
        self.process_fn = process_fn if process_fn is not None else swap_macs
        self.rx_to_work = SpscRing(stage_ring_capacity)
        self.work_to_tx = SpscRing(stage_ring_capacity)

    # each stage is a polling pass — no blocking anywhere
    def run_lcore(self, lcore: Lcore) -> int:
        if lcore.lcore_id == self._RX:
            return self._rx_pass(lcore.burst_size)
        if lcore.lcore_id == self._WORK:
            return self._work_pass(lcore.burst_size)
        return self._tx_pass(lcore.burst_size)

    def _rx_pass(self, burst: int) -> int:
        # DCA accumulate-then-forward parity with the bypass stack (virtual
        # time only): a queue whose written-back backlog is below the RX
        # stage's burst is left to accumulate, bounded by the give-up
        # deadline, before the stage pushes anything downstream.
        accumulate = self._dca_wait_ns is not None and self.clock is not None
        for qi, ring in enumerate(self.port.rx_queues):
            qstats = self.queue_stats[(0, qi)]
            if accumulate:
                avail = ring.done_count
                key = (0, qi)
                if avail == 0:
                    qstats.poll_iterations += 1
                    qstats.empty_polls += 1
                    self._queue_deadline.pop(key, None)
                    continue
                if self._dca_accumulate_wait(key, avail, burst):
                    qstats.poll_iterations += 1
                    continue
            batch = ring.poll(burst)
            qstats.poll_iterations += 1
            if not batch:
                qstats.empty_polls += 1
                continue
            qstats.record_burst(len(batch))
            items = [(slot, length, qi) for slot, length in batch]
            pushed = self.rx_to_work.push_burst(items)
            for slot, _len, _q in items[pushed:]:
                self.port.pool.free(slot)  # stage ring full → drop
        return 0

    def _work_pass(self, burst: int) -> int:
        batch = self.rx_to_work.pop_burst(burst)
        for slot, length, qi in batch:
            self.process_fn(self.port.pool.view(slot, length))
            qstats = self.queue_stats[(0, qi)]
            qstats.rx_packets += 1
            qstats.rx_bytes += length
        if batch:
            pushed = self.work_to_tx.push_burst(batch)
            for slot, _len, _q in batch[pushed:]:
                self.port.pool.free(slot)  # stage ring full → drop
            if self.clock is not None:
                # the worker stage carries the per-packet processing cost;
                # rx/tx stages are descriptor shuffling (folded into it)
                self.charge_ns(self.sim_cost.pmd_burst_ns(len(batch)))
        return len(batch)

    def _tx_pass(self, burst: int) -> int:
        batch = self.work_to_tx.pop_burst(burst)
        for slot, length, qi in batch:
            if self.port.tx_queues[qi].post(slot, length):
                self.queue_stats[(0, qi)].tx_packets += 1
            else:
                self.port.pool.free(slot)
        return 0

    # seed-era thread API, now on the shared lcore-thread machinery
    def start(self) -> None:
        self.start_lcore_threads()

    def stop(self) -> None:
        self.stop_lcore_threads()
