"""Host-cost model for the parts of the gem5 timing model this container
cannot execute natively.

The copies, allocations, ring operations and packet processing in this
framework are REAL (measured wall-clock on the host CPU).  What a CPU-only
container cannot reproduce natively is gem5's *microarchitectural timing* of
kernel-only events: interrupt entry/exit, context switches, syscall crossings.
Following the paper's own methodology (gem5 is itself a timing model), those
are modeled explicitly as calibrated busy-wait costs expressed in CPU cycles at
a configurable core frequency — which is exactly the knob the paper's Fig. 3(b)
sensitivity study turns (2 GHz → 3 GHz).

The polling-mode (DPDK) path uses none of these costs: its overheads are all
real code.  That asymmetry is the paper's point.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, replace


@dataclass(frozen=True)
class HostCostModel:
    """Cycle costs are rough Linux x86 figures; freq scales them (Fig. 3b)."""

    cpu_ghz: float = 2.0
    interrupt_cycles: int = 8000      # hardirq entry + softirq (NET_RX) schedule
    syscall_cycles: int = 1400        # read()/sendto() user<->kernel crossing
    per_packet_kernel_cycles: int = 2500  # skb setup, protocol demux, socket queue

    def ns(self, cycles: int) -> float:
        return cycles / self.cpu_ghz  # cycles / (GHz) == ns

    def with_freq(self, cpu_ghz: float) -> "HostCostModel":
        return replace(self, cpu_ghz=cpu_ghz)


def spin_ns(duration_ns: float) -> None:
    """Calibrated busy-wait (a model 'cost'), burning real host CPU."""
    if duration_ns <= 0:
        return
    deadline = time.perf_counter_ns() + int(duration_ns)
    while time.perf_counter_ns() < deadline:
        pass


ZERO_COST = HostCostModel(cpu_ghz=2.0, interrupt_cycles=0, syscall_cycles=0,
                          per_packet_kernel_cycles=0)
