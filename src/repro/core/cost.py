"""Host-cost model for the parts of the gem5 timing model this container
cannot execute natively.

Two execution modes share this model:

* **Wall-clock mode** (the seed behaviour, kept for host-overhead studies):
  the copies, allocations, ring operations and packet processing are REAL
  (measured wall-clock on the host CPU), and the kernel-only events gem5
  would time microarchitecturally — interrupt entry/exit, context switches,
  syscall crossings — are modeled as calibrated :func:`spin_ns` busy-waits.

* **Virtual-time mode** (the default since the SimClock refactor): *no* cost
  burns host CPU.  The same cycle figures are charged to the serving lcore's
  virtual busy-time instead (see
  :meth:`repro.core.netstack.NetworkStack.charge_ns`), which is exactly how
  gem5 itself accounts time.  Because real host execution no longer sets the
  pace, the polling-mode (DPDK) path also needs an explicit per-packet cost
  in this mode — ``pmd_poll_cycles``/``pmd_per_packet_cycles`` below,
  calibrated so the bypass:kernel MSB ratio lands in the paper's Fig. 3(a)
  regime (~5-6x at one port).

The frequency knob (``cpu_ghz``) scales every cycle figure — the exact knob
the paper's Fig. 3(b) sensitivity study turns (2 GHz → 3 GHz).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, replace


@dataclass(frozen=True)
class HostCostModel:
    """Cycle costs are rough Linux x86 figures; freq scales them (Fig. 3b)."""

    cpu_ghz: float = 2.0
    interrupt_cycles: int = 8000      # hardirq entry + softirq (NET_RX) schedule
    syscall_cycles: int = 1400        # read()/sendto() user<->kernel crossing
    per_packet_kernel_cycles: int = 2500  # skb setup, protocol demux, socket queue
    # polling-path costs, charged ONLY in virtual-time mode (in wall-clock
    # mode the PMD's real code is its own cost — the paper's asymmetry):
    pmd_poll_cycles: int = 150        # one non-empty rx_burst/tx_burst pass
    pmd_per_packet_cycles: int = 1100  # L2Fwd header rewrite + descriptor work

    def ns(self, cycles: int) -> float:
        return cycles / self.cpu_ghz  # cycles / (GHz) == ns

    def with_freq(self, cpu_ghz: float) -> "HostCostModel":
        return replace(self, cpu_ghz=cpu_ghz)

    def pmd_burst_ns(self, n_packets: int) -> float:
        """Virtual-time cost of one PMD loop iteration forwarding n packets."""
        if n_packets <= 0:
            return 0.0
        return self.ns(self.pmd_poll_cycles + n_packets * self.pmd_per_packet_cycles)


def spin_ns(duration_ns: float) -> None:
    """Calibrated busy-wait (a model 'cost'), burning real host CPU.

    Wall-clock mode only; virtual-time mode charges the same duration to the
    serving lcore's SimClock busy-time instead.
    """
    if duration_ns <= 0:
        return
    deadline = time.perf_counter_ns() + int(duration_ns)  # simlint: disable=SL001 -- wall-mode host-cost spin
    while time.perf_counter_ns() < deadline:  # simlint: disable=SL001 -- wall-mode host-cost spin
        pass


ZERO_COST = HostCostModel(cpu_ghz=2.0, interrupt_cycles=0, syscall_cycles=0,
                          per_packet_kernel_cycles=0, pmd_poll_cycles=0,
                          pmd_per_packet_cycles=0)
