"""Epoch-batched fast path for the open-loop virtual-time simulation.

:meth:`repro.core.loadgen.LoadGen.run_sim` advances the sim event by event —
every emission, wire hop, RSS steer, descriptor writeback, harvest, and TX
drain is a Python-level round, which caps throughput near ~1e5 simulated
packets/s.  This engine advances the same run one *epoch* at a time
(SimBricks-style: the epoch length is never below the minimum link latency,
scaled up so each pass covers ~64k packets) and processes each epoch's slice
of the analytic emission schedule as whole-array passes
(:mod:`repro.kernels.epoch_fastpath`):

* **emission → arrival**: the FIFO wire recursion closed into one
  cumsum + cummax pass per port (bit-identical to per-frame
  :meth:`~repro.core.simclock.Wire.transmit` calls);
* **steer**: RSS queue choice as a gather through a per-flow-id queue table
  (the loadgen's synthetic flow tuples cycle mod ``n_flows``, so the
  Toeplitz hash + indirection lookup is hoisted out of the per-packet path);
* **writeback**: with no ring-full event, descriptor publishes are
  poll-independent — the k-th writeback of a queue happens exactly when its
  ``k*W``-th frame arrives (threshold ``W``), so publish times are a strided
  slice of the arrival array;
* **harvest/charge**: each lcore's service history is a short burst-level
  cascade — ``t = max(lcore_free, earliest publish)``, harvest
  ``min(burst, backlog)`` per assigned queue in order, accumulate
  ``pmd_burst_ns`` in Python floats exactly like
  :meth:`~repro.core.netstack.NetworkStack.poll_at`, then
  ``free = t + int(round(accum))`` — followed by a terminal flush phase at
  ``T_flush = max(last arrival, all lcore frees)`` mirroring the event
  loop's quiet-wire ``flush_rx``;
* **drain/RTT**: TX drains happen in the same round as the harvest that
  posted them, so return-wire arrivals are one more array pass per port,
  with RTTs recorded in the event loop's global (time, port, queue) order
  (latency stats such as ``np.mean`` are float-order-sensitive).

**Exactness contract**: the engine plans the whole run *purely* (no state
mutated), validates that the run stays inside the fast-path regime — no RX
ring ever fills (no drops, no full-triggered writeback), the packet pool
never exhausts, no writeback-timeout timers, no DCA accumulate mode, default
burst transform — and only then commits counters, latency samples, meter
windows, lcore busy times, and the final clock in one step.  Any unsupported
configuration or validation failure falls back to ``loadgen.run_sim`` before
anything is touched, so **RunReports are bit-identical to the event loop in
every case** — either computed by the closed forms proven equivalent, or by
the event loop itself.

Known (documented) divergences outside the RunReport: per-queue
``ServerStats.poll_iterations``/``empty_polls`` count only harvesting polls
(the event loop also counts empty polls each round), and internal ring/arena
arrays (slot contents, frame bytes) are not written since no report reads
them.  Pool free-list order after a run also differs (frames are never
actually allocated).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from ..kernels.epoch_fastpath import (epoch_pass_np, get_epoch_pass_jax,
                                      serialization_ns_vec,
                                      wire_arrival_pass_np)
from .packet import DEFAULT_DST_IP, DEFAULT_SRC_IP_BASE, swap_macs_vec
from .pmd import BypassL2FwdServer
from .simclock import SimClock
from .telemetry import RunReport

__all__ = ["EpochRunInfo", "EPOCH_FALLBACK_REASONS", "PARTITIONED_REASON",
           "run_epoch_sim", "iter_epoch_slices", "default_epoch_ns",
           "validate_epoch_fallback_reason"]

# fallback-taxonomy reason for topology runs executing under a partition
# engine (TopologyConfig.partition != "shared-clock"): domains advance on
# private clocks, so the single-testbed epoch planner does not apply.  The
# run falls back cleanly to the (partitioned) event loop and surfaces this
# reason in EpochRunInfo rather than erroring.
PARTITIONED_REASON = "partitioned domain execution"

# The closed taxonomy of epoch fallback reasons.  Every string assigned to
# ``EpochRunInfo.fallback_reason`` must be one of these literals or match
# one of the parameterized patterns below — a typo'd or ad-hoc reason fails
# loudly at assignment instead of silently fragmenting the taxonomy that
# ``tests/test_fallback_taxonomy.py`` and sweep tooling key on.
EPOCH_FALLBACK_REASONS: Tuple[str, ...] = (
    "no SimClock attached",
    "custom packet-processing function",
    "DCA accumulate mode",
    "pending queue accumulation deadlines",
    "integrity verification enabled",
    "DCTCP rate-adaptive loadgen active",
    "pending scheduler events",
    "no ports",
    "server and loadgen port lists differ",
    "zero-cost host model",
    "writeback-timeout timers armed",
    "writeback DMA latency armed",
    "RX ring not idle",
    "TX ring not idle",
    "lcore burst exceeds loadgen max_tx_burst (TX would linger)",
    "lcore burst exceeds TX ring size",
    "RX ring would fill (overflow writeback/drop regime)",
    "packet pool would exhaust",
    PARTITIONED_REASON,
)

# reasons carrying an interpolated server type / exception repr
_EPOCH_REASON_PATTERNS = (
    re.compile(r"server type \S+ is not BypassL2FwdServer"),
    re.compile(r"planning failed: .*", re.DOTALL),
)


def validate_epoch_fallback_reason(reason: Optional[str]) -> None:
    """Raise ``ValueError`` unless ``reason`` is None, a literal from
    :data:`EPOCH_FALLBACK_REASONS`, or matches a parameterized pattern."""
    if reason is None or reason in EPOCH_FALLBACK_REASONS:
        return
    for pat in _EPOCH_REASON_PATTERNS:
        if pat.fullmatch(reason):
            return
    raise ValueError(
        f"unknown epoch fallback reason {reason!r}: not in the closed "
        "EPOCH_FALLBACK_REASONS taxonomy (repro.core.fastpath)")

# target packets per epoch pass: large enough to amortize numpy/JAX dispatch,
# small enough that slicing is exercised (and memory stays bounded per pass)
_EPOCH_TARGET_PKTS = 1 << 16


def iter_epoch_slices(times: np.ndarray, epoch_ns: int,
                      ) -> Iterator[Tuple[int, int]]:
    """Yield (lo, hi) index pairs slicing a sorted emission schedule into
    epochs of ``epoch_ns``: slice k covers times in
    ``[t0 + k*epoch_ns, t0 + (k+1)*epoch_ns)``.  Empty epochs are skipped;
    the slices partition ``[0, len(times))`` in order (no packet lost or
    reordered at a boundary)."""
    n = len(times)
    if n == 0:
        return
    if epoch_ns <= 0:
        yield 0, n
        return
    t0 = int(times[0])
    lo = 0
    while lo < n:
        k = (int(times[lo]) - t0) // epoch_ns
        bound = t0 + (k + 1) * epoch_ns
        hi = int(np.searchsorted(times, bound, side="left"))
        if hi <= lo:  # defensive: always make progress
            hi = lo + 1
        yield lo, hi
        lo = hi


def default_epoch_ns(ports, times: np.ndarray) -> int:
    """SimBricks-style epoch bound: at least the minimum (nonzero) link
    latency across the ports, scaled up so the run is covered in roughly
    ``_EPOCH_TARGET_PKTS``-packet passes."""
    n = len(times)
    if n == 0:
        return 1
    lats = [int(getattr(p, "link_latency_ns", 0)) for p in ports]
    base = min((l for l in lats if l > 0), default=0)
    span = int(times[-1]) - int(times[0]) + 1
    n_chunks = max(1, -(-n // _EPOCH_TARGET_PKTS))
    chunk = -(-span // n_chunks)
    return max(1, base, chunk)


@dataclass
class EpochRunInfo:
    """Out-of-band run descriptor (NOT in the RunReport, which must stay
    bit-identical across engines).  Pass an instance to :func:`run_epoch_sim`
    to learn whether the fast path ran and why it fell back."""

    engine: str = "epoch"
    fastpath: bool = False
    fallback_reason: Optional[str] = None
    used_jax: bool = False
    n_epochs: int = 0
    n_packets: int = 0

    def __setattr__(self, name: str, value) -> None:
        # dataclass __init__ assigns via setattr, so construction-time
        # reasons are validated too
        if name == "fallback_reason":
            validate_epoch_fallback_reason(value)
        object.__setattr__(self, name, value)


class _QueuePlan:
    """Planned per-(port, queue) arrival stream + harvest history."""

    __slots__ = ("pi", "qi", "ring", "arr", "orig", "n", "W", "n_full",
                 "batch_times", "pos", "wb_ptr", "tail_time", "harvests")

    def __init__(self, pi: int, qi: int, ring, arr: np.ndarray,
                 orig: np.ndarray):
        self.pi, self.qi, self.ring = pi, qi, ring
        self.arr = arr      # arrival times at the NIC, sorted (wire FIFO)
        self.orig = orig    # global emission indices, arrival order
        self.n = len(arr)
        thr = ring.writeback_threshold
        self.W = ring.size if thr is None else int(thr)
        self.n_full = self.n // self.W
        # the k-th threshold writeback publishes when frame (k+1)*W-1 lands
        self.batch_times = arr[self.W - 1::self.W][:self.n_full]
        self.pos = 0         # descriptors harvested so far (the PMD tail)
        self.wb_ptr = 0      # full batches published by current cascade time
        self.tail_time: Optional[int] = None  # T_flush once the tail phase runs
        self.harvests: List[Tuple[int, int]] = []  # [(t, n)], time order

    def next_pub_time(self) -> Optional[int]:
        """When the first not-yet-harvested descriptor becomes PMD-visible."""
        if self.pos < self.n_full * self.W:
            return int(self.batch_times[self.pos // self.W])
        if self.tail_time is not None and self.pos < self.n:
            return self.tail_time
        return None

    def published_at(self, t: int) -> int:
        """Total descriptors written back at time <= t (t must be
        non-decreasing across calls — it is, per lcore)."""
        while self.wb_ptr < self.n_full and self.batch_times[self.wb_ptr] <= t:
            self.wb_ptr += 1
        if self.tail_time is not None and t >= self.tail_time:
            return self.n
        return self.wb_ptr * self.W


@dataclass
class _Plan:
    """Everything the commit step needs, computed without side effects."""

    n: int
    start: int
    open_window_at: int = 0
    sizes: Optional[np.ndarray] = None
    qplans: List[_QueuePlan] = field(default_factory=list)
    lcore_free: List[int] = field(default_factory=list)
    final_now: int = 0
    rtts: Optional[np.ndarray] = None
    meter_bytes: int = 0
    meter_start: int = 0
    meter_end: int = 0


def _fallback_reason(lg, server, sched) -> Optional[str]:
    """None when the config is inside the fast-path regime, else why not."""
    if type(server) is not BypassL2FwdServer:
        return f"server type {type(server).__name__} is not BypassL2FwdServer"
    if server.clock is None:
        return "no SimClock attached"
    if server.process_fn is not None or server.burst_process_fn is not swap_macs_vec:
        return "custom packet-processing function"
    if server._dca_wait_ns is not None:
        return "DCA accumulate mode"
    if server._queue_deadline:
        return "pending queue accumulation deadlines"
    if lg.verify_integrity:
        return "integrity verification enabled"
    if getattr(lg, "cc", None) is not None:
        # DCTCP adapts the offered rate mid-trial on echo feedback; the
        # epoch planner precomputes the whole emission schedule up front
        return "DCTCP rate-adaptive loadgen active"
    if sched is not None and len(sched) > 0:
        return "pending scheduler events"
    if not lg.ports:
        return "no ports"
    if len(server.ports) != len(lg.ports) or any(
            a is not b for a, b in zip(server.ports, lg.ports)):
        return "server and loadgen port lists differ"
    # a harvest must advance the lcore's busy window or the event loop polls
    # the same instant forever; the cascade's termination leans on this too
    if int(round(server.sim_cost.pmd_burst_ns(1))) < 1:
        return "zero-cost host model"
    for port in lg.ports:
        for ring in port.rx_queues:
            if ring._sched is not None and ring._timeout_ns > 0:
                return "writeback-timeout timers armed"
            if ring._sched is not None and ring._dma_ns > 0:
                return "writeback DMA latency armed"
            if ring.head != ring.tail or ring.published != ring.tail \
                    or ring._cached != 0 or ring._dma_pending != 0:
                return "RX ring not idle"
        for ring in port.tx_queues:
            if ring.pending != 0:
                return "TX ring not idle"
    for lc in server.lcores:
        if lc.burst_size > lg.max_tx_burst:
            return "lcore burst exceeds loadgen max_tx_burst (TX would linger)"
        for pi, qi in lc.assignments:
            if lc.burst_size > lg.ports[pi].tx_queues[qi].size:
                return "lcore burst exceeds TX ring size"
    return None


def _flow_queue_table(port, n_flows: int, src_ip_base: Optional[int],
                      dst_ip: Optional[int]) -> Optional[np.ndarray]:
    """Per-flow-id RSS queue table for one port (None for single-queue).

    Builds the same big-endian flow-tuple bytes as
    :func:`repro.core.packet.write_flow_ids_vec` and steers them through the
    port's real Toeplitz hash + indirection table, so the gathered queue of
    frame ``seq`` equals ``rss.steer_one(read_flow_bytes(...))`` bit-for-bit.
    """
    if port.n_queues <= 1:
        return None
    ids = np.arange(n_flows, dtype=np.int64)
    base = DEFAULT_SRC_IP_BASE if src_ip_base is None else int(src_ip_base)
    dst = DEFAULT_DST_IP if dst_ip is None else int(dst_ip)
    mat = np.empty((n_flows, 12), dtype=np.uint8)
    mat[:, 0:4] = (base | (ids & 0xFFFF)).astype(">u4").view(np.uint8).reshape(-1, 4)
    mat[:, 4:8] = np.full(n_flows, dst, dtype=">u4").view(np.uint8).reshape(-1, 4)
    mat[:, 8:10] = (1024 + (ids % 60000)).astype(">u2").view(np.uint8).reshape(-1, 2)
    mat[:, 10:12] = np.full(n_flows, 443, dtype=">u2").view(np.uint8).reshape(-1, 2)
    return port.rss.steer(mat).astype(np.int64)


def _cascade(group: List[_QueuePlan], free: int, burst: int, cost_fn,
             events: List[Tuple[int, _QueuePlan, int, int]]) -> int:
    """Replay one lcore's harvest history against its planned queues.

    Each iteration is one event-loop round the lcore actually harvests in:
    the earliest time both the lcore is free and something is published.
    Queues are serviced in assignment order with the same float cost
    accumulation as ``poll_at`` (order matters for the final rounding).
    """
    while True:
        t_next: Optional[int] = None
        for qp in group:
            pt = qp.next_pub_time()
            if pt is not None and (t_next is None or pt < t_next):
                t_next = pt
        if t_next is None:
            return free
        t = t_next if t_next > free else free
        accum = 0.0
        for qp in group:
            avail = qp.published_at(t) - qp.pos
            if avail <= 0:
                continue
            h = burst if avail > burst else avail
            events.append((t, qp, qp.pos, h))
            qp.harvests.append((t, h))
            qp.pos += h
            accum += cost_fn(h)
        free = t + int(round(accum))


def _build_plan(lg, server, pattern, clock, duration_s: float,
                epoch_ns: Optional[int], use_jax: bool,
                info: EpochRunInfo) -> Optional[_Plan]:
    """Pure planning pass: returns a complete :class:`_Plan`, or None (with
    ``info.fallback_reason`` set) when a validation shows the run would
    leave the fast-path regime.  Mutates nothing."""
    rng = np.random.default_rng(pattern.seed)
    times, sizes = pattern.emission_schedule(int(duration_s * 1e9), rng)
    n = len(times)
    start = clock.now_ns
    info.n_packets = n
    if n == 0:
        return _Plan(n=0, start=start, final_now=start)
    times_abs = times + start

    pass_fn = epoch_pass_np
    if use_jax:
        jax_pass = get_epoch_pass_jax()
        if jax_pass is not None:
            pass_fn = jax_pass
            info.used_jax = True
    if epoch_ns is None:
        epoch_ns = default_epoch_ns(lg.ports, times_abs)

    ports = lg.ports
    nports = len(ports)
    seq0 = lg._next_seq
    qplans: Dict[Tuple[int, int], _QueuePlan] = {}
    empty_i64 = np.empty(0, dtype=np.int64)

    # -- phase A: per-port wire pass + RSS split over epoch slices ----------
    for pi, port in enumerate(ports):
        e_p = times_abs[pi::nports]
        orig_p = np.arange(pi, n, nports, dtype=np.int64)
        sz_p = sizes[pi::nports]
        gbps = float(getattr(port, "link_gbps", 0.0))
        lat = int(getattr(port, "link_latency_ns", 0))
        if len(e_p) == 0:
            for qi in range(port.n_queues):
                qplans[(pi, qi)] = _QueuePlan(pi, qi, port.rx_queues[qi],
                                              empty_i64, empty_i64)
            continue
        ser_p = serialization_ns_vec(sz_p, gbps)
        table = _flow_queue_table(port, lg.n_flows, lg.src_ip_base, lg.dst_ip)
        fids = ((seq0 + orig_p) % lg.n_flows) if table is not None else None
        busy = 0
        arr_parts: List[np.ndarray] = []
        q_parts: List[np.ndarray] = []
        for lo, hi in iter_epoch_slices(e_p, epoch_ns):
            a, busy, q = pass_fn(e_p[lo:hi], ser_p[lo:hi], busy, lat, table,
                                 None if fids is None else fids[lo:hi])
            arr_parts.append(np.asarray(a))
            if q is not None:
                q_parts.append(np.asarray(q))
            info.n_epochs += 1
        arr_p = np.concatenate(arr_parts)
        if table is None:
            qplans[(pi, 0)] = _QueuePlan(pi, 0, port.rx_queues[0], arr_p, orig_p)
        else:
            q_all = np.concatenate(q_parts)
            for qi in range(port.n_queues):
                mask = q_all == qi
                qplans[(pi, qi)] = _QueuePlan(pi, qi, port.rx_queues[qi],
                                              arr_p[mask], orig_p[mask])

    # -- phase B: per-lcore harvest cascade + terminal flush ----------------
    cost_fn = server.sim_cost.pmd_burst_ns
    lcore_free = list(server._lcore_next_free)
    events: List[Tuple[int, _QueuePlan, int, int]] = []
    for i, lc in enumerate(server.lcores):
        group = [qplans[pr] for pr in lc.assignments]
        lcore_free[i] = _cascade(group, lcore_free[i], lc.burst_size,
                                 cost_fn, events)
    a_last = max(int(qp.arr[-1]) for qp in qplans.values() if qp.n)
    # the event loop's quiet-wire flush_rx fires once no emission, wire
    # arrival, or future lcore-free candidate remains
    t_flush = max([a_last] + lcore_free)
    for qp in qplans.values():
        qp.tail_time = t_flush
    for i, lc in enumerate(server.lcores):
        group = [qplans[pr] for pr in lc.assignments]
        lcore_free[i] = _cascade(group, lcore_free[i], lc.burst_size,
                                 cost_fn, events)
    final_now = max([t_flush] + lcore_free)

    # -- validation 1: no RX ring ever fills --------------------------------
    # before accepting arrival j (0-indexed), in_flight is j minus harvests
    # strictly earlier (same-round harvests run after delivery); require the
    # post-accept occupancy j+1-hb to stay < size, which rules out both the
    # drop path and the full-triggered early writeback
    for qp in qplans.values():
        if qp.n == 0:
            continue
        ht = np.fromiter((t for t, _ in qp.harvests), dtype=np.int64,
                         count=len(qp.harvests))
        hc = np.cumsum(np.fromiter((h for _, h in qp.harvests),
                                   dtype=np.int64, count=len(qp.harvests)))
        idx = np.searchsorted(ht, qp.arr, side="left")
        hb = np.where(idx > 0, hc[np.maximum(idx - 1, 0)], 0)
        occ = np.arange(1, qp.n + 1, dtype=np.int64) - hb
        if int(occ.max()) >= qp.ring.size:
            info.fallback_reason = (
                "RX ring would fill (overflow writeback/drop regime)")
            return None

    # -- validation 2: the packet pool never exhausts -----------------------
    # +1 at each emission, -1 at the harvest round that drains the frame
    # (the event loop frees at drain time, not at return-wire arrival);
    # same-time allocs precede frees (loop step order: emit ... drain)
    free_t = np.empty(n, dtype=np.int64)
    for t, qp, s, h in events:
        free_t[qp.orig[s:s + h]] = t
    pool_ports: Dict[int, Tuple[object, List[int]]] = {}
    for pi, port in enumerate(ports):
        pool_ports.setdefault(id(port.pool), (port.pool, []))[1].append(pi)
    for pool, pis in pool_ports.values():
        alloc_t = np.concatenate([times_abs[pi::nports] for pi in pis])
        freed_t = np.concatenate([free_t[pi::nports] for pi in pis])
        if len(alloc_t) == 0:
            continue
        ev_t = np.concatenate([alloc_t, freed_t])
        delta = np.concatenate([np.ones(len(alloc_t), dtype=np.int64),
                                -np.ones(len(freed_t), dtype=np.int64)])
        kind = np.concatenate([np.zeros(len(alloc_t), dtype=np.int8),
                               np.ones(len(freed_t), dtype=np.int8)])
        order = np.lexsort((kind, ev_t))
        occ = np.cumsum(delta[order])
        if int(occ.max()) > pool.n_free:
            info.fallback_reason = "packet pool would exhaust"
            return None

    # -- phase C: TX drains through the return wires ------------------------
    # drains happen in the same round as their harvest; per round the event
    # loop drains ports in order and queues in order within a port, and the
    # RTT sample order must match exactly (mean/std are order-sensitive)
    ev_by_port: Dict[int, List[Tuple[int, int, _QueuePlan, int, int]]] = {}
    for t, qp, s, h in events:
        ev_by_port.setdefault(qp.pi, []).append((t, qp.qi, qp, s, h))
    tagged: List[Tuple[int, int, int, np.ndarray]] = []
    meter_bytes = 0
    meter_start: Optional[int] = None
    meter_end: Optional[int] = None
    for pi, evs in ev_by_port.items():
        evs.sort(key=lambda e: (e[0], e[1]))
        handed = np.concatenate(
            [np.full(h, t, dtype=np.int64) for t, _qi, _qp, _s, h in evs])
        origs = np.concatenate([qp.orig[s:s + h] for _t, _qi, qp, s, h in evs])
        lens = sizes[origs]
        port = ports[pi]
        gbps = float(getattr(port, "link_gbps", 0.0))
        lat = int(getattr(port, "link_latency_ns", 0))
        ser_b = serialization_ns_vec(lens, gbps)
        arr_b, _ = wire_arrival_pass_np(handed, ser_b, 0, lat)
        rtts_p = np.maximum(0, arr_b - times_abs[origs])
        meter_bytes += int(lens.sum())
        ms, me = int(arr_b[0]), int(arr_b[-1])  # FIFO: endpoints are min/max
        meter_start = ms if meter_start is None else min(meter_start, ms)
        meter_end = me if meter_end is None else max(meter_end, me)
        off = 0
        for t, qi, _qp, _s, h in evs:
            tagged.append((t, pi, qi, rtts_p[off:off + h]))
            off += h
    tagged.sort(key=lambda e: (e[0], e[1], e[2]))
    rtts = (np.concatenate([e[3] for e in tagged]) if tagged
            else np.empty(0, dtype=np.int64))

    return _Plan(n=n, start=start, open_window_at=int(times_abs[0]),
                 sizes=sizes, qplans=list(qplans.values()),
                 lcore_free=lcore_free, final_now=final_now, rtts=rtts,
                 meter_bytes=meter_bytes, meter_start=int(meter_start),
                 meter_end=int(meter_end))


def _commit(lg, server, pattern, clock, plan: _Plan) -> RunReport:
    """Apply a validated plan: every counter the event loop would have
    touched, in one step, then the final report."""
    if plan.n:
        lg.meter.open_window(plan.open_window_at)
        lg.flight.sent += plan.n
        lg._next_seq += plan.n
        for qp in plan.qplans:
            if qp.n == 0:
                continue
            nbytes = int(plan.sizes[qp.orig].sum())
            ring = qp.ring
            ring.delivered += qp.n
            ring.delivered_bytes += nbytes
            ring.head += qp.n
            ring.tail += qp.n
            ring.published += qp.n
            rem = qp.n - qp.n_full * qp.W
            ring.writebacks += qp.n_full + (1 if rem else 0)
            ring.writeback_sizes.extend([qp.W] * qp.n_full)
            if rem:
                ring.writeback_sizes.append(rem)
            txr = lg.ports[qp.pi].tx_queues[qp.qi]
            txr.posted += qp.n
            txr.posted_bytes += nbytes
            txr.transmitted += qp.n
            txr.transmitted_bytes += nbytes
            txr.head += qp.n
            txr.tail += qp.n
            qs = server.queue_stats[(qp.pi, qp.qi)]
            qs.rx_packets += qp.n
            qs.rx_bytes += nbytes
            qs.tx_packets += qp.n
            qs.poll_iterations += len(qp.harvests)
            for _t, h in qp.harvests:
                qs.record_burst(h)
        server._lcore_next_free[:] = plan.lcore_free
        lg.latency.record_many(plan.rtts)
        lg.flight.received += plan.n
        lg.meter.merge_counts(plan.n, plan.meter_bytes,
                              plan.meter_start, plan.meter_end)
        clock.advance_to(plan.final_now)
    rep = lg._report(
        offered_gbps=pattern.rate_gbps if pattern.trace is None else 0.0)
    rep.extras["sim_time"] = 1.0
    rep.extras["virtual_elapsed_ns"] = float(clock.now_ns - plan.start)
    return rep


def run_epoch_sim(loadgen, server, pattern, duration_s: float = 0.25,
                  clock: Optional[SimClock] = None, sched=None,
                  use_jax: bool = False, epoch_ns: Optional[int] = None,
                  max_rounds: int = 50_000_000,
                  info: Optional[EpochRunInfo] = None) -> RunReport:
    """Run one open-loop virtual-time measurement through the epoch-batched
    fast path, falling back to ``loadgen.run_sim`` for any configuration the
    fast path cannot reproduce bit-identically.

    Drop-in replacement for :meth:`~repro.core.loadgen.LoadGen.run_sim`
    (same clock/sched resolution, same RunReport).  ``use_jax`` routes the
    array passes through the jit-compiled JAX kernel when available;
    ``epoch_ns`` overrides the epoch length (default: see
    :func:`default_epoch_ns`); ``info`` receives fast-path/fallback details.
    """
    if info is None:
        info = EpochRunInfo()
    info.engine = "epoch-jit" if use_jax else "epoch"
    if clock is None:
        clock = getattr(server, "clock", None)
    if clock is None:
        clock = SimClock()
    if hasattr(server, "attach_clock") \
            and getattr(server, "clock", None) is not clock:
        server.attach_clock(clock)
    if sched is None:
        sched = next((s for s in (getattr(p, "event_sched", None)
                                  for p in loadgen.ports) if s is not None),
                     None)
    plan: Optional[_Plan] = None
    try:
        reason = _fallback_reason(loadgen, server, sched)
        if reason is not None:
            info.fallback_reason = reason
        else:
            plan = _build_plan(loadgen, server, pattern, clock, duration_s,
                               epoch_ns, use_jax, info)
    except Exception as exc:  # planning is pure — always safe to fall back
        info.fallback_reason = f"planning failed: {exc!r}"
        plan = None
    if plan is None:
        info.fastpath = False
        return loadgen.run_sim(server, pattern, duration_s=duration_s,
                               clock=clock, max_rounds=max_rounds,
                               sched=sched)
    info.fastpath = True
    return _commit(loadgen, server, pattern, clock, plan)
