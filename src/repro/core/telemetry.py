"""Per-packet telemetry: RTT stats, drop accounting, histograms, throughput.

This is the measurement half of EtherLoadGen (paper §3.3): "reports mean,
median, standard deviation, and tail latency of network packets ... also
produces a packet drop percentage and a histogram of packet forwarding
latency."
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


@dataclass
class LatencyStats:
    count: int
    mean_ns: float
    median_ns: float
    std_ns: float
    p95_ns: float
    p99_ns: float
    p999_ns: float
    max_ns: float
    min_ns: float

    def as_dict(self) -> Dict[str, float]:
        return dict(
            count=self.count, mean_ns=self.mean_ns, median_ns=self.median_ns,
            std_ns=self.std_ns, p95_ns=self.p95_ns, p99_ns=self.p99_ns,
            p999_ns=self.p999_ns, max_ns=self.max_ns, min_ns=self.min_ns,
        )

    def __str__(self) -> str:  # human-readable one-liner for stats files
        us = 1e3
        return (
            f"n={self.count} mean={self.mean_ns/us:.2f}us med={self.median_ns/us:.2f}us "
            f"std={self.std_ns/us:.2f}us p95={self.p95_ns/us:.2f}us "
            f"p99={self.p99_ns/us:.2f}us p99.9={self.p999_ns/us:.2f}us "
            f"max={self.max_ns/us:.2f}us"
        )


class LatencyRecorder:
    """Append-only RTT recorder with percentile stats + log-bucket histogram."""

    def __init__(self, capacity_hint: int = 1 << 16):
        self._buf = np.zeros(max(16, capacity_hint), dtype=np.int64)
        self._n = 0

    def record(self, rtt_ns: int) -> None:
        if self._n == len(self._buf):
            self._buf = np.concatenate([self._buf, np.zeros_like(self._buf)])
        self._buf[self._n] = rtt_ns
        self._n += 1

    def record_many(self, rtts_ns: np.ndarray) -> None:
        m = len(rtts_ns)
        while self._n + m > len(self._buf):
            self._buf = np.concatenate([self._buf, np.zeros_like(self._buf)])
        self._buf[self._n : self._n + m] = rtts_ns
        self._n += m

    @property
    def count(self) -> int:
        return self._n

    def values(self) -> np.ndarray:
        return self._buf[: self._n]

    def stats(self) -> Optional[LatencyStats]:
        if self._n == 0:
            return None
        v = self.values().astype(np.float64)
        return LatencyStats(
            count=self._n,
            mean_ns=float(v.mean()),
            median_ns=float(np.median(v)),
            std_ns=float(v.std()),
            p95_ns=float(np.percentile(v, 95)),
            p99_ns=float(np.percentile(v, 99)),
            p999_ns=float(np.percentile(v, 99.9)),
            max_ns=float(v.max()),
            min_ns=float(v.min()),
        )

    def histogram(self, n_buckets: int = 24) -> List[Dict[str, float]]:
        """Log-spaced latency histogram (the paper's 'histogram of packet
        forwarding latency')."""
        if self._n == 0:
            return []
        v = self.values().astype(np.float64)
        lo = max(1.0, float(v.min()))
        hi = max(lo * 1.0001, float(v.max()))
        edges = np.logspace(math.log10(lo), math.log10(hi), n_buckets + 1)
        counts, _ = np.histogram(v, bins=edges)
        return [
            {"lo_ns": float(edges[i]), "hi_ns": float(edges[i + 1]), "count": int(counts[i])}
            for i in range(n_buckets)
        ]


def writeback_extras(ports: List[object], prefix: str = "") -> Dict[str, float]:
    """Per-RX-ring descriptor-writeback telemetry, RunReport.extras-shaped.

    For every (port, queue) RX ring: the number of writeback DMA events
    (``writebacks``), the mean/max writeback burst size (the distribution the
    paper's Fig. 4 studies — large bursts are the LLC-thrashing regime), and
    how many of those events were forced by the idle-timeout timer
    (``timeout_flushes``, the ITR analogue).  ``prefix`` namespaces the keys
    for multi-host reports (e.g. ``n0_``).
    """
    out: Dict[str, float] = {}
    for pi, port in enumerate(ports):
        for qi, ring in enumerate(port.rx_queues):
            k = f"{prefix}p{pi}q{qi}"
            sizes = ring.writeback_sizes
            out[f"{k}_writebacks"] = float(ring.writebacks)
            out[f"{k}_wb_size_mean"] = float(np.mean(sizes)) if sizes else 0.0
            out[f"{k}_wb_size_max"] = float(max(sizes)) if sizes else 0.0
            out[f"{k}_timeout_flushes"] = float(ring.timeout_flushes)
    return out


def rss_skew(per_queue_counts: List[int]) -> Dict[str, float]:
    """RSS load-imbalance summary over per-queue packet counts.

    ``max_over_mean`` is the classic imbalance factor (1.0 == perfectly
    balanced; a queue at 2.0 is the hot queue bottlenecking core scaling);
    ``cov`` is the coefficient of variation across queues.
    """
    counts = np.asarray(per_queue_counts, dtype=np.float64)
    if counts.size == 0 or counts.sum() == 0:
        return {"max_over_mean": 0.0, "cov": 0.0}
    mean = counts.mean()
    return {
        "max_over_mean": float(counts.max() / mean),
        "cov": float(counts.std() / mean),
    }


class QueueTelemetry:
    """Per-(port, queue) RX-descriptor occupancy sampler.

    Sample once per poll/scheduling round; summarizes mean and high-water
    occupancy per queue plus the RSS skew of total per-queue traffic — the
    observable that shows whether flows actually spread across queues
    (paper Fig. 3(a) core scaling needs balance).
    """

    def __init__(self) -> None:
        self._sum: Dict[tuple, int] = {}
        self._high: Dict[tuple, int] = {}
        self._n = 0

    def sample(self, ports: List[object]) -> None:
        self._n += 1
        for pi, port in enumerate(ports):
            for qi, occ in enumerate(port.queue_occupancy()):
                key = (pi, qi)
                self._sum[key] = self._sum.get(key, 0) + occ
                self._high[key] = max(self._high.get(key, 0), occ)

    @property
    def samples(self) -> int:
        return self._n

    def mean_occupancy(self) -> Dict[tuple, float]:
        return {k: v / self._n for k, v in self._sum.items()} if self._n else {}

    def high_water(self) -> Dict[tuple, int]:
        return dict(self._high)

    def summary(self, ports: List[object]) -> Dict[str, float]:
        """Flat metrics dict (RunReport.extras-shaped)."""
        out: Dict[str, float] = {}
        means = self.mean_occupancy()
        for (pi, qi), m in sorted(means.items()):
            out[f"p{pi}q{qi}_occ_mean"] = m
            out[f"p{pi}q{qi}_occ_high"] = float(self._high[(pi, qi)])
        for pi, port in enumerate(ports):
            skew = rss_skew(port.rx_queue_delivered())
            out[f"p{pi}_rss_imbalance"] = skew["max_over_mean"]
            out[f"p{pi}_rss_cov"] = skew["cov"]
        return out


@dataclass
class ThroughputMeter:
    """Counts packets/bytes over an interval → Gbps / Mpps."""

    packets: int = 0
    bytes: int = 0
    start_ns: Optional[int] = None
    end_ns: Optional[int] = None

    def open_window(self, start_ns: int) -> None:
        """Anchor the measurement window at the run's first emission.

        Without this, a run whose completions all publish in one terminal
        writeback flush would measure its throughput over the (tiny) drain
        burst instead of the traffic interval and report absurd rates.
        """
        if self.start_ns is None:
            self.start_ns = start_ns

    def on_packet(self, length: int, now_ns: int) -> None:
        if self.start_ns is None:
            self.start_ns = now_ns
        self.end_ns = now_ns
        self.packets += 1
        self.bytes += length

    def merge_counts(self, packets: int, nbytes: int, start_ns: int, end_ns: int) -> None:
        self.packets += packets
        self.bytes += nbytes
        self.start_ns = start_ns if self.start_ns is None else min(self.start_ns, start_ns)
        self.end_ns = end_ns if self.end_ns is None else max(self.end_ns, end_ns)

    @property
    def elapsed_s(self) -> float:
        if self.start_ns is None or self.end_ns is None:
            return 0.0
        if self.end_ns <= self.start_ns:
            # degenerate window: every completion landed on one clock tick
            # (e.g. a single packet published by a terminal writeback flush).
            # Measure over the 1 ns tick floor instead of claiming the run
            # moved zero traffic.
            return 1e-9 if self.packets > 0 else 0.0
        return (self.end_ns - self.start_ns) / 1e9

    @property
    def gbps(self) -> float:
        el = self.elapsed_s
        return (self.bytes * 8 / 1e9 / el) if el > 0 else 0.0

    @property
    def mpps(self) -> float:
        el = self.elapsed_s
        return (self.packets / 1e6 / el) if el > 0 else 0.0


@dataclass
class RunReport:
    """One benchmark run's stats file — EtherLoadGen's 'statistics file'."""

    offered_gbps: float = 0.0
    achieved_gbps: float = 0.0
    achieved_mpps: float = 0.0
    sent: int = 0
    received: int = 0
    dropped: int = 0
    latency: Optional[LatencyStats] = None
    histogram: List[Dict[str, float]] = field(default_factory=list)
    extras: Dict[str, float] = field(default_factory=dict)

    @property
    def drop_pct(self) -> float:
        return 100.0 * self.dropped / self.sent if self.sent else 0.0

    def to_dict(self) -> Dict[str, object]:
        """JSON-safe plain-data form (suite-runner artifacts; round-trips
        through :meth:`from_dict`)."""
        return {
            "offered_gbps": self.offered_gbps,
            "achieved_gbps": self.achieved_gbps,
            "achieved_mpps": self.achieved_mpps,
            "sent": self.sent,
            "received": self.received,
            "dropped": self.dropped,
            "latency": None if self.latency is None else self.latency.as_dict(),
            "histogram": [dict(b) for b in self.histogram],
            "extras": dict(self.extras),
        }

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "RunReport":
        d = dict(d)
        if d.get("latency") is not None:
            d["latency"] = LatencyStats(**d["latency"])
        return cls(**d)

    def summary(self) -> str:
        lines = [
            f"offered={self.offered_gbps:.3f}Gbps achieved={self.achieved_gbps:.3f}Gbps "
            f"({self.achieved_mpps:.3f}Mpps) sent={self.sent} rx={self.received} "
            f"drops={self.dropped} ({self.drop_pct:.3f}%)"
        ]
        if self.latency is not None:
            lines.append(f"latency: {self.latency}")
        for k, v in self.extras.items():
            lines.append(f"{k}={v}")
        return "\n".join(lines)
