"""Virtual-time simulation core: a deterministic clock, an event scheduler,
and a wire (link) model.

The paper's EtherLoadGen "adds a timestamp to each outgoing packet ... and
compares the timestamp with the current tick" — it measures in **simulated
ticks**, exactly like gem5 itself (a discrete-event timing model).  This
module gives the repo the same discipline: every producer of "now" in the
measurement pipeline (load generator pacing, RTT stamps, host-cost charging,
throughput meters) can read one :class:`SimClock` instead of
``time.perf_counter_ns()``, which makes every downstream number

* **deterministic** — same config + seed → bit-identical stats, and
* **host-independent** — 400 Gbps of offered load simulates fine on a laptop,
  because simulated time is decoupled from how fast the host executes.

Wall-clock mode survives (the host-overhead study needs it); the clock is
simply not installed and callers keep reading the host timer.

Components:

* :class:`SimClock` — current virtual time in integer nanoseconds, advancing
  monotonically and only explicitly.
* :class:`EventScheduler` — a lightweight min-heap of (time, callback) events
  with deterministic FIFO tie-breaking, for anything that needs "call me at
  T" semantics on top of the clock.  ``schedule_at``/``schedule_in`` return a
  token that :meth:`EventScheduler.cancel` accepts, so *timers* (events that
  may be superseded before they fire — e.g. the NIC descriptor-cache
  writeback timeout, the ITR analogue) compose with ordinary events.
  Cancellation is lazy: tombstoned entries are purged when they reach the
  heap top, so cancel is O(1) and the heap never fires a dead callback.
  (The load generator's hot loop inlines its own event selection for speed —
  emissions, wire arrivals and lcore-free times are each already sorted —
  but composed scenarios (the Switch/Topology layer, descriptor-writeback
  timers) schedule here, and the loop folds ``next_time_ns()`` into its
  candidate set.)
* :class:`Wire` — one simplex link: serialization delay (``bytes*8/gbps``)
  plus fixed propagation latency, with FIFO busy-until semantics so back-to-
  back frames queue on the wire like they do on real copper/fiber.
"""
from __future__ import annotations

import heapq
from typing import Callable, List, Optional, Tuple

import numpy as np


class SimClock:
    """Current virtual time, in integer nanoseconds.

    Monotonic by construction: ``advance_to`` is a no-op for times in the
    past, ``advance`` rejects negative deltas.  All virtual-time consumers
    (load generator, servers, telemetry) share one instance per testbed.
    """

    __slots__ = ("now_ns",)

    def __init__(self, start_ns: int = 0):
        self.now_ns = int(start_ns)

    def advance_to(self, t_ns: int) -> int:
        """Move the clock forward to ``t_ns`` (never backward)."""
        if t_ns > self.now_ns:
            self.now_ns = int(t_ns)
        return self.now_ns

    def advance(self, dt_ns: int) -> int:
        """Move the clock forward by ``dt_ns`` >= 0."""
        if dt_ns < 0:
            raise ValueError("SimClock cannot run backwards")
        self.now_ns += int(dt_ns)
        return self.now_ns

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"SimClock(now_ns={self.now_ns})"


class EventScheduler:
    """Deterministic discrete-event queue over a :class:`SimClock`.

    Events at equal times fire in insertion order (FIFO tie-break via a
    monotone sequence number), so two runs of the same schedule are
    bit-identical — the property every determinism test leans on.

    ``schedule_at``/``schedule_in`` return an opaque token; :meth:`cancel`
    tombstones the matching event (lazy deletion — the entry is discarded
    when it surfaces at the heap top, never fired).  ``len(sched)`` counts
    *live* events only.
    """

    def __init__(self, clock: Optional[SimClock] = None):
        self.clock = clock if clock is not None else SimClock()
        self._heap: List[Tuple[int, int, Callable[[], None]]] = []
        self._seq = 0
        self._live: set = set()  # seq numbers of not-yet-fired, not-cancelled

    def __len__(self) -> int:
        return len(self._live)

    def schedule_at(self, t_ns: int, fn: Callable[[], None]) -> int:
        """Schedule ``fn`` to run when the clock reaches ``t_ns``.  Times in
        the past fire on the next ``run_until``/``run_next`` at current now.
        Returns a token accepted by :meth:`cancel`."""
        heapq.heappush(self._heap, (int(t_ns), self._seq, fn))
        self._live.add(self._seq)
        token = self._seq
        self._seq += 1
        return token

    def schedule_in(self, delay_ns: int, fn: Callable[[], None]) -> int:
        return self.schedule_at(self.clock.now_ns + int(delay_ns), fn)

    def cancel(self, token: int) -> bool:
        """Cancel a pending event by token.  Returns True if it was still
        pending (it will never fire), False if it already fired, was already
        cancelled, or the token is unknown."""
        if token in self._live:
            self._live.discard(token)
            # lazy deletion never fires a dead event, but tombstones below
            # the heap top linger; compact when they dominate so arm/cancel
            # churn (e.g. per-packet writeback timers) stays O(live)
            if len(self._heap) > 64 and len(self._heap) > 4 * len(self._live):
                self._heap = [e for e in self._heap if e[1] in self._live]
                heapq.heapify(self._heap)
            return True
        return False

    def _drop_dead_head(self) -> None:
        """Purge tombstoned (cancelled) entries off the heap top."""
        while self._heap and self._heap[0][1] not in self._live:
            heapq.heappop(self._heap)

    def next_time_ns(self) -> Optional[int]:
        """Timestamp of the earliest *live* pending event, or None if empty."""
        self._drop_dead_head()
        return self._heap[0][0] if self._heap else None

    def run_next(self) -> bool:
        """Advance the clock to the earliest live event and run it.  Returns
        False when no live events are pending."""
        self._drop_dead_head()
        if not self._heap:
            return False
        t, seq, fn = heapq.heappop(self._heap)
        self._live.discard(seq)
        self.clock.advance_to(t)
        fn()
        return True

    def run_until(self, t_ns: int) -> int:
        """Run every live event scheduled at or before ``t_ns`` (in time
        order), then advance the clock to ``t_ns``.  Returns the number of
        events that fired."""
        fired = 0
        while True:
            nt = self.next_time_ns()
            if nt is None or nt > t_ns:
                break
            self.run_next()
            fired += 1
        self.clock.advance_to(t_ns)
        return fired

    def run_all(self, max_events: int = 10_000_000) -> int:
        """Drain the queue (events may schedule further events)."""
        fired = 0
        while self.run_next():
            fired += 1
            if fired >= max_events:
                raise RuntimeError("EventScheduler.run_all exceeded max_events")
        return fired


class Wire:
    """One simplex link: serialization + propagation, FIFO.

    ``gbps <= 0`` models an ideal wire (zero serialization delay) — the
    legacy behaviour for testbeds that never configured a link.  Otherwise a
    frame handed to the wire at ``t`` begins serializing when the wire frees
    up (``busy_until``), occupies it for ``bytes*8/gbps`` ns, and lands at
    the far end a further ``latency_ns`` later.  1 Gbps == 1 bit/ns, so the
    serialization arithmetic stays in exact ns.
    """

    __slots__ = ("gbps", "latency_ns", "busy_until_ns")

    def __init__(self, gbps: float = 0.0, latency_ns: int = 0):
        if latency_ns < 0:
            raise ValueError("latency_ns must be >= 0")
        self.gbps = float(gbps)
        self.latency_ns = int(latency_ns)
        self.busy_until_ns = 0

    def serialization_ns(self, nbytes: int) -> int:
        if self.gbps <= 0.0:
            return 0
        return int(round(nbytes * 8 / self.gbps))

    def transmit(self, t_ns: int, nbytes: int) -> int:
        """Put a frame on the wire at ``t_ns``; returns its arrival time at
        the far end.  Arrival times are non-decreasing (FIFO wire)."""
        start = max(int(t_ns), self.busy_until_ns)
        end = start + self.serialization_ns(nbytes)
        self.busy_until_ns = end
        return end + self.latency_ns

    def transmit_burst(self, t_ns: int, lengths) -> np.ndarray:
        """Vectorized :meth:`transmit` for a back-to-back frame burst handed
        to the wire at ``t_ns``; returns the per-frame arrival times.  An
        empty burst returns an empty array and leaves the wire untouched."""
        n = len(lengths)
        if n == 0:
            return np.empty(0, dtype=np.int64)
        start = max(int(t_ns), self.busy_until_ns)
        if self.gbps <= 0.0:
            self.busy_until_ns = start
            return np.full(n, start + self.latency_ns, dtype=np.int64)
        ser = np.round(np.asarray(lengths, dtype=np.float64) * 8.0
                       / self.gbps).astype(np.int64)
        ends = start + np.cumsum(ser)
        self.busy_until_ns = int(ends[-1])
        return ends + self.latency_ns

    def reset(self) -> None:
        self.busy_until_ns = 0
