"""DCA (direct cache access) burst analysis — paper §5.2 / Fig. 4 analogue.

The paper studies how the L2Fwd *burst size* interacts with DCA: forwarding in
bursts of 32 overlaps packet processing with NIC→LLC DMA and lets L2 demand
misses make LLC room, while waiting for 1024 packets before processing floods
the LLC ring buffer and causes writeback storms.

The measurable analogue here is staging-queue dynamics: with a fixed arrival
process, a small processing burst keeps descriptor-ring / staging occupancy low
(DMA overlapped with compute), while a large burst lets occupancy build to the
full train before any draining happens.  We trace occupancy over time and
summarize it with a high-water mark and an "overflow pressure" integral — the
stand-ins for LLC ring-buffer contention and writeback rate.

On the device side the same knob exists as the :class:`BurstPlan` used by the
bypass dataplane and by the `burst_gather` Pallas kernel (how many packets are
staged HBM→VMEM per grid step).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class BurstPlan:
    """Processing-burst configuration shared by host + device paths.

    Applies **per-lcore**: each polling engine resolves its own burst via
    :meth:`burst_for`, so heterogeneous lcores (e.g. one queue carrying an
    elephant flow) can run different DCA-overlap depths in one experiment.
    ``per_lcore=None`` keeps the uniform seed behaviour.
    """

    burst_size: int = 32        # packets processed per poll (DPDK burst)
    prefetch_depth: int = 2     # transfers in flight (DCA overlap depth)
    per_lcore: Optional[Tuple[int, ...]] = None  # per-lcore burst overrides

    def __post_init__(self) -> None:
        if self.burst_size < 1 or self.prefetch_depth < 1:
            raise ValueError("burst_size and prefetch_depth must be >= 1")
        if self.per_lcore is not None:
            if len(self.per_lcore) == 0 or any(b < 1 for b in self.per_lcore):
                raise ValueError("per_lcore bursts must be a nonempty tuple of >= 1")

    def validate_lcores(self, n_lcores: int) -> "BurstPlan":
        """Attach-time check: a ``per_lcore`` tuple must name exactly one
        burst per lcore of the stack adopting this plan.  A 3-entry tuple on
        a 4-lcore stack would silently recycle entry 0 for lcore 3 through
        the :meth:`burst_for` modulo fallback — a misconfiguration, not a
        layout choice, so stacks reject it loudly."""
        if self.per_lcore is not None and len(self.per_lcore) != n_lcores:
            raise ValueError(
                f"BurstPlan.per_lcore has {len(self.per_lcore)} entries for a "
                f"stack with {n_lcores} lcores; pass exactly one burst per "
                "lcore (the burst_for modulo wrap is a fallback for direct "
                "calls, not a layout policy)")
        return self

    def burst_for(self, lcore_id: int) -> int:
        """The burst size lcore ``lcore_id`` polls with.

        When ``per_lcore`` is set, out-of-range lcore ids wrap modulo the
        tuple length — **documented fallback only**, for direct callers that
        probe a plan without a stack; stacks validate exact length at attach
        time via :meth:`validate_lcores`."""
        if self.per_lcore is None:
            return self.burst_size
        return self.per_lcore[lcore_id % len(self.per_lcore)]


@dataclass
class OccupancyTrace:
    """Queue-occupancy samples over a run (one per poll iteration)."""

    samples: List[int] = field(default_factory=list)
    capacity: int = 0

    def record(self, occupancy: int) -> None:
        self.samples.append(occupancy)

    @property
    def high_water(self) -> int:
        return max(self.samples) if self.samples else 0

    @property
    def mean(self) -> float:
        return float(np.mean(self.samples)) if self.samples else 0.0

    def pressure(self, threshold_frac: float = 0.5) -> float:
        """Fraction of samples above threshold_frac of capacity.

        This is the LLC-contention stand-in: time spent with the staging
        buffer more than half full == time the 'cache' is being thrashed by
        DMA faster than demand misses can make room (paper Fig. 4(b)).
        """
        if not self.samples or self.capacity == 0:
            return 0.0
        thr = threshold_frac * self.capacity
        return float(np.mean([s > thr for s in self.samples]))


def run_burst_experiment(
    n_packets: int,
    burst_size: int,
    ring_size: int = 2048,
    writeback_threshold: Optional[int] = 32,
    arrival_chunk: int = 64,
    process_cost_fn: Optional[Callable[[np.ndarray], None]] = None,
    packet_size: int = 1024,
    clock: Optional["SimClock"] = None,
    tick_ns: int = 1_000,
) -> Tuple[OccupancyTrace, "np.ndarray"]:
    """Reproduce the Fig. 4 setup: deliver ``n_packets`` in a short interval,
    process them in ``burst_size`` chunks, trace occupancy + per-packet delay.

    Runs on a :class:`~repro.core.simclock.SimClock` (one service round ==
    ``tick_ns`` of virtual time); pass an existing clock to compose with a
    larger virtual-time experiment.  Returns (occupancy trace, per-packet
    queue delay in virtual ns).
    """
    from .descriptor import RxDescriptorRing
    from .packet import PacketPool, swap_macs
    from .simclock import SimClock

    pool = PacketPool(ring_size, packet_size)
    ring = RxDescriptorRing(ring_size, writeback_threshold=writeback_threshold)
    process = process_cost_fn or swap_macs
    clock = clock if clock is not None else SimClock()

    trace = OccupancyTrace(capacity=ring_size)
    enqueue_tick = np.full(n_packets, -1, dtype=np.int64)
    dequeue_tick = np.full(n_packets, -1, dtype=np.int64)

    delivered = 0
    processed = 0
    # Service capacity per tick covers the arrival rate (and a whole burst
    # once one is ready) for every configuration — the paper's Fig. 4
    # asymmetry is about WHEN processing starts (overlapped small bursts vs.
    # accumulate-then-forward), not about a slower server.
    service_per_tick = max(arrival_chunk, burst_size)
    while processed < n_packets:
        tick = clock.advance(tick_ns)
        # Arrival process: the whole train arrives "in a short time interval"
        # — arrival_chunk packets per tick.
        for _ in range(arrival_chunk):
            if delivered >= n_packets:
                break
            slot = pool.alloc()
            if slot is None:
                break
            pool.write_packet(slot, seq=delivered, length=packet_size, fill=0)
            if ring.nic_deliver(slot, packet_size):
                enqueue_tick[delivered] = tick
                delivered += 1
            else:
                pool.free(slot)
        ring.flush()
        # occupancy is sampled post-DMA / pre-processing: the staging pressure
        # the LLC sees in the paper's Fig. 4
        trace.record(ring.in_flight)
        # L2Fwd aggregates a full burst before forwarding — Fig. 4(b) "waits
        # until 1024 packets are received and then starts the forwarding"
        if ring.in_flight < burst_size and delivered < n_packets:
            continue
        served = 0
        while served < service_per_tick:
            batch = ring.poll(min(burst_size, service_per_tick - served)
                              if burst_size < n_packets else burst_size)
            if not batch:
                break
            for slot, length in batch:
                buf = pool.view(slot, length)
                process(buf)
                dequeue_tick[processed] = tick  # FIFO ring → in-order
                processed += 1
                pool.free(slot)
            served += len(batch)
            if burst_size >= n_packets:
                break  # one mega-burst per tick
    delay = (dequeue_tick - enqueue_tick).astype(np.int64)
    return trace, delay
