"""DPDK-faithful ``rte_ethdev`` facade over the :class:`~repro.core.pmd.Port`
engine.

The paper's contribution is making gem5's NIC model speak the userspace-driver
contract DPDK expects.  This module is that contract for this repo: an
:class:`EthDev` walks the exact ``rte_ethdev`` lifecycle —

    UNCONFIGURED --configure()--> CONFIGURED
    CONFIGURED   --rx/tx_queue_setup() per queue, then dev_start()--> STARTED
    STARTED      --dev_stop()--> STOPPED
    STOPPED      --dev_start()--> STARTED   (counters persist, like hardware)
    STOPPED      --configure()--> CONFIGURED (reconfigure wipes queue setups)

Invalid transitions raise :class:`EthDevError` instead of silently doing the
wrong thing, exactly like DPDK's ``-EBUSY``/``-EINVAL`` returns.  The burst
dataplane calls — ``rx_burst(queue, nb)`` and ``tx_burst(queue, slots,
lengths)`` — are only legal while STARTED.

Stats follow DPDK's two-tier scheme: :meth:`EthDev.stats` returns the basic
``rte_eth_stats`` aggregate (``ipackets``/``opackets``/``imissed``/
``rx_nombuf``/…), while :meth:`EthDev.xstats` returns the *extended* named
counters (``rx_q{N}_packets``, ``rx_q{N}_errors``, ``tx_q{N}_packets``, …)
that wrap the existing descriptor-ring counters under one naming scheme.

The wire side (what the load generator drives: ``deliver``/``drain_tx``/…)
delegates to the owned :class:`Port`, so an ``EthDev`` drops into every slot
that previously took a ``Port``.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from .descriptor import RxDescriptorRing, TxDescriptorRing
from .packet import PacketPool
from .pmd import Port
from .rss import DEFAULT_TABLE_SIZE, RssIndirection


class EthDevState(enum.Enum):
    UNCONFIGURED = "unconfigured"
    CONFIGURED = "configured"
    STARTED = "started"
    STOPPED = "stopped"


class EthDevError(RuntimeError):
    """Invalid lifecycle transition or dataplane call in the wrong state —
    the exception analogue of DPDK's ``-EBUSY``/``-EINVAL`` returns."""


@dataclass(frozen=True)
class EthConf:
    """``rte_eth_conf`` analogue: what ``configure()`` fixes for the device.

    Queue counts are set here (like ``nb_rx_q``/``nb_tx_q`` in
    ``rte_eth_dev_configure``); per-queue descriptor counts come later in
    ``rx_queue_setup``/``tx_queue_setup``, exactly like DPDK.
    """

    n_rx_queues: int = 1
    n_tx_queues: int = 1
    rss_key: Optional[bytes] = None          # None == the Microsoft default key
    rss_table_size: int = DEFAULT_TABLE_SIZE
    # wire parameters (virtual-time mode): serialization rate of the attached
    # link (<= 0 == ideal wire, the legacy behaviour) + one-way propagation
    link_gbps: float = 0.0
    link_latency_ns: int = 0

    def __post_init__(self) -> None:
        if self.n_rx_queues < 1 or self.n_tx_queues < 1:
            raise ValueError("queue counts must be >= 1")
        if self.n_rx_queues != self.n_tx_queues:
            # the Port engine pairs RX/TX queues one-to-one
            raise ValueError("n_rx_queues must equal n_tx_queues")
        if self.link_latency_ns < 0:
            raise ValueError("link_latency_ns must be >= 0")


@dataclass(frozen=True)
class EthStats:
    """Basic ``rte_eth_stats``: the aggregate counter block every DPDK app
    reads first."""

    ipackets: int = 0    # received by the host (delivered into RX rings)
    opackets: int = 0    # accepted for transmission (posted to TX rings)
    ibytes: int = 0      # bytes delivered into RX rings
    obytes: int = 0      # bytes accepted for transmission (pairs opackets;
    #                      wire-drained bytes are xstats tx_q*_transmitted_bytes)
    imissed: int = 0     # dropped at the NIC: no free RX descriptor
    ierrors: int = 0     # malformed input (always 0 in this model)
    oerrors: int = 0     # TX post failures (TX ring full)
    rx_nombuf: int = 0   # mbuf allocation failures (pool-scoped: the mempool
    #                      may be shared between devices, like a shared DPDK
    #                      mempool; since stats_reset on this device)

    def as_dict(self) -> Dict[str, int]:
        return dict(ipackets=self.ipackets, opackets=self.opackets,
                    ibytes=self.ibytes, obytes=self.obytes,
                    imissed=self.imissed, ierrors=self.ierrors,
                    oerrors=self.oerrors, rx_nombuf=self.rx_nombuf)


class EthDev:
    """One NIC device speaking the ``rte_ethdev`` lifecycle + burst API.

    Owns a :class:`~repro.core.pmd.Port` as its internal engine once started;
    everything the legacy wire side needs (``deliver``, ``drain_tx``,
    per-queue counters) is delegated so an ``EthDev`` is a drop-in for a
    ``Port`` in servers and the load generator.
    """

    def __init__(self, pool: PacketPool, dev_id: int = 0):
        self.pool = pool
        self.dev_id = int(dev_id)
        self._state = EthDevState.UNCONFIGURED
        self._conf: Optional[EthConf] = None
        self._rx_rings: List[Optional[RxDescriptorRing]] = []
        self._tx_rings: List[Optional[TxDescriptorRing]] = []
        self._port: Optional[Port] = None
        self._rss: Optional[RssIndirection] = None
        # rx_nombuf baseline: the mempool may be shared between devices
        # (pool.alloc_failures is pool-scoped); the baseline makes
        # stats_reset() restart this device's view of the counter.
        self._nombuf_base = 0
        # event scheduler driving this device's descriptor-cache writeback
        # timeout timers (virtual-time DCA mode; see attach_dca)
        self.event_sched = None

    # -- lifecycle ------------------------------------------------------------
    @property
    def state(self) -> EthDevState:
        return self._state

    @property
    def conf(self) -> Optional[EthConf]:
        return self._conf

    def configure(self, conf: EthConf) -> "EthDev":
        """``rte_eth_dev_configure``: fix queue counts + RSS.  Legal from
        UNCONFIGURED, CONFIGURED (re-configure) and STOPPED; a running device
        must be stopped first.  Reconfiguring wipes all queue setups."""
        if self._state is EthDevState.STARTED:
            raise EthDevError(
                f"dev {self.dev_id}: configure() while STARTED; call dev_stop() first")
        self._conf = conf
        self._rx_rings = [None] * conf.n_rx_queues
        self._tx_rings = [None] * conf.n_tx_queues
        self._port = None
        # RSS state lives with the configuration: it survives stop/start
        # cycles (indirection-table rebalances persist, like hardware) and
        # resets on reconfigure.
        self._rss = RssIndirection(conf.n_rx_queues,
                                   table_size=conf.rss_table_size,
                                   key=conf.rss_key)
        self._state = EthDevState.CONFIGURED
        return self

    def rx_queue_setup(self, queue_id: int, nb_desc: int,
                       writeback_threshold: Optional[int] = 32) -> "EthDev":
        """``rte_eth_rx_queue_setup``: size one RX descriptor ring.  The
        writeback threshold is the paper's §3.1.4 parameter."""
        self._check_queue_setup("rx", queue_id, len(self._rx_rings), nb_desc)
        self._rx_rings[queue_id] = RxDescriptorRing(
            nb_desc, writeback_threshold=writeback_threshold, queue_id=queue_id)
        return self

    def tx_queue_setup(self, queue_id: int, nb_desc: int) -> "EthDev":
        """``rte_eth_tx_queue_setup``: size one TX descriptor ring."""
        self._check_queue_setup("tx", queue_id, len(self._tx_rings), nb_desc)
        self._tx_rings[queue_id] = TxDescriptorRing(nb_desc, queue_id=queue_id)
        return self

    def _check_queue_setup(self, side: str, queue_id: int, n_queues: int,
                           nb_desc: int) -> None:
        if self._state is EthDevState.UNCONFIGURED:
            raise EthDevError(
                f"dev {self.dev_id}: {side}_queue_setup before configure()")
        if self._state is EthDevState.STARTED:
            raise EthDevError(
                f"dev {self.dev_id}: {side}_queue_setup while STARTED; "
                "call dev_stop() first")
        if not 0 <= queue_id < n_queues:
            raise EthDevError(
                f"dev {self.dev_id}: {side} queue {queue_id} out of range "
                f"[0, {n_queues})")
        if nb_desc < 1:
            raise EthDevError(f"dev {self.dev_id}: nb_desc must be >= 1")

    def dev_start(self) -> "EthDev":
        """``rte_eth_dev_start``: assemble the Port engine and open the
        dataplane.  Every queue must have been set up."""
        if self._state is EthDevState.STARTED:
            raise EthDevError(f"dev {self.dev_id}: already STARTED")
        if self._state is EthDevState.UNCONFIGURED:
            raise EthDevError(f"dev {self.dev_id}: dev_start before configure()")
        missing = [i for i, r in enumerate(self._rx_rings) if r is None]
        missing += [i for i, r in enumerate(self._tx_rings) if r is None]
        if missing:
            raise EthDevError(
                f"dev {self.dev_id}: dev_start with unset queues {sorted(set(missing))}")
        # Re-assemble the engine from the current rings every start, so a
        # queue re-setup done while STOPPED takes effect on the next start
        # (DPDK semantics).  Counters persist because the rings persist.
        assert self._conf is not None
        self._port = Port(self.pool, self._rx_rings, self._tx_rings,
                          rss=self._rss,
                          link_gbps=self._conf.link_gbps,
                          link_latency_ns=self._conf.link_latency_ns)
        self._state = EthDevState.STARTED
        return self

    def dev_stop(self) -> "EthDev":
        """``rte_eth_dev_stop``: close the dataplane.  Descriptor caches are
        flushed (a stopping NIC publishes completed descriptors); counters and
        rings persist so a later ``dev_start`` resumes, DPDK-style."""
        if self._state is not EthDevState.STARTED:
            raise EthDevError(
                f"dev {self.dev_id}: dev_stop in state {self._state.name}")
        assert self._port is not None
        self._port.flush_rx()
        self._state = EthDevState.STOPPED
        return self

    def attach_dca(self, sched, writeback_timeout_ns: int,
                   writeback_dma_ns: int = 0) -> "EthDev":
        """Arm the descriptor-cache **writeback timeout** (ITR analogue) on
        every RX ring: completions idling in a ring's descriptor cache are
        flushed ``writeback_timeout_ns`` after the first one arrives, as an
        event on ``sched``.  ``writeback_dma_ns`` additionally models the
        writeback DMA transfer time — descriptors become PMD-visible that
        many ns after the threshold crossing (0 == instantaneous, the legacy
        behaviour).  Call after the queues are set up (a later
        ``configure()`` builds fresh rings and must be re-attached); the
        scheduler is also what the virtual-time load generator drives, so it
        must share the testbed's SimClock."""
        if self._state is EthDevState.UNCONFIGURED:
            raise EthDevError(
                f"dev {self.dev_id}: attach_dca before configure()")
        self.event_sched = sched
        for ring in self._rx_rings:
            if ring is not None:
                ring.attach_scheduler(sched, writeback_timeout_ns,
                                      writeback_dma_ns)
        return self

    def _started_port(self) -> Port:
        if self._state is not EthDevState.STARTED or self._port is None:
            raise EthDevError(
                f"dev {self.dev_id}: dataplane call in state {self._state.name}")
        return self._port

    # -- burst dataplane (PMD side; STARTED only) -----------------------------
    def rx_burst(self, queue_id: int, nb_pkts: int) -> Tuple[np.ndarray, np.ndarray]:
        """``rte_eth_rx_burst``: harvest up to ``nb_pkts`` completed RX
        descriptors from one queue → (slots, lengths) arrays, zero copy."""
        return self._started_port().rx_burst(queue_id, nb_pkts)

    def tx_burst(self, queue_id: int, slots: np.ndarray,
                 lengths: np.ndarray) -> int:
        """``rte_eth_tx_burst``: post a burst on one TX queue; returns the
        number accepted (the rest is the caller's to free, like DPDK)."""
        return self._started_port().tx_burst(queue_id, slots, lengths)

    # -- stats (DPDK two-tier scheme) -----------------------------------------
    def stats(self) -> EthStats:
        """``rte_eth_stats_get``: the basic aggregate counter block."""
        port = self._port
        if port is None:
            return EthStats()
        return EthStats(
            ipackets=port.rx_delivered,
            opackets=port.tx_posted,
            ibytes=sum(r.delivered_bytes for r in port.rx_queues),
            obytes=sum(r.posted_bytes for r in port.tx_queues),
            imissed=port.rx_dropped,
            ierrors=0,
            oerrors=sum(r.rejected for r in port.tx_queues),
            rx_nombuf=self.pool.alloc_failures - self._nombuf_base,
        )

    def xstats(self) -> Dict[str, int]:
        """``rte_eth_xstats_get``: named extended counters.

        Naming follows DPDK PMDs: per-queue ``rx_q{N}_packets`` (delivered
        into the ring), ``rx_q{N}_errors`` (dropped: ring full),
        ``tx_q{N}_packets`` (posted), plus device-level ``imissed``,
        ``rx_nombuf`` and the paper-specific descriptor-writeback counters.
        Sums are exact over the legacy Port counters:
        ``sum(rx_q*_packets) == Port.rx_delivered`` etc.
        """
        out: Dict[str, int] = {}
        port = self._port
        if port is None:
            return out
        for q, ring in enumerate(port.rx_queues):
            out[f"rx_q{q}_packets"] = ring.delivered
            out[f"rx_q{q}_errors"] = ring.dropped
            out[f"rx_q{q}_writebacks"] = ring.writebacks
            out[f"rx_q{q}_timeout_flushes"] = ring.timeout_flushes
        for q, ring in enumerate(port.tx_queues):
            out[f"tx_q{q}_packets"] = ring.posted
            out[f"tx_q{q}_errors"] = ring.rejected
            out[f"tx_q{q}_transmitted"] = ring.transmitted
            out[f"tx_q{q}_transmitted_bytes"] = ring.transmitted_bytes
        out["rx_good_packets"] = port.rx_delivered
        out["tx_good_packets"] = port.tx_posted
        out["imissed"] = port.rx_dropped
        out["rx_nombuf"] = self.pool.alloc_failures - self._nombuf_base
        return out

    def stats_reset(self) -> None:
        """``rte_eth_stats_reset``: zero every ring counter and restart this
        device's view of the pool-scoped rx_nombuf counter."""
        self._nombuf_base = self.pool.alloc_failures
        port = self._port
        if port is None:
            return
        for ring in port.rx_queues:
            ring.delivered = 0
            ring.delivered_bytes = 0
            ring.dropped = 0
            ring.writebacks = 0
            ring.writeback_sizes = []
            ring.timeout_flushes = 0
        for ring in port.tx_queues:
            ring.posted = 0
            ring.posted_bytes = 0
            ring.rejected = 0
            ring.transmitted = 0
            ring.transmitted_bytes = 0

    # -- engine / wire-side delegation ---------------------------------------
    # An EthDev is a drop-in for a Port: servers poll its queues, the load
    # generator plays the wire.  All of these require the dataplane open.
    @property
    def port(self) -> Port:
        """The internal engine (STARTED only) — the legacy object, for code
        that still needs raw ring access."""
        return self._started_port()

    @property
    def n_queues(self) -> int:
        if self._conf is None:
            return 0
        return self._conf.n_rx_queues

    @property
    def link_gbps(self) -> float:
        return self._conf.link_gbps if self._conf is not None else 0.0

    @property
    def link_latency_ns(self) -> int:
        return self._conf.link_latency_ns if self._conf is not None else 0

    @property
    def rx_queues(self) -> List[RxDescriptorRing]:
        return self._started_port().rx_queues

    @property
    def tx_queues(self) -> List[TxDescriptorRing]:
        return self._started_port().tx_queues

    @property
    def rss(self) -> RssIndirection:
        return self._started_port().rss

    def deliver(self, packet_slot: int, length: int) -> bool:
        return self._started_port().deliver(packet_slot, length)

    def deliver_burst(self, packet_slots: np.ndarray, lengths: np.ndarray) -> int:
        return self._started_port().deliver_burst(packet_slots, lengths)

    def flush_rx(self) -> None:
        self._started_port().flush_rx()

    def drain_tx(self, max_n_per_queue: int):
        return self._started_port().drain_tx(max_n_per_queue)

    def drain_tx_bursts(self, max_n_per_queue: int):
        return self._started_port().drain_tx_bursts(max_n_per_queue)

    @property
    def tx_pending(self) -> int:
        return self._started_port().tx_pending

    @property
    def tx_posted(self) -> int:
        return self._started_port().tx_posted

    @property
    def rx_delivered(self) -> int:
        return self._started_port().rx_delivered

    @property
    def rx_dropped(self) -> int:
        return self._started_port().rx_dropped

    def rx_queue_delivered(self) -> List[int]:
        return self._started_port().rx_queue_delivered()

    def rx_queue_dropped(self) -> List[int]:
        return self._started_port().rx_queue_dropped()

    def queue_occupancy(self) -> List[int]:
        return self._started_port().queue_occupancy()

    # -- convenience ----------------------------------------------------------
    @classmethod
    def make(
        cls,
        pool: PacketPool,
        ring_size: int = 256,
        writeback_threshold: Optional[int] = 32,
        n_queues: int = 1,
        rss_key: Optional[bytes] = None,
        rss_table_size: int = DEFAULT_TABLE_SIZE,
        dev_id: int = 0,
        link_gbps: float = 0.0,
        link_latency_ns: int = 0,
    ) -> "EthDev":
        """configure + set up every queue + start, in one call (the shape
        every DPDK example's ``port_init()`` takes)."""
        dev = cls(pool, dev_id=dev_id).configure(EthConf(
            n_rx_queues=n_queues, n_tx_queues=n_queues,
            rss_key=rss_key, rss_table_size=rss_table_size,
            link_gbps=link_gbps, link_latency_ns=link_latency_ns))
        for q in range(n_queues):
            dev.rx_queue_setup(q, ring_size, writeback_threshold=writeback_threshold)
            dev.tx_queue_setup(q, ring_size)
        return dev.dev_start()
