"""Packet representation over a pre-pinned buffer arena.

This is the DPDK ``rte_mbuf`` / hugepage-mempool analogue: all packet payloads
live in one contiguous, pre-allocated numpy arena ("pinned hugepages"); a packet
is just (slot index, length) plus zero-copy views into the arena.  The
interrupt-driven baseline (:mod:`repro.core.kernel_stack`) deliberately does NOT
use the pool — it allocates and copies per packet, like sk_buffs.

Wire layout (offsets in bytes), loosely Ethernet-shaped:

    0..5    dst "mac"
    6..11   src "mac"
    12..13  ethertype (we use 0x88B5, local experimental; bit 0 of byte 12
            doubles as the ECN CE mark — see ``set_ce``/``read_ce``)
    14..21  u64 sequence number (little endian)
    22..29  u64 transmit timestamp in ns (the EtherLoadGen stamp; offset is
            configurable per the paper — "adds a timestamp to each outgoing
            packet at a configurable offset")
    30..41  flow 4-tuple, big endian (src_ip u32, dst_ip u32, src_port u16,
            dst_port u16) — the fields RSS hashes to steer the frame to an
            RX queue (see :mod:`repro.core.rss`)
    42..    payload
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

ETH_HEADER_SIZE = 14
SEQ_OFFSET = 14
DEFAULT_TS_OFFSET = 22
FLOW_OFFSET = 30
FLOW_SIZE = 12  # src_ip(4) + dst_ip(4) + src_port(2) + dst_port(2), big endian
MIN_FRAME = 64
DEFAULT_MTU = 1518
ETHERTYPE = 0x88B5

# ECN congestion-experienced mark: bit 0 of the ethertype high byte (0x88 is
# even, so the bit is born clear).  The location is deliberate — outside the
# seq/ts/flow fields the loadgen and echo servers rewrite, untouched by
# ``swap_macs(_vec)``/``swap_flow_ips(_vec)``, and excluded from both
# ``payload_checksum`` and ``echo_payload_checksum`` — so a switch-applied
# mark survives the full echo round-trip back to the client that sent it.
CE_OFFSET = 12
CE_MASK = 0x01


def _u64_to_bytes(value: int) -> np.ndarray:
    return np.frombuffer(int(value).to_bytes(8, "little"), dtype=np.uint8).copy()


def _bytes_to_u64(buf: np.ndarray) -> int:
    return int.from_bytes(bytes(buf[:8]), "little")


class PacketPool:
    """Pre-pinned fixed-slot packet arena + free list (DPDK mempool analogue).

    ``alloc``/``free`` never touch the allocator after construction; payload
    access is by zero-copy numpy views.  Single lock-free-under-GIL free ring.
    """

    def __init__(self, n_slots: int, slot_size: int = DEFAULT_MTU):
        if n_slots <= 0:
            raise ValueError("n_slots must be positive")
        self.n_slots = int(n_slots)
        self.slot_size = int(slot_size)
        self.arena = np.zeros((self.n_slots, self.slot_size), dtype=np.uint8)
        self.lengths = np.zeros(self.n_slots, dtype=np.int32)
        # free list as a ring of slot indices; head==push cursor, tail==pop cursor
        self._free = list(range(self.n_slots - 1, -1, -1))
        self.alloc_failures = 0

    # -- allocation ---------------------------------------------------------
    def alloc(self) -> Optional[int]:
        if not self._free:
            self.alloc_failures += 1
            return None
        return self._free.pop()

    def alloc_burst(self, n: int) -> List[int]:
        take = min(n, len(self._free))
        if take < n:
            self.alloc_failures += n - take
        if take == 0:
            return []
        out = self._free[-take:][::-1]
        del self._free[-take:]
        return out

    def free(self, slot: int) -> None:
        self._free.append(slot)

    def free_burst(self, slots: Sequence[int]) -> None:
        self._free.extend(slots)

    @property
    def n_free(self) -> int:
        return len(self._free)

    # -- packet access ------------------------------------------------------
    def view(self, slot: int, length: Optional[int] = None) -> np.ndarray:
        """Zero-copy view of a packet's bytes."""
        n = self.lengths[slot] if length is None else length
        return self.arena[slot, : int(n)]

    def write_packet(
        self,
        slot: int,
        *,
        seq: int,
        length: int,
        ts_offset: int = DEFAULT_TS_OFFSET,
        timestamp_ns: int = 0,
        fill: Optional[int] = None,
        rng: Optional[np.random.Generator] = None,
    ) -> None:
        """Format a frame in-place (header + seq + timestamp + payload)."""
        if length < MIN_FRAME or length > self.slot_size:
            raise ValueError(f"bad frame length {length}")
        buf = self.arena[slot]
        buf[0:6] = 0xFF  # broadcast dst
        buf[6:12] = 0xAB  # loadgen src
        buf[12] = (ETHERTYPE >> 8) & 0xFF
        buf[13] = ETHERTYPE & 0xFF
        buf[SEQ_OFFSET : SEQ_OFFSET + 8] = _u64_to_bytes(seq)
        payload_start = ts_offset + 8
        if rng is not None:
            buf[payload_start:length] = rng.integers(
                0, 256, size=max(0, length - payload_start), dtype=np.uint8
            )
        elif fill is not None:
            buf[payload_start:length] = fill
        stamp(buf, ts_offset, timestamp_ns)
        self.lengths[slot] = length


# -- header/field helpers (operate on raw views) ----------------------------

def stamp(buf: np.ndarray, ts_offset: int, ns: int) -> None:
    buf[ts_offset : ts_offset + 8] = _u64_to_bytes(ns)


def read_stamp(buf: np.ndarray, ts_offset: int) -> int:
    return _bytes_to_u64(buf[ts_offset : ts_offset + 8])


def read_seq(buf: np.ndarray) -> int:
    return _bytes_to_u64(buf[SEQ_OFFSET : SEQ_OFFSET + 8])


def write_seq(buf: np.ndarray, seq: int) -> None:
    buf[SEQ_OFFSET : SEQ_OFFSET + 8] = _u64_to_bytes(seq)


def set_ce(buf: np.ndarray) -> None:
    """Mark a frame congestion-experienced (the ECN-marking switch op)."""
    buf[CE_OFFSET] |= CE_MASK


def clear_ce(buf: np.ndarray) -> None:
    buf[CE_OFFSET] &= 0xFF ^ CE_MASK


def read_ce(buf: np.ndarray) -> bool:
    """True iff the frame carries the congestion-experienced mark."""
    return bool(buf[CE_OFFSET] & CE_MASK)


def swap_macs(buf: np.ndarray) -> None:
    """The L2Fwd operation: swap src/dst 'mac' addresses in place."""
    tmp = buf[0:6].copy()
    buf[0:6] = buf[6:12]
    buf[6:12] = tmp


DEFAULT_SRC_IP_BASE = 0x0A000000  # 10.0.0.0: the loadgen's client space
DEFAULT_DST_IP = 0xC0A80001       # 192.168.0.1: the single-host server


def flow_tuple_for_id(
    flow_id: int,
    src_ip_base: Optional[int] = None,
    dst_ip: Optional[int] = None,
) -> Tuple[int, int, int, int]:
    """Synthetic (src_ip, dst_ip, src_port, dst_port) for an abstract flow id.

    Distinct ids differ in src_ip and src_port — the fields real load
    generators sweep — so distinct flows hash apart under RSS.  Topology
    scenarios override ``src_ip_base`` (a per-generator /16 such as
    ``10.g.0.0``, so a switch can route replies back to the right client)
    and ``dst_ip`` (the target node's address, what the switch forwards on).
    """
    flow_id = int(flow_id)
    base = DEFAULT_SRC_IP_BASE if src_ip_base is None else int(src_ip_base)
    src_ip = base | (flow_id & 0xFFFF)
    src_port = 1024 + (flow_id % 60000)
    dst_port = 443
    return (src_ip,
            DEFAULT_DST_IP if dst_ip is None else int(dst_ip),
            src_port, dst_port)


def write_flow(buf: np.ndarray, src_ip: int, dst_ip: int,
               src_port: int, dst_port: int) -> None:
    """Write the RSS flow 4-tuple (big endian, like the wire)."""
    raw = (int(src_ip).to_bytes(4, "big") + int(dst_ip).to_bytes(4, "big")
           + int(src_port).to_bytes(2, "big") + int(dst_port).to_bytes(2, "big"))
    buf[FLOW_OFFSET : FLOW_OFFSET + FLOW_SIZE] = np.frombuffer(raw, dtype=np.uint8)


def read_flow(buf: np.ndarray) -> Tuple[int, int, int, int]:
    raw = bytes(buf[FLOW_OFFSET : FLOW_OFFSET + FLOW_SIZE])
    return (
        int.from_bytes(raw[0:4], "big"),
        int.from_bytes(raw[4:8], "big"),
        int.from_bytes(raw[8:10], "big"),
        int.from_bytes(raw[10:12], "big"),
    )


def flow_bytes(buf: np.ndarray) -> np.ndarray:
    """Zero-copy view of the 12 flow-tuple bytes (the RSS hash input)."""
    return buf[FLOW_OFFSET : FLOW_OFFSET + FLOW_SIZE]


def read_dst_ip(buf: np.ndarray) -> int:
    """The frame's destination address (flow dst_ip, big endian) — the field
    a :class:`~repro.core.switch.Switch` forwards on."""
    return int.from_bytes(bytes(buf[FLOW_OFFSET + 4 : FLOW_OFFSET + 8]), "big")


def swap_flow_ips(buf: np.ndarray) -> None:
    """Swap the flow src/dst IPs in place — the reply-addressing half of an
    echo server (pairs :func:`swap_macs`), so switched topologies can route
    the reply back to the client that sent the request."""
    tmp = buf[FLOW_OFFSET : FLOW_OFFSET + 4].copy()
    buf[FLOW_OFFSET : FLOW_OFFSET + 4] = buf[FLOW_OFFSET + 4 : FLOW_OFFSET + 8]
    buf[FLOW_OFFSET + 4 : FLOW_OFFSET + 8] = tmp


def l2fwd_echo(buf: np.ndarray) -> None:
    """The topology-aware L2Fwd transform: swap macs AND flow IPs, so the
    forwarded frame is addressed back to its sender."""
    swap_macs(buf)
    swap_flow_ips(buf)


def checksum(buf: np.ndarray) -> int:
    """CRC32 over the whole frame (payload-integrity check, paper §4.2)."""
    return zlib.crc32(buf.tobytes()) & 0xFFFFFFFF


def payload_checksum(buf: np.ndarray, ts_offset: int = DEFAULT_TS_OFFSET) -> int:
    """CRC32 over payload only (excludes header/seq/timestamp, which L2Fwd and
    the loadgen legitimately rewrite)."""
    return zlib.crc32(buf[ts_offset + 8 :].tobytes()) & 0xFFFFFFFF


def echo_payload_checksum(buf: np.ndarray) -> int:
    """CRC32 over payload past the flow tuple — the integrity check for
    switched topologies, where the echo server legitimately rewrites the
    flow IPs (:func:`swap_flow_ips`) in addition to header/seq/timestamp."""
    return zlib.crc32(buf[FLOW_OFFSET + FLOW_SIZE :].tobytes()) & 0xFFFFFFFF


# -- vectorized burst helpers (DPDK-style amortization) ---------------------
#
# DPDK's performance comes from amortizing *everything* over a burst: one
# descriptor-ring sweep, one prefetch train, one header rewrite loop that the
# compiler vectorizes.  The Python analogue is doing each burst operation as a
# single fancy-indexed numpy op over the shared arena instead of a per-packet
# interpreter loop.  The kernel-stack baseline cannot do this: its per-packet
# skb alloc/copy/syscall structure is the bottleneck being modeled.

def write_packets_vec(
    pool: PacketPool,
    slots: np.ndarray,
    seqs: np.ndarray,
    length: int,
    ts_offset: int,
    timestamp_ns: int,
) -> None:
    """Format a burst of identical-size frames in one shot."""
    arena = pool.arena
    arena[slots, 0:6] = 0xFF
    arena[slots, 6:12] = 0xAB
    arena[slots, 12] = (ETHERTYPE >> 8) & 0xFF
    arena[slots, 13] = ETHERTYPE & 0xFF
    arena[slots, SEQ_OFFSET : SEQ_OFFSET + 8] = (
        seqs.astype("<u8").view(np.uint8).reshape(-1, 8)
    )
    ts = np.full(len(slots), timestamp_ns, dtype="<u8")
    arena[slots, ts_offset : ts_offset + 8] = ts.view(np.uint8).reshape(-1, 8)
    payload_start = ts_offset + 8
    arena[slots, payload_start:length] = (
        (seqs & 0xFF).astype(np.uint8)[:, None]
    )
    pool.lengths[slots] = length


def read_stamps_vec(pool: PacketPool, slots: np.ndarray, ts_offset: int) -> np.ndarray:
    """Read a burst of timestamps → int64 ns array."""
    raw = pool.arena[slots, ts_offset : ts_offset + 8]
    return raw.copy().view("<u8").reshape(-1).astype(np.int64)


def read_seqs_vec(pool: PacketPool, slots: np.ndarray) -> np.ndarray:
    raw = pool.arena[slots, SEQ_OFFSET : SEQ_OFFSET + 8]
    return raw.copy().view("<u8").reshape(-1).astype(np.int64)


def write_flow_ids_vec(pool: PacketPool, slots: np.ndarray,
                       flow_ids: np.ndarray,
                       src_ip_base: Optional[int] = None,
                       dst_ip: Optional[int] = None) -> None:
    """Write synthetic flow 4-tuples for a burst (one fancy-indexed store).

    Same mapping as :func:`flow_tuple_for_id` (including its topology
    ``src_ip_base``/``dst_ip`` overrides), vectorized over the burst.
    """
    arena = pool.arena
    ids = flow_ids.astype(np.int64)
    base = DEFAULT_SRC_IP_BASE if src_ip_base is None else int(src_ip_base)
    src_ip = (base | (ids & 0xFFFF)).astype(">u4")
    dst_ip = np.full(len(ids),
                     DEFAULT_DST_IP if dst_ip is None else int(dst_ip),
                     dtype=">u4")
    src_port = (1024 + (ids % 60000)).astype(">u2")
    dst_port = np.full(len(ids), 443, dtype=">u2")
    arena[slots, FLOW_OFFSET : FLOW_OFFSET + 4] = src_ip.view(np.uint8).reshape(-1, 4)
    arena[slots, FLOW_OFFSET + 4 : FLOW_OFFSET + 8] = dst_ip.view(np.uint8).reshape(-1, 4)
    arena[slots, FLOW_OFFSET + 8 : FLOW_OFFSET + 10] = src_port.view(np.uint8).reshape(-1, 2)
    arena[slots, FLOW_OFFSET + 10 : FLOW_OFFSET + 12] = dst_port.view(np.uint8).reshape(-1, 2)


def read_flow_bytes_vec(pool: PacketPool, slots: np.ndarray) -> np.ndarray:
    """(N, 12) raw flow-tuple bytes for a burst — the RSS hash input."""
    return pool.arena[slots, FLOW_OFFSET : FLOW_OFFSET + FLOW_SIZE]


def read_flow_bytes(pool: PacketPool, slot: int) -> np.ndarray:
    """(12,) flow-tuple bytes of one packet, as a zero-copy view.

    The scalar sibling of :func:`read_flow_bytes_vec`: basic slicing of the
    arena row allocates no array data, which is what the single-packet
    delivery hot path (:meth:`repro.core.pmd.Port.deliver`) needs.
    """
    return pool.arena[slot, FLOW_OFFSET : FLOW_OFFSET + FLOW_SIZE]


def set_ce_vec(pool: PacketPool, slots: np.ndarray) -> None:
    """Burst variant of :func:`set_ce`."""
    pool.arena[slots, CE_OFFSET] |= CE_MASK


def read_ce_vec(pool: PacketPool, slots: np.ndarray) -> np.ndarray:
    """Burst variant of :func:`read_ce` — boolean array over the burst."""
    return (pool.arena[slots, CE_OFFSET] & CE_MASK) != 0


def swap_macs_vec(pool: PacketPool, slots: np.ndarray,
                  lengths: Optional[np.ndarray] = None) -> None:
    """L2Fwd header rewrite for a whole burst in one vectorized op."""
    arena = pool.arena
    dst = arena[slots, 0:6].copy()
    arena[slots, 0:6] = arena[slots, 6:12]
    arena[slots, 6:12] = dst


def swap_flow_ips_vec(pool: PacketPool, slots: np.ndarray,
                      lengths: Optional[np.ndarray] = None) -> None:
    """Burst variant of :func:`swap_flow_ips`."""
    arena = pool.arena
    src = arena[slots, FLOW_OFFSET : FLOW_OFFSET + 4].copy()
    arena[slots, FLOW_OFFSET : FLOW_OFFSET + 4] = (
        arena[slots, FLOW_OFFSET + 4 : FLOW_OFFSET + 8])
    arena[slots, FLOW_OFFSET + 4 : FLOW_OFFSET + 8] = src


def l2fwd_echo_vec(pool: PacketPool, slots: np.ndarray,
                   lengths: Optional[np.ndarray] = None) -> None:
    """Burst variant of :func:`l2fwd_echo` (macs + flow IPs swapped)."""
    swap_macs_vec(pool, slots, lengths)
    swap_flow_ips_vec(pool, slots, lengths)


@dataclass
class PacketRef:
    """A packet in flight = (pool, slot, length). Zero-copy handle."""

    pool: PacketPool
    slot: int
    length: int

    @property
    def buf(self) -> np.ndarray:
        return self.pool.view(self.slot, self.length)

    def release(self) -> None:
        self.pool.free(self.slot)
