"""Unified NetworkStack interface: per-lcore engines over (port, queue) pairs.

DPDK's execution model assigns each *lcore* (logical core) a set of
(port, queue) pairs that it polls run-to-completion; with RSS steering flows
to queues, cores scale without sharing — the paper's Fig. 3(a) core axis.
This module is the common machinery all three servers
(:class:`~repro.core.pmd.BypassL2FwdServer`,
:class:`~repro.core.pmd.PipelineServer`,
:class:`~repro.core.kernel_stack.KernelStackServer`) now run on:

* :class:`Lcore` — one engine: an ordered list of (port, queue) assignments
  plus its processing burst size (per-lcore via
  :class:`~repro.core.dca.BurstPlan`).
* :class:`NetworkStack` — owns the lcores and per-queue
  :class:`ServerStats`.  ``poll_once`` schedules the lcores **sequentially
  round-robin**, which is GIL-aware and deterministic: on a 1-core host it
  measures exactly one core's worth of work in a reproducible order.
  Threads are optional (``start_lcore_threads``) for hosts with real
  parallelism.

Stats discipline: every (port, queue) pair has its own :class:`ServerStats`
written by exactly one lcore (no sharing, like DPDK's per-queue counters);
``stack.stats`` aggregates them on read, so the seed-era single-stats API
keeps working.
"""
from __future__ import annotations

import dataclasses
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# Power-of-two burst-size bins: bucket i counts bursts of [2^i, 2^(i+1)).
# Fixed size => stats memory is O(1) regardless of run length.
N_BURST_BUCKETS = 12


@dataclass
class ServerStats:
    rx_packets: int = 0
    tx_packets: int = 0
    rx_bytes: int = 0
    poll_iterations: int = 0
    empty_polls: int = 0
    burst_count: int = 0
    burst_packets: int = 0
    burst_buckets: np.ndarray = field(
        default_factory=lambda: np.zeros(N_BURST_BUCKETS, dtype=np.int64)
    )

    def record_burst(self, n: int) -> None:
        self.burst_count += 1
        self.burst_packets += int(n)
        self.burst_buckets[min(max(int(n), 1).bit_length() - 1,
                               N_BURST_BUCKETS - 1)] += 1

    @property
    def avg_burst(self) -> float:
        return self.burst_packets / self.burst_count if self.burst_count else 0.0

    @property
    def burst_histogram(self) -> List[Dict[str, int]]:
        """Fixed-bin view of burst sizes: [{lo, hi, count}], empty bins omitted."""
        return [
            {"lo": 1 << i, "hi": (1 << (i + 1)) - 1, "count": int(c)}
            for i, c in enumerate(self.burst_buckets)
            if c
        ]

    def merge_from(self, other: "ServerStats") -> "ServerStats":
        """Accumulate another stats object (per-queue → aggregate)."""
        for f in dataclasses.fields(other):
            v = getattr(other, f.name)
            if isinstance(v, np.ndarray):
                getattr(self, f.name).__iadd__(v)
            elif isinstance(v, int):
                setattr(self, f.name, getattr(self, f.name, 0) + v)
        return self


@dataclass
class Lcore:
    """One polling engine: services its (port_idx, queue_idx) pairs in order."""

    lcore_id: int
    assignments: List[Tuple[int, int]]
    burst_size: int = 32


class NetworkStack:
    """Base class every server implements: lcores + per-queue stats.

    Subclasses implement :meth:`_service_queue` (one lcore quantum on one
    queue) or override :meth:`run_lcore` for non-queue-parallel topologies
    (the pipeline's stage lcores).
    """

    stats_cls = ServerStats

    def __init__(
        self,
        ports: Sequence[object],
        n_lcores: Optional[int] = None,
        burst_size: int = 32,
        plan: Optional[object] = None,  # duck-typed BurstPlan (burst_for)
    ):
        self.ports = list(ports)
        self.queue_pairs: List[Tuple[int, int]] = [
            (pi, qi)
            for pi, p in enumerate(self.ports)
            for qi in range(getattr(p, "n_queues", 1))
        ]
        if n_lcores is None:
            n_lcores = len(self.queue_pairs)  # DPDK default: one lcore per queue
        if n_lcores < 1:
            raise ValueError("n_lcores must be >= 1")
        self.lcores: List[Lcore] = []
        for i in range(n_lcores):
            assigned = [pr for j, pr in enumerate(self.queue_pairs)
                        if j % n_lcores == i]
            b = plan.burst_for(i) if plan is not None else burst_size
            self.lcores.append(Lcore(i, assigned, b))
        self.queue_stats: Dict[Tuple[int, int], ServerStats] = {
            pr: self.stats_cls() for pr in self.queue_pairs
        }
        self._stop_evt = threading.Event()
        self._threads: List[threading.Thread] = []

    # -- scheduling -----------------------------------------------------------
    def poll_once(self) -> int:
        """One scheduling round: every lcore runs once, sequentially.

        Deterministic (fixed lcore order, fixed assignment order within each
        lcore) so single-core measurements are exactly reproducible.
        """
        total = 0
        for lcore in self.lcores:
            total += self.run_lcore(lcore)
        return total

    def run_lcore(self, lcore: Lcore) -> int:
        """One run-to-completion pass over the lcore's assigned queues."""
        total = 0
        for pi, qi in lcore.assignments:
            total += self._service_queue(lcore, pi, qi, self.queue_stats[(pi, qi)])
        return total

    def _service_queue(self, lcore: Lcore, port_idx: int, queue_idx: int,
                       qstats: ServerStats) -> int:
        raise NotImplementedError

    # -- optional threaded execution (real-parallelism hosts) -----------------
    def start_lcore_threads(self) -> None:
        """Run each lcore in its own thread (GIL-serialized on 1-core hosts;
        use sequential ``poll_once`` for bandwidth numbers there)."""
        if self._threads:
            return
        self._stop_evt.clear()

        def loop(lc: Lcore) -> None:
            while not self._stop_evt.is_set():
                self.run_lcore(lc)

        self._threads = [
            threading.Thread(target=loop, args=(lc,), daemon=True,
                             name=f"lcore-{lc.lcore_id}")
            for lc in self.lcores
        ]
        for t in self._threads:
            t.start()

    def stop_lcore_threads(self) -> None:
        self._stop_evt.set()
        for t in self._threads:
            t.join(timeout=5)
        self._threads = []

    # -- stats ----------------------------------------------------------------
    def per_queue_stats(self) -> Dict[Tuple[int, int], ServerStats]:
        """Per-(port, queue) counters; each written by exactly one lcore."""
        return dict(self.queue_stats)

    @property
    def stats(self) -> ServerStats:
        """Aggregate across all queues (seed-compatible single-stats view)."""
        agg = self.stats_cls()
        for st in self.queue_stats.values():
            agg.merge_from(st)
        return agg
