"""Unified NetworkStack interface: per-lcore engines over (port, queue) pairs.

DPDK's execution model assigns each *lcore* (logical core) a set of
(port, queue) pairs that it polls run-to-completion; with RSS steering flows
to queues, cores scale without sharing — the paper's Fig. 3(a) core axis.
This module is the common machinery all three servers
(:class:`~repro.core.pmd.BypassL2FwdServer`,
:class:`~repro.core.pmd.PipelineServer`,
:class:`~repro.core.kernel_stack.KernelStackServer`) now run on:

* :class:`Lcore` — one engine: an ordered list of (port, queue) assignments
  plus its processing burst size (per-lcore via
  :class:`~repro.core.dca.BurstPlan`).
* :class:`NetworkStack` — owns the lcores and per-queue
  :class:`ServerStats`.  ``poll_once`` schedules the lcores **sequentially
  round-robin**, which is GIL-aware and deterministic: on a 1-core host it
  measures exactly one core's worth of work in a reproducible order.
  Threads are optional (``start_lcore_threads``) for hosts with real
  parallelism.

Stats discipline: every (port, queue) pair has its own :class:`ServerStats`
written by exactly one lcore (no sharing, like DPDK's per-queue counters);
``stack.stats`` aggregates them on read, so the seed-era single-stats API
keeps working.

Virtual-time mode: :meth:`NetworkStack.attach_clock` installs a
:class:`~repro.core.simclock.SimClock`.  Each lcore then carries its own
*busy-until* timestamp: costs charged while it services queues
(:meth:`NetworkStack.charge_ns`) extend that lcore's busy window instead of
busy-waiting the host, and :meth:`NetworkStack.poll_at` only runs lcores
whose busy window has passed.  N lcores therefore process packets in
*parallel virtual time* even on a 1-core GIL-bound host — which is what lets
the Fig. 3(a) core-scaling axis actually scale in this container.
"""
from __future__ import annotations

import dataclasses
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .cost import HostCostModel, spin_ns
from .simclock import SimClock

# Power-of-two burst-size bins: bucket i counts bursts of [2^i, 2^(i+1)).
# Fixed size => stats memory is O(1) regardless of run length.
N_BURST_BUCKETS = 12


@dataclass
class ServerStats:
    rx_packets: int = 0
    tx_packets: int = 0
    rx_bytes: int = 0
    poll_iterations: int = 0
    empty_polls: int = 0
    burst_count: int = 0
    burst_packets: int = 0
    burst_buckets: np.ndarray = field(
        default_factory=lambda: np.zeros(N_BURST_BUCKETS, dtype=np.int64)
    )

    def record_burst(self, n: int) -> None:
        self.burst_count += 1
        self.burst_packets += int(n)
        self.burst_buckets[min(max(int(n), 1).bit_length() - 1,
                               N_BURST_BUCKETS - 1)] += 1

    @property
    def avg_burst(self) -> float:
        return self.burst_packets / self.burst_count if self.burst_count else 0.0

    @property
    def burst_histogram(self) -> List[Dict[str, int]]:
        """Fixed-bin view of burst sizes: [{lo, hi, count}], empty bins omitted."""
        return [
            {"lo": 1 << i, "hi": (1 << (i + 1)) - 1, "count": int(c)}
            for i, c in enumerate(self.burst_buckets)
            if c
        ]

    def merge_from(self, other: "ServerStats") -> "ServerStats":
        """Accumulate another stats object (per-queue → aggregate).

        Exhaustive over the dataclass fields: numeric fields add, array
        fields add elementwise, and anything else raises — a stats subclass
        adding a field of an unmergeable type must override this method
        rather than have its counters silently dropped from aggregates.
        """
        for f in dataclasses.fields(other):
            v = getattr(other, f.name)
            if isinstance(v, np.ndarray):
                getattr(self, f.name).__iadd__(v)
            elif isinstance(v, (int, float, np.integer, np.floating)):
                setattr(self, f.name, getattr(self, f.name, 0) + v)
            else:
                raise TypeError(
                    f"{type(self).__name__}.merge_from cannot merge field "
                    f"{f.name!r} of type {type(v).__name__}; override "
                    "merge_from in the subclass")
        return self


@dataclass
class Lcore:
    """One polling engine: services its (port_idx, queue_idx) pairs in order."""

    lcore_id: int
    assignments: List[Tuple[int, int]]
    burst_size: int = 32


class NetworkStack:
    """Base class every server implements: lcores + per-queue stats.

    Subclasses implement :meth:`_service_queue` (one lcore quantum on one
    queue) or override :meth:`run_lcore` for non-queue-parallel topologies
    (the pipeline's stage lcores).
    """

    stats_cls = ServerStats

    def __init__(
        self,
        ports: Sequence[object],
        n_lcores: Optional[int] = None,
        burst_size: int = 32,
        plan: Optional[object] = None,  # duck-typed BurstPlan (burst_for)
    ):
        self.ports = list(ports)
        self.queue_pairs: List[Tuple[int, int]] = [
            (pi, qi)
            for pi, p in enumerate(self.ports)
            for qi in range(getattr(p, "n_queues", 1))
        ]
        if n_lcores is None:
            n_lcores = len(self.queue_pairs)  # DPDK default: one lcore per queue
        if n_lcores < 1:
            raise ValueError("n_lcores must be >= 1")
        if plan is not None and hasattr(plan, "validate_lcores"):
            # a per_lcore tuple must name exactly one burst per lcore —
            # silent modulo recycling misassigns bursts (see BurstPlan)
            plan.validate_lcores(n_lcores)
        self.lcores: List[Lcore] = []
        for i in range(n_lcores):
            assigned = [pr for j, pr in enumerate(self.queue_pairs)
                        if j % n_lcores == i]
            b = plan.burst_for(i) if plan is not None else burst_size
            self.lcores.append(Lcore(i, assigned, b))
        self.queue_stats: Dict[Tuple[int, int], ServerStats] = {
            pr: self.stats_cls() for pr in self.queue_pairs
        }
        self._stop_evt = threading.Event()
        self._threads: List[threading.Thread] = []
        # virtual-time state (installed by attach_clock; None == wall-clock)
        self.clock: Optional[SimClock] = None
        self.sim_cost: HostCostModel = HostCostModel()
        self._lcore_next_free: List[int] = []
        self._accum_ns: float = 0.0
        self._poll_now_ns: int = 0  # virtual now of the current poll_at round
        # per-(port, queue) give-up deadlines for stacks that *accumulate*
        # toward a full burst before forwarding (the Fig. 4 DCA semantics);
        # next_free_ns surfaces them so event loops advance time to them
        self._queue_deadline: Dict[Tuple[int, int], int] = {}
        self._dca_wait_ns: Optional[int] = None

    # -- DCA accumulate-then-forward (paper Fig. 4(b)) ------------------------
    def enable_dca_accumulate(self, wait_timeout_ns: int) -> "NetworkStack":
        """Turn on Fig. 4 accumulate-then-forward: a queue whose written-back
        backlog is below the servicing burst size is left to accumulate, with
        a give-up deadline ``wait_timeout_ns`` past the first observation of a
        partial backlog (surfaced to event loops via :meth:`next_free_ns`).
        Only meaningful with an attached SimClock — wall-clock mode ignores
        it, there the host's real pacing is the measurement."""
        if wait_timeout_ns < 0:
            raise ValueError("wait_timeout_ns must be >= 0")
        self._dca_wait_ns = int(wait_timeout_ns)
        return self

    def _dca_accumulate_wait(self, key: Tuple[int, int], avail: int,
                             burst: int) -> bool:
        """Accumulate-gate decision for one nonempty queue: True → leave the
        backlog to keep growing toward a full burst.  Maintains the per-queue
        give-up deadline (armed at first sight of a partial backlog, cleared
        on forward)."""
        if avail >= burst:
            self._queue_deadline.pop(key, None)
            return False
        now = self._poll_now_ns
        deadline = self._queue_deadline.get(key)
        if deadline is None:
            # first sight of a partial burst: start the give-up timer
            self._queue_deadline[key] = now + self._dca_wait_ns
            return True
        if now < deadline:
            return True
        # deadline expired: forward the partial burst (bounds the worst-case
        # latency of a train that ends mid-burst)
        self._queue_deadline.pop(key, None)
        return False

    # -- virtual time ---------------------------------------------------------
    def attach_clock(self, clock: SimClock,
                     cost: Optional[HostCostModel] = None) -> "NetworkStack":
        """Switch the stack to virtual-time execution.

        ``cost`` supplies the polling-path cycle figures
        (``pmd_poll_cycles``/``pmd_per_packet_cycles``) charged per serviced
        burst; interrupt-driven stacks keep charging their own constructor
        cost model, just onto the clock instead of a busy-wait.
        """
        self.clock = clock
        if cost is not None:
            self.sim_cost = cost
        self._lcore_next_free = [clock.now_ns] * len(self.lcores)
        return self

    def charge_ns(self, ns: float) -> None:
        """Account ``ns`` of host work on the currently-running lcore.

        Wall-clock mode burns it for real (:func:`spin_ns`); virtual-time
        mode accumulates it into the lcore's busy window (applied by
        :meth:`poll_at` when the lcore quantum finishes).
        """
        if self.clock is None:
            spin_ns(ns)
        else:
            self._accum_ns += ns

    def poll_at(self, now_ns: int) -> int:
        """One virtual-time scheduling round at ``now_ns``: every lcore whose
        busy window has passed runs once; the costs it charges push its
        next-free time forward.  Falls back to :meth:`poll_once` when no
        clock is attached."""
        if self.clock is None:
            return self.poll_once()
        self._poll_now_ns = now_ns
        total = 0
        for i, lcore in enumerate(self.lcores):
            if self._lcore_next_free[i] > now_ns:
                continue  # core still busy with earlier packets
            self._accum_ns = 0.0
            total += self.run_lcore(lcore)
            if self._accum_ns > 0:
                self._lcore_next_free[i] = now_ns + int(round(self._accum_ns))
        return total

    def next_free_ns(self, now_ns: int) -> Optional[int]:
        """Earliest future time any busy lcore frees up, or any queue's
        burst-accumulation deadline expires (None if neither) — the event
        the load generator waits on when the wire is quiet."""
        future = [t for t in self._lcore_next_free if t > now_ns]
        future += [t for t in self._queue_deadline.values() if t > now_ns]
        return min(future) if future else None

    # -- scheduling -----------------------------------------------------------
    def poll_once(self) -> int:
        """One scheduling round: every lcore runs once, sequentially.

        Deterministic (fixed lcore order, fixed assignment order within each
        lcore) so single-core measurements are exactly reproducible.
        """
        total = 0
        for lcore in self.lcores:
            total += self.run_lcore(lcore)
        return total

    def run_lcore(self, lcore: Lcore) -> int:
        """One run-to-completion pass over the lcore's assigned queues."""
        total = 0
        for pi, qi in lcore.assignments:
            total += self._service_queue(lcore, pi, qi, self.queue_stats[(pi, qi)])
        return total

    def _service_queue(self, lcore: Lcore, port_idx: int, queue_idx: int,
                       qstats: ServerStats) -> int:
        raise NotImplementedError

    # -- optional threaded execution (real-parallelism hosts) -----------------
    def start_lcore_threads(self) -> None:
        """Run each lcore in its own thread (GIL-serialized on 1-core hosts;
        use sequential ``poll_once`` for bandwidth numbers there)."""
        if self.clock is not None:
            # threads pace themselves on the host clock; with a SimClock
            # attached, charges would race on _accum_ns and never apply to
            # any lcore busy window — measurements would silently be wrong
            raise RuntimeError(
                "lcore threads are a wall-clock execution mode; build the "
                "testbed with TrafficConfig(sim_time=False) (or don't "
                "attach_clock) before start_lcore_threads()")
        if self._threads:
            return
        self._stop_evt.clear()

        def loop(lc: Lcore) -> None:
            while not self._stop_evt.is_set():
                self.run_lcore(lc)

        self._threads = [
            threading.Thread(target=loop, args=(lc,), daemon=True,
                             name=f"lcore-{lc.lcore_id}")
            for lc in self.lcores
        ]
        for t in self._threads:
            t.start()

    def stop_lcore_threads(self) -> None:
        self._stop_evt.set()
        for t in self._threads:
            t.join(timeout=5)
        self._threads = []

    # -- stats ----------------------------------------------------------------
    def per_queue_stats(self) -> Dict[Tuple[int, int], ServerStats]:
        """Per-(port, queue) counters; each written by exactly one lcore."""
        return dict(self.queue_stats)

    @property
    def stats(self) -> ServerStats:
        """Aggregate across all queues (seed-compatible single-stats view)."""
        agg = self.stats_cls()
        for st in self.queue_stats.values():
            agg.merge_from(st)
        return agg
