"""Partitioned-parallel execution of multi-host topologies.

The shared-clock :meth:`repro.exp.topology.Cluster.run` loop advances every
client, node, and the switch in one round per virtual instant — correct, but
serial by construction.  This module splits the same scenario into
per-endpoint **simulation domains** (one per client, one per node, one for
the switch), each with a private :class:`~repro.core.simclock.SimClock` and
scheduler, exchanging frames only at domain boundaries: the fabric's wires.
Because every boundary has at least ``link_latency_ns`` of propagation, a
frame emitted at ``t`` cannot affect any other domain before ``t +
link_latency_ns`` — SimBricks' conservative-parallel invariant
(arXiv:2012.14219).  Domains therefore advance in lockstep **windows**: each
window ends ``link_latency_ns`` past the earliest pending activity, every
domain runs freely up to the window end, and the frames minted inside it
(``Crossing`` records) are delivered at the start of a later window.

**Bit-identical ordering.**  The shared loop breaks simultaneous-event ties
with a global FIFO sequence number.  Domains cannot share a counter, so every
event instead carries a **birth key** — a tuple encoding *when and where it
was minted*:

* phase-0 client emissions: ``(t, 0, client_index, k)``;
* events minted while executing another event: ``(t, 1, *parent_birth, k)``;
* phase-2 node poll/drain rounds: ``(t, 2, node_index, k)``;

with ``k`` a per-(t, phase) running counter.  Lexicographic order over these
tuples reproduces the shared loop's mint order exactly: earlier virtual
mint-time first, then the shared round's phase order (client emissions,
scheduler events, node rounds), then client/node index, then per-phase FIFO.
Heaps order on ``(fire_time, birth)``, so the order crossings *arrive* in is
irrelevant — which is what makes the multiprocessing mode deterministic.

Policy (which configs are provably equivalent, how domains are built from a
``TopologyConfig``, report assembly) lives in :mod:`repro.exp.topology`; this
module is pure mechanism and imports nothing from ``repro.exp``.
"""
from __future__ import annotations

import heapq
import multiprocessing
import re
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .simclock import SimClock, Wire
from .switch import Switch
from .telemetry import writeback_extras

__all__ = [
    "Crossing", "DomainScheduler", "ClientDomain", "NodeDomain",
    "SwitchDomain", "DomainSwitch", "PartitionEngine", "MpPartitionEngine",
    "PartitionRunInfo", "PartitionSanitizer", "CausalityError",
    "PARTITION_FALLBACK_REASONS", "assign_groups",
    "validate_partition_fallback_reason",
]

# one frame crossing a domain boundary:
# (dst_domain, fire_t_ns, birth, kind, payload) where kind is "fwd"
# (endpoint uplink -> switch ingress, payload (in_port_id, frame)) or
# "deliver" (switch egress -> endpoint, payload frame)
Crossing = Tuple[int, int, tuple, str, object]

_PRE_RUN_CTX = (-1,)  # births minted before any phase/event context

# The closed taxonomy of partition fallback reasons.  Every string stamped
# into ``PartitionRunInfo.fallback_reason`` must fullmatch one of these
# patterns (``.+`` spans the ``{name!r}``/``{kind!r}`` interpolations of
# ``repro.exp.topology.partition_fallback_reason``).  Keeping the list here,
# next to the dataclass that enforces it, means a typo'd or ad-hoc reason
# fails loudly at assignment instead of silently fragmenting the taxonomy
# that tests and sweep tooling key on.
PARTITION_FALLBACK_REASONS: Tuple[str, ...] = (
    r"serving topology: balancer reads live cross-domain state",
    r"zero-latency links leave no conservative lookahead window",
    r"node .+: zero-cost PMD model needs the shared loop's "
    r"every-round polling",
    r"node .+: zero-cost kernel model needs the shared loop's "
    r"every-round polling",
    r"node .+: stack kind .+ not proven partition-equivalent",
    r"AQM policy .+ not proven partition-equivalent",
    r"DCTCP rate-adaptive clients adapt on cross-domain echo feedback",
    r"multi-switch trunk fabric not proven partition-equivalent",
)

_PARTITION_REASON_RES = tuple(re.compile(p) for p in
                              PARTITION_FALLBACK_REASONS)


def validate_partition_fallback_reason(reason: Optional[str]) -> None:
    """Raise ``ValueError`` unless ``reason`` is None or matches the closed
    :data:`PARTITION_FALLBACK_REASONS` taxonomy."""
    if reason is None:
        return
    for pat in _PARTITION_REASON_RES:
        if pat.fullmatch(reason):
            return
    raise ValueError(
        f"unknown partition fallback reason {reason!r}: not in the closed "
        "PARTITION_FALLBACK_REASONS taxonomy (repro.core.partition)")


@dataclass
class PartitionRunInfo:
    """Out-of-band partition-run descriptor (NOT in the RunReport, which must
    stay bit-identical across execution modes)."""

    mode_requested: str = "shared-clock"
    mode_used: str = "shared-clock"
    fallback_reason: Optional[str] = None
    n_domains: int = 0
    n_windows: int = 0
    n_workers: int = 0
    n_sanitized: int = 0  # crossings checked by PartitionSanitizer (0 = off)

    def __setattr__(self, name: str, value) -> None:
        # dataclass __init__ assigns via setattr, so construction-time
        # reasons are validated too
        if name == "fallback_reason":
            validate_partition_fallback_reason(value)
        object.__setattr__(self, name, value)


class CausalityError(RuntimeError):
    """A crossing violated the conservative-parallel invariant: it fired
    before its link-latency bound, before its destination's clock, or out of
    (fire_t, birth) order — any of which means domain state diverged from the
    shared-clock loop (a determinism race, not a modeling choice)."""


class PartitionSanitizer:
    """Always-available runtime race detector for crossing delivery.

    :mod:`tests.test_partition_property` proves (via hypothesis) that every
    crossing respects the conservative bound; this class promotes that
    property into a production check the engines can run on every delivery.
    Three invariants, all cheap enough to leave on for whole parity corpora:

    1. **Link-latency bound.**  Every crossing is minted by a wire transmit
       at its birth instant, so a frame can never legally fire before
       ``birth_t + serialization_ns(len(frame)) + latency_ns`` — the
       fresh-wire (idle-FIFO) lower bound of
       :meth:`repro.core.simclock.Wire.transmit`.
    2. **Destination clock.**  Due crossings are delivered at a window start;
       the destination domain only ever advanced strictly below the previous
       window end, which the crossing's fire time must meet or exceed.
    3. **Per-destination delivery order.**  ``_deliver_due`` hands each
       domain its crossings sorted by ``(fire_t, birth)`` under a monotone
       window end, so the delivery key per destination must never decrease.

    ``latency_ns`` is the conservative (minimum) link latency — the engines'
    ``delta``.  ``gbps <= 0`` drops the serialization term, keeping the bound
    sound for mixed-rate fabrics.
    """

    def __init__(self, latency_ns: int, gbps: float = 0.0):
        self.latency_ns = int(latency_ns)
        self.gbps = float(gbps)
        self.checked = 0
        self._last: Dict[int, Tuple[int, tuple]] = {}

    def _serialization_ns(self, nbytes: int) -> int:
        if self.gbps <= 0:
            return 0
        return int(round(nbytes * 8 / self.gbps))

    @staticmethod
    def _frame_len(crossing: Crossing) -> int:
        payload = crossing[4]
        if crossing[3] == "fwd":
            payload = payload[1]
        try:
            return len(payload)
        except TypeError:
            return 0

    def check(self, crossing: Crossing,
              dst_clock_ns: Optional[int] = None) -> None:
        """Validate one crossing just before delivery; raises
        :class:`CausalityError` on any invariant breach."""
        dst, fire_t, birth, kind, _payload = crossing
        self.checked += 1
        bound = (int(birth[0]) + self._serialization_ns(
            self._frame_len(crossing)) + self.latency_ns)
        if fire_t < bound:
            raise CausalityError(
                f"crossing to domain {dst} ({kind}) fires at {fire_t} ns, "
                f"before its conservative bound {bound} ns (birth "
                f"{birth!r} + serialization + link latency "
                f"{self.latency_ns} ns)")
        if dst_clock_ns is not None and fire_t < dst_clock_ns:
            raise CausalityError(
                f"crossing to domain {dst} ({kind}) fires at {fire_t} ns, "
                f"behind the destination clock at {dst_clock_ns} ns — the "
                "domain already simulated past the delivery instant")
        key = (int(fire_t), tuple(birth))
        prev = self._last.get(dst)
        if prev is not None and key < prev:
            raise CausalityError(
                f"crossing to domain {dst} ({kind}) delivered out of order: "
                f"key {key!r} after {prev!r} — (fire_t, birth) delivery "
                "order per destination must be non-decreasing")
        self._last[dst] = key


class DomainScheduler:
    """An :class:`~repro.core.simclock.EventScheduler` drop-in whose tie-break
    is a birth key instead of a process-local FIFO counter.

    The EventScheduler API (``schedule_at``/``schedule_in``/``cancel``/
    ``next_time_ns``/``run_until``/``run_next``/``__len__``/``.clock``) is
    preserved so descriptor-ring writeback timers and DCA plumbing attach to
    a domain unchanged.  On top of it: :meth:`begin_phase` establishes the
    mint context for a client-emission or node-round phase, and every
    ``schedule_*`` call (or explicit :meth:`mint_birth`) stamps the next
    birth in that context.  While an event executes, the context is the
    event's own birth — children sort after their parent, in FIFO order
    among siblings, exactly like fresh sequence numbers in the shared loop.
    """

    def __init__(self, clock: SimClock):
        self.clock = clock
        self._heap: List[Tuple[int, tuple, int, Callable[[], None]]] = []
        self._live: set = set()
        self._next_token = 0
        self._ctx: tuple = _PRE_RUN_CTX
        self._k = 0
        self._phase_key: Optional[tuple] = None
        # per-(t, phase, idx) counters persist across re-rounds at one
        # instant (the quiet-fabric flush re-round); cleared on time change
        self._phase_t = -1
        self._phase_k: Dict[tuple, int] = {}

    # -- birth minting --------------------------------------------------------
    def begin_phase(self, t: int, phase: int, idx: int) -> None:
        """Enter mint context ``(t, phase, idx)`` — phase 0 for client
        emissions, 2 for node poll/drain rounds (1 is reserved for event
        execution).  The per-context counter resumes where a previous round
        at the same instant left it."""
        t = int(t)
        if t != self._phase_t:
            self._phase_k.clear()
            self._phase_t = t
        key = (t, phase, idx)
        self._ctx = key
        self._phase_key = key
        self._k = self._phase_k.get(key, 0)

    def mint_birth(self) -> tuple:
        birth = self._ctx + (self._k,)
        self._k += 1
        if self._phase_key is not None:
            self._phase_k[self._phase_key] = self._k
        return birth

    # -- EventScheduler-compatible API ----------------------------------------
    def schedule_at(self, t_ns: int, fn: Callable[[], None]) -> int:
        return self.schedule_with_birth(t_ns, self.mint_birth(), fn)

    def schedule_in(self, delay_ns: int, fn: Callable[[], None]) -> int:
        return self.schedule_at(self.clock.now_ns + int(delay_ns), fn)

    def schedule_with_birth(self, t_ns: int, birth: tuple,
                            fn: Callable[[], None]) -> int:
        token = self._next_token
        self._next_token += 1
        self._live.add(token)
        heapq.heappush(self._heap, (int(t_ns), birth, token, fn))
        return token

    def cancel(self, token: int) -> bool:
        if token not in self._live:
            return False
        self._live.discard(token)
        if len(self._heap) > 64 and len(self._heap) > 4 * len(self._live):
            self._heap = [e for e in self._heap if e[2] in self._live]
            heapq.heapify(self._heap)
        return True

    def __len__(self) -> int:
        return len(self._live)

    def _drop_dead(self) -> None:
        heap = self._heap
        while heap and heap[0][2] not in self._live:
            heapq.heappop(heap)

    def next_time_ns(self) -> Optional[int]:
        self._drop_dead()
        return self._heap[0][0] if self._heap else None

    def run_next(self) -> bool:
        self._drop_dead()
        if not self._heap:
            return False
        t, birth, token, fn = heapq.heappop(self._heap)
        self._live.discard(token)
        self.clock.advance_to(t)
        saved = (self._ctx, self._k, self._phase_key)
        self._ctx = (t, 1) + birth
        self._k = 0
        self._phase_key = None
        try:
            fn()
        finally:
            self._ctx, self._k, self._phase_key = saved
        return True

    def run_until(self, t_ns: int) -> int:
        fired = 0
        while True:
            nt = self.next_time_ns()
            if nt is None or nt > t_ns:
                break
            self.run_next()
            fired += 1
        self.clock.advance_to(t_ns)
        return fired


class DomainSwitch(Switch):
    """The switch, rehomed into its own domain.

    Endpoints no longer call :meth:`send` — each endpoint domain owns its
    port's uplink :class:`~repro.core.simclock.Wire` (only that endpoint ever
    transmits on it, so the FIFO arithmetic is unchanged) and emits a ``fwd``
    crossing instead.  The forward pipeline (classify -> route -> AQM ->
    enqueue) is inherited verbatim from :class:`~repro.core.switch.Switch`;
    only the emission stage differs — delivery becomes a ``deliver`` crossing
    to the egress port's owner domain.  Tx counters are charged at crossing
    mint time (the shared loop charges them at delivery, and nothing reads
    them before the final report, so end state is identical).
    """

    def __init__(self, n_ports: int, sched: DomainScheduler, gbps: float,
                 latency_ns: int, egress_capacity: int,
                 domain_of_port: Sequence[int], outbox: List[Crossing]):
        super().__init__(n_ports, sched, gbps=gbps, latency_ns=latency_ns,
                         egress_capacity=egress_capacity)
        self._domain_of_port = list(domain_of_port)
        self._outbox = outbox

    def send(self, port_id: int, frame: np.ndarray,
             t_ns: Optional[int] = None) -> None:
        raise RuntimeError(
            "partitioned fabric: endpoints transmit on their own uplink "
            "wires (ClientDomain/NodeDomain emit crossings), not Switch.send")

    def _emit(self, out, frame: np.ndarray, arrival: int) -> None:
        out.tx_frames += 1
        out.tx_bytes += len(frame)
        self._outbox.append((self._domain_of_port[out.port_id], arrival,
                             self.sched.mint_birth(), "deliver", frame))


class _DomainBase:
    """Window-bounded free-running: process local candidates strictly below
    the window end, one round per candidate instant."""

    ds: DomainScheduler
    outbox: List[Crossing]

    @property
    def clock(self) -> SimClock:
        return self.ds.clock

    def next_candidate(self) -> Optional[int]:
        raise NotImplementedError

    def round_at(self, now: int) -> int:
        raise NotImplementedError

    def run_window(self, w_end: int) -> int:
        moved = 0
        while True:
            c = self.next_candidate()
            if c is None or c >= w_end:
                return moved
            self.clock.advance_to(c)
            moved += self.round_at(self.clock.now_ns)


class ClientDomain(_DomainBase):
    """One fabric-attached load generator: analytic emission schedule in,
    RTT completions (``deliver`` crossings) out."""

    kind = "client"

    def __init__(self, index: int, ds: DomainScheduler, lg, pool, port_id: int,
                 uplink: Wire, times: np.ndarray, sizes: Optional[np.ndarray],
                 rng, verify_integrity: bool, switch_domain: int,
                 outbox: List[Crossing]):
        self.index = index
        self.ds = ds
        self.lg = lg
        self.pool = pool
        self.port_id = port_id
        self.uplink = uplink
        self.times = times
        self.sizes = sizes
        self.rng = rng
        self.verify_integrity = verify_integrity
        self.switch_domain = switch_domain
        self.outbox = outbox
        self.cursor = 0

    def next_candidate(self) -> Optional[int]:
        cands = []
        if self.cursor < len(self.times):
            cands.append(int(self.times[self.cursor]))
        nt = self.ds.next_time_ns()
        if nt is not None:
            cands.append(nt)
        return min(cands) if cands else None

    def round_at(self, now: int) -> int:
        ds = self.ds
        ds.begin_phase(now, 0, self.index)
        times, sizes, i = self.times, self.sizes, self.cursor
        n = len(times)
        while i < n and times[i] <= now:
            t_emit = int(times[i])
            frame = self.lg.make_frame(
                self.pool, int(sizes[i]), t_emit,
                self.rng if self.verify_integrity else None)
            if frame is not None:
                arrival = self.uplink.transmit(t_emit, len(frame))
                self.outbox.append((self.switch_domain, arrival,
                                    ds.mint_birth(), "fwd",
                                    (self.port_id, frame)))
            i += 1
        moved = i - self.cursor
        self.cursor = i
        moved += ds.run_until(now)
        return moved

    def accept(self, crossing: Crossing) -> None:
        _dst, fire_t, birth, kind, frame = crossing
        assert kind == "deliver", kind
        lg = self.lg
        self.ds.schedule_with_birth(
            fire_t, birth, lambda: lg.complete_frame(frame, fire_t))

    def chunk(self) -> Dict[str, object]:
        m = self.lg.meter
        return {"sent": self.lg.flight.sent,
                "received": self.lg.flight.received,
                "integrity_errors": self.lg.flight.integrity_errors,
                "latency": self.lg.latency.values().copy(),
                "meter": (m.packets, m.bytes, m.start_ns, m.end_ns)}


class NodeDomain(_DomainBase):
    """One simulated host: NIC deliveries in, served/echoed frames out."""

    kind = "node"

    def __init__(self, index: int, ds: DomainScheduler, dev, pool, server,
                 port_id: int, uplink: Wire, max_tx_burst: int,
                 switch_domain: int, outbox: List[Crossing]):
        self.index = index
        self.ds = ds
        self.dev = dev
        self.pool = pool
        self.server = server
        self.port_id = port_id
        self.uplink = uplink
        self.max_tx_burst = max_tx_burst
        self.switch_domain = switch_domain
        self.outbox = outbox

    def next_candidate(self) -> Optional[int]:
        cands = []
        nt = self.ds.next_time_ns()
        if nt is not None:
            cands.append(nt)
        nf = self.server.next_free_ns(self.clock.now_ns)
        if nf is not None:
            cands.append(nf)
        return min(cands) if cands else None

    def round_at(self, now: int) -> int:
        moved = self.ds.run_until(now)
        self.ds.begin_phase(now, 2, self.index)
        moved += self.server.poll_at(now)
        moved += self._drain_tx(now)
        return moved

    def _drain_tx(self, now: int) -> int:
        slots, lengths = self.dev.drain_tx_bursts(self.max_tx_burst)
        n = len(slots)
        for k in range(n):
            slot = int(slots[k])
            frame = self.pool.view(slot, int(lengths[k])).copy()
            self.pool.free(slot)
            arrival = self.uplink.transmit(now, len(frame))
            self.outbox.append((self.switch_domain, arrival,
                                self.ds.mint_birth(), "fwd",
                                (self.port_id, frame)))
        return n

    def accept(self, crossing: Crossing) -> None:
        _dst, fire_t, birth, kind, frame = crossing
        assert kind == "deliver", kind
        self.ds.schedule_with_birth(
            fire_t, birth, lambda: self._nic_deliver(frame))

    def _nic_deliver(self, frame: np.ndarray) -> None:
        slot = self.pool.alloc()
        if slot is None:
            return  # arena exhausted: the dev's rx_nombuf counter records it
        n = len(frame)
        self.pool.arena[slot, :n] = frame
        self.pool.lengths[slot] = n
        self.dev.deliver(slot, n)

    def flush(self) -> None:
        self.dev.flush_rx()

    def chunk(self) -> Dict[str, object]:
        st = self.dev.stats()
        out: Dict[str, object] = {
            "ipackets": st.ipackets, "imissed": st.imissed,
            "rx_nombuf": st.rx_nombuf,
            "writeback": writeback_extras([self.dev]),
        }
        if hasattr(self.server, "extras"):
            out["stack"] = dict(self.server.extras())
        return out


class SwitchDomain(_DomainBase):
    """The fabric: ``fwd`` crossings in, ``deliver`` crossings out."""

    kind = "switch"

    def __init__(self, index: int, ds: DomainScheduler, switch: DomainSwitch):
        self.index = index
        self.ds = ds
        self.switch = switch
        self.outbox = switch._outbox

    def next_candidate(self) -> Optional[int]:
        return self.ds.next_time_ns()

    def round_at(self, now: int) -> int:
        return self.ds.run_until(now)

    def accept(self, crossing: Crossing) -> None:
        _dst, fire_t, birth, kind, payload = crossing
        assert kind == "fwd", kind
        in_port, frame = payload
        sw = self.switch
        self.ds.schedule_with_birth(
            fire_t, birth, lambda: sw._forward(in_port, frame))

    def chunk(self) -> Dict[str, object]:
        return {"extras": self.switch.extras()}


def assign_groups(n_domains: int, n_groups: int) -> List[List[int]]:
    """Deterministic domain → execution-group assignment.  The switch (by
    convention the last domain) talks to everyone, so it gets a group of its
    own when more than one group exists; endpoints round-robin over the
    rest.  Grouping never changes results — domains inside one window are
    independent — only which worker runs them."""
    n_groups = max(1, min(int(n_groups), n_domains))
    if n_groups == 1:
        return [list(range(n_domains))]
    buckets: List[List[int]] = [[] for _ in range(n_groups - 1)]
    for d in range(n_domains - 1):
        buckets[d % (n_groups - 1)].append(d)
    return [b for b in buckets if b] + [[n_domains - 1]]


def _deliver_due(pending: List[Crossing], w_end: int,
                 ) -> Tuple[List[Crossing], List[Crossing]]:
    """Split pending crossings into (due before w_end, still pending); due
    ones are sorted by (fire_t, birth) so delivery order is deterministic
    no matter which worker produced them in what order."""
    due = [c for c in pending if c[1] < w_end]
    rest = [c for c in pending if c[1] >= w_end]
    due.sort(key=lambda c: (c[1], c[2]))
    return due, rest


class PartitionEngine:
    """In-process window loop over a set of domains (mode ``partitioned``).

    Each iteration: the next window ends ``delta`` (the minimum link
    latency) past the earliest pending activity, due crossings enter their
    domains' heaps, every group of domains runs up to the window end, and
    freshly minted crossings join the pending set.  At quiescence the
    quiet-fabric flush mirrors the shared loop: every node advances to the
    global max clock, flushes timeout-held descriptor writebacks, then runs
    one harvest round; a second quiescence ends the run.
    """

    def __init__(self, domains: Sequence[_DomainBase], delta: int,
                 outbox: List[Crossing], n_groups: int = 1,
                 max_rounds: int = 50_000_000,
                 trace: Optional[List[Crossing]] = None,
                 sanitizer: Optional[PartitionSanitizer] = None):
        if delta < 1:
            raise ValueError("partitioned execution needs link latency >= 1ns")
        self.domains = list(domains)
        self.delta = int(delta)
        self.outbox = outbox
        self.groups = assign_groups(len(self.domains), n_groups)
        self.max_rounds = max_rounds
        self.trace = trace
        self.sanitizer = sanitizer
        self.n_windows = 0

    def _drain_outbox(self, pending: List[Crossing]) -> None:
        if self.trace is not None:
            self.trace.extend(self.outbox)
        pending.extend(self.outbox)
        self.outbox.clear()

    def run(self) -> int:
        pending: List[Crossing] = []
        flushed_idle = False
        rounds = 0
        while True:
            cands = [c for c in (d.next_candidate() for d in self.domains)
                     if c is not None]
            cands.extend(c[1] for c in pending)
            if cands:
                flushed_idle = False
                w_end = min(cands) + self.delta
                due, pending = _deliver_due(pending, w_end)
                for c in due:
                    if self.sanitizer is not None:
                        self.sanitizer.check(
                            c, self.domains[c[0]].clock.now_ns)
                    self.domains[c[0]].accept(c)
                for group in self.groups:
                    for di in group:
                        rounds += self.domains[di].run_window(w_end)
                self._drain_outbox(pending)
                self.n_windows += 1
                if rounds > self.max_rounds:
                    raise RuntimeError(
                        f"PartitionEngine exceeded max_rounds="
                        f"{self.max_rounds} without quiescing — a node stack "
                        "is likely re-addressing frames to itself or "
                        "traffic never drains")
                continue
            if not flushed_idle:
                t_flush = max(d.clock.now_ns for d in self.domains)
                for d in self.domains:
                    if d.kind == "node":
                        d.clock.advance_to(t_flush)
                        d.flush()
                for d in self.domains:
                    if d.kind == "node":
                        rounds += d.round_at(t_flush)
                self._drain_outbox(pending)
                flushed_idle = True
                continue
            break
        return rounds

    @property
    def final_clock_ns(self) -> int:
        return max((d.clock.now_ns for d in self.domains), default=0)

    def chunks(self) -> Dict[int, Dict[str, object]]:
        return {i: d.chunk() for i, d in enumerate(self.domains)}


# -- multiprocessing mode -----------------------------------------------------

def _pack_crossings(crossings: List[Crossing]) -> Tuple[list, bytes]:
    """Flatten crossings into (metadata list, one contiguous frame buffer).

    Pickling a window's crossings naively costs one ndarray reduction per
    frame; a 64-frame window is 64 small pickle objects each way.  Packed,
    the same window is one metadata list (ints, birth tuples, kinds) plus a
    single bytes blob every frame is concatenated into — one pickled list
    per (worker, window) message regardless of crossing count.  A payload
    that isn't a plain frame (or ``(port, frame)``) rides in the metadata
    row unpacked, so exotic crossings stay correct, just unoptimized.
    """
    metas: list = []
    buf = bytearray()
    for dst, fire, birth, kind, payload in crossings:
        if kind == "fwd":
            port, frame = payload
        else:
            port, frame = -1, payload
        if not (isinstance(frame, np.ndarray) and frame.dtype == np.uint8
                and frame.ndim == 1):
            metas.append((dst, fire, birth, kind, None, payload))
            continue
        off = len(buf)
        buf += frame.tobytes()
        metas.append((dst, fire, birth, kind, port, (off, len(frame))))
    return metas, bytes(buf)


def _unpack_crossings(metas: list, buf: bytes) -> List[Crossing]:
    """Inverse of :func:`_pack_crossings`.  Frames come back as writable
    disjoint views over one private copy of the buffer (the switch's ECN
    stage writes the CE bit in place), byte-identical to what was packed."""
    arr = np.frombuffer(bytearray(buf), dtype=np.uint8)
    out: List[Crossing] = []
    for dst, fire, birth, kind, port, span in metas:
        if port is None:
            out.append((dst, fire, birth, kind, span))
            continue
        off, ln = span
        frame = arr[off:off + ln]
        payload = (port, frame) if kind == "fwd" else frame
        out.append((dst, fire, birth, kind, payload))
    return out


def _mp_worker_main(conn, builder: Tuple[str, str], cfg_dict: dict,
                    ids: List[int]) -> None:
    """One worker: builds its subset of domains (via the exp-layer builder
    named by ``builder`` — imported lazily so repro.core never imports
    repro.exp at module load) and serves window/flush/report requests."""
    try:
        import importlib
        mod = importlib.import_module(builder[0])
        build = getattr(mod, builder[1])
        outbox: List[Crossing] = []
        domains: Dict[int, _DomainBase] = build(cfg_dict, ids, outbox)
        order = sorted(domains)

        def state() -> Tuple[dict, dict]:
            return ({i: domains[i].next_candidate() for i in order},
                    {i: domains[i].clock.now_ns for i in order})

        conn.send(("ready",) + state())
        while True:
            msg = conn.recv()
            op = msg[0]
            if op == "window":
                _op, w_end, metas, buf = msg
                for c in _unpack_crossings(metas, buf):
                    domains[c[0]].accept(c)
                moved = 0
                for i in order:
                    moved += domains[i].run_window(w_end)
                out = _pack_crossings(outbox)
                outbox.clear()
                conn.send(("done", moved, out) + state())
            elif op == "flush":
                _op, t_flush = msg
                moved = 0
                for i in order:
                    d = domains[i]
                    if d.kind == "node":
                        d.clock.advance_to(t_flush)
                        d.flush()
                for i in order:
                    d = domains[i]
                    if d.kind == "node":
                        moved += d.round_at(t_flush)
                out = _pack_crossings(outbox)
                outbox.clear()
                conn.send(("done", moved, out) + state())
            elif op == "report":
                conn.send(("report", {i: domains[i].chunk() for i in order}))
            else:  # "stop"
                break
    except BaseException:
        import traceback
        try:
            conn.send(("error", traceback.format_exc()))
        except Exception:
            pass
        raise
    finally:
        conn.close()


class MpPartitionEngine:
    """The window loop of :class:`PartitionEngine`, with domain groups living
    in worker processes (mode ``partitioned-mp``).  The coordinator only
    routes candidates and crossings; all simulation state stays worker-local,
    so per-window IPC is O(crossings), not O(state) — and crossings travel
    packed (:func:`_pack_crossings`): one metadata list plus one contiguous
    frame buffer per (worker, window) message instead of one pickled ndarray
    per frame.  Determinism: crossings are delivered sorted by
    (fire_t, birth) and every heap orders on the same key, so worker
    scheduling cannot reorder anything observable."""

    def __init__(self, cfg_dict: dict, builder: Tuple[str, str],
                 n_domains: int, delta: int, n_workers: int,
                 max_rounds: int = 50_000_000,
                 sanitizer: Optional[PartitionSanitizer] = None):
        if delta < 1:
            raise ValueError("partitioned execution needs link latency >= 1ns")
        self.delta = int(delta)
        self.max_rounds = max_rounds
        self.sanitizer = sanitizer
        self.n_windows = 0
        self.final_clock_ns = 0
        groups = assign_groups(n_domains, n_workers)
        methods = multiprocessing.get_all_start_methods()
        ctx = multiprocessing.get_context(
            "fork" if "fork" in methods else "spawn")
        self._owner: List[List[int]] = groups
        self._ownset = [set(g) for g in groups]
        self._conns = []
        self._procs = []
        try:
            for ids in groups:
                parent, child = ctx.Pipe()
                p = ctx.Process(target=_mp_worker_main,
                                args=(child, builder, cfg_dict, ids),
                                daemon=True)
                p.start()
                child.close()
                self._conns.append(parent)
                self._procs.append(p)
        except Exception:
            self.close()
            raise

    @property
    def n_workers(self) -> int:
        return len(self._procs)

    def _recv(self, conn, want: str):
        try:
            msg = conn.recv()
        except EOFError:
            raise RuntimeError("partition worker died mid-run")
        if msg[0] == "error":
            raise RuntimeError(f"partition worker failed:\n{msg[1]}")
        if msg[0] != want:
            raise RuntimeError(f"partition worker sent {msg[0]!r}, "
                               f"expected {want!r}")
        return msg

    def run(self) -> Dict[int, Dict[str, object]]:
        cands: Dict[int, Optional[int]] = {}
        clocks: Dict[int, int] = {}
        for conn in self._conns:
            _tag, wc, wk = self._recv(conn, "ready")
            cands.update(wc)
            clocks.update(wk)
        pending: List[Crossing] = []
        flushed_idle = False
        rounds = 0
        while True:
            cvals = [c for c in cands.values() if c is not None]
            cvals.extend(c[1] for c in pending)
            if cvals:
                flushed_idle = False
                w_end = min(cvals) + self.delta
                due, pending = _deliver_due(pending, w_end)
                if self.sanitizer is not None:
                    for c in due:
                        self.sanitizer.check(c, clocks.get(c[0]))
                active = []
                for wi, conn in enumerate(self._conns):
                    mine = [c for c in due if c[0] in self._ownset[wi]]
                    busy = bool(mine) or any(
                        cands.get(i) is not None and cands[i] < w_end
                        for i in self._owner[wi])
                    if not busy:
                        continue  # whole window is a no-op for this worker
                    conn.send(("window", w_end) + _pack_crossings(mine))
                    active.append(conn)
                for conn in active:
                    _tag, moved, out, wc, wk = self._recv(conn, "done")
                    rounds += moved
                    pending.extend(_unpack_crossings(*out))
                    cands.update(wc)
                    clocks.update(wk)
                self.n_windows += 1
                if rounds > self.max_rounds:
                    raise RuntimeError(
                        f"MpPartitionEngine exceeded max_rounds="
                        f"{self.max_rounds} without quiescing")
                continue
            if not flushed_idle:
                t_flush = max(clocks.values(), default=0)
                for conn in self._conns:
                    conn.send(("flush", t_flush))
                for conn in self._conns:
                    _tag, moved, out, wc, wk = self._recv(conn, "done")
                    rounds += moved
                    pending.extend(_unpack_crossings(*out))
                    cands.update(wc)
                    clocks.update(wk)
                flushed_idle = True
                continue
            break
        self.final_clock_ns = max(clocks.values(), default=0)
        chunks: Dict[int, Dict[str, object]] = {}
        for conn in self._conns:
            conn.send(("report",))
        for conn in self._conns:
            _tag, wchunks = self._recv(conn, "report")
            chunks.update(wchunks)
        return chunks

    def close(self) -> None:
        for conn in self._conns:
            try:
                conn.send(("stop",))
            except Exception:
                pass
        for p in self._procs:
            p.join(timeout=10)
            if p.is_alive():
                p.terminate()
                p.join(timeout=5)
        for conn in self._conns:
            try:
                conn.close()
            except Exception:
                pass

    def __enter__(self) -> "MpPartitionEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
