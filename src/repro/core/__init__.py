# The paper's primary contribution: a kernel-bypass network dataplane and a
# hardware load-generator measurement model, adapted TPU-natively (DESIGN.md §2).
from .cost import HostCostModel, ZERO_COST, spin_ns
from .dataplane import BypassDataplane, FeedStats, KernelStackFeed, make_feed
from .dca import BurstPlan, OccupancyTrace, run_burst_experiment
from .descriptor import RxDescriptorRing, TxDescriptorRing, STATUS_DONE, STATUS_FREE
from .kernel_stack import KernelStackServer, KernelStats
from .loadgen import LoadGen, TrafficPattern, find_max_sustainable_bandwidth
from .packet import (
    DEFAULT_MTU,
    DEFAULT_TS_OFFSET,
    ETH_HEADER_SIZE,
    MIN_FRAME,
    PacketPool,
    PacketRef,
    checksum,
    payload_checksum,
    read_seq,
    read_seqs_vec,
    read_stamp,
    read_stamps_vec,
    stamp,
    swap_macs,
    swap_macs_vec,
    write_packets_vec,
    write_seq,
)
from .pmd import BypassL2FwdServer, PipelineServer, Port, ServerStats
from .rings import SpscRing
from .telemetry import LatencyRecorder, LatencyStats, RunReport, ThroughputMeter

__all__ = [
    "BypassDataplane", "BypassL2FwdServer", "BurstPlan", "FeedStats",
    "HostCostModel", "KernelStackFeed", "KernelStackServer", "KernelStats",
    "LatencyRecorder", "LatencyStats", "LoadGen", "OccupancyTrace",
    "PacketPool", "PacketRef", "PipelineServer", "Port", "RunReport",
    "RxDescriptorRing", "ServerStats", "SpscRing", "ThroughputMeter",
    "TrafficPattern", "TxDescriptorRing", "ZERO_COST",
    "checksum", "find_max_sustainable_bandwidth", "make_feed",
    "payload_checksum", "read_seq", "read_stamp", "run_burst_experiment",
    "spin_ns", "stamp", "swap_macs", "write_seq",
    "DEFAULT_MTU", "DEFAULT_TS_OFFSET", "ETH_HEADER_SIZE", "MIN_FRAME",
    "STATUS_DONE", "STATUS_FREE",
]
