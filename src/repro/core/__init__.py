# The paper's primary contribution: a kernel-bypass network dataplane and a
# hardware load-generator measurement model, adapted TPU-natively (DESIGN.md §2).
from .cost import HostCostModel, ZERO_COST, spin_ns
from .dataplane import BypassDataplane, FeedStats, KernelStackFeed, make_feed
from .dca import BurstPlan, OccupancyTrace, run_burst_experiment
from .descriptor import RxDescriptorRing, TxDescriptorRing, STATUS_DONE, STATUS_FREE
from .ethdev import EthConf, EthDev, EthDevError, EthDevState, EthStats
from .fastpath import (EPOCH_FALLBACK_REASONS, EpochRunInfo,
                       PARTITIONED_REASON, run_epoch_sim,
                       validate_epoch_fallback_reason)
from .kernel_stack import KernelStackServer, KernelStats
from .loadgen import (DctcpRateController, LoadGen, TrafficPattern,
                      find_max_sustainable_bandwidth)
from .netstack import Lcore, NetworkStack, ServerStats
from .packet import (
    DEFAULT_MTU,
    DEFAULT_TS_OFFSET,
    ETH_HEADER_SIZE,
    FLOW_OFFSET,
    FLOW_SIZE,
    MIN_FRAME,
    PacketPool,
    PacketRef,
    checksum,
    clear_ce,
    echo_payload_checksum,
    flow_bytes,
    flow_tuple_for_id,
    l2fwd_echo,
    l2fwd_echo_vec,
    payload_checksum,
    read_ce,
    read_ce_vec,
    read_dst_ip,
    read_flow,
    read_flow_bytes,
    read_flow_bytes_vec,
    read_seq,
    read_seqs_vec,
    read_stamp,
    read_stamps_vec,
    set_ce,
    set_ce_vec,
    stamp,
    swap_flow_ips,
    swap_flow_ips_vec,
    swap_macs,
    swap_macs_vec,
    write_flow,
    write_flow_ids_vec,
    write_packets_vec,
    write_seq,
)
from .partition import (PARTITION_FALLBACK_REASONS, CausalityError,
                        ClientDomain, Crossing, DomainScheduler, DomainSwitch,
                        MpPartitionEngine, NodeDomain, PartitionEngine,
                        PartitionRunInfo, PartitionSanitizer, SwitchDomain,
                        assign_groups, validate_partition_fallback_reason)
from .pmd import BypassL2FwdServer, PipelineServer, Port
from .rings import SpscRing
from .simclock import EventScheduler, SimClock, Wire
from .switch import (AqmRed, Switch, SwitchPort, aqm_uniform_u64,
                     red_probability)
from .rss import DEFAULT_RSS_KEY, RssIndirection, toeplitz_hash, toeplitz_hash_vec
from .telemetry import (LatencyRecorder, LatencyStats, QueueTelemetry,
                        RunReport, ThroughputMeter, rss_skew,
                        writeback_extras)

__all__ = [
    "BypassDataplane", "BypassL2FwdServer", "BurstPlan", "CausalityError",
    "ClientDomain",
    "AqmRed", "Crossing", "DctcpRateController", "DomainScheduler",
    "DomainSwitch", "EthConf",
    "EthDev",
    "EPOCH_FALLBACK_REASONS", "EpochRunInfo",
    "EthDevError", "EthDevState", "EthStats", "EventScheduler", "FeedStats",
    "validate_epoch_fallback_reason", "validate_partition_fallback_reason",
    "HostCostModel", "KernelStackFeed", "KernelStackServer", "KernelStats",
    "LatencyRecorder", "LatencyStats", "Lcore", "LoadGen",
    "MpPartitionEngine", "NetworkStack", "NodeDomain",
    "OccupancyTrace", "PARTITIONED_REASON", "PARTITION_FALLBACK_REASONS",
    "PacketPool", "PacketRef",
    "PartitionEngine", "PartitionRunInfo", "PartitionSanitizer",
    "PipelineServer", "Port",
    "QueueTelemetry", "RssIndirection", "RunReport", "RxDescriptorRing",
    "ServerStats", "SimClock", "SpscRing", "Switch", "SwitchDomain",
    "SwitchPort", "aqm_uniform_u64", "red_probability",
    "ThroughputMeter", "TrafficPattern",
    "TxDescriptorRing", "Wire", "ZERO_COST",
    "assign_groups",
    "checksum", "clear_ce", "echo_payload_checksum",
    "find_max_sustainable_bandwidth",
    "flow_bytes",
    "flow_tuple_for_id", "l2fwd_echo", "l2fwd_echo_vec", "make_feed",
    "payload_checksum", "read_ce", "read_ce_vec", "read_dst_ip", "read_flow",
    "read_flow_bytes", "read_flow_bytes_vec", "read_seq", "read_stamp",
    "rss_skew",
    "run_burst_experiment", "run_epoch_sim", "set_ce", "set_ce_vec",
    "spin_ns", "stamp",
    "swap_flow_ips",
    "swap_flow_ips_vec", "swap_macs",
    "toeplitz_hash", "toeplitz_hash_vec", "write_flow", "write_flow_ids_vec",
    "write_seq", "writeback_extras",
    "DEFAULT_MTU", "DEFAULT_RSS_KEY", "DEFAULT_TS_OFFSET", "ETH_HEADER_SIZE",
    "FLOW_OFFSET", "FLOW_SIZE", "MIN_FRAME", "STATUS_DONE", "STATUS_FREE",
]
