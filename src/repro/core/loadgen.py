"""LoadGen — the EtherLoadGen hardware load-generator model (paper §3.3).

"The hardware load generator model can generate packets at arbitrary rates and
sizes ... parameters are packet rate, packet size, and protocol ... a packet
trace can be passed ... adds a timestamp to each outgoing packet at a
configurable offset and compares the timestamp with the current tick on
incoming packets to compute per-packet round-trip latency ... reports mean,
median, standard deviation, and tail latency ... a packet drop percentage and
a histogram ... also supports a bandwidth test mode where it gradually
increases the bandwidth to find the maximum sustainable bandwidth."

This class implements all of the above against in-process servers
(:class:`~repro.core.pmd.BypassL2FwdServer` or
:class:`~repro.core.kernel_stack.KernelStackServer`).  It plays the NIC role on
the wire side: it DMAs frames into RX descriptor rings and drains TX rings.
Like its hardware counterpart, the generator itself never drops or delays
packets — all loss is attributable to the system under test (ring overflow /
pool exhaustion / link saturation), which is what "maximum sustainable
bandwidth" measures.

Timing comes in two modes:

* **Virtual time** (:meth:`LoadGen.run_sim`, the default through
  :mod:`repro.exp`): packet emission times are computed *analytically* from
  the :class:`TrafficPattern` (uniform spacing, pre-drawn exponential
  inter-arrivals for Poisson, burst trains, trace replay) and a
  :class:`~repro.core.simclock.SimClock` advances event-by-event — the
  paper's "compares the timestamp with the current tick" semantics.  Results
  are deterministic and independent of host speed: 400 Gbps of offered load
  simulates fine on a laptop.  Frames cross a :class:`~repro.core.simclock.
  Wire` per direction, so RTTs include per-link serialization
  (``bytes*8/link_gbps``) and propagation latency.

* **Wall clock** (:meth:`LoadGen.run`): the same analytic schedule is paced
  against ``time.perf_counter_ns()`` — kept for host-overhead studies where
  the real Python execution cost *is* the measurement.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Protocol, Sequence, Tuple

import numpy as np

from .packet import (
    DEFAULT_TS_OFFSET,
    FLOW_OFFSET,
    FLOW_SIZE,
    MIN_FRAME,
    PacketPool,
    echo_payload_checksum,
    flow_tuple_for_id,
    payload_checksum,
    read_ce,
    read_ce_vec,
    read_seq,
    read_seqs_vec,
    read_stamp,
    read_stamps_vec,
    stamp,
    write_flow,
    write_flow_ids_vec,
    write_packets_vec,
)
from .pmd import Port
from .simclock import EventScheduler, SimClock, Wire
from .telemetry import (LatencyRecorder, RunReport, ThroughputMeter, rss_skew,
                        writeback_extras)

TRAFFIC_KINDS = ("uniform", "poisson", "bursty")


class Server(Protocol):
    def poll_once(self) -> int: ...


@dataclass(frozen=True)
class TrafficPattern:
    """Static traffic description (rate/size/pattern), or trace replay.

    ``kind``:

    * ``uniform`` — constant inter-arrival ``1/pps``;
    * ``poisson`` — pre-drawn i.i.d. exponential inter-arrivals with mean
      ``1/pps`` (a true Poisson process; the seed implementation re-drew
      ``rng.poisson(cumulative_target)`` each iteration, which is
      non-monotonic in expectation and has the wrong marginal distribution);
    * ``bursty`` — back-to-back trains of ``burst_len`` packets, trains
      spaced so the long-run rate matches ``rate_gbps``.
    """

    rate_gbps: float = 1.0
    packet_size: int = 1518
    kind: str = "uniform"          # uniform | poisson | bursty
    burst_len: int = 32            # for kind="bursty": packets per burst train
    trace: Optional[Sequence[Tuple[int, int]]] = None  # [(t_ns_offset, size)]
    seed: int = 0

    def packets_per_second(self) -> float:
        return self.rate_gbps * 1e9 / 8.0 / self.packet_size

    def emission_schedule(
        self, duration_ns: int, rng: Optional[np.random.Generator] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Analytic per-packet emission times for one run.

        Returns ``(times_ns int64, sizes int32)``, times non-decreasing and
        ``< duration_ns`` (bursty trains may start before the cutoff and
        finish their train).  Fully determined by the pattern + rng state, so
        two runs with the same seed emit identical schedules — the root of
        run-to-run determinism.

        The schedule is materialized up front (12 bytes/packet): high-rate
        runs should use short simulated durations — a 1 ms window at
        400 Gbps/64B is ~780k packets.  Chunked/streaming schedules for
        multi-minute trace replays are a ROADMAP item.
        """
        empty = (np.empty(0, dtype=np.int64), np.empty(0, dtype=np.int32))
        if self.trace is not None:
            raw = [(int(t), max(MIN_FRAME, int(s))) for t, s in self.trace]
            if any(t < 0 for t, _ in raw):
                raise ValueError("trace time offsets must be >= 0")
            # the contract is "times non-decreasing": an out-of-order trace
            # would silently corrupt both run_sim's event loop and run's
            # searchsorted credit, so sort here (stable: equal-time entries
            # keep their input order)
            raw.sort(key=lambda e: e[0])
            entries = [e for e in raw if e[0] < duration_ns]
            if not entries:
                return empty
            times = np.array([t for t, _ in entries], dtype=np.int64)
            sizes = np.array([s for _, s in entries], dtype=np.int32)
            return times, sizes
        pps = self.packets_per_second()
        if pps <= 0 or duration_ns <= 0:
            return empty
        gap_ns = 1e9 / pps
        if self.kind == "uniform":
            n = int(duration_ns * 1e-9 * pps)
            times = (np.arange(n, dtype=np.float64) * gap_ns).astype(np.int64)
        elif self.kind == "poisson":
            rng = rng if rng is not None else np.random.default_rng(self.seed)
            chunks: List[np.ndarray] = []
            last = 0.0
            block = max(64, int(duration_ns * 1e-9 * pps) + 64)
            while last < duration_ns:
                cum = np.cumsum(rng.exponential(gap_ns, size=block)) + last
                chunks.append(cum)
                last = float(cum[-1])
            cat = np.concatenate(chunks)
            times = cat[cat < duration_ns].astype(np.int64)
        elif self.kind == "bursty":
            train_gap = gap_ns * self.burst_len
            n_trains = max(1, int(np.ceil(duration_ns / train_gap)))
            starts = (np.arange(n_trains, dtype=np.float64) * train_gap)
            starts = starts[starts < duration_ns]
            times = np.repeat(starts.astype(np.int64), self.burst_len)
        else:
            raise ValueError(
                f"unknown traffic kind {self.kind!r}; expected one of "
                f"{TRAFFIC_KINDS}")
        sizes = np.full(len(times), self.packet_size, dtype=np.int32)
        return times, sizes


@dataclass
class _Flight:
    sent: int = 0
    received: int = 0
    integrity_errors: int = 0
    # emissions that found the generator out of buffers: counted as sent
    # (offered load) but never put on a wire.  Without this counter the
    # loss shows up as generic "dropped" with nothing attributing it —
    # pool-level ``alloc_failures`` (rx_nombuf) aggregates every consumer
    # of the pool, not the generator's own starvation.
    alloc_failures: int = 0
    # completions whose frame came back with the ECN CE bit set (an AQM on
    # the fabric marked instead of dropping); only surfaced in reports when
    # nonzero or when a rate controller is attached
    ce_marked: int = 0
    checksums: dict = field(default_factory=dict)


def _port_wire(port: Port) -> Wire:
    """One direction of the port's attached link (ideal if unconfigured)."""
    return Wire(gbps=getattr(port, "link_gbps", 0.0),
                latency_ns=getattr(port, "link_latency_ns", 0))


class DctcpRateController:
    """DCTCP-style rate adaptation over virtual-time windows.

    The hardware generator has no TCP stack, so congestion control is modeled
    the way DCTCP's fluid model describes it: per *window* (a fixed slice of
    virtual time, standing in for an RTT round) the controller measures the
    fraction ``F`` of echoes that carried a CE mark — plus any sends old
    enough that their echo is overdue, inferred lost — and keeps an EWMA

        ``alpha <- (1 - g) * alpha + g * F``

    A window with any marks/losses cuts the offered rate by ``alpha/2``
    (DCTCP's proportional backoff); the ``k``-th consecutive clean window
    grows it additively by ``k * increase_gbps`` (DCQCN-style fast
    recovery: near the operating point marks are frequent, the clean run
    stays short and steps stay small, while after a deep cut a long clean
    run ramps the rate back in O(sqrt(deficit)) windows instead of
    O(deficit)).  Multiplicative decrease with additive increase (AIMD)
    is what makes competing clients converge toward a fair share — a
    multiplicative increase would leave per-client rates wandering apart.
    The rate is clamped to ``[min_gbps, max_gbps]`` where ``max_gbps`` is
    the attachment link's line rate.

    Everything is plain arithmetic on counters fed by the generator
    (``on_send`` / ``on_ack``) — no RNG, no wall clock — so runs are
    bit-identical per config + seed.  Loss inference is evidence-based: a
    send is only written off once an echo for a *later* send has come back —
    FIFO proof that the fabric already had its chance to deliver it (the
    topology fabric is in-order per client path).  Batching stalls (NIC-side
    writeback holding a whole in-order tail) therefore never masquerade as
    congestion loss; the flip side is that losses at the very end of a run,
    with no later echo to prove them, go uninferred — harmless, since there
    is no window left to adapt.
    """

    __slots__ = ("rate_gbps", "window_ns", "gain", "min_gbps", "max_gbps",
                 "increase_gbps", "max_inflight", "alpha", "window_end",
                 "sent", "acked", "marked", "lost_accounted", "windows",
                 "rate_min", "rate_max", "_acked_at_roll", "_marked_at_roll",
                 "_hist", "_max_acked_sent", "_clean_run")

    def __init__(self, rate_gbps: float, window_ns: int,
                 gain: float = 0.0625, min_gbps: float = 0.05,
                 max_gbps: float = float("inf"),
                 increase_gbps: float = 0.25, max_inflight: int = 0,
                 start_ns: int = 0):
        if rate_gbps <= 0:
            raise ValueError("rate_gbps must be > 0")
        if window_ns < 1:
            raise ValueError("window_ns must be >= 1")
        if not (0.0 < gain <= 1.0):
            raise ValueError("gain must be in (0, 1]")
        if min_gbps <= 0 or min_gbps > max_gbps:
            raise ValueError("need 0 < min_gbps <= max_gbps")
        if increase_gbps <= 0.0:
            raise ValueError("increase_gbps must be > 0")
        if max_inflight < 0:
            raise ValueError("max_inflight must be >= 0 (0 == uncapped)")
        self.rate_gbps = min(max(rate_gbps, min_gbps), max_gbps)
        self.window_ns = int(window_ns)
        self.gain = gain
        self.min_gbps = min_gbps
        self.max_gbps = max_gbps
        self.increase_gbps = increase_gbps
        self.max_inflight = int(max_inflight)
        # alpha starts saturated (as in the Linux DCTCP implementation):
        # the first congested window then cuts the rate in half instead of
        # waiting ~1/gain windows for the EWMA to warm up, which matters
        # during an incast transient where every window is fully marked.
        self.alpha = 1.0
        self.window_end = int(start_ns) + self.window_ns
        self.sent = 0
        self.acked = 0
        self.marked = 0
        self.lost_accounted = 0
        self.windows = 0
        self.rate_min = self.rate_gbps
        self.rate_max = self.rate_gbps
        self._acked_at_roll = 0
        self._marked_at_roll = 0
        # (window boundary, cumulative sends with stamp < boundary) per roll,
        # consumed left-to-right as echo evidence advances past boundaries
        self._hist: deque = deque()
        self._max_acked_sent = -1  # newest send stamp seen on any echo
        self._clean_run = 0        # consecutive clean windows (fast recovery)

    def _roll_to(self, t_ns: int) -> None:
        while t_ns >= self.window_end:
            delivered = self.acked - self._acked_at_roll
            fresh_marked = self.marked - self._marked_at_roll
            # FIFO-evidence loss inference: the newest send stamp seen on an
            # echo proves every send from before that boundary is either
            # delivered or gone; count the gone ones (once each)
            hist = self._hist
            while len(hist) > 1 and hist[1][0] <= self._max_acked_sent:
                hist.popleft()
            new_lost = 0
            if hist and hist[0][0] <= self._max_acked_sent:
                overdue = hist[0][1] - self.acked - self.lost_accounted
                new_lost = overdue if overdue > 0 else 0
            self.lost_accounted += new_lost
            denom = delivered + new_lost
            if denom > 0:
                frac = (fresh_marked + new_lost) / denom
                self.alpha = (1.0 - self.gain) * self.alpha + self.gain * frac
                if frac > 0.0:
                    self.rate_gbps *= 1.0 - self.alpha / 2.0
                    self._clean_run = 0
                else:
                    self._clean_run += 1
                    self.rate_gbps += self.increase_gbps * self._clean_run
                if self.rate_gbps < self.min_gbps:
                    self.rate_gbps = self.min_gbps
                elif self.rate_gbps > self.max_gbps:
                    self.rate_gbps = self.max_gbps
                if self.rate_gbps < self.rate_min:
                    self.rate_min = self.rate_gbps
                elif self.rate_gbps > self.rate_max:
                    self.rate_max = self.rate_gbps
                self.windows += 1
            self._acked_at_roll = self.acked
            self._marked_at_roll = self.marked
            hist.append((self.window_end, self.sent))
            if len(hist) > 4096:   # bound memory under pathological stalls
                hist.popleft()
            self.window_end += self.window_ns

    def on_send(self, t_ns: int) -> None:
        self._roll_to(int(t_ns))
        self.sent += 1

    def on_ack(self, t_ns: int, ce: bool,
               sent_ns: Optional[int] = None) -> None:
        self._roll_to(int(t_ns))
        self.acked += 1
        if ce:
            self.marked += 1
        if sent_ns is not None and int(sent_ns) > self._max_acked_sent:
            self._max_acked_sent = int(sent_ns)

    def on_acks(self, t_ns: int, n: int, n_marked: int,
                max_sent_ns: Optional[int] = None) -> None:
        self._roll_to(int(t_ns))
        self.acked += int(n)
        self.marked += int(n_marked)
        if max_sent_ns is not None and int(max_sent_ns) > self._max_acked_sent:
            self._max_acked_sent = int(max_sent_ns)

    @property
    def outstanding(self) -> int:
        """Sends neither echoed back nor written off as lost."""
        return self.sent - self.acked - self.lost_accounted

    def can_send(self) -> bool:
        """Self-clocking guard (TX-credit / cwnd analogue): with
        ``max_inflight`` set, refuse new sends while that many frames are
        outstanding.  Pure rate pacing keeps integrating overshoot into the
        bottleneck queue for a full feedback delay; the in-flight cap is
        the ack-clocked backpressure that stops it instantly, the way a
        TCP sender can never exceed its window."""
        return self.max_inflight <= 0 or self.outstanding < self.max_inflight

    def gap_ns(self, size_bytes: int) -> float:
        """Inter-emission gap (ns) at the current rate for one frame."""
        return size_bytes * 8.0 / self.rate_gbps


class LoadGen:
    """Software model of a hardware traffic generator wired to N ports."""

    def __init__(
        self,
        ports: Sequence[Port],
        ts_offset: int = DEFAULT_TS_OFFSET,
        verify_integrity: bool = False,
        max_tx_burst: int = 64,
        latency_capacity_hint: int = 1 << 16,
        n_flows: int = 256,
        src_ip_base: Optional[int] = None,
        dst_ip: Optional[int] = None,
    ):
        if n_flows < 1:
            raise ValueError("n_flows must be >= 1")
        # the flow 4-tuple occupies fixed bytes FLOW_OFFSET..FLOW_OFFSET+12;
        # a timestamp stamped inside that window would be overwritten and
        # every RTT would silently be garbage
        if ts_offset + 8 > FLOW_OFFSET and ts_offset < FLOW_OFFSET + FLOW_SIZE:
            raise ValueError(
                f"ts_offset={ts_offset} overlaps the flow fields at "
                f"[{FLOW_OFFSET}, {FLOW_OFFSET + FLOW_SIZE})"
            )
        self.ports = list(ports)
        self.ts_offset = ts_offset
        self.verify_integrity = verify_integrity
        self.max_tx_burst = max_tx_burst
        # distinct flow 4-tuples emitted round-robin; RSS spreads them over
        # the port's RX queues (the Fig. 3(a) core-scaling traffic shape).
        # Topology scenarios pin src_ip_base (this generator's client /16,
        # what a switch routes replies back on) and dst_ip (the target node).
        self.n_flows = n_flows
        self.src_ip_base = src_ip_base
        self.dst_ip = dst_ip
        self.latency = LatencyRecorder(latency_capacity_hint)
        self.meter = ThroughputMeter()
        self.flight = _Flight()
        self._next_seq = 0
        # optional DCTCP-style rate controller (attach_cc); when set,
        # run_sim generates its emission schedule incrementally and every
        # completion feeds the controller its CE bit
        self.cc: Optional[DctcpRateController] = None

    def attach_cc(self, cc: DctcpRateController) -> None:
        """Attach a rate controller; subsequent sends/completions feed it."""
        self.cc = cc

    # -- wire-side primitives ------------------------------------------------
    def _write_frame(self, pool: PacketPool, slot: int, size: int,
                     stamp_ns: int, rng: Optional[np.random.Generator],
                     record_checksum: bool = True) -> int:
        """Fill one allocated slot: seq, timestamp, flow tuple, checksum.
        Fabric emitters pass ``record_checksum=False`` and record their own
        (echo-safe) checksum over the byte copy instead."""
        seq = self._next_seq
        self._next_seq += 1
        pool.write_packet(
            slot, seq=seq, length=size, ts_offset=self.ts_offset,
            timestamp_ns=stamp_ns, fill=(seq & 0xFF) if rng is None else None,
            rng=rng,
        )
        write_flow(pool.arena[slot], *flow_tuple_for_id(
            seq % self.n_flows, src_ip_base=self.src_ip_base,
            dst_ip=self.dst_ip))
        if self.verify_integrity and record_checksum:
            self.flight.checksums[seq] = payload_checksum(
                pool.view(slot, size), self.ts_offset
            )
        return seq

    def _send_one(self, port: Port, size: int, now_ns: int,
                  rng: Optional[np.random.Generator]) -> bool:
        slot = port.pool.alloc()
        if slot is None:
            # Generator out of buffers == system not recycling fast enough.
            self.flight.sent += 1
            self.flight.alloc_failures += 1
            return False
        self._write_frame(port.pool, slot, size, now_ns, rng)
        self.flight.sent += 1
        # RSS steers the frame to a queue; ring overflow → drop at the NIC
        # (the Port recycles the buffer)
        return port.deliver(slot, size)

    def _send_burst(self, port: Port, n: int, size: int, now_ns: int) -> int:
        """Vectorized burst emit (non-integrity fast path). Returns #delivered."""
        slots = port.pool.alloc_burst(n)
        self.flight.sent += n
        if len(slots) < n:
            self.flight.alloc_failures += n - len(slots)
        if not slots:
            return 0
        slots_arr = np.asarray(slots, dtype=np.int64)
        seqs = np.arange(self._next_seq, self._next_seq + len(slots), dtype=np.int64)
        self._next_seq += len(slots)
        write_packets_vec(port.pool, slots_arr, seqs, size, self.ts_offset, now_ns)
        write_flow_ids_vec(port.pool, slots_arr, seqs % self.n_flows,
                           src_ip_base=self.src_ip_base, dst_ip=self.dst_ip)
        lengths = np.full(len(slots), size, dtype=np.int32)
        # RSS routes the burst across the port's RX queues; per-queue ring
        # overflow drops at the NIC (the Port recycles those buffers)
        return port.deliver_burst(slots_arr, lengths)

    def _drain_port(self, port: Port, now_ns: int,
                    back_wire: Optional[Wire] = None) -> int:
        """Collect forwarded packets from every TX queue; timestamp-compare
        for RTT.  With ``back_wire`` (virtual time), every frame pays the
        return link's serialization + latency before its RTT is recorded."""
        if not self.verify_integrity:
            slots, lengths = port.drain_tx_bursts(self.max_tx_burst)
            n = len(slots)
            if n == 0:
                return 0
            stamps = read_stamps_vec(port.pool, slots, self.ts_offset)
            if back_wire is None:
                rtts = np.maximum(0, now_ns - stamps)
                t0 = t1 = now_ns
            else:
                arrivals = back_wire.transmit_burst(now_ns, lengths)
                rtts = np.maximum(0, arrivals - stamps)
                t0, t1 = int(arrivals[0]), int(arrivals[-1])
            self.latency.record_many(rtts)
            self.meter.merge_counts(n, int(lengths.sum()), t0, t1)
            self.flight.received += n
            if self.cc is not None:
                n_marked = int(read_ce_vec(port.pool, slots).sum())
                self.flight.ce_marked += n_marked
                self.cc.on_acks(t1, n, n_marked,
                                max_sent_ns=int(stamps.max()))
            port.pool.free_burst([int(s) for s in slots])
            return n
        done = port.drain_tx(self.max_tx_burst)
        for slot, length in done:
            buf = port.pool.view(slot, length)
            sent_ns = read_stamp(buf, self.ts_offset)
            rx_ns = (now_ns if back_wire is None
                     else back_wire.transmit(now_ns, length))
            rtt = max(0, rx_ns - sent_ns)
            self.latency.record(rtt)
            self.meter.on_packet(length, rx_ns)
            seq = read_seq(buf)
            want = self.flight.checksums.pop(seq, None)
            if want is not None and payload_checksum(buf, self.ts_offset) != want:
                self.flight.integrity_errors += 1
            self.flight.received += 1
            if self.cc is not None:
                ce = read_ce(buf)
                if ce:
                    self.flight.ce_marked += 1
                self.cc.on_ack(rx_ns, ce, sent_ns=sent_ns)
            port.pool.free(slot)
        return len(done)

    # -- fabric attachment (switch/topology mode) -----------------------------
    # A generator attached to a :class:`~repro.core.switch.Switch` port does
    # not own the far NIC: its frames leave as raw bytes on the fabric and
    # completions come back the same way.  These two primitives are the
    # switch-port counterparts of _send_one/_drain_port; the topology driver
    # (:mod:`repro.exp.topology`) supplies the timing.

    def make_frame(self, pool: PacketPool, size: int, stamp_ns: int,
                   rng: Optional[np.random.Generator] = None,
                   ) -> Optional[np.ndarray]:
        """Emit one frame for a fabric attachment: format it in ``pool``
        (this generator's own buffer arena) and hand back a byte copy — the
        serialized form a wire carries between address spaces.  Returns None
        (and counts the send, so the loss is attributed) when the generator
        is out of buffers."""
        slot = pool.alloc()
        self.flight.sent += 1
        if self.cc is not None:
            # alloc failures still count: a starved generator is offered
            # load that will never echo, which the controller must see
            self.cc.on_send(int(stamp_ns))
        if slot is None:
            self.flight.alloc_failures += 1
            return None
        seq = self._write_frame(pool, slot, size, stamp_ns, rng,
                                record_checksum=False)
        frame = pool.view(slot, size).copy()
        pool.free(slot)
        if self.verify_integrity:
            # the fabric's echo server legitimately rewrites macs + flow IPs,
            # so integrity is checked past the flow tuple
            self.flight.checksums[seq] = echo_payload_checksum(frame)
        return frame

    def complete_frame(self, frame: np.ndarray, now_ns: int) -> None:
        """Record one completion arriving off the fabric at virtual
        ``now_ns`` (the switch's egress wire already charged serialization +
        propagation): timestamp-compare for RTT, throughput, integrity."""
        sent_ns = read_stamp(frame, self.ts_offset)
        self.latency.record(max(0, int(now_ns) - sent_ns))
        self.meter.on_packet(len(frame), int(now_ns))
        if self.verify_integrity:
            want = self.flight.checksums.pop(read_seq(frame), None)
            if want is not None and echo_payload_checksum(frame) != want:
                self.flight.integrity_errors += 1
        ce = read_ce(frame)
        if ce:
            self.flight.ce_marked += 1
        if self.cc is not None:
            self.cc.on_ack(int(now_ns), ce, sent_ns=sent_ns)
        self.flight.received += 1

    # -- closed-loop (deterministic, for tests) -------------------------------
    def run_closed_loop(self, server: Server, n_packets: int,
                        packet_size: int = 256, window: int = 32,
                        rng: Optional[np.random.Generator] = None,
                        clock: Optional[SimClock] = None,
                        round_ns: int = 1_000,
                        max_rounds: int = 2_000_000) -> RunReport:
        """Send exactly n packets keeping ≤window in flight; fully drain.

        With a :class:`SimClock`, each scheduling round advances virtual time
        by ``round_ns`` (a processing quantum), so RTTs and stats are exact
        and bit-identical run-to-run; without one, the seed wall-clock
        behaviour is preserved.
        """
        sent = 0
        if clock is not None and hasattr(server, "attach_clock") \
                and getattr(server, "clock", None) is not clock:
            server.attach_clock(clock)
        poll_at = getattr(server, "poll_at", None) if clock is not None else None
        start = time.perf_counter_ns() if clock is None else clock.now_ns  # simlint: disable=SL001 -- wall-clock pacing mode
        rounds = 0
        while self.flight.received < n_packets:
            rounds += 1
            now = time.perf_counter_ns() if clock is None else clock.now_ns  # simlint: disable=SL001 -- wall-clock pacing mode
            while sent < n_packets and (sent - self.flight.received) < window:
                self._send_one(self.ports[sent % len(self.ports)], packet_size, now, rng)
                sent += 1
            for port in self.ports:
                port.flush_rx()  # closed loop: no idle traffic to trigger writeback
            if clock is None:
                server.poll_once()
                now = time.perf_counter_ns()  # simlint: disable=SL001 -- wall-clock pacing mode
            else:
                clock.advance(round_ns)  # the quantum packets spend in service
                if poll_at is not None:
                    poll_at(clock.now_ns)
                else:
                    server.poll_once()
                now = clock.now_ns
            for port in self.ports:
                self._drain_port(port, now)
            if clock is None:
                if time.perf_counter_ns() - start > 60e9:  # simlint: disable=SL001 -- wall-clock pacing mode
                    break  # safety: never hang a test
            elif rounds >= max_rounds:
                break  # safety: never hang a test (virtual-time analogue)
        return self._report(offered_gbps=0.0)

    # -- open-loop virtual-time run (the default measurement mode) ------------
    def run_sim(self, server: Server, pattern: TrafficPattern,
                duration_s: float = 0.25,
                clock: Optional[SimClock] = None,
                max_rounds: int = 50_000_000,
                sched: Optional[EventScheduler] = None) -> RunReport:
        """Offered-load run in virtual time: event-by-event over the analytic
        emission schedule.  Deterministic, host-speed-independent, and able
        to simulate arbitrary rates (100 Gbps on one laptop core).

        Event loop: the next event is the earliest of (next scheduled
        emission, next frame landing off a wire, next lcore finishing its
        modeled work or giving up on burst accumulation, next event on
        ``sched``).  At each event time we emit due frames onto the forward
        wires, deliver due frames into RX rings (RSS + overflow drops), fire
        due scheduler events (descriptor-cache writeback timeouts), give the
        server one scheduling round, and drain TX rings through the return
        wires (recording RTT at return-arrival time).

        ``sched`` carries NIC-side timers (the DCA writeback-timeout events
        armed via :meth:`~repro.core.ethdev.EthDev.attach_dca`); when not
        passed explicitly it is discovered from the ports, so factory-built
        setups (MSB trials) keep their timers firing.
        """
        if clock is None:
            clock = getattr(server, "clock", None)
        if clock is None:
            clock = SimClock()
        if hasattr(server, "attach_clock") \
                and getattr(server, "clock", None) is not clock:
            server.attach_clock(clock)
        if sched is None:
            sched = next((s for s in (getattr(p, "event_sched", None)
                                      for p in self.ports) if s is not None),
                         None)
        rng = np.random.default_rng(pattern.seed)
        use_rng_payload = self.verify_integrity
        start = clock.now_ns
        cc = self.cc
        cc_next: Optional[float] = None
        cc_end = start + int(duration_s * 1e9)
        if cc is not None:
            # rate-adaptive mode: each emission gap depends on the
            # controller's rate *at that moment*, so the schedule is
            # generated incrementally instead of precomputed
            times = np.empty(0, dtype=np.int64)
            sizes = np.empty(0, dtype=np.int32)
            if pattern.packets_per_second() > 0 and cc_end > start:
                cc_next = float(start)
                self.meter.open_window(start)
        else:
            times, sizes = pattern.emission_schedule(int(duration_s * 1e9),
                                                     rng)
            if len(times):
                times = times + start
                # anchor throughput at the first emission so a terminal
                # writeback-flush drain can't shrink the measurement window
                self.meter.open_window(int(times[0]))
        nports = len(self.ports)
        fwd = [_port_wire(p) for p in self.ports]
        back = [_port_wire(p) for p in self.ports]
        # frames in flight on each forward wire: FIFO of (arrival, slot, size)
        on_wire: List[deque] = [deque() for _ in self.ports]
        poll_at = getattr(server, "poll_at", None)
        next_free = getattr(server, "next_free_ns", None)
        i, n = 0, len(times)
        flushed_idle = False
        for _ in range(max_rounds):
            now = clock.now_ns
            moved = 0
            # 1) emissions due: stamp with the *scheduled* time and put the
            #    frame on its port's forward wire
            while i < n and times[i] <= now:
                t_emit = int(times[i])
                size = int(sizes[i])
                port = self.ports[i % nports]
                slot = port.pool.alloc()
                self.flight.sent += 1
                if slot is not None:
                    self._write_frame(port.pool, slot, size, t_emit,
                                      rng if use_rng_payload else None)
                    arrival = fwd[i % nports].transmit(t_emit, size)
                    on_wire[i % nports].append((arrival, slot, size))
                else:
                    # out of buffers: the emission still counts as offered
                    # load, but attribute the vanished frame explicitly
                    self.flight.alloc_failures += 1
                i += 1
                moved += 1
            # 1b) rate-adaptive emissions: same body, but the next emission
            #     time is minted per frame from the controller's current rate
            while cc_next is not None and int(cc_next) <= now:
                t_emit = int(cc_next)
                size = pattern.packet_size
                # a tick finding the in-flight cap exhausted is forfeited
                # (paced probing); the cursor still advances
                if cc.can_send():
                    port = self.ports[i % nports]
                    slot = port.pool.alloc()
                    self.flight.sent += 1
                    cc.on_send(t_emit)
                    if slot is not None:
                        self._write_frame(port.pool, slot, size, t_emit,
                                          rng if use_rng_payload else None)
                        arrival = fwd[i % nports].transmit(t_emit, size)
                        on_wire[i % nports].append((arrival, slot, size))
                    else:
                        self.flight.alloc_failures += 1
                    i += 1
                moved += 1
                cc_next += cc.gap_ns(size)
                if cc_next >= cc_end:
                    cc_next = None
            # 2) wire arrivals due: NIC-side delivery (RSS steering; ring
            #    overflow drops here, exactly like hardware)
            for pi, dq in enumerate(on_wire):
                port = self.ports[pi]
                while dq and dq[0][0] <= now:
                    _, slot, size = dq.popleft()
                    port.deliver(slot, size)
                    moved += 1
            # 2b) scheduler events due: descriptor-cache writeback timeouts
            #     fire after deliveries at `now` (a threshold crossing at the
            #     same instant cancels the timer first), before the PMD polls
            if sched is not None:
                moved += sched.run_until(now)
            # 3) one server scheduling round at virtual `now`
            if poll_at is not None:
                moved += poll_at(now)
            else:
                moved += server.poll_once()
            # 4) wire-side TX drain; RTT recorded at return-link arrival
            for pi, port in enumerate(self.ports):
                moved += self._drain_port(port, now, back_wire=back[pi])
            # 5) advance to the next event
            cands = []
            if i < n:
                cands.append(int(times[i]))
            if cc_next is not None:
                cands.append(int(cc_next))
            for dq in on_wire:
                if dq:
                    cands.append(dq[0][0])
            if next_free is not None:
                nf = next_free(now)
                if nf is not None:
                    cands.append(nf)
            if sched is not None:
                nt = sched.next_time_ns()
                if nt is not None:
                    cands.append(nt)
            if cands:
                flushed_idle = False
                clock.advance_to(min(cands))
                continue
            if moved > 0:
                flushed_idle = False
                continue
            if not flushed_idle:
                # quiet wire: the NIC's timeout-driven descriptor-cache
                # writeback fires, releasing sub-threshold completions
                for port in self.ports:
                    port.flush_rx()
                flushed_idle = True
                continue
            break  # nothing scheduled, nothing moving: remaining == drops
        rep = self._report(
            offered_gbps=pattern.rate_gbps if pattern.trace is None else 0.0)
        rep.extras["sim_time"] = 1.0
        rep.extras["virtual_elapsed_ns"] = float(clock.now_ns - start)
        return rep

    # -- open-loop timed run (wall-clock mode, for host-overhead studies) -----
    def run(self, server: Server, pattern: TrafficPattern,
            duration_s: float = 0.25, drain_timeout_s: float = 0.5) -> RunReport:
        """Offered-load run paced against the host clock.

        Uses the same analytic :meth:`TrafficPattern.emission_schedule` as
        virtual time (so Poisson pacing is a true Poisson process here too);
        the credit at elapsed wall time t is the number of scheduled
        emissions ≤ t.
        """
        rng = np.random.default_rng(pattern.seed)
        use_rng_payload = self.verify_integrity
        duration_ns = int(duration_s * 1e9)
        times, sizes = pattern.emission_schedule(duration_ns, rng)
        n_sched = len(times)
        fixed_size = pattern.trace is None
        start = time.perf_counter_ns()  # simlint: disable=SL001 -- wall-clock pacing mode
        end = start + duration_ns
        if n_sched:
            self.meter.open_window(start + int(times[0]))
        sent_i = 0
        while True:
            now = time.perf_counter_ns()  # simlint: disable=SL001 -- wall-clock pacing mode
            if now >= end:
                break
            # how many scheduled emissions are due by now?
            credit = int(np.searchsorted(times, now - start, side="right"))
            burst = min(credit - sent_i, self.max_tx_burst)
            if burst > 0:
                if fixed_size and not use_rng_payload:
                    # vectorized emit, split evenly across ports (multi-NIC)
                    nports = len(self.ports)
                    share = burst // nports
                    extra = burst % nports
                    for pi, port in enumerate(self.ports):
                        k = share + (1 if pi < extra else 0)
                        if k > 0:
                            self._send_burst(port, k, pattern.packet_size, now)
                    sent_i += burst
                else:
                    for _ in range(burst):
                        port = self.ports[sent_i % len(self.ports)]
                        self._send_one(port, int(sizes[sent_i]), now,
                                       rng if use_rng_payload else None)
                        sent_i += 1
            server.poll_once()
            now = time.perf_counter_ns()  # simlint: disable=SL001 -- wall-clock pacing mode
            for port in self.ports:
                self._drain_port(port, now)
        # drain in-flight tail so drop accounting is exact
        drain_end = time.perf_counter_ns() + int(drain_timeout_s * 1e9)  # simlint: disable=SL001 -- wall-clock pacing mode
        while (self.flight.received < self.flight.sent
               and time.perf_counter_ns() < drain_end):  # simlint: disable=SL001 -- wall-clock pacing mode
            for port in self.ports:
                port.flush_rx()
            if server.poll_once() == 0 and all(p.tx_pending == 0 for p in self.ports):
                # nothing moving and nothing queued: remaining packets were dropped
                break
            now = time.perf_counter_ns()  # simlint: disable=SL001 -- wall-clock pacing mode
            for port in self.ports:
                self._drain_port(port, now)
        return self._report(
            offered_gbps=pattern.rate_gbps if pattern.trace is None else 0.0)

    def _report(self, offered_gbps: float) -> RunReport:
        rep = RunReport(
            offered_gbps=offered_gbps,
            achieved_gbps=self.meter.gbps,
            achieved_mpps=self.meter.mpps,
            sent=self.flight.sent,
            received=self.flight.received,
            dropped=self.flight.sent - self.flight.received,
            latency=self.latency.stats(),
            histogram=self.latency.histogram(),
        )
        rep.extras["integrity_errors"] = float(self.flight.integrity_errors)
        # generator buffer starvation (offered load that never hit a wire)
        rep.extras["loadgen_alloc_failures"] = float(self.flight.alloc_failures)
        # ECN / congestion-control telemetry, only when the fabric actually
        # marked something or a controller is attached (keeps pre-AQM
        # reports byte-identical)
        if self.flight.ce_marked or self.cc is not None:
            rep.extras["ce_marked"] = float(self.flight.ce_marked)
        if self.cc is not None:
            rep.extras["cc_windows"] = float(self.cc.windows)
            rep.extras["cc_final_rate_gbps"] = self.cc.rate_gbps
            rep.extras["cc_min_rate_gbps"] = self.cc.rate_min
            rep.extras["cc_max_rate_gbps"] = self.cc.rate_max
            rep.extras["cc_alpha"] = self.cc.alpha
            rep.extras["cc_lost_inferred"] = float(self.cc.lost_accounted)
        # per-RX-ring descriptor-writeback telemetry (the Fig. 4 observable)
        rep.extras.update(writeback_extras(self.ports))
        # per-queue NIC-side accounting (the RSS-skew observable); only
        # reported for multi-queue ports to keep single-queue reports terse
        for pi, port in enumerate(self.ports):
            if port.n_queues <= 1:
                continue
            delivered = port.rx_queue_delivered()
            dropped = port.rx_queue_dropped()
            for qi in range(port.n_queues):
                rep.extras[f"p{pi}q{qi}_rx_delivered"] = float(delivered[qi])
                rep.extras[f"p{pi}q{qi}_rx_dropped"] = float(dropped[qi])
            skew = rss_skew(delivered)
            rep.extras[f"p{pi}_rss_imbalance"] = skew["max_over_mean"]
            rep.extras[f"p{pi}_rss_cov"] = skew["cov"]
        return rep


# -- bandwidth test mode ------------------------------------------------------

def find_max_sustainable_bandwidth(
    make_setup: Callable[[], Tuple[Server, List[Port]]],
    packet_size: int = 1518,
    start_gbps: float = 0.25,
    max_gbps: float = 400.0,
    trial_s: float = 0.2,
    drop_tolerance_pct: float = 0.0,
    refine_iters: int = 5,
    pattern_kind: str = "uniform",
    sim_time: Optional[bool] = None,
    engine: str = "event",
) -> Tuple[float, List[RunReport]]:
    """EtherLoadGen bandwidth-test mode: "gradually increases the bandwidth to
    find the maximum sustainable bandwidth ... without packet drops."

    Multiplicative increase until the system drops packets, then bisection
    between the last sustainable and first unsustainable rates.  The reported
    MSB is the highest *offered* rate whose trial actually sustained (the
    per-trial achieved rates live in the returned reports) — and the
    bisection's lower bound is always a rate that was probed and sustained:
    if the very first ramp trial fails, the search probes downward before
    refining instead of assuming an unvalidated ``bad/2`` floor.  Every trial
    uses a fresh server/rings via ``make_setup`` so state never leaks.

    ``sim_time``: True runs each trial in virtual time (deterministic,
    host-independent — the default through :mod:`repro.exp`); False forces
    wall-clock; None auto-detects (virtual when the factory's server carries
    an attached :class:`SimClock`).  ``engine`` selects the virtual-time
    execution engine per trial: ``"event"`` (the per-event loop),
    ``"epoch"`` (the epoch-batched fast path of
    :mod:`repro.core.fastpath`, bit-identical reports), or ``"epoch-jit"``
    (same, with the JAX kernel).  Returns (msb_gbps, all trial reports).
    """

    reports: List[RunReport] = []

    def trial(rate: float) -> RunReport:
        server, ports = make_setup()
        lg = LoadGen(ports)
        pattern = TrafficPattern(rate_gbps=rate, packet_size=packet_size,
                                 kind=pattern_kind)
        use_sim = sim_time
        if use_sim is None:
            use_sim = getattr(server, "clock", None) is not None
        if use_sim:
            if engine in ("epoch", "epoch-jit"):
                from .fastpath import run_epoch_sim  # avoid import cycle
                rep = run_epoch_sim(lg, server, pattern, duration_s=trial_s,
                                    use_jax=(engine == "epoch-jit"))
            else:
                rep = lg.run_sim(server, pattern, duration_s=trial_s)
        else:
            rep = lg.run(server, pattern, duration_s=trial_s)
        reports.append(rep)
        return rep

    def sustained(rep: RunReport) -> bool:
        return rep.drop_pct <= drop_tolerance_pct and rep.sent > 0

    # Phase 1: multiplicative ramp.  ``good`` tracks the highest *offered*
    # rate that sustained (achieved rates stay in the reports).
    good, bad = 0.0, None
    rate = start_gbps
    while rate <= max_gbps:
        if sustained(trial(rate)):
            good = max(good, rate)
            rate *= 2.0
        else:
            bad = rate
            break
    if bad is None:
        return good, reports
    lo, hi = bad / 2.0, bad
    if good == 0.0:
        # The very first ramp trial failed, so ``lo`` was never validated as
        # sustainable.  Probe downward until a sustainable floor is found
        # (restoring the bisection invariant) or give up at 0.
        found = False
        for _ in range(12):
            if sustained(trial(lo)):
                good, found = lo, True
                break
            lo, hi = lo / 2.0, lo
        if not found:
            return 0.0, reports
    # Phase 2: bisection between a validated-sustainable lo and a failing hi
    for _ in range(refine_iters):
        mid = 0.5 * (lo + hi)
        if sustained(trial(mid)):
            good = max(good, mid)
            lo = mid
        else:
            hi = mid
    return good, reports
