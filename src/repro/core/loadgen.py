"""LoadGen — the EtherLoadGen hardware load-generator model (paper §3.3).

"The hardware load generator model can generate packets at arbitrary rates and
sizes ... parameters are packet rate, packet size, and protocol ... a packet
trace can be passed ... adds a timestamp to each outgoing packet at a
configurable offset and compares the timestamp with the current tick on
incoming packets to compute per-packet round-trip latency ... reports mean,
median, standard deviation, and tail latency ... a packet drop percentage and
a histogram ... also supports a bandwidth test mode where it gradually
increases the bandwidth to find the maximum sustainable bandwidth."

This class implements all of the above against in-process servers
(:class:`~repro.core.pmd.BypassL2FwdServer` or
:class:`~repro.core.kernel_stack.KernelStackServer`).  It plays the NIC role on
the wire side: it DMAs frames into RX descriptor rings and drains TX rings.
Like its hardware counterpart, the generator itself never drops or delays
packets — all loss is attributable to the system under test (ring overflow /
pool exhaustion), which is what "maximum sustainable bandwidth" measures.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Protocol, Sequence, Tuple

import numpy as np

from .packet import (
    DEFAULT_TS_OFFSET,
    FLOW_OFFSET,
    FLOW_SIZE,
    MIN_FRAME,
    PacketPool,
    flow_tuple_for_id,
    payload_checksum,
    read_seq,
    read_seqs_vec,
    read_stamp,
    read_stamps_vec,
    stamp,
    write_flow,
    write_flow_ids_vec,
    write_packets_vec,
)
from .pmd import Port
from .telemetry import LatencyRecorder, RunReport, ThroughputMeter, rss_skew


class Server(Protocol):
    def poll_once(self) -> int: ...


@dataclass(frozen=True)
class TrafficPattern:
    """Static traffic description (rate/size/pattern), or trace replay."""

    rate_gbps: float = 1.0
    packet_size: int = 1518
    kind: str = "uniform"          # uniform | poisson | bursty
    burst_len: int = 32            # for kind="bursty": packets per burst train
    trace: Optional[Sequence[Tuple[int, int]]] = None  # [(t_ns_offset, size)]
    seed: int = 0

    def packets_per_second(self) -> float:
        return self.rate_gbps * 1e9 / 8.0 / self.packet_size


@dataclass
class _Flight:
    sent: int = 0
    received: int = 0
    integrity_errors: int = 0
    checksums: dict = field(default_factory=dict)


class LoadGen:
    """Software model of a hardware traffic generator wired to N ports."""

    def __init__(
        self,
        ports: Sequence[Port],
        ts_offset: int = DEFAULT_TS_OFFSET,
        verify_integrity: bool = False,
        max_tx_burst: int = 64,
        latency_capacity_hint: int = 1 << 16,
        n_flows: int = 256,
    ):
        if n_flows < 1:
            raise ValueError("n_flows must be >= 1")
        # the flow 4-tuple occupies fixed bytes FLOW_OFFSET..FLOW_OFFSET+12;
        # a timestamp stamped inside that window would be overwritten and
        # every RTT would silently be garbage
        if ts_offset + 8 > FLOW_OFFSET and ts_offset < FLOW_OFFSET + FLOW_SIZE:
            raise ValueError(
                f"ts_offset={ts_offset} overlaps the flow fields at "
                f"[{FLOW_OFFSET}, {FLOW_OFFSET + FLOW_SIZE})"
            )
        self.ports = list(ports)
        self.ts_offset = ts_offset
        self.verify_integrity = verify_integrity
        self.max_tx_burst = max_tx_burst
        # distinct flow 4-tuples emitted round-robin; RSS spreads them over
        # the port's RX queues (the Fig. 3(a) core-scaling traffic shape)
        self.n_flows = n_flows
        self.latency = LatencyRecorder(latency_capacity_hint)
        self.meter = ThroughputMeter()
        self.flight = _Flight()
        self._next_seq = 0

    # -- wire-side primitives ------------------------------------------------
    def _send_one(self, port: Port, size: int, now_ns: int,
                  rng: Optional[np.random.Generator]) -> bool:
        slot = port.pool.alloc()
        if slot is None:
            # Generator out of buffers == system not recycling fast enough.
            self.flight.sent += 1
            return False
        seq = self._next_seq
        self._next_seq += 1
        port.pool.write_packet(
            slot, seq=seq, length=size, ts_offset=self.ts_offset,
            timestamp_ns=now_ns, fill=(seq & 0xFF) if rng is None else None, rng=rng,
        )
        write_flow(port.pool.arena[slot], *flow_tuple_for_id(seq % self.n_flows))
        if self.verify_integrity:
            self.flight.checksums[seq] = payload_checksum(
                port.pool.view(slot, size), self.ts_offset
            )
        self.flight.sent += 1
        # RSS steers the frame to a queue; ring overflow → drop at the NIC
        # (the Port recycles the buffer)
        return port.deliver(slot, size)

    def _send_burst(self, port: Port, n: int, size: int, now_ns: int) -> int:
        """Vectorized burst emit (non-integrity fast path). Returns #delivered."""
        slots = port.pool.alloc_burst(n)
        self.flight.sent += n
        if not slots:
            return 0
        slots_arr = np.asarray(slots, dtype=np.int64)
        seqs = np.arange(self._next_seq, self._next_seq + len(slots), dtype=np.int64)
        self._next_seq += len(slots)
        write_packets_vec(port.pool, slots_arr, seqs, size, self.ts_offset, now_ns)
        write_flow_ids_vec(port.pool, slots_arr, seqs % self.n_flows)
        lengths = np.full(len(slots), size, dtype=np.int32)
        # RSS routes the burst across the port's RX queues; per-queue ring
        # overflow drops at the NIC (the Port recycles those buffers)
        return port.deliver_burst(slots_arr, lengths)

    def _drain_port(self, port: Port, now_ns: int) -> int:
        """Collect forwarded packets from every TX queue; timestamp-compare
        for RTT."""
        if not self.verify_integrity:
            slots, lengths = port.drain_tx_bursts(self.max_tx_burst)
            n = len(slots)
            if n == 0:
                return 0
            stamps = read_stamps_vec(port.pool, slots, self.ts_offset)
            rtts = np.maximum(0, now_ns - stamps)
            self.latency.record_many(rtts)
            self.meter.merge_counts(n, int(lengths.sum()), now_ns, now_ns)
            self.flight.received += n
            port.pool.free_burst([int(s) for s in slots])
            return n
        done = port.drain_tx(self.max_tx_burst)
        for slot, length in done:
            buf = port.pool.view(slot, length)
            sent_ns = read_stamp(buf, self.ts_offset)
            rtt = max(0, now_ns - sent_ns)
            self.latency.record(rtt)
            self.meter.on_packet(length, now_ns)
            seq = read_seq(buf)
            want = self.flight.checksums.pop(seq, None)
            if want is not None and payload_checksum(buf, self.ts_offset) != want:
                self.flight.integrity_errors += 1
            self.flight.received += 1
            port.pool.free(slot)
        return len(done)

    # -- closed-loop (deterministic, for tests) -------------------------------
    def run_closed_loop(self, server: Server, n_packets: int,
                        packet_size: int = 256, window: int = 32,
                        rng: Optional[np.random.Generator] = None) -> RunReport:
        """Send exactly n packets keeping ≤window in flight; fully drain."""
        sent = 0
        start = time.perf_counter_ns()
        while self.flight.received < n_packets:
            now = time.perf_counter_ns()
            while sent < n_packets and (sent - self.flight.received) < window:
                self._send_one(self.ports[sent % len(self.ports)], packet_size, now, rng)
                sent += 1
            for port in self.ports:
                port.flush_rx()  # closed loop: no idle traffic to trigger writeback
            server.poll_once()
            now = time.perf_counter_ns()
            for port in self.ports:
                self._drain_port(port, now)
            if time.perf_counter_ns() - start > 60e9:
                break  # safety: never hang a test
        return self._report(offered_gbps=0.0)

    # -- open-loop timed run (bandwidth/latency measurement) ------------------
    def run(self, server: Server, pattern: TrafficPattern,
            duration_s: float = 0.25, drain_timeout_s: float = 0.5) -> RunReport:
        """Offered-load run: pace packets at pattern.rate, measure RTT + drops."""
        rng = np.random.default_rng(pattern.seed)
        use_rng_payload = self.verify_integrity
        start = time.perf_counter_ns()
        end = start + int(duration_s * 1e9)
        pps = pattern.packets_per_second()
        trace = list(pattern.trace) if pattern.trace is not None else None
        trace_i = 0
        # Poisson pacing: pre-draw inter-arrival jitter factors
        credit_sent = 0
        while True:
            now = time.perf_counter_ns()
            if now >= end:
                break
            # how many packets should have been emitted by now?
            if trace is not None:
                while trace_i < len(trace) and trace[trace_i][0] <= now - start:
                    _, size = trace[trace_i]
                    self._send_one(self.ports[trace_i % len(self.ports)],
                                   max(MIN_FRAME, size), now,
                                   rng if use_rng_payload else None)
                    trace_i += 1
            else:
                target = int((now - start) * 1e-9 * pps)
                if pattern.kind == "poisson":
                    # jitter the credit target ±Poisson noise around the mean
                    target = int(rng.poisson(max(target, 0)))
                elif pattern.kind == "bursty":
                    target = (target // pattern.burst_len) * pattern.burst_len
                burst = min(target - credit_sent, self.max_tx_burst)
                if burst > 0 and not use_rng_payload:
                    # vectorized emit, split evenly across ports (multi-NIC)
                    nports = len(self.ports)
                    share = burst // nports
                    extra = burst % nports
                    for pi, port in enumerate(self.ports):
                        k = share + (1 if pi < extra else 0)
                        if k > 0:
                            self._send_burst(port, k, pattern.packet_size, now)
                    credit_sent += burst
                else:
                    for _ in range(max(0, burst)):
                        port = self.ports[credit_sent % len(self.ports)]
                        self._send_one(port, pattern.packet_size, now,
                                       rng if use_rng_payload else None)
                        credit_sent += 1
            server.poll_once()
            now = time.perf_counter_ns()
            for port in self.ports:
                self._drain_port(port, now)
        # drain in-flight tail so drop accounting is exact
        drain_end = time.perf_counter_ns() + int(drain_timeout_s * 1e9)
        while (self.flight.received < self.flight.sent
               and time.perf_counter_ns() < drain_end):
            for port in self.ports:
                port.flush_rx()
            if server.poll_once() == 0 and all(p.tx_pending == 0 for p in self.ports):
                # nothing moving and nothing queued: remaining packets were dropped
                break
            now = time.perf_counter_ns()
            for port in self.ports:
                self._drain_port(port, now)
        return self._report(offered_gbps=pattern.rate_gbps)

    def _report(self, offered_gbps: float) -> RunReport:
        rep = RunReport(
            offered_gbps=offered_gbps,
            achieved_gbps=self.meter.gbps,
            achieved_mpps=self.meter.mpps,
            sent=self.flight.sent,
            received=self.flight.received,
            dropped=self.flight.sent - self.flight.received,
            latency=self.latency.stats(),
            histogram=self.latency.histogram(),
        )
        rep.extras["integrity_errors"] = float(self.flight.integrity_errors)
        # per-queue NIC-side accounting (the RSS-skew observable); only
        # reported for multi-queue ports to keep single-queue reports terse
        for pi, port in enumerate(self.ports):
            if port.n_queues <= 1:
                continue
            delivered = port.rx_queue_delivered()
            dropped = port.rx_queue_dropped()
            for qi in range(port.n_queues):
                rep.extras[f"p{pi}q{qi}_rx_delivered"] = float(delivered[qi])
                rep.extras[f"p{pi}q{qi}_rx_dropped"] = float(dropped[qi])
            skew = rss_skew(delivered)
            rep.extras[f"p{pi}_rss_imbalance"] = skew["max_over_mean"]
            rep.extras[f"p{pi}_rss_cov"] = skew["cov"]
        return rep


# -- bandwidth test mode ------------------------------------------------------

def find_max_sustainable_bandwidth(
    make_setup: Callable[[], Tuple[Server, List[Port]]],
    packet_size: int = 1518,
    start_gbps: float = 0.25,
    max_gbps: float = 400.0,
    trial_s: float = 0.2,
    drop_tolerance_pct: float = 0.0,
    refine_iters: int = 5,
    pattern_kind: str = "uniform",
) -> Tuple[float, List[RunReport]]:
    """EtherLoadGen bandwidth-test mode: "gradually increases the bandwidth to
    find the maximum sustainable bandwidth ... without packet drops."

    Multiplicative increase until the system drops packets, then bisection
    between the last sustainable and first unsustainable rates.  Every trial
    uses a fresh server/rings via ``make_setup`` so state never leaks.
    Returns (msb_gbps, all trial reports).
    """

    reports: List[RunReport] = []

    def trial(rate: float) -> RunReport:
        server, ports = make_setup()
        lg = LoadGen(ports)
        rep = lg.run(server, TrafficPattern(rate_gbps=rate, packet_size=packet_size,
                                            kind=pattern_kind), duration_s=trial_s)
        reports.append(rep)
        return rep

    # Phase 1: multiplicative ramp
    good, bad = 0.0, None
    rate = start_gbps
    while rate <= max_gbps:
        rep = trial(rate)
        if rep.drop_pct <= drop_tolerance_pct and rep.sent > 0:
            good = max(good, rep.achieved_gbps)
            rate *= 2.0
        else:
            bad = rate
            break
    if bad is None:
        return good, reports
    # Phase 2: bisection
    lo, hi = bad / 2.0, bad
    for _ in range(refine_iters):
        mid = 0.5 * (lo + hi)
        rep = trial(mid)
        if rep.drop_pct <= drop_tolerance_pct and rep.sent > 0:
            good = max(good, rep.achieved_gbps)
            lo = mid
        else:
            hi = mid
    return good, reports
