"""Receive-side scaling: Toeplitz flow hashing + queue indirection table.

Modern NICs steer each received frame to one of ``n_queues`` hardware RX
queues so that every core services its own queue without sharing — the
mechanism behind the paper's Fig. 3(a) core-scaling axis.  Steering is a
two-step function, modeled exactly as the Microsoft RSS spec (and every
real NIC) defines it:

1. a **Toeplitz hash** over the flow fields of the frame header (src/dst
   address + src/dst port, big-endian, in that order), keyed by a 40-byte
   secret so adversarial traffic cannot target one queue;
2. a **128-entry indirection table** indexed by the low bits of the hash,
   whose entries name RX queues.  The table is software-writable, which is
   how drivers rebalance flows without rehashing.

Packets of one flow always land on one queue (no intra-flow reordering);
distinct flows spread across queues in proportion to table occupancy.

The hash here is the real algorithm, vectorized: one ``unpackbits`` +
masked-XOR reduction per burst, no per-packet Python loop.  It matches the
published Microsoft test vectors (see ``tests/test_rss.py``).
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

# The de-facto-standard 40-byte RSS key (Microsoft's verification-suite key,
# shipped as the default by ixgbe/i40e/mlx5).  320 bits == enough for a
# 12-byte (96-bit) IPv4 4-tuple input window.
DEFAULT_RSS_KEY = bytes(
    [
        0x6D, 0x5A, 0x56, 0xDA, 0x25, 0x5B, 0x0E, 0xC2,
        0x41, 0x67, 0x25, 0x3D, 0x43, 0xA3, 0x8F, 0xB0,
        0xD0, 0xCA, 0x2B, 0xCB, 0xAE, 0x7B, 0x30, 0xB4,
        0x77, 0xCB, 0x2D, 0xA3, 0x80, 0x30, 0xF2, 0x0C,
        0x6A, 0x42, 0xB7, 0x3B, 0xBE, 0xAC, 0x01, 0xFA,
    ]
)

FLOW_TUPLE_BYTES = 12  # src_ip(4) + dst_ip(4) + src_port(2) + dst_port(2)
DEFAULT_TABLE_SIZE = 128


def _key_windows(key: bytes, n_input_bits: int) -> np.ndarray:
    """Precompute the 32-bit key window for every input bit position.

    Toeplitz: hash = XOR over set input bits i of key[i .. i+31].  With the
    windows precomputed the per-burst cost is one unpackbits + one masked
    XOR-reduction.
    """
    total_bits = len(key) * 8
    if n_input_bits + 32 > total_bits:
        raise ValueError("RSS key too short for input width")
    k = int.from_bytes(key, "big")
    out = np.empty(n_input_bits, dtype=np.uint32)
    for i in range(n_input_bits):
        out[i] = (k >> (total_bits - 32 - i)) & 0xFFFFFFFF
    return out


def _key_byte_tables(windows: np.ndarray) -> List[List[int]]:
    """Per-(byte position, byte value) XOR contributions to the Toeplitz hash.

    tables[p][v] == XOR of the key windows for the set bits of value ``v`` at
    byte position ``p``.  With these, hashing one 12-byte tuple is 12 plain
    list lookups — no numpy temporaries, which is what the single-packet
    delivery hot path needs (burst paths keep the vectorized route).
    """
    tables: List[List[int]] = []
    for p in range(len(windows) // 8):
        w = windows[p * 8 : (p + 1) * 8]
        row = [0] * 256
        for v in range(256):
            h = 0
            for bit in range(8):
                if v & (0x80 >> bit):
                    h ^= int(w[bit])
            row[v] = h
        tables.append(row)
    return tables


_WINDOWS = _key_windows(DEFAULT_RSS_KEY, FLOW_TUPLE_BYTES * 8)
_BYTE_TABLES = _key_byte_tables(_WINDOWS)


def _hash_with_windows(flow_bytes: np.ndarray, windows: np.ndarray) -> np.ndarray:
    fb = np.ascontiguousarray(flow_bytes, dtype=np.uint8)
    if fb.ndim == 1:
        fb = fb.reshape(1, -1)
    if fb.shape[1] != FLOW_TUPLE_BYTES:
        raise ValueError(f"flow tuple must be {FLOW_TUPLE_BYTES} bytes")
    bits = np.unpackbits(fb, axis=1).astype(bool)  # (N, 96), MSB-first
    masked = np.where(bits, windows[None, :], np.uint32(0))
    return np.bitwise_xor.reduce(masked, axis=1)


def toeplitz_hash_vec(flow_bytes: np.ndarray, key: Optional[bytes] = None) -> np.ndarray:
    """Toeplitz hash of a burst of flow tuples.

    ``flow_bytes`` is an (N, 12) uint8 array of big-endian 4-tuples
    (src_ip, dst_ip, src_port, dst_port).  Returns (N,) uint32 hashes.
    """
    windows = _WINDOWS if key is None else _key_windows(key, FLOW_TUPLE_BYTES * 8)
    return _hash_with_windows(flow_bytes, windows)


def toeplitz_hash(flow_bytes: np.ndarray, key: Optional[bytes] = None) -> int:
    """Scalar convenience wrapper: hash one 12-byte flow tuple."""
    return int(toeplitz_hash_vec(flow_bytes, key)[0])


class RssIndirection:
    """Hash → queue steering via a software-writable indirection table.

    The default table round-robins queues across its entries, which is what
    drivers program at init; ``rebalance`` rewrites entries to shift load
    (the knob flow-director scenarios build on).
    """

    def __init__(
        self,
        n_queues: int,
        table_size: int = DEFAULT_TABLE_SIZE,
        key: Optional[bytes] = None,
    ):
        if n_queues < 1:
            raise ValueError("n_queues must be >= 1")
        if table_size < n_queues:
            raise ValueError("table_size must be >= n_queues")
        self.n_queues = int(n_queues)
        self.key = DEFAULT_RSS_KEY if key is None else key
        # key windows precomputed once — steering is on the per-burst hot path
        self._windows = (_WINDOWS if key is None
                         else _key_windows(key, FLOW_TUPLE_BYTES * 8))
        # per-byte lookup tables for the scalar (single-packet) path
        self._byte_tables = (_BYTE_TABLES if key is None
                             else _key_byte_tables(self._windows))
        self.table = (np.arange(table_size) % n_queues).astype(np.int32)
        self._table_list: List[int] = self.table.tolist()

    def steer(self, flow_bytes: np.ndarray) -> np.ndarray:
        """Map a burst of (N, 12) flow tuples to (N,) queue indices."""
        hashes = _hash_with_windows(flow_bytes, self._windows)
        return self.table[hashes % np.uint32(len(self.table))]

    def hash_one(self, flow_bytes: np.ndarray) -> int:
        """Scalar Toeplitz hash of one 12-byte flow tuple.

        Allocation-free: 12 table lookups, for the per-frame delivery path
        (:meth:`repro.core.pmd.Port.deliver`).  Matches
        :func:`toeplitz_hash_vec` bit for bit.
        """
        if len(flow_bytes) != FLOW_TUPLE_BYTES:
            raise ValueError(f"flow tuple must be {FLOW_TUPLE_BYTES} bytes")
        tables = self._byte_tables
        h = 0
        for p in range(FLOW_TUPLE_BYTES):
            h ^= tables[p][flow_bytes[p]]
        return h

    def steer_one(self, flow_bytes: np.ndarray) -> int:
        """Scalar steering: one 12-byte flow tuple → queue index, without the
        per-packet numpy temporaries of the burst path."""
        fb = flow_bytes.reshape(-1) if flow_bytes.ndim > 1 else flow_bytes
        return self._table_list[self.hash_one(fb) % len(self._table_list)]

    def rebalance(self, entries: Sequence[int]) -> None:
        """Reprogram the indirection table (driver-style rebalancing)."""
        table = np.asarray(entries, dtype=np.int32)
        if table.ndim != 1 or len(table) < self.n_queues:
            raise ValueError("table must be 1-D with >= n_queues entries")
        if (table < 0).any() or (table >= self.n_queues).any():
            raise ValueError("table entries must name valid queues")
        self.table = table.copy()
        self._table_list = self.table.tolist()
