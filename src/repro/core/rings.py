"""Single-producer/single-consumer rings (DPDK ``rte_ring`` analogue).

Power-of-two capacity, monotonically increasing head/tail cursors, masked
indexing.  Under CPython's GIL, the single-word cursor updates are atomic, so
one producer thread and one consumer thread can share a ring without locks —
the same discipline DPDK's SPSC ring uses with store-release/load-acquire.

Used for: (a) pipeline-mode stage hand-off (paper §2 "Pipeline mode ... cores
pass packets between each other via a ring buffer"), (b) descriptor transport
between the loadgen and the device under test.
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence


def _round_up_pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


class SpscRing:
    """Lock-free (1P/1C) object ring."""

    __slots__ = ("_slots", "_mask", "_cap", "_head", "_tail", "enq_drops")

    def __init__(self, capacity: int):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        cap = _round_up_pow2(capacity)
        self._slots: List[Any] = [None] * cap
        self._mask = cap - 1
        self._cap = cap
        self._head = 0  # producer cursor (next write)
        self._tail = 0  # consumer cursor (next read)
        self.enq_drops = 0  # producer-side drops on full ring

    # -- capacity ------------------------------------------------------------
    @property
    def capacity(self) -> int:
        return self._cap

    def __len__(self) -> int:
        return self._head - self._tail

    @property
    def free_space(self) -> int:
        return self._cap - (self._head - self._tail)

    def is_empty(self) -> bool:
        return self._head == self._tail

    def is_full(self) -> bool:
        return self._head - self._tail >= self._cap

    # -- producer side --------------------------------------------------------
    def try_push(self, item: Any) -> bool:
        head = self._head
        if head - self._tail >= self._cap:
            self.enq_drops += 1
            return False
        self._slots[head & self._mask] = item
        self._head = head + 1  # publish
        return True

    def push_burst(self, items: Sequence[Any]) -> int:
        """Enqueue up to len(items); returns number enqueued (rest dropped)."""
        head = self._head
        space = self._cap - (head - self._tail)
        take = min(len(items), space)
        mask = self._mask
        slots = self._slots
        for i in range(take):
            slots[(head + i) & mask] = items[i]
        self._head = head + take
        self.enq_drops += len(items) - take
        return take

    # -- consumer side ---------------------------------------------------------
    def try_pop(self) -> Optional[Any]:
        tail = self._tail
        if tail == self._head:
            return None
        item = self._slots[tail & self._mask]
        self._slots[tail & self._mask] = None
        self._tail = tail + 1
        return item

    def pop_burst(self, max_n: int) -> List[Any]:
        tail = self._tail
        avail = self._head - tail
        take = min(max_n, avail)
        mask = self._mask
        slots = self._slots
        out = []
        for i in range(take):
            idx = (tail + i) & mask
            out.append(slots[idx])
            slots[idx] = None
        self._tail = tail + take
        return out
