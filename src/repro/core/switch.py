"""Output-queued switch model — the multi-host fabric.

The paper's testbed faces the load generator at a single simulated host; the
scale-out direction (gem5 stdlib's dist-gem5 topologies, SimBricks-style
composition of independently-built node models) needs a fabric that connects
*several* hosts' NICs on one shared virtual clock.  This module is that
fabric: an output-queued Ethernet switch whose ports carry independently
modeled full-duplex links.

Model (per port):

* **ingress wire** — endpoint → switch: a frame handed to :meth:`Switch.send`
  at ``t`` pays serialization + propagation on its port's uplink
  (:class:`~repro.core.simclock.Wire` FIFO semantics) before it reaches the
  forwarding logic.
* **forwarding** — on arrival the switch reads the frame's destination
  address (the flow dst_ip the load generator writes and RSS hashes —
  :func:`~repro.core.packet.read_dst_ip`) and looks it up in a
  longest-prefix-match route table.  Unroutable frames are dropped and
  counted.
* **egress queue** — each egress port owns a bounded drop-tail buffer in
  front of its egress wire.  A frame enqueues if fewer than ``capacity``
  frames are queued-or-serializing, serializes FIFO at the wire's rate, and
  lands at the endpoint ``latency_ns`` later; otherwise it is **dropped at
  the switch** — the loss mechanism of every incast workload, distinct from
  NIC-side ring overflow (``imissed``) and pool exhaustion (``rx_nombuf``).

Frames on the fabric are raw byte arrays (copies), never pool slots: each
node owns a private :class:`~repro.core.packet.PacketPool`, exactly like
SimBricks peers own private memory, so crossing the fabric serializes out of
one arena and DMAs into another.

All timing runs through one :class:`~repro.core.simclock.EventScheduler` on
the shared :class:`~repro.core.simclock.SimClock` — two events per egress
frame (serialization end frees the buffer slot; arrival delivers to the
endpoint sink), one per ingress frame.  Deterministic: FIFO tie-breaks in the
scheduler plus insertion-ordered route/port structures make two runs of the
same topology bit-identical.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .packet import read_dst_ip
from .simclock import EventScheduler, Wire

# an endpoint's delivery sink: (frame bytes, arrival time in virtual ns).
# The scheduler has already advanced the clock to the arrival time.
Sink = Callable[[np.ndarray, int], None]


class SwitchPort:
    """One full-duplex switch port: uplink + egress wire + bounded buffer."""

    __slots__ = ("port_id", "ingress", "egress", "capacity", "sink",
                 "occupancy", "occ_high", "rx_frames", "tx_frames",
                 "tx_bytes", "egress_enqueued", "egress_drops")

    def __init__(self, port_id: int, gbps: float, latency_ns: int,
                 capacity: int):
        if capacity < 1:
            raise ValueError("egress capacity must be >= 1 frame")
        self.port_id = port_id
        self.ingress = Wire(gbps=gbps, latency_ns=latency_ns)
        self.egress = Wire(gbps=gbps, latency_ns=latency_ns)
        self.capacity = capacity
        self.sink: Optional[Sink] = None
        # occupancy counts frames enqueued-or-serializing on the egress side
        self.occupancy = 0
        self.occ_high = 0
        self.rx_frames = 0          # frames that entered the switch here
        self.tx_frames = 0          # frames delivered out of this port
        self.tx_bytes = 0
        self.egress_enqueued = 0
        self.egress_drops = 0       # drop-tail: egress buffer full


class Switch:
    """N-port output-queued switch over one shared :class:`EventScheduler`.

    Endpoints (node NICs, fabric-attached load generators) are wired with
    :meth:`attach`; addresses with :meth:`add_route` (longest-prefix match,
    so a node gets a /32 and a generator's client space a /16).  Frames enter
    with :meth:`send`; every hop after that is an event on the scheduler.
    """

    def __init__(self, n_ports: int, sched: EventScheduler,
                 gbps: float = 100.0, latency_ns: int = 1_000,
                 egress_capacity: int = 64):
        if n_ports < 1:
            raise ValueError("a switch needs at least one port")
        self.sched = sched
        self.ports: List[SwitchPort] = [
            SwitchPort(i, gbps, latency_ns, egress_capacity)
            for i in range(n_ports)
        ]
        # (prefix_len, ip, mask) -> port, kept sorted longest-prefix-first
        self._routes: List[Tuple[int, int, int, int]] = []
        self._route_cache: Dict[int, Optional[int]] = {}
        self.unrouted = 0           # frames with no matching route

    @property
    def n_ports(self) -> int:
        return len(self.ports)

    # -- control plane --------------------------------------------------------
    def attach(self, port_id: int, sink: Sink) -> None:
        """Wire an endpoint's delivery sink to a port."""
        self.ports[port_id].sink = sink

    def add_route(self, dst_ip: int, port_id: int, prefix_len: int = 32) -> None:
        """Route ``dst_ip/prefix_len`` out of ``port_id`` (LPM on lookup)."""
        if not 0 <= port_id < len(self.ports):
            raise ValueError(f"port {port_id} out of range [0, {len(self.ports)})")
        if not 0 <= prefix_len <= 32:
            raise ValueError("prefix_len must be in [0, 32]")
        mask = 0 if prefix_len == 0 else (0xFFFFFFFF << (32 - prefix_len)) & 0xFFFFFFFF
        self._routes.append((prefix_len, int(dst_ip) & mask, mask, port_id))
        # longest prefix first; insertion order breaks ties deterministically
        self._routes.sort(key=lambda r: -r[0])
        self._route_cache.clear()

    def lookup(self, dst_ip: int) -> Optional[int]:
        """Longest-prefix-match route lookup (None == unroutable)."""
        dst_ip = int(dst_ip)
        if dst_ip in self._route_cache:
            return self._route_cache[dst_ip]
        out: Optional[int] = None
        for _plen, ip, mask, port_id in self._routes:
            if (dst_ip & mask) == ip:
                out = port_id
                break
        self._route_cache[dst_ip] = out
        return out

    # -- data plane -----------------------------------------------------------
    def send(self, port_id: int, frame: np.ndarray,
             t_ns: Optional[int] = None) -> None:
        """An endpoint hands one frame to its port at ``t_ns`` (default: the
        clock's now).  The frame pays the uplink's serialization +
        propagation, then forwards on arrival at the switch."""
        port = self.ports[port_id]
        t = self.sched.clock.now_ns if t_ns is None else int(t_ns)
        arrival = port.ingress.transmit(t, len(frame))
        self.sched.schedule_at(arrival, lambda: self._forward(port_id, frame))

    def _forward(self, in_port_id: int, frame: np.ndarray) -> None:
        """Ingress arrival: route on the frame's dst address, enqueue egress."""
        self.ports[in_port_id].rx_frames += 1
        out_id = self.lookup(read_dst_ip(frame))
        if out_id is None:
            self.unrouted += 1
            return
        out = self.ports[out_id]
        if out.occupancy >= out.capacity:
            out.egress_drops += 1   # drop-tail: the incast loss mechanism
            return
        out.occupancy += 1
        out.occ_high = max(out.occ_high, out.occupancy)
        out.egress_enqueued += 1
        nbytes = len(frame)
        now = self.sched.clock.now_ns
        arrival = out.egress.transmit(now, nbytes)
        ser_end = arrival - out.egress.latency_ns
        # the buffer slot frees when serialization completes (the frame has
        # left the switch), not when the frame lands after propagation
        self.sched.schedule_at(ser_end, lambda: self._egress_done(out))
        self.sched.schedule_at(arrival, lambda: self._deliver(out, frame, arrival))

    def _egress_done(self, port: SwitchPort) -> None:
        port.occupancy -= 1

    def _deliver(self, port: SwitchPort, frame: np.ndarray,
                 arrival_ns: int) -> None:
        port.tx_frames += 1
        port.tx_bytes += len(frame)
        if port.sink is not None:
            port.sink(frame, arrival_ns)

    # -- telemetry ------------------------------------------------------------
    @property
    def egress_drops(self) -> int:
        """Total frames lost to full egress buffers, all ports."""
        return sum(p.egress_drops for p in self.ports)

    def extras(self, prefix: str = "sw") -> Dict[str, float]:
        """Per-port drop/occupancy counters, RunReport.extras-shaped."""
        out: Dict[str, float] = {f"{prefix}_unrouted": float(self.unrouted)}
        for p in self.ports:
            out[f"{prefix}_p{p.port_id}_egress_drops"] = float(p.egress_drops)
            out[f"{prefix}_p{p.port_id}_egress_forwarded"] = float(p.tx_frames)
            out[f"{prefix}_p{p.port_id}_occ_high"] = float(p.occ_high)
        return out
