"""Output-queued switch model — the multi-host fabric.

The paper's testbed faces the load generator at a single simulated host; the
scale-out direction (gem5 stdlib's dist-gem5 topologies, SimBricks-style
composition of independently-built node models) needs a fabric that connects
*several* hosts' NICs on one shared virtual clock.  This module is that
fabric: an output-queued Ethernet switch whose ports carry independently
modeled full-duplex links.

Model (per port):

* **ingress wire** — endpoint → switch: a frame handed to :meth:`Switch.send`
  at ``t`` pays serialization + propagation on its port's uplink
  (:class:`~repro.core.simclock.Wire` FIFO semantics) before it reaches the
  forwarding logic.
* **forwarding pipeline** — on arrival the frame runs a P4sim-style pipeline
  of composable per-port stages: **classify** (parse the header, extract the
  match key — the flow dst_ip the load generator writes and RSS hashes,
  :func:`~repro.core.packet.read_dst_ip`), **route** (longest-prefix-match
  table lookup; unroutable frames are dropped and counted), **AQM** (the
  egress port's queue-management policy decides pass/early-drop/CE-mark —
  see :class:`AqmRed`), and **enqueue** (the bounded egress buffer below).
* **egress queue** — each egress port owns a bounded drop-tail buffer in
  front of its egress wire.  A frame enqueues if fewer than ``capacity``
  frames are queued-or-serializing, serializes FIFO at the wire's rate, and
  lands at the endpoint ``latency_ns`` later; otherwise it is **dropped at
  the switch** — the loss mechanism of every incast workload, distinct from
  NIC-side ring overflow (``imissed``) and pool exhaustion (``rx_nombuf``).

The AQM stage is pluggable per port (:meth:`Switch.set_aqm`): the default is
the drop-tail behavior above (no policy object, no extra arithmetic — runs
bit-identically to the pre-pipeline switch), ``red`` drops probabilistically
before the buffer fills, and ``ecn`` applies the same RED curve as a CE mark
(:func:`~repro.core.packet.set_ce`) instead of a drop.  RED randomness comes
from a counter-seeded splitmix64 stream per (seed, port, decision) — fully
deterministic, no wall-clock or global RNG state (simlint SL002).

Frames on the fabric are raw byte arrays (copies), never pool slots: each
node owns a private :class:`~repro.core.packet.PacketPool`, exactly like
SimBricks peers own private memory, so crossing the fabric serializes out of
one arena and DMAs into another.

All timing runs through one :class:`~repro.core.simclock.EventScheduler` on
the shared :class:`~repro.core.simclock.SimClock` — two events per egress
frame (serialization end frees the buffer slot; arrival delivers to the
endpoint sink), one per ingress frame.  Deterministic: FIFO tie-breaks in the
scheduler plus insertion-ordered route/port structures make two runs of the
same topology bit-identical.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .packet import read_dst_ip, set_ce
from .simclock import EventScheduler, Wire

# an endpoint's delivery sink: (frame bytes, arrival time in virtual ns).
# The scheduler has already advanced the clock to the arrival time.
Sink = Callable[[np.ndarray, int], None]

# AQM stage verdicts
AQM_PASS = 0
AQM_DROP = 1
AQM_MARK = 2

_M64 = (1 << 64) - 1


def _splitmix64(x: int) -> int:
    """One splitmix64 output step — the deterministic per-decision uniform."""
    x = (x + 0x9E3779B97F4A7C15) & _M64
    z = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _M64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _M64
    return z ^ (z >> 31)


def aqm_uniform_u64(seed: int, port_id: int, counter: int) -> int:
    """The k-th uniform u64 of port ``port_id``'s AQM decision stream.

    Counter-seeded (seed, port, decision index) -> u64: replayable from
    counters alone, so partitioned replicas of a switch draw the identical
    stream — no shared-RNG state to keep in sync.
    """
    x = _splitmix64((int(seed) & _M64) ^ 0xD1B54A32D192ED03)
    x = _splitmix64(x ^ ((int(port_id) & _M64) * 0x9E3779B97F4A7C15 & _M64))
    return _splitmix64(x ^ (int(counter) & _M64))


def red_probability(depth: int, min_thresh: int, max_thresh: int,
                    max_p: float) -> float:
    """Classic RED curve on instantaneous queue depth (frames).

    0 below ``min_thresh``; linear ramp to ``max_p`` across the threshold
    band; certain (1.0) at or above ``max_thresh``.  Monotone non-decreasing
    in ``depth`` for any valid thresholds (min <= max) — the property the
    hypothesis suite pins.
    """
    if depth >= max_thresh:
        return 1.0
    if depth < min_thresh:
        return 0.0
    return max_p * (depth - min_thresh) / float(max_thresh - min_thresh)


class AqmRed:
    """RED-family AQM policy for one egress port: early-drop or CE-mark.

    ``kind`` selects the action taken when the RED curve fires: ``"red"``
    drops the arriving frame before it occupies a buffer slot; ``"ecn"``
    sets the CE bit and lets the frame through (the DCTCP fabric half).
    Decisions observe the arriving-frame-inclusive depth (``occupancy + 1``,
    DCTCP's mark-on-enqueue convention) and sample the port's occupancy
    high-water at decision time — so a port whose policy refuses frames at
    depth k still records the demand that reached it (the enqueue-only
    sampling bug this stage fixes).
    """

    __slots__ = ("kind", "min_thresh", "max_thresh", "max_p", "seed",
                 "decisions", "ecn_marked", "early_drops")

    def __init__(self, kind: str, min_thresh: int, max_thresh: int,
                 max_p: float, seed: int):
        if kind not in ("red", "ecn"):
            raise ValueError(f"unknown AQM kind {kind!r}")
        if not 1 <= min_thresh <= max_thresh:
            raise ValueError("need 1 <= min_thresh <= max_thresh")
        if not 0.0 < max_p <= 1.0:
            raise ValueError("max_p must be in (0, 1]")
        self.kind = kind
        self.min_thresh = int(min_thresh)
        self.max_thresh = int(max_thresh)
        self.max_p = float(max_p)
        self.seed = int(seed)
        self.decisions = 0          # the per-port RNG counter
        self.ecn_marked = 0
        self.early_drops = 0

    def decide(self, port: "SwitchPort") -> int:
        depth = port.occupancy + 1
        # satellite fix: record demand when the policy looks, not only on
        # enqueue — a RED drop at depth k must leave occ_high >= k
        if depth > port.occ_high:
            port.occ_high = depth
        k = self.decisions
        self.decisions += 1
        p = red_probability(depth, self.min_thresh, self.max_thresh,
                            self.max_p)
        if p <= 0.0:
            return AQM_PASS
        if aqm_uniform_u64(self.seed, port.port_id, k) >= int(p * 2.0 ** 64):
            return AQM_PASS
        if self.kind == "ecn":
            self.ecn_marked += 1
            return AQM_MARK
        self.early_drops += 1
        return AQM_DROP


class SwitchPort:
    """One full-duplex switch port: uplink + egress wire + bounded buffer."""

    __slots__ = ("port_id", "ingress", "egress", "capacity", "sink",
                 "occupancy", "occ_high", "rx_frames", "tx_frames",
                 "tx_bytes", "egress_enqueued", "egress_drops", "aqm")

    def __init__(self, port_id: int, gbps: float, latency_ns: int,
                 capacity: int):
        if capacity < 1:
            raise ValueError("egress capacity must be >= 1 frame")
        self.port_id = port_id
        self.ingress = Wire(gbps=gbps, latency_ns=latency_ns)
        self.egress = Wire(gbps=gbps, latency_ns=latency_ns)
        self.capacity = capacity
        self.sink: Optional[Sink] = None
        # occupancy counts frames enqueued-or-serializing on the egress side
        self.occupancy = 0
        self.occ_high = 0
        self.rx_frames = 0          # frames that entered the switch here
        self.tx_frames = 0          # frames delivered out of this port
        self.tx_bytes = 0
        self.egress_enqueued = 0
        self.egress_drops = 0       # drop-tail: egress buffer full
        self.aqm: Optional[AqmRed] = None   # None == plain drop-tail stage


class Switch:
    """N-port output-queued switch over one shared :class:`EventScheduler`.

    Endpoints (node NICs, fabric-attached load generators) are wired with
    :meth:`attach`; addresses with :meth:`add_route` (longest-prefix match,
    so a node gets a /32 and a generator's client space a /16).  Frames enter
    with :meth:`send`; every hop after that is an event on the scheduler.
    """

    def __init__(self, n_ports: int, sched: EventScheduler,
                 gbps: float = 100.0, latency_ns: int = 1_000,
                 egress_capacity: int = 64):
        if n_ports < 1:
            raise ValueError("a switch needs at least one port")
        self.sched = sched
        self.ports: List[SwitchPort] = [
            SwitchPort(i, gbps, latency_ns, egress_capacity)
            for i in range(n_ports)
        ]
        # (prefix_len, ip, mask) -> port, kept sorted longest-prefix-first
        self._routes: List[Tuple[int, int, int, int]] = []
        self._route_cache: Dict[int, Optional[int]] = {}
        self.unrouted = 0           # frames with no matching route

    @property
    def n_ports(self) -> int:
        return len(self.ports)

    # -- control plane --------------------------------------------------------
    def attach(self, port_id: int, sink: Sink) -> None:
        """Wire an endpoint's delivery sink to a port."""
        self.ports[port_id].sink = sink

    def set_aqm(self, port_id: int, aqm: Optional[AqmRed]) -> None:
        """Install (or clear) the AQM stage policy on one egress port."""
        self.ports[port_id].aqm = aqm

    def add_route(self, dst_ip: int, port_id: int, prefix_len: int = 32) -> None:
        """Route ``dst_ip/prefix_len`` out of ``port_id`` (LPM on lookup)."""
        if not 0 <= port_id < len(self.ports):
            raise ValueError(f"port {port_id} out of range [0, {len(self.ports)})")
        if not 0 <= prefix_len <= 32:
            raise ValueError("prefix_len must be in [0, 32]")
        mask = 0 if prefix_len == 0 else (0xFFFFFFFF << (32 - prefix_len)) & 0xFFFFFFFF
        self._routes.append((prefix_len, int(dst_ip) & mask, mask, port_id))
        # longest prefix first; insertion order breaks ties deterministically
        self._routes.sort(key=lambda r: -r[0])
        self._route_cache.clear()

    def lookup(self, dst_ip: int) -> Optional[int]:
        """Longest-prefix-match route lookup (None == unroutable)."""
        dst_ip = int(dst_ip)
        if dst_ip in self._route_cache:
            return self._route_cache[dst_ip]
        out: Optional[int] = None
        for _plen, ip, mask, port_id in self._routes:
            if (dst_ip & mask) == ip:
                out = port_id
                break
        self._route_cache[dst_ip] = out
        return out

    # -- data plane -----------------------------------------------------------
    def send(self, port_id: int, frame: np.ndarray,
             t_ns: Optional[int] = None) -> None:
        """An endpoint hands one frame to its port at ``t_ns`` (default: the
        clock's now).  The frame pays the uplink's serialization +
        propagation, then forwards on arrival at the switch."""
        port = self.ports[port_id]
        t = self.sched.clock.now_ns if t_ns is None else int(t_ns)
        arrival = port.ingress.transmit(t, len(frame))
        self.sched.schedule_at(arrival, lambda: self._forward(port_id, frame))

    def _forward(self, in_port_id: int, frame: np.ndarray) -> None:
        """Ingress arrival: run the per-port pipeline — classify -> route ->
        AQM -> enqueue.  Stages are methods so a subclass (the partitioned
        :class:`~repro.core.partition.DomainSwitch`) can replace exactly one
        (egress emission) without forking the forward path."""
        key = self._classify(in_port_id, frame)
        out_id = self._route(key)
        if out_id is None:
            self.unrouted += 1
            return
        out = self.ports[out_id]
        verdict = self._aqm_decide(out)
        if verdict == AQM_DROP:
            return
        if verdict == AQM_MARK:
            set_ce(frame)
        self._enqueue(out, frame)

    # -- pipeline stages ------------------------------------------------------
    def _classify(self, in_port_id: int, frame: np.ndarray) -> int:
        """Parse stage: count the ingress arrival, extract the match key."""
        self.ports[in_port_id].rx_frames += 1
        return read_dst_ip(frame)

    def _route(self, dst_ip: int) -> Optional[int]:
        """Match stage: LPM table lookup (None == unroutable)."""
        return self.lookup(dst_ip)

    def _aqm_decide(self, out: SwitchPort) -> int:
        """AQM stage: the egress port's policy votes on the arriving frame.
        No policy installed == drop-tail: pass with zero extra arithmetic,
        so default configs run bit-identically to the pre-pipeline switch."""
        if out.aqm is None:
            return AQM_PASS
        return out.aqm.decide(out)

    def _enqueue(self, out: SwitchPort, frame: np.ndarray) -> None:
        """Enqueue stage: bounded drop-tail buffer in front of the egress
        wire, then emission (two scheduler events per frame)."""
        if out.occupancy >= out.capacity:
            out.egress_drops += 1   # drop-tail: the incast loss mechanism
            return
        out.occupancy += 1
        out.occ_high = max(out.occ_high, out.occupancy)
        out.egress_enqueued += 1
        nbytes = len(frame)
        now = self.sched.clock.now_ns
        arrival = out.egress.transmit(now, nbytes)
        ser_end = arrival - out.egress.latency_ns
        # the buffer slot frees when serialization completes (the frame has
        # left the switch), not when the frame lands after propagation
        self.sched.schedule_at(ser_end, lambda: self._egress_done(out))
        self._emit(out, frame, arrival)

    def _emit(self, out: SwitchPort, frame: np.ndarray, arrival: int) -> None:
        """Emission: hand the serialized frame to the egress wire's far end.
        The one stage partitioned execution overrides (a crossing record
        instead of a local delivery event)."""
        self.sched.schedule_at(arrival,
                               lambda: self._deliver(out, frame, arrival))

    def _egress_done(self, port: SwitchPort) -> None:
        port.occupancy -= 1

    def _deliver(self, port: SwitchPort, frame: np.ndarray,
                 arrival_ns: int) -> None:
        port.tx_frames += 1
        port.tx_bytes += len(frame)
        if port.sink is not None:
            port.sink(frame, arrival_ns)

    # -- telemetry ------------------------------------------------------------
    @property
    def egress_drops(self) -> int:
        """Total frames lost to full egress buffers, all ports."""
        return sum(p.egress_drops for p in self.ports)

    def extras(self, prefix: str = "sw") -> Dict[str, float]:
        """Per-port drop/occupancy counters, RunReport.extras-shaped.

        AQM keys appear only for ports with a policy installed — default
        (drop-tail) extras stay byte-identical to the pre-pipeline switch.
        """
        out: Dict[str, float] = {f"{prefix}_unrouted": float(self.unrouted)}
        for p in self.ports:
            out[f"{prefix}_p{p.port_id}_egress_drops"] = float(p.egress_drops)
            out[f"{prefix}_p{p.port_id}_egress_forwarded"] = float(p.tx_frames)
            out[f"{prefix}_p{p.port_id}_occ_high"] = float(p.occ_high)
            if p.aqm is not None:
                out[f"{prefix}_p{p.port_id}_ecn_marked"] = float(
                    p.aqm.ecn_marked)
                out[f"{prefix}_p{p.port_id}_aqm_early_drops"] = float(
                    p.aqm.early_drops)
        return out
