"""NIC descriptor rings with an on-NIC descriptor cache and a configurable
writeback threshold — the paper's §3.1.4 contribution.

A real NIC holds a handful of completed RX descriptors in an on-chip
*descriptor cache* and writes them back (DMA) to host memory in groups.  The
paper found that gem5's model, when driven by a polling-mode driver, only wrote
descriptors back once the *entire* ring was used — DMA-ing packets to memory in
pathological 32–64-packet batches, hammering the memory subsystem and causing
drops.  Their fix: expose the writeback threshold as a parameter.

We model exactly that:

* ``nic_deliver`` — the "NIC" places a received frame into a descriptor; the
  completion is buffered in the descriptor cache.
* the cache is *written back* (status published to the consumer-visible array)
  when ``writeback_threshold`` completions have accumulated (one writeback
  **per threshold crossing** — a 256-frame burst at threshold 32 is eight
  32-descriptor DMAs, not one 256-descriptor DMA), when the ring becomes
  full, on an explicit ``flush``, or — with a scheduler attached via
  :meth:`RxDescriptorRing.attach_scheduler` — when the **writeback timeout**
  fires (the ITR analogue: an idle timer armed by the first completion that
  enters an empty cache, cancelled when a threshold/full/flush writeback
  empties it).
* ``poll`` / ``poll_burst`` — the PMD side harvests *written-back*
  descriptors without blocking; completions still sitting in the descriptor
  cache are invisible (``done_count`` is the PMD-visible backlog).

``writeback_threshold=None`` reproduces the pathological pre-fix behaviour
(writeback only when all descriptors are used).  Small thresholds reproduce the
paper's fix and are what the DCA burst study (Fig. 4) sweeps.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

STATUS_FREE = 0  # descriptor available to the NIC
STATUS_DONE = 1  # written back; visible to the PMD/driver

_EMPTY_I64 = np.empty(0, dtype=np.int64)
_EMPTY_I32 = np.empty(0, dtype=np.int32)


class RxDescriptorRing:
    def __init__(self, size: int, writeback_threshold: Optional[int] = None,
                 queue_id: int = 0):
        if size <= 0:
            raise ValueError("size must be positive")
        if writeback_threshold is not None and not (1 <= writeback_threshold <= size):
            raise ValueError("writeback_threshold must be in [1, size]")
        self.size = int(size)
        self.queue_id = int(queue_id)  # which HW queue of the port this is
        # None == pathological "writeback only when all descriptors used"
        self.writeback_threshold = writeback_threshold
        self.slots = np.full(self.size, -1, dtype=np.int64)  # packet slot index
        self.lengths = np.zeros(self.size, dtype=np.int32)
        self.status = np.full(self.size, STATUS_FREE, dtype=np.uint8)
        self.head = 0  # NIC cursor (next descriptor the NIC fills)
        self.tail = 0  # driver cursor (next descriptor the PMD inspects)
        self.published = 0  # cursor: total completions written back (DONE)
        self._cached = 0  # completions sitting in the descriptor cache
        # writeback-timeout timer (ITR analogue); armed only when a
        # scheduler is attached (virtual-time mode)
        self._sched = None            # EventScheduler, via attach_scheduler
        self._timeout_ns = 0
        self._timer: Optional[int] = None  # pending timer token
        # modeled writeback DMA latency: with a scheduler attached and
        # _dma_ns > 0, a threshold crossing *starts* a DMA and the
        # descriptors only become PMD-visible _dma_ns later (0 == the legacy
        # instantaneous publish, bit-identical to pre-DMA reports)
        self._dma_ns = 0
        self._dma_pending = 0         # descriptors in DMA flight
        self._dma_tokens: List[object] = []  # cancellable completion events
        # stats
        self.delivered = 0
        self.delivered_bytes = 0
        self.dropped = 0
        self.writebacks = 0  # number of writeback *events* (DMA bursts)
        self.writeback_sizes: List[int] = []  # burst size of each writeback
        self.timeout_flushes = 0  # writebacks forced by the idle timer

    # -- invariant helpers ----------------------------------------------------
    @property
    def in_flight(self) -> int:
        """Descriptors owned by NIC-or-cache-or-consumer (not yet polled)."""
        return self.head - self.tail

    @property
    def free_descriptors(self) -> int:
        return self.size - self.in_flight

    @property
    def done_count(self) -> int:
        """Written-back, not-yet-harvested descriptors — what the PMD can
        see *right now* (completions still in the descriptor cache are
        invisible until a writeback publishes them)."""
        return self.published - self.tail

    def _effective_threshold(self) -> int:
        return self.size if self.writeback_threshold is None else self.writeback_threshold

    # -- writeback timeout (ITR analogue) --------------------------------------
    def attach_scheduler(self, sched, timeout_ns: int,
                         writeback_dma_ns: int = 0) -> "RxDescriptorRing":
        """Enable the descriptor-cache **writeback timeout** on this ring.

        With a scheduler attached, a completion entering an empty cache arms
        an idle timer ``timeout_ns`` in the future; if no threshold/full
        writeback empties the cache before it fires, the timer flushes the
        cached completions (one timeout writeback).  This is the interrupt-
        throttling (ITR) analogue the paper's §3.1.4 discussion calls for:
        it bounds the worst-case time a frame sits PMD-invisible.

        ``writeback_dma_ns`` models the DMA transfer itself: a writeback
        *starts* when the threshold crosses (or the timer fires) but its
        descriptors only become PMD-visible ``writeback_dma_ns`` later, as a
        scheduler event.  The default 0 keeps the legacy instantaneous
        publish, bit-identical to pre-DMA reports.
        """
        if timeout_ns < 0:
            raise ValueError("timeout_ns must be >= 0")
        if writeback_dma_ns < 0:
            raise ValueError("writeback_dma_ns must be >= 0")
        self._sched = sched
        self._timeout_ns = int(timeout_ns)
        self._dma_ns = int(writeback_dma_ns)
        self._update_timer()
        return self

    def _on_timeout(self) -> None:
        self._timer = None
        if self._cached > 0:
            self.timeout_flushes += 1
            self._writeback_n(self._cached)
        self._update_timer()

    def _update_timer(self) -> None:
        """Arm the idle timer when completions wait in an empty-timer cache;
        cancel it when a writeback has emptied the cache."""
        if self._sched is None or self._timeout_ns <= 0:
            return
        if self._cached > 0 and self._timer is None:
            self._timer = self._sched.schedule_in(self._timeout_ns,
                                                  self._on_timeout)
        elif self._cached == 0 and self._timer is not None:
            self._sched.cancel(self._timer)
            self._timer = None

    # -- NIC side ---------------------------------------------------------------
    def nic_deliver(self, packet_slot: int, length: int) -> bool:
        """NIC receives a frame. Returns False (drop) if no free descriptor."""
        if self.in_flight >= self.size:
            self.dropped += 1
            return False
        idx = self.head % self.size
        self.slots[idx] = packet_slot
        self.lengths[idx] = length
        self.head += 1
        self._cached += 1
        self.delivered += 1
        self.delivered_bytes += int(length)
        if self._cached >= self._effective_threshold() or self.in_flight >= self.size:
            self._writeback()
        self._update_timer()
        return True

    def nic_deliver_burst(self, packet_slots: np.ndarray, lengths: np.ndarray) -> int:
        """Vectorized delivery of a frame burst. Returns #accepted (rest drop).

        Writeback semantics match the per-packet path exactly: one DMA burst
        of ``writeback_threshold`` descriptors per threshold *crossing* (a
        256-frame burst at threshold 32 records eight 32-descriptor
        writebacks), plus a final flush of the remainder if the ring filled.
        ``writeback_sizes`` is the quantity the paper's Fig. 4 studies — the
        vectorized path must not coarsen it.
        """
        n = len(packet_slots)
        space = self.size - self.in_flight
        take = min(n, space)
        if take > 0:
            idx = (self.head + np.arange(take)) % self.size
            self.slots[idx] = packet_slots[:take]
            self.lengths[idx] = lengths[:take]
            self.head += take
            self._cached += take
            self.delivered += take
            self.delivered_bytes += int(lengths[:take].sum(dtype=np.int64))
        self.dropped += n - take
        thr = self._effective_threshold()
        while self._cached >= thr:
            self._writeback_n(thr)
        if self.in_flight >= self.size:
            self._writeback()
        self._update_timer()
        return take

    def _writeback_n(self, k: int) -> None:
        """Start a writeback of the ``k`` oldest cached completions — one DMA
        burst of descriptor writebacks (the quantity the paper's Fig. 4 shows
        stressing the cache hierarchy when too large).  With a modeled DMA
        latency the publish happens ``_dma_ns`` later; otherwise it is
        immediate."""
        if k <= 0:
            return
        # the k oldest cached descriptors start right after everything that
        # has already been published or put in DMA flight:
        # published + _dma_pending + _cached == head always holds
        start = self.head - self._cached
        idx = (start + np.arange(k)) % self.size
        self._cached -= k
        if self._sched is not None and self._dma_ns > 0:
            self._dma_pending += k
            self._dma_tokens.append(
                self._sched.schedule_in(self._dma_ns,
                                        lambda: self._dma_complete(idx, k)))
            return
        self._publish(idx, k)

    def _publish(self, idx: np.ndarray, k: int) -> None:
        """Make ``k`` descriptors PMD-visible and record the DMA burst."""
        self.status[idx] = STATUS_DONE
        self.writebacks += 1
        self.writeback_sizes.append(k)
        self.published += k

    def _dma_complete(self, idx: np.ndarray, k: int) -> None:
        """A writeback DMA lands: its descriptors become PMD-visible.
        Equal-delay FIFO scheduling means completions land in start order,
        so the DONE run from ``tail`` stays contiguous."""
        if self._dma_tokens:
            self._dma_tokens.pop(0)
        self._dma_pending -= k
        self._publish(idx, k)

    def _writeback(self) -> None:
        """Publish every cached completion in one DMA burst."""
        self._writeback_n(self._cached)

    def flush(self) -> None:
        """Explicit full writeback (a stopping NIC publishes its cache; the
        pre-timer event loops also call this on a quiet wire).  Idempotent:
        an empty cache records no writeback event.

        Synchronous by contract even with a modeled DMA latency — closed-loop
        drivers flush without pumping the scheduler, so in-flight DMAs are
        cancelled and their descriptors published immediately (one burst)."""
        if self._dma_pending > 0:
            for tok in self._dma_tokens:
                self._sched.cancel(tok)
            self._dma_tokens.clear()
            k = self._dma_pending
            start = self.head - self._cached - k
            idx = (start + np.arange(k)) % self.size
            self._dma_pending = 0
            self._publish(idx, k)
        self._writeback()
        self._update_timer()

    # -- PMD / driver side --------------------------------------------------------
    def poll(self, max_n: int) -> List[Tuple[int, int]]:
        """Harvest up to ``max_n`` completed descriptors. Non-blocking.

        Returns [(packet_slot, length), ...] and recycles the descriptors.
        """
        out: List[Tuple[int, int]] = []
        while len(out) < max_n and self.tail < self.head:
            idx = self.tail % self.size
            if self.status[idx] != STATUS_DONE:
                break  # still in the descriptor cache — not yet written back
            out.append((int(self.slots[idx]), int(self.lengths[idx])))
            self.status[idx] = STATUS_FREE
            self.slots[idx] = -1
            self.tail += 1
        return out

    def poll_burst(self, max_n: int) -> Tuple[np.ndarray, np.ndarray]:
        """Vectorized PMD harvest: one status sweep per burst.

        Returns (packet_slots, lengths) arrays of the contiguous DONE run
        starting at tail (completions publish in order, so the run is
        contiguous by construction).
        """
        avail = self.head - self.tail
        k = min(max_n, avail)
        if k <= 0:
            return _EMPTY_I64, _EMPTY_I32
        idx = (self.tail + np.arange(k)) % self.size
        done = self.status[idx] == STATUS_DONE
        n = int(done.argmin()) if not done.all() else k
        if n == 0:
            return _EMPTY_I64, _EMPTY_I32
        idx = idx[:n]
        slots = self.slots[idx].copy()
        lengths = self.lengths[idx].copy()
        self.status[idx] = STATUS_FREE
        self.slots[idx] = -1
        self.tail += n
        return slots, lengths


class TxDescriptorRing:
    """TX side: the driver posts frames, the 'NIC' drains them.

    Symmetric but simpler — completion is immediate on drain; we keep the same
    poll discipline so PMD TX reclaim is burst-based too.
    """

    def __init__(self, size: int, queue_id: int = 0):
        self.size = int(size)
        self.queue_id = int(queue_id)
        self.slots = np.full(self.size, -1, dtype=np.int64)
        self.lengths = np.zeros(self.size, dtype=np.int32)
        self.head = 0  # driver cursor (next post)
        self.tail = 0  # NIC cursor (next transmit)
        self.posted = 0
        self.posted_bytes = 0
        self.rejected = 0
        self.transmitted = 0
        self.transmitted_bytes = 0

    @property
    def pending(self) -> int:
        return self.head - self.tail

    def post(self, packet_slot: int, length: int) -> bool:
        if self.pending >= self.size:
            self.rejected += 1
            return False
        idx = self.head % self.size
        self.slots[idx] = packet_slot
        self.lengths[idx] = length
        self.head += 1
        self.posted += 1
        self.posted_bytes += int(length)
        return True

    def post_burst(self, items: List[Tuple[int, int]]) -> int:
        """Scalar TX post of a burst. Returns #posted — and, like
        :meth:`post_burst_vec`, counts **every** unposted item as rejected
        (a full ring rejects the whole tail, not just the first item)."""
        n = 0
        for slot, length in items:
            if not self.post(slot, length):
                # post() counted the failing item; the untried tail is
                # rejected too, so scalar and vectorized stats agree
                self.rejected += len(items) - n - 1
                break
            n += 1
        return n

    def post_burst_vec(self, packet_slots: np.ndarray, lengths: np.ndarray) -> int:
        """Vectorized TX post. Returns #posted (rest rejected)."""
        n = len(packet_slots)
        space = self.size - self.pending
        take = min(n, space)
        if take > 0:
            idx = (self.head + np.arange(take)) % self.size
            self.slots[idx] = packet_slots[:take]
            self.lengths[idx] = lengths[:take]
            self.head += take
            self.posted += take
            self.posted_bytes += int(lengths[:take].sum(dtype=np.int64))
        self.rejected += n - take
        return take

    def drain(self, max_n: int) -> List[Tuple[int, int]]:
        """NIC transmits up to max_n pending frames."""
        out: List[Tuple[int, int]] = []
        while len(out) < max_n and self.tail < self.head:
            idx = self.tail % self.size
            out.append((int(self.slots[idx]), int(self.lengths[idx])))
            self.slots[idx] = -1
            self.tail += 1
            self.transmitted += 1
            self.transmitted_bytes += int(self.lengths[idx])
        return out

    def drain_burst(self, max_n: int) -> Tuple[np.ndarray, np.ndarray]:
        """Vectorized drain: (packet_slots, lengths)."""
        take = min(max_n, self.pending)
        if take <= 0:
            return _EMPTY_I64, _EMPTY_I32
        idx = (self.tail + np.arange(take)) % self.size
        slots = self.slots[idx].copy()
        lengths = self.lengths[idx].copy()
        self.slots[idx] = -1
        self.tail += take
        self.transmitted += take
        self.transmitted_bytes += int(lengths.sum(dtype=np.int64))
        return slots, lengths
