"""Device ingest dataplane: kernel-style blocking feed vs. PMD-style bypass feed.

This module carries the paper's insight onto the accelerator boundary.  On a
TPU pod the host→device input path has exactly the kernel-stack pathologies the
paper bypasses on a NIC:

* blocking `device_put` inside the step loop  == syscall + interrupt semantics
* fresh host allocations per batch            == per-packet skb allocation
* implicit synchronization (`block_until_ready`) == interrupt-driven completion

:class:`KernelStackFeed` implements that baseline honestly.
:class:`BypassDataplane` is the DPDK analogue: a depth-K ring of pre-issued
asynchronous transfers ("pinned hugepage" buffer recycling via donation),
readiness *polling* (`jax.Array.is_ready`), multi-port host production, and
burst-size control — so device DMA overlaps both host production and device
compute (the DCA overlap, paper §5.2).

Both feeds speak the same protocol so the trainer/server runtime and the
benchmarks can swap them with one flag.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator, List, Optional, Sequence

import jax
import numpy as np

from .rings import SpscRing

HostBatch = Any  # pytree of np.ndarray
DeviceBatch = Any  # pytree of jax.Array


@dataclass
class FeedStats:
    batches: int = 0
    bytes: int = 0
    wait_ns: int = 0          # time the consumer stalled waiting for data
    put_ns: int = 0           # time spent issuing transfers
    host_alloc_ns: int = 0    # host-side production time on the critical path
    empty_polls: int = 0
    occupancy_sum: int = 0    # ring occupancy integral (for avg occupancy)

    @property
    def avg_occupancy(self) -> float:
        return self.occupancy_sum / self.batches if self.batches else 0.0

    def gbps(self, elapsed_s: float) -> float:
        return self.bytes * 8 / 1e9 / elapsed_s if elapsed_s > 0 else 0.0


def _tree_bytes(tree: Any) -> int:
    return sum(x.nbytes for x in jax.tree_util.tree_leaves(tree))


class KernelStackFeed:
    """Baseline feed: synchronous, copying, interrupt-style.

    Each ``next_batch``: produce host batch (fresh allocation), blocking
    transfer, full synchronization.  No overlap anywhere — the device idles
    while the host works and vice versa.
    """

    def __init__(self, batch_iter: Iterator[HostBatch], sharding: Optional[Any] = None):
        self._it = batch_iter
        self._sharding = sharding
        self.stats = FeedStats()

    def next_batch(self) -> Optional[DeviceBatch]:
        t0 = time.perf_counter_ns()  # simlint: disable=SL001 -- wall-clock feed mode
        try:
            host = next(self._it)
        except StopIteration:
            return None
        # defensive copy: the kernel stack never trusts caller buffers (skb copy)
        host = jax.tree_util.tree_map(np.array, host)
        t1 = time.perf_counter_ns()  # simlint: disable=SL001 -- wall-clock feed mode
        dev = (jax.device_put(host, self._sharding) if self._sharding is not None
               else jax.device_put(host))
        jax.block_until_ready(dev)  # interrupt-driven completion: hard sync
        t2 = time.perf_counter_ns()  # simlint: disable=SL001 -- wall-clock feed mode
        self.stats.host_alloc_ns += t1 - t0
        self.stats.put_ns += t2 - t1
        self.stats.batches += 1
        self.stats.bytes += _tree_bytes(host)
        return dev

    def stop(self) -> None:
        pass


class BypassDataplane:
    """PMD-style device feed: pre-issued async DMA ring + readiness polling.

    * ``depth`` in-flight transfers (descriptor-ring depth);
    * ``ports`` host producer threads filling an SPSC staging ring each
      (multi-NIC analogue — Fig. 3(a) scalability axis);
    * consumer *polls* (`is_ready`) instead of blocking; a not-ready head with
      ready successors is reordered like out-of-order descriptor completion;
    * consumed device buffers are donated by the step function, so steady-state
      runs in place ("hugepage" recycling — allocation happens once).
    """

    def __init__(
        self,
        batch_iter_factory: Callable[[int, int], Iterator[HostBatch]],
        *,
        depth: int = 3,
        ports: int = 1,
        sharding: Optional[Any] = None,
        staging_capacity: int = 8,
        poll_interval_s: float = 0.0,
    ):
        if depth < 1:
            raise ValueError("depth must be >= 1")
        if ports < 1:
            raise ValueError("ports must be >= 1")
        self._sharding = sharding
        self._depth = depth
        self._ports = ports
        self._poll_interval_s = poll_interval_s
        self.stats = FeedStats()
        self._stage: List[SpscRing] = [SpscRing(staging_capacity) for _ in range(ports)]
        self._stop_evt = threading.Event()
        self._producers: List[threading.Thread] = []
        self._exhausted = [False] * ports
        self._rr = 0  # round-robin port cursor
        self._inflight: List[DeviceBatch] = []
        for p in range(ports):
            it = batch_iter_factory(p, ports)
            t = threading.Thread(
                target=self._producer_loop, args=(p, it), daemon=True,
                name=f"dataplane-port{p}",
            )
            self._producers.append(t)
            t.start()

    # -- host producer threads (the "NIC ports") -----------------------------
    def _producer_loop(self, port: int, it: Iterator[HostBatch]) -> None:
        ring = self._stage[port]
        while not self._stop_evt.is_set():
            try:
                host = next(it)
            except StopIteration:
                self._exhausted[port] = True
                return
            while not ring.try_push(host):
                if self._stop_evt.is_set():
                    return
                time.sleep(0)  # staging full: yield (backpressure, no drop)

    # -- DMA issue -------------------------------------------------------------
    def _issue_one(self) -> bool:
        """Pop one staged host batch and start its async transfer."""
        for _ in range(self._ports):
            ring = self._stage[self._rr]
            self._rr = (self._rr + 1) % self._ports
            host = ring.try_pop()
            if host is not None:
                t0 = time.perf_counter_ns()  # simlint: disable=SL001 -- wall-clock feed mode
                dev = (jax.device_put(host, self._sharding)
                       if self._sharding is not None else jax.device_put(host))
                # NOTE: no block_until_ready — the transfer proceeds while we
                # return to compute. Readiness is observed by polling.
                self.stats.put_ns += time.perf_counter_ns() - t0  # simlint: disable=SL001 -- wall-clock feed mode
                self._inflight.append(dev)
                return True
        return False

    def _refill(self) -> None:
        while len(self._inflight) < self._depth:
            if not self._issue_one():
                break

    # -- consumer API ------------------------------------------------------------
    def next_batch(self, timeout_s: float = 30.0) -> Optional[DeviceBatch]:
        """Poll for the next ready batch (PMD rx_burst of size 1)."""
        deadline = time.perf_counter_ns() + int(timeout_s * 1e9)  # simlint: disable=SL001 -- wall-clock feed mode
        t_start = time.perf_counter_ns()  # simlint: disable=SL001 -- wall-clock feed mode
        self._refill()
        while True:
            # poll in-flight transfers; prefer the oldest ready one
            for i, dev in enumerate(self._inflight):
                ready = True
                for leaf in jax.tree_util.tree_leaves(dev):
                    if hasattr(leaf, "is_ready") and not leaf.is_ready():
                        ready = False
                        break
                if ready:
                    self._inflight.pop(i)
                    self._refill()  # keep the ring full before returning
                    self.stats.batches += 1
                    self.stats.bytes += _tree_bytes(dev)
                    self.stats.occupancy_sum += len(self._inflight) + 1
                    self.stats.wait_ns += time.perf_counter_ns() - t_start  # simlint: disable=SL001 -- wall-clock feed mode
                    return dev
            if not self._inflight:
                if all(self._exhausted) and all(r.is_empty() for r in self._stage):
                    return None  # clean end of stream
                self._refill()
            self.stats.empty_polls += 1
            if time.perf_counter_ns() > deadline:  # simlint: disable=SL001 -- wall-clock feed mode
                raise TimeoutError("dataplane: no batch became ready in time")
            if self._poll_interval_s:
                time.sleep(self._poll_interval_s)
            else:
                time.sleep(0)  # single-core: let producers run

    def stop(self) -> None:
        self._stop_evt.set()
        for t in self._producers:
            t.join(timeout=5)
        self._inflight.clear()


def make_feed(kind: str, batch_iter_factory: Callable[[int, int], Iterator[HostBatch]],
              **kw: Any):
    """Factory: kind in {"kernel", "bypass"} — one flag swaps the stacks."""
    if kind == "kernel":
        it = batch_iter_factory(0, 1)
        return KernelStackFeed(it, sharding=kw.get("sharding"))
    if kind == "bypass":
        return BypassDataplane(batch_iter_factory, **kw)
    raise ValueError(f"unknown feed kind: {kind}")
