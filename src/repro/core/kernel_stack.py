"""Interrupt-driven kernel network stack — the baseline (iperf analogue).

This is the path the paper's DPDK work *bypasses*.  We reproduce its three
bottlenecks (paper §2) honestly:

1. **Frequent syscalls** — every user-space read()/sendto() crossing pays a
   modeled syscall cost (see :mod:`repro.core.cost` for why these are modeled
   rather than executed).
2. **Buffer copies** — NIC buffer → freshly-allocated "skb" (copy 1, real numpy
   allocation + copy), then skb → user buffer (copy 2, real), then user buffer
   → fresh NIC TX buffer (copy 3, real).  Per-packet allocation is real too.
3. **Interrupt processing** — packets only become visible to the kernel on an
   interrupt (one per descriptor-writeback event), each paying a modeled
   interrupt cost; per-packet protocol processing pays a modeled kernel cost.

Runs on the unified :class:`~repro.core.netstack.NetworkStack` interface:
each (port, queue) pair — multi-queue NICs expose one IRQ vector per queue —
is serviced by a kernel "lcore" quantum: IRQ bottom half, then the
application half.  Socket receive queues are per-queue ``deque``s (O(1)
drain; the seed's ``list.pop(0)`` was O(n)).

The contrast server, :class:`repro.core.pmd.BypassL2FwdServer`, does none of
these: no syscalls, no interrupts, zero copies, no per-packet allocation.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, Optional, Sequence, Tuple

import numpy as np

from .cost import HostCostModel
from .netstack import Lcore, NetworkStack, ServerStats
from .packet import swap_macs
from .pmd import Port, ProcessFn


@dataclass
class KernelStats(ServerStats):
    interrupts: int = 0
    syscalls: int = 0
    copies: int = 0
    copied_bytes: int = 0
    allocs: int = 0
    sockdrops: int = 0  # skbs dropped on socket-buffer overflow (rmem cap)


class KernelStackServer(NetworkStack):
    """Interrupt-driven echo/forward server over N multi-queue ports.

    Each lcore quantum on a (port, queue) pair mimics the kernel + application
    flow for whatever packets that queue's interrupt has made visible:
    IRQ → skb alloc+copy → protocol processing → read() syscall copy-to-user →
    application processing → sendto() syscall copy-from-user → TX post.
    """

    stats_cls = KernelStats

    def __init__(
        self,
        ports: Sequence[Port],
        cost_model: Optional[HostCostModel] = None,
        sockbuf_budget: int = 16,  # packets drained per read() syscall
        process_fn: Optional[ProcessFn] = None,
        n_lcores: Optional[int] = None,
        sockbuf_capacity: int = 512,  # rmem cap: skbs queued per socket
    ):
        super().__init__(ports, n_lcores=n_lcores)
        if sockbuf_capacity < 1:
            raise ValueError("sockbuf_capacity must be >= 1")
        self.cost = cost_model or HostCostModel()
        self.sockbuf_budget = sockbuf_budget
        self.sockbuf_capacity = sockbuf_capacity
        self.process_fn = process_fn if process_fn is not None else swap_macs
        # socket receive queues (skbs waiting for the app), one per HW queue
        self._sock_queues: Dict[Tuple[int, int], Deque[np.ndarray]] = {
            pair: deque() for pair in self.queue_pairs
        }

    # -- kernel half ----------------------------------------------------------
    def _irq_bottom_half(self, port_idx: int, queue_idx: int,
                         qstats: KernelStats) -> int:
        """Interrupt: move written-back descriptors into the socket queue."""
        port = self.ports[port_idx]
        ring = port.rx_queues[queue_idx]
        batch = ring.poll(ring.size)  # kernel drains what's visible
        if not batch:
            return 0
        qstats.interrupts += 1
        self.charge_ns(self.cost.ns(self.cost.interrupt_cycles))
        q = self._sock_queues[(port_idx, queue_idx)]
        for slot, length in batch:
            if len(q) >= self.sockbuf_capacity:
                # socket buffer full (the rmem cap): the kernel drops the
                # frame — the loss mechanism a saturated iperf actually sees
                port.pool.free(slot)
                qstats.sockdrops += 1
                continue
            # copy 1: NIC DMA buffer -> fresh skb (real alloc + real copy)
            skb = np.array(port.pool.view(slot, length))  # allocates + copies
            qstats.allocs += 1
            qstats.copies += 1
            qstats.copied_bytes += length
            port.pool.free(slot)  # NIC buffer recycled immediately (kernel owns skb)
            self.charge_ns(self.cost.ns(self.cost.per_packet_kernel_cycles))
            q.append(skb)
        return len(batch)

    # -- application half ------------------------------------------------------
    def _app_read_process_send(self, port_idx: int, queue_idx: int,
                               qstats: KernelStats) -> int:
        port = self.ports[port_idx]
        q = self._sock_queues[(port_idx, queue_idx)]
        if not q:
            return 0
        # read() syscall: drains up to sockbuf_budget skbs into user buffers
        qstats.syscalls += 1
        self.charge_ns(self.cost.ns(self.cost.syscall_cycles))
        n = min(self.sockbuf_budget, len(q))
        done = 0
        for _ in range(n):
            skb = q.popleft()
            # copy 2: skb -> user buffer (real alloc + copy)
            user_buf = np.array(skb)
            qstats.allocs += 1
            qstats.copies += 1
            qstats.copied_bytes += len(user_buf)
            self.process_fn(user_buf)
            # sendto() syscall per packet + copy 3: user buffer -> NIC TX buffer
            qstats.syscalls += 1
            self.charge_ns(self.cost.ns(self.cost.syscall_cycles))
            tx_slot = port.pool.alloc()
            if tx_slot is None:
                continue  # pool exhausted: drop on TX
            length = len(user_buf)
            port.pool.arena[tx_slot, :length] = user_buf
            port.pool.lengths[tx_slot] = length
            qstats.copies += 1
            qstats.copied_bytes += length
            self.charge_ns(self.cost.ns(self.cost.per_packet_kernel_cycles))
            if port.tx_queues[queue_idx].post(tx_slot, length):
                qstats.tx_packets += 1
            else:
                port.pool.free(tx_slot)
            qstats.rx_packets += 1
            qstats.rx_bytes += length
            done += 1
        return done

    # -- lcore quantum ---------------------------------------------------------
    def _service_queue(self, lcore: Lcore, port_idx: int, queue_idx: int,
                       qstats: ServerStats) -> int:
        """One scheduling quantum on one queue: service its IRQ, run the app."""
        self._irq_bottom_half(port_idx, queue_idx, qstats)
        done = self._app_read_process_send(port_idx, queue_idx, qstats)
        qstats.poll_iterations += 1
        if done == 0:
            qstats.empty_polls += 1
        return done

    @property
    def queued(self) -> int:
        return sum(len(q) for q in self._sock_queues.values())
