"""Interrupt-driven kernel network stack — the baseline (iperf analogue).

This is the path the paper's DPDK work *bypasses*.  We reproduce its three
bottlenecks (paper §2) honestly:

1. **Frequent syscalls** — every user-space read()/sendto() crossing pays a
   modeled syscall cost (see :mod:`repro.core.cost` for why these are modeled
   rather than executed).
2. **Buffer copies** — NIC buffer → freshly-allocated "skb" (copy 1, real numpy
   allocation + copy), then skb → user buffer (copy 2, real), then user buffer
   → fresh NIC TX buffer (copy 3, real).  Per-packet allocation is real too.
3. **Interrupt processing** — packets only become visible to the kernel on an
   interrupt (one per descriptor-writeback event), each paying a modeled
   interrupt cost; per-packet protocol processing pays a modeled kernel cost.

The contrast server, :class:`repro.core.pmd.BypassL2FwdServer`, does none of
these: no syscalls, no interrupts, zero copies, no per-packet allocation.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

from .cost import HostCostModel, spin_ns
from .packet import swap_macs
from .pmd import Port, ProcessFn, ServerStats


@dataclass
class KernelStats(ServerStats):
    interrupts: int = 0
    syscalls: int = 0
    copies: int = 0
    copied_bytes: int = 0
    allocs: int = 0


class KernelStackServer:
    """Interrupt-driven echo/forward server over N ports.

    ``poll_once`` mimics the kernel + application flow for whatever packets an
    interrupt has made visible: IRQ → skb alloc+copy → protocol processing →
    read() syscall copy-to-user → application processing → sendto() syscall
    copy-from-user → TX post.
    """

    def __init__(
        self,
        ports: Sequence[Port],
        cost_model: Optional[HostCostModel] = None,
        sockbuf_budget: int = 16,  # packets drained per read() syscall
        process_fn: Optional[ProcessFn] = None,
    ):
        self.ports = list(ports)
        self.cost = cost_model or HostCostModel()
        self.sockbuf_budget = sockbuf_budget
        self.process_fn = process_fn if process_fn is not None else swap_macs
        self.stats = KernelStats()
        # socket receive queues (skbs waiting for the app), per port
        self._sock_queues: List[List[np.ndarray]] = [[] for _ in self.ports]

    # -- kernel half ----------------------------------------------------------
    def _irq_bottom_half(self, port_idx: int) -> int:
        """Interrupt: move written-back descriptors into the socket queue."""
        port = self.ports[port_idx]
        batch = port.rx.poll(len(port.rx.status))  # kernel drains what's visible
        if not batch:
            return 0
        self.stats.interrupts += 1
        spin_ns(self.cost.ns(self.cost.interrupt_cycles))
        q = self._sock_queues[port_idx]
        for slot, length in batch:
            # copy 1: NIC DMA buffer -> fresh skb (real alloc + real copy)
            skb = np.array(port.pool.view(slot, length))  # allocates + copies
            self.stats.allocs += 1
            self.stats.copies += 1
            self.stats.copied_bytes += length
            port.pool.free(slot)  # NIC buffer recycled immediately (kernel owns skb)
            spin_ns(self.cost.ns(self.cost.per_packet_kernel_cycles))
            q.append(skb)
        return len(batch)

    # -- application half ------------------------------------------------------
    def _app_read_process_send(self, port_idx: int) -> int:
        port = self.ports[port_idx]
        q = self._sock_queues[port_idx]
        if not q:
            return 0
        # read() syscall: drains up to sockbuf_budget skbs into user buffers
        self.stats.syscalls += 1
        spin_ns(self.cost.ns(self.cost.syscall_cycles))
        n = min(self.sockbuf_budget, len(q))
        done = 0
        for _ in range(n):
            skb = q.pop(0)
            # copy 2: skb -> user buffer (real alloc + copy)
            user_buf = np.array(skb)
            self.stats.allocs += 1
            self.stats.copies += 1
            self.stats.copied_bytes += len(user_buf)
            self.process_fn(user_buf)
            # sendto() syscall per packet + copy 3: user buffer -> NIC TX buffer
            self.stats.syscalls += 1
            spin_ns(self.cost.ns(self.cost.syscall_cycles))
            tx_slot = port.pool.alloc()
            if tx_slot is None:
                continue  # pool exhausted: drop on TX
            length = len(user_buf)
            port.pool.arena[tx_slot, :length] = user_buf
            port.pool.lengths[tx_slot] = length
            self.stats.copies += 1
            self.stats.copied_bytes += length
            spin_ns(self.cost.ns(self.cost.per_packet_kernel_cycles))
            if not port.tx.post(tx_slot, length):
                port.pool.free(tx_slot)
            self.stats.rx_packets += 1
            self.stats.rx_bytes += length
            done += 1
        return done

    def poll_once(self) -> int:
        """One scheduling quantum: service IRQs then let the app run."""
        total = 0
        for i in range(len(self.ports)):
            self._irq_bottom_half(i)
            total += self._app_read_process_send(i)
        self.stats.poll_iterations += 1
        if total == 0:
            self.stats.empty_polls += 1
        self.stats.tx_packets = sum(p.tx.posted for p in self.ports)
        return total

    @property
    def queued(self) -> int:
        return sum(len(q) for q in self._sock_queues)
