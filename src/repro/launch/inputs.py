"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape_name)`` returns the abstract args for the step
function that the given shape cell lowers:

  train_4k            → train_step(params, opt_state, batch)
  prefill_32k         → prefill_step(params, batch)
  decode_32k/long_500k→ decode_step(params, cache, token, pos)

(only the batch/cache/token parts are returned here; params/opt-state structs
come from jax.eval_shape over the initializers).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig
from repro.models.registry import SHAPES, STEP_KIND

SDS = jax.ShapeDtypeStruct


def batch_specs(cfg: ModelConfig, seq_len: int, global_batch: int
                ) -> Dict[str, Any]:
    B, S = global_batch, seq_len
    if cfg.frontend == "audio_frames":
        return {
            "frames": SDS((B, S, cfg.d_model), jnp.bfloat16),
            "labels": SDS((B, S), jnp.int32),
        }
    if cfg.frontend == "vision_patches":
        s_text = S - cfg.n_patches
        return {
            "tokens": SDS((B, s_text), jnp.int32),
            "patches": SDS((B, cfg.n_patches, cfg.d_model), jnp.bfloat16),
            "labels": SDS((B, s_text), jnp.int32),
        }
    return {
        "tokens": SDS((B, S), jnp.int32),
        "labels": SDS((B, S), jnp.int32),
    }


def prompt_specs(cfg: ModelConfig, seq_len: int, global_batch: int
                 ) -> Dict[str, Any]:
    b = batch_specs(cfg, seq_len, global_batch)
    b.pop("labels", None)
    return b


def cache_specs(cfg: ModelConfig, global_batch: int, max_len: int) -> Any:
    return jax.eval_shape(lambda: lm.init_cache(cfg, global_batch, max_len))


def input_specs(cfg: ModelConfig, shape_name: str) -> Tuple[Any, ...]:
    dims = SHAPES[shape_name]
    S, B = dims["seq_len"], dims["global_batch"]
    kind = STEP_KIND[shape_name]
    if kind == "train":
        return (batch_specs(cfg, S, B),)
    if kind == "prefill":
        return (prompt_specs(cfg, S, B),)
    if kind == "decode":
        cache = cache_specs(cfg, B, S)
        token = SDS((B,), jnp.int32)
        pos = SDS((B,), jnp.int32)
        return (cache, token, pos)
    raise ValueError(shape_name)
