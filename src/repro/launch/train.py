"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
        --steps 50 --feed bypass --ports 2 --ckpt-dir /tmp/ckpt

``--smoke`` selects the reduced config (CPU-runnable); the full configs are
for real pods (and are exercised via the dry-run here).  ``--mesh`` attaches
the production mesh/rules when multiple devices exist.
"""
from __future__ import annotations

import argparse

import jax

from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_production_mesh, make_smoke_mesh, rules_for
from repro.models.registry import ARCHS, get_config, get_smoke_config
from repro.optim import adamw
from repro.runtime.trainer import TrainerConfig, TrainerRuntime


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--feed", choices=["bypass", "kernel"], default="bypass")
    ap.add_argument("--ports", type=int, default=1)
    ap.add_argument("--depth", type=int, default=3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", choices=["none", "single", "multi"],
                    default="none")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    dcfg = DataConfig(seq_len=args.seq_len, global_batch=args.global_batch,
                      seed=args.seed)
    tcfg = TrainerConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir, feed=args.feed,
                         feed_ports=args.ports, feed_depth=args.depth,
                         log_every=args.log_every, seed=args.seed)
    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=min(100, args.steps // 10 + 1),
                                decay_steps=args.steps)

    mesh = rules = None
    if args.mesh != "none":
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
        rules = rules_for(mesh)

    runtime = TrainerRuntime(cfg, dcfg, tcfg, opt_cfg, mesh=mesh, rules=rules)
    state = runtime.run()
    print(f"[train] finished at step {state.step}; "
          f"stragglers={runtime.straggler_events}")
    if runtime.metrics_log:
        first, last = runtime.metrics_log[0], runtime.metrics_log[-1]
        print(f"[train] loss {first['loss']:.4f} -> {last['loss']:.4f}")


if __name__ == "__main__":
    main()
