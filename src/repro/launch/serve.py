"""Serving launcher: prefill + decode loop driven by a request load generator.

The serving analogue of the paper's measurement setup: a LoadGen-style
request generator (Poisson/uniform arrivals) offers token-generation requests
to the model server; per-request latency (time-to-first-token for prefill,
per-token decode latency) is timestamped exactly like EtherLoadGen packets.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --requests 32 --prompt-len 64 --gen-len 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.telemetry import LatencyRecorder
from repro.models import lm
from repro.models.registry import ARCHS, get_config, get_smoke_config
from repro.runtime.steps import make_decode_step, make_prefill_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not cfg.has_decode:
        raise SystemExit(f"{args.arch} is encoder-only: no decode serving")
    params = lm.init_params(cfg, jax.random.PRNGKey(args.seed))

    B = args.batch
    max_len = args.prompt_len + args.gen_len + (
        cfg.n_patches if cfg.frontend == "vision_patches" else 0)
    prefill = jax.jit(make_prefill_step(cfg, max_len))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))

    rng = np.random.default_rng(args.seed)
    ttft = LatencyRecorder()
    tpot = LatencyRecorder()
    n_batches = (args.requests + B - 1) // B
    total_tokens = 0
    t_start = time.perf_counter_ns()
    for _ in range(n_batches):
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(B, args.prompt_len)),
            jnp.int32)}
        if cfg.frontend == "vision_patches":
            batch["patches"] = jnp.asarray(
                rng.standard_normal((B, cfg.n_patches, cfg.d_model)) * 0.02,
                jnp.dtype(cfg.compute_dtype))
        t0 = time.perf_counter_ns()
        logits, cache = prefill(params, batch)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        jax.block_until_ready(tok)
        ttft.record(time.perf_counter_ns() - t0)
        pos0 = args.prompt_len + (cfg.n_patches
                                  if cfg.frontend == "vision_patches" else 0)
        for i in range(args.gen_len):
            t1 = time.perf_counter_ns()
            pos = jnp.full((B,), pos0 + i, jnp.int32)
            tok, logits, cache = decode(params, cache, tok, pos)
            jax.block_until_ready(tok)
            tpot.record(time.perf_counter_ns() - t1)
            total_tokens += B
    wall_s = (time.perf_counter_ns() - t_start) / 1e9
    print(f"[serve] {args.requests} requests, {total_tokens} generated tokens "
          f"in {wall_s:.2f}s ({total_tokens / wall_s:.1f} tok/s)")
    print(f"[serve] TTFT: {ttft.stats()}")
    print(f"[serve] per-token: {tpot.stats()}")


if __name__ == "__main__":
    main()
