import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (architecture × shape) cell on the
production meshes and extract memory / cost / collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out results/dryrun.jsonl

Each record proves the cell compiles on (16,16)=256 chips (and (2,16,16)=512
for --mesh multi/both) and carries the §Roofline terms.
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.launch.inputs import input_specs
from repro.launch.mesh import make_production_mesh, rules_for
from repro.models import lm
from repro.models.config import ModelConfig
from repro.models.registry import (ARCHS, SHAPES, STEP_KIND, all_cells,
                                   cell_status, get_config)
from repro.optim import adamw
from repro.parallel import hlo_analysis, hlo_counter
from repro.parallel.axes import axis_rules
from repro.parallel.specs import (make_batch_specs, make_cache_specs,
                                  make_param_specs, make_shardings)
from repro.runtime.steps import (make_decode_step, make_prefill_step,
                                 make_train_step)


def _abstract_params(cfg: ModelConfig):
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda: lm.init_params(cfg, key))


def _mem_dict(compiled) -> Dict[str, Any]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for f in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "temp_size_in_bytes"):
        v = getattr(ma, f, None)
        if v is not None:
            out[f] = int(v)
    return out


def run_cell(arch: str, shape: str, multi_pod: bool,
             opt_override=None, lower_only: bool = False) -> Dict[str, Any]:
    cfg = get_config(arch)
    ok, reason = cell_status(cfg, shape)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "step": STEP_KIND[shape],
    }
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec

    dims = SHAPES[shape]
    kind = STEP_KIND[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    # decode cells always use the tp layout (kv_seq context sharding);
    # train/prefill follow the arch's tuned layout — but pure-FSDP needs the
    # global batch to split across every chip (prefill_32k's batch=32 cannot
    # shard 256 ways; replicated activations would 8x the compute term)
    layout = cfg.parallel_layout
    if kind == "decode" or dims["global_batch"] % mesh.size != 0:
        layout = "tp"
    layout = os.environ.get("REPRO_FORCE_LAYOUT", layout)
    rules = rules_for(mesh, layout)
    rec["layout"] = layout
    n_dev = mesh.size

    t0 = time.time()
    with axis_rules(rules, mesh):
        params_s = _abstract_params(cfg)
        pspecs = make_param_specs(params_s, rules, mesh)
        pshard = make_shardings(pspecs, mesh)
        args = input_specs(cfg, shape)
        if kind == "train":
            opt_cfg = opt_override or adamw.AdamWConfig()
            opt_s = jax.eval_shape(lambda p: adamw.init(opt_cfg, p), params_s)
            ospecs = adamw.OptState(
                step=jax.sharding.PartitionSpec(),
                master=pspecs if opt_cfg.master_fp32 else (),
                m=pspecs, v=pspecs)
            oshard = make_shardings(ospecs, mesh)
            bshard = make_shardings(make_batch_specs(args[0], rules, mesh), mesh)
            step_fn = make_train_step(cfg, opt_cfg, grad_shardings=pshard)
            jitted = jax.jit(step_fn, in_shardings=(pshard, oshard, bshard),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_s, opt_s, args[0])
        elif kind == "prefill":
            bshard = make_shardings(make_batch_specs(args[0], rules, mesh), mesh)
            step_fn = make_prefill_step(cfg, dims["seq_len"])
            jitted = jax.jit(step_fn, in_shardings=(pshard, bshard))
            lowered = jitted.lower(params_s, args[0])
        else:  # decode
            cache_s, token_s, pos_s = args
            cspecs = make_cache_specs(cfg, cache_s, rules, mesh)
            cshard = make_shardings(cspecs, mesh)
            tshard = make_shardings(make_batch_specs(token_s, rules, mesh), mesh)
            qshard = make_shardings(make_batch_specs(pos_s, rules, mesh), mesh)
            step_fn = make_decode_step(cfg)
            jitted = jax.jit(step_fn,
                             in_shardings=(pshard, cshard, tshard, qshard),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_s, cache_s, token_s, pos_s)
        t_lower = time.time() - t0
        rec["lower_s"] = round(t_lower, 2)
        if lower_only:
            rec["status"] = "lowered"
            return rec
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

    cost = compiled.cost_analysis() or {}
    hlo_text = compiled.as_text()
    # trip-count-aware static analysis (cost_analysis counts loop bodies once)
    counted = hlo_counter.analyze(hlo_text)
    model_flops = hlo_analysis.model_flops_for_step(
        cfg, kind, dims["seq_len"], dims["global_batch"])
    roof = hlo_analysis.Roofline(
        flops_per_device=counted.dot_flops,
        hbm_bytes_per_device=counted.hbm_bytes,
        wire_bytes_per_device=counted.total_wire_bytes,
        n_devices=n_dev,
        model_flops_total=model_flops,
    )
    rec.update(
        status="ok",
        n_devices=n_dev,
        params=cfg.param_count(),
        active_params=cfg.active_param_count(),
        memory=_mem_dict(compiled),
        xla_cost_analysis={"flops": float(cost.get("flops", 0.0)),
                           "bytes_accessed": float(cost.get("bytes accessed", 0.0))},
        collective_counts=counted.collective_counts,
        collective_op_bytes={k: round(v) for k, v
                             in counted.collective_op_bytes.items()},
        collective_wire_bytes={k: round(v) for k, v
                               in counted.collective_wire_bytes.items()},
        roofline=roof.as_dict(),
    )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch × shape) cell")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    ap.add_argument("--lower-only", action="store_true")
    args = ap.parse_args()

    if args.all:
        cells = all_cells()
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    out_f = open(args.out, "a") if args.out else None
    n_fail = 0
    for arch, shape in cells:
        for multi in meshes:
            try:
                rec = run_cell(arch, shape, multi, lower_only=args.lower_only)
            except Exception as e:  # noqa: BLE001 — a failed cell is a bug
                rec = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if multi else "16x16",
                       "status": "error", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
                n_fail += 1
            line = json.dumps(rec)
            print(line if rec.get("status") != "error"
                  else json.dumps({k: rec[k] for k in
                                   ("arch", "shape", "mesh", "status", "error")}))
            if out_f:
                out_f.write(line + "\n")
                out_f.flush()
    if out_f:
        out_f.close()
    if n_fail:
        raise SystemExit(f"{n_fail} cell(s) failed")


if __name__ == "__main__":
    main()
