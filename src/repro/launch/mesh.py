"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — smoke tests must keep seeing 1 device.

``AxisType`` only exists in jax >= 0.5; on older jax every mesh axis is
implicitly Auto, so the compat helpers below simply omit the argument.  All
repo code (and the subprocess test scripts) build meshes through them
instead of importing ``jax.sharding.AxisType`` directly.
"""
from __future__ import annotations

from typing import Dict, Sequence, Tuple

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5
    from jax.sharding import AxisType
except ImportError:  # jax <= 0.4.x: axes are Auto by construction
    AxisType = None

from repro.parallel.axes import (AxisRules, multi_pod_rules, pure_fsdp_rules,
                                 single_pod_rules)


def auto_axis_types_kw(n_axes: int) -> Dict[str, Tuple]:
    """``{"axis_types": (Auto,) * n}`` where supported, else ``{}``."""
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def make_auto_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    """``jax.make_mesh`` with every axis Auto, on any supported jax."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **auto_axis_types_kw(len(axes)))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_auto_mesh(shape, axes)


def rules_for(mesh: Mesh, layout: str = "tp") -> AxisRules:
    """layout: "tp" (TP over model + FSDP over data, the baseline) or "fsdp"
    (pure 256-way ZeRO-3, single-pod only — multi-pod falls back to tp since
    global_batch 256 cannot split 512 ways)."""
    if "pod" in mesh.axis_names:
        return multi_pod_rules()
    if layout == "fsdp":
        return pure_fsdp_rules()
    return single_pod_rules()


def make_smoke_mesh(n_devices: int = 1) -> Mesh:
    """Tiny mesh over however many real devices exist (tests)."""
    devs = jax.devices()[:n_devices]
    return Mesh(
        __import__("numpy").array(devs).reshape(1, len(devs)),
        ("data", "model"),
        **auto_axis_types_kw(2),
    )
