"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh

from repro.parallel.axes import (AxisRules, multi_pod_rules, pure_fsdp_rules,
                                 single_pod_rules)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def rules_for(mesh: Mesh, layout: str = "tp") -> AxisRules:
    """layout: "tp" (TP over model + FSDP over data, the baseline) or "fsdp"
    (pure 256-way ZeRO-3, single-pod only — multi-pod falls back to tp since
    global_batch 256 cannot split 512 ways)."""
    if "pod" in mesh.axis_names:
        return multi_pod_rules()
    if layout == "fsdp":
        return pure_fsdp_rules()
    return single_pod_rules()


def make_smoke_mesh(n_devices: int = 1) -> Mesh:
    """Tiny mesh over however many real devices exist (tests)."""
    devs = jax.devices()[:n_devices]
    return Mesh(
        __import__("numpy").array(devs).reshape(1, len(devs)),
        ("data", "model"),
        axis_types=(AxisType.Auto, AxisType.Auto),
    )
