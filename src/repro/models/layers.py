"""Shared neural-net layers: norms, RoPE, GQA attention, MLP, embeddings.

Functional style: ``init_*`` returns a param pytree, ``apply_*`` consumes it.
Parameters never embed layer indices — model modules stack layer params with a
leading layer axis and drive them through ``jax.lax.scan`` (small HLO, fast
512-device SPMD compiles).

Sharding is expressed through logical axes (repro.parallel.axes.shard); on a
single CPU device every annotation is a no-op.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.parallel.axes import gather_weight, shard
from .config import ModelConfig

Params = Dict[str, Any]


def remat_wrap(cfg: ModelConfig, fn):
    """Unit-scan remat with the config's policy (EXPERIMENTS.md §Perf iter 3)."""
    if cfg.remat_policy == "save_dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def dt(cfg: ModelConfig) -> jnp.dtype:
    return jnp.dtype(cfg.param_dtype)


def cdt(cfg: ModelConfig) -> jnp.dtype:
    return jnp.dtype(cfg.compute_dtype)


def _normal(key, shape, scale, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# =============================================================================
# Norms
# =============================================================================

def init_norm(cfg: ModelConfig, dim: Optional[int] = None) -> Params:
    d = dim if dim is not None else cfg.d_model
    p = {"scale": jnp.ones((d,), dt(cfg))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), dt(cfg))
    return p


def apply_norm(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    """qk-norm: RMS over head_dim, learned per-dim scale (qwen3)."""
    xf = x.astype(jnp.float32)
    ms = (xf * xf).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# =============================================================================
# RoPE
# =============================================================================

def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, Dh), positions: (B, S) or (S,). Rotates pairs (even, odd
    halves convention)."""
    B, S, H, Dh = x.shape
    half = Dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# =============================================================================
# Attention block (GQA + qk_norm + RoPE + full/sliding window)
# =============================================================================

def init_attention(cfg: ModelConfig, key) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    D = cfg.d_model
    scale = 0.02
    out_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    p = {
        "wq": _normal(k1, (D, cfg.n_heads, cfg.head_dim), scale, dt(cfg)),
        "wk": _normal(k2, (D, cfg.n_kv_heads, cfg.head_dim), scale, dt(cfg)),
        "wv": _normal(k3, (D, cfg.n_kv_heads, cfg.head_dim), scale, dt(cfg)),
        "wo": _normal(k4, (cfg.n_heads, cfg.head_dim, D), out_scale, dt(cfg)),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.head_dim,), dt(cfg))
        p["k_norm"] = jnp.ones((cfg.head_dim,), dt(cfg))
    return p


def _project_qkv(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                 positions: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    q = jnp.einsum("bsd,dhk->bshk", x, gather_weight(p["wq"]).astype(cdt(cfg)))
    k = jnp.einsum("bsd,dhk->bshk", x, gather_weight(p["wk"]).astype(cdt(cfg)))
    v = jnp.einsum("bsd,dhk->bshk", x, gather_weight(p["wv"]).astype(cdt(cfg)))
    if cfg.qk_norm:
        q = rms_head_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_head_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)
    return q, k, v


def apply_attention(
    cfg: ModelConfig,
    p: Params,
    x: jnp.ndarray,              # (B, S, D)
    positions: jnp.ndarray,      # (B, S) absolute positions
    *,
    window_override: Optional[int] = None,
) -> jnp.ndarray:
    """Full-sequence attention (train / prefill body)."""
    q, k, v = _project_qkv(cfg, p, x, positions)
    window = cfg.window if window_override is None else window_override
    causal = cfg.causal
    out = ops.flash_attention(q, k, v, causal=causal, window=window)
    out = shard(out, "batch", None, "heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out, gather_weight(p["wo"]).astype(cdt(cfg)))
    return shard(y, "batch", None, None)


def attention_prefill_kv(
    cfg: ModelConfig, p: Params, x: jnp.ndarray, positions: jnp.ndarray,
    cache_size: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Compute post-RoPE K/V for cache population during prefill.

    Returns (k, v) shaped (B, cache_size, Hkv, Dh): the last ``cache_size``
    positions (ring semantics for windowed caches)."""
    _, k, v = _project_qkv(cfg, p, x, positions)
    S = k.shape[1]
    if cache_size < S:
        # keep the most recent cache_size entries, ring-rotated so that
        # slot = pos % cache_size (matches decode-time insertion)
        k = k[:, -cache_size:]
        v = v[:, -cache_size:]
        first_pos = positions[..., -cache_size:]
        first = (first_pos[0, 0] if first_pos.ndim == 2 else first_pos[0])
        rot = jnp.mod(first, cache_size)
        k = jnp.roll(k, shift=rot, axis=1)
        v = jnp.roll(v, shift=rot, axis=1)
    elif cache_size > S:
        padw = ((0, 0), (0, cache_size - S), (0, 0), (0, 0))
        k, v = jnp.pad(k, padw), jnp.pad(v, padw)
    return k, v


def apply_attention_decode(
    cfg: ModelConfig,
    p: Params,
    x_t: jnp.ndarray,            # (B, 1, D) current token
    pos: jnp.ndarray,            # (B,) absolute position of this token
    k_cache: jnp.ndarray,        # (B, C, Hkv, Dh) (C = window or max len)
    v_cache: jnp.ndarray,
    *,
    window_override: Optional[int] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One decode step. Returns (y (B,1,D), new k_cache, new v_cache)."""
    B, _, D = x_t.shape
    C = k_cache.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x_t, p["wq"].astype(cdt(cfg)))
    k = jnp.einsum("bsd,dhk->bshk", x_t, p["wk"].astype(cdt(cfg)))
    v = jnp.einsum("bsd,dhk->bshk", x_t, p["wv"].astype(cdt(cfg)))
    if cfg.qk_norm:
        q = rms_head_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_head_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)
    # ring insert at pos % C (full caches: C == max len → plain append)
    slot = jnp.mod(pos, C)  # (B,)
    bidx = jnp.arange(B)
    k_cache = k_cache.at[bidx, slot].set(k[:, 0])
    v_cache = v_cache.at[bidx, slot].set(v[:, 0])
    k_cache = shard(k_cache, "batch", "kv_seq", None, None)
    v_cache = shard(v_cache, "batch", "kv_seq", None, None)
    cache_len = jnp.minimum(pos + 1, C)
    out = ops.decode_attention(q[:, 0], k_cache, v_cache, cache_len)
    out = shard(out, "batch", "heads", None)
    y = jnp.einsum("bhk,hkd->bd", out, p["wo"].astype(cdt(cfg)))[:, None]
    return shard(y, "batch", None, None), k_cache, v_cache


# =============================================================================
# MLP (SwiGLU or GELU)
# =============================================================================

def init_mlp(cfg: ModelConfig, key, d_ff: Optional[int] = None) -> Params:
    D = cfg.d_model
    F = d_ff if d_ff is not None else cfg.d_ff
    out_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    if cfg.act == "silu":
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "w_gate": _normal(k1, (D, F), 0.02, dt(cfg)),
            "w_up": _normal(k2, (D, F), 0.02, dt(cfg)),
            "w_down": _normal(k3, (F, D), out_scale, dt(cfg)),
        }
    k1, k2 = jax.random.split(key, 2)
    return {
        "w_up": _normal(k1, (D, F), 0.02, dt(cfg)),
        "b_up": jnp.zeros((F,), dt(cfg)),
        "w_down": _normal(k2, (F, D), out_scale, dt(cfg)),
        "b_down": jnp.zeros((D,), dt(cfg)),
    }


def apply_mlp(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.act == "silu":
        g = jnp.einsum("...d,df->...f", x, gather_weight(p["w_gate"]).astype(cdt(cfg)))
        u = jnp.einsum("...d,df->...f", x, gather_weight(p["w_up"]).astype(cdt(cfg)))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(cdt(cfg)) * u
        h = shard(h, "batch", None, "ffn")
        y = jnp.einsum("...f,fd->...d", h, gather_weight(p["w_down"]).astype(cdt(cfg)))
        return shard(y, "batch", None, None)
    u = jnp.einsum("...d,df->...f", x, gather_weight(p["w_up"]).astype(cdt(cfg))) + p["b_up"]
    h = jax.nn.gelu(u.astype(jnp.float32)).astype(cdt(cfg))
    h = shard(h, "batch", None, "ffn")
    y = jnp.einsum("...f,fd->...d", h, gather_weight(p["w_down"]).astype(cdt(cfg))) + p["b_down"]
    return shard(y, "batch", None, None)


# =============================================================================
# Embedding / unembedding
# =============================================================================

def init_embedding(cfg: ModelConfig, key) -> Params:
    k1, k2 = jax.random.split(key)
    p = {"tok": _normal(k1, (cfg.vocab_size, cfg.d_model), 0.02, dt(cfg))}
    if not cfg.tie_embeddings:
        p["unembed"] = _normal(k2, (cfg.vocab_size, cfg.d_model),
                               1.0 / math.sqrt(cfg.d_model), dt(cfg))
    return p


def embed_tokens(cfg: ModelConfig, p: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    x = gather_weight(p["tok"]).astype(cdt(cfg))[tokens]
    return shard(x, "batch", None, None)


def unembed_matrix(cfg: ModelConfig, p: Params) -> jnp.ndarray:
    w = p["unembed"] if not cfg.tie_embeddings else p["tok"]
    return w.astype(cdt(cfg))


def logits_for(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    """Full logits — use only for single-position decode outputs."""
    w = unembed_matrix(cfg, p)
    out = jnp.einsum("...d,vd->...v", x, w)
    return shard(out, "batch", "vocab") if out.ndim == 2 else shard(
        out, "batch", None, "vocab")


def chunked_softmax_xent(
    cfg: ModelConfig,
    p_embed: Params,
    x: jnp.ndarray,        # (B, S, D) final hidden states
    labels: jnp.ndarray,   # (B, S) int32; -100 = ignore
    s_chunk: int = 2048,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Cross-entropy without materializing (B, S, V) — logits are computed
    per sequence chunk inside a scan (memory: B × s_chunk × V).

    Returns (sum_loss, n_valid_tokens) as f32 scalars.
    """
    B, S, D = x.shape
    w = unembed_matrix(cfg, p_embed)  # (V, D)
    sc = min(s_chunk, S)
    pad = (-S) % sc
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-100)
    nc = x.shape[1] // sc
    xs = x.reshape(B, nc, sc, D).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, nc, sc).transpose(1, 0, 2)

    def body(carry, inp):
        loss_sum, n_valid = carry
        x_c, l_c = inp
        logits = jnp.einsum("bsd,vd->bsv", x_c, w).astype(jnp.float32)
        logits = shard(logits, "batch", None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        valid = l_c != -100
        safe_labels = jnp.where(valid, l_c, 0)
        picked = jnp.take_along_axis(logits, safe_labels[..., None], axis=-1)[..., 0]
        tok_loss = jnp.where(valid, lse - picked, 0.0)
        return (loss_sum + tok_loss.sum(), n_valid + valid.sum()), None

    (loss_sum, n_valid), _ = jax.lax.scan(body, (jnp.float32(0), jnp.int32(0)),
                                          (xs, ls))
    return loss_sum, n_valid.astype(jnp.float32)
