"""Model configuration shared by all 10 assigned architectures."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                      # dense | moe | hybrid | ssm | encoder | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None   # default: d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-6
    act: str = "silu"                # silu (SwiGLU) | gelu (plain MLP)
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    causal: bool = True              # False for encoder-only
    tie_embeddings: bool = False

    # attention variants
    attention_kind: str = "full"     # full | sliding (SWA) | local (hybrid)
    window: int = 0                  # sliding/local window size

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1               # 1 = every layer is MoE; 2 = alternate
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # hybrid (RG-LRU / recurrentgemma): repeating block pattern
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rglru", "rglru", "attn")
    lru_width: Optional[int] = None       # defaults to d_model

    # ssm (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_width: int = 4

    # modality frontend (STUB per assignment: precomputed embeddings)
    frontend: str = "none"           # none | audio_frames | vision_patches
    n_patches: int = 256             # vision_patches: patches per image

    # distribution: "tp" (TP+FSDP baseline) | "fsdp" (pure ZeRO-3; best for
    # small models on a 256-chip pod — see EXPERIMENTS.md §Perf)
    parallel_layout: str = "tp"
    # remat: "full" (recompute everything) | "save_dots" (keep no-batch-dim
    # matmul outputs; trades HBM footprint for ~25% less recompute — only
    # viable when per-device activations are small)
    remat_policy: str = "full"

    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    def __post_init__(self) -> None:
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // max(1, self.n_heads))
        if self.family == "hybrid" and not self.block_pattern:
            object.__setattr__(self, "block_pattern", ("rglru", "rglru", "attn"))
        if self.lru_width is None:
            object.__setattr__(self, "lru_width", self.d_model)

    # -- derived ------------------------------------------------------------
    @property
    def d_inner(self) -> int:  # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def is_subquadratic(self) -> bool:
        """Can this arch run 500k-token contexts? (SSM/hybrid/SWA)"""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.attention_kind == "sliding" and self.window > 0

    @property
    def has_decode(self) -> bool:
        return self.causal  # encoder-only archs have no autoregressive decode

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- parameter counting (for 6·N·D model-flops and EXPERIMENTS.md) --------
    def param_count(self) -> int:
        return _param_count(self, active_only=False)

    def active_param_count(self) -> int:
        return _param_count(self, active_only=True)


def _param_count(cfg: ModelConfig, active_only: bool) -> int:
    D, F, V = cfg.d_model, cfg.d_ff, cfg.vocab_size
    emb = V * D
    out_head = 0 if cfg.tie_embeddings else V * D
    total = emb + out_head + D  # final norm

    def attn_params() -> int:
        return D * cfg.q_dim + 2 * D * cfg.kv_dim + cfg.q_dim * D + (
            2 * cfg.head_dim if cfg.qk_norm else 0
        ) + 2 * D  # two norms per block

    def mlp_params(f: int) -> int:
        if cfg.act == "silu":
            return 3 * D * f
        return 2 * D * f

    if cfg.family == "ssm":
        # mamba2: in_proj (D -> 2*d_inner + 2*G*N + H), conv, A/D, norm, out_proj
        d_in = cfg.d_inner
        H = cfg.n_ssm_heads
        G = 1  # single B/C group
        in_proj = D * (2 * d_in + 2 * G * cfg.ssm_state + H)
        conv = cfg.conv_width * (d_in + 2 * G * cfg.ssm_state)
        per_layer = in_proj + conv + 2 * H + d_in + d_in * D + D
        total += cfg.n_layers * per_layer
        return total

    if cfg.family == "hybrid":
        W = cfg.lru_width
        # RG-LRU block: in projs (2), conv, gates (2 diag-ish dense), out proj
        rglru = D * W * 2 + cfg.conv_width * W + 2 * W * W // 8 + W * D + 2 * W + 2 * D
        attn = attn_params()
        mlp = mlp_params(F) + D
        n_rec = sum(1 for i in range(cfg.n_layers)
                    if cfg.block_pattern[i % len(cfg.block_pattern)] == "rglru")
        n_att = cfg.n_layers - n_rec
        total += n_rec * (rglru + mlp) + n_att * (attn + mlp)
        return total

    for layer in range(cfg.n_layers):
        total += attn_params()
        is_moe = cfg.n_experts > 0 and (layer % cfg.moe_every == cfg.moe_every - 1)
        if is_moe:
            router = D * cfg.n_experts
            experts = cfg.n_experts if not active_only else cfg.experts_per_token
            total += router + experts * mlp_params(F)
            total += cfg.n_shared_experts * mlp_params(F)
        else:
            total += mlp_params(F)
    return total
