"""Architecture registry: --arch <id> → ModelConfig + shape-cell metadata."""
from __future__ import annotations

import importlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from .config import ModelConfig

# arch id → config module name under repro.configs
ARCHS: Dict[str, str] = {
    "qwen3-1.7b": "qwen3_1p7b",
    "granite-8b": "granite_8b",
    "phi4-mini-3.8b": "phi4_mini_3p8b",
    "llama3.2-3b": "llama3p2_3b",
    "hubert-xlarge": "hubert_xlarge",
    "internvl2-26b": "internvl2_26b",
    "mixtral-8x7b": "mixtral_8x7b",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "mamba2-1.3b": "mamba2_1p3b",
}

SHAPES: Dict[str, Dict[str, int]] = {
    "train_4k": {"seq_len": 4096, "global_batch": 256},
    "prefill_32k": {"seq_len": 32768, "global_batch": 32},
    "decode_32k": {"seq_len": 32768, "global_batch": 128},
    "long_500k": {"seq_len": 524288, "global_batch": 1},
}

STEP_KIND = {
    "train_4k": "train",
    "prefill_32k": "prefill",
    "decode_32k": "decode",
    "long_500k": "decode",
}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch_id]}")
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch_id]}")
    return mod.SMOKE_CONFIG


def cell_status(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """(runnable, reason-if-skipped) for an (arch × shape) cell."""
    kind = STEP_KIND[shape]
    if kind == "decode" and not cfg.has_decode:
        return False, "encoder-only arch has no decode step"
    if shape == "long_500k" and not cfg.is_subquadratic:
        return False, "pure full-attention arch; 500k context needs sub-quadratic attention"
    return True, ""


def all_cells() -> List[Tuple[str, str]]:
    return [(a, s) for a in ARCHS for s in SHAPES]
