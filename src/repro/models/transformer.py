"""Decoder/encoder transformer stack with optional interleaved MoE FFNs.

Covers: qwen3-1.7b, granite-8b, phi4-mini-3.8b, llama3.2-3b (dense causal),
hubert-xlarge (encoder, bidirectional), internvl2-26b backbone (dense causal),
mixtral-8x7b (MoE every layer, SWA), llama4-maverick (MoE every other layer +
shared expert).

Layers are stacked into scan *units* of ``moe_every`` consecutive layers so a
single compiled unit body serves the whole depth (small HLO, fast SPMD
partitioning on 512 devices).  Each unit body is rematerialized
(jax.checkpoint) for training memory.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.axes import shard
from .config import ModelConfig
from .layers import (
    Params,
    remat_wrap,
    apply_attention,
    apply_attention_decode,
    apply_mlp,
    apply_norm,
    attention_prefill_kv,
    init_attention,
    init_mlp,
    init_norm,
)
from .moe import apply_moe, init_moe_layer


def _unit_size(cfg: ModelConfig) -> int:
    return cfg.moe_every if cfg.n_experts > 0 else 1


def _n_units(cfg: ModelConfig) -> int:
    assert cfg.n_layers % _unit_size(cfg) == 0
    return cfg.n_layers // _unit_size(cfg)


def _layer_is_moe(cfg: ModelConfig, pos_in_unit: int) -> bool:
    # MoE occupies the last layer of each unit (llama4: dense, moe, dense, ...)
    return cfg.n_experts > 0 and pos_in_unit == _unit_size(cfg) - 1


def init_layer(cfg: ModelConfig, key, is_moe: bool) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "attn_norm": init_norm(cfg),
        "attn": init_attention(cfg, k1),
        "mlp_norm": init_norm(cfg),
    }
    if is_moe:
        p["moe"] = init_moe_layer(cfg, k2)
    else:
        p["mlp"] = init_mlp(cfg, k3)
    return p


def init_params(cfg: ModelConfig, key) -> Params:
    """Stacked params: every leaf gets a leading (n_units,) axis."""
    u = _unit_size(cfg)
    n_units = _n_units(cfg)
    keys = jax.random.split(key, n_units * u).reshape(n_units, u, 2)

    unit_params: List[Params] = []
    for pos in range(u):
        is_moe = _layer_is_moe(cfg, pos)
        per_unit = [init_layer(cfg, keys[i, pos], is_moe) for i in range(n_units)]
        unit_params.append(jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *per_unit))
    return {"units": unit_params}


def _apply_layer(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                 positions: jnp.ndarray, is_moe: bool) -> Tuple[jnp.ndarray, jnp.ndarray]:
    h = apply_attention(cfg, p["attn"], apply_norm(cfg, p["attn_norm"], x),
                        positions)
    x = x + h
    ffn_in = apply_norm(cfg, p["mlp_norm"], x)
    if is_moe:
        y, aux = apply_moe(cfg, p["moe"], ffn_in)
    else:
        y, aux = apply_mlp(cfg, p["mlp"], ffn_in), jnp.float32(0)
    return x + y, aux


def forward_hidden(cfg: ModelConfig, params: Params, x: jnp.ndarray,
                   positions: jnp.ndarray, *, remat: bool = True
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Run all layers. Returns (hidden (B,S,D), aux loss)."""
    u = _unit_size(cfg)

    def unit_body(carry, unit_p):
        x, aux = carry
        for pos in range(u):
            x, a = _apply_layer(cfg, unit_p[pos], x, positions,
                                _layer_is_moe(cfg, pos))
            aux = aux + a
        x = shard(x, "batch", None, None)
        return (x, aux), None

    body = remat_wrap(cfg, unit_body) if remat else unit_body
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0)),
                               tuple(params["units"]))
    return x, aux


# =============================================================================
# Inference: prefill + decode with per-layer KV caches
# =============================================================================

def cache_size_for(cfg: ModelConfig, max_len: int) -> int:
    if cfg.attention_kind in ("sliding", "local") and cfg.window > 0:
        return min(max_len, cfg.window)
    return max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    """KV caches stacked (n_units, unit, B, C, Hkv, Dh)."""
    C = cache_size_for(cfg, max_len)
    shape = (_n_units(cfg), _unit_size(cfg), batch, C, cfg.n_kv_heads,
             cfg.head_dim)
    z = jnp.zeros(shape, jnp.dtype(cfg.param_dtype))
    return {"k": z, "v": z}


def prefill_hidden(cfg: ModelConfig, params: Params, x: jnp.ndarray,
                   positions: jnp.ndarray, cache: Params
                   ) -> Tuple[jnp.ndarray, Params]:
    """Forward + populate caches. Returns (hidden, cache)."""
    u = _unit_size(cfg)
    C = cache["k"].shape[3]

    def unit_body(x, unit_p):
        ks, vs = [], []
        for pos in range(u):
            p = unit_p[pos]
            h_in = apply_norm(cfg, p["attn_norm"], x)
            k, v = attention_prefill_kv(cfg, p["attn"], h_in, positions, C)
            ks.append(k)
            vs.append(v)
            x, _ = _apply_layer(cfg, p, x, positions, _layer_is_moe(cfg, pos))
        x = shard(x, "batch", None, None)
        return x, (jnp.stack(ks), jnp.stack(vs))

    x, (k_all, v_all) = jax.lax.scan(unit_body, x, tuple(params["units"]))
    return x, {"k": k_all, "v": v_all}


def decode_hidden(cfg: ModelConfig, params: Params, cache: Params,
                  x_t: jnp.ndarray, pos: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, Params]:
    """One token through all layers. x_t: (B,1,D), pos: (B,)."""
    u = _unit_size(cfg)

    def unit_body(x, inp):
        unit_p, kc_u, vc_u = inp
        new_k, new_v = [], []
        for p_in_u in range(u):
            p = unit_p[p_in_u]
            h_in = apply_norm(cfg, p["attn_norm"], x)
            h, kc, vc = apply_attention_decode(
                cfg, p["attn"], h_in, pos, kc_u[p_in_u], vc_u[p_in_u])
            new_k.append(kc)
            new_v.append(vc)
            x = x + h
            ffn_in = apply_norm(cfg, p["mlp_norm"], x)
            if _layer_is_moe(cfg, p_in_u):
                y, _ = apply_moe(cfg, p["moe"], ffn_in)
            else:
                y = apply_mlp(cfg, p["mlp"], ffn_in)
            x = x + y
        return x, (jnp.stack(new_k), jnp.stack(new_v))

    x, (k_all, v_all) = jax.lax.scan(
        unit_body, x_t, (tuple(params["units"]), cache["k"], cache["v"]))
    return x, {"k": k_all, "v": v_all}
