"""Token-choice top-k Mixture-of-Experts with a unified EP×FP sharding scheme.

Covers mixtral-8x7b (8 experts, top-2, every layer) and llama4-maverick
(128 experts, top-1, every other layer, + shared expert).

Sharding design (DESIGN.md §4): the `model` mesh axis (size TP) is factored
into ``ep × fp`` where ``ep = gcd(E, TP)`` shards the expert dim and ``fp``
shards each expert's FFN hidden dim.  Expert weights are stored pre-blocked as
``(TP, E/ep, D, F/fp)`` so a single ``P('model', ...)`` in_spec hands every
shard exactly its expert/F-slice block:

* llama4 (E=128, TP=16): ep=16, fp=1  → true expert parallelism, 8 experts/shard
* mixtral (E=8,  TP=16): ep=8,  fp=2  → EP over 8 × tensor-split FFN over 2

Inside shard_map, tokens are replicated over `model`; each shard gathers the
tokens routed to its local experts into a fixed-capacity buffer (capacity
dropping, Switch-style), runs the expert FFN on its F-slice, scatters partial
outputs back, and one psum over `model` combines everything (this psum is the
layer's EP collective).  The D dim of expert weights is additionally sharded
over `data` (FSDP); the explicit all_gather over `data` inside the shard_map
is the FSDP parameter gather.

Without a mesh (smoke tests) the same math runs unsharded via `_moe_compute`.
"""
from __future__ import annotations

import math
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.6: top-level API, replication check renamed to check_vma
    _shard_map = jax.shard_map
    _SHARD_MAP_KW = {"check_vma": False}
except AttributeError:  # jax <= 0.5: experimental module, check_rep
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_KW = {"check_rep": False}

from repro.parallel.axes import current_mesh, current_rules
from .config import ModelConfig
from .layers import Params, _normal, cdt, dt, init_mlp, apply_mlp


def _ep_fp(cfg: ModelConfig, tp: int) -> Tuple[int, int]:
    ep = math.gcd(cfg.n_experts, tp)
    fp = tp // ep
    return ep, fp


def init_moe_layer(cfg: ModelConfig, key, tp_hint: int = 16) -> Params:
    """Expert weights stored in the (TP, E/ep, D, F/fp) blocked layout.

    ``tp_hint`` fixes the blocking at init; running on a mesh with a different
    model-axis size requires re-blocking (checkpoint manager handles that).
    """
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ep, fp = _ep_fp(cfg, tp_hint)
    e_loc, f_loc = E // ep, F // fp
    k_r, k_g, k_u, k_d, k_s = jax.random.split(key, 5)
    out_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    p = {
        "router": _normal(k_r, (D, E), 0.02, jnp.float32),
        "w_gate": _normal(k_g, (tp_hint, e_loc, D, f_loc), 0.02, dt(cfg)),
        "w_up": _normal(k_u, (tp_hint, e_loc, D, f_loc), 0.02, dt(cfg)),
        "w_down": _normal(k_d, (tp_hint, e_loc, f_loc, D), out_scale, dt(cfg)),
    }
    if cfg.n_shared_experts > 0:
        p["shared"] = init_mlp(cfg, k_s, d_ff=cfg.n_shared_experts * cfg.d_ff)
    return p


def _route(cfg: ModelConfig, router: jnp.ndarray, x2d: jnp.ndarray
           ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (expert_idx (T,k), combine_weights (T,k) f32, aux_loss scalar)."""
    logits = (x2d.astype(jnp.float32) @ router).astype(jnp.float32)  # (T, E)
    k = cfg.experts_per_token
    vals, idx = jax.lax.top_k(logits, k)
    weights = jax.nn.softmax(vals, axis=-1)
    # Switch-style load-balancing aux + router z-loss
    probs = jax.nn.softmax(logits, axis=-1)
    me = probs.mean(axis=0)                                  # (E,)
    ce = jnp.zeros((cfg.n_experts,), jnp.float32)
    ce = ce.at[idx.reshape(-1)].add(1.0) / (x2d.shape[0] * k)
    aux = cfg.n_experts * jnp.sum(me * ce) * cfg.router_aux_coef
    zloss = 1e-4 * jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return idx, weights, aux + zloss


def _capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(math.ceil(n_tokens * cfg.experts_per_token * cfg.capacity_factor
                      / cfg.n_experts))
    return max(4, c)


def _dispatch_indices(
    cfg: ModelConfig, idx: jnp.ndarray, e_lo: jnp.ndarray, e_hi: jnp.ndarray,
    n_local: int, capacity: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Compute buffer positions for assignments routed to local experts.

    idx: (T, k) global expert ids.  Local experts are [e_lo, e_hi).
    Returns (flat buffer position (T*k,) int32 with -1 for non-local/overflow,
             local expert id per assignment (T*k,)).
    """
    T, k = idx.shape
    flat = idx.reshape(-1)
    local = (flat >= e_lo) & (flat < e_hi)
    loc_e = jnp.where(local, flat - e_lo, n_local)  # overflow bucket n_local
    onehot = jax.nn.one_hot(loc_e, n_local + 1, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1
    my_pos = jnp.take_along_axis(pos, loc_e[:, None], axis=1)[:, 0]
    ok = local & (my_pos < capacity)
    buf_pos = jnp.where(ok, loc_e * capacity + my_pos, -1)
    return buf_pos, loc_e


def _expert_ffn(cfg: ModelConfig, wg, wu, wd, buf: jnp.ndarray) -> jnp.ndarray:
    """buf: (E_loc, C, D) -> (E_loc, C, D) through each expert's (sliced) FFN."""
    g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(cdt(cfg)))
    u = jnp.einsum("ecd,edf->ecf", buf, wu.astype(cdt(cfg)))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(cdt(cfg)) * u
    return jnp.einsum("ecf,efd->ecd", h, wd.astype(cdt(cfg)))


def _moe_shard_body(cfg: ModelConfig, capacity: int, e_loc: int, fp: int,
                    axis_names: Tuple[str, ...], gather_weights: bool,
                    tokens_data_sharded: bool = True):
    """Returns the per-shard function for shard_map.

    Two data-movement modes, auto-selected by apply_moe (napkin math over
    weight-gather vs. activation-psum bytes):

    * ``gather_weights=True`` (token-heavy, e.g. training): FSDP all-gather
      the expert weights over `data` once per layer and compute locally —
      the gather amortizes over tens of thousands of tokens.
    * ``gather_weights=False`` (token-light, e.g. decode): weights never move.
      The (tiny) token batch is first all-gathered over `data` so every shard
      holds the SAME tokens, each shard computes up/gate partials with its
      D-slice of the weights, the partials are psum'd over `data`, the down
      projection emits this shard's D-slice which is all-gathered back, and
      each shard finally slices out its own batch rows.  For 400B-scale
      decode this moves ~MBs of activations instead of ~GBs of experts.
    """

    def body(x_loc, router, wg, wu, wd):
        # x_loc: (B_loc, S, D) — sharded over data/pod (batch), replicated
        # over model.  wg/wu: (1, e_loc, D/dp, f_loc); wd: (1, e_loc, f_loc,
        # D/dp) — this shard's expert block, D sharded over `data` (FSDP).
        B_loc, S, D = x_loc.shape
        x2d = x_loc.reshape(-1, D)
        T = x2d.shape[0]
        if not gather_weights and tokens_data_sharded:
            # weight-stationary mode: all shards must see the same tokens
            x2d = jax.lax.all_gather(x2d, "data", axis=0, tiled=True)
        T_eff = x2d.shape[0]
        idx, weights, aux = _route(cfg, router, x2d)
        shard_id = jax.lax.axis_index("model")
        ep_group = shard_id // fp
        e_lo = ep_group * e_loc
        cap = capacity if gather_weights else capacity * (T_eff // max(T, 1))
        buf_pos, _ = _dispatch_indices(cfg, idx, e_lo, e_lo + e_loc, e_loc,
                                       cap)
        k = cfg.experts_per_token
        # gather tokens into the capacity buffer (dropped/-1 -> scratch row)
        safe_pos = jnp.where(buf_pos >= 0, buf_pos, e_loc * cap)
        buf = jnp.zeros((e_loc * cap + 1, D), cdt(cfg))
        src = jnp.repeat(x2d, k, axis=0).astype(cdt(cfg))
        buf = buf.at[safe_pos].set(src)
        buf = buf[:-1].reshape(e_loc, cap, D)

        if gather_weights:
            wg_f = jax.lax.all_gather(wg[0], "data", axis=1, tiled=True)
            wu_f = jax.lax.all_gather(wu[0], "data", axis=1, tiled=True)
            wd_f = jax.lax.all_gather(wd[0], "data", axis=2, tiled=True)
            out_buf = _expert_ffn(cfg, wg_f, wu_f, wd_f, buf).reshape(-1, D)
        else:
            # weight-stationary: contract this shard's D-slice, psum partials
            # (psum of a literal == axis size; lax.axis_size is jax >= 0.6)
            n_dp = (jax.lax.axis_size("data")
                    if hasattr(jax.lax, "axis_size")
                    else jax.lax.psum(1, "data"))
            d_loc = D // n_dp
            d_lo = jax.lax.axis_index("data") * d_loc
            buf_d = jax.lax.dynamic_slice_in_dim(buf, d_lo, d_loc, axis=2)
            g = jnp.einsum("ecd,edf->ecf", buf_d, wg[0].astype(cdt(cfg)))
            u = jnp.einsum("ecd,edf->ecf", buf_d, wu[0].astype(cdt(cfg)))
            gu = jax.lax.psum(
                jnp.stack([g, u]).astype(jnp.float32), "data")  # partial→full
            h = (jax.nn.silu(gu[0]) * gu[1]).astype(cdt(cfg))
            out_d = jnp.einsum("ecf,efd->ecd", h, wd[0].astype(cdt(cfg)))
            out_buf = jax.lax.all_gather(
                out_d, "data", axis=2, tiled=True).reshape(-1, D)

        out_buf = jnp.concatenate([out_buf, jnp.zeros((1, D), out_buf.dtype)])
        # combine: weighted scatter back to token order
        gathered = out_buf[jnp.where(buf_pos >= 0, buf_pos, e_loc * cap)]
        w_flat = weights.reshape(-1, 1).astype(jnp.float32)
        w_flat = jnp.where((buf_pos >= 0)[:, None], w_flat, 0.0)
        contrib = (gathered.astype(jnp.float32) * w_flat).reshape(T_eff, k, D)
        y = contrib.sum(axis=1)
        # bf16 on the wire: the psum over `model` carries the combined expert
        # outputs; f32 buys nothing after the f32 combine-weight multiply
        y = jax.lax.psum(y.astype(cdt(cfg)), "model")
        if not gather_weights and tokens_data_sharded:
            # slice back this data shard's own rows
            y = jax.lax.dynamic_slice_in_dim(
                y, jax.lax.axis_index("data") * T, T, axis=0)
        # aux varies over data shards (different tokens) → make it a true
        # global mean so the out_spec P() (replicated) is sound
        aux = jax.lax.pmean(aux, axis_name=axis_names)
        return y.reshape(B_loc, S, D).astype(x_loc.dtype), aux

    return body


def _moe_compute_local(cfg: ModelConfig, p: Params, x: jnp.ndarray
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-device path: all experts local, same capacity semantics."""
    B, S, D = x.shape
    x2d = x.reshape(-1, D)
    T = x2d.shape[0]
    idx, weights, aux = _route(cfg, p["router"], x2d)
    capacity = _capacity(cfg, T)
    E = cfg.n_experts
    # reassemble full expert weights from the blocked layout
    tp = p["w_gate"].shape[0]
    ep, fp = _ep_fp(cfg, tp)
    e_loc, f_loc = E // ep, cfg.d_ff // fp

    wg = jnp.concatenate(
        [p["w_gate"].reshape(ep, fp, e_loc, D, f_loc)[:, i] for i in range(fp)],
        axis=-1).reshape(E, D, cfg.d_ff)
    wu = jnp.concatenate(
        [p["w_up"].reshape(ep, fp, e_loc, D, f_loc)[:, i] for i in range(fp)],
        axis=-1).reshape(E, D, cfg.d_ff)
    wd = jnp.concatenate(
        [p["w_down"].reshape(ep, fp, e_loc, f_loc, D)[:, i] for i in range(fp)],
        axis=-2).reshape(E, cfg.d_ff, D)

    buf_pos, _ = _dispatch_indices(cfg, idx, jnp.int32(0), jnp.int32(E), E,
                                   capacity)
    k = cfg.experts_per_token
    safe_pos = jnp.where(buf_pos >= 0, buf_pos, E * capacity)
    buf = jnp.zeros((E * capacity + 1, D), cdt(cfg))
    buf = buf.at[safe_pos].set(jnp.repeat(x2d, k, axis=0).astype(cdt(cfg)))
    buf = buf[:-1].reshape(E, capacity, D)
    out_buf = _expert_ffn(cfg, wg, wu, wd, buf).reshape(-1, D)
    out_buf = jnp.concatenate([out_buf, jnp.zeros((1, D), out_buf.dtype)])
    gathered = out_buf[safe_pos]
    w_flat = weights.reshape(-1, 1).astype(jnp.float32)
    w_flat = jnp.where((buf_pos >= 0)[:, None], w_flat, 0.0)
    y = (gathered.astype(jnp.float32) * w_flat).reshape(T, k, D).sum(axis=1)
    return y.reshape(B, S, D).astype(x.dtype), aux


def apply_moe(cfg: ModelConfig, p: Params, x: jnp.ndarray
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """MoE FFN. Returns (y, aux_loss). Adds shared expert if configured."""
    mesh = current_mesh()
    rules = current_rules()
    use_shard_map = (
        mesh is not None and rules is not None
        and "model" in mesh.axis_names and "data" in mesh.axis_names
        and p["w_gate"].shape[0] == mesh.shape["model"]
    )
    if use_shard_map:
        tp = mesh.shape["model"]
        ep, fp = _ep_fp(cfg, tp)
        e_loc = cfg.n_experts // ep
        B, S, D = x.shape
        batch_axes = rules.resolve("batch")
        if isinstance(batch_axes, str):
            batch_axes = (batch_axes,)
        n_batch_shards = 1
        for a in (batch_axes or ()):
            if a is not None:
                n_batch_shards *= mesh.shape[a]
        if batch_axes is None or B % n_batch_shards != 0:
            # tiny/odd batches (e.g. long-context decode, B=1): replicate
            # tokens over the DP axes; EP still splits the expert work
            batch_axes = None
            n_batch_shards = 1
        t_loc = (B // n_batch_shards) * S
        capacity = _capacity(cfg, t_loc)
        # napkin math: weight-gather bytes vs weight-stationary bytes per
        # layer.  Stationary mode pays: the token all-gather over data (every
        # shard needs the same tokens), the g+u partial psum (f32, ring 2x),
        # and n_dp-fold compute replication is tolerated only when the token
        # count is tiny — all captured by scaling with T_eff = t_loc * n_dp.
        n_dp = mesh.shape["data"]
        f_loc = cfg.d_ff // fp
        gather_bytes = 3 * e_loc * D * f_loc * 2            # 3 weight mats bf16
        cap_eff = capacity * n_dp
        act_bytes = (2 * e_loc * cap_eff * f_loc * 4 * 2    # g+u psum, f32 ring
                     + 2 * t_loc * n_dp * D * 2)            # token gather + out
        gather_weights = gather_bytes * (n_dp - 1) / n_dp < act_bytes
        force = os.environ.get("REPRO_MOE_FORCE_GATHER")
        if force is not None and force != "":
            gather_weights = force == "1"
        tokens_data_sharded = False
        for a in (batch_axes or ()):
            if a == "data":
                tokens_data_sharded = True
        body = _moe_shard_body(cfg, capacity, e_loc, fp,
                               tuple(mesh.axis_names), gather_weights,
                               tokens_data_sharded)
        xspec = P(batch_axes, None, None)
        wspec = P("model", None, "data", None)
        wdspec = P("model", None, None, "data")
        y, aux = _shard_map(
            body, mesh=mesh,
            in_specs=(xspec, P(), wspec, wspec, wdspec),
            out_specs=(xspec, P()),
            **_SHARD_MAP_KW,
        )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    else:
        y, aux = _moe_compute_local(cfg, p, x)
    if "shared" in p:
        y = y + apply_mlp(cfg, p["shared"], x)
    return y, aux
