"""Mamba-2 (SSD, state-space duality) — attention-free LM.

Block: in_proj → [z gate | x | B | C | dt] → depthwise causal conv over
(x,B,C) → SSD scan → gated RMSNorm → out_proj.  The SSD runs through
kernels.ops.ssd_scan (chunked dual form) with a Pallas kernel on TPU.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.parallel.axes import gather_weight, shard
from .config import ModelConfig
from .layers import (Params, _normal, apply_norm, cdt, dt, init_norm,
                     remat_wrap)

N_GROUPS = 1  # single B/C group (mamba2-1.3b default)


def init_block(cfg: ModelConfig, key) -> Params:
    D = cfg.d_model
    d_in = cfg.d_inner
    H = cfg.n_ssm_heads
    N = cfg.ssm_state
    K = cfg.conv_width
    conv_dim = d_in + 2 * N_GROUPS * N
    k1, k2, k3 = jax.random.split(key, 3)
    out_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    # dt bias init: softplus^-1 of dt in [1e-3, 1e-1] (mamba2 default)
    u = jax.random.uniform(k3, (H,), jnp.float32)
    dt0 = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))
    return {
        "in_proj": _normal(k1, (D, 2 * d_in + 2 * N_GROUPS * N + H), 0.02,
                           dt(cfg)),
        "conv_w": _normal(k2, (K, conv_dim), 0.02, dt(cfg)),
        "conv_b": jnp.zeros((conv_dim,), dt(cfg)),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "dt_bias": dt_bias,
        "d_skip": jnp.ones((H,), jnp.float32),
        "out_norm": jnp.ones((d_in,), dt(cfg)),
        "out_proj": _normal(jax.random.fold_in(key, 7), (d_in, D), out_scale,
                            dt(cfg)),
    }


def init_params(cfg: ModelConfig, key) -> Params:
    keys = jax.random.split(key, cfg.n_layers)
    blocks = [init_block(cfg, k) for k in keys]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)
    norms = [init_norm(cfg) for _ in range(cfg.n_layers)]
    stacked_norms = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *norms)
    return {"blocks": stacked, "norms": stacked_norms}


def _split_proj(cfg: ModelConfig, z_x_bc_dt: jnp.ndarray):
    d_in = cfg.d_inner
    N = cfg.ssm_state
    H = cfg.n_ssm_heads
    z = z_x_bc_dt[..., :d_in]
    xbc = z_x_bc_dt[..., d_in: d_in + d_in + 2 * N_GROUPS * N]
    dt_raw = z_x_bc_dt[..., -H:]
    return z, xbc, dt_raw


def _gated_out(cfg: ModelConfig, p: Params, y: jnp.ndarray, z: jnp.ndarray
               ) -> jnp.ndarray:
    """Gated RMSNorm + out projection. y, z: (..., d_inner)."""
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    ms = (yf * yf).mean(-1, keepdims=True)
    yn = yf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["out_norm"].astype(jnp.float32)
    return jnp.einsum("...w,wd->...d", yn.astype(cdt(cfg)),
                      gather_weight(p["out_proj"]).astype(cdt(cfg)))


def _conv_full(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(K))
    return jax.nn.silu((y + b.astype(x.dtype)).astype(jnp.float32)).astype(x.dtype)


def apply_block(cfg: ModelConfig, p: Params, x: jnp.ndarray
                ) -> jnp.ndarray:
    """(B,S,D) -> (B,S,D), full sequence."""
    B, S, D = x.shape
    H, P, N = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    proj = jnp.einsum("bsd,de->bse", x, gather_weight(p["in_proj"]).astype(cdt(cfg)))
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc = _conv_full(xbc, p["conv_w"], p["conv_b"])
    xs = xbc[..., :cfg.d_inner]
    Bmat = xbc[..., cfg.d_inner:cfg.d_inner + N]
    Cmat = xbc[..., cfg.d_inner + N:]
    dt_v = jax.nn.softplus(dt_raw.astype(jnp.float32)
                           + p["dt_bias"].astype(jnp.float32))
    xh = xs.reshape(B, S, H, P)
    xh = shard(xh, "batch", None, "ssm_heads", None)
    y, _ = ops.ssd_scan(xh, dt_v, -jnp.exp(p["a_log"]), Bmat, Cmat,
                        chunk=cfg.ssm_chunk)
    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, cfg.d_inner).astype(cdt(cfg))
    out = _gated_out(cfg, p, y, z)
    return shard(out, "batch", None, None)


def forward_hidden(cfg: ModelConfig, params: Params, x: jnp.ndarray,
                   positions: jnp.ndarray, *, remat: bool = True
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    def body(x, inp):
        p_block, p_norm = inp
        x = x + apply_block(cfg, p_block, apply_norm(cfg, p_norm, x))
        return shard(x, "batch", None, None), None

    body = remat_wrap(cfg, body) if remat else body
    x, _ = jax.lax.scan(body, x, (params["blocks"], params["norms"]))
    return x, jnp.float32(0)


# =============================================================================
# Inference: recurrent state (no KV cache)
# =============================================================================

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    H, P, N = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv_dim = cfg.d_inner + 2 * N_GROUPS * N
    return {
        "ssm": jnp.zeros((cfg.n_layers, batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.conv_width - 1, conv_dim),
                          jnp.float32),
    }


def _block_prefill(cfg: ModelConfig, p: Params, x: jnp.ndarray):
    """Forward + final state for one block."""
    B, S, D = x.shape
    H, P, N = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    proj = jnp.einsum("bsd,de->bse", x, gather_weight(p["in_proj"]).astype(cdt(cfg)))
    z, xbc, dt_raw = _split_proj(cfg, proj)
    conv_tail = xbc[:, -(cfg.conv_width - 1):].astype(jnp.float32)
    xbc = _conv_full(xbc, p["conv_w"], p["conv_b"])
    xs = xbc[..., :cfg.d_inner]
    Bmat = xbc[..., cfg.d_inner:cfg.d_inner + N]
    Cmat = xbc[..., cfg.d_inner + N:]
    dt_v = jax.nn.softplus(dt_raw.astype(jnp.float32)
                           + p["dt_bias"].astype(jnp.float32))
    xh = xs.reshape(B, S, H, P)
    y, h_final = ops.ssd_scan(xh, dt_v, -jnp.exp(p["a_log"]), Bmat, Cmat,
                              chunk=cfg.ssm_chunk)
    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, cfg.d_inner).astype(cdt(cfg))
    out = _gated_out(cfg, p, y, z)
    return out, h_final, conv_tail


def prefill_hidden(cfg: ModelConfig, params: Params, x: jnp.ndarray,
                   positions: jnp.ndarray, cache: Params
                   ) -> Tuple[jnp.ndarray, Params]:
    def body(x, inp):
        p_block, p_norm = inp
        out, h_final, conv_tail = _block_prefill(
            cfg, p_block, apply_norm(cfg, p_norm, x))
        return x + out, (h_final, conv_tail)

    x, (ssm, conv) = jax.lax.scan(body, x, (params["blocks"], params["norms"]))
    return x, {"ssm": ssm, "conv": conv}


def decode_hidden(cfg: ModelConfig, params: Params, cache: Params,
                  x_t: jnp.ndarray, pos: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, Params]:
    H, P, N = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state

    def body(x, inp):
        p_block, p_norm, h, conv_tail = inp
        B = x.shape[0]
        h_in = apply_norm(cfg, p_norm, x)
        proj = jnp.einsum("bsd,de->bse", h_in, p_block["in_proj"].astype(cdt(cfg)))
        z, xbc, dt_raw = _split_proj(cfg, proj)
        # conv with carried tail
        K = cfg.conv_width
        xp = jnp.concatenate([conv_tail.astype(xbc.dtype), xbc], axis=1)
        yc = sum(xp[:, i:i + 1] * p_block["conv_w"][i].astype(xbc.dtype)
                 for i in range(K))
        yc = jax.nn.silu((yc + p_block["conv_b"].astype(xbc.dtype))
                         .astype(jnp.float32)).astype(xbc.dtype)
        new_tail = jnp.concatenate([conv_tail[:, 1:], xbc.astype(jnp.float32)],
                                   axis=1)
        xs = yc[..., :cfg.d_inner]
        Bmat = yc[..., cfg.d_inner:cfg.d_inner + N]
        Cmat = yc[..., cfg.d_inner + N:]
        dt_v = jax.nn.softplus(dt_raw.astype(jnp.float32)
                               + p_block["dt_bias"].astype(jnp.float32))
        xh = xs.reshape(B, H, P)
        y, h_new = ops.ssd_decode_step(xh, dt_v[:, 0], -jnp.exp(p_block["a_log"]),
                                       Bmat[:, 0], Cmat[:, 0], h)
        y = y + p_block["d_skip"].astype(jnp.float32)[None, :, None] \
            * xh.astype(jnp.float32)
        y = y.reshape(B, 1, cfg.d_inner).astype(cdt(cfg))
        out = _gated_out(cfg, p_block, y, z)
        return x + out, (h_new, new_tail)

    x, (ssm, conv) = jax.lax.scan(
        body, x_t, (params["blocks"], params["norms"], cache["ssm"],
                    cache["conv"]))
    return x, {"ssm": ssm, "conv": conv}
