"""RecurrentGemma / Griffin: RG-LRU recurrent blocks + local attention, 1:2.

Block pattern (rglru, rglru, attn) repeats; every temporal-mixing block is
followed by a SwiGLU MLP.  The RG-LRU recurrence runs through
kernels.ops.rglru_scan (associative scan on CPU/dry-run, Pallas kernel on TPU).

Layers that don't fit a whole pattern repeat (38 = 12×3 + 2) are appended as
individually-applied trailing blocks.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.parallel.axes import shard
from .config import ModelConfig
from .layers import (
    Params,
    _normal,
    remat_wrap,
    apply_attention,
    apply_attention_decode,
    apply_mlp,
    apply_norm,
    attention_prefill_kv,
    cdt,
    dt,
    init_attention,
    init_mlp,
    init_norm,
)

N_DIAG_BLOCKS = 8  # RG-LRU gate matrices are block-diagonal (Griffin §2.4)
C_RGLRU = 8.0      # decay sharpness constant


# =============================================================================
# RG-LRU temporal-mixing block
# =============================================================================

def init_rglru_block(cfg: ModelConfig, key) -> Params:
    W = cfg.lru_width
    D = cfg.d_model
    kb = W // N_DIAG_BLOCKS
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    out_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    # Λ init so that a = exp(-c softplus(Λ) σ(...)) starts near 0.9..0.999
    lam = jnp.log(jnp.expm1(-jnp.log(
        jnp.linspace(0.9, 0.999, W, dtype=jnp.float32)) / C_RGLRU))
    return {
        "w_x": _normal(k1, (D, W), 0.02, dt(cfg)),       # input branch
        "w_gate": _normal(k2, (D, W), 0.02, dt(cfg)),    # gelu gate branch
        "conv_w": _normal(k3, (cfg.conv_width, W), 0.02, dt(cfg)),
        "conv_b": jnp.zeros((W,), dt(cfg)),
        "w_a": _normal(k4, (N_DIAG_BLOCKS, kb, kb), 0.02, dt(cfg)),  # recurrence gate
        "b_a": jnp.zeros((W,), dt(cfg)),
        "w_i": _normal(k5, (N_DIAG_BLOCKS, kb, kb), 0.02, dt(cfg)),  # input gate
        "b_i": jnp.zeros((W,), dt(cfg)),
        "lam": lam,                                       # (W,) f32
        "w_out": _normal(k6, (W, D), out_scale, dt(cfg)),
    }


def _block_diag_matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x: (..., W), w: (nb, kb, kb) block-diagonal — (..., W) out."""
    nb, kb, _ = w.shape
    xs = x.reshape(*x.shape[:-1], nb, kb)
    y = jnp.einsum("...nk,nkj->...nj", xs, w)
    return y.reshape(*x.shape)


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 tail: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Depthwise causal conv over seq. x: (B,S,W), w: (K,W).

    ``tail``: (B, K-1, W) carried context from previous tokens (decode)."""
    K = w.shape[0]
    if tail is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(K))
    return y + b.astype(x.dtype)


def _rglru_gates(cfg: ModelConfig, p: Params, xc: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (a_log (B,S,W) <= 0, gated input (B,S,W))."""
    r = jax.nn.sigmoid(_block_diag_matmul(xc, p["w_a"]).astype(jnp.float32)
                       + p["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(_block_diag_matmul(xc, p["w_i"]).astype(jnp.float32)
                       + p["b_i"].astype(jnp.float32))
    a_log = -C_RGLRU * jax.nn.softplus(p["lam"]) * r  # (B,S,W), <= 0
    return a_log, (i * xc.astype(jnp.float32))


def apply_rglru_block(cfg: ModelConfig, p: Params, x: jnp.ndarray
                      ) -> jnp.ndarray:
    """Full-sequence RG-LRU mixing. x: (B,S,D) -> (B,S,D)."""
    gate = jax.nn.gelu(
        jnp.einsum("bsd,dw->bsw", x, p["w_gate"].astype(cdt(cfg)))
        .astype(jnp.float32))
    xb = jnp.einsum("bsd,dw->bsw", x, p["w_x"].astype(cdt(cfg)))
    xb = shard(xb, "batch", None, "ffn")
    xc = _causal_conv(xb, p["conv_w"], p["conv_b"])
    a_log, gated = _rglru_gates(cfg, p, xc)
    h, _ = ops.rglru_scan(gated.astype(cdt(cfg)), a_log)
    y = h.astype(jnp.float32) * gate
    out = jnp.einsum("bsw,wd->bsd", y.astype(cdt(cfg)),
                     p["w_out"].astype(cdt(cfg)))
    return shard(out, "batch", None, None)


def rglru_block_decode(cfg: ModelConfig, p: Params, x_t: jnp.ndarray,
                       state: Dict[str, jnp.ndarray]
                       ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One-token RG-LRU step. x_t: (B,1,D); state: {h (B,W) f32, conv (B,K-1,W)}."""
    gate = jax.nn.gelu(
        jnp.einsum("bsd,dw->bsw", x_t, p["w_gate"].astype(cdt(cfg)))
        .astype(jnp.float32))
    xb = jnp.einsum("bsd,dw->bsw", x_t, p["w_x"].astype(cdt(cfg)))
    xc = _causal_conv(xb, p["conv_w"], p["conv_b"], tail=state["conv"])
    new_conv = jnp.concatenate([state["conv"][:, 1:], xb.astype(jnp.float32)],
                               axis=1)
    a_log, gated = _rglru_gates(cfg, p, xc)
    h = ops.rglru_decode_step(gated[:, 0], a_log[:, 0], state["h"])
    y = h[:, None].astype(jnp.float32) * gate
    out = jnp.einsum("bsw,wd->bsd", y.astype(cdt(cfg)),
                     p["w_out"].astype(cdt(cfg)))
    return out, {"h": h, "conv": new_conv}


# =============================================================================
# Hybrid stack
# =============================================================================

def _pattern(cfg: ModelConfig) -> Tuple[str, ...]:
    return cfg.block_pattern


def _n_units(cfg: ModelConfig) -> int:
    return cfg.n_layers // len(_pattern(cfg))


def _n_tail(cfg: ModelConfig) -> int:
    return cfg.n_layers % len(_pattern(cfg))


def init_layer(cfg: ModelConfig, key, kind: str) -> Params:
    k1, k2 = jax.random.split(key)
    p = {"mix_norm": init_norm(cfg), "mlp_norm": init_norm(cfg),
         "mlp": init_mlp(cfg, k2)}
    if kind == "attn":
        p["attn"] = init_attention(cfg, k1)
    else:
        p["rglru"] = init_rglru_block(cfg, k1)
    return p


def init_params(cfg: ModelConfig, key) -> Params:
    pat = _pattern(cfg)
    n_units, n_tail = _n_units(cfg), _n_tail(cfg)
    keys = jax.random.split(key, cfg.n_layers)
    unit_params: List[Params] = []
    for pos, kind in enumerate(pat):
        per_unit = [init_layer(cfg, keys[i * len(pat) + pos], kind)
                    for i in range(n_units)]
        unit_params.append(jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *per_unit))
    tail = [init_layer(cfg, keys[n_units * len(pat) + t], pat[t % len(pat)])
            for t in range(n_tail)]
    return {"units": unit_params, "tail": tail}


def _apply_layer(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                 positions: jnp.ndarray, kind: str) -> jnp.ndarray:
    h_in = apply_norm(cfg, p["mix_norm"], x)
    if kind == "attn":
        h = apply_attention(cfg, p["attn"], h_in, positions,
                            window_override=cfg.window)
    else:
        h = apply_rglru_block(cfg, p["rglru"], h_in)
    x = x + h
    x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["mlp_norm"], x))
    return x


def forward_hidden(cfg: ModelConfig, params: Params, x: jnp.ndarray,
                   positions: jnp.ndarray, *, remat: bool = True
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    pat = _pattern(cfg)

    def unit_body(x, unit_p):
        for pos, kind in enumerate(pat):
            x = _apply_layer(cfg, unit_p[pos], x, positions, kind)
        return shard(x, "batch", None, None), None

    body = remat_wrap(cfg, unit_body) if remat else unit_body
    x, _ = jax.lax.scan(body, x, tuple(params["units"]))
    for t, p in enumerate(params["tail"]):
        x = _apply_layer(cfg, p, x, positions, pat[t % len(pat)])
    return x, jnp.float32(0)


# =============================================================================
# Inference state: attention ring caches + recurrent states
# =============================================================================

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    pat = _pattern(cfg)
    n_units, n_tail = _n_units(cfg), _n_tail(cfg)
    C = min(max_len, cfg.window) if cfg.window else max_len
    W = cfg.lru_width
    K = cfg.conv_width
    cache: Dict[str, Any] = {"units": [], "tail": []}
    for pos, kind in enumerate(pat):
        if kind == "attn":
            z = jnp.zeros((n_units, batch, C, cfg.n_kv_heads, cfg.head_dim),
                          jnp.dtype(cfg.param_dtype))
            cache["units"].append({"k": z, "v": z})
        else:
            cache["units"].append({
                "h": jnp.zeros((n_units, batch, W), jnp.float32),
                "conv": jnp.zeros((n_units, batch, K - 1, W), jnp.float32),
            })
    for t in range(n_tail):
        kind = pat[t % len(pat)]
        if kind == "attn":
            z = jnp.zeros((batch, C, cfg.n_kv_heads, cfg.head_dim),
                          jnp.dtype(cfg.param_dtype))
            cache["tail"].append({"k": z, "v": z})
        else:
            cache["tail"].append({
                "h": jnp.zeros((batch, W), jnp.float32),
                "conv": jnp.zeros((batch, K - 1, W), jnp.float32),
            })
    return cache


def prefill_hidden(cfg: ModelConfig, params: Params, x: jnp.ndarray,
                   positions: jnp.ndarray, cache: Params
                   ) -> Tuple[jnp.ndarray, Params]:
    """Sequential (layer-scanned) prefill that also fills caches/states."""
    pat = _pattern(cfg)
    C = None
    for c in cache["units"]:
        if "k" in c:
            C = c["k"].shape[2]

    def prefill_layer(x, p, kind):
        """One layer forward that also emits its cache/state (single pass)."""
        h_in = apply_norm(cfg, p["mix_norm"], x)
        if kind == "attn":
            k, v = attention_prefill_kv(cfg, p["attn"], h_in, positions, C)
            h = apply_attention(cfg, p["attn"], h_in, positions,
                                window_override=cfg.window)
            new_c = {"k": k, "v": v}
        else:
            rp = p["rglru"]
            gate = jax.nn.gelu(jnp.einsum(
                "bsd,dw->bsw", h_in, rp["w_gate"].astype(cdt(cfg))
            ).astype(jnp.float32))
            xb = jnp.einsum("bsd,dw->bsw", h_in, rp["w_x"].astype(cdt(cfg)))
            xc = _causal_conv(xb, rp["conv_w"], rp["conv_b"])
            a_log, gated = _rglru_gates(cfg, rp, xc)
            hs, h_last = ops.rglru_scan(gated.astype(cdt(cfg)), a_log)
            y = hs.astype(jnp.float32) * gate
            h = jnp.einsum("bsw,wd->bsd", y.astype(cdt(cfg)),
                           rp["w_out"].astype(cdt(cfg)))
            new_c = {
                "h": h_last.astype(jnp.float32),
                "conv": xb[:, -(cfg.conv_width - 1):].astype(jnp.float32),
            }
        x = x + h
        x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["mlp_norm"], x))
        return x, new_c

    def unit_body(x, unit_p):
        new_c = []
        for pos, kind in enumerate(pat):
            x, nc = prefill_layer(x, unit_p[pos], kind)
            new_c.append(nc)
        return x, tuple(new_c)

    x, caches = jax.lax.scan(unit_body, x, tuple(params["units"]))
    new_cache = {"units": list(caches), "tail": []}
    for t, p in enumerate(params["tail"]):
        kind = pat[t % len(pat)]
        x, nc = prefill_layer(x, p, kind)
        new_cache["tail"].append(nc)
    return x, new_cache


def decode_hidden(cfg: ModelConfig, params: Params, cache: Params,
                  x_t: jnp.ndarray, pos: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, Params]:
    pat = _pattern(cfg)

    def step_layer(x, p, c, kind):
        h_in = apply_norm(cfg, p["mix_norm"], x)
        if kind == "attn":
            h, kc, vc = apply_attention_decode(
                cfg, p["attn"], h_in, pos, c["k"], c["v"],
                window_override=cfg.window)
            new_c = {"k": kc, "v": vc}
        else:
            h, new_c = rglru_block_decode(cfg, p["rglru"], h_in, c)
        x = x + h
        x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg, p["mlp_norm"], x))
        return x, new_c

    def unit_body(x, inp):
        unit_p, unit_c = inp
        new_cs = []
        for i, kind in enumerate(pat):
            x, nc = step_layer(x, unit_p[i], unit_c[i], kind)
            new_cs.append(nc)
        return x, tuple(new_cs)

    x, caches = jax.lax.scan(
        unit_body, x_t, (tuple(params["units"]), tuple(cache["units"])))
    new_cache = {"units": list(caches), "tail": []}
    for t, p in enumerate(params["tail"]):
        kind = pat[t % len(pat)]
        x, nc = step_layer(x, p, cache["tail"][t], kind)
        new_cache["tail"].append(nc)
    return x, new_cache
