"""Unified LM facade over the model families.

One API for all 10 architectures:

* ``init_params``                    — full parameter pytree
* ``train_loss(cfg, params, batch)`` — scalar loss + metrics
* ``init_cache`` / ``prefill`` / ``decode_step`` — serving path

Batch layouts by family:
  dense/moe/hybrid/ssm : {"tokens": (B,S) i32, "labels": (B,S) i32}
  encoder (audio stub) : {"frames": (B,S,D) bf16, "labels": (B,S) i32}
  vlm (patch stub)     : {"tokens": (B,S_text) i32, "patches": (B,P,D) bf16,
                          "labels": (B,S_text) i32}
The VLM fuses patches before text (early fusion); S_text = seq_len - n_patches
so every assigned (arch × shape) cell keeps its exact total sequence length.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.axes import shard
from . import mamba2, rglru, transformer
from .config import ModelConfig
from .layers import (
    Params,
    apply_norm,
    chunked_softmax_xent,
    embed_tokens,
    init_embedding,
    init_norm,
    logits_for,
)

_FAMILY = {
    "dense": transformer,
    "moe": transformer,
    "encoder": transformer,
    "vlm": transformer,
    "hybrid": rglru,
    "ssm": mamba2,
}


def backbone(cfg: ModelConfig):
    return _FAMILY[cfg.family]


def init_params(cfg: ModelConfig, key) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "embed": init_embedding(cfg, k1),
        "backbone": backbone(cfg).init_params(cfg, k2),
        "final_norm": init_norm(cfg),
    }


def _embed_inputs(cfg: ModelConfig, params: Params, batch: Dict[str, Any]
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, Optional[jnp.ndarray]]:
    """Returns (x (B,S,D), positions (S,) or (B,S), labels or None)."""
    if cfg.frontend == "audio_frames":
        x = batch["frames"].astype(jnp.dtype(cfg.compute_dtype))
        labels = batch.get("labels")
    elif cfg.frontend == "vision_patches":
        tok = embed_tokens(cfg, params["embed"], batch["tokens"])
        patches = batch["patches"].astype(tok.dtype)
        x = jnp.concatenate([patches, tok], axis=1)  # early fusion
        labels = batch.get("labels")
        if labels is not None:
            # patch positions carry no LM loss
            pad = jnp.full(patches.shape[:2], -100, labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
    else:
        x = embed_tokens(cfg, params["embed"], batch["tokens"])
        labels = batch.get("labels")
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :].repeat(
        x.shape[0], axis=0)
    x = shard(x, "batch", None, None)
    return x, positions, labels


def train_loss(cfg: ModelConfig, params: Params, batch: Dict[str, Any]
               ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    x, positions, labels = _embed_inputs(cfg, params, batch)
    hidden, aux = backbone(cfg).forward_hidden(cfg, params["backbone"], x,
                                               positions, remat=True)
    hidden = apply_norm(cfg, params["final_norm"], hidden)
    loss_sum, n_valid = chunked_softmax_xent(cfg, params["embed"], hidden,
                                             labels)
    n_valid = jnp.maximum(n_valid, 1.0)
    xent = loss_sum / n_valid
    loss = xent + aux
    return loss, {"loss": loss, "xent": xent, "aux": aux, "tokens": n_valid}


# =============================================================================
# Serving
# =============================================================================

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    return backbone(cfg).init_cache(cfg, batch, max_len)


def prefill(cfg: ModelConfig, params: Params, batch: Dict[str, Any],
            max_len: int) -> Tuple[jnp.ndarray, Params]:
    """Run the prompt; returns (last-position logits (B,V), populated cache)."""
    x, positions, _ = _embed_inputs(cfg, params, batch)
    cache = init_cache(cfg, x.shape[0], max_len)
    hidden, cache = backbone(cfg).prefill_hidden(cfg, params["backbone"], x,
                                                 positions, cache)
    last = apply_norm(cfg, params["final_norm"], hidden[:, -1])
    return logits_for(cfg, params["embed"], last), cache


def decode_step(cfg: ModelConfig, params: Params, cache: Params,
                token: jnp.ndarray, pos: jnp.ndarray
                ) -> Tuple[jnp.ndarray, Params]:
    """One decode step. token: (B,) i32, pos: (B,) i32 absolute position.

    Returns (logits (B,V), updated cache)."""
    x_t = embed_tokens(cfg, params["embed"], token[:, None])
    x_t, cache = backbone(cfg).decode_hidden(cfg, params["backbone"], cache,
                                             x_t, pos)
    h = apply_norm(cfg, params["final_norm"], x_t[:, 0])
    return logits_for(cfg, params["embed"], h), cache
