"""Stable RNG-seed derivation from config content (not positional counters).

Every per-client and per-replicate seed used to be a positional offset
(``traffic.seed + client_index``, submission-order trial counters).  That
made seeds depend on *where* a config sat in a sweep or in what order trials
were submitted — exactly what a parallel runner shuffles.  Here seeds derive
from the sha256 of the config's canonical JSON plus a role salt and an
index, so:

* the same config produces the same seeds no matter how (or where) it runs;
* *execution-only* knobs — the config ``name``, the partition mode/worker
  count, and the traffic ``engine`` — are scrubbed before hashing, because
  two runs that differ only in how they are executed must stay bit-identical
  (the partition parity contract and the engine parity contract both lean on
  this);
* physics knobs (including ``traffic.seed`` itself) stay in the hash, so
  distinct experiments stay decorrelated.
"""
from __future__ import annotations

import hashlib
import json
from typing import Any, Dict

__all__ = ["EXECUTION_ONLY_KEYS", "scrub_execution_keys",
           "config_fingerprint", "derive_seed"]

# top-level config keys that select *how* a run executes, never *what* it
# simulates; they must not perturb any derived seed
EXECUTION_ONLY_KEYS = ("name", "partition", "partition_workers",
                       "partition_sanitize")


def scrub_execution_keys(cfg_dict: Dict[str, Any]) -> Dict[str, Any]:
    """A copy of a config dict with execution-only knobs removed (top-level
    ``name``/``partition``/``partition_workers``/``partition_sanitize`` and
    ``traffic.engine``)."""
    out = {k: v for k, v in cfg_dict.items() if k not in EXECUTION_ONLY_KEYS}
    traffic = out.get("traffic")
    if isinstance(traffic, dict):
        out["traffic"] = {k: v for k, v in traffic.items() if k != "engine"}
    return out


def config_fingerprint(cfg_dict: Dict[str, Any]) -> str:
    """sha256 hex digest of the scrubbed config's canonical JSON.

    Canonical == sorted keys, minimal separators — byte-stable across dict
    insertion orders and JSON round-trips.
    """
    canon = json.dumps(scrub_execution_keys(cfg_dict), sort_keys=True,
                       separators=(",", ":"))
    return hashlib.sha256(canon.encode("utf-8")).hexdigest()


def derive_seed(fingerprint: str, index: int, salt: str = "") -> int:
    """A stable 63-bit seed for role ``salt`` + ``index`` under one config.

    ``np.random.default_rng`` and ``random.Random`` both accept it; distinct
    (fingerprint, salt, index) triples give independent streams.
    """
    h = hashlib.sha256(
        f"{fingerprint}:{salt}:{int(index)}".encode("utf-8")).digest()
    return int.from_bytes(h[:8], "big") >> 1
