"""Stable RNG-seed derivation from config content (not positional counters).

Every per-client and per-replicate seed used to be a positional offset
(``traffic.seed + client_index``, submission-order trial counters).  That
made seeds depend on *where* a config sat in a sweep or in what order trials
were submitted — exactly what a parallel runner shuffles.  Here seeds derive
from the sha256 of the config's canonical JSON plus a role salt and an
index, so:

* the same config produces the same seeds no matter how (or where) it runs;
* *execution-only* knobs — the config ``name``, the partition mode/worker
  count, and the traffic ``engine`` — are scrubbed before hashing, because
  two runs that differ only in how they are executed must stay bit-identical
  (the partition parity contract and the engine parity contract both lean on
  this);
* physics knobs (including ``traffic.seed`` itself) stay in the hash, so
  distinct experiments stay decorrelated.
"""
from __future__ import annotations

import hashlib
import json
from typing import Any, Dict

__all__ = ["EXECUTION_ONLY_KEYS", "scrub_execution_keys",
           "config_fingerprint", "derive_seed"]

# top-level config keys that select *how* a run executes, never *what* it
# simulates; they must not perturb any derived seed
EXECUTION_ONLY_KEYS = ("name", "partition", "partition_workers",
                       "partition_sanitize")


# config keys that are *inert at their default value*: they were added after
# fingerprints already seeded real experiments, so when unset they are elided
# before hashing — a config that doesn't use the feature hashes (and seeds)
# exactly as it did before the feature existed.  Non-default values stay in
# the hash, keeping distinct experiments decorrelated.
_INERT_WHEN_NONE = ("node_switch", "client_switch")          # topology level
_INERT_SWITCH_WHEN_NONE = ("pipeline", "trunk")              # switch level
_CC_KEYS = ("cc_mode", "cc_window_ns", "cc_gain", "cc_min_gbps",
            "cc_increase_gbps", "cc_max_inflight")           # traffic level


def scrub_execution_keys(cfg_dict: Dict[str, Any]) -> Dict[str, Any]:
    """A copy of a config dict with execution-only knobs removed (top-level
    ``name``/``partition``/``partition_workers``/``partition_sanitize`` and
    ``traffic.engine``) and later-added feature knobs elided when inert
    (switch ``pipeline``/``trunk`` unset, ``cc_mode`` fixed, default
    two-switch placement)."""
    out = {k: v for k, v in cfg_dict.items() if k not in EXECUTION_ONLY_KEYS}
    for key in _INERT_WHEN_NONE:
        if key in out and out[key] is None:
            del out[key]
    traffic = out.get("traffic")
    if isinstance(traffic, dict):
        traffic = {k: v for k, v in traffic.items() if k != "engine"}
        if traffic.get("cc_mode", "fixed") == "fixed":
            # every cc_* knob is inert while cc is off
            traffic = {k: v for k, v in traffic.items() if k not in _CC_KEYS}
        out["traffic"] = traffic
    switch = out.get("switch")
    if isinstance(switch, dict):
        switch = dict(switch)
        for key in _INERT_SWITCH_WHEN_NONE:
            if switch.get(key) is None:
                switch.pop(key, None)
        out["switch"] = switch
    return out


def config_fingerprint(cfg_dict: Dict[str, Any]) -> str:
    """sha256 hex digest of the scrubbed config's canonical JSON.

    Canonical == sorted keys, minimal separators — byte-stable across dict
    insertion orders and JSON round-trips.
    """
    canon = json.dumps(scrub_execution_keys(cfg_dict), sort_keys=True,
                       separators=(",", ":"))
    return hashlib.sha256(canon.encode("utf-8")).hexdigest()


def derive_seed(fingerprint: str, index: int, salt: str = "") -> int:
    """A stable 63-bit seed for role ``salt`` + ``index`` under one config.

    ``np.random.default_rng`` and ``random.Random`` both accept it; distinct
    (fingerprint, salt, index) triples give independent streams.
    """
    h = hashlib.sha256(
        f"{fingerprint}:{salt}:{int(index)}".encode("utf-8")).digest()
    return int.from_bytes(h[:8], "big") >> 1
