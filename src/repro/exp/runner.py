"""``run_experiment(cfg) -> RunReport`` — the single entry point.

Every paper benchmark and example drives the dataplane through this function
(or through a :class:`~repro.exp.testbed.Testbed` it built itself when it
needs mid-run access to the server).  The traffic mode selects the drive:

* ``closed_loop`` — deterministic n-packet conservation run;
* ``open_loop``   — paced offered load for a fixed duration;
* ``msb``         — EtherLoadGen bandwidth-test mode (fresh testbed per
  trial, so no state leaks between rates), reporting the best sustainable
  trial with ``extras["msb_gbps"]``.
"""
from __future__ import annotations

from typing import Callable, List, Tuple

import numpy as np

from typing import Optional

from repro.core import (EpochRunInfo, EthDev, NetworkStack, PARTITIONED_REASON,
                        PartitionRunInfo, RunReport, TrafficPattern,
                        find_max_sustainable_bandwidth, run_epoch_sim)

from .config import ExperimentConfig, TopologyConfig
from .testbed import Testbed
from .topology import Cluster, run_partitioned_topology


def make_server_factory(
    cfg: ExperimentConfig,
) -> Callable[[], Tuple[NetworkStack, List[EthDev]]]:
    """Fresh-state ``() -> (server, devs)`` factory — what MSB searches and
    repeated-trial sweeps need (every call builds a brand-new testbed)."""

    def factory() -> Tuple[NetworkStack, List[EthDev]]:
        tb = Testbed.build(cfg)
        return tb.server, tb.devs

    return factory


def run_testbed(tb: Testbed) -> RunReport:
    """Drive an already-built testbed per its config's traffic mode
    (``closed_loop`` or ``open_loop``; ``msb`` needs fresh testbeds per trial
    — use :func:`run_experiment`).  ``cfg.traffic.sim_time`` selects virtual
    time (the testbed's SimClock, deterministic) vs. wall-clock pacing."""
    t = tb.cfg.traffic
    if t.mode == "closed_loop":
        rng = (np.random.default_rng(t.payload_seed)
               if t.payload_seed is not None else None)
        return tb.loadgen.run_closed_loop(
            tb.server, n_packets=t.n_packets, packet_size=t.packet_size,
            window=t.window, rng=rng, clock=tb.clock)
    if t.mode == "open_loop":
        pattern = TrafficPattern(rate_gbps=t.rate_gbps,
                                 packet_size=t.packet_size, kind=t.kind,
                                 burst_len=t.burst_len, seed=t.seed)
        if tb.clock is not None:
            if t.engine in ("epoch", "epoch-jit"):
                # bit-identical fast path; configs it cannot prove exact
                # (timers, DCA accumulate, custom stacks) fall back to the
                # event loop inside run_epoch_sim, so the report never changes
                return run_epoch_sim(tb.loadgen, tb.server, pattern,
                                     duration_s=t.duration_s, clock=tb.clock,
                                     sched=tb.sched,
                                     use_jax=(t.engine == "epoch-jit"))
            return tb.loadgen.run_sim(tb.server, pattern,
                                      duration_s=t.duration_s, clock=tb.clock,
                                      sched=tb.sched)
        return tb.loadgen.run(tb.server, pattern, duration_s=t.duration_s,
                              drain_timeout_s=t.drain_timeout_s)
    raise ValueError(f"run_testbed cannot drive traffic mode {t.mode!r}")


def run_experiment(cfg: ExperimentConfig) -> RunReport:
    """Build + run one experiment from config alone."""
    t = cfg.traffic
    if t.mode in ("closed_loop", "open_loop"):
        return run_testbed(Testbed.build(cfg))
    # msb: ramp + bisect over fresh testbeds
    gbps, reports = find_max_sustainable_bandwidth(
        make_server_factory(cfg),
        packet_size=t.packet_size,
        start_gbps=t.start_gbps,
        max_gbps=t.max_gbps,
        trial_s=t.trial_s,
        drop_tolerance_pct=t.drop_tolerance_pct,
        refine_iters=t.refine_iters,
        pattern_kind=t.kind,
        sim_time=t.sim_time,
        engine=t.engine,
    )
    good = [r for r in reports
            if r.drop_pct <= t.drop_tolerance_pct and r.received > 0]
    rep = max(good, key=lambda r: r.achieved_gbps) if good else RunReport()
    rep.extras["msb_gbps"] = gbps
    rep.extras["msb_trials"] = float(len(reports))
    return rep


def run_topology_experiment(cfg: TopologyConfig, *,
                            info: Optional[EpochRunInfo] = None,
                            partition_info: Optional[PartitionRunInfo] = None,
                            ) -> RunReport:
    """Build + run one multi-host topology (N clients → switch → nodes) from
    config alone; the merged RunReport carries per-switch-port
    drop/occupancy telemetry in ``extras``.

    ``cfg.partition`` selects the execution engine — the shared-clock loop
    or the epoch-windowed partitioned engines; the report is bit-identical
    either way (ineligible configs fall back, reason in ``partition_info``).
    Partitioned execution is an *event-loop* engine: if the traffic config
    also asked for the epoch fast path (``traffic.engine != "event"``), that
    request records a :data:`~repro.core.fastpath.PARTITIONED_REASON`
    fallback in ``info`` — the taxonomy composes instead of silently
    ignoring one knob."""
    if cfg.partition == "shared-clock":
        if partition_info is not None:
            partition_info.mode_requested = partition_info.mode_used = \
                "shared-clock"
            partition_info.n_workers = 1
        return Cluster.build(cfg).run()
    if info is not None and cfg.traffic.engine != "event":
        info.engine = cfg.traffic.engine
        info.fastpath = False
        info.fallback_reason = PARTITIONED_REASON
    return run_partitioned_topology(cfg, info=partition_info)
