"""Multi-host topology builder + driver: N node testbeds and N fabric-attached
load-generator clients around one :class:`~repro.core.switch.Switch`.

This is the SimBricks-style composition the ROADMAP called for: every node is
an independently-built model (its own :class:`~repro.core.packet.PacketPool`,
its own :class:`~repro.core.ethdev.EthDev`, its own server stack from the
same registry single-host testbeds use), and the pieces meet only on the
fabric — frames cross between address spaces as byte copies over modeled
wires.

The traffic shape is client/server: each client is a
:class:`~repro.core.loadgen.LoadGen` attached to a switch port through the
fabric primitives (``make_frame``/``complete_frame``), addressing one target
node (``TopologyConfig.target``, or per-client ``client_targets``).  The
target's stack echoes each frame back to its sender (macs + flow IPs
swapped), so every client measures true four-hop RTTs: uplink → switch
egress queue → server NIC/stack → and the same in reverse.  With N clients
on one target this is the classic **incast**: the switch egress port facing
the target saturates first, and losses show up in the *switch's* per-port
drop counters while every NIC stays loss-free — exactly the observable the
incast benchmark asserts.

Two execution engines share this module, selected by ``cfg.partition``:

* ``shared-clock`` — :meth:`Cluster.run`, the reference loop: ONE
  :class:`~repro.core.simclock.SimClock`, one
  :class:`~repro.core.simclock.EventScheduler`, one round per virtual
  instant across every component.
* ``partitioned`` / ``partitioned-mp`` — :func:`run_partitioned_topology`
  splits the same config into per-endpoint domains driven by
  :class:`~repro.core.partition.PartitionEngine` (optionally across worker
  processes).  :func:`partition_fallback_reason` names the configs the
  partition engine cannot prove equivalent for; those fall back to the
  shared loop, recording the reason in a
  :class:`~repro.core.partition.PartitionRunInfo`.  For everything else the
  contract is **bit-identical** reports — both engines assemble their
  :class:`~repro.core.telemetry.RunReport` from the same plain-data *chunks*
  (:func:`assemble_echo_report`), so they cannot drift apart structurally.

Determinism: one virtual timeline, birth-key/FIFO event tie-breaks,
per-client seeds derived from the config's content hash
(:mod:`repro.exp.seeding` — NOT positional counters), and insertion-ordered
build/dispatch loops — the same ``TopologyConfig`` produces a bit-identical
``RunReport`` every run, under every engine.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import (AqmRed, DctcpRateController, EthConf, EthDev,
                        EventScheduler, LatencyRecorder, LoadGen,
                        NetworkStack, PacketPool, RunReport, SimClock, Switch,
                        ThroughputMeter, TrafficPattern, Wire,
                        writeback_extras)
from repro.core.packet import (l2fwd_echo, l2fwd_echo_vec, swap_macs,
                               swap_macs_vec)
from repro.core.partition import (ClientDomain, Crossing, DomainScheduler,
                                  DomainSwitch, MpPartitionEngine, NodeDomain,
                                  PartitionEngine, PartitionRunInfo,
                                  PartitionSanitizer, SwitchDomain)

from .config import CostConfig, NodeConfig, TopologyConfig
from .seeding import config_fingerprint, derive_seed
from .testbed import (apply_dca, build_stack, effective_stack_config,
                      effective_writeback_threshold)

CLIENT_IP_BASE = 0x0A000000   # client g owns 10.(g+1).0.0/16 on the fabric
NODE_AUTO_IP_BASE = 0xC0A80001  # auto-assigned node i: 192.168.0.(i+1)

# exp-layer builder the mp partition workers import to reconstruct their
# domain subset from a config dict (repro.core stays exp-agnostic)
PARTITION_BUILDER = ("repro.exp.topology", "build_partition_domains_subset")


@dataclass
class Node:
    """One live simulated host: private arena, one NIC, a server stack, and
    the switch port it hangs off."""

    cfg: NodeConfig
    ip: int
    pool: PacketPool
    dev: EthDev
    server: NetworkStack
    port_id: int


@dataclass
class Client:
    """One fabric-attached client population and its private buffer arena.

    Echo workloads drive a :class:`~repro.core.loadgen.LoadGen`; serving
    topologies (``TopologyConfig.serving``) drive a
    :class:`~repro.serving.requestgen.ServingClient` instead and ``lg`` is
    None."""

    lg: Optional[LoadGen]
    pool: PacketPool
    port_id: int
    seed: int
    serving: Optional[object] = None  # repro.serving.ServingClient


def _node_sink(node: Node) -> Callable[[np.ndarray, int], None]:
    """Switch egress → node NIC: DMA the wire bytes into the node's private
    arena and deliver through the normal NIC path (RSS steering, ring
    overflow drops, writeback thresholds all apply)."""
    pool, dev = node.pool, node.dev

    def sink(frame: np.ndarray, t_ns: int) -> None:
        slot = pool.alloc()
        if slot is None:
            return  # arena exhausted: the dev's rx_nombuf counter records it
        n = len(frame)
        pool.arena[slot, :n] = frame
        pool.lengths[slot] = n
        dev.deliver(slot, n)

    return sink


def _client_sink(client: Client) -> Callable[[np.ndarray, int], None]:
    """Switch egress → client: the reply is home; record RTT (echo) or
    token-stream SLO state (serving) at arrival."""

    if client.serving is not None:
        serving = client.serving

        def sink(frame: np.ndarray, t_ns: int) -> None:
            serving.complete_frame(frame, t_ns)

        return sink

    def sink(frame: np.ndarray, t_ns: int) -> None:
        client.lg.complete_frame(frame, t_ns)

    return sink


def _merge_extras(extras: Dict[str, float], new: Dict[str, float],
                  source: str) -> None:
    """Merge a component's extras into a RunReport, refusing key collisions.

    Every merge point used to be a blind ``dict.update``; a collision (two
    nodes exporting the same counter name, a stack reusing a switch key)
    silently replaced the earlier value and corrupted the report.  Now it
    raises, naming the offender."""
    for k in new:
        if k in extras:
            raise ValueError(
                f"RunReport extras key collision: {source} re-exports {k!r}")
    extras.update(new)


# -- shared build helpers (Cluster + partition domains) -----------------------

def _resolve_node_ips(cfg: TopologyConfig) -> List[int]:
    """Node fabric addresses, resolved up front so collisions fail loudly
    instead of silently shadowing a route (stable LPM sort keeps
    first-added)."""
    ips = [nc.ip if nc.ip else NODE_AUTO_IP_BASE + i
           for i, nc in enumerate(cfg.nodes)]
    if len(set(ips)) != len(ips):
        raise ValueError(
            f"resolved node ips collide: {[hex(ip) for ip in ips]}; "
            "auto-assignment uses 192.168.0.(index+1) — pick explicit "
            "ips outside that range")
    for ip in ips:
        if any(ip & 0xFFFF0000 == CLIENT_IP_BASE | ((g + 1) << 16)
               for g in range(cfg.n_clients)):
            raise ValueError(
                f"node ip {hex(ip)} falls inside a client /16 "
                f"(10.1.0.0 .. 10.{cfg.n_clients}.255.255); replies to "
                "that client would be shadowed")
    return ips


def _client_target_ip(cfg: TopologyConfig, g: int, ips: List[int]) -> int:
    """Client ``g``'s destination node address (``client_targets`` entry, or
    the topology-wide ``target``, or the first node)."""
    if cfg.client_targets is not None:
        name = cfg.client_targets[g]
    else:
        name = cfg.target or cfg.nodes[0].name
    for i, nc in enumerate(cfg.nodes):
        if nc.name == name:
            return ips[i]
    raise ValueError(f"target {name!r} names no node")  # config validates this


def _build_node_parts(nc: NodeConfig, i: int, clock: SimClock,
                      sched) -> Tuple[PacketPool, EthDev, NetworkStack]:
    """One node's private arena, NIC, and server stack — identical wiring for
    the shared-clock Cluster and a partitioned NodeDomain (``sched`` is an
    EventScheduler or a DomainScheduler; same API)."""
    pool = PacketPool(nc.pool.n_slots, nc.pool.slot_size)
    # the node NIC's own link is ideal: the switch port's wires carry
    # all link timing for this host
    dev = EthDev(pool, dev_id=i).configure(EthConf(
        n_rx_queues=nc.port.n_queues, n_tx_queues=nc.port.n_queues,
        rss_key=nc.port.rss.key,
        rss_table_size=nc.port.rss.table_size))
    for q in range(nc.port.n_queues):
        dev.rx_queue_setup(
            q, nc.port.ring_size,
            writeback_threshold=effective_writeback_threshold(
                nc.dca, nc.port.writeback_threshold, q))
        dev.tx_queue_setup(q, nc.port.ring_size)
    dev.dev_start()
    server = build_stack(effective_stack_config(nc.stack, nc.dca), [dev])
    if hasattr(server, "attach_clock"):
        cost = nc.stack.cost if nc.stack.cost is not None else CostConfig()
        server.attach_clock(clock, cost.to_host_cost_model())
    # the node's writeback timers ride the domain/cluster scheduler, so they
    # interleave deterministically with fabric events; same wiring as a
    # single-host testbed by construction
    apply_dca(nc.dca, [dev], server, sched)
    # a switched fabric needs replies re-addressed to their sender: upgrade
    # the stock L2Fwd transform to the echo variant (custom process fns
    # registered by scenario stacks are left alone)
    if getattr(server, "burst_process_fn", None) is swap_macs_vec:
        server.burst_process_fn = l2fwd_echo_vec
    if getattr(server, "process_fn", None) is swap_macs:
        server.process_fn = l2fwd_echo
    return pool, dev, server


def _echo_schedule(t, seed: int, dur_ns: int, start: int):
    """One client's analytic emission plan: (times, sizes, rng) — THE
    function both engines call, so a schedule can never diverge between
    them."""
    pattern = TrafficPattern(
        rate_gbps=t.rate_gbps, packet_size=t.packet_size, kind=t.kind,
        burst_len=t.burst_len, seed=seed)
    rng = np.random.default_rng(seed)
    times, sizes = pattern.emission_schedule(dur_ns, rng)
    if len(times):
        times = times + start
    return times, sizes, rng


class TrunkFabric:
    """Two switches joined by a trunk link, presenting the single-switch
    control/data-plane surface (``attach``/``add_route``/``send``/
    ``set_aqm``/``extras``) in the global endpoint namespace the builder
    already speaks (nodes ``0..N-1``, clients ``N..N+G-1``).

    Each switch carries its local endpoints plus one **trunk port** (always
    the switch's last port, pseudo ids ``N+G`` for switch 0 and ``N+G+1``
    for switch 1 in ``set_aqm``).  The trunk port's egress wire carries the
    trunk link's timing — set ``trunk.gbps`` below the aggregate edge rate
    and the core oversubscribes: the trunk egress queue builds and its
    drop/mark counters (``sw0_p*_...``/``sw1_p*_...`` extras) light up
    first.  Frames landing off one switch's trunk egress enter the peer's
    forward pipeline at arrival, so a cross-switch path pays: uplink →
    switch A queue+egress → trunk wire → switch B queue+egress → endpoint.

    Everything rides the one shared :class:`EventScheduler`, so the trunk
    fabric is exactly as deterministic as the single switch.
    """

    def __init__(self, cfg: TopologyConfig, sched: EventScheduler):
        link, trunk = cfg.switch.link, cfg.switch.trunk
        N, G = len(cfg.nodes), cfg.n_clients
        node_sw = cfg.node_switch or tuple(0 for _ in range(N))
        client_sw = cfg.client_switch or tuple(1 for _ in range(G))
        self.place: List[int] = list(node_sw) + list(client_sw)
        self.n_endpoints = N + G
        counts = [self.place.count(0), self.place.count(1)]
        self.switches: List[Switch] = [
            Switch(counts[si] + 1, sched, gbps=link.gbps,
                   latency_ns=link.latency_ns,
                   egress_capacity=cfg.switch.egress_capacity)
            for si in (0, 1)
        ]
        self.trunk_port = [counts[0], counts[1]]
        # local port ids assigned in global endpoint order (deterministic)
        self.local: List[int] = []
        next_id = [0, 0]
        for si in self.place:
            self.local.append(next_id[si])
            next_id[si] += 1
        for si, sw in enumerate(self.switches):
            tp = sw.ports[self.trunk_port[si]]
            # the trunk port's wires carry the trunk link's timing (the
            # ingress wire is unused — peer frames enter via _forward — but
            # is kept consistent for anyone reading port state)
            tp.egress = Wire(gbps=trunk.gbps, latency_ns=trunk.latency_ns)
            tp.ingress = Wire(gbps=trunk.gbps, latency_ns=trunk.latency_ns)
            peer, ptp = self.switches[1 - si], self.trunk_port[1 - si]
            sw.attach(self.trunk_port[si],
                      lambda frame, t_ns, _p=peer, _t=ptp:
                          _p._forward(_t, frame))

    def _home(self, eid: int) -> Tuple[int, Switch, int]:
        si = self.place[eid]
        return si, self.switches[si], self.local[eid]

    # -- the single-switch surface the builder/driver speak -------------------
    def attach(self, eid: int, sink) -> None:
        _, sw, lp = self._home(eid)
        sw.attach(lp, sink)

    def add_route(self, dst_ip: int, eid: int, prefix_len: int = 32) -> None:
        """Route on the home switch directly; on the peer, via its trunk."""
        si, sw, lp = self._home(eid)
        sw.add_route(dst_ip, lp, prefix_len)
        other = 1 - si
        self.switches[other].add_route(dst_ip, self.trunk_port[other],
                                       prefix_len)

    def send(self, eid: int, frame: np.ndarray,
             t_ns: Optional[int] = None) -> None:
        _, sw, lp = self._home(eid)
        sw.send(lp, frame, t_ns=t_ns)

    def set_aqm(self, pid: int, aqm: Optional[AqmRed]) -> None:
        if pid >= self.n_endpoints:   # pseudo ids: the two trunk ports
            si = pid - self.n_endpoints
            self.switches[si].set_aqm(self.trunk_port[si], aqm)
            return
        _, sw, lp = self._home(pid)
        sw.set_aqm(lp, aqm)

    def switch_index(self, pid: int) -> int:
        """Which physical switch owns fabric port ``pid`` (seed salt)."""
        if pid >= self.n_endpoints:
            return pid - self.n_endpoints
        return self.place[pid]

    @property
    def egress_drops(self) -> int:
        return sum(sw.egress_drops for sw in self.switches)

    def extras(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for si, sw in enumerate(self.switches):
            out.update(sw.extras(prefix=f"sw{si}"))
        return out


def _install_aqm(cfg: TopologyConfig, fabric) -> None:
    """Apply ``switch.pipeline`` to a built fabric: one fresh
    :class:`~repro.core.switch.AqmRed` per non-drop-tail egress port.

    Port ids are global endpoint ids; a trunk fabric has two extra ports
    (``N+G`` = switch 0's trunk, ``N+G+1`` = switch 1's).  ``per_port_aqm``
    may cover just the endpoints (trunk ports fall through to the default
    policy) or every port.  On a trunk fabric the owning switch's index is
    added to the policy seed, so the two replicas draw distinct streams."""
    pipe = cfg.switch.pipeline
    if pipe is None:
        return
    n_end = len(cfg.nodes) + cfg.n_clients
    n_ports = n_end + (2 if cfg.switch.trunk is not None else 0)
    if pipe.per_port_aqm is not None \
            and len(pipe.per_port_aqm) not in (n_end, n_ports):
        raise ValueError(
            f"per_port_aqm has {len(pipe.per_port_aqm)} entries; this fabric "
            f"has {n_ports} ports ({n_end} endpoint-facing)")
    for pid in range(n_ports):
        ac = pipe.aqm_for(pid)
        if ac.kind == "drop-tail":
            continue
        salt = fabric.switch_index(pid) if isinstance(fabric, TrunkFabric) \
            else 0
        fabric.set_aqm(pid, AqmRed(
            kind=ac.kind, min_thresh=ac.min_thresh,
            max_thresh=ac.max_thresh, max_p=ac.max_p, seed=ac.seed + salt))


class Cluster:
    """Live multi-host scenario built from one :class:`TopologyConfig`."""

    def __init__(self, cfg: TopologyConfig, clock: SimClock,
                 sched: EventScheduler, switch: Switch, nodes: List[Node],
                 clients: List[Client]):
        self.cfg = cfg
        self.clock = clock
        self.sched = sched
        self.switch = switch
        self.nodes = nodes
        self.clients = clients

    @classmethod
    def build(cls, cfg: TopologyConfig) -> "Cluster":
        if cfg.serving is not None:
            import repro.serving  # noqa: F401 — registers the serving kinds
        clock = SimClock()
        sched = EventScheduler(clock)
        if cfg.switch.trunk is not None:
            switch = TrunkFabric(cfg, sched)
        else:
            switch = Switch(len(cfg.nodes) + cfg.n_clients, sched,
                            gbps=cfg.switch.link.gbps,
                            latency_ns=cfg.switch.link.latency_ns,
                            egress_capacity=cfg.switch.egress_capacity)
        _install_aqm(cfg, switch)
        ips = _resolve_node_ips(cfg)
        nodes: List[Node] = []
        for i, nc in enumerate(cfg.nodes):
            pool, dev, server = _build_node_parts(nc, i, clock, sched)
            node = Node(cfg=nc, ip=ips[i], pool=pool, dev=dev, server=server,
                        port_id=i)
            switch.attach(i, _node_sink(node))
            switch.add_route(ips[i], i, prefix_len=32)
            nodes.append(node)
        t = cfg.traffic
        # per-client seeds derive from the config's content hash, not the
        # client's position in some loop — a sweep runner can shuffle,
        # shard, or replay this config and always get the same streams
        fp = config_fingerprint(cfg.to_dict())
        if cfg.serving is not None:
            from repro.serving import ServingClient, wire_serving
            wire_serving(cfg.serving, {n.cfg.name: n for n in nodes})
            balancer_ip = next(n.ip for n in nodes
                               if n.cfg.name == cfg.serving.balancer)
        clients: List[Client] = []
        for g in range(cfg.n_clients):
            port_id = len(nodes) + g
            pool = PacketPool(cfg.client_pool.n_slots, cfg.client_pool.slot_size)
            src_base = CLIENT_IP_BASE | ((g + 1) << 16)
            seed = derive_seed(fp, g, "client")
            if cfg.serving is not None:
                sc = ServingClient(serving=cfg.serving, client_index=g,
                                   src_ip=src_base, balancer_ip=balancer_ip,
                                   seed=seed)
                client = Client(lg=None, pool=pool, port_id=port_id,
                                seed=seed, serving=sc)
            else:
                lg = LoadGen([], ts_offset=t.ts_offset,
                             verify_integrity=t.verify_integrity,
                             max_tx_burst=t.max_tx_burst, n_flows=t.n_flows,
                             src_ip_base=src_base,
                             dst_ip=_client_target_ip(cfg, g, ips))
                client = Client(lg=lg, pool=pool, port_id=port_id, seed=seed)
            switch.attach(port_id, _client_sink(client))
            switch.add_route(src_base, port_id, prefix_len=16)
            clients.append(client)
        return cls(cfg, clock, sched, switch, nodes, clients)

    # -- driver ---------------------------------------------------------------
    def run(self, duration_s: Optional[float] = None,
            max_rounds: int = 50_000_000) -> RunReport:
        """Drive the whole cluster event-by-event in virtual time.

        Per round: due client emissions enter the fabric (stamped with their
        *scheduled* times), due fabric events fire (wire arrivals, egress
        completions, deliveries into NICs and clients), every node gets one
        scheduling round at virtual now and its TX drains back onto the
        fabric, then the clock advances to the earliest pending event.
        """
        t = self.cfg.traffic
        dur_ns = int((t.duration_s if duration_s is None else duration_s) * 1e9)
        clock, sched = self.clock, self.sched
        start = clock.now_ns
        end_t = start + dur_ns
        cc_on = t.cc_mode == "dctcp" and self.cfg.serving is None
        # per-client analytic schedules: [times, sizes, cursor, rng].  DCTCP
        # clients have no precomputed schedule (times=None): their cursor is
        # the next emission instant (float ns, None == done), minted per
        # frame from the controller's current rate.
        scheds: List[list] = []
        for client in self.clients:
            if client.serving is not None:
                times = client.serving.plan(dur_ns, start)
                scheds.append([times, None, 0, None])
                continue
            if cc_on:
                # Stagger window phases across clients so rate cuts and
                # recoveries do not synchronise (synchronised windows make
                # all clients overshoot and back off in lockstep, idling
                # the bottleneck).  The offset is a pure function of the
                # client index, so runs stay deterministic.
                phase = (len(scheds) * t.cc_window_ns) // max(
                    1, len(self.clients))
                client.lg.attach_cc(DctcpRateController(
                    rate_gbps=t.rate_gbps, window_ns=t.cc_window_ns,
                    gain=t.cc_gain, min_gbps=t.cc_min_gbps,
                    max_gbps=self.cfg.switch.link.gbps,
                    increase_gbps=t.cc_increase_gbps,
                    max_inflight=t.cc_max_inflight,
                    start_ns=start + phase))
                if dur_ns > 0:
                    client.lg.meter.open_window(start)
                scheds.append([None, None,
                               float(start) if dur_ns > 0 else None,
                               np.random.default_rng(client.seed)])
                continue
            times, sizes, rng = _echo_schedule(t, client.seed, dur_ns, start)
            if len(times):
                client.lg.meter.open_window(int(times[0]))
            scheds.append([times, sizes, 0, rng])
        flushed_idle = False
        for _ in range(max_rounds):
            now = clock.now_ns
            moved = 0
            # 1) due emissions, client order then time order (deterministic)
            for client, st in zip(self.clients, scheds):
                times, sizes, i, rng = st
                if times is None:   # DCTCP rate-adaptive client
                    cc = client.lg.cc
                    nxt = i
                    while nxt is not None and int(nxt) <= now:
                        t_emit = int(nxt)
                        # a tick that finds the in-flight cap exhausted is
                        # forfeited (paced probing): the cursor still
                        # advances, and the freed slot is used by the next
                        # tick after echoes drain the window
                        if cc.can_send():
                            frame = client.lg.make_frame(
                                client.pool, t.packet_size, t_emit,
                                rng if t.verify_integrity else None)
                            if frame is not None:
                                self.switch.send(client.port_id, frame,
                                                 t_ns=t_emit)
                        moved += 1
                        nxt += cc.gap_ns(t.packet_size)
                        if nxt >= end_t:
                            nxt = None
                    st[2] = nxt
                    continue
                n = len(times)
                while i < n and times[i] <= now:
                    t_emit = int(times[i])
                    if client.serving is not None:
                        # one due request == its whole frame flow; the
                        # uplink wire's FIFO serialization spaces the frames
                        for frame in client.serving.emit_request(i, t_emit):
                            self.switch.send(client.port_id, frame,
                                             t_ns=t_emit)
                    else:
                        frame = client.lg.make_frame(
                            client.pool, int(sizes[i]), t_emit,
                            rng if t.verify_integrity else None)
                        if frame is not None:
                            self.switch.send(client.port_id, frame,
                                             t_ns=t_emit)
                    i += 1
                    moved += 1
                st[2] = i
            # 2) fabric events due at now
            moved += sched.run_until(now)
            # 3) one scheduling round per node; TX drains onto the fabric
            for node in self.nodes:
                moved += node.server.poll_at(now)
                moved += self._drain_node_tx(node, now)
            # 4) advance to the next event
            cands: List[int] = []
            for st in scheds:
                if st[0] is None:
                    if st[2] is not None:
                        cands.append(int(st[2]))
                elif st[2] < len(st[0]):
                    cands.append(int(st[0][st[2]]))
            nt = sched.next_time_ns()
            if nt is not None:
                cands.append(nt)
            for node in self.nodes:
                nf = node.server.next_free_ns(now)
                if nf is not None:
                    cands.append(nf)
            if cands:
                flushed_idle = False
                clock.advance_to(min(cands))
                continue
            if moved > 0:
                flushed_idle = False
                continue
            if not flushed_idle:
                # quiet fabric: NIC timeout-driven descriptor writebacks fire
                for node in self.nodes:
                    node.dev.flush_rx()
                flushed_idle = True
                continue
            break  # nothing scheduled, nothing moving: remaining == drops
        else:
            raise RuntimeError(
                f"Cluster.run exceeded max_rounds={max_rounds} without "
                "quiescing — a node stack is likely re-addressing frames to "
                "itself (echo must swap flow IPs) or traffic never drains")
        return self._report(start)

    def _drain_node_tx(self, node: Node, now_ns: int) -> int:
        """Node NIC TX → fabric: serialize each reply out of the node's arena
        and hand it to the node's switch port."""
        slots, lengths = node.dev.drain_tx_bursts(self.cfg.traffic.max_tx_burst)
        n = len(slots)
        for k in range(n):
            slot = int(slots[k])
            frame = node.pool.view(slot, int(lengths[k])).copy()
            node.pool.free(slot)
            self.switch.send(node.port_id, frame, t_ns=now_ns)
        return n

    # -- reporting ------------------------------------------------------------
    def _report(self, start_ns: int) -> RunReport:
        """Merge every client's telemetry into one RunReport, with per-switch-
        port drop/occupancy counters and per-node NIC counters in extras.

        The echo path goes through the same plain-data *chunks* the
        partition engines report through (:func:`assemble_echo_report`), so
        the two execution modes share one assembly and cannot drift."""
        elapsed = float(self.clock.now_ns - start_ns)
        node_chunks = [_node_chunk(n.dev, n.server) for n in self.nodes]
        if self.cfg.serving is not None:
            rep = self._serving_report()
            _append_infra_extras(rep, self.cfg, node_chunks,
                                 self.switch.extras(), elapsed)
            return rep
        return assemble_echo_report(
            self.cfg, [_client_chunk(c.lg) for c in self.clients],
            node_chunks, self.switch.extras(), elapsed)

    def _serving_report(self) -> RunReport:
        """Serving semantics: sent/received count *requests*, the latency
        column is request E2E completion time, and the serving SLOs (TTFT /
        TPOT percentiles, virtual ns) ride in extras."""
        s = self.cfg.serving
        scs = [c.serving for c in self.clients]
        sent = sum(sc.requests_sent for sc in scs)
        received = sum(sc.requests_completed for sc in scs)
        e2e, ttft, tpot = LatencyRecorder(), LatencyRecorder(), LatencyRecorder()
        for sc in scs:
            for rec, merged in ((sc.e2e, e2e), (sc.ttft, ttft),
                                (sc.tpot, tpot)):
                vals = rec.values()
                if len(vals):
                    merged.record_many(vals)
        meter = ThroughputMeter()
        for sc in scs:
            m = sc.meter
            if m.start_ns is not None and m.end_ns is not None:
                meter.merge_counts(m.packets, m.bytes, m.start_ns, m.end_ns)
        rep = RunReport(
            offered_gbps=(s.qps * s.request_frame_bytes * 8 / 1e9
                          * len(self.clients)),
            achieved_gbps=meter.gbps,
            achieved_mpps=meter.mpps,
            sent=sent,
            received=received,
            dropped=sent - received,
            latency=e2e.stats(),
            histogram=e2e.histogram(),
        )
        x = rep.extras
        x["serving"] = 1.0
        x["offered_qps"] = float(s.qps * len(self.clients))
        for name, rec in (("ttft", ttft), ("tpot", tpot)):
            st = rec.stats()
            x[f"{name}_p50_ns"] = float(st.median_ns) if st else 0.0
            x[f"{name}_p99_ns"] = float(st.p99_ns) if st else 0.0
            x[f"{name}_mean_ns"] = float(st.mean_ns) if st else 0.0
            x[f"{name}_count"] = float(rec.count)
        for gi, sc in enumerate(scs):
            _merge_extras(x,
                          {f"g{gi}_{k}": v for k, v in sc.extras().items()},
                          f"client {gi} serving extras")
        return rep


# -- chunk-based report assembly (shared-clock AND partitioned) ---------------

def _client_chunk(lg: LoadGen) -> Dict[str, object]:
    """One echo client's contribution to the report, as plain picklable data
    (mirrors :meth:`repro.core.partition.ClientDomain.chunk`)."""
    m = lg.meter
    out: Dict[str, object] = {
        "sent": lg.flight.sent,
        "received": lg.flight.received,
        "integrity_errors": lg.flight.integrity_errors,
        "latency": lg.latency.values().copy(),
        "meter": (m.packets, m.bytes, m.start_ns, m.end_ns)}
    # congestion telemetry keys exist only when the fabric marked something
    # or a rate controller ran — pre-AQM chunks (and the partition replicas
    # that mirror this function) stay byte-identical
    if lg.flight.ce_marked or lg.cc is not None:
        out["ce_marked"] = lg.flight.ce_marked
    if lg.cc is not None:
        out["cc_final_rate_gbps"] = lg.cc.rate_gbps
        out["cc_min_rate_gbps"] = lg.cc.rate_min
        out["cc_windows"] = lg.cc.windows
        out["cc_lost_inferred"] = lg.cc.lost_accounted
    return out


def _node_chunk(dev: EthDev, server: NetworkStack) -> Dict[str, object]:
    """One node's NIC/stack counters as plain data (mirrors
    :meth:`repro.core.partition.NodeDomain.chunk`)."""
    st = dev.stats()
    out: Dict[str, object] = {
        "ipackets": st.ipackets, "imissed": st.imissed,
        "rx_nombuf": st.rx_nombuf,
        "writeback": writeback_extras([dev]),
    }
    if hasattr(server, "extras"):
        out["stack"] = dict(server.extras())
    return out


def _append_infra_extras(rep: RunReport, cfg: TopologyConfig,
                         node_chunks: Sequence[Dict[str, object]],
                         switch_extras: Dict[str, float],
                         virtual_elapsed_ns: float) -> None:
    """The report tail every topology run shares: sim provenance, per-node
    NIC counters + descriptor-writeback telemetry, switch port counters.
    Merge order is load-bearing (extras is insertion-ordered) — this one
    function defines it for both execution engines."""
    rep.extras["sim_time"] = 1.0
    rep.extras["virtual_elapsed_ns"] = virtual_elapsed_ns
    for ni, chunk in enumerate(node_chunks):
        name = cfg.nodes[ni].name
        rep.extras[f"n{ni}_rx_packets"] = float(chunk["ipackets"])
        rep.extras[f"n{ni}_imissed"] = float(chunk["imissed"])
        rep.extras[f"n{ni}_rx_nombuf"] = float(chunk["rx_nombuf"])
        # per-ring descriptor-writeback telemetry (the Fig. 4 observable)
        _merge_extras(rep.extras,
                      {f"n{ni}_{k}": v for k, v in chunk["writeback"].items()},
                      f"node {name!r} writeback telemetry")
        if "stack" in chunk:
            _merge_extras(
                rep.extras,
                {f"n{ni}_{k}": v for k, v in chunk["stack"].items()},
                f"node {name!r} stack extras")
    _merge_extras(rep.extras, switch_extras, "switch telemetry")


def assemble_echo_report(cfg: TopologyConfig,
                         client_chunks: Sequence[Dict[str, object]],
                         node_chunks: Sequence[Dict[str, object]],
                         switch_extras: Dict[str, float],
                         virtual_elapsed_ns: float) -> RunReport:
    """One echo RunReport from per-component chunks.  Every aggregation is
    order-fixed (client index, node index), so any engine that produces
    identical chunks produces a bit-identical report."""
    t = cfg.traffic
    sent = sum(c["sent"] for c in client_chunks)
    received = sum(c["received"] for c in client_chunks)
    lat = LatencyRecorder()
    for c in client_chunks:
        vals = c["latency"]
        if len(vals):
            lat.record_many(vals)
    meter = ThroughputMeter()
    for c in client_chunks:
        packets, nbytes, start_ns, end_ns = c["meter"]
        if start_ns is not None and end_ns is not None:
            meter.merge_counts(packets, nbytes, start_ns, end_ns)
    rep = RunReport(
        offered_gbps=t.rate_gbps * len(client_chunks),
        achieved_gbps=meter.gbps,
        achieved_mpps=meter.mpps,
        sent=sent,
        received=received,
        dropped=sent - received,
        latency=lat.stats(),
        histogram=lat.histogram(),
    )
    rep.extras["integrity_errors"] = float(
        sum(c["integrity_errors"] for c in client_chunks))
    for gi, c in enumerate(client_chunks):
        rep.extras[f"g{gi}_sent"] = float(c["sent"])
        rep.extras[f"g{gi}_received"] = float(c["received"])
        for key in ("ce_marked", "cc_final_rate_gbps", "cc_min_rate_gbps",
                    "cc_windows", "cc_lost_inferred"):
            if key in c:
                rep.extras[f"g{gi}_{key}"] = float(c[key])
    _append_infra_extras(rep, cfg, node_chunks, switch_extras,
                         virtual_elapsed_ns)
    return rep


# -- partitioned execution ----------------------------------------------------

def partition_fallback_reason(cfg: TopologyConfig) -> Optional[str]:
    """Why this config must run on the shared clock — or None if partitioned
    execution is provably bit-identical.

    The conservative-window argument needs (a) ≥ 1 ns of link latency (the
    lookahead window), and (b) every endpoint to expose its next activity as
    a candidate time.  A node whose host-cost model rounds to zero ns
    processes frames only when *polled*, and the shared loop polls every
    node at every global event time while a domain only rounds at its own —
    so zero-cost stacks (and stack kinds we haven't proven self-scheduling,
    e.g. the pipeline stack's zero-charge passes) stay on the shared clock.
    Serving topologies share live balancer state across nodes and are out of
    scope entirely.  The PR-10 features are conservatively excluded until
    proven: an active AQM policy reorders its decision counter relative to
    the shared loop's arrival interleaving, a trunk fabric inserts a
    switch-to-switch hop the single-SwitchDomain layout cannot express, and
    DCTCP clients adapt their *emission schedule* on echo feedback — the one
    thing the partition contract assumes is precomputable per domain."""
    if cfg.serving is not None:
        return "serving topology: balancer reads live cross-domain state"
    if cfg.switch.link.latency_ns < 1:
        return "zero-latency links leave no conservative lookahead window"
    if cfg.switch.trunk is not None:
        return "multi-switch trunk fabric not proven partition-equivalent"
    pipe = cfg.switch.pipeline
    if pipe is not None:
        kinds = {pipe.aqm.kind}
        for entry in pipe.per_port_aqm or ():
            if entry is not None:
                kinds.add(entry.kind)
        kinds.discard("drop-tail")   # explicit drop-tail == the default path
        if kinds:
            return (f"AQM policy {sorted(kinds)[0]!r} not proven "
                    "partition-equivalent")
    if cfg.traffic.cc_mode != "fixed":
        return "DCTCP rate-adaptive clients adapt on cross-domain echo feedback"
    for nc in cfg.nodes:
        kind = effective_stack_config(nc.stack, nc.dca).kind
        m = (nc.stack.cost if nc.stack.cost is not None
             else CostConfig()).to_host_cost_model()
        if kind == "bypass":
            if int(round(m.pmd_burst_ns(1))) < 1:
                return (f"node {nc.name!r}: zero-cost PMD model needs the "
                        "shared loop's every-round polling")
        elif kind == "kernel":
            if (int(round(m.ns(m.interrupt_cycles))) < 1
                    or int(round(m.ns(m.syscall_cycles
                                      + m.per_packet_kernel_cycles))) < 1):
                return (f"node {nc.name!r}: zero-cost kernel model needs the "
                        "shared loop's every-round polling")
        else:
            return (f"node {nc.name!r}: stack kind {kind!r} not proven "
                    "partition-equivalent")
    return None


def _build_domain(cfg: TopologyConfig, idx: int, outbox: List[Crossing]):
    """Domain ``idx`` of a partitioned topology, built standalone.

    Layout: clients 0..G-1, nodes G..G+N-1, the switch at G+N.  Every domain
    derives all shared facts (addresses, seeds, schedules) from ``cfg``
    alone, so workers can build disjoint subsets with no cross-talk."""
    G, N = cfg.n_clients, len(cfg.nodes)
    switch_domain = G + N
    link = cfg.switch.link
    ips = _resolve_node_ips(cfg)
    clock = SimClock()
    ds = DomainScheduler(clock)
    t = cfg.traffic
    if idx < G:  # client domain
        g = idx
        fp = config_fingerprint(cfg.to_dict())
        seed = derive_seed(fp, g, "client")
        pool = PacketPool(cfg.client_pool.n_slots, cfg.client_pool.slot_size)
        src_base = CLIENT_IP_BASE | ((g + 1) << 16)
        lg = LoadGen([], ts_offset=t.ts_offset,
                     verify_integrity=t.verify_integrity,
                     max_tx_burst=t.max_tx_burst, n_flows=t.n_flows,
                     src_ip_base=src_base,
                     dst_ip=_client_target_ip(cfg, g, ips))
        times, sizes, rng = _echo_schedule(
            t, seed, int(t.duration_s * 1e9), start=0)
        if len(times):
            lg.meter.open_window(int(times[0]))
        return ClientDomain(
            index=g, ds=ds, lg=lg, pool=pool, port_id=N + g,
            uplink=Wire(gbps=link.gbps, latency_ns=link.latency_ns),
            times=times, sizes=sizes, rng=rng,
            verify_integrity=t.verify_integrity,
            switch_domain=switch_domain, outbox=outbox)
    if idx < G + N:  # node domain
        ni = idx - G
        pool, dev, server = _build_node_parts(cfg.nodes[ni], ni, clock, ds)
        return NodeDomain(
            index=ni, ds=ds, dev=dev, pool=pool, server=server, port_id=ni,
            uplink=Wire(gbps=link.gbps, latency_ns=link.latency_ns),
            max_tx_burst=t.max_tx_burst, switch_domain=switch_domain,
            outbox=outbox)
    # switch domain: owns routes, egress wires/queues, and all drop counters
    domain_of_port = [G + i for i in range(N)] + list(range(G))
    sw = DomainSwitch(N + G, ds, gbps=link.gbps, latency_ns=link.latency_ns,
                      egress_capacity=cfg.switch.egress_capacity,
                      domain_of_port=domain_of_port, outbox=outbox)
    for i in range(N):
        sw.add_route(ips[i], i, prefix_len=32)
    for g in range(G):
        sw.add_route(CLIENT_IP_BASE | ((g + 1) << 16), N + g, prefix_len=16)
    return SwitchDomain(index=switch_domain, ds=ds, switch=sw)


def build_partition_domains_subset(cfg_dict: dict, ids: Sequence[int],
                                   outbox: List[Crossing]) -> Dict[int, object]:
    """mp-worker entry point (imported by name via
    :data:`PARTITION_BUILDER`): rebuild domains ``ids`` from a config
    dict."""
    cfg = TopologyConfig.from_dict(cfg_dict)
    return {i: _build_domain(cfg, i, outbox) for i in ids}


def _report_from_chunks(cfg: TopologyConfig, chunks: Dict[int, Dict[str, object]],
                        final_clock_ns: int) -> RunReport:
    G, N = cfg.n_clients, len(cfg.nodes)
    return assemble_echo_report(
        cfg,
        [chunks[g] for g in range(G)],
        [chunks[G + ni] for ni in range(N)],
        chunks[G + N]["extras"],
        float(final_clock_ns))


def _sanitize_enabled(cfg: TopologyConfig) -> bool:
    """Sanitizer opt-in: the config flag, or the env override (any value but
    '' / '0' turns it on — CI sets REPRO_PARTITION_SANITIZE=1 for the parity
    corpus)."""
    if cfg.partition_sanitize:
        return True
    return os.environ.get("REPRO_PARTITION_SANITIZE", "0") not in ("", "0")


def run_partitioned_topology(cfg: TopologyConfig, *,
                             info: Optional[PartitionRunInfo] = None,
                             n_groups: int = 1,
                             trace: Optional[List[Crossing]] = None
                             ) -> RunReport:
    """Run one topology config under its requested partition mode.

    Configs the engine cannot prove equivalent for (see
    :func:`partition_fallback_reason`) fall back to the shared-clock loop;
    ``info`` (if given) records what actually ran.  ``n_groups`` only
    regroups in-process domain execution (results are identical by
    construction); ``trace``, if a list, collects every boundary
    :data:`~repro.core.partition.Crossing` for property tests.  With
    ``cfg.partition_sanitize`` (or env ``REPRO_PARTITION_SANITIZE=1``) every
    crossing delivery additionally runs through a
    :class:`~repro.core.partition.PartitionSanitizer`, raising
    :class:`~repro.core.partition.CausalityError` on any conservative-bound
    or ordering breach; ``info.n_sanitized`` counts the checks."""
    if info is None:
        info = PartitionRunInfo()
    info.mode_requested = cfg.partition
    reason = partition_fallback_reason(cfg) if cfg.partition != "shared-clock" \
        else None
    if cfg.partition == "shared-clock" or reason is not None:
        info.mode_used = "shared-clock"
        info.fallback_reason = reason
        info.n_workers = 1
        return Cluster.build(cfg).run()
    G, N = cfg.n_clients, len(cfg.nodes)
    n_domains = G + N + 1
    delta = cfg.switch.link.latency_ns
    info.n_domains = n_domains
    workers = cfg.partition_workers
    if cfg.partition == "partitioned-mp" and workers == 0:
        workers = max(2, os.cpu_count() or 1)
    sanitizer = (PartitionSanitizer(delta, gbps=cfg.switch.link.gbps)
                 if _sanitize_enabled(cfg) else None)
    if cfg.partition == "partitioned-mp" and workers > 1:
        eng = MpPartitionEngine(cfg.to_dict(), PARTITION_BUILDER, n_domains,
                                delta, workers, sanitizer=sanitizer)
        try:
            chunks = eng.run()
        finally:
            eng.close()
        info.mode_used = "partitioned-mp"
        info.n_windows = eng.n_windows
        info.n_workers = eng.n_workers
        if sanitizer is not None:
            info.n_sanitized = sanitizer.checked
        return _report_from_chunks(cfg, chunks, eng.final_clock_ns)
    # in-process: mode "partitioned", or "partitioned-mp" pinned to 1 worker
    outbox: List[Crossing] = []
    domains = [_build_domain(cfg, i, outbox) for i in range(n_domains)]
    eng = PartitionEngine(domains, delta, outbox, n_groups=n_groups,
                          trace=trace, sanitizer=sanitizer)
    eng.run()
    info.mode_used = "partitioned"
    info.n_windows = eng.n_windows
    info.n_workers = 1
    if sanitizer is not None:
        info.n_sanitized = sanitizer.checked
    return _report_from_chunks(cfg, eng.chunks(), eng.final_clock_ns)
