"""Multi-host topology builder + driver: N node testbeds and N fabric-attached
load-generator clients around one :class:`~repro.core.switch.Switch`, all on
ONE shared :class:`~repro.core.simclock.SimClock`.

This is the SimBricks-style composition the ROADMAP called for: every node is
an independently-built model (its own :class:`~repro.core.packet.PacketPool`,
its own :class:`~repro.core.ethdev.EthDev`, its own server stack from the
same registry single-host testbeds use), and the pieces meet only on the
fabric — frames cross between address spaces as byte copies over modeled
wires, and all timing runs through one
:class:`~repro.core.simclock.EventScheduler`.

The traffic shape is client/server: each client is a
:class:`~repro.core.loadgen.LoadGen` attached to a switch port through the
fabric primitives (``make_frame``/``complete_frame``), addressing one target
node (``TopologyConfig.target``).  The target's stack echoes each frame back
to its sender (macs + flow IPs swapped), so every client measures true
four-hop RTTs: uplink → switch egress queue → server NIC/stack → and the
same in reverse.  With N clients this is the classic **incast**: the switch
egress port facing the target saturates first, and losses show up in the
*switch's* per-port drop counters while every NIC stays loss-free —
exactly the observable the incast benchmark asserts.

Determinism: one clock, FIFO event tie-breaks, per-client seeds derived as
``traffic.seed + client_index``, and insertion-ordered build/dispatch loops —
the same ``TopologyConfig`` produces a bit-identical ``RunReport`` every run.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core import (EthConf, EthDev, EventScheduler, LatencyRecorder,
                        LoadGen, NetworkStack, PacketPool, RunReport,
                        SimClock, Switch, ThroughputMeter, TrafficPattern,
                        writeback_extras)
from repro.core.packet import (l2fwd_echo, l2fwd_echo_vec, swap_macs,
                               swap_macs_vec)

from .config import CostConfig, NodeConfig, TopologyConfig
from .testbed import (apply_dca, build_stack, effective_stack_config,
                      effective_writeback_threshold)

CLIENT_IP_BASE = 0x0A000000   # client g owns 10.(g+1).0.0/16 on the fabric
NODE_AUTO_IP_BASE = 0xC0A80001  # auto-assigned node i: 192.168.0.(i+1)


@dataclass
class Node:
    """One live simulated host: private arena, one NIC, a server stack, and
    the switch port it hangs off."""

    cfg: NodeConfig
    ip: int
    pool: PacketPool
    dev: EthDev
    server: NetworkStack
    port_id: int


@dataclass
class Client:
    """One fabric-attached client population and its private buffer arena.

    Echo workloads drive a :class:`~repro.core.loadgen.LoadGen`; serving
    topologies (``TopologyConfig.serving``) drive a
    :class:`~repro.serving.requestgen.ServingClient` instead and ``lg`` is
    None."""

    lg: Optional[LoadGen]
    pool: PacketPool
    port_id: int
    seed: int
    serving: Optional[object] = None  # repro.serving.ServingClient


def _node_sink(node: Node) -> Callable[[np.ndarray, int], None]:
    """Switch egress → node NIC: DMA the wire bytes into the node's private
    arena and deliver through the normal NIC path (RSS steering, ring
    overflow drops, writeback thresholds all apply)."""
    pool, dev = node.pool, node.dev

    def sink(frame: np.ndarray, t_ns: int) -> None:
        slot = pool.alloc()
        if slot is None:
            return  # arena exhausted: the dev's rx_nombuf counter records it
        n = len(frame)
        pool.arena[slot, :n] = frame
        pool.lengths[slot] = n
        dev.deliver(slot, n)

    return sink


def _client_sink(client: Client) -> Callable[[np.ndarray, int], None]:
    """Switch egress → client: the reply is home; record RTT (echo) or
    token-stream SLO state (serving) at arrival."""

    if client.serving is not None:
        serving = client.serving

        def sink(frame: np.ndarray, t_ns: int) -> None:
            serving.complete_frame(frame, t_ns)

        return sink

    def sink(frame: np.ndarray, t_ns: int) -> None:
        client.lg.complete_frame(frame, t_ns)

    return sink


def _merge_extras(extras: Dict[str, float], new: Dict[str, float],
                  source: str) -> None:
    """Merge a component's extras into a RunReport, refusing key collisions.

    Every merge point used to be a blind ``dict.update``; a collision (two
    nodes exporting the same counter name, a stack reusing a switch key)
    silently replaced the earlier value and corrupted the report.  Now it
    raises, naming the offender."""
    for k in new:
        if k in extras:
            raise ValueError(
                f"RunReport extras key collision: {source} re-exports {k!r}")
    extras.update(new)


class Cluster:
    """Live multi-host scenario built from one :class:`TopologyConfig`."""

    def __init__(self, cfg: TopologyConfig, clock: SimClock,
                 sched: EventScheduler, switch: Switch, nodes: List[Node],
                 clients: List[Client]):
        self.cfg = cfg
        self.clock = clock
        self.sched = sched
        self.switch = switch
        self.nodes = nodes
        self.clients = clients

    @classmethod
    def build(cls, cfg: TopologyConfig) -> "Cluster":
        if cfg.serving is not None:
            import repro.serving  # noqa: F401 — registers the serving kinds
        clock = SimClock()
        sched = EventScheduler(clock)
        switch = Switch(len(cfg.nodes) + cfg.n_clients, sched,
                        gbps=cfg.switch.link.gbps,
                        latency_ns=cfg.switch.link.latency_ns,
                        egress_capacity=cfg.switch.egress_capacity)
        # resolve node addresses up front so collisions fail loudly instead
        # of silently shadowing a route (stable LPM sort keeps first-added)
        ips = [nc.ip if nc.ip else NODE_AUTO_IP_BASE + i
               for i, nc in enumerate(cfg.nodes)]
        if len(set(ips)) != len(ips):
            raise ValueError(
                f"resolved node ips collide: {[hex(ip) for ip in ips]}; "
                "auto-assignment uses 192.168.0.(index+1) — pick explicit "
                "ips outside that range")
        for ip in ips:
            if any(ip & 0xFFFF0000 == CLIENT_IP_BASE | ((g + 1) << 16)
                   for g in range(cfg.n_clients)):
                raise ValueError(
                    f"node ip {hex(ip)} falls inside a client /16 "
                    f"(10.1.0.0 .. 10.{cfg.n_clients}.255.255); replies to "
                    "that client would be shadowed")
        nodes: List[Node] = []
        for i, nc in enumerate(cfg.nodes):
            ip = ips[i]
            pool = PacketPool(nc.pool.n_slots, nc.pool.slot_size)
            # the node NIC's own link is ideal: the switch port's wires carry
            # all link timing for this host
            dev = EthDev(pool, dev_id=i).configure(EthConf(
                n_rx_queues=nc.port.n_queues, n_tx_queues=nc.port.n_queues,
                rss_key=nc.port.rss.key,
                rss_table_size=nc.port.rss.table_size))
            for q in range(nc.port.n_queues):
                dev.rx_queue_setup(
                    q, nc.port.ring_size,
                    writeback_threshold=effective_writeback_threshold(
                        nc.dca, nc.port.writeback_threshold, q))
                dev.tx_queue_setup(q, nc.port.ring_size)
            dev.dev_start()
            server = build_stack(effective_stack_config(nc.stack, nc.dca), [dev])
            if hasattr(server, "attach_clock"):
                cost = nc.stack.cost if nc.stack.cost is not None else CostConfig()
                server.attach_clock(clock, cost.to_host_cost_model())
            # the node's writeback timers ride the cluster's shared
            # scheduler, so they interleave deterministically with fabric
            # events; same wiring as a single-host testbed by construction
            apply_dca(nc.dca, [dev], server, sched)
            # a switched fabric needs replies re-addressed to their sender:
            # upgrade the stock L2Fwd transform to the echo variant (custom
            # process fns registered by scenario stacks are left alone)
            if getattr(server, "burst_process_fn", None) is swap_macs_vec:
                server.burst_process_fn = l2fwd_echo_vec
            if getattr(server, "process_fn", None) is swap_macs:
                server.process_fn = l2fwd_echo
            node = Node(cfg=nc, ip=ip, pool=pool, dev=dev, server=server,
                        port_id=i)
            switch.attach(i, _node_sink(node))
            switch.add_route(ip, i, prefix_len=32)
            nodes.append(node)
        t = cfg.traffic
        if cfg.serving is not None:
            from repro.serving import ServingClient, wire_serving
            wire_serving(cfg.serving, {n.cfg.name: n for n in nodes})
            balancer_ip = next(n.ip for n in nodes
                               if n.cfg.name == cfg.serving.balancer)
        else:
            target_name = cfg.target or cfg.nodes[0].name
            target_ip = next(n.ip for n in nodes if n.cfg.name == target_name)
        clients: List[Client] = []
        for g in range(cfg.n_clients):
            port_id = len(nodes) + g
            pool = PacketPool(cfg.client_pool.n_slots, cfg.client_pool.slot_size)
            src_base = CLIENT_IP_BASE | ((g + 1) << 16)
            if cfg.serving is not None:
                sc = ServingClient(serving=cfg.serving, client_index=g,
                                   src_ip=src_base, balancer_ip=balancer_ip,
                                   seed=t.seed + g)
                client = Client(lg=None, pool=pool, port_id=port_id,
                                seed=t.seed + g, serving=sc)
            else:
                lg = LoadGen([], ts_offset=t.ts_offset,
                             verify_integrity=t.verify_integrity,
                             max_tx_burst=t.max_tx_burst, n_flows=t.n_flows,
                             src_ip_base=src_base, dst_ip=target_ip)
                client = Client(lg=lg, pool=pool, port_id=port_id,
                                seed=t.seed + g)
            switch.attach(port_id, _client_sink(client))
            switch.add_route(src_base, port_id, prefix_len=16)
            clients.append(client)
        return cls(cfg, clock, sched, switch, nodes, clients)

    # -- driver ---------------------------------------------------------------
    def run(self, duration_s: Optional[float] = None,
            max_rounds: int = 50_000_000) -> RunReport:
        """Drive the whole cluster event-by-event in virtual time.

        Per round: due client emissions enter the fabric (stamped with their
        *scheduled* times), due fabric events fire (wire arrivals, egress
        completions, deliveries into NICs and clients), every node gets one
        scheduling round at virtual now and its TX drains back onto the
        fabric, then the clock advances to the earliest pending event.
        """
        t = self.cfg.traffic
        dur_ns = int((t.duration_s if duration_s is None else duration_s) * 1e9)
        clock, sched = self.clock, self.sched
        start = clock.now_ns
        # per-client analytic schedules: [times, sizes, cursor, rng]
        scheds: List[list] = []
        for client in self.clients:
            if client.serving is not None:
                times = client.serving.plan(dur_ns, start)
                scheds.append([times, None, 0, None])
                continue
            pattern = TrafficPattern(
                rate_gbps=t.rate_gbps, packet_size=t.packet_size, kind=t.kind,
                burst_len=t.burst_len, seed=client.seed)
            rng = np.random.default_rng(client.seed)
            times, sizes = pattern.emission_schedule(dur_ns, rng)
            if len(times):
                times = times + start
                client.lg.meter.open_window(int(times[0]))
            scheds.append([times, sizes, 0, rng])
        flushed_idle = False
        for _ in range(max_rounds):
            now = clock.now_ns
            moved = 0
            # 1) due emissions, client order then time order (deterministic)
            for client, st in zip(self.clients, scheds):
                times, sizes, i, rng = st
                n = len(times)
                while i < n and times[i] <= now:
                    t_emit = int(times[i])
                    if client.serving is not None:
                        # one due request == its whole frame flow; the
                        # uplink wire's FIFO serialization spaces the frames
                        for frame in client.serving.emit_request(i, t_emit):
                            self.switch.send(client.port_id, frame,
                                             t_ns=t_emit)
                    else:
                        frame = client.lg.make_frame(
                            client.pool, int(sizes[i]), t_emit,
                            rng if t.verify_integrity else None)
                        if frame is not None:
                            self.switch.send(client.port_id, frame,
                                             t_ns=t_emit)
                    i += 1
                    moved += 1
                st[2] = i
            # 2) fabric events due at now
            moved += sched.run_until(now)
            # 3) one scheduling round per node; TX drains onto the fabric
            for node in self.nodes:
                moved += node.server.poll_at(now)
                moved += self._drain_node_tx(node, now)
            # 4) advance to the next event
            cands: List[int] = []
            for st in scheds:
                if st[2] < len(st[0]):
                    cands.append(int(st[0][st[2]]))
            nt = sched.next_time_ns()
            if nt is not None:
                cands.append(nt)
            for node in self.nodes:
                nf = node.server.next_free_ns(now)
                if nf is not None:
                    cands.append(nf)
            if cands:
                flushed_idle = False
                clock.advance_to(min(cands))
                continue
            if moved > 0:
                flushed_idle = False
                continue
            if not flushed_idle:
                # quiet fabric: NIC timeout-driven descriptor writebacks fire
                for node in self.nodes:
                    node.dev.flush_rx()
                flushed_idle = True
                continue
            break  # nothing scheduled, nothing moving: remaining == drops
        else:
            raise RuntimeError(
                f"Cluster.run exceeded max_rounds={max_rounds} without "
                "quiescing — a node stack is likely re-addressing frames to "
                "itself (echo must swap flow IPs) or traffic never drains")
        return self._report(start)

    def _drain_node_tx(self, node: Node, now_ns: int) -> int:
        """Node NIC TX → fabric: serialize each reply out of the node's arena
        and hand it to the node's switch port."""
        slots, lengths = node.dev.drain_tx_bursts(self.cfg.traffic.max_tx_burst)
        n = len(slots)
        for k in range(n):
            slot = int(slots[k])
            frame = node.pool.view(slot, int(lengths[k])).copy()
            node.pool.free(slot)
            self.switch.send(node.port_id, frame, t_ns=now_ns)
        return n

    # -- reporting ------------------------------------------------------------
    def _report(self, start_ns: int) -> RunReport:
        """Merge every client's telemetry into one RunReport, with per-switch-
        port drop/occupancy counters and per-node NIC counters in extras.
        Every extras merge goes through :func:`_merge_extras`, so a key
        collision between components raises instead of silently corrupting
        the report."""
        if self.cfg.serving is not None:
            rep = self._serving_report()
        else:
            rep = self._echo_report()
        rep.extras["sim_time"] = 1.0
        rep.extras["virtual_elapsed_ns"] = float(self.clock.now_ns - start_ns)
        for ni, node in enumerate(self.nodes):
            st = node.dev.stats()
            rep.extras[f"n{ni}_rx_packets"] = float(st.ipackets)
            rep.extras[f"n{ni}_imissed"] = float(st.imissed)
            rep.extras[f"n{ni}_rx_nombuf"] = float(st.rx_nombuf)
            # per-ring descriptor-writeback telemetry (the Fig. 4 observable)
            _merge_extras(rep.extras,
                          writeback_extras([node.dev], prefix=f"n{ni}_"),
                          f"node {node.cfg.name!r} writeback telemetry")
            if hasattr(node.server, "extras"):
                _merge_extras(
                    rep.extras,
                    {f"n{ni}_{k}": v for k, v in node.server.extras().items()},
                    f"node {node.cfg.name!r} stack extras")
        _merge_extras(rep.extras, self.switch.extras(), "switch telemetry")
        return rep

    def _echo_report(self) -> RunReport:
        t = self.cfg.traffic
        sent = sum(c.lg.flight.sent for c in self.clients)
        received = sum(c.lg.flight.received for c in self.clients)
        lat = LatencyRecorder()
        for c in self.clients:
            vals = c.lg.latency.values()
            if len(vals):
                lat.record_many(vals)
        meter = ThroughputMeter()
        for c in self.clients:
            m = c.lg.meter
            if m.start_ns is not None and m.end_ns is not None:
                meter.merge_counts(m.packets, m.bytes, m.start_ns, m.end_ns)
        rep = RunReport(
            offered_gbps=t.rate_gbps * len(self.clients),
            achieved_gbps=meter.gbps,
            achieved_mpps=meter.mpps,
            sent=sent,
            received=received,
            dropped=sent - received,
            latency=lat.stats(),
            histogram=lat.histogram(),
        )
        rep.extras["integrity_errors"] = float(
            sum(c.lg.flight.integrity_errors for c in self.clients))
        for gi, c in enumerate(self.clients):
            rep.extras[f"g{gi}_sent"] = float(c.lg.flight.sent)
            rep.extras[f"g{gi}_received"] = float(c.lg.flight.received)
        return rep

    def _serving_report(self) -> RunReport:
        """Serving semantics: sent/received count *requests*, the latency
        column is request E2E completion time, and the serving SLOs (TTFT /
        TPOT percentiles, virtual ns) ride in extras."""
        s = self.cfg.serving
        scs = [c.serving for c in self.clients]
        sent = sum(sc.requests_sent for sc in scs)
        received = sum(sc.requests_completed for sc in scs)
        e2e, ttft, tpot = LatencyRecorder(), LatencyRecorder(), LatencyRecorder()
        for sc in scs:
            for rec, merged in ((sc.e2e, e2e), (sc.ttft, ttft),
                                (sc.tpot, tpot)):
                vals = rec.values()
                if len(vals):
                    merged.record_many(vals)
        meter = ThroughputMeter()
        for sc in scs:
            m = sc.meter
            if m.start_ns is not None and m.end_ns is not None:
                meter.merge_counts(m.packets, m.bytes, m.start_ns, m.end_ns)
        rep = RunReport(
            offered_gbps=(s.qps * s.request_frame_bytes * 8 / 1e9
                          * len(self.clients)),
            achieved_gbps=meter.gbps,
            achieved_mpps=meter.mpps,
            sent=sent,
            received=received,
            dropped=sent - received,
            latency=e2e.stats(),
            histogram=e2e.histogram(),
        )
        x = rep.extras
        x["serving"] = 1.0
        x["offered_qps"] = float(s.qps * len(self.clients))
        for name, rec in (("ttft", ttft), ("tpot", tpot)):
            st = rec.stats()
            x[f"{name}_p50_ns"] = float(st.median_ns) if st else 0.0
            x[f"{name}_p99_ns"] = float(st.p99_ns) if st else 0.0
            x[f"{name}_mean_ns"] = float(st.mean_ns) if st else 0.0
            x[f"{name}_count"] = float(rec.count)
        for gi, sc in enumerate(scs):
            _merge_extras(x,
                          {f"g{gi}_{k}": v for k, v in sc.extras().items()},
                          f"client {gi} serving extras")
        return rep
