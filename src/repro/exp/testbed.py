"""Testbed builder: one :class:`ExperimentConfig` → live pool / EthDevs /
server / load generator.

The server stack is chosen from a **registry** keyed by
``StackConfig.kind`` — ``bypass`` / ``pipeline`` / ``kernel`` ship built in,
and scenario PRs can :func:`register_stack` new ones without touching this
module (the gem5-stdlib/SimBricks extension point).
"""
from __future__ import annotations

from dataclasses import replace
from typing import Callable, Dict, List, Optional, Sequence

from repro.core import (BurstPlan, BypassL2FwdServer, EthConf, EthDev,
                        EventScheduler, KernelStackServer, LoadGen,
                        NetworkStack, PacketPool, PipelineServer,
                        QueueTelemetry, SimClock)

from .config import CostConfig, DcaConfig, ExperimentConfig, StackConfig

StackFactory = Callable[[StackConfig, Sequence[EthDev]], NetworkStack]

_STACKS: Dict[str, StackFactory] = {}


def register_stack(kind: str) -> Callable[[StackFactory], StackFactory]:
    """Register a server-stack factory under ``StackConfig.kind == kind``."""

    def deco(fn: StackFactory) -> StackFactory:
        _STACKS[kind] = fn
        return fn

    return deco


def stack_kinds() -> List[str]:
    return sorted(_STACKS)


def build_stack(cfg: StackConfig, devs: Sequence[EthDev]) -> NetworkStack:
    """Resolve ``cfg.kind`` through the registry and build the server — the
    one lookup point shared by :class:`Testbed` and the topology builder."""
    if cfg.kind not in _STACKS:
        raise ValueError(
            f"unknown stack kind {cfg.kind!r}; registered: {stack_kinds()}")
    return _STACKS[cfg.kind](cfg, devs)


@register_stack("bypass")
def _build_bypass(cfg: StackConfig, devs: Sequence[EthDev]) -> NetworkStack:
    plan = (BurstPlan(burst_size=cfg.burst_size, per_lcore=cfg.per_lcore_bursts)
            if cfg.per_lcore_bursts is not None else None)
    return BypassL2FwdServer(list(devs), burst_size=cfg.burst_size,
                             n_lcores=cfg.n_lcores, plan=plan)


def effective_stack_config(stack: StackConfig,
                           dca: Optional[DcaConfig]) -> StackConfig:
    """Fold a :class:`DcaConfig`'s burst plan into the stack config (DCA
    overrides the legacy burst knobs) — shared by Testbed and Cluster."""
    if dca is None:
        return stack
    return replace(stack, burst_size=dca.burst_size,
                   per_lcore_bursts=dca.per_lcore_bursts)


def effective_writeback_threshold(dca: Optional[DcaConfig],
                                  legacy: Optional[int],
                                  queue_id: int = 0) -> Optional[int]:
    """One RX ring's writeback threshold: the DcaConfig centralizes the
    descriptor-path knobs and overrides the per-port legacy value; a
    per-queue entry (``dca.per_queue_writeback_thresholds``) in turn
    overrides the DcaConfig-global threshold for its queue."""
    return dca.threshold_for(queue_id) if dca is not None else legacy


def apply_dca(dca: Optional[DcaConfig], devs: Sequence[EthDev],
              server: NetworkStack, sched: EventScheduler) -> None:
    """Arm the sim-time DCA model on built devices + stack: writeback-timeout
    timers on every RX ring (ITR analogue, events on ``sched``) and Fig. 4
    accumulate-then-forward on stacks that support it, both bounded by the
    same ``writeback_timeout_ns``.  One code path for single-host testbeds
    and topology nodes, so the two can never diverge on the same DcaConfig."""
    if dca is None:
        return
    for dev in devs:
        dev.attach_dca(sched, dca.writeback_timeout_ns, dca.writeback_dma_ns)
    if hasattr(server, "enable_dca_accumulate"):
        server.enable_dca_accumulate(dca.writeback_timeout_ns)


@register_stack("pipeline")
def _build_pipeline(cfg: StackConfig, devs: Sequence[EthDev]) -> NetworkStack:
    return PipelineServer(devs[0], burst_size=cfg.burst_size,
                          stage_ring_capacity=cfg.stage_ring_capacity)


@register_stack("kernel")
def _build_kernel(cfg: StackConfig, devs: Sequence[EthDev]) -> NetworkStack:
    cost = cfg.cost.to_host_cost_model() if cfg.cost is not None else None
    return KernelStackServer(list(devs), cost_model=cost,
                             sockbuf_budget=cfg.sockbuf_budget,
                             sockbuf_capacity=cfg.sockbuf_capacity,
                             n_lcores=cfg.n_lcores)


class Testbed:
    """Live experiment objects built from one config; the single assembly
    point that replaces the hand-wired pool → rings → Port → server → LoadGen
    setup every benchmark used to copy-paste."""

    __test__ = False  # name starts with "Test" but this is not a test class

    def __init__(self, cfg: ExperimentConfig, pool: PacketPool,
                 devs: List[EthDev], server: NetworkStack, loadgen: LoadGen,
                 clock: Optional[SimClock] = None,
                 sched: Optional[EventScheduler] = None):
        self.cfg = cfg
        self.pool = pool
        self.devs = devs
        self.server = server
        self.loadgen = loadgen
        self.clock = clock  # the testbed's virtual time (None == wall clock)
        self.sched = sched  # event queue on that clock (writeback timers &c.)
        self.telemetry = QueueTelemetry()

    @property
    def ports(self) -> List[EthDev]:
        """The wire-side devices (EthDevs are drop-ins for legacy Ports)."""
        return self.devs

    @classmethod
    def build(cls, cfg: ExperimentConfig) -> "Testbed":
        pool = PacketPool(cfg.pool.n_slots, cfg.pool.slot_size)
        devs: List[EthDev] = []
        for dev_id, pc in enumerate(cfg.ports):
            dev = EthDev(pool, dev_id=dev_id).configure(EthConf(
                n_rx_queues=pc.n_queues, n_tx_queues=pc.n_queues,
                rss_key=pc.rss.key, rss_table_size=pc.rss.table_size,
                link_gbps=pc.link.gbps, link_latency_ns=pc.link.latency_ns))
            for q in range(pc.n_queues):
                thr = effective_writeback_threshold(
                    cfg.dca, pc.writeback_threshold, q)
                dev.rx_queue_setup(q, pc.ring_size, writeback_threshold=thr)
                dev.tx_queue_setup(q, pc.ring_size)
            devs.append(dev.dev_start())
        server = build_stack(effective_stack_config(cfg.stack, cfg.dca), devs)
        clock: Optional[SimClock] = None
        sched: Optional[EventScheduler] = None
        if cfg.traffic.sim_time:
            # one virtual clock per testbed: the loadgen advances it, the
            # server charges lcore busy-time against it, and one event queue
            # on that clock carries NIC-side timers
            clock = SimClock()
            sched = EventScheduler(clock)
            if hasattr(server, "attach_clock"):
                cost = (cfg.stack.cost if cfg.stack.cost is not None
                        else CostConfig())
                server.attach_clock(clock, cost.to_host_cost_model())
            apply_dca(cfg.dca, devs, server, sched)
        t = cfg.traffic
        loadgen = LoadGen(devs, ts_offset=t.ts_offset,
                          verify_integrity=t.verify_integrity,
                          max_tx_burst=t.max_tx_burst, n_flows=t.n_flows)
        return cls(cfg, pool, devs, server, loadgen, clock=clock, sched=sched)

    def xstats(self) -> Dict[str, int]:
        """Merged extended stats over every device, DPDK-named with a
        ``d{dev}_`` prefix."""
        out: Dict[str, int] = {}
        for dev in self.devs:
            for k, v in dev.xstats().items():
                out[f"d{dev.dev_id}_{k}"] = v
        return out
