"""Frozen, declarative experiment configs — the orchestration contract.

Every testbed this repo can build (pool → EthDevs → server stack → load
generator → telemetry) is described by one :class:`ExperimentConfig`: a tree
of frozen dataclasses that round-trips losslessly through plain dicts
(``cfg == ExperimentConfig.from_dict(cfg.to_dict())``), so experiments can be
stored as JSON, diffed, swept programmatically, and reproduced exactly —
the SimBricks/gem5-stdlib lesson applied to this repo.

The configs are *pure data*: nothing here imports the dataplane.  Building
live objects from a config is :mod:`repro.exp.testbed`'s job; running one is
:func:`repro.exp.runner.run_experiment`'s.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, field, fields, replace
from typing import Any, Dict, List, Optional, Tuple

from repro.core.cost import HostCostModel
from repro.core.loadgen import TRAFFIC_KINDS
from repro.core.packet import DEFAULT_MTU, DEFAULT_TS_OFFSET
from repro.core.rss import DEFAULT_TABLE_SIZE

TRAFFIC_MODES = ("open_loop", "closed_loop", "msb")
TRAFFIC_ENGINES = ("event", "epoch", "epoch-jit")
# the switch pipeline's AQM stage policies (repro.core.switch)
AQM_KINDS = ("drop-tail", "red", "ecn")
# loadgen congestion control: fixed offered rate (the paper's EtherLoadGen)
# or DCTCP-style multiplicative adaptation on CE-mark/loss feedback
CC_MODES = ("fixed", "dctcp")
# how a topology's event loop executes: one shared SimClock (reference),
# per-domain clocks synchronized in link-latency epochs (SimBricks,
# arXiv:2012.14219), or the same partitioning spread across worker processes.
# All three produce bit-identical RunReports; the knob only trades wall time.
PARTITION_MODES = ("shared-clock", "partitioned", "partitioned-mp")


def _plain(value: Any) -> Any:
    """Recursively convert a config value to JSON-safe plain data."""
    if hasattr(value, "to_dict"):
        return value.to_dict()
    if isinstance(value, tuple):
        return [_plain(v) for v in value]
    return value


def _config_to_dict(cfg: Any) -> Dict[str, Any]:
    return {f.name: _plain(getattr(cfg, f.name)) for f in fields(cfg)}


@dataclass(frozen=True)
class PoolConfig:
    """The packet arena (DPDK mempool / pinned hugepages analogue)."""

    n_slots: int = 16384
    slot_size: int = DEFAULT_MTU

    def __post_init__(self) -> None:
        if self.n_slots < 1 or self.slot_size < 64:
            raise ValueError("pool needs >= 1 slot of >= 64 bytes")

    def to_dict(self) -> Dict[str, Any]:
        return _config_to_dict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "PoolConfig":
        return cls(**d)


@dataclass(frozen=True)
class RssConfig:
    """RSS steering: indirection-table size + optional key override.

    The key is carried as a hex string so configs stay JSON-safe; ``None``
    means the Microsoft default key.
    """

    table_size: int = DEFAULT_TABLE_SIZE
    key_hex: Optional[str] = None

    def __post_init__(self) -> None:
        if self.key_hex is not None:
            if len(bytes.fromhex(self.key_hex)) < 16:
                raise ValueError("RSS key must be at least 16 bytes")

    @property
    def key(self) -> Optional[bytes]:
        return None if self.key_hex is None else bytes.fromhex(self.key_hex)

    def to_dict(self) -> Dict[str, Any]:
        return _config_to_dict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "RssConfig":
        return cls(**d)


@dataclass(frozen=True)
class LinkConfig:
    """The wire attached to one port (virtual-time semantics).

    ``gbps`` is the serialization rate — a frame occupies the wire for
    ``bytes*8/gbps`` ns, and back-to-back frames queue FIFO behind it —
    and ``latency_ns`` is one-way propagation.  ``gbps <= 0`` models an
    ideal (infinitely fast) wire, the pre-SimClock behaviour.  The default
    is a 100GbE link with 1 µs of cable+PHY latency, the paper's testbed
    fabric.  Ignored in wall-clock mode, where the host *is* the wire.
    """

    gbps: float = 100.0
    latency_ns: int = 1_000

    def __post_init__(self) -> None:
        if self.latency_ns < 0:
            raise ValueError("latency_ns must be >= 0")

    def to_dict(self) -> Dict[str, Any]:
        return _config_to_dict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "LinkConfig":
        return cls(**d)


@dataclass(frozen=True)
class PortConfig:
    """One NIC device: queue count, per-queue ring size, writeback threshold
    (the paper's §3.1.4 parameter), RSS, and the attached link."""

    n_queues: int = 1
    ring_size: int = 1024
    writeback_threshold: Optional[int] = 32
    rss: RssConfig = field(default_factory=RssConfig)
    link: LinkConfig = field(default_factory=LinkConfig)

    def __post_init__(self) -> None:
        if self.n_queues < 1:
            raise ValueError("n_queues must be >= 1")
        if self.ring_size < 1:
            raise ValueError("ring_size must be >= 1")

    def to_dict(self) -> Dict[str, Any]:
        return _config_to_dict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "PortConfig":
        d = dict(d)
        d["rss"] = RssConfig.from_dict(d.get("rss", {}))
        d["link"] = LinkConfig.from_dict(d.get("link", {}))
        return cls(**d)


@dataclass(frozen=True)
class DcaConfig:
    """The paper's §3.1.4/§5.2 DCA knobs, as one sim-time unit.

    When set on an :class:`ExperimentConfig` (or :class:`NodeConfig`), the
    descriptor path runs the full virtual-time DCA model and these values
    override the scattered legacy knobs (``PortConfig.writeback_threshold``,
    ``StackConfig.burst_size``/``per_lcore_bursts``):

    * ``writeback_threshold`` — completions per descriptor-cache writeback
      DMA (``None`` == the pathological pre-fix "whole ring" behaviour);
    * ``writeback_timeout_ns`` — the ITR analogue: an idle timer (an
      :class:`~repro.core.simclock.EventScheduler` event) flushes cached
      completions this long after the first one arrives, bounding how long a
      frame can sit PMD-invisible.  The same bound caps how long a bypass
      lcore accumulates toward a full burst before forwarding a partial one
      (Fig. 4's tail-of-train case);
    * ``burst_size`` / ``per_lcore_bursts`` — the L2Fwd processing burst the
      paper's Fig. 4 sweeps: in DCA mode the bypass stack *accumulates* a
      full burst of written-back descriptors before forwarding, so this knob
      moves measured RTT percentiles end-to-end.

    Requires ``traffic.sim_time`` — the writeback timer and accumulation
    deadline are virtual-time events.
    """

    burst_size: int = 32
    writeback_threshold: Optional[int] = 32
    writeback_timeout_ns: int = 200_000
    # modeled writeback DMA transfer time: descriptors become PMD-visible
    # this many ns after the threshold crossing starts the writeback
    # (0 == instantaneous — bit-identical to pre-DMA legacy reports)
    writeback_dma_ns: int = 0
    per_lcore_bursts: Optional[Tuple[int, ...]] = None
    # per-RX-queue writeback thresholds (index == queue id); entries override
    # ``writeback_threshold`` for their queue, ``None`` entries fall through
    # to it.  Must match the port's queue count — validated where the config
    # meets a PortConfig (ExperimentConfig/NodeConfig __post_init__).
    per_queue_writeback_thresholds: Optional[Tuple[Optional[int], ...]] = None

    def __post_init__(self) -> None:
        if self.burst_size < 1:
            raise ValueError("burst_size must be >= 1")
        if self.writeback_threshold is not None and self.writeback_threshold < 1:
            raise ValueError("writeback_threshold must be >= 1 or None")
        if self.per_queue_writeback_thresholds is not None:
            if len(self.per_queue_writeback_thresholds) == 0:
                raise ValueError(
                    "per_queue_writeback_thresholds must be nonempty or None")
            for q, thr in enumerate(self.per_queue_writeback_thresholds):
                if thr is not None and thr < 1:
                    raise ValueError(
                        f"per_queue_writeback_thresholds[{q}]={thr} "
                        "must be >= 1 or None")
        if self.writeback_timeout_ns < 1:
            # 0 would mean "never flush" at the NIC timer but "give up
            # immediately" at the PMD — opposite semantics for one knob.
            # The timeout is the model's latency bound; it must exist.
            raise ValueError(
                "writeback_timeout_ns must be >= 1 (it bounds how long a "
                "completion can sit PMD-invisible; to make timeouts "
                "irrelevant use a small writeback_threshold instead)")
        if self.writeback_dma_ns < 0:
            raise ValueError("writeback_dma_ns must be >= 0")
        if self.per_lcore_bursts is not None and (
                len(self.per_lcore_bursts) == 0
                or any(b < 1 for b in self.per_lcore_bursts)):
            raise ValueError("per_lcore_bursts must be a nonempty tuple of >= 1")

    def max_burst(self) -> int:
        """Largest burst any lcore can be asked to accumulate."""
        if self.per_lcore_bursts is not None:
            return max(self.per_lcore_bursts)
        return self.burst_size

    def threshold_for(self, queue_id: int) -> Optional[int]:
        """The effective writeback threshold for one RX queue: the per-queue
        entry when set (and not None), else the global threshold."""
        if self.per_queue_writeback_thresholds is not None:
            if not 0 <= queue_id < len(self.per_queue_writeback_thresholds):
                raise ValueError(
                    f"queue_id={queue_id} out of range for "
                    f"{len(self.per_queue_writeback_thresholds)} per-queue "
                    "writeback thresholds")
            per_q = self.per_queue_writeback_thresholds[queue_id]
            if per_q is not None:
                return per_q
        return self.writeback_threshold

    def validate_queues(self, n_queues: int, what: str) -> None:
        """A per-queue threshold list must cover the port's queues exactly —
        a silent length mismatch would leave queues on the wrong knob."""
        if (self.per_queue_writeback_thresholds is not None
                and len(self.per_queue_writeback_thresholds) != n_queues):
            raise ValueError(
                f"dca.per_queue_writeback_thresholds has "
                f"{len(self.per_queue_writeback_thresholds)} entries but "
                f"{what} port has n_queues={n_queues}")

    def validate_ring(self, ring_size: int, what: str) -> None:
        """A threshold or accumulation burst larger than the ring can never
        be reached — the sweep knob would silently degenerate to
        timeout-only publication/forwarding, so reject it at config time."""
        if (self.writeback_threshold is not None
                and self.writeback_threshold > ring_size):
            raise ValueError(
                f"dca.writeback_threshold={self.writeback_threshold} "
                f"exceeds {what} ring_size={ring_size}")
        if self.per_queue_writeback_thresholds is not None:
            for q, thr in enumerate(self.per_queue_writeback_thresholds):
                if thr is not None and thr > ring_size:
                    raise ValueError(
                        f"dca.per_queue_writeback_thresholds[{q}]={thr} "
                        f"exceeds {what} ring_size={ring_size}")
        if self.max_burst() > ring_size:
            raise ValueError(
                f"dca burst_size={self.max_burst()} exceeds {what} "
                f"ring_size={ring_size}; a full burst could never "
                "accumulate (every forward would wait out the timeout)")

    def to_dict(self) -> Dict[str, Any]:
        return _config_to_dict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "DcaConfig":
        d = dict(d)
        if d.get("per_lcore_bursts") is not None:
            d["per_lcore_bursts"] = tuple(d["per_lcore_bursts"])
        if d.get("per_queue_writeback_thresholds") is not None:
            d["per_queue_writeback_thresholds"] = tuple(
                d["per_queue_writeback_thresholds"])
        return cls(**d)


@dataclass(frozen=True)
class CostConfig:
    """Host-cost model (mirrors :class:`repro.core.cost.HostCostModel`); the
    Fig. 3(b) knobs.  The ``pmd_*`` figures price the polling path in
    virtual-time mode only (in wall-clock mode the PMD's real code is its
    own cost — the paper's asymmetry)."""

    cpu_ghz: float = 2.0
    interrupt_cycles: int = 8000
    syscall_cycles: int = 1400
    per_packet_kernel_cycles: int = 2500
    pmd_poll_cycles: int = 150
    pmd_per_packet_cycles: int = 1100

    def to_host_cost_model(self) -> HostCostModel:
        return HostCostModel(**asdict(self))

    @classmethod
    def from_host_cost_model(cls, m: HostCostModel) -> "CostConfig":
        return cls(cpu_ghz=m.cpu_ghz, interrupt_cycles=m.interrupt_cycles,
                   syscall_cycles=m.syscall_cycles,
                   per_packet_kernel_cycles=m.per_packet_kernel_cycles,
                   pmd_poll_cycles=m.pmd_poll_cycles,
                   pmd_per_packet_cycles=m.pmd_per_packet_cycles)

    def to_dict(self) -> Dict[str, Any]:
        return _config_to_dict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "CostConfig":
        return cls(**d)


@dataclass(frozen=True)
class StackConfig:
    """Which server stack processes packets, and its knobs.

    ``kind`` selects from the stack registry (:mod:`repro.exp.testbed`):
    ``bypass`` (run-to-completion DPDK L2Fwd), ``pipeline`` (rx→work→tx stage
    lcores), ``kernel`` (the interrupt-driven baseline), or any kind a
    scenario registered via :func:`repro.exp.register_stack`.  Kind names are
    resolved at build time so configs stay pure data.
    """

    kind: str = "bypass"
    burst_size: int = 64
    n_lcores: Optional[int] = None           # None == one lcore per queue
    per_lcore_bursts: Optional[Tuple[int, ...]] = None  # BurstPlan override
    sockbuf_budget: int = 16                 # kernel stack: pkts per read()
    sockbuf_capacity: int = 512              # kernel stack: rmem cap (skbs)
    stage_ring_capacity: int = 1024          # pipeline stack: SPSC ring depth
    # modeled host costs: the kernel stack's syscall/IRQ figures in both
    # timing modes, plus the pmd_* figures pricing polling stacks in
    # virtual time.  None == CostConfig() defaults.
    cost: Optional[CostConfig] = None

    def __post_init__(self) -> None:
        if self.burst_size < 1:
            raise ValueError("burst_size must be >= 1")

    def to_dict(self) -> Dict[str, Any]:
        return _config_to_dict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "StackConfig":
        d = dict(d)
        if d.get("cost") is not None:
            d["cost"] = CostConfig.from_dict(d["cost"])
        if d.get("per_lcore_bursts") is not None:
            d["per_lcore_bursts"] = tuple(d["per_lcore_bursts"])
        return cls(**d)


@dataclass(frozen=True)
class TrafficConfig:
    """What the load generator offers, and how the run is driven.

    Modes:

    * ``open_loop`` — paced offered load (``rate_gbps``/``kind``) for
      ``duration_s``; the EtherLoadGen measurement mode.
    * ``closed_loop`` — exactly ``n_packets`` with ``window`` in flight;
      deterministic, the conservation-test mode.
    * ``msb`` — the bandwidth-test mode: ramp + bisect to the maximum
      sustainable bandwidth (``start_gbps``/``max_gbps``/``trial_s``/
      ``refine_iters``/``drop_tolerance_pct``).

    ``sim_time`` (default on) runs the experiment on a
    :class:`~repro.core.simclock.SimClock`: durations are *virtual* seconds,
    results are deterministic and host-independent, and host costs are
    charged to lcore busy-time.  Turn it off to pace against the host clock
    (the seed behaviour) for host-overhead studies.

    ``engine`` picks how virtual-time open-loop trials are advanced:
    ``"epoch"`` (default) runs the epoch-batched fast path
    (:func:`repro.core.fastpath.run_epoch_sim` — whole-array passes,
    bit-identical reports, automatic fallback to the event loop for configs
    it cannot prove exact); ``"epoch-jit"`` additionally jit-compiles the
    inner pass with JAX when available; ``"event"`` forces the per-event
    reference loop.  Ignored in wall-clock mode.
    """

    mode: str = "open_loop"
    packet_size: int = 1518
    sim_time: bool = True
    engine: str = "epoch"
    # open_loop
    rate_gbps: float = 1.0
    kind: str = "uniform"                    # uniform | poisson | bursty
    burst_len: int = 32
    duration_s: float = 0.25
    drain_timeout_s: float = 0.5
    seed: int = 0
    # closed_loop
    n_packets: int = 1000
    window: int = 32
    payload_seed: Optional[int] = None       # rng-filled payloads when set
    # msb
    start_gbps: float = 0.25
    max_gbps: float = 400.0
    trial_s: float = 0.2
    refine_iters: int = 5
    drop_tolerance_pct: float = 0.0
    # loadgen knobs (all modes)
    n_flows: int = 256
    ts_offset: int = DEFAULT_TS_OFFSET
    verify_integrity: bool = False
    max_tx_burst: int = 64
    # congestion control (open_loop + sim_time): "fixed" offers rate_gbps
    # unconditionally; "dctcp" starts at rate_gbps and adapts it per
    # cc_window_ns of virtual time from the fraction of CE-marked/lost
    # echoes (alpha = (1-g)*alpha + g*F; marked window: rate *= 1-alpha/2,
    # clean window: rate += cc_increase_gbps — AIMD, so competing clients
    # converge to a fair share), clamped to
    # [cc_min_gbps, the attached link rate]
    # cc_max_inflight is the TX-credit/cwnd analogue: a client never has
    # more than this many frames outstanding (0 == uncapped).  Rate pacing
    # alone keeps pouring into the bottleneck queue for a full feedback
    # delay after an overshoot; the in-flight cap is the ack-clocked
    # backpressure that stops it immediately.
    cc_mode: str = "fixed"
    cc_window_ns: int = 100_000
    cc_gain: float = 0.0625
    cc_min_gbps: float = 0.05
    cc_increase_gbps: float = 0.25
    cc_max_inflight: int = 0

    def __post_init__(self) -> None:
        if self.mode not in TRAFFIC_MODES:
            raise ValueError(f"traffic mode must be one of {TRAFFIC_MODES}")
        if self.engine not in TRAFFIC_ENGINES:
            raise ValueError(
                f"traffic engine must be one of {TRAFFIC_ENGINES}")
        if self.kind not in TRAFFIC_KINDS:
            raise ValueError(f"traffic kind must be one of {TRAFFIC_KINDS}")
        if self.packet_size < 64:
            raise ValueError("packet_size must be >= 64 (MIN_FRAME)")
        if self.cc_mode not in CC_MODES:
            raise ValueError(f"cc_mode must be one of {CC_MODES}")
        if self.cc_mode != "fixed":
            if self.mode != "open_loop" or not self.sim_time:
                raise ValueError(
                    "cc_mode='dctcp' needs open_loop traffic in sim time "
                    "(rates adapt per virtual-time window)")
            if self.cc_window_ns < 1:
                raise ValueError("cc_window_ns must be >= 1")
            if not 0.0 < self.cc_gain <= 1.0:
                raise ValueError("cc_gain must be in (0, 1]")
            if self.cc_min_gbps <= 0.0:
                raise ValueError("cc_min_gbps must be > 0")
            if self.cc_increase_gbps <= 0.0:
                raise ValueError("cc_increase_gbps must be > 0")
            if self.cc_max_inflight < 0:
                raise ValueError("cc_max_inflight must be >= 0 (0 uncapped)")

    def to_dict(self) -> Dict[str, Any]:
        return _config_to_dict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "TrafficConfig":
        return cls(**d)


@dataclass(frozen=True)
class ExperimentConfig:
    """One complete, reproducible experiment: pool + devices + stack +
    traffic.  ``from_dict(to_dict())`` round-trips exactly."""

    name: str = "experiment"
    pool: PoolConfig = field(default_factory=PoolConfig)
    ports: Tuple[PortConfig, ...] = (PortConfig(),)
    stack: StackConfig = field(default_factory=StackConfig)
    traffic: TrafficConfig = field(default_factory=TrafficConfig)
    # sim-time DCA model (writeback threshold/timeout + processing burst);
    # None == legacy behaviour (synchronous thresholds, no timers, no
    # burst accumulation)
    dca: Optional[DcaConfig] = None

    def __post_init__(self) -> None:
        if not self.ports:
            raise ValueError("need at least one port")
        if self.stack.kind == "pipeline" and len(self.ports) != 1:
            raise ValueError("the pipeline stack drives exactly one port")
        if self.dca is not None:
            if not self.traffic.sim_time:
                raise ValueError(
                    "DcaConfig is a virtual-time model; it needs "
                    "traffic.sim_time=True")
            for p in self.ports:
                self.dca.validate_ring(p.ring_size, "a port's")
                self.dca.validate_queues(p.n_queues, "a")

    def to_dict(self) -> Dict[str, Any]:
        return _config_to_dict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ExperimentConfig":
        d = dict(d)
        d["pool"] = PoolConfig.from_dict(d.get("pool", {}))
        d["ports"] = tuple(PortConfig.from_dict(p) for p in d.get("ports", [{}]))
        d["stack"] = StackConfig.from_dict(d.get("stack", {}))
        d["traffic"] = TrafficConfig.from_dict(d.get("traffic", {}))
        if d.get("dca") is not None:
            d["dca"] = DcaConfig.from_dict(d["dca"])
        return cls(**d)

    # replace() helpers keep sweep code terse: cfg.with_traffic(rate_gbps=2.0)
    def with_stack(self, **kw: Any) -> "ExperimentConfig":
        return replace(self, stack=replace(self.stack, **kw))

    def with_traffic(self, **kw: Any) -> "ExperimentConfig":
        return replace(self, traffic=replace(self.traffic, **kw))

    def with_ports(self, **kw: Any) -> "ExperimentConfig":
        return replace(self, ports=tuple(replace(p, **kw) for p in self.ports))

    def with_dca(self, **kw: Any) -> "ExperimentConfig":
        """Sweep helper: override fields of ``dca`` (starting from defaults
        when unset) — ``cfg.with_dca(burst_size=1024)``."""
        base = self.dca if self.dca is not None else DcaConfig()
        return replace(self, dca=replace(base, **kw))


# -- multi-host topologies ----------------------------------------------------

@dataclass(frozen=True)
class AqmConfig:
    """One egress port's active-queue-management policy (the pipeline's AQM
    stage — :class:`repro.core.switch.AqmRed`).

    ``kind``: ``"drop-tail"`` (no policy object installed — bit-identical to
    the pre-pipeline switch), ``"red"`` (probabilistic early drop on the
    classic RED curve over instantaneous queue depth), or ``"ecn"`` (the same
    curve applied as a CE mark instead of a drop — the DCTCP fabric half).
    ``seed`` feeds the deterministic counter-seeded per-port RNG stream.
    """

    kind: str = "drop-tail"
    min_thresh: int = 8
    max_thresh: int = 24
    max_p: float = 0.1
    seed: int = 0

    def __post_init__(self) -> None:
        if self.kind not in AQM_KINDS:
            raise ValueError(f"aqm kind must be one of {AQM_KINDS}")
        if not 1 <= self.min_thresh <= self.max_thresh:
            raise ValueError("need 1 <= min_thresh <= max_thresh")
        if not 0.0 < self.max_p <= 1.0:
            raise ValueError("max_p must be in (0, 1]")

    def to_dict(self) -> Dict[str, Any]:
        return _config_to_dict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "AqmConfig":
        return cls(**d)


@dataclass(frozen=True)
class PipelineConfig:
    """The per-port forwarding pipeline's configurable stages.

    ``classify`` names the match key the parse stage extracts (``"dst-ip"``
    is the only key today — the flow dst_ip the LPM table routes on).
    ``aqm`` is the default AQM policy applied to **every** egress port;
    ``per_port_aqm`` (index == port id, entries may be None == fall through
    to ``aqm``) overrides it per port — e.g. RED only on the hot incast
    egress.  Length is validated at build time against the actual port
    count, which a config cannot know (ports = nodes + clients [+ trunk]).
    """

    classify: str = "dst-ip"
    aqm: AqmConfig = field(default_factory=AqmConfig)
    per_port_aqm: Optional[Tuple[Optional[AqmConfig], ...]] = None

    def __post_init__(self) -> None:
        if self.classify != "dst-ip":
            raise ValueError("classify must be 'dst-ip'")
        if self.per_port_aqm is not None and len(self.per_port_aqm) == 0:
            raise ValueError("per_port_aqm must be nonempty or None")

    def aqm_for(self, port_id: int) -> AqmConfig:
        """The effective policy for one port (per-port override or default)."""
        if self.per_port_aqm is not None and \
                0 <= port_id < len(self.per_port_aqm):
            per = self.per_port_aqm[port_id]
            if per is not None:
                return per
        return self.aqm

    def to_dict(self) -> Dict[str, Any]:
        return _config_to_dict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "PipelineConfig":
        d = dict(d)
        d["aqm"] = AqmConfig.from_dict(d.get("aqm", {}))
        if d.get("per_port_aqm") is not None:
            d["per_port_aqm"] = tuple(
                None if e is None else AqmConfig.from_dict(e)
                for e in d["per_port_aqm"])
        return cls(**d)


@dataclass(frozen=True)
class SwitchConfig:
    """The fabric: an output-queued switch whose ports all carry ``link``
    (full duplex) and buffer at most ``egress_capacity`` frames per egress
    port (drop-tail — the incast loss mechanism).

    ``pipeline`` (optional) configures the per-port forwarding pipeline's
    AQM stage; ``None`` keeps pure drop-tail, bit-identical to pre-pipeline
    reports.  ``trunk`` (optional) turns the fabric into **two** switches
    joined by a trunk link carrying ``trunk`` timing — set ``trunk.gbps``
    below the aggregate endpoint rate for an oversubscribed core.  Endpoint
    placement defaults to nodes on switch 0 / clients on switch 1 and is
    overridden by ``TopologyConfig.node_switch``/``client_switch``.
    """

    egress_capacity: int = 64
    link: LinkConfig = field(default_factory=LinkConfig)
    pipeline: Optional[PipelineConfig] = None
    trunk: Optional[LinkConfig] = None

    def __post_init__(self) -> None:
        if self.egress_capacity < 1:
            raise ValueError("egress_capacity must be >= 1 frame")

    def to_dict(self) -> Dict[str, Any]:
        return _config_to_dict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "SwitchConfig":
        d = dict(d)
        d["link"] = LinkConfig.from_dict(d.get("link", {}))
        if d.get("pipeline") is not None:
            d["pipeline"] = PipelineConfig.from_dict(d["pipeline"])
        if d.get("trunk") is not None:
            d["trunk"] = LinkConfig.from_dict(d["trunk"])
        return cls(**d)


@dataclass(frozen=True)
class NodeConfig:
    """One simulated host on the fabric: its own packet arena, one NIC, and
    a server stack.  ``ip`` is the node's address on the fabric (what the
    switch routes on); 0 auto-assigns ``192.168.0.(index+1)`` at build time.
    The NIC's own ``PortConfig.link`` is ignored in a topology — the switch
    port's wires carry the link timing."""

    name: str = "node"
    ip: int = 0
    pool: PoolConfig = field(default_factory=PoolConfig)
    port: PortConfig = field(default_factory=PortConfig)
    stack: StackConfig = field(default_factory=StackConfig)
    # sim-time DCA model for this node's NIC/stack (topologies always run in
    # virtual time, so no sim_time gate is needed here)
    dca: Optional[DcaConfig] = None

    def __post_init__(self) -> None:
        if not 0 <= self.ip <= 0xFFFFFFFF:
            raise ValueError("ip must be a u32 (0 == auto-assign)")
        if self.dca is not None:
            self.dca.validate_ring(self.port.ring_size, "the node's")
            self.dca.validate_queues(self.port.n_queues, "the node's")

    def to_dict(self) -> Dict[str, Any]:
        return _config_to_dict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "NodeConfig":
        d = dict(d)
        d["pool"] = PoolConfig.from_dict(d.get("pool", {}))
        d["port"] = PortConfig.from_dict(d.get("port", {}))
        d["stack"] = StackConfig.from_dict(d.get("stack", {}))
        if d.get("dca") is not None:
            d["dca"] = DcaConfig.from_dict(d["dca"])
        return cls(**d)


@dataclass(frozen=True)
class TopologyConfig:
    """One complete multi-host scenario: N server nodes and N fabric-attached
    load-generator clients around one switch, all on one shared SimClock.

    ``traffic`` describes each client's *individual* offered load (mode must
    be ``open_loop`` and ``sim_time`` must stay on — topologies are a
    virtual-time construction); client ``g`` derives its emission schedule
    from ``traffic.seed + g``, so the scenario stays deterministic while
    clients stay decorrelated.  ``target`` names the node all clients send to
    ("" == the first node) — the N:1 shape of an incast.

    ``serving`` (optional) turns the scenario into an LLM-inference-serving
    cluster: clients become request populations (QPS, token-length mix) and
    the named balancer/prefill/decode nodes must carry the matching serving
    stack kinds.  ``traffic`` then only contributes duration/seed/engine
    knobs — the offered load comes from ``serving.qps``.

    ``partition`` selects the execution engine (:data:`PARTITION_MODES`):
    ``shared-clock`` is the reference event loop, ``partitioned`` gives every
    client/node/switch its own clock+scheduler advancing in link-latency
    epochs, and ``partitioned-mp`` spreads those domains across worker
    processes (``partition_workers``, 0 == one per CPU).  Reports are
    bit-identical across all three — execution knobs never touch physics, so
    they are also excluded from derived-seed fingerprints
    (:mod:`repro.exp.seeding`).  Configs the partition engine cannot prove
    equivalent (serving, zero-cost hosts, zero-latency links) fall back to
    shared-clock with the reason surfaced in ``PartitionRunInfo``.

    ``client_targets`` (optional) gives client ``g`` its own destination node
    name — an N:M traffic matrix instead of the N:1 ``target`` incast.
    """

    name: str = "topology"
    nodes: Tuple[NodeConfig, ...] = (NodeConfig(),)
    n_clients: int = 1
    client_pool: PoolConfig = field(default_factory=lambda: PoolConfig(n_slots=4096))
    switch: SwitchConfig = field(default_factory=SwitchConfig)
    traffic: TrafficConfig = field(default_factory=TrafficConfig)
    target: str = ""
    # repro.serving.ServingConfig; typed loosely to keep repro.exp importable
    # without the serving package (it imports this module back)
    serving: Optional[Any] = None
    # execution engine (never affects results — see PARTITION_MODES)
    partition: str = "shared-clock"
    partition_workers: int = 0
    # run every partitioned crossing through the PartitionSanitizer race
    # detector (also forced on by env REPRO_PARTITION_SANITIZE=1); execution
    # -only — scrubbed from seed fingerprints like partition itself
    partition_sanitize: bool = False
    # per-client destination node names (len == n_clients); None == all
    # clients send to ``target``
    client_targets: Optional[Tuple[str, ...]] = None
    # two-switch placement (requires switch.trunk): which switch (0 or 1)
    # each node/client attaches to.  None == the default split (nodes on
    # switch 0, clients on switch 1).
    node_switch: Optional[Tuple[int, ...]] = None
    client_switch: Optional[Tuple[int, ...]] = None

    def __post_init__(self) -> None:
        if not self.nodes:
            raise ValueError("need at least one node")
        if not 1 <= self.n_clients <= 255:
            raise ValueError("n_clients must be in [1, 255] (one /16 each)")
        if self.traffic.packet_size > self.client_pool.slot_size:
            raise ValueError("packet_size exceeds the client pool slot size")
        for n in self.nodes:
            if self.traffic.packet_size > n.pool.slot_size:
                raise ValueError(
                    f"packet_size exceeds node {n.name!r} pool slot size")
        names = [n.name for n in self.nodes]
        if len(set(names)) != len(names):
            raise ValueError(f"node names must be unique, got {names}")
        ips = [n.ip for n in self.nodes if n.ip != 0]
        if len(set(ips)) != len(ips):
            raise ValueError("explicit node ips must be unique")
        if self.target and self.target not in names:
            raise ValueError(f"target {self.target!r} is not a node name")
        if self.traffic.mode != "open_loop":
            raise ValueError("topology traffic mode must be open_loop")
        if not self.traffic.sim_time:
            raise ValueError("topologies run in virtual time (sim_time=True)")
        if self.partition not in PARTITION_MODES:
            raise ValueError(
                f"partition must be one of {PARTITION_MODES}, "
                f"got {self.partition!r}")
        if self.partition_workers < 0:
            raise ValueError("partition_workers must be >= 0 (0 == auto)")
        if self.client_targets is not None:
            if len(self.client_targets) != self.n_clients:
                raise ValueError(
                    f"client_targets has {len(self.client_targets)} entries "
                    f"but n_clients={self.n_clients}")
            for g, t in enumerate(self.client_targets):
                if t not in names:
                    raise ValueError(
                        f"client_targets[{g}]={t!r} is not a node name "
                        f"(have {names})")
            if self.serving is not None:
                raise ValueError(
                    "client_targets is an echo-topology knob; serving "
                    "clients address the balancer")
        for label, placement, count in (
                ("node_switch", self.node_switch, len(self.nodes)),
                ("client_switch", self.client_switch, self.n_clients)):
            if placement is None:
                continue
            if self.switch.trunk is None:
                raise ValueError(
                    f"{label} needs a two-switch fabric (switch.trunk)")
            if len(placement) != count:
                raise ValueError(
                    f"{label} has {len(placement)} entries, need {count}")
            if any(s not in (0, 1) for s in placement):
                raise ValueError(f"{label} entries must be 0 or 1")
        if self.serving is not None:
            pipe = self.switch.pipeline
            if pipe is not None and (
                    pipe.aqm.kind != "drop-tail" or pipe.per_port_aqm):
                raise ValueError(
                    "serving topologies don't support AQM marking (serving "
                    "frames carry their own header layout)")
            if self.traffic.cc_mode != "fixed":
                raise ValueError(
                    "serving topologies drive load from serving.qps; "
                    "cc_mode must stay 'fixed'")
            self._validate_serving(names)

    def _validate_serving(self, names: List[str]) -> None:
        from repro.serving.config import ServingConfig
        s = self.serving
        if not isinstance(s, ServingConfig):
            raise ValueError(
                f"serving must be a ServingConfig, got {type(s).__name__}")
        by_name = {n.name: n for n in self.nodes}
        roles = [(s.balancer, "balancer"), *[(p, "prefill") for p in s.prefill],
                 *[(d, "decode") for d in s.decode]]
        for node_name, kind in roles:
            if node_name not in by_name:
                raise ValueError(
                    f"serving {kind} node {node_name!r} is not a node name "
                    f"(have {names})")
            nc = by_name[node_name]
            if nc.stack.kind != kind:
                raise ValueError(
                    f"serving {kind} node {node_name!r} has stack kind "
                    f"{nc.stack.kind!r}; it must be {kind!r}")
            # serving nodes exchange full-size request/KV frames
            max_frame = max(s.request_frame_bytes, s.kv_segment_bytes,
                            s.token_frame_bytes)
            if max_frame > nc.pool.slot_size:
                raise ValueError(
                    f"serving frames up to {max_frame}B exceed node "
                    f"{node_name!r} pool slot size {nc.pool.slot_size}")
            # engine iterations park a node's lcore for long virtual
            # windows; frames idling below a >1 writeback threshold would
            # only surface at quiet-fabric flushes, stalling the pipeline.
            # Either expose completions immediately (threshold 1) or model
            # DCA properly (DcaConfig arms the give-up timers).
            if nc.dca is None and nc.port.writeback_threshold != 1:
                raise ValueError(
                    f"serving node {node_name!r} needs "
                    "port.writeback_threshold == 1 (or an explicit "
                    "DcaConfig with writeback timers)")
        if s.request_frame_bytes > self.client_pool.slot_size:
            raise ValueError(
                "serving request_frame_bytes exceeds the client pool slot size")

    def to_dict(self) -> Dict[str, Any]:
        return _config_to_dict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "TopologyConfig":
        d = dict(d)
        d["nodes"] = tuple(NodeConfig.from_dict(n) for n in d.get("nodes", [{}]))
        d["client_pool"] = PoolConfig.from_dict(d.get("client_pool", {}))
        d["switch"] = SwitchConfig.from_dict(d.get("switch", {}))
        d["traffic"] = TrafficConfig.from_dict(d.get("traffic", {}))
        if d.get("serving") is not None:
            from repro.serving.config import ServingConfig
            d["serving"] = ServingConfig.from_dict(d["serving"])
        if d.get("client_targets") is not None:
            d["client_targets"] = tuple(d["client_targets"])
        for key in ("node_switch", "client_switch"):
            if d.get(key) is not None:
                d[key] = tuple(d[key])
        return cls(**d)

    def with_traffic(self, **kw: Any) -> "TopologyConfig":
        return replace(self, traffic=replace(self.traffic, **kw))

    def with_switch(self, **kw: Any) -> "TopologyConfig":
        return replace(self, switch=replace(self.switch, **kw))

    def with_partition(self, mode: str, workers: int = 0,
                       sanitize: Optional[bool] = None) -> "TopologyConfig":
        kw: Dict[str, Any] = dict(partition=mode, partition_workers=workers)
        if sanitize is not None:
            kw["partition_sanitize"] = sanitize
        return replace(self, **kw)
