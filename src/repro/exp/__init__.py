# Declarative experiment layer: frozen configs -> Testbed -> RunReport.
# The API every scenario (benchmark, example, future PR) builds on.
# Multi-host scenarios: TopologyConfig -> Cluster -> RunReport.
from .config import (CostConfig, DcaConfig, ExperimentConfig, LinkConfig,
                     NodeConfig, PoolConfig, PortConfig, RssConfig,
                     StackConfig, SwitchConfig, TopologyConfig, TrafficConfig)
from .runner import (make_server_factory, run_experiment,
                     run_topology_experiment, run_testbed)
from .testbed import Testbed, build_stack, register_stack, stack_kinds
from .topology import Client, Cluster, Node

__all__ = [
    "Client", "Cluster", "CostConfig", "DcaConfig", "ExperimentConfig",
    "LinkConfig",
    "Node", "NodeConfig", "PoolConfig", "PortConfig",
    "RssConfig", "StackConfig", "SwitchConfig", "TopologyConfig",
    "TrafficConfig",
    "Testbed", "build_stack", "make_server_factory", "register_stack",
    "run_experiment", "run_testbed", "run_topology_experiment", "stack_kinds",
]
