# Declarative experiment layer: frozen configs -> Testbed -> RunReport.
# The API every scenario (benchmark, example, future PR) builds on.
from .config import (CostConfig, ExperimentConfig, LinkConfig, PoolConfig,
                     PortConfig, RssConfig, StackConfig, TrafficConfig)
from .runner import make_server_factory, run_experiment, run_testbed
from .testbed import Testbed, register_stack, stack_kinds

__all__ = [
    "CostConfig", "ExperimentConfig", "LinkConfig", "PoolConfig", "PortConfig",
    "RssConfig", "StackConfig", "TrafficConfig",
    "Testbed", "make_server_factory", "register_stack", "run_experiment",
    "run_testbed", "stack_kinds",
]
