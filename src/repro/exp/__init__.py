# Declarative experiment layer: frozen configs -> Testbed -> RunReport.
# The API every scenario (benchmark, example, future PR) builds on.
# Multi-host scenarios: TopologyConfig -> Cluster -> RunReport, under the
# shared-clock loop or the partitioned engines (PARTITION_MODES).
from .config import (AqmConfig, CostConfig, DcaConfig, ExperimentConfig,
                     LinkConfig, NodeConfig, PARTITION_MODES, PipelineConfig,
                     PoolConfig, PortConfig, RssConfig, StackConfig,
                     SwitchConfig, TopologyConfig, TrafficConfig)
from .runner import (make_server_factory, run_experiment,
                     run_topology_experiment, run_testbed)
from .seeding import config_fingerprint, derive_seed
from .testbed import Testbed, build_stack, register_stack, stack_kinds
from .topology import (Client, Cluster, Node, partition_fallback_reason,
                       run_partitioned_topology)

__all__ = [
    "AqmConfig",
    "Client", "Cluster", "CostConfig", "DcaConfig", "ExperimentConfig",
    "LinkConfig", "PipelineConfig",
    "Node", "NodeConfig", "PARTITION_MODES", "PoolConfig", "PortConfig",
    "RssConfig", "StackConfig", "SwitchConfig", "TopologyConfig",
    "TrafficConfig",
    "Testbed", "build_stack", "config_fingerprint", "derive_seed",
    "make_server_factory", "partition_fallback_reason", "register_stack",
    "run_experiment", "run_partitioned_topology", "run_testbed",
    "run_topology_experiment", "stack_kinds",
]
