"""Synthetic data pipeline feeding the kernel-bypass dataplane.

Deterministic, seeded, shardable token streams (the "corpus"): each port of
the BypassDataplane pulls batches from its own stream slice, so multi-port
ingest is reproducible and restart-exact — after a crash, `skip_steps`
fast-forwards the stream to the checkpointed step (the paper's loadgen
replays traces the same way).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Any, Dict, Iterator, Optional

import numpy as np

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 0
    # synthetic corpus parameters: a mixture of zipfian unigrams and short
    # repeated motifs so the LM loss actually decreases during examples
    zipf_alpha: float = 1.1
    motif_len: int = 16
    motif_prob: float = 0.5


def _rng_for(seed: int, port: int, step: int) -> np.random.Generator:
    mix = hashlib.blake2s(f"{seed}:{port}:{step}".encode(),
                          digest_size=8).digest()
    return np.random.default_rng(int.from_bytes(mix, "little"))


def synth_tokens(cfg: ModelConfig, dcfg: DataConfig, port: int, n_ports: int,
                 step: int) -> Dict[str, np.ndarray]:
    """One host batch (this port's slice of the global batch)."""
    rng = _rng_for(dcfg.seed, port, step)
    B = dcfg.global_batch // n_ports
    S = dcfg.seq_len
    V = cfg.vocab_size

    # zipfian unigram stream
    ranks = rng.zipf(dcfg.zipf_alpha, size=(B, S + 1)).astype(np.int64)
    toks = np.minimum(ranks, V - 1).astype(np.int32)
    # inject repeated motifs (predictable structure for the loss to learn)
    n_motifs = max(1, S // (4 * dcfg.motif_len))
    motif = rng.integers(0, V, size=(B, dcfg.motif_len), dtype=np.int32)
    for _ in range(n_motifs):
        if rng.random() < dcfg.motif_prob:
            pos = rng.integers(0, S + 1 - dcfg.motif_len)
            toks[:, pos:pos + dcfg.motif_len] = motif

    if cfg.frontend == "audio_frames":
        frames = rng.standard_normal((B, S, cfg.d_model)).astype(np.float32) * 0.02
        return {"frames": frames, "labels": toks[:, :S] % V}
    if cfg.frontend == "vision_patches":
        s_text = S - cfg.n_patches
        patches = rng.standard_normal(
            (B, cfg.n_patches, cfg.d_model)).astype(np.float32) * 0.02
        return {"tokens": toks[:, :s_text],
                "patches": patches,
                "labels": toks[:, 1:s_text + 1]}
    return {"tokens": toks[:, :S], "labels": toks[:, 1:S + 1]}


def make_stream(cfg: ModelConfig, dcfg: DataConfig, port: int, n_ports: int,
                start_step: int = 0, n_steps: Optional[int] = None
                ) -> Iterator[Dict[str, np.ndarray]]:
    """Deterministic per-port batch iterator (resume via start_step)."""
    step = start_step
    while n_steps is None or step < start_step + n_steps:
        yield synth_tokens(cfg, dcfg, port, n_ports, step)
        step += 1


def stream_factory(cfg: ModelConfig, dcfg: DataConfig, start_step: int = 0,
                   n_steps: Optional[int] = None):
    """Factory with the (port, n_ports) signature the dataplane expects."""
    def factory(port: int, n_ports: int):
        return make_stream(cfg, dcfg, port, n_ports, start_step, n_steps)
    return factory
