"""Sharded, async, integrity-checked checkpointing with elastic restore.

Layout (one directory per step):

    ckpt_dir/
      step_000120/
        manifest.json         # tree structure, shapes, dtypes, hashes, meta
        arrays/<leaf-id>.npy  # one file per pytree leaf
      LATEST                  # atomically-updated pointer file

* **async** — `save()` snapshots device arrays to host then hands the file
  writes to a background thread; training continues immediately (double-
  buffered: at most one outstanding save, back-pressure if two).
* **integrity** — every array file carries a blake2s digest in the manifest;
  `restore()` verifies before use; a torn/partial directory (no manifest or
  bad hashes) is skipped and the previous step is used — crash-safe.
* **elastic restore** — arrays are saved unsharded (host-gathered); restore
  applies whatever NamedShardings the *current* mesh prescribes, so a job can
  restart on a different pod/slice count (DESIGN.md §4 fault tolerance).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _leaf_paths(tree: Any) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        out[key] = leaf
    return out


def _digest(arr: np.ndarray) -> str:
    return hashlib.blake2s(arr.tobytes(), digest_size=16).hexdigest()


class CheckpointManager:
    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        os.makedirs(ckpt_dir, exist_ok=True)
        self._pending: Optional[threading.Thread] = None

    # -- save ------------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[Dict[str, Any]] = None,
             block: bool = False) -> None:
        """Snapshot now, write asynchronously."""
        self.wait()  # back-pressure: one outstanding save max
        host = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)),
                                      tree)
        t = threading.Thread(target=self._write, args=(step, host, extra or {}),
                             daemon=True, name=f"ckpt-{step}")
        self._pending = t
        t.start()
        if block:
            self.wait()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _write(self, step: int, host_tree: Any, extra: Dict[str, Any]) -> None:
        tmp = os.path.join(self.dir, f".tmp_step_{step:09d}")
        final = os.path.join(self.dir, f"step_{step:09d}")
        arrays_dir = os.path.join(tmp, "arrays")
        os.makedirs(arrays_dir, exist_ok=True)
        leaves = _leaf_paths(host_tree)
        manifest = {"step": step, "extra": extra, "leaves": {}}
        for i, (key, arr) in enumerate(sorted(leaves.items())):
            arr = np.asarray(arr)
            logical_dtype = str(arr.dtype)
            if arr.dtype.kind not in "biufc":
                # non-native dtypes (bfloat16 etc.): store as raw uint bytes
                arr = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
            fname = f"{i:05d}.npy"
            np.save(os.path.join(arrays_dir, fname), arr, allow_pickle=False)
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": logical_dtype, "stored_dtype": str(arr.dtype),
                "digest": _digest(arr),
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, final)  # atomic publish
        with open(os.path.join(self.dir, ".LATEST_tmp"), "w") as f:
            f.write(os.path.basename(final))
        os.replace(os.path.join(self.dir, ".LATEST_tmp"),
                   os.path.join(self.dir, "LATEST"))
        self._gc()

    def _gc(self) -> None:
        steps = sorted(d for d in os.listdir(self.dir) if d.startswith("step_"))
        for d in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # -- restore ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        candidates = []
        ptr = os.path.join(self.dir, "LATEST")
        if os.path.exists(ptr):
            with open(ptr) as f:
                candidates.append(f.read().strip())
        candidates += sorted(
            (d for d in os.listdir(self.dir) if d.startswith("step_")),
            reverse=True)
        for c in candidates:
            if os.path.exists(os.path.join(self.dir, c, "manifest.json")):
                return int(c.split("_")[1])
        return None

    def restore(self, step: Optional[int], like: Any,
                shardings: Optional[Any] = None
                ) -> Tuple[Any, int, Dict[str, Any]]:
        """Load into the structure of ``like``; apply shardings if given.

        Verifies digests; raises on corruption (callers fall back to an
        earlier step).  Returns (tree, step, extra).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        like_leaves = _leaf_paths(like)
        shard_leaves = _leaf_paths(shardings) if shardings is not None else {}
        loaded: Dict[str, Any] = {}
        for key, meta in manifest["leaves"].items():
            if key not in like_leaves:
                continue
            arr = np.load(os.path.join(d, "arrays", meta["file"]),
                          allow_pickle=False)
            if _digest(arr) != meta["digest"]:
                raise IOError(f"checkpoint corruption in {key} @ step {step}")
            target = like_leaves[key]
            if list(arr.shape) != list(target.shape):
                raise ValueError(
                    f"{key}: ckpt shape {arr.shape} != model {target.shape}")
            if meta["dtype"] != str(arr.dtype):
                # stored as raw uint bytes → view back as the logical dtype
                arr = arr.view(np.dtype(target.dtype)
                               if str(target.dtype) == meta["dtype"]
                               else meta["dtype"])
            if str(arr.dtype) != str(target.dtype):
                arr = arr.astype(target.dtype)
            sh = shard_leaves.get(key)
            loaded[key] = (jax.device_put(arr, sh) if sh is not None
                           else jax.device_put(arr))
        missing = set(like_leaves) - set(loaded)
        if missing:
            raise KeyError(f"checkpoint missing leaves: {sorted(missing)[:5]}")
        # rebuild tree in `like`'s structure
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        keys_in_order = ["/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path) for path, _ in flat]
        rebuilt = jax.tree_util.tree_unflatten(
            treedef, [loaded[k] for k in keys_in_order])
        return rebuilt, manifest["step"], manifest.get("extra", {})
