"""Trainer runtime: bypass-fed step loop with fault tolerance.

Wires together the paper's dataplane (kernel-stack or bypass feed), the model
step functions, checkpoint/restart, and straggler mitigation:

* **feed choice** — ``feed="bypass"`` (polling, multi-port, pre-issued DMA) or
  ``feed="kernel"`` (blocking baseline); one flag, same loop.
* **checkpoint/restart** — async sharded checkpoints every N steps; on start,
  the trainer resumes from the latest valid checkpoint and fast-forwards the
  deterministic data stream (exact replay).
* **straggler mitigation** — the bypass feed's poll deadline bounds how long a
  slow producer port can stall a step; on timeout the runtime drops the
  stalled transfer and refills from the staging rings (drop-and-refill, the
  inverse of the loadgen's no-drop guarantee), and counts the event.
* **elastic scaling** — restore() re-shards the checkpoint onto whatever mesh
  the relaunch built (pod counts can change between runs).
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core.dataplane import BypassDataplane, KernelStackFeed, make_feed
from repro.data.pipeline import DataConfig, stream_factory
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.parallel.axes import AxisRules, axis_rules
from repro.parallel.specs import (make_batch_specs, make_param_specs,
                                  make_shardings)
from repro.runtime.steps import make_train_step


@dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    feed: str = "bypass"             # bypass | kernel
    feed_ports: int = 1
    feed_depth: int = 3
    step_deadline_s: float = 120.0   # straggler watchdog
    log_every: int = 10
    seed: int = 0


@dataclass
class TrainerState:
    params: Any
    opt_state: adamw.OptState
    step: int = 0


class TrainerRuntime:
    def __init__(self, cfg: ModelConfig, dcfg: DataConfig,
                 tcfg: TrainerConfig,
                 opt_cfg: Optional[adamw.AdamWConfig] = None,
                 mesh=None, rules: Optional[AxisRules] = None):
        self.cfg = cfg
        self.dcfg = dcfg
        self.tcfg = tcfg
        self.opt_cfg = opt_cfg or adamw.AdamWConfig()
        self.mesh = mesh
        self.rules = rules
        self.ckpt = (CheckpointManager(tcfg.ckpt_dir)
                     if tcfg.ckpt_dir else None)
        self.metrics_log: list = []
        self.straggler_events = 0
        self._feed = None

    # -- setup ------------------------------------------------------------------
    def _ctx(self):
        if self.rules is not None:
            return axis_rules(self.rules, self.mesh)
        import contextlib
        return contextlib.nullcontext()

    def init_state(self) -> TrainerState:
        with self._ctx():
            params = lm.init_params(self.cfg, jax.random.PRNGKey(self.tcfg.seed))
            opt_state = adamw.init(self.opt_cfg, params)
        return TrainerState(params=params, opt_state=opt_state, step=0)

    def _shardings(self, params):
        if self.rules is None or self.mesh is None:
            return None, None
        pspecs = make_param_specs(params, self.rules, self.mesh)
        pshard = make_shardings(pspecs, self.mesh)
        ospecs = adamw.OptState(
            step=jax.sharding.PartitionSpec(),
            master=pspecs if self.opt_cfg.master_fp32 else (),
            m=pspecs, v=pspecs)
        oshard = make_shardings(ospecs, self.mesh)
        return pshard, oshard

    def maybe_restore(self, state: TrainerState) -> TrainerState:
        if self.ckpt is None:
            return state
        latest = self.ckpt.latest_step()
        if latest is None:
            return state
        pshard, oshard = self._shardings(state.params)
        tree = {"params": state.params, "opt": state.opt_state}
        shardings = ({"params": pshard, "opt": oshard}
                     if pshard is not None else None)
        restored, step, extra = self.ckpt.restore(latest, tree, shardings)
        print(f"[trainer] restored checkpoint @ step {step}")
        return TrainerState(params=restored["params"], opt_state=restored["opt"],
                            step=step)

    # -- run -------------------------------------------------------------------
    def run(self, state: Optional[TrainerState] = None) -> TrainerState:
        tcfg = self.tcfg
        with self._ctx():
            if state is None:
                state = self.init_state()
                state = self.maybe_restore(state)

            step_fn = make_train_step(self.cfg, self.opt_cfg)
            if self.mesh is not None:
                pshard, oshard = self._shardings(state.params)
                probe = stream_factory(self.cfg, self.dcfg)(0, 1)
                bshard = make_shardings(
                    make_batch_specs(next(iter([next(probe)])), self.rules, self.mesh),
                    self.mesh)
                jitted = jax.jit(step_fn, in_shardings=(pshard, oshard, bshard),
                                 donate_argnums=(0, 1))
            else:
                jitted = jax.jit(step_fn, donate_argnums=(0, 1))

            factory = stream_factory(self.cfg, self.dcfg,
                                     start_step=state.step,
                                     n_steps=tcfg.steps - state.step)
            feed = make_feed(tcfg.feed, factory, depth=tcfg.feed_depth,
                             ports=tcfg.feed_ports)
            self._feed = feed
            t_start = time.perf_counter()
            try:
                while state.step < tcfg.steps:
                    try:
                        batch = feed.next_batch(
                            timeout_s=tcfg.step_deadline_s) if isinstance(
                                feed, BypassDataplane) else feed.next_batch()
                    except TimeoutError:
                        # straggler port: drop in-flight, refill, retry once
                        self.straggler_events += 1
                        feed._inflight.clear()
                        batch = feed.next_batch(timeout_s=tcfg.step_deadline_s)
                    if batch is None:
                        break
                    params, opt_state, metrics = jitted(
                        state.params, state.opt_state, batch)
                    state = TrainerState(params=params, opt_state=opt_state,
                                         step=state.step + 1)
                    if state.step % tcfg.log_every == 0 or state.step == 1:
                        m = {k: float(v) for k, v in metrics.items()}
                        m["step"] = state.step
                        m["wall_s"] = round(time.perf_counter() - t_start, 2)
                        self.metrics_log.append(m)
                        print(f"[trainer] step {state.step}: "
                              f"loss={m['loss']:.4f} gnorm={m['grad_norm']:.3f} "
                              f"({m['wall_s']}s)")
                    if (self.ckpt is not None
                            and state.step % tcfg.ckpt_every == 0):
                        self.ckpt.save(state.step,
                                       {"params": state.params,
                                        "opt": state.opt_state},
                                       extra={"step": state.step})
            finally:
                feed.stop()
                if self.ckpt is not None:
                    self.ckpt.wait()
            return state
