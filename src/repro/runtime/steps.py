"""Jittable step functions: train / prefill / decode.

These are what the launcher jits (with shardings) and what the dry-run lowers
for every (arch × shape) cell.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import adamw


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                    grad_shardings=None) -> Callable:
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = lm.train_loss(cfg, p, batch)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        if grad_shardings is not None:
            # pin grads to the param sharding: GSPMD then lowers the data-
            # parallel reduction as a reduce-scatter instead of an all-reduce
            grads = jax.lax.with_sharding_constraint(grads, grad_shardings)
        params, opt_state, opt_metrics = adamw.apply_updates(
            opt_cfg, params, grads, opt_state)
        return params, opt_state, {**metrics, **opt_metrics}

    return train_step


def make_loss_fn(cfg: ModelConfig) -> Callable:
    def loss_fn(params, batch):
        loss, metrics = lm.train_loss(cfg, params, batch)
        return loss, metrics
    return loss_fn


def make_prefill_step(cfg: ModelConfig, max_len: int) -> Callable:
    def prefill_step(params, batch):
        return lm.prefill(cfg, params, batch, max_len)
    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    def decode_step(params, cache, token, pos):
        logits, cache = lm.decode_step(cfg, params, cache, token, pos)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, logits, cache
    return decode_step
