"""Parameter / state / batch PartitionSpec derivation.

Leaf-path pattern table → logical axes → (via AxisRules) mesh PartitionSpecs.
FSDP ("data") shards a storage dim of every large tensor; TP ("model") shards
heads / ffn / experts / vocab.  XLA GSPMD inserts the FSDP all-gathers at use
and grad reduce-scatters automatically; uneven dims (24 heads / 16 shards,
92553 vocab / 16) are legal — GSPMD pads internally (verified in tests).
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .axes import AxisRules

# (regex over "/"-joined path, logical axes per trailing dims)
# Leading scan/stack dims not covered by the pattern are replicated (None).
_PARAM_RULES: List[Tuple[str, Tuple[Optional[str], ...]]] = [
    # embeddings
    (r"embed/tok$", ("vocab", "fsdp")),
    (r"embed/unembed$", ("vocab", "fsdp")),
    # attention
    (r"attn/wq$", ("fsdp", "heads", None)),
    (r"attn/wk$", ("fsdp", "kv_heads", None)),
    (r"attn/wv$", ("fsdp", "kv_heads", None)),
    (r"attn/wo$", ("heads", None, "fsdp")),
    (r"attn/(q_norm|k_norm)$", (None,)),
    # dense mlp / shared expert
    (r"(mlp|shared)/w_gate$", ("fsdp", "ffn")),
    (r"(mlp|shared)/w_up$", ("fsdp", "ffn")),
    (r"(mlp|shared)/w_down$", ("ffn", "fsdp")),
    (r"(mlp|shared)/b_(up|down)$", (None,)),
    # moe (blocked layout (TP, E_loc, D, F_loc))
    (r"moe/router$", (None, None)),
    (r"moe/w_gate$", ("experts", None, "fsdp", None)),
    (r"moe/w_up$", ("experts", None, "fsdp", None)),
    (r"moe/w_down$", ("experts", None, None, "fsdp")),
    # rg-lru
    (r"rglru/w_x$", ("fsdp", "ffn")),
    (r"rglru/w_gate$", ("fsdp", "ffn")),
    (r"rglru/conv_[wb]$", None),  # tiny; replicate fully
    (r"rglru/w_[ai]$", (None, "fsdp", "ffn")),
    (r"rglru/(b_[ai]|lam)$", (None,)),
    (r"rglru/w_out$", ("ffn", "fsdp")),
    # mamba2
    (r"blocks/in_proj$", ("fsdp", "ffn")),
    (r"blocks/conv_[wb]$", None),
    (r"blocks/(a_log|dt_bias|d_skip|out_norm)$", None),
    (r"blocks/out_proj$", ("ffn", "fsdp")),
    # norms
    (r"norm", None),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            parts.append(k.name)
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_logical_axes(params: Any) -> Any:
    """Pytree of logical-axis tuples matching params (trailing-dims aligned)."""

    def leaf_axes(path, leaf) -> Tuple[Optional[str], ...]:
        ps = _path_str(path)
        ndim = np.ndim(leaf) if not hasattr(leaf, "ndim") else leaf.ndim
        for pat, axes in _PARAM_RULES:
            if re.search(pat, ps):
                if axes is None:
                    return (None,) * ndim
                pad = ndim - len(axes)
                assert pad >= 0, f"{ps}: rank {ndim} < rule {axes}"
                return (None,) * pad + tuple(axes)
        if ndim <= 1:
            return (None,) * ndim
        raise ValueError(f"no partition rule for param leaf {ps} (rank {ndim})")

    return jax.tree_util.tree_map_with_path(leaf_axes, params)


def _axis_size(mesh: Optional[Mesh], names) -> int:
    if mesh is None or names is None:
        return 1
    if isinstance(names, str):
        names = (names,)
    n = 1
    for a in names:
        if a is not None:
            n *= mesh.shape[a]
    return n


def sanitize_spec(spec: P, shape, mesh: Optional[Mesh]) -> P:
    """Drop sharding on dims the mesh cannot divide evenly.

    pjit *argument* shardings must divide exactly (GSPMD only pads internal
    constraints), so e.g. 24 heads / 16-way model axis or batch=1 / data axis
    fall back to replication on that dim — the internal with_sharding_
    constraint annotations still apply (padded) sharding to the activations.
    """
    out = []
    for d, names in enumerate(spec):
        if names is None:
            out.append(None)
            continue
        div = _axis_size(mesh, names)
        out.append(names if (d < len(shape) and shape[d] % div == 0) else None)
    return P(*out)


def make_param_specs(params: Any, rules: AxisRules,
                     mesh: Optional[Mesh] = None) -> Any:
    """Pytree of PartitionSpec for params (or same-shaped states)."""
    axes = param_logical_axes(params)
    is_axes_leaf = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)
    specs = jax.tree_util.tree_map(
        lambda a: rules.spec(*a), axes, is_leaf=is_axes_leaf)
    if mesh is None:
        return specs
    return jax.tree_util.tree_map(
        lambda s, p: sanitize_spec(s, p.shape, mesh), specs, params,
        is_leaf=lambda x: isinstance(x, P))


def make_shardings(specs: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


# -- batch / cache specs -------------------------------------------------------

def batch_logical_axes(batch_like: Any) -> Any:
    def leaf_axes(path, leaf):
        ndim = leaf.ndim
        return ("batch",) + (None,) * (ndim - 1)
    return jax.tree_util.tree_map_with_path(leaf_axes, batch_like)


def make_batch_specs(batch_like: Any, rules: AxisRules,
                     mesh: Optional[Mesh] = None) -> Any:
    return jax.tree_util.tree_map(
        lambda leaf: sanitize_spec(
            rules.spec(*(("batch",) + (None,) * (leaf.ndim - 1))),
            leaf.shape, mesh),
        batch_like)


def make_cache_specs(cfg, cache_like: Any, rules: AxisRules,
                     mesh: Optional[Mesh] = None) -> Any:
    """Decode-state sharding: batch over DP axes; long axes context-sharded.

    * attention k/v caches: sequence dim over `model` (flash-decoding layout)
    * mamba2 ssm state: head dim over `model`
    * rg-lru h/conv states: width dim over `model`
    """

    def leaf_axes(path, leaf) -> Tuple[Optional[str], ...]:
        ps = _path_str(path)
        nd = leaf.ndim
        if re.search(r"(^|/)(k|v)$", ps):
            # (..., B, C, Hkv, Dh): batch at -4, cache seq at -3
            lead = (None,) * (nd - 4)
            return lead + ("batch", "kv_seq", None, None)
        if ps.endswith("ssm"):  # (L, B, H, P, N)
            return (None, "batch", "ssm_heads", None, None)
        if ps.endswith("conv") and nd == 4:  # (L, B, K-1, conv_dim)
            return (None, "batch", None, "ffn")
        if ps.endswith("h") and nd == 3:  # (units, B, W)
            return (None, "batch", "ffn")
        if ps.endswith("conv") and nd == 3:  # tail rglru (B, K-1, W)
            return ("batch", None, "ffn")
        if ps.endswith("h") and nd == 2:
            return ("batch", "ffn")
        if nd >= 1:
            lead = (None,) * (nd - 1)
            return lead + (None,)
        return ()

    axes = jax.tree_util.tree_map_with_path(leaf_axes, cache_like)
    is_axes_leaf = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)
    specs = jax.tree_util.tree_map(
        lambda a: rules.spec(*a), axes, is_leaf=is_axes_leaf)
    if mesh is None:
        return specs
    return jax.tree_util.tree_map(
        lambda s, c: sanitize_spec(s, c.shape, mesh), specs, cache_like,
        is_leaf=lambda x: isinstance(x, P))
