"""Logical-axis sharding: model code names axes, the launcher binds them.

Model code annotates activations/parameters with *logical* axis names
("batch", "heads", "ffn", "vocab", "fsdp", "experts", "kv_seq", ...).  The
launcher installs an :class:`AxisRules` mapping logical names to mesh axes
(single-pod, multi-pod, or none for single-device smoke tests).  When no rules
or no mesh are active, every annotation is a no-op, so the same model code
runs on one CPU device and on a 512-chip mesh.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclass(frozen=True)
class AxisRules:
    """logical axis name -> mesh axis (or tuple of mesh axes, or None)."""

    rules: Dict[str, MeshAxes] = field(default_factory=dict)
    # pure-FSDP layouts: force an explicit all-gather of weights at use-time
    # so GSPMD never "optimizes" into per-layer activation all-reduces
    # (EXPERIMENTS.md §Perf iter 4 — 5x collective reduction on cell A)
    gather_weights_at_use: bool = False

    def resolve(self, logical: Optional[str]) -> MeshAxes:
        if logical is None:
            return None
        return self.rules.get(logical)

    def spec(self, *logical_axes: Optional[str]) -> P:
        return P(*[self.resolve(a) for a in logical_axes])


# -- thread-local active rules -------------------------------------------------
class _State(threading.local):
    def __init__(self) -> None:
        self.rules: Optional[AxisRules] = None
        self.mesh: Optional[Mesh] = None


_STATE = _State()


@contextmanager
def axis_rules(rules: AxisRules, mesh: Optional[Mesh] = None) -> Iterator[None]:
    prev_r, prev_m = _STATE.rules, _STATE.mesh
    _STATE.rules, _STATE.mesh = rules, mesh
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _STATE.rules, _STATE.mesh = prev_r, prev_m


def current_rules() -> Optional[AxisRules]:
    return _STATE.rules


def current_mesh() -> Optional[Mesh]:
    if _STATE.mesh is not None:
        return _STATE.mesh
    # fall back to jax's ambient mesh if one is entered directly
    env = getattr(jax.sharding, "get_abstract_mesh", None)
    return None


def logical_spec(*logical_axes: Optional[str]) -> P:
    """Resolve logical axes to a PartitionSpec under the active rules."""
    rules = current_rules()
    if rules is None:
        return P()
    return rules.spec(*logical_axes)


def shard(x: Any, *logical_axes: Optional[str]) -> Any:
    """with_sharding_constraint by logical axes; no-op without rules/mesh."""
    rules = current_rules()
    if rules is None or _STATE.mesh is None:
        return x
    spec = rules.spec(*logical_axes)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(_STATE.mesh, spec))


def gather_weight(w: Any) -> Any:
    """Force a weight to be all-gathered (replicated) at its use site.

    No-op unless the active rules opt in (pure-FSDP layouts).  This pins
    GSPMD to the ZeRO-3 schedule: gather small weights once per layer rather
    than all-reducing large partial activations."""
    rules = current_rules()
    if (rules is None or _STATE.mesh is None
            or not rules.gather_weights_at_use):
        return w
    return jax.lax.with_sharding_constraint(
        w, NamedSharding(_STATE.mesh, P(*([None] * w.ndim))))


def named_sharding(*logical_axes: Optional[str]) -> Optional[NamedSharding]:
    rules = current_rules()
    if rules is None or _STATE.mesh is None:
        return None
    return NamedSharding(_STATE.mesh, rules.spec(*logical_axes))


# -- standard rule sets -----------------------------------------------------------

def single_pod_rules() -> AxisRules:
    """(data=16, model=16) mesh."""
    return AxisRules(rules={
        "batch": ("data",),      # DP/FSDP batch dim
        "fsdp": ("data",),       # parameter storage sharding (ZeRO-3 style)
        "heads": "model",        # TP attention heads
        "kv_heads": None,        # GQA KV heads: replicated under TP
        "ffn": "model",          # TP MLP hidden
        "vocab": "model",        # TP vocab/logits
        "embed": None,           # d_model stays unsharded in activations
        "experts": "model",      # EP expert dim
        "seq": None,             # sequence dim of activations (train/prefill)
        "kv_seq": "model",       # decode KV-cache sequence dim (flash-decoding)
        "seq_shard": "model",    # context-parallel sequence dim (long ctx / EDP)
        "ssm_heads": "model",    # SSM / RG-LRU state heads
    })


def multi_pod_rules() -> AxisRules:
    """(pod=2, data=16, model=16) mesh — pod extends the DP axis; FSDP stays
    intra-pod so param all-gathers never cross the (slow) pod interconnect."""
    r = single_pod_rules().rules.copy()
    r["batch"] = ("pod", "data")
    return AxisRules(rules=r)


def pure_fsdp_rules() -> AxisRules:
    """Single-pod (data=16, model=16) with NO tensor parallelism: both mesh
    axes act as one 256-way DP/FSDP domain.

    For small models (≲2B params) per-layer TP activation psums dwarf the
    compute (hillclimb cells A/B); pure ZeRO-3 replaces them with per-layer
    param all-gathers that are ~100× smaller at these sizes.  Requires
    global_batch % 256 == 0.
    """
    return AxisRules(rules={
        "batch": ("data", "model"),
        "fsdp": ("data", "model"),
        "heads": None,
        "kv_heads": None,
        "ffn": None,
        "vocab": None,
        "embed": None,
        "experts": None,
        "seq": None,
        "kv_seq": None,
        "seq_shard": None,
        "ssm_heads": None,
    }, gather_weights_at_use=True)


def no_rules() -> AxisRules:
    return AxisRules(rules={})
