"""Static HLO cost counter with while-loop trip multipliers.

``compiled.cost_analysis()`` counts each computation once, but our programs
put the layer stack (and attention/loss chunking) inside ``while`` loops —
undercounting flops, bytes and collectives by the trip count.  This module
parses the post-SPMD optimized HLO text and computes:

* **dot flops** — 2 · prod(output dims) · prod(contracting dims), recursively
  through fusions, × enclosing while trip counts;
* **collective wire bytes** — ring-model per-chip bytes per collective kind,
  × trip counts;
* **HBM traffic proxy** — Σ (operand + result bytes) of ops that must touch
  HBM on a well-fused TPU program — dots/convs, collectives, copies,
  (dynamic-)slices/updates, gathers/scatters/sorts/concats — × trip counts.
  Elementwise/reduction fusion I/O is deliberately EXCLUDED: on TPU those fuse
  into the surrounding matmuls (and the Pallas flash kernels fuse softmax/norm
  traffic), whereas the CPU backend's kLoop fusions would count it ~5× over.
  The proxy still double-counts producer→consumer handoffs between counted
  ops (a result counted once as output, once as the next op's input), so it
  is a mild overestimate — consistent across cells, which is what the
  hillclimb needs.

Operands are name references in optimized HLO, so shapes are resolved through
a per-computation symbol table.  Trip counts come from the comparison constant
in each while condition — exact for ``lax.scan``-generated counted loops.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_TOKEN = re.compile(
    r"\b(pred|s4|u4|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|c64|c128)"
    r"\[([0-9,]*)\]")

_COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute")

_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(")
_INST = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPND = re.compile(r"%([\w.\-]+)")
_DOT_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUP_LIST = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUP_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONST = re.compile(r"constant\((\d+)\)")


def _shapes_bytes(shapes: List[Tuple[str, str]]) -> int:
    total = 0
    for d, s in shapes:
        n = 1
        if s:
            for x in s.split(","):
                n *= int(x)
        total += n * _DTYPE_BYTES[d]
    return total


def _prod(dims: str) -> int:
    n = 1
    if dims:
        for x in dims.split(","):
            n *= int(x)
    return n


@dataclass
class Instruction:
    name: str
    opcode: str
    result_shapes: List[Tuple[str, str]]
    operands: List[str]
    attrs: str
    raw: str


@dataclass
class Computation:
    name: str
    instructions: List[Instruction] = field(default_factory=list)
    shapes: Dict[str, List[Tuple[str, str]]] = field(default_factory=dict)


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        ls = line.strip()
        if cur is None or (line and not line.startswith(" ")):
            # potential computation header (column-0 lines)
            if ls.endswith("{") and "HloModule" not in ls:
                hm = _HDR.match(ls)
                if hm:
                    cur = Computation(hm.group(2))
                    comps[cur.name] = cur
                    if hm.group(1):
                        entry = cur.name
                continue
        if ls.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _INST.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        om = re.search(r" ([a-z][a-z0-9\-]*)\(", " " + rest)
        if not om:
            continue
        opcode = om.group(1)
        result_part = rest[: om.start()]
        call_part = rest[om.start():]
        depth = 0
        end = len(call_part) - 1
        for i, ch in enumerate(call_part):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_str = call_part[: end + 1]
        attrs = call_part[end + 1:]
        inst = Instruction(
            name=name,
            opcode=opcode,
            result_shapes=_SHAPE_TOKEN.findall(result_part),
            operands=_OPND.findall(operand_str),
            attrs=attrs,
            raw=rest,
        )
        cur.instructions.append(inst)
        cur.shapes[name] = inst.result_shapes
    return comps, entry


def _operand_bytes(comp: Computation, inst: Instruction) -> int:
    total = 0
    for op in inst.operands:
        total += _shapes_bytes(comp.shapes.get(op, []))
    return total


def _operand_shape(comp: Computation, inst: Instruction, idx: int
                   ) -> List[Tuple[str, str]]:
    if idx < len(inst.operands):
        return comp.shapes.get(inst.operands[idx], [])
    return []


def _dot_flops(comp: Computation, inst: Instruction) -> float:
    out_elems = sum(_prod(s) for _, s in inst.result_shapes)
    k = 1
    cm = _DOT_CONTRACT.search(inst.attrs)
    lhs_shapes = _operand_shape(comp, inst, 0)
    if cm and cm.group(1) and lhs_shapes:
        dims = lhs_shapes[0][1]
        lhs = [int(d) for d in dims.split(",")] if dims else []
        for ci in cm.group(1).split(","):
            ci = int(ci)
            if ci < len(lhs):
                k *= lhs[ci]
    return 2.0 * out_elems * k


def _while_trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = 1
    for inst in cond.instructions:
        if inst.opcode == "constant":
            cm = _CONST.search(inst.raw)
            if cm:
                best = max(best, int(cm.group(1)))
        # comparison constants may sit inside a fused compare computation
        for am in re.finditer(r"calls=%([\w.\-]+)", inst.attrs):
            sub = comps.get(am.group(1))
            if sub:
                for si in sub.instructions:
                    cm = _CONST.search(si.raw)
                    if cm and si.opcode == "constant":
                        best = max(best, int(cm.group(1)))
    return best


_MEM_SKIP = {"tuple", "get-tuple-element", "parameter", "constant", "bitcast",
             "after-all", "partition-id", "replica-id", "copy-done",
             "all-gather-done", "all-reduce-done", "collective-permute-done",
             "send", "recv", "send-done", "recv-done"}

# ops whose I/O is counted toward the HBM-traffic proxy (see module docstring)
_MEM_COUNT = {"dot", "convolution", "copy", "copy-start", "dynamic-slice",
              "dynamic-update-slice", "gather", "scatter", "sort",
              "concatenate"}


@dataclass
class HloCost:
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_counts: Dict[str, float] = field(default_factory=dict)
    collective_op_bytes: Dict[str, float] = field(default_factory=dict)
    collective_wire_bytes: Dict[str, float] = field(default_factory=dict)
    n_while: int = 0
    max_trip: int = 1

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.collective_wire_bytes.values())

    def add_collective(self, kind: str, n: float, op_b: float, wire_b: float):
        self.collective_counts[kind] = self.collective_counts.get(kind, 0) + n
        self.collective_op_bytes[kind] = (
            self.collective_op_bytes.get(kind, 0) + op_b)
        self.collective_wire_bytes[kind] = (
            self.collective_wire_bytes.get(kind, 0) + wire_b)


def analyze(text: str) -> HloCost:
    comps, entry = parse_hlo(text)
    cost = HloCost()
    if entry is None:
        entry = next(iter(comps), None)
        if entry is None:
            return cost

    fusion_flops_cache: Dict[str, float] = {}

    def fusion_flops(comp_name: str) -> float:
        if comp_name in fusion_flops_cache:
            return fusion_flops_cache[comp_name]
        comp = comps.get(comp_name)
        fl = 0.0
        if comp:
            for inst in comp.instructions:
                if inst.opcode in ("dot", "convolution"):
                    fl += _dot_flops(comp, inst)
                for am in re.finditer(r"calls=%([\w.\-]+)", inst.attrs):
                    fl += fusion_flops(am.group(1))
        fusion_flops_cache[comp_name] = fl
        return fl

    def walk(comp_name: str, mult: float) -> None:
        comp = comps.get(comp_name)
        if comp is None:
            return
        for inst in comp.instructions:
            op = inst.opcode
            if op == "while":
                bm = re.search(r"body=%?([\w.\-]+)", inst.attrs)
                cm = re.search(r"condition=%?([\w.\-]+)", inst.attrs)
                trips = _while_trip_count(comps, cm.group(1)) if cm else 1
                cost.n_while += 1
                cost.max_trip = max(cost.max_trip, trips)
                if bm:
                    walk(bm.group(1), mult * trips)
                continue
            if op in ("call", "async-start"):
                for am in re.finditer(r"(?:to_apply|called_computations=\{?)="
                                      r"?%?([\w.\-]+)", inst.attrs):
                    walk(am.group(1), mult)
                cm = re.search(r"to_apply=%?([\w.\-]+)", inst.attrs)
                if cm:
                    walk(cm.group(1), mult)
                continue
            if op == "conditional":
                for am in re.finditer(r"%([\w.\-]+)", inst.attrs):
                    if am.group(1) in comps:
                        walk(am.group(1), mult)
                continue
            if op == "dynamic-update-slice":
                # in-place slice write: traffic = read+write of the UPDATE
                # operand, not the whole (aliased) buffer
                upd = _operand_shape(comp, inst, 1)
                cost.hbm_bytes += mult * 2 * _shapes_bytes(upd)
                continue
            if op in ("dynamic-slice", "gather"):
                # read+write of the extracted slice only
                cost.hbm_bytes += mult * 2 * _shapes_bytes(inst.result_shapes)
                continue
            if op == "scatter":
                # in-place scatter: read+write of the updates operand
                upd = _operand_shape(comp, inst, 2)
                cost.hbm_bytes += mult * 2 * _shapes_bytes(upd)
                continue
            io_bytes = _operand_bytes(comp, inst) + _shapes_bytes(
                inst.result_shapes)
            if op in ("dot", "convolution"):
                cost.dot_flops += mult * _dot_flops(comp, inst)
                cost.hbm_bytes += mult * io_bytes
                continue
            if op == "fusion":
                fm = re.search(r"calls=%([\w.\-]+)", inst.attrs)
                if fm:
                    fl = fusion_flops(fm.group(1))
                    cost.dot_flops += mult * fl
                    if fl > 0:  # fusions containing dots do hit HBM
                        cost.hbm_bytes += mult * io_bytes
                continue
            kind = next((k for k in _COLLECTIVE_KINDS
                         if op in (k, k + "-start")), None)
            if kind is not None:
                nbytes = _operand_bytes(comp, inst) or _shapes_bytes(
                    inst.result_shapes)
                gm = _GROUP_LIST.search(inst.attrs)
                if gm:
                    gsize = len(gm.group(1).split(","))
                else:
                    gi = _GROUP_IOTA.search(inst.attrs)
                    gsize = int(gi.group(2)) if gi else 2
                gsize = max(2, gsize)
                ring = (gsize - 1) / gsize
                if kind == "all-reduce":
                    wire = 2.0 * ring * nbytes
                elif kind == "collective-permute":
                    wire = float(nbytes)
                else:
                    wire = ring * nbytes
                cost.add_collective(kind, mult, mult * nbytes, mult * wire)
                cost.hbm_bytes += mult * io_bytes
                continue
            if op in _MEM_COUNT:
                cost.hbm_bytes += mult * io_bytes

    walk(entry, 1.0)
    return cost
