"""Post-SPMD HLO analysis: collective inventory + roofline terms.

``cost_analysis()`` gives per-device FLOPs and bytes, but nothing about
collectives — those are parsed from the compiled HLO text: every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op's operand
shapes are summed into per-chip wire-byte estimates using standard ring-
algorithm factors.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

# TPU v5e hardware constants (roofline targets)
PEAK_FLOPS_BF16 = 197e12     # per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~4 links/chip on a 2D torus)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64)"
                       r"\[([\d,]*)\]")
_GROUP_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUP_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=dict)
    op_bytes: Dict[str, int] = field(default_factory=dict)    # Σ operand bytes
    wire_bytes: Dict[str, float] = field(default_factory=dict)  # ring model / chip

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())

    @property
    def total_op_bytes(self) -> int:
        return sum(self.op_bytes.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Scan a compiled (post-SPMD) HLO module for collective ops."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # instruction lines look like: "%name = TYPE[SHAPE] op-name(...), attrs"
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+)$", stripped)
        if not m:
            continue
        rest = m.group(1)
        kind = None
        for c in _COLLECTIVES:
            # match op name at the call position, e.g. " all-gather(" or
            # "all-reduce-start("
            if re.search(rf"\b{c}(-start)?\(", rest):
                kind = c
                break
        if kind is None:
            continue
        # operand shapes: everything inside the call parens
        call = rest[rest.index("("):]
        shapes = _SHAPE_RE.findall(call)
        if not shapes:
            # fall back to the result shape (before the op name)
            shapes = _SHAPE_RE.findall(rest)
        nbytes = sum(_shape_bytes(d, s) for d, s in shapes)
        # group size for ring factors
        gm = _GROUP_RE.search(rest)
        if gm:
            gsize = len(gm.group(1).split(","))
        else:
            gi = _GROUP_IOTA_RE.search(rest)
            gsize = int(gi.group(2)) if gi else 2
        gsize = max(2, gsize)
        ring = (gsize - 1) / gsize
        if kind == "all-reduce":
            wire = 2.0 * ring * nbytes
        elif kind == "collective-permute":
            wire = float(nbytes)
        else:  # all-gather / reduce-scatter / all-to-all
            wire = ring * nbytes
        stats.counts[kind] = stats.counts.get(kind, 0) + 1
        stats.op_bytes[kind] = stats.op_bytes.get(kind, 0) + nbytes
        stats.wire_bytes[kind] = stats.wire_bytes.get(kind, 0.0) + wire
    return stats


@dataclass
class Roofline:
    flops_per_device: float
    hbm_bytes_per_device: float
    wire_bytes_per_device: float
    n_devices: int
    model_flops_total: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.wire_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time_lower_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        total_hlo = self.flops_per_device * self.n_devices
        return self.model_flops_total / total_hlo if total_hlo else 0.0

    @property
    def mfu_upper_bound(self) -> float:
        """Model-flops utilization if the step ran exactly at the roofline."""
        t = self.step_time_lower_bound
        if t <= 0:
            return 0.0
        return (self.model_flops_total
                / (self.n_devices * PEAK_FLOPS_BF16 * t))

    def as_dict(self) -> Dict[str, float]:
        return {
            "flops_per_device": self.flops_per_device,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "wire_bytes_per_device": self.wire_bytes_per_device,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops_total": self.model_flops_total,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_upper_bound": self.mfu_upper_bound,
        }


def model_flops_for_step(cfg, step_kind: str, seq_len: int, global_batch: int
                         ) -> float:
    """6·N·D (train) / 2·N·D (inference) with N = active params."""
    n_active = cfg.active_param_count()
    tokens = (seq_len * global_batch if step_kind in ("train", "prefill")
              else global_batch)
    mult = 6.0 if step_kind == "train" else 2.0
    return mult * n_active * tokens
