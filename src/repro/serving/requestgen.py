"""Request generation + the client-side SLO measurement point.

:class:`RequestGenerator` turns a :class:`~repro.serving.config.ServingConfig`
into analytic per-request arrival times (reusing
:meth:`~repro.core.loadgen.TrafficPattern.emission_schedule`, so poisson /
bursty / uniform arrivals behave exactly like the echo workloads') plus
per-request prompt/output token draws from the
:class:`~repro.serving.config.RequestMixConfig` distributions.

:class:`ServingClient` is the fabric-attached user population for one switch
port: it emits each due request as a multi-frame flow addressed to the
balancer, tracks per-request state as token frames come home, and records
the serving SLOs in virtual ns:

* **TTFT** — time to first token: first-token arrival minus request
  emission (includes balancer hop, prefill queueing and prefill compute);
* **TPOT** — time per output token: the mean inter-token gap over the
  decode token stream;
* **E2E**  — request completion latency (the RunReport's latency column).

Everything is deterministic per (config, seed): schedules and token draws
are precomputed, and arrival processing is pure bookkeeping.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.loadgen import TrafficPattern
from repro.core.telemetry import LatencyRecorder, ThroughputMeter

from .config import ServingConfig
from .protocol import (MSG_FIRST_TOKEN, MSG_REQUEST, MSG_TOKEN, build_frame,
                       is_serving_frame, read_header)

# request ids: client g owns [(g+1) << 22, (g+2) << 22) — globally unique
# for up to ~4M requests per client and 1023 clients in a u32
REQ_ID_STRIDE = 1 << 22


class RequestGenerator:
    """Deterministic request stream: arrival times + token-length draws."""

    def __init__(self, serving: ServingConfig, seed: int):
        self.serving = serving
        self.seed = int(seed)
        # offered QPS -> the pattern's packets-per-second identity:
        # pps == rate_gbps * 1e9 / 8 / packet_size
        rate_gbps = (serving.qps * serving.request_frame_bytes * 8) / 1e9
        self.pattern = TrafficPattern(
            rate_gbps=rate_gbps, packet_size=serving.request_frame_bytes,
            kind=serving.arrival_kind, burst_len=serving.arrival_burst_len,
            seed=self.seed)

    def generate(self, duration_ns: int):
        """(times int64[n], prompt_tokens int64[n], output_tokens int64[n])."""
        rng = np.random.default_rng(self.seed)
        times, _sizes = self.pattern.emission_schedule(duration_ns, rng)
        prompts, outputs = self.serving.mix.sample(rng, len(times))
        return times, prompts, outputs


@dataclass
class _RequestState:
    emit_ns: int
    prompt_tokens: int
    output_tokens: int
    tokens_received: int = 0
    first_ns: Optional[int] = None
    last_ns: Optional[int] = None
    done: bool = False


@dataclass
class ServingClient:
    """One client population on one switch port: emits requests, measures
    SLOs on the token stream coming back."""

    serving: ServingConfig
    client_index: int
    src_ip: int
    balancer_ip: int
    seed: int

    requests_sent: int = 0
    requests_completed: int = 0
    frames_sent: int = 0
    tokens_received: int = 0
    stray_frames: int = 0  # non-serving or unknown-request arrivals

    ttft: LatencyRecorder = field(default_factory=LatencyRecorder)
    tpot: LatencyRecorder = field(default_factory=LatencyRecorder)
    e2e: LatencyRecorder = field(default_factory=LatencyRecorder)
    meter: ThroughputMeter = field(default_factory=ThroughputMeter)

    def __post_init__(self) -> None:
        self.gen = RequestGenerator(self.serving, self.seed)
        self._req: Dict[int, _RequestState] = {}
        self._times = np.empty(0, dtype=np.int64)
        self._prompts = np.empty(0, dtype=np.int64)
        self._outputs = np.empty(0, dtype=np.int64)
        self._req_id_base = (self.client_index + 1) * REQ_ID_STRIDE

    # -- emission --------------------------------------------------------------
    def plan(self, duration_ns: int, start_ns: int) -> np.ndarray:
        """Precompute this run's request stream; returns the arrival times
        (already offset to ``start_ns``) the driver walks a cursor over."""
        times, prompts, outputs = self.gen.generate(duration_ns)
        self._times = times + start_ns if len(times) else times
        self._prompts, self._outputs = prompts, outputs
        if len(self._times):
            self.meter.open_window(int(self._times[0]))
        return self._times

    def emit_request(self, i: int, t_emit: int) -> List[np.ndarray]:
        """Materialize request ``i`` of the plan as its frame flow (all
        frames enter the client's uplink at ``t_emit``; the wire's FIFO
        serialization spaces them)."""
        s = self.serving
        prompt = int(self._prompts[i])
        output = int(self._outputs[i])
        req_id = self._req_id_base + i
        n_frames = s.request_frames(prompt)
        frames: List[np.ndarray] = []
        for seg in range(n_frames):
            buf = np.zeros(s.request_frame_bytes, dtype=np.uint8)
            build_frame(buf, size=s.request_frame_bytes,
                        seq=self.frames_sent, src_ip=self.src_ip,
                        dst_ip=self.balancer_ip, stamp_ns=t_emit,
                        msg=MSG_REQUEST, req_id=req_id, seg=seg,
                        seg_count=n_frames, prompt_tokens=prompt,
                        output_tokens=output, last=(seg == n_frames - 1))
            self.frames_sent += 1
            frames.append(buf)
        self._req[req_id] = _RequestState(
            emit_ns=t_emit, prompt_tokens=prompt, output_tokens=output)
        self.requests_sent += 1
        return frames

    # -- arrivals (the switch egress sink calls this) --------------------------
    def complete_frame(self, frame: np.ndarray, t_ns: int) -> None:
        if not is_serving_frame(frame):
            self.stray_frames += 1
            return
        hdr = read_header(frame)
        st = self._req.get(hdr.req_id)
        if st is None or st.done or hdr.msg not in (MSG_FIRST_TOKEN, MSG_TOKEN):
            self.stray_frames += 1
            return
        self.meter.on_packet(t_ns, len(frame))
        st.tokens_received += 1
        self.tokens_received += 1
        if hdr.msg == MSG_FIRST_TOKEN and st.first_ns is None:
            st.first_ns = t_ns
            self.ttft.record(t_ns - st.emit_ns)
        st.last_ns = t_ns
        if st.tokens_received >= st.output_tokens:
            st.done = True
            self.requests_completed += 1
            self.e2e.record(t_ns - st.emit_ns)
            if st.first_ns is not None and st.output_tokens > 1:
                self.tpot.record(
                    (st.last_ns - st.first_ns) / (st.output_tokens - 1))

    # -- accounting ------------------------------------------------------------
    @property
    def requests_incomplete(self) -> int:
        return self.requests_sent - self.requests_completed

    def extras(self) -> Dict[str, float]:
        return {
            "requests_sent": float(self.requests_sent),
            "requests_completed": float(self.requests_completed),
            "tokens_received": float(self.tokens_received),
            "stray_frames": float(self.stray_frames),
        }
