"""LLM-inference-serving application layer over the simulated fabric.

Importing this package registers the serving stack kinds (``balancer``,
``prefill``, ``decode``) with the testbed stack registry — ``Cluster.build``
does so when a :class:`~repro.exp.config.TopologyConfig` carries a
:class:`ServingConfig`.
"""
from .config import (BALANCER_POLICIES, MIN_SERVING_FRAME, TOKEN_DISTS,
                     RequestMixConfig, ServingConfig)
from .protocol import (FLAG_LAST, HEADER_END, MAGIC, MSG_FIRST_TOKEN,
                       MSG_KV_SEG, MSG_REQUEST, MSG_TOKEN, SERVING_DST_PORT,
                       ServingHeader, build_frame, is_serving_frame,
                       read_header, set_aux, set_dst_ip, write_header)
from .requestgen import RequestGenerator, ServingClient
from .stacks import (BalancerServer, DecodeServer, PrefillServer,
                     wire_serving)

__all__ = [
    "BALANCER_POLICIES",
    "TOKEN_DISTS",
    "MIN_SERVING_FRAME",
    "RequestMixConfig",
    "ServingConfig",
    "ServingHeader",
    "MAGIC",
    "HEADER_END",
    "FLAG_LAST",
    "MSG_REQUEST",
    "MSG_FIRST_TOKEN",
    "MSG_KV_SEG",
    "MSG_TOKEN",
    "SERVING_DST_PORT",
    "build_frame",
    "read_header",
    "write_header",
    "is_serving_frame",
    "set_dst_ip",
    "set_aux",
    "RequestGenerator",
    "ServingClient",
    "BalancerServer",
    "PrefillServer",
    "DecodeServer",
    "wire_serving",
]
