"""Serving node stacks: balancer, prefill, decode — registered stack kinds.

All three are :class:`~repro.core.netstack.NetworkStack` subclasses built by
the same registry (:func:`~repro.exp.testbed.register_stack`) single-host
testbeds use, so they inherit the whole NIC/descriptor/lcore machinery: RSS
steering into multi-queue rings, writeback thresholds, per-queue
:class:`~repro.core.netstack.ServerStats`, and virtual-time lcore busy
windows.

Execution model (prefill/decode): **two lcores**, mirroring a real serving
host's split between a NIC polling thread and an accelerator engine —

* lcore 0 — *harvest*: polls every RX queue, parses serving frames into
  application state (request/KV reassembly), charged at the PMD cost model;
* lcore 1 — *engine*: the continuous-batching iteration loop.  Starting an
  iteration charges ``overhead + ns_per_token·batch_tokens`` to the lcore's
  busy window, so the cluster event loop next wakes the engine exactly at
  iteration completion — queueing delay and compute time land in measured
  TTFT/TPOT with no extra machinery.

The balancer is a single-lcore forwarding stack: it rewrites each request
frame's flow dst_ip to the chosen prefill replica (zero-copy, in its own
arena) and pins a decode replica in the header's aux word.

A stack built from the registry alone is *unwired* (it knows no peers); it
drops every frame it harvests and counts it, so serving kinds degrade
cleanly in single-host testbeds (the engine-fallback taxonomy tests rely on
this).  :func:`wire_serving` — called by ``Cluster.build`` — installs the
:class:`~repro.serving.config.ServingConfig`, role ips, and policy state.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.ethdev import EthDev
from repro.core.netstack import Lcore, NetworkStack, ServerStats
from repro.exp.testbed import register_stack

from .config import ServingConfig
from .protocol import (MSG_FIRST_TOKEN, MSG_KV_SEG, MSG_REQUEST, MSG_TOKEN,
                       build_frame, is_serving_frame, read_header, set_aux,
                       set_dst_ip)


class _ServingStackBase(NetworkStack):
    """Shared harvest/emit machinery for the serving node stacks."""

    _HARVEST, _ENGINE = 0, 1

    def __init__(self, port, burst_size: int = 32):
        super().__init__([port], n_lcores=1, burst_size=burst_size)
        all_queues = [(0, qi) for qi in range(port.n_queues)]
        self.lcores = [Lcore(self._HARVEST, all_queues, burst_size),
                       Lcore(self._ENGINE, [], burst_size)]
        self.port = port
        self.burst_size = burst_size
        self.serving: Optional[ServingConfig] = None
        self.node_ip = 0
        self._seq = 0
        self._tx_rr = 0
        # counters every role shares
        self.non_serving_drops = 0   # frames without the serving header
        self.unwired_drops = 0       # frames seen before wire_serving
        self.tx_alloc_failures = 0   # node arena exhausted on emit
        self.tx_ring_drops = 0       # TX descriptor ring full on emit

    # -- lcore dispatch --------------------------------------------------------
    def run_lcore(self, lcore: Lcore) -> int:
        if lcore.lcore_id == self._HARVEST:
            return self._harvest_pass(lcore)
        return self._engine_step()

    def _harvest_pass(self, lcore: Lcore) -> int:
        total = 0
        for pi, qi in lcore.assignments:
            qstats = self.queue_stats[(pi, qi)]
            slots, lengths = self.port.rx_burst(qi, lcore.burst_size)
            qstats.poll_iterations += 1
            n = len(slots)
            if n == 0:
                qstats.empty_polls += 1
                continue
            qstats.record_burst(n)
            qstats.rx_packets += n
            qstats.rx_bytes += int(lengths.sum())
            for k in range(n):
                slot = int(slots[k])
                frame = self.port.pool.view(slot, int(lengths[k]))
                self._consume(frame)
                self.port.pool.free(slot)
            if self.clock is not None:
                self.charge_ns(self.sim_cost.pmd_burst_ns(n))
            total += n
        return total

    def _consume(self, frame: np.ndarray) -> None:
        """Parse one harvested frame into application state (frame bytes are
        only valid for the duration of the call)."""
        if not is_serving_frame(frame):
            self.non_serving_drops += 1
            return
        if self.serving is None:
            self.unwired_drops += 1
            return
        self._on_serving_frame(frame)

    def _on_serving_frame(self, frame: np.ndarray) -> None:
        raise NotImplementedError

    def _engine_step(self) -> int:
        return 0  # balancer has no engine; prefill/decode override

    # -- emission --------------------------------------------------------------
    def _emit(self, *, size: int, dst_ip: int, msg: int, req_id: int,
              seg: int = 0, seg_count: int = 1, prompt_tokens: int = 0,
              output_tokens: int = 0, aux: int = 0, last: bool = False) -> bool:
        """Format one serving frame in the node arena and post it on a TX
        queue (round-robin); the cluster drains TX onto the fabric."""
        pool = self.port.pool
        slot = pool.alloc()
        if slot is None:
            self.tx_alloc_failures += 1
            return False
        build_frame(pool.arena[slot], size=size, seq=self._seq,
                    src_ip=self.node_ip, dst_ip=dst_ip,
                    stamp_ns=self._poll_now_ns, msg=msg, req_id=req_id,
                    seg=seg, seg_count=seg_count, prompt_tokens=prompt_tokens,
                    output_tokens=output_tokens, aux=aux, last=last)
        self._seq += 1
        pool.lengths[slot] = size
        q = self._tx_rr % self.port.n_queues
        self._tx_rr += 1
        if not self.port.tx_queues[q].post(slot, size):
            pool.free(slot)
            self.tx_ring_drops += 1
            return False
        self.queue_stats[(0, q)].tx_packets += 1
        return True

    def _base_extras(self, role: str) -> Dict[str, float]:
        return {
            f"{role}_non_serving_drops": float(self.non_serving_drops),
            f"{role}_unwired_drops": float(self.unwired_drops),
            f"{role}_tx_alloc_failures": float(self.tx_alloc_failures),
            f"{role}_tx_ring_drops": float(self.tx_ring_drops),
        }


class BalancerServer(_ServingStackBase):
    """The flexlb-style front door: routes each request flow to a prefill
    replica and pins a decode replica for its KV cache + token stream.

    Policies (per request, all deterministic):

    * ``round_robin`` — cycle the prefill replicas;
    * ``least_loaded`` — the replica with the fewest queued-or-running
      prompt tokens (an in-fabric oracle: the balancer reads replica queue
      depths with zero staleness — the idealized upper bound a real
      heartbeat-based flexlb approximates);
    * ``weighted`` — smooth weighted round-robin over
      ``ServingConfig.prefill_weights`` (weight 0 excludes a replica).

    Decode replicas are pinned round-robin over the healthy set; after
    ``fail_at_ns`` the failed replica is withdrawn for *new* requests
    (in-flight requests pinned to it strand — the failover observable).
    """

    def __init__(self, port, burst_size: int = 32):
        super().__init__(port, burst_size)
        self.prefill_ips: List[int] = []
        self.decode_ips: List[int] = []
        self.prefill_servers: List["PrefillServer"] = []
        self.weights: List[int] = []
        self._wrr_current: List[int] = []
        self._rr_prefill = 0
        self._rr_decode = 0
        self.fail_decode_ip: Optional[int] = None
        self.fail_at_ns: Optional[int] = None
        # req_id -> (prefill_ip, decode_ip) while the request flow is in flight
        self._route: Dict[int, Tuple[int, int]] = {}
        self.requests_routed = 0
        self.frames_forwarded = 0
        self.per_prefill_requests: List[int] = []

    def wire(self, serving: ServingConfig, node_ip: int,
             prefill_ips: Sequence[int], decode_ips: Sequence[int],
             prefill_servers: Sequence["PrefillServer"]) -> None:
        self.serving = serving
        self.node_ip = node_ip
        self.prefill_ips = list(prefill_ips)
        self.decode_ips = list(decode_ips)
        self.prefill_servers = list(prefill_servers)
        self.weights = (list(serving.prefill_weights)
                        if serving.prefill_weights is not None
                        else [1] * len(self.prefill_ips))
        self._wrr_current = [0] * len(self.prefill_ips)
        self.per_prefill_requests = [0] * len(self.prefill_ips)
        if serving.fail_node:
            self.fail_decode_ip = decode_ips[
                serving.decode.index(serving.fail_node)]
            self.fail_at_ns = serving.fail_at_ns()

    # -- policy ----------------------------------------------------------------
    def _pick_prefill(self) -> int:
        s = self.serving
        if s.policy == "least_loaded" and self.prefill_servers:
            loads = [srv.queued_tokens for srv in self.prefill_servers]
            return int(np.argmin(loads))  # ties -> lowest index
        if s.policy == "weighted":
            # smooth weighted round-robin (nginx): deterministic, spreads
            # picks evenly at every prefix of the sequence
            total = sum(self.weights)
            for i, w in enumerate(self.weights):
                self._wrr_current[i] += w
            best = max(range(len(self.weights)),
                       key=lambda i: (self._wrr_current[i], -i))
            self._wrr_current[best] -= total
            return best
        i = self._rr_prefill % len(self.prefill_ips)
        self._rr_prefill += 1
        return i

    def _pick_decode(self, now_ns: int) -> int:
        healthy = [ip for ip in self.decode_ips
                   if not (self.fail_at_ns is not None
                           and now_ns >= self.fail_at_ns
                           and ip == self.fail_decode_ip)]
        if not healthy:
            healthy = self.decode_ips  # nothing left: route and strand
        ip = healthy[self._rr_decode % len(healthy)]
        self._rr_decode += 1
        return ip

    # -- dataplane -------------------------------------------------------------
    def _on_serving_frame(self, frame: np.ndarray) -> None:
        hdr = read_header(frame)
        if hdr.msg != MSG_REQUEST:
            self.non_serving_drops += 1
            return
        route = self._route.get(hdr.req_id)
        if route is None:
            pi = self._pick_prefill()
            decode_ip = self._pick_decode(self._poll_now_ns)
            route = (self.prefill_ips[pi], decode_ip)
            self._route[hdr.req_id] = route
            self.per_prefill_requests[pi] += 1
            self.requests_routed += 1
        if hdr.last:
            self._route.pop(hdr.req_id, None)
        prefill_ip, decode_ip = route
        # zero-copy forward: rewrite dst + pin the decode replica, then
        # re-emit the same bytes from this node's arena
        out = frame.copy()
        set_dst_ip(out, prefill_ip)
        set_aux(out, decode_ip)
        self._forward(out)

    def _forward(self, frame: np.ndarray) -> None:
        pool = self.port.pool
        slot = pool.alloc()
        if slot is None:
            self.tx_alloc_failures += 1
            return
        n = len(frame)
        pool.arena[slot, :n] = frame
        pool.lengths[slot] = n
        q = self._tx_rr % self.port.n_queues
        self._tx_rr += 1
        if not self.port.tx_queues[q].post(slot, n):
            pool.free(slot)
            self.tx_ring_drops += 1
            return
        self.queue_stats[(0, q)].tx_packets += 1
        self.frames_forwarded += 1

    def extras(self) -> Dict[str, float]:
        out = self._base_extras("lb")
        out["lb_requests_routed"] = float(self.requests_routed)
        out["lb_frames_forwarded"] = float(self.frames_forwarded)
        for i, c in enumerate(self.per_prefill_requests):
            out[f"lb_prefill{i}_requests"] = float(c)
        return out


class _PendingRequest:
    __slots__ = ("req_id", "client_ip", "decode_ip", "prompt_tokens",
                 "output_tokens", "frames_seen")

    def __init__(self, req_id: int, client_ip: int, decode_ip: int,
                 prompt_tokens: int, output_tokens: int):
        self.req_id = req_id
        self.client_ip = client_ip
        self.decode_ip = decode_ip
        self.prompt_tokens = prompt_tokens
        self.output_tokens = output_tokens
        self.frames_seen = 0


class PrefillServer(_ServingStackBase):
    """Prefill replica: reassembles request flows, runs continuous-batching
    prefill iterations, and on completion emits the first token to the
    client plus the KV-cache elephant flow to the pinned decode replica."""

    def __init__(self, port, burst_size: int = 32):
        super().__init__(port, burst_size)
        self.queue: Deque[_PendingRequest] = deque()
        self._reasm: Dict[int, _PendingRequest] = {}
        self._batch: Optional[List[_PendingRequest]] = None
        self._batch_done_ns = 0
        self.queued_tokens = 0  # queued + running prompt tokens (lb oracle)
        self.requests_in = 0
        self.batches = 0
        self.batch_tokens_total = 0
        self.queue_high = 0
        self.first_tokens_sent = 0
        self.kv_segments_sent = 0

    def wire(self, serving: ServingConfig, node_ip: int) -> None:
        self.serving = serving
        self.node_ip = node_ip

    def _on_serving_frame(self, frame: np.ndarray) -> None:
        hdr = read_header(frame)
        if hdr.msg != MSG_REQUEST:
            self.non_serving_drops += 1
            return
        st = self._reasm.get(hdr.req_id)
        if st is None:
            from repro.core.packet import read_flow
            src_ip, _dst, _sp, _dp = read_flow(frame)
            st = _PendingRequest(hdr.req_id, src_ip, hdr.aux,
                                 hdr.prompt_tokens, hdr.output_tokens)
            self._reasm[hdr.req_id] = st
        st.frames_seen += 1
        if st.frames_seen >= hdr.seg_count:
            del self._reasm[hdr.req_id]
            self.queue.append(st)
            self.queued_tokens += st.prompt_tokens
            self.requests_in += 1
            self.queue_high = max(self.queue_high, len(self.queue))

    def _engine_step(self) -> int:
        if self.serving is None:
            return 0
        now = self._poll_now_ns
        moved = 0
        if self._batch is not None and now >= self._batch_done_ns:
            for req in self._batch:
                self._complete(req)
            moved += len(self._batch)
            self._batch = None
        if self._batch is None and self.queue:
            s = self.serving
            batch: List[_PendingRequest] = []
            tokens = 0
            while self.queue and len(batch) < s.max_batch_requests:
                nxt = self.queue[0]
                if batch and tokens + nxt.prompt_tokens > s.max_batch_tokens:
                    break
                batch.append(self.queue.popleft())
                tokens += nxt.prompt_tokens
            iter_ns = (s.prefill_overhead_ns
                       + tokens * s.resolved_prefill_ns_per_token())
            self.charge_ns(iter_ns)
            self._batch = batch
            self._batch_done_ns = now + int(iter_ns)
            self.batches += 1
            self.batch_tokens_total += tokens
            moved += len(batch)
        return moved

    def _complete(self, req: _PendingRequest) -> None:
        s = self.serving
        self.queued_tokens -= req.prompt_tokens
        # first token home (TTFT stops here — it never waits on the KV path)
        if self._emit(size=s.token_frame_bytes, dst_ip=req.client_ip,
                      msg=MSG_FIRST_TOKEN, req_id=req.req_id, seg=0,
                      seg_count=req.output_tokens,
                      prompt_tokens=req.prompt_tokens,
                      output_tokens=req.output_tokens,
                      last=(req.output_tokens <= 1)):
            self.first_tokens_sent += 1
        if req.output_tokens <= 1:
            return  # single-token request: no decode phase, no KV transfer
        # KV-cache elephant flow to the pinned decode replica
        n_segs = s.kv_segments(req.prompt_tokens)
        for seg in range(n_segs):
            if self._emit(size=s.kv_segment_bytes, dst_ip=req.decode_ip,
                          msg=MSG_KV_SEG, req_id=req.req_id, seg=seg,
                          seg_count=n_segs, prompt_tokens=req.prompt_tokens,
                          output_tokens=req.output_tokens, aux=req.client_ip,
                          last=(seg == n_segs - 1)):
                self.kv_segments_sent += 1

    def extras(self) -> Dict[str, float]:
        out = self._base_extras("prefill")
        out.update({
            "prefill_requests_in": float(self.requests_in),
            "prefill_batches": float(self.batches),
            "prefill_batch_tokens": float(self.batch_tokens_total),
            "prefill_queue_high": float(self.queue_high),
            "prefill_first_tokens": float(self.first_tokens_sent),
            "prefill_kv_segments": float(self.kv_segments_sent),
            "prefill_reasm_pending": float(len(self._reasm)),
        })
        return out


class DecodeServer(_ServingStackBase):
    """Decode replica: reassembles KV elephant flows, then streams one output
    token per continuous-batching iteration per running request.

    Failover: after ``fail_at_ns`` (wired for the configured ``fail_node``
    only) the engine stops and arriving frames are dropped — requests pinned
    here strand, which the client reports as incomplete."""

    def __init__(self, port, burst_size: int = 32):
        super().__init__(port, burst_size)
        self._reasm: Dict[int, Tuple[_PendingRequest, int]] = {}
        self.pending: Deque[_PendingRequest] = deque()
        self.running: List[_PendingRequest] = []
        self._emitted: Dict[int, int] = {}  # req_id -> tokens emitted so far
        self._iter_busy = False
        self._iter_done_ns = 0
        self.fail_at_ns: Optional[int] = None
        self.kv_segments_in = 0
        self.requests_admitted = 0
        self.iterations = 0
        self.tokens_out = 0
        self.requests_done = 0
        self.running_high = 0
        self.failed_drops = 0      # frames discarded after the failure time
        self.stranded_requests = 0  # running/pending abandoned at failure

    def wire(self, serving: ServingConfig, node_ip: int,
             fail_at_ns: Optional[int] = None) -> None:
        self.serving = serving
        self.node_ip = node_ip
        self.fail_at_ns = fail_at_ns

    def _failed(self, now_ns: int) -> bool:
        return self.fail_at_ns is not None and now_ns >= self.fail_at_ns

    def _on_serving_frame(self, frame: np.ndarray) -> None:
        if self._failed(self._poll_now_ns):
            self.failed_drops += 1
            return
        hdr = read_header(frame)
        if hdr.msg != MSG_KV_SEG:
            self.non_serving_drops += 1
            return
        self.kv_segments_in += 1
        entry = self._reasm.get(hdr.req_id)
        if entry is None:
            req = _PendingRequest(hdr.req_id, hdr.aux, self.node_ip,
                                  hdr.prompt_tokens, hdr.output_tokens)
            entry = (req, 0)
        req, seen = entry
        seen += 1
        if seen >= hdr.seg_count:
            self._reasm.pop(hdr.req_id, None)
            self.pending.append(req)
        else:
            self._reasm[hdr.req_id] = (req, seen)

    def _engine_step(self) -> int:
        if self.serving is None:
            return 0
        now = self._poll_now_ns
        if self._failed(now):
            if self.running or self.pending:
                self.stranded_requests += len(self.running) + len(self.pending)
                self.running = []
                self.pending.clear()
                self._iter_busy = False
            return 0
        s = self.serving
        moved = 0
        if self._iter_busy and now >= self._iter_done_ns:
            self._iter_busy = False
            still: List[_PendingRequest] = []
            for req in self.running:
                # token 0 came from prefill; we stream 1..output_tokens-1
                emitted = self._emitted.get(req.req_id, 1) + 1
                done = emitted >= req.output_tokens
                if self._emit(size=s.token_frame_bytes, dst_ip=req.client_ip,
                              msg=MSG_TOKEN, req_id=req.req_id,
                              seg=emitted - 1, seg_count=req.output_tokens,
                              prompt_tokens=req.prompt_tokens,
                              output_tokens=req.output_tokens, last=done):
                    self.tokens_out += 1
                moved += 1
                if done:
                    self._emitted.pop(req.req_id, None)
                    self.requests_done += 1
                else:
                    self._emitted[req.req_id] = emitted
                    still.append(req)
            self.running = still
        if not self._iter_busy:
            while self.pending and len(self.running) < s.decode_max_batch_requests:
                req = self.pending.popleft()
                self._emitted[req.req_id] = 1
                self.running.append(req)
                self.requests_admitted += 1
                moved += 1
            self.running_high = max(self.running_high, len(self.running))
            if self.running:
                iter_ns = (s.resolved_decode_overhead_ns()
                           + len(self.running) * s.resolved_decode_ns_per_token())
                self.charge_ns(iter_ns)
                self._iter_busy = True
                self._iter_done_ns = now + int(iter_ns)
                self.iterations += 1
        return moved

    def extras(self) -> Dict[str, float]:
        out = self._base_extras("decode")
        out.update({
            "decode_kv_segments_in": float(self.kv_segments_in),
            "decode_requests_admitted": float(self.requests_admitted),
            "decode_iterations": float(self.iterations),
            "decode_tokens_out": float(self.tokens_out),
            "decode_requests_done": float(self.requests_done),
            "decode_running_high": float(self.running_high),
            "decode_reasm_pending": float(len(self._reasm)),
            "decode_failed_drops": float(self.failed_drops),
            "decode_stranded_requests": float(self.stranded_requests),
        })
        return out


# -- registry ------------------------------------------------------------------
@register_stack("balancer")
def _build_balancer(cfg, devs: Sequence[EthDev]) -> NetworkStack:
    return BalancerServer(devs[0], burst_size=cfg.burst_size)


@register_stack("prefill")
def _build_prefill(cfg, devs: Sequence[EthDev]) -> NetworkStack:
    return PrefillServer(devs[0], burst_size=cfg.burst_size)


@register_stack("decode")
def _build_decode(cfg, devs: Sequence[EthDev]) -> NetworkStack:
    return DecodeServer(devs[0], burst_size=cfg.burst_size)


def wire_serving(serving: ServingConfig, nodes_by_name: Dict[str, object]) -> None:
    """Install role wiring on a built cluster's serving stacks (called by
    ``Cluster.build``): resolved ips, policy state, and the failover clock.
    ``nodes_by_name`` maps node name -> the builder's Node (needs ``.ip`` and
    ``.server``)."""

    def node(name: str):
        return nodes_by_name[name]

    prefill_nodes = [node(n) for n in serving.prefill]
    decode_nodes = [node(n) for n in serving.decode]
    lb = node(serving.balancer)
    for n in prefill_nodes:
        n.server.wire(serving, n.ip)
    fail_at = serving.fail_at_ns()
    for n in decode_nodes:
        n.server.wire(serving, n.ip,
                      fail_at_ns=(fail_at if n.cfg.name == serving.fail_node
                                  else None))
    lb.server.wire(serving, lb.ip,
                   prefill_ips=[n.ip for n in prefill_nodes],
                   decode_ips=[n.ip for n in decode_nodes],
                   prefill_servers=[n.server for n in prefill_nodes])
