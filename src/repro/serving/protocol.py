"""Serving wire protocol: an application header in the frame payload.

Serving frames are ordinary fabric frames — the flow 4-tuple at
``FLOW_OFFSET`` still drives switch routing and RSS steering, the seq and
timestamp words are where every workload puts them — with an application
header in the payload region (offset 42, right after the flow tuple):

====== ====== =============================================================
offset size   field
====== ====== =============================================================
42     2      magic (LE) — ``MAGIC``; anything else is not a serving frame
44     1      msg type — REQUEST / FIRST_TOKEN / KV_SEG / TOKEN
45     1      flags — bit0: last frame of its flow (request/KV/token stream)
46     4      request id (LE) — globally unique across clients
50     4      segment index (LE) — request frame / KV segment / token index
54     4      segment count (LE) — total frames in this frame's flow
58     4      prompt tokens (LE)
62     4      output tokens (LE)
66     4      aux (LE) — REQUEST: decode-replica ip pinned by the balancer
              (0 until routed); KV_SEG: the client ip the decode node
              streams tokens to
====== ====== =============================================================

Message flow for one request::

    client --REQUEST*n--> balancer --(rewrite dst, pin decode)--> prefill
    prefill --FIRST_TOKEN--> client          (TTFT measured here)
    prefill --KV_SEG*m--> decode             (the elephant flow)
    decode  --TOKEN*k--> client              (TPOT measured here)

All helpers operate on any uint8 buffer (arena views and standalone
arrays alike).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.packet import ETHERTYPE, write_flow, write_seq

PAYLOAD_OFFSET = 42  # FLOW_OFFSET + FLOW_SIZE
MAGIC = 0x5E15
HEADER_END = 70

MSG_REQUEST = 1      # client -> balancer -> prefill (prompt shard)
MSG_FIRST_TOKEN = 2  # prefill -> client (prefill done; token 0)
MSG_KV_SEG = 3       # prefill -> decode (KV-cache transfer segment)
MSG_TOKEN = 4        # decode -> client (output token i >= 1)

FLAG_LAST = 0x01

SERVING_DST_PORT = 8000  # flow dst_port for all serving traffic


@dataclass
class ServingHeader:
    msg: int
    flags: int
    req_id: int
    seg: int
    seg_count: int
    prompt_tokens: int
    output_tokens: int
    aux: int

    @property
    def last(self) -> bool:
        return bool(self.flags & FLAG_LAST)


def _put_u32(buf: np.ndarray, off: int, value: int) -> None:
    buf[off:off + 4] = np.frombuffer(
        int(value).to_bytes(4, "little"), dtype=np.uint8)


def _get_u32(buf: np.ndarray, off: int) -> int:
    return int.from_bytes(bytes(buf[off:off + 4]), "little")


def is_serving_frame(buf: np.ndarray) -> bool:
    return (len(buf) >= HEADER_END
            and int.from_bytes(bytes(buf[42:44]), "little") == MAGIC)


def write_header(buf: np.ndarray, *, msg: int, req_id: int, seg: int = 0,
                 seg_count: int = 1, prompt_tokens: int = 0,
                 output_tokens: int = 0, aux: int = 0,
                 last: bool = False) -> None:
    buf[42:44] = np.frombuffer(MAGIC.to_bytes(2, "little"), dtype=np.uint8)
    buf[44] = msg
    buf[45] = FLAG_LAST if last else 0
    _put_u32(buf, 46, req_id)
    _put_u32(buf, 50, seg)
    _put_u32(buf, 54, seg_count)
    _put_u32(buf, 58, prompt_tokens)
    _put_u32(buf, 62, output_tokens)
    _put_u32(buf, 66, aux)


def read_header(buf: np.ndarray) -> ServingHeader:
    return ServingHeader(
        msg=int(buf[44]), flags=int(buf[45]),
        req_id=_get_u32(buf, 46), seg=_get_u32(buf, 50),
        seg_count=_get_u32(buf, 54), prompt_tokens=_get_u32(buf, 58),
        output_tokens=_get_u32(buf, 62), aux=_get_u32(buf, 66))


def set_dst_ip(buf: np.ndarray, dst_ip: int) -> None:
    """Rewrite the flow dst_ip in place (the balancer's forwarding op)."""
    buf[34:38] = np.frombuffer(
        int(dst_ip).to_bytes(4, "big"), dtype=np.uint8)


def set_aux(buf: np.ndarray, aux: int) -> None:
    """Rewrite the aux word in place (the balancer pins the decode ip)."""
    _put_u32(buf, 66, aux)


def build_frame(buf: np.ndarray, *, size: int, seq: int, src_ip: int,
                dst_ip: int, stamp_ns: int, msg: int, req_id: int,
                seg: int = 0, seg_count: int = 1, prompt_tokens: int = 0,
                output_tokens: int = 0, aux: int = 0,
                last: bool = False) -> None:
    """Format one complete serving frame into ``buf[:size]``.

    The flow src_port carries ``req_id`` entropy so multi-queue RSS spreads
    concurrent requests across a node's queues; dst_port is the serving
    port.  ``buf`` must hold at least ``size`` >= HEADER_END bytes.
    """
    if size < HEADER_END:
        raise ValueError(f"serving frame size {size} < header end {HEADER_END}")
    frame = buf[:size]
    frame[0:6] = 0x0E   # serving dst "mac"
    frame[6:12] = 0x0A  # serving src "mac"
    frame[12] = (ETHERTYPE >> 8) & 0xFF
    frame[13] = ETHERTYPE & 0xFF
    write_seq(frame, seq)
    # ts word (offset 22): the emission stamp, for debuggability — SLO
    # accounting happens at the client on arrival times
    frame[22:30] = np.frombuffer(
        int(stamp_ns).to_bytes(8, "little"), dtype=np.uint8)
    write_flow(frame, src_ip, dst_ip, 1024 + (req_id % 60000),
               SERVING_DST_PORT)
    frame[HEADER_END:size] = 0
    write_header(frame, msg=msg, req_id=req_id, seg=seg, seg_count=seg_count,
                 prompt_tokens=prompt_tokens, output_tokens=output_tokens,
                 aux=aux, last=last)
