"""Frozen, declarative LLM-serving configs — pure data, JSON round-trip.

The serving layer turns the fabric's echo workloads into a stateful
application: clients emit *requests* (multi-frame flows), a balancer routes
them across prefill replicas, prefill nodes run continuous-batching
iterations and ship the KV cache to a decode replica as an elephant flow,
and decode nodes stream output tokens back to the client.  Everything the
scenario needs is described here:

* :class:`RequestMixConfig` — the workload: which model architecture
  (``repro.models`` registry id) and the prompt/output token-length
  distributions drawn per request.
* :class:`ServingConfig` — the deployment: node roles, balancer policy,
  offered request rate, continuous-batching limits, the compute cost model
  (derivable from the model config, overridable as data), wire formats for
  request/token/KV-segment frames, and an optional decode-replica failover.

Like every config in :mod:`repro.exp.config`, these are frozen dataclasses
with exact ``to_dict``/``from_dict`` round-trip.  Nothing here imports the
dataplane or the exp layer — :mod:`repro.exp.config` embeds a
``ServingConfig`` inside ``TopologyConfig`` and :mod:`repro.exp.topology`
builds the live objects.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, fields, replace
from typing import Any, Dict, Optional, Tuple

from repro.core.loadgen import TRAFFIC_KINDS
from repro.models.registry import ARCHS, get_config

BALANCER_POLICIES = ("round_robin", "least_loaded", "weighted")
TOKEN_DISTS = ("fixed", "exponential", "lognormal")

# serving frames carry an application header after the flow tuple; keep a
# comfortable floor above it (see repro.serving.protocol.HEADER_END == 70)
MIN_SERVING_FRAME = 96


def _plain(value: Any) -> Any:
    if hasattr(value, "to_dict"):
        return value.to_dict()
    if isinstance(value, tuple):
        return [_plain(v) for v in value]
    return value


def _to_dict(cfg: Any) -> Dict[str, Any]:
    return {f.name: _plain(getattr(cfg, f.name)) for f in fields(cfg)}


@dataclass(frozen=True)
class RequestMixConfig:
    """The request workload: model architecture + token-length distributions.

    ``model`` is an id from the :mod:`repro.models` registry (e.g.
    ``"llama3.2-3b"``, ``"mixtral-8x7b"``); the serving cost model and the
    KV-cache transfer size derive their defaults from its
    :class:`~repro.models.config.ModelConfig`.  Prompt/output lengths are
    drawn per request: ``fixed`` (the mean, exactly), ``exponential``
    (scale == mean), or ``lognormal`` (mean + coefficient of variation),
    clipped into the configured bounds.
    """

    model: str = "llama3.2-3b"
    prompt_mean_tokens: int = 256
    prompt_dist: str = "lognormal"
    prompt_cv: float = 0.5
    max_prompt_tokens: int = 4096
    output_mean_tokens: int = 8
    output_dist: str = "fixed"
    output_cv: float = 0.5
    min_output_tokens: int = 2
    max_output_tokens: int = 512

    def __post_init__(self) -> None:
        if self.model not in ARCHS:
            raise ValueError(
                f"unknown model {self.model!r}; registry has {sorted(ARCHS)}")
        for d, what in ((self.prompt_dist, "prompt_dist"),
                        (self.output_dist, "output_dist")):
            if d not in TOKEN_DISTS:
                raise ValueError(f"{what} must be one of {TOKEN_DISTS}")
        if self.prompt_mean_tokens < 1 or self.output_mean_tokens < 1:
            raise ValueError("token means must be >= 1")
        if self.prompt_cv < 0 or self.output_cv < 0:
            raise ValueError("coefficients of variation must be >= 0")
        if self.max_prompt_tokens < self.prompt_mean_tokens:
            raise ValueError("max_prompt_tokens < prompt_mean_tokens")
        if not 1 <= self.min_output_tokens <= self.max_output_tokens:
            raise ValueError(
                "need 1 <= min_output_tokens <= max_output_tokens")

    def sample(self, rng, n: int):
        """Draw ``n`` (prompt_tokens, output_tokens) pairs — deterministic
        given the generator state.  Returns two int64 numpy arrays."""
        import numpy as np

        def draw(dist, mean, cv, lo, hi):
            if dist == "fixed" or cv == 0.0:
                vals = np.full(n, mean, dtype=np.float64)
                if dist == "exponential" and cv != 0.0:
                    vals = rng.exponential(mean, size=n)
            elif dist == "exponential":
                vals = rng.exponential(mean, size=n)
            else:  # lognormal parameterized by mean + cv
                sigma2 = math.log(1.0 + cv * cv)
                mu = math.log(mean) - sigma2 / 2.0
                vals = rng.lognormal(mu, math.sqrt(sigma2), size=n)
            return np.clip(np.rint(vals).astype(np.int64), lo, hi)

        prompts = draw(self.prompt_dist, self.prompt_mean_tokens,
                       self.prompt_cv, 1, self.max_prompt_tokens)
        outputs = draw(self.output_dist, self.output_mean_tokens,
                       self.output_cv, self.min_output_tokens,
                       self.max_output_tokens)
        return prompts, outputs

    def to_dict(self) -> Dict[str, Any]:
        return _to_dict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "RequestMixConfig":
        return cls(**d)


@dataclass(frozen=True)
class ServingConfig:
    """One disaggregated serving deployment over a ``TopologyConfig``.

    Role wiring: ``balancer``/``prefill``/``decode`` name nodes of the
    enclosing topology; the named nodes' stacks must be the matching
    registered kinds (``"balancer"``/``"prefill"``/``"decode"``).  Clients
    address all requests to the balancer, which rewrites each request flow
    to a prefill replica (``policy``) and pins a decode replica for the
    request's KV cache + token stream.

    Offered load: each client emits ``qps`` requests per second with
    ``arrival_kind`` arrivals (the same analytic schedules
    :meth:`~repro.core.loadgen.TrafficPattern.emission_schedule` gives the
    echo workloads).

    Cost model: per-iteration compute charged to the serving node's engine
    lcore is ``overhead + ns_per_token * batch_tokens``.  ``None`` figures
    derive from the :class:`~repro.models.config.ModelConfig`:

    * ``prefill_ns_per_token`` — 2·active_params FLOPs/token at
      ``hw_tflops`` (compute-bound);
    * ``decode_overhead_ns`` — streaming the weights once per iteration at
      ``hw_hbm_gbps`` GB/s (bandwidth-bound — the continuous-batching
      economics: the overhead amortizes across the running batch);
    * ``decode_ns_per_token`` — the per-request marginal compute, same
      figure as prefill;
    * ``kv_bytes_per_token`` — 2·n_layers·kv_dim·2 bytes (K+V, bf16).
    """

    mix: RequestMixConfig = field(default_factory=RequestMixConfig)
    balancer: str = "lb"
    prefill: Tuple[str, ...] = ("prefill0", "prefill1")
    decode: Tuple[str, ...] = ("decode0", "decode1")
    policy: str = "round_robin"
    prefill_weights: Optional[Tuple[int, ...]] = None
    # offered load, per client
    qps: float = 500.0
    arrival_kind: str = "poisson"
    arrival_burst_len: int = 8
    # continuous batching
    max_batch_tokens: int = 8192
    max_batch_requests: int = 16
    decode_max_batch_requests: int = 64
    # compute cost model (None == derive from the model config)
    prefill_ns_per_token: Optional[int] = None
    prefill_overhead_ns: int = 20_000
    decode_ns_per_token: Optional[int] = None
    decode_overhead_ns: Optional[int] = None
    hw_tflops: float = 200.0
    hw_hbm_gbps: float = 1600.0
    # wire formats
    request_frame_bytes: int = 512
    request_tokens_per_frame: int = 128
    token_frame_bytes: int = 128
    kv_segment_bytes: int = 4096
    kv_bytes_per_token: Optional[int] = None
    # failover: withdraw one decode replica mid-run ("" == no failure)
    fail_node: str = ""
    fail_at_s: float = 0.0

    def __post_init__(self) -> None:
        if not self.balancer or not self.prefill or not self.decode:
            raise ValueError("serving needs a balancer, >=1 prefill and "
                             ">=1 decode node name")
        roles = [self.balancer, *self.prefill, *self.decode]
        if len(set(roles)) != len(roles):
            raise ValueError(f"serving role node names overlap: {roles}")
        if self.policy not in BALANCER_POLICIES:
            raise ValueError(f"policy must be one of {BALANCER_POLICIES}")
        if self.prefill_weights is not None:
            if len(self.prefill_weights) != len(self.prefill):
                raise ValueError(
                    f"prefill_weights has {len(self.prefill_weights)} "
                    f"entries for {len(self.prefill)} prefill nodes")
            if any(w < 0 for w in self.prefill_weights) \
                    or sum(self.prefill_weights) <= 0:
                raise ValueError("prefill_weights must be >= 0, sum > 0")
        if self.qps <= 0:
            raise ValueError("qps must be positive")
        if self.arrival_kind not in TRAFFIC_KINDS:
            raise ValueError(f"arrival_kind must be one of {TRAFFIC_KINDS}")
        if self.arrival_burst_len < 1:
            raise ValueError("arrival_burst_len must be >= 1")
        if self.max_batch_tokens < 1 or self.max_batch_requests < 1 \
                or self.decode_max_batch_requests < 1:
            raise ValueError("batching limits must be >= 1")
        for v, what in ((self.prefill_ns_per_token, "prefill_ns_per_token"),
                        (self.decode_ns_per_token, "decode_ns_per_token"),
                        (self.decode_overhead_ns, "decode_overhead_ns"),
                        (self.kv_bytes_per_token, "kv_bytes_per_token")):
            if v is not None and v < 1:
                raise ValueError(f"{what} must be >= 1 or None")
        if self.prefill_overhead_ns < 0:
            raise ValueError("prefill_overhead_ns must be >= 0")
        if self.hw_tflops <= 0 or self.hw_hbm_gbps <= 0:
            raise ValueError("hardware throughput figures must be positive")
        for v, what in ((self.request_frame_bytes, "request_frame_bytes"),
                        (self.token_frame_bytes, "token_frame_bytes"),
                        (self.kv_segment_bytes, "kv_segment_bytes")):
            if v < MIN_SERVING_FRAME:
                raise ValueError(
                    f"{what}={v} below MIN_SERVING_FRAME={MIN_SERVING_FRAME} "
                    "(serving frames carry an application header)")
        if self.request_tokens_per_frame < 1:
            raise ValueError("request_tokens_per_frame must be >= 1")
        if self.fail_node and self.fail_node not in self.decode:
            raise ValueError(
                f"fail_node {self.fail_node!r} is not a decode node "
                "(failover currently models decode-replica loss)")
        if self.fail_at_s < 0:
            raise ValueError("fail_at_s must be >= 0")

    # -- model-derived defaults ------------------------------------------------
    def model_config(self):
        return get_config(self.mix.model)

    def resolved_prefill_ns_per_token(self) -> int:
        if self.prefill_ns_per_token is not None:
            return self.prefill_ns_per_token
        flops = 2.0 * self.model_config().active_param_count()
        return max(1, int(round(flops / (self.hw_tflops * 1e3))))

    def resolved_decode_ns_per_token(self) -> int:
        if self.decode_ns_per_token is not None:
            return self.decode_ns_per_token
        return self.resolved_prefill_ns_per_token()

    def resolved_decode_overhead_ns(self) -> int:
        if self.decode_overhead_ns is not None:
            return self.decode_overhead_ns
        weight_bytes = 2.0 * self.model_config().active_param_count()
        return max(1, int(round(weight_bytes / self.hw_hbm_gbps)))

    def resolved_kv_bytes_per_token(self) -> int:
        if self.kv_bytes_per_token is not None:
            return self.kv_bytes_per_token
        m = self.model_config()
        return 2 * m.n_layers * m.kv_dim * 2  # K+V, bf16

    def request_frames(self, prompt_tokens: int) -> int:
        """How many request frames carry a prompt of this many tokens."""
        return max(1, math.ceil(prompt_tokens / self.request_tokens_per_frame))

    def kv_segments(self, prompt_tokens: int) -> int:
        """KV-transfer elephant-flow length (frames) for one request."""
        kv_bytes = prompt_tokens * self.resolved_kv_bytes_per_token()
        return max(1, math.ceil(kv_bytes / self.kv_segment_bytes))

    def fail_at_ns(self) -> Optional[int]:
        return int(self.fail_at_s * 1e9) if self.fail_node else None

    # -- round-trip ------------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return _to_dict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ServingConfig":
        d = dict(d)
        d["mix"] = RequestMixConfig.from_dict(d.get("mix", {}))
        d["prefill"] = tuple(d.get("prefill", ()))
        d["decode"] = tuple(d.get("decode", ()))
        if d.get("prefill_weights") is not None:
            d["prefill_weights"] = tuple(d["prefill_weights"])
        return cls(**d)

    def with_mix(self, **kw: Any) -> "ServingConfig":
        return replace(self, mix=replace(self.mix, **kw))
