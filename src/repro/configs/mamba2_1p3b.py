"""mamba2-1.3b [ssm] 48L d2048 attn-free v50280, ssm_state=128, SSD [arXiv:2405.21060] — exact assigned config + reduced smoke config."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    parallel_layout='fsdp',
    remat_policy='save_dots',
    arch_id='mamba2-1.3b',
    family='ssm',
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    conv_width=4,
    head_dim=1,
    tie_embeddings=True,)

SMOKE_CONFIG = ModelConfig(
    arch_id='mamba2-1.3b',
    family='ssm',
    n_layers=4,
    d_model=64,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=512,
    ssm_state=16,
    ssm_head_dim=16,
    ssm_expand=2,
    ssm_chunk=8,
    conv_width=4,
    head_dim=1,
    tie_embeddings=True,)
