"""granite-8b [dense] 36L d4096 32H GQA-8 ff14336 v49152 (llama-arch, code) [arXiv:2405.04324] — exact assigned config + reduced smoke config."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    parallel_layout='fsdp',
    arch_id='granite-8b',
    family='dense',
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=49152,
    rope_theta=10000.0,)

SMOKE_CONFIG = ModelConfig(
    arch_id='granite-8b',
    family='dense',
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,)
