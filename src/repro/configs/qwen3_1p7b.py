"""qwen3-1.7b [dense] 28L d2048 16H GQA-8 ff6144 v151936 (qk_norm) [hf:Qwen/Qwen3-8B] — exact assigned config + reduced smoke config."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    parallel_layout='fsdp',
    arch_id='qwen3-1.7b',
    family='dense',
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=6144,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1000000.0,
    tie_embeddings=True,)

SMOKE_CONFIG = ModelConfig(
    arch_id='qwen3-1.7b',
    family='dense',
    qk_norm=True,
    tie_embeddings=True,
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,)
