"""hubert-xlarge [audio] 48L d1280 16H MHA ff5120 v504 (encoder-only, w2v2 family) [arXiv:2106.07447] — exact assigned config + reduced smoke config."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    parallel_layout='fsdp',
    remat_policy='save_dots',
    arch_id='hubert-xlarge',
    family='encoder',
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    causal=False,
    act='gelu',
    norm='layernorm',
    frontend='audio_frames',
    rope_theta=10000.0,)

SMOKE_CONFIG = ModelConfig(
    arch_id='hubert-xlarge',
    family='encoder',
    causal=False,
    act='gelu',
    norm='layernorm',
    frontend='audio_frames',
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=64,)
