"""recurrentgemma-9b [hybrid] 38L d4096 16H MQA ff12288 v256000, RG-LRU + local attn 1:2 [arXiv:2402.19427] — exact assigned config + reduced smoke config."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    parallel_layout='fsdp',
    arch_id='recurrentgemma-9b',
    family='hybrid',
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    attention_kind='local',
    window=2048,
    block_pattern=('rglru', 'rglru', 'attn'),
    rope_theta=10000.0,
    tie_embeddings=True,)

SMOKE_CONFIG = ModelConfig(
    arch_id='recurrentgemma-9b',
    family='hybrid',
    attention_kind='local',
    window=16,
    block_pattern=('rglru', 'rglru', 'attn'),
    tie_embeddings=True,
    n_layers=5,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_ff=128,
    vocab_size=512,)
