"""llama3.2-3b [dense] 28L d3072 24H GQA-8 ff8192 v128256 [hf:meta-llama/Llama-3.2-1B] — exact assigned config + reduced smoke config."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    parallel_layout='fsdp',
    arch_id='llama3.2-3b',
    family='dense',
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=500000.0,
    tie_embeddings=True,)

SMOKE_CONFIG = ModelConfig(
    arch_id='llama3.2-3b',
    family='dense',
    tie_embeddings=True,
    n_layers=4,
    d_model=60,
    n_heads=6,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,)
