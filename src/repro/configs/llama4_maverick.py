"""llama4-maverick-400b-a17b [moe] 48L d5120 40H GQA-8 ff8192 v202048, 128e top-1 every-2nd layer + shared expert [hf:meta-llama/Llama-4-*] — exact assigned config + reduced smoke config."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id='llama4-maverick-400b-a17b',
    family='moe',
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    n_experts=128,
    experts_per_token=1,
    moe_every=2,
    n_shared_experts=1,
    rope_theta=500000.0,)

SMOKE_CONFIG = ModelConfig(
    arch_id='llama4-maverick-400b-a17b',
    family='moe',
    n_experts=8,
    experts_per_token=1,
    moe_every=2,
    n_shared_experts=1,
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,)
