"""phi4-mini-3.8b [dense] 32L d3072 24H GQA-8 ff8192 v200064 (RoPE SwiGLU GQA) [arXiv:2412.08905] — exact assigned config + reduced smoke config."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    parallel_layout='fsdp',
    arch_id='phi4-mini-3.8b',
    family='dense',
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=200064,
    rope_theta=10000.0,
    tie_embeddings=True,)

SMOKE_CONFIG = ModelConfig(
    arch_id='phi4-mini-3.8b',
    family='dense',
    tie_embeddings=True,
    n_layers=4,
    d_model=60,
    n_heads=6,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,)
