"""mixtral-8x7b [moe] 32L d4096 32H GQA-8 ff14336 v32000, 8e top-2, SWA-4096 [arXiv:2401.04088] — exact assigned config + reduced smoke config."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id='mixtral-8x7b',
    family='moe',
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    n_experts=8,
    experts_per_token=2,
    moe_every=1,
    attention_kind='sliding',
    window=4096,
    rope_theta=1000000.0,)

SMOKE_CONFIG = ModelConfig(
    arch_id='mixtral-8x7b',
    family='moe',
    n_experts=4,
    experts_per_token=2,
    moe_every=1,
    attention_kind='sliding',
    window=32,
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,)
