"""internvl2-26b [vlm] 48L d6144 48H GQA-8 ff16384 v92553 (InternViT stub + InternLM2) [arXiv:2404.16821] — exact assigned config + reduced smoke config."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    parallel_layout='fsdp',
    arch_id='internvl2-26b',
    family='vlm',
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    frontend='vision_patches',
    n_patches=256,
    rope_theta=1000000.0,)

SMOKE_CONFIG = ModelConfig(
    arch_id='internvl2-26b',
    family='vlm',
    frontend='vision_patches',
    n_patches=16,
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,)
