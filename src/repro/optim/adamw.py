"""AdamW with fp32 master weights, global-norm clipping, schedules, and an
optional int8 gradient-compression hook for cross-pod reduction.

Functional, pytree-based (no optax dependency): states mirror the param tree,
so the same PartitionSpecs shard params, master, m and v — ZeRO-3 style.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1
    master_fp32: bool = True


class OptState(NamedTuple):
    step: jnp.ndarray      # () i32
    master: Params         # fp32 master copy (or () when disabled)
    m: Params
    v: Params


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup → cosine decay to min_lr_frac·lr."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(1.0, cfg.warmup_steps)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(1.0, cfg.decay_steps - cfg.warmup_steps), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def init(cfg: AdamWConfig, params: Params) -> OptState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    # jnp.array(copy=True): master must never alias params (both get donated)
    master = (jax.tree_util.tree_map(
        lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params)
        if cfg.master_fp32 else ())
    return OptState(step=jnp.zeros((), jnp.int32), master=master,
                    m=zeros, v=jax.tree_util.tree_map(jnp.copy, zeros))


def global_norm(tree: Params) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Params, max_norm: float
                        ) -> Tuple[Params, jnp.ndarray]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    # preserve gradient dtype: casting to f32 here would double the bytes of
    # any cross-device grad reduction scheduled after the clip
    return jax.tree_util.tree_map(
        lambda g: (g * scale.astype(g.dtype)), grads), gnorm


# -- optional gradient compression (cross-pod reduction trick) -----------------

def compress_int8(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor symmetric int8 quantization: (q, scale)."""
    amax = jnp.max(jnp.abs(g)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def apply_updates(cfg: AdamWConfig, params: Params, grads: Params,
                  state: OptState) -> Tuple[Params, OptState, Dict[str, Any]]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    new_m = jax.tree_util.tree_map(
        lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
        state.m, grads)
    new_v = jax.tree_util.tree_map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        state.v, grads)

    def upd(p_master, m, v):
        mh = m / bc1
        vh = v / bc2
        return p_master - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                                + cfg.weight_decay * p_master)

    if cfg.master_fp32:
        new_master = jax.tree_util.tree_map(upd, state.master, new_m, new_v)
        new_params = jax.tree_util.tree_map(
            lambda mp, p: mp.astype(p.dtype), new_master, params)
    else:
        new_master = ()
        new_params = jax.tree_util.tree_map(
            lambda p, m, v: upd(p.astype(jnp.float32), m, v).astype(p.dtype),
            params, new_m, new_v)

    new_state = OptState(step=step, master=new_master, m=new_m, v=new_v)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
