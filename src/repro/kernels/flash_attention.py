"""Pallas TPU flash attention (causal / sliding-window / bidirectional, GQA).

Design (TPU-native, not a CUDA port):

* grid = (B·H, n_q_blocks, n_kv_blocks); the kv dimension is ``arbitrary``
  (sequential) so the online-softmax state lives in VMEM scratch across kv
  steps — the TPU analogue of a warp-persistent accumulator.
* BlockSpecs tile Q/K/V into VMEM: (1, blk_q, Dh) and (1, blk_k, Dh) blocks,
  MXU-aligned (blk ≥ 128, Dh is the lane dim).
* causal/window skip happens at the BLOCK level with ``pl.when`` — blocks
  entirely outside the mask are never computed (the flop skip the chunked-jnp
  fallback cannot express).
* GQA: the kv index map folds the query head onto its kv group
  (h → h // group), so KV blocks are fetched once per group — no host-side
  repeat.

Validated against kernels.ref.mha in interpret mode (tests/test_kernels.py).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, blk_q: int, blk_k: int, causal: bool,
                  window: int, q_offset: int, n_kv_blocks: int, s_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * blk_q + q_offset
    k_start = ki * blk_k

    # block-level mask reasoning (static per grid point only via pl.when on
    # traced predicates — Pallas evaluates the body under the predicate)
    block_needed = True
    if causal:
        # kv block strictly after the last query position → skip
        block_needed = k_start <= q_start + blk_q - 1
    if window > 0:
        block_needed = jnp.logical_and(
            block_needed, k_start + blk_k - 1 > q_start - window)

    @pl.when(block_needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (blk_q, Dh)
        k = k_ref[0].astype(jnp.float32)          # (blk_k, Dh)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
        mask = kpos < s_kv  # mask KV padding (matters for bidirectional)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                       # (blk_q,)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows → zero output
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,  # (B, Sq, H, Dh)
    k: jnp.ndarray,  # (B, Skv, Hkv, Dh)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    softmax_scale: Optional[float] = None,
    blk_q: int = 128,
    blk_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Sq, H, Dh = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    group = H // Hkv
    scale = softmax_scale if softmax_scale is not None else Dh ** -0.5
    blk_q = min(blk_q, Sq)
    blk_k = min(blk_k, Skv)

    pad_q = (-Sq) % blk_q
    pad_k = (-Skv) % blk_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        # padded kv is masked off via kpos >= Skv below through the causal /
        # window mask; for bidirectional (non-causal) we add an explicit mask
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    Sq_p, Skv_p = q.shape[1], k.shape[1]
    nq, nk = Sq_p // blk_q, Skv_p // blk_k

    # layout: (B*H, S, Dh) with heads folded into the leading grid dim
    qr = q.transpose(0, 2, 1, 3).reshape(B * H, Sq_p, Dh)
    kr = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv_p, Dh)
    vr = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv_p, Dh)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        b, h = bh // H, bh % H
        return (b * Hkv + h // group, ki, 0)

    kernel = functools.partial(
        _flash_kernel, scale=scale, blk_q=blk_q, blk_k=blk_k,
        causal=causal, window=window, q_offset=q_offset, n_kv_blocks=nk,
        s_kv=Skv)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, blk_q, Dh), q_map),
            pl.BlockSpec((1, blk_k, Dh), kv_map),
            pl.BlockSpec((1, blk_k, Dh), kv_map),
        ],
        out_specs=pl.BlockSpec((1, blk_q, Dh), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq_p, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q,), jnp.float32),       # running max m
            pltpu.VMEM((blk_q,), jnp.float32),       # running denom l
            pltpu.VMEM((blk_q, Dh), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(qr, kr, vr)
    out = out.reshape(B, H, Sq_p, Dh).transpose(0, 2, 1, 3)
    return out[:, :Sq]
