"""Public jit'd kernel wrappers.

Every op has up to three interchangeable implementations:

* ``ref``      — naive oracle (ref.py), small shapes, ground truth;
* ``chunked``  — production pure-jnp path: memory-bounded, scan-based; this is
                 what the CPU dry-run lowers (and what XLA:TPU would run if
                 Pallas were disabled);
* ``pallas``   — the TPU kernel (explicit BlockSpec VMEM tiling); validated in
                 interpret mode against ``ref`` in tests.

Dispatch: ``impl="auto"`` picks pallas on TPU backends, chunked elsewhere.
"""
from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref as _ref


def _auto_impl() -> str:
    if os.environ.get("REPRO_FORCE_IMPL"):
        return os.environ["REPRO_FORCE_IMPL"]
    return "pallas" if jax.default_backend() == "tpu" else "chunked"


# =============================================================================
# Flash attention (train/prefill)
# =============================================================================

def _chunked_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
    causal: bool, window: int, q_offset: int, scale: float, q_chunk: int,
) -> jnp.ndarray:
    """Memory-bounded attention: scan over query chunks.

    Full/causal: each chunk attends to the whole KV with a mask (the causal
    flop-skip lives in the Pallas kernel / pair-scheduled variant).
    Sliding window: each chunk attends only to its (window + chunk) KV slice —
    exact O(S·W) flops, which is what makes 32k/500k SWA prefill lowerable.
    """
    B, Sq, H, Dh = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    group = H // Hkv
    qc = min(q_chunk, Sq)
    pad = (-Sq) % qc
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = q.shape[1] // qc
    qs = q.transpose(1, 0, 2, 3).reshape(nq, qc, B, H, Dh)
    # GQA via KV broadcast to H query heads: keeps the head axis evenly
    # sharded under TP (a (Hkv, group) reshape makes GSPMD re-lay-out the
    # uneven factor with all-to-alls)
    if group > 1:
        k = jnp.repeat(k, group, axis=2)
        v = jnp.repeat(v, group, axis=2)

    use_window_slice = window > 0 and Skv > window + qc
    kv_span = min(Skv, window + qc) if use_window_slice else Skv

    # MXU-style numerics: bf16 inputs with f32 accumulation when the model
    # runs bf16 (halves attention dot traffic); full f32 for f32 inputs so
    # oracle comparisons stay exact
    dot_dt = q.dtype if q.dtype == jnp.bfloat16 else jnp.float32

    def body(_, inp):
        i, q_c = inp  # q_c: (qc, B, H, Dh)
        qpos = q_offset + i * qc + jnp.arange(qc)
        if use_window_slice:
            start = jnp.clip(q_offset + i * qc + qc - kv_span, 0, Skv - kv_span)
            k_c = jax.lax.dynamic_slice_in_dim(k, start, kv_span, axis=1)
            v_c = jax.lax.dynamic_slice_in_dim(v, start, kv_span, axis=1)
            kpos = start + jnp.arange(kv_span)
        else:
            k_c, v_c, kpos = k, v, jnp.arange(Skv)
        s = jnp.einsum("qbhd,bkhd->bhqk", q_c.astype(dot_dt),
                       k_c.astype(dot_dt),
                       preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((qc, kv_span), dtype=bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window > 0:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bkhd->qbhd", p.astype(dot_dt),
                       v_c.astype(dot_dt),
                       preferred_element_type=jnp.float32)
        return None, o.astype(q.dtype)

    # flash-attention backward semantics: recompute scores per chunk instead
    # of saving softmax activations (O(S^2) f32) for the bwd pass
    body = jax.checkpoint(body)
    _, outs = jax.lax.scan(body, None, (jnp.arange(nq), qs))
    out = outs.reshape(nq * qc, B, H, Dh).transpose(1, 0, 2, 3)
    return out[:, :Sq]


def _paired_causal_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
    scale: float, chunk: int,
) -> jnp.ndarray:
    """Exact-flops causal attention: only valid (q-block, kv-block) pairs.

    The plain chunked path computes the full S×S rectangle and masks half of
    it away — 2× wasted attention flops in the lowered HLO (EXPERIMENTS.md
    §Perf iter 6).  Here the scan runs over the static list of causal block
    pairs (i, j≤i), carrying flash-style online-softmax state per q-block;
    flops are S²/2·(1+1/n) exact.  Pads S to a chunk multiple; GQA KV is
    broadcast to query heads (even TP sharding).
    """
    B, S, H, Dh = q.shape
    Hkv = k.shape[2]
    group = H // Hkv
    qc = min(chunk, S)
    pad = (-S) % qc
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    if group > 1:
        k = jnp.repeat(k, group, axis=2)
        v = jnp.repeat(v, group, axis=2)
    Sp = q.shape[1]
    n = Sp // qc
    qT = q.transpose(1, 0, 2, 3)  # (S, B, H, Dh) — row-sliceable
    kT = k.transpose(1, 0, 2, 3)
    vT = v.transpose(1, 0, 2, 3)
    dot_dt = q.dtype if q.dtype == jnp.bfloat16 else jnp.float32

    # static causal pair schedule, grouped by q-block (j ascending within i)
    import numpy as _np
    pairs = [(i, j) for i in range(n) for j in range(i + 1)]
    i_arr = jnp.asarray(_np.array([p[0] for p in pairs], _np.int32))
    j_arr = jnp.asarray(_np.array([p[1] for p in pairs], _np.int32))

    def body(carry, ij):
        i, j = ij
        acc, m, l, out = carry
        fresh = j == 0  # first kv block of a new q block: reset the state
        acc = jnp.where(fresh, 0.0, acc)
        m = jnp.where(fresh, NEG_INF_PAIRED, m)
        l = jnp.where(fresh, 0.0, l)
        q_c = jax.lax.dynamic_slice_in_dim(qT, i * qc, qc, axis=0)
        k_c = jax.lax.dynamic_slice_in_dim(kT, j * qc, qc, axis=0)
        v_c = jax.lax.dynamic_slice_in_dim(vT, j * qc, qc, axis=0)
        s = jnp.einsum("qbhd,kbhd->bhqk", q_c.astype(dot_dt),
                       k_c.astype(dot_dt),
                       preferred_element_type=jnp.float32) * scale
        # mask matters only on the diagonal block (i == j)
        qpos = i * qc + jnp.arange(qc)
        kpos = j * qc + jnp.arange(qc)
        s = jnp.where(kpos[None, :] <= qpos[:, None], s, NEG_INF_PAIRED)
        m_new = jnp.maximum(m, s.max(axis=-1))           # (B, H, qc)
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bhqk,kbhd->qbhd", p.astype(dot_dt),
                        v_c.astype(dot_dt),
                        preferred_element_type=jnp.float32)
        acc = acc * alpha.transpose(2, 0, 1)[..., None] + pv
        # publish the (so-far-normalized) rows; the last j for each i wins
        l_safe = jnp.where(l == 0.0, 1.0, l)
        norm = (acc / l_safe.transpose(2, 0, 1)[..., None]).astype(out.dtype)
        out = jax.lax.dynamic_update_slice_in_dim(out, norm, i * qc, axis=0)
        return (acc, m_new, l, out), None

    init = (
        jnp.zeros((qc, B, H, Dh), jnp.float32),
        jnp.full((B, H, qc), NEG_INF_PAIRED, jnp.float32),
        jnp.zeros((B, H, qc), jnp.float32),
        jnp.zeros((Sp, B, H, Dh), q.dtype),
    )
    body = jax.checkpoint(body)  # flash bwd semantics: recompute per pair
    (_, _, _, out), _ = jax.lax.scan(body, init, (i_arr, j_arr))
    return out.transpose(1, 0, 2, 3)[:, :S]


NEG_INF_PAIRED = -1e30


def flash_attention(
    q: jnp.ndarray,  # (B, Sq, H, Dh)
    k: jnp.ndarray,  # (B, Skv, Hkv, Dh)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    softmax_scale: Optional[float] = None,
    q_chunk: int = 256,
    impl: str = "auto",
    interpret: bool = False,
) -> jnp.ndarray:
    scale = softmax_scale if softmax_scale is not None else q.shape[-1] ** -0.5
    impl = _auto_impl() if impl == "auto" else impl
    if impl == "ref":
        return _ref.mha(q, k, v, causal=causal, window=window, q_offset=q_offset,
                        softmax_scale=scale)
    if impl == "pallas":
        from .flash_attention import flash_attention_pallas
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      q_offset=q_offset, softmax_scale=scale,
                                      interpret=interpret)
    if (impl in ("chunked", "paired") and causal and window == 0
            and q_offset == 0 and q.shape[1] == k.shape[1] and q.shape[1] > 1
            and os.environ.get("REPRO_NO_PAIRED") != "1"):
        # causal full attention: exact-flops pair schedule (no masked waste)
        return _paired_causal_attention(q, k, v, scale=scale, chunk=q_chunk)
    return _chunked_attention(q, k, v, causal=causal, window=window,
                              q_offset=q_offset, scale=scale, q_chunk=q_chunk)


# =============================================================================
# Decode attention (single new token against a KV cache)
# =============================================================================

def decode_attention(
    q: jnp.ndarray,          # (B, H, Dh)
    k_cache: jnp.ndarray,    # (B, S, Hkv, Dh)
    v_cache: jnp.ndarray,
    cache_len: jnp.ndarray,  # (B,) valid entries (ring caches: min(pos+1, W))
    *,
    softmax_scale: Optional[float] = None,
    impl: str = "auto",
    interpret: bool = False,
) -> jnp.ndarray:
    scale = softmax_scale if softmax_scale is not None else q.shape[-1] ** -0.5
    impl = _auto_impl() if impl == "auto" else impl
    if impl == "pallas":
        from .decode_attention import decode_attention_pallas
        return decode_attention_pallas(q, k_cache, v_cache, cache_len,
                                       softmax_scale=scale, interpret=interpret)
    # chunked == ref math here (scores are (B,H,S): already memory-linear)
    return _ref.decode_attention(q, k_cache, v_cache, cache_len,
                                 softmax_scale=scale)


# =============================================================================
# RG-LRU scan (recurrentgemma)
# =============================================================================

def rglru_scan(
    x: jnp.ndarray,      # (B, S, W)
    a_log: jnp.ndarray,  # (B, S, W) log-decay (<= 0)
    *,
    h0: Optional[jnp.ndarray] = None,   # (B, W) initial state
    impl: str = "auto",
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (hidden states (B,S,W), final state (B,W))."""
    impl = _auto_impl() if impl == "auto" else impl
    if impl == "pallas":
        from .rglru_scan import rglru_scan_pallas
        return rglru_scan_pallas(x, a_log, h0=h0, interpret=interpret)
    if impl == "ref":
        hs = _ref.rglru(x, a_log)
        if h0 is not None:
            raise NotImplementedError("ref path has no h0")
        return hs, hs[:, -1]
    # production jnp: log-depth associative scan over (a, b) pairs
    a = jnp.exp(a_log.astype(jnp.float32))
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * x.astype(jnp.float32)
    if h0 is not None:
        # fold the carried-in state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    aa, bb = jax.lax.associative_scan(combine, (a, b), axis=1)
    hs = bb.astype(x.dtype)
    return hs, hs[:, -1]


def rglru_decode_step(
    x_t: jnp.ndarray, a_log_t: jnp.ndarray, h: jnp.ndarray
) -> jnp.ndarray:
    """One-token RG-LRU update: (B, W) state in/out."""
    a = jnp.exp(a_log_t.astype(jnp.float32))
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * x_t.astype(jnp.float32)
    return (a * h.astype(jnp.float32) + b).astype(h.dtype)


# =============================================================================
# Mamba-2 SSD (chunked state-space duality)
# =============================================================================

def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """(..., Q) -> (..., Q, Q) lower-triangular pairwise cumulative sums."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]  # sum over (j, i]
    mask = jnp.tril(jnp.ones((Q, Q), dtype=bool), 0)
    return jnp.where(mask, seg, -jnp.inf)


def ssd_scan(
    x: jnp.ndarray,     # (B, S, H, P)
    dt: jnp.ndarray,    # (B, S, H) positive
    A: jnp.ndarray,     # (H,) negative
    Bmat: jnp.ndarray,  # (B, S, N)
    Cmat: jnp.ndarray,  # (B, S, N)
    *,
    chunk: int = 128,
    h0: Optional[jnp.ndarray] = None,  # (B, H, P, N)
    impl: str = "auto",
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD: intra-chunk quadratic attention-duality + inter-chunk
    recurrence. Returns (y (B,S,H,P), final state (B,H,P,N))."""
    impl = _auto_impl() if impl == "auto" else impl
    if impl == "pallas":
        from .ssd_scan import ssd_scan_pallas
        return ssd_scan_pallas(x, dt, A, Bmat, Cmat, chunk=chunk, h0=h0,
                               interpret=interpret)
    if impl == "ref":
        y = _ref.ssd(x, dt, A, Bmat, Cmat)
        return y, jnp.zeros((x.shape[0], x.shape[2], x.shape[3], Bmat.shape[-1]),
                            jnp.float32)

    B_, S, H, P = x.shape
    N = Bmat.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        # zero-pad the tail: dt=0 rows leave the state untouched (decay=1,
        # update=0) and their outputs are sliced away below
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
    S_pad = S + pad
    nc = S_pad // Q
    xf = x.astype(jnp.float32).reshape(B_, nc, Q, H, P)
    dtf = dt.astype(jnp.float32).reshape(B_, nc, Q, H)
    Bf = Bmat.astype(jnp.float32).reshape(B_, nc, Q, N)
    Cf = Cmat.astype(jnp.float32).reshape(B_, nc, Q, N)
    Af = A.astype(jnp.float32)

    # per-step log decay within chunks: (B, nc, Q, H)
    dA = dtf * Af[None, None, None, :]
    xdt = xf * dtf[..., None]  # dt-weighted inputs

    # bf16 dot inputs (f32 accumulate) when the model runs bf16 — the decay
    # accumulation (cumsum/exp) stays f32 for stability
    dot_dt = x.dtype if x.dtype == jnp.bfloat16 else jnp.float32

    # ---- intra-chunk (quadratic, attention-like duality) --------------------
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))  # (B, nc, H, Q, Q)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cf.astype(dot_dt),
                        Bf.astype(dot_dt),
                        preferred_element_type=jnp.float32)  # (B, nc, Q, Q)
    # scores (q,k) * per-head decay L (q,k), applied to dt-weighted input at k
    w_qk = (L * scores[:, :, None]).astype(dot_dt)  # (B, nc, H, Q, Q)
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", w_qk, xdt.astype(dot_dt),
                         preferred_element_type=jnp.float32)

    # ---- chunk summary states ----------------------------------------------
    dA_cum = jnp.cumsum(dA, axis=2)                      # (B, nc, Q, H)
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)  # (B, nc, Q, H)
    xdt_w = (xdt * decay_to_end[..., None]).astype(dot_dt)
    S_chunk = jnp.einsum("bcqn,bcqhp->bchpn", Bf.astype(dot_dt), xdt_w,
                         preferred_element_type=jnp.float32)

    # ---- inter-chunk recurrence (scan over nc chunks) ------------------------
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])  # (B, nc, H) total decay per chunk

    def step(h, inp):
        s_c, d_c = inp  # (B,H,P,N), (B,H)
        h_next = d_c[..., None, None] * h + s_c
        return h_next, h  # emit state *entering* the chunk

    s_sw = jnp.moveaxis(S_chunk, 1, 0)
    d_sw = jnp.moveaxis(chunk_decay, 1, 0)
    h_init = (h0.astype(jnp.float32) if h0 is not None
              else jnp.zeros((B_, H, P, N), jnp.float32))
    h_final, h_enter = jax.lax.scan(step, h_init, (s_sw, d_sw))
    h_enter = jnp.moveaxis(h_enter, 0, 1)  # (B, nc, H, P, N)

    # ---- inter-chunk contribution ------------------------------------------
    decay_from_start = jnp.exp(dA_cum)  # (B, nc, Q, H)
    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", Cf, decay_from_start, h_enter)

    y = (y_intra + y_inter).reshape(B_, S_pad, H, P)[:, :S].astype(x.dtype)
    return y, h_final


def ssd_decode_step(
    x_t: jnp.ndarray,   # (B, H, P)
    dt_t: jnp.ndarray,  # (B, H)
    A: jnp.ndarray,     # (H,)
    B_t: jnp.ndarray,   # (B, N)
    C_t: jnp.ndarray,   # (B, N)
    h: jnp.ndarray,     # (B, H, P, N) f32
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One-token SSD update. Returns (y (B,H,P), new state)."""
    decay = jnp.exp(A.astype(jnp.float32)[None] * dt_t.astype(jnp.float32))
    update = (dt_t[..., None, None] * x_t.astype(jnp.float32)[..., None]
              ) * B_t.astype(jnp.float32)[:, None, None, :]
    h_new = decay[..., None, None] * h + update
    y = jnp.einsum("bhpn,bn->bhp", h_new, C_t.astype(jnp.float32))
    return y.astype(x_t.dtype), h_new


# =============================================================================
# Burst gather (packet arena -> contiguous batch; the DMA/DCA device path)
# =============================================================================

def burst_gather(
    arena: jnp.ndarray,    # (n_slots, slot_size) uint8
    slots: jnp.ndarray,    # (n,) int32
    lengths: jnp.ndarray,  # (n,) int32
    out_width: int,
    *,
    impl: str = "auto",
    interpret: bool = False,
) -> jnp.ndarray:
    impl = _auto_impl() if impl == "auto" else impl
    if impl == "pallas":
        from .burst_gather import burst_gather_pallas
        return burst_gather_pallas(arena, slots, lengths, out_width,
                                   interpret=interpret)
    return _ref.burst_gather(arena, slots, lengths, out_width)
