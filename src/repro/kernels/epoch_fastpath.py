"""Epoch fast-path array kernels: the pure-array inner pass of the
epoch-batched simulation engine (:mod:`repro.core.fastpath`).

One epoch slice of the analytic emission schedule is advanced as whole-array
passes instead of per-event Python rounds:

* **emission → arrival**: the FIFO wire recursion
  ``end_i = max(end_{i-1}, t_i) + ser_i`` is a max-plus scan.  With
  ``S_i = cumsum(ser)_i`` it closes to
  ``end_i = max(busy0, cummax_j<=i(t_j - S_{j-1})) + S_i`` — one cumsum and
  one cummax, bit-identical to :meth:`repro.core.simclock.Wire.transmit`
  called per frame (serialization uses the same ``round(bytes*8/gbps)``
  half-to-even float64 arithmetic);
* **steer**: the per-frame RSS queue is a gather through a precomputed
  per-flow-id queue table (the Toeplitz hash + indirection lookup of
  :meth:`repro.core.rss.RssIndirection.steer` hoisted out of the per-packet
  path — the loadgen's synthetic flow ids cycle mod ``n_flows``);
* **charge**: per-burst lcore busy-time ``(poll + n*per_packet)/ghz`` as a
  vectorized cost table, consumed by the harvest cascade.

The numpy implementation is the portable reference and the default.  The JAX
variant jit-compiles the same integer scan; it is only *used* when 64-bit
mode is available (``jax_enable_x64``), because the engine's contract is
bit-identical timestamps and int32 would overflow ns arithmetic.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

__all__ = [
    "serialization_ns_vec",
    "wire_arrival_pass_np",
    "epoch_pass_np",
    "pmd_burst_cost_table",
    "get_epoch_pass_jax",
]


def serialization_ns_vec(lengths: np.ndarray, gbps: float) -> np.ndarray:
    """Per-frame serialization delay, matching ``Wire.serialization_ns``
    element-for-element (``int(round(bytes*8/gbps))``, half-to-even)."""
    if gbps <= 0.0:
        return np.zeros(len(lengths), dtype=np.int64)
    return np.round(np.asarray(lengths, dtype=np.float64) * 8.0
                    / gbps).astype(np.int64)


def wire_arrival_pass_np(
    handed_ns: np.ndarray, ser_ns: np.ndarray, busy0_ns: int, latency_ns: int,
) -> Tuple[np.ndarray, int]:
    """Arrival times of frames handed one-at-a-time to a FIFO wire.

    ``handed_ns`` must be non-decreasing (the emission schedule is).  Returns
    ``(arrivals, busy_until)`` — exactly what N sequential
    ``Wire.transmit(t_i, size_i)`` calls would produce.
    """
    n = len(handed_ns)
    if n == 0:
        return np.empty(0, dtype=np.int64), int(busy0_ns)
    handed = np.asarray(handed_ns, dtype=np.int64)
    ser = np.asarray(ser_ns, dtype=np.int64)
    cum = np.cumsum(ser)
    # end_i = max(busy0, max_{j<=i}(t_j - S_{j-1})) + S_i ; S_{-1} = 0
    pre = handed - (cum - ser)
    m = np.maximum(np.maximum.accumulate(pre), np.int64(busy0_ns))
    ends = m + cum
    return ends + np.int64(latency_ns), int(ends[-1])


def epoch_pass_np(
    handed_ns: np.ndarray,
    ser_ns: np.ndarray,
    busy0_ns: int,
    latency_ns: int,
    flow_queue_table: Optional[np.ndarray],
    flow_ids: Optional[np.ndarray],
) -> Tuple[np.ndarray, int, Optional[np.ndarray]]:
    """One epoch slice: wire arrivals + RSS steering in one pass.

    Returns ``(arrival_ns, busy_until, queue_idx)``; ``queue_idx`` is None
    for single-queue ports (no steering).
    """
    arrivals, busy = wire_arrival_pass_np(handed_ns, ser_ns, busy0_ns,
                                          latency_ns)
    queues = None
    if flow_queue_table is not None and flow_ids is not None:
        queues = flow_queue_table[flow_ids]
    return arrivals, busy, queues


def pmd_burst_cost_table(max_burst: int, poll_cycles: int,
                         per_packet_cycles: int, cpu_ghz: float) -> np.ndarray:
    """``cost[n] = pmd_burst_ns(n)`` for n in [0, max_burst] — the vectorized
    charge table the harvest cascade indexes per burst (float64, identical
    arithmetic to :meth:`repro.core.cost.HostCostModel.pmd_burst_ns`)."""
    n = np.arange(max_burst + 1, dtype=np.float64)
    table = (poll_cycles + n * per_packet_cycles) / cpu_ghz
    table[0] = 0.0
    return table


_JAX_PASS = None
_JAX_TRIED = False


def get_epoch_pass_jax():
    """The jit-compiled epoch pass, or None when JAX (with 64-bit integer
    mode) is unavailable.  Signature matches :func:`epoch_pass_np`.

    The serialization rounding stays in numpy (cheap, and Python/numpy
    half-to-even is the reference); the jitted part is the integer max-plus
    scan + steering gather — exact in int64, so results are bit-identical to
    the numpy pass and the engine can treat the two as interchangeable.
    """
    global _JAX_PASS, _JAX_TRIED
    if _JAX_TRIED:
        return _JAX_PASS
    _JAX_TRIED = True
    try:
        import jax
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        @jax.jit
        def _scan(handed, ser, busy0, latency):
            cum = jnp.cumsum(ser)
            pre = handed - (cum - ser)
            m = jnp.maximum(jax.lax.cummax(pre), busy0)
            ends = m + cum
            return ends + latency, ends[-1]

        @jax.jit
        def _gather(table, ids):
            return table[ids]

        def epoch_pass_jax(handed_ns, ser_ns, busy0_ns, latency_ns,
                           flow_queue_table, flow_ids):
            if len(handed_ns) == 0:
                return np.empty(0, dtype=np.int64), int(busy0_ns), None
            # 64-bit mode is scoped to this call: ns timestamps overflow
            # int32, and the engine's contract is bit-identical results
            with enable_x64():
                arr, busy = _scan(jnp.asarray(handed_ns, dtype=jnp.int64),
                                  jnp.asarray(ser_ns, dtype=jnp.int64),
                                  jnp.int64(busy0_ns), jnp.int64(latency_ns))
                queues = None
                if flow_queue_table is not None and flow_ids is not None:
                    queues = np.asarray(_gather(
                        jnp.asarray(flow_queue_table), jnp.asarray(flow_ids)))
                arr = np.asarray(arr)
                busy = int(busy)
            return arr, busy, queues

        # smoke-verify exactness against the reference once, on a case with
        # wire queueing; any divergence (e.g. x64 quietly off) disables JAX
        h = np.array([0, 5, 5, 40], dtype=np.int64)
        s = np.array([10, 10, 10, 10], dtype=np.int64)
        want, wb = wire_arrival_pass_np(h, s, 3, 7)
        got, gb, _ = epoch_pass_jax(h, s, 3, 7, None, None)
        if not (np.array_equal(want, got) and wb == gb):  # pragma: no cover
            return None
        _JAX_PASS = epoch_pass_jax
    except Exception:  # pragma: no cover - jax not installed / broken
        _JAX_PASS = None
    return _JAX_PASS
