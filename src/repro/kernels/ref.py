"""Pure-jnp oracles for every kernel in this package.

These are the ground truth the Pallas kernels (and the production chunked-jnp
paths in ops.py) are validated against in tests — naive, O(S^2)-materializing,
numerically straightforward.  Use small shapes only.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def attention_mask(
    s_q: int, s_kv: int, *, causal: bool, window: int = 0, q_offset: int = 0
) -> jnp.ndarray:
    """(s_q, s_kv) boolean mask. window>0 limits lookback (sliding/local)."""
    qpos = jnp.arange(s_q)[:, None] + q_offset
    kpos = jnp.arange(s_kv)[None, :]
    mask = jnp.ones((s_q, s_kv), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window and window > 0:
        mask &= kpos > qpos - window
    return mask


def mha(
    q: jnp.ndarray,  # (B, Sq, H, Dh)
    k: jnp.ndarray,  # (B, Skv, Hkv, Dh)
    v: jnp.ndarray,  # (B, Skv, Hkv, Dh)
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    softmax_scale: Optional[float] = None,
) -> jnp.ndarray:
    """Naive GQA attention oracle. Returns (B, Sq, H, Dh)."""
    B, Sq, H, Dh = q.shape
    Hkv = k.shape[2]
    group = H // Hkv
    scale = softmax_scale if softmax_scale is not None else Dh ** -0.5
    qg = q.reshape(B, Sq, Hkv, group, Dh)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    mask = attention_mask(Sq, k.shape[1], causal=causal, window=window,
                          q_offset=q_offset)
    scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, Dh).astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,        # (B, H, Dh) single query token
    k_cache: jnp.ndarray,  # (B, S, Hkv, Dh)
    v_cache: jnp.ndarray,  # (B, S, Hkv, Dh)
    cache_len: jnp.ndarray,  # (B,) valid prefix length (ring-ordered caches
                             # pass S and handle rotation outside)
    *,
    softmax_scale: Optional[float] = None,
) -> jnp.ndarray:
    """Single-token KV-cache attention oracle. Returns (B, H, Dh)."""
    B, H, Dh = q.shape
    Hkv = k_cache.shape[2]
    group = H // Hkv
    S = k_cache.shape[1]
    scale = softmax_scale if softmax_scale is not None else Dh ** -0.5
    qg = q.reshape(B, Hkv, group, Dh)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    valid = jnp.arange(S)[None] < cache_len[:, None]  # (B, S)
    scores = jnp.where(valid[:, None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, H, Dh).astype(q.dtype)


def rglru(
    x: jnp.ndarray,          # (B, S, W) gated input
    a_log: jnp.ndarray,      # (B, S, W) log of per-step decay in (0,1)
) -> jnp.ndarray:
    """RG-LRU linear recurrence oracle: h_t = a_t * h_{t-1} + sqrt(1-a_t^2) * x_t.

    a = exp(a_log) elementwise in (0, 1).  Sequential reference.
    """
    a = jnp.exp(a_log.astype(jnp.float32))
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * x.astype(jnp.float32)

    def step(h, inp):
        a_t, g_t = inp
        h = a_t * h + g_t
        return h, h

    a_sw = jnp.moveaxis(a, 1, 0)       # (S, B, W)
    g_sw = jnp.moveaxis(gated, 1, 0)
    h0 = jnp.zeros_like(g_sw[0])
    _, hs = jax.lax.scan(step, h0, (a_sw, g_sw))
    return jnp.moveaxis(hs, 0, 1).astype(x.dtype)


def ssd(
    x: jnp.ndarray,      # (B, S, H, P)   inputs per head
    dt: jnp.ndarray,     # (B, S, H)      softplus'd timestep > 0
    A: jnp.ndarray,      # (H,)           negative decay rate
    Bmat: jnp.ndarray,   # (B, S, N)      input projection (single group)
    Cmat: jnp.ndarray,   # (B, S, N)      output projection
) -> jnp.ndarray:
    """Mamba-2 SSD oracle (sequential state update). Returns (B, S, H, P).

    h_t = exp(A*dt_t) * h_{t-1} + dt_t * B_t ⊗ x_t ;  y_t = C_t · h_t
    State h has shape (B, H, P, N).
    """
    Bb, S, H, P = x.shape
    N = Bmat.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = Bmat.astype(jnp.float32)
    Cf = Cmat.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp  # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(Af[None] * dt_t)  # (B, H)
        update = (dt_t[..., None, None] * x_t[..., None]) * b_t[:, None, None, :]
        h = decay[..., None, None] * h + update  # (B,H,P,N)
        y = jnp.einsum("bhpn,bn->bhp", h, c_t)
        return h, y

    xs = jnp.moveaxis(xf, 1, 0)
    dts = jnp.moveaxis(dtf, 1, 0)
    bs = jnp.moveaxis(Bf, 1, 0)
    cs = jnp.moveaxis(Cf, 1, 0)
    h0 = jnp.zeros((Bb, H, P, N), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (xs, dts, bs, cs))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)


def burst_gather(
    arena: jnp.ndarray,   # (n_slots, slot_size) uint8 packet arena
    slots: jnp.ndarray,   # (n,) int32 descriptor slot indices
    lengths: jnp.ndarray, # (n,) int32 valid bytes per packet
    out_width: int,
) -> jnp.ndarray:
    """Descriptor-driven gather of a packet burst into a contiguous batch,
    zero-padded to out_width. Returns (n, out_width) uint8."""
    rows = arena[slots]  # (n, slot_size)
    rows = rows[:, :out_width] if rows.shape[1] >= out_width else jnp.pad(
        rows, ((0, 0), (0, out_width - rows.shape[1]))
    )
    col = jnp.arange(out_width)[None, :]
    return jnp.where(col < lengths[:, None], rows, 0).astype(jnp.uint8)
