"""Pallas TPU burst gather: descriptor-driven packet-arena → contiguous batch.

This is the paper's DMA path as a TPU kernel: the NIC (loadgen) leaves
variable-length packets scattered across a pinned arena; consumers want a
dense (burst, width) tensor.  The descriptor ring (slot indices) is passed as
a **scalar-prefetch** operand — Pallas reads the indices in SMEM *before*
issuing each block's HBM→VMEM DMA, which is exactly the descriptor-cache →
descriptor-driven-DMA structure of a NIC RX queue (§3.1.4), and the burst is
the DCA staging unit (§5.2): one grid step stages ``blk_n`` packets.

Non-TPU note (DESIGN.md §2): the gem5 changes themselves are register-level
x86 shims with no TPU analogue; this kernel is the *functional* equivalent —
userspace-owned descriptor-driven data movement with explicit staging.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(slots_ref, len_ref, arena_ref, out_ref, *, out_width: int):
    i = pl.program_id(0)
    # arena_ref block was DMA'd using the prefetched descriptor (see index_map)
    row = arena_ref[0, :out_width]
    col = jax.lax.broadcasted_iota(jnp.int32, (out_width,), 0)
    n = len_ref[i]
    out_ref[0] = jnp.where(col < n, row, 0).astype(out_ref.dtype)


def burst_gather_pallas(
    arena: jnp.ndarray,    # (n_slots, slot_size) uint8
    slots: jnp.ndarray,    # (n,) int32 descriptor slot indices
    lengths: jnp.ndarray,  # (n,) int32
    out_width: int,
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    n = slots.shape[0]
    slot_size = arena.shape[1]
    w = min(out_width, slot_size)

    def arena_map(i, slots_s, lens_s):
        # descriptor-driven DMA: the block row comes from the prefetched ring
        return (slots_s[i], 0)

    def out_map(i, slots_s, lens_s):
        return (i, 0)

    kernel = functools.partial(_gather_kernel, out_width=w)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, slot_size), arena_map)],
        out_specs=pl.BlockSpec((1, w), out_map),
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, w), jnp.uint8),
        interpret=interpret,
    )(slots.astype(jnp.int32), lengths.astype(jnp.int32), arena)
    if w < out_width:
        out = jnp.pad(out, ((0, 0), (0, out_width - w)))
    return out
