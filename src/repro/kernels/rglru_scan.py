"""Pallas TPU RG-LRU scan kernel.

Grid = (B, n_width_blocks, n_seq_blocks); the seq dim is sequential, carrying
the recurrent state h in VMEM scratch across seq blocks (TPU grid iteration
order makes the last dim innermost).  Within a block the recurrence runs as a
fori_loop over rows of a (blk_s, blk_w) VMEM tile — VPU elementwise work with
the state vector resident in registers/VMEM, which is how a TPU wants a
width-parallel linear scan (contrast a GPU chunked-scan with shared-memory
staging).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(x_ref, a_ref, h0_ref, y_ref, hlast_ref, h_scr, *,
                  blk_s: int, n_seq_blocks: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)  # (blk_w,)

    a = jnp.exp(a_ref[0].astype(jnp.float32))       # (blk_s, blk_w)
    g = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * x_ref[0].astype(jnp.float32)

    def body(t, h):
        h = a[t] * h + g[t]
        y_ref[0, t, :] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, blk_s, body, h_scr[...])
    h_scr[...] = h

    @pl.when(si == n_seq_blocks - 1)
    def _emit_final():
        hlast_ref[0] = h.astype(hlast_ref.dtype)


def rglru_scan_pallas(
    x: jnp.ndarray,      # (B, S, W)
    a_log: jnp.ndarray,  # (B, S, W)
    *,
    h0: Optional[jnp.ndarray] = None,
    blk_s: int = 256,
    blk_w: int = 512,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, S, W = x.shape
    blk_s = min(blk_s, S)
    blk_w = min(blk_w, W)
    assert S % blk_s == 0 and W % blk_w == 0, (S, W, blk_s, blk_w)
    ns, nw = S // blk_s, W // blk_w
    h0_in = (h0 if h0 is not None else jnp.zeros((B, W), x.dtype))

    kernel = functools.partial(_rglru_kernel, blk_s=blk_s, n_seq_blocks=ns)
    y, hlast = pl.pallas_call(
        kernel,
        grid=(B, nw, ns),
        in_specs=[
            pl.BlockSpec((1, blk_s, blk_w), lambda b, wi, si: (b, si, wi)),
            pl.BlockSpec((1, blk_s, blk_w), lambda b, wi, si: (b, si, wi)),
            pl.BlockSpec((1, blk_w), lambda b, wi, si: (b, wi)),
        ],
        out_specs=[
            pl.BlockSpec((1, blk_s, blk_w), lambda b, wi, si: (b, si, wi)),
            pl.BlockSpec((1, blk_w), lambda b, wi, si: (b, wi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, W), x.dtype),
            jax.ShapeDtypeStruct((B, W), x.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((blk_w,), jnp.float32)],
        interpret=interpret,
    )(x, a_log, h0_in)
    return y, hlast
