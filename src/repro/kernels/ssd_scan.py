"""Pallas TPU Mamba-2 SSD kernel (chunked state-space duality).

Grid = (B, n_chunks) with the chunk dim sequential; the inter-chunk state
h (H, P, N) persists in VMEM scratch.  Each grid step does the intra-chunk
quadratic duality on the MXU (Q×Q score and decay matrices) plus the state
update — the TPU-native blocking of SSD: chunk Q sized so the (H, Q, Q) decay
tensor and the (H, P, N) state both fit VMEM.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_scr, *,
                chunk: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)      # (Q, H, P)
    dt = dt_ref[0].astype(jnp.float32)    # (Q, H)
    A = a_ref[...].astype(jnp.float32)    # (H,)
    Bm = b_ref[0].astype(jnp.float32)     # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)     # (Q, N)

    dA = dt * A[None, :]                  # (Q, H)
    dA_cum = jnp.cumsum(dA, axis=0)       # (Q, H)
    xdt = x * dt[..., None]               # (Q, H, P)

    # intra-chunk: y[q] = sum_{k<=q} exp(dAcum[q]-dAcum[k]) * (C_q·B_k) xdt[k]
    seg = dA_cum[:, None, :] - dA_cum[None, :, :]          # (Q, Q, H)
    Q = seg.shape[0]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1))
    L = jnp.where(tri[..., None], jnp.exp(seg), 0.0)       # (Q, Q, H)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q, Q)
    w = L * scores[..., None]                              # (Q, Q, H)
    y_intra = jnp.einsum("qkh,khp->qhp", w, xdt)

    # inter-chunk: contribution of the carried state
    h = h_scr[...]                                         # (H, P, N)
    decay_in = jnp.exp(dA_cum)                             # (Q, H)
    y_inter = jnp.einsum("qn,hpn->qhp", Cm, h) * decay_in[..., None]

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h' = exp(dAcum[-1]) h + sum_k exp(dAcum[-1]-dAcum[k]) B_k xdt[k]
    decay_to_end = jnp.exp(dA_cum[-1][None, :] - dA_cum)   # (Q, H)
    s_chunk = jnp.einsum("qn,qh,qhp->hpn", Bm, decay_to_end, xdt)
    h_scr[...] = jnp.exp(dA_cum[-1])[:, None, None] * h + s_chunk

    @pl.when(ci == n_chunks - 1)
    def _emit():
        hout_ref[0] = h_scr[...]


def ssd_scan_pallas(
    x: jnp.ndarray,     # (B, S, H, P)
    dt: jnp.ndarray,    # (B, S, H)
    A: jnp.ndarray,     # (H,)
    Bmat: jnp.ndarray,  # (B, S, N)
    Cmat: jnp.ndarray,  # (B, S, N)
    *,
    chunk: int = 128,
    h0: Optional[jnp.ndarray] = None,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if h0 is not None:
        raise NotImplementedError("pallas ssd kernel starts from h=0; fold "
                                  "carried state via ops.ssd_decode_step")
    B, S, H, P = x.shape
    N = Bmat.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    kernel = functools.partial(_ssd_kernel, chunk=Q, n_chunks=nc)
    y, h_final = pl.pallas_call(
        kernel,
        grid=(B, nc),
        in_specs=[
            pl.BlockSpec((1, Q, H, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, Q, H), lambda b, c: (b, c, 0)),
            pl.BlockSpec((H,), lambda b, c: (0,)),
            pl.BlockSpec((1, Q, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, H, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, H, P, N), lambda b, c: (b, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((H, P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bmat, Cmat)
    return y, h_final
