"""Pallas TPU decode attention: one query token vs. a (possibly ring) KV cache.

Flash-decoding layout: grid = (B·H, n_kv_blocks) with the kv dim sequential;
online-softmax state in VMEM scratch; cache-length masking via a scalar-
prefetch operand (lengths live in SMEM and are read before the DMA of each
block — the descriptor-cache pattern from the paper's NIC, applied to VMEM).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale: float, blk_k: int, n_kv_blocks: int, n_heads: int):
    bh = pl.program_id(0)
    ki = pl.program_id(1)
    b = bh // n_heads

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    cache_len = len_ref[b]
    k_start = ki * blk_k

    @pl.when(k_start < cache_len)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (1, Dh)
        k = k_ref[0].astype(jnp.float32)          # (blk_k, Dh)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, blk_k), 1)
        s = jnp.where(kpos < cache_len, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                              preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention_pallas(
    q: jnp.ndarray,          # (B, H, Dh)
    k_cache: jnp.ndarray,    # (B, S, Hkv, Dh)
    v_cache: jnp.ndarray,
    cache_len: jnp.ndarray,  # (B,) i32
    *,
    softmax_scale: Optional[float] = None,
    blk_k: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    B, H, Dh = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    group = H // Hkv
    scale = softmax_scale if softmax_scale is not None else Dh ** -0.5
    blk_k = min(blk_k, S)
    pad_k = (-S) % blk_k
    if pad_k:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    S_p = k_cache.shape[1]
    nk = S_p // blk_k

    qr = q.reshape(B * H, 1, Dh)
    kr = k_cache.transpose(0, 2, 1, 3).reshape(B * Hkv, S_p, Dh)
    vr = v_cache.transpose(0, 2, 1, 3).reshape(B * Hkv, S_p, Dh)

    def q_map(bh, ki, lens):  # grid indices first, scalar-prefetch ref last
        return (bh, 0, 0)

    def kv_map(bh, ki, lens):
        b, h = bh // H, bh % H
        return (b * Hkv + h // group, ki, 0)

    kernel = functools.partial(_decode_kernel, scale=scale, blk_k=blk_k,
                               n_kv_blocks=nk, n_heads=H)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * H, nk),
        in_specs=[
            pl.BlockSpec((1, 1, Dh), q_map),
            pl.BlockSpec((1, blk_k, Dh), kv_map),
            pl.BlockSpec((1, blk_k, Dh), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, Dh), q_map),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, Dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * H, 1, Dh), q.dtype),
        interpret=interpret,
    )(cache_len.astype(jnp.int32), qr, kr, vr)
    return out.reshape(B, H, Dh)
