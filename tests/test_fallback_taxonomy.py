"""Fallback-reason taxonomy for the epoch engine (satellite of PR 7).

``repro.core.fastpath._fallback_reason`` documents a closed list of reasons a
config is outside the closed-form fast-path regime.  Each reason is a
contract: the engine must *refuse* (and run the event loop bit-identically)
rather than mis-simulate.  This file gives every documented reason a
triggering configuration — through the public run paths where a config can
reach it, and through direct ``_fallback_reason`` probes for the mid-run
states no fresh config can produce.  The serving stacks ride the same
taxonomy: they fall back on the server-type check, cleanly and bit-identically.
"""
import pytest

from repro.core import (BypassL2FwdServer, EpochRunInfo, EventScheduler,
                        HostCostModel, KernelStackServer, LoadGen, PacketPool,
                        PipelineServer, Port, SimClock, TrafficPattern,
                        run_epoch_sim)
from repro.core.fastpath import _fallback_reason

PATTERN = TrafficPattern(rate_gbps=5.0, packet_size=1518)
DUR = 0.0005


def _ports(ring=1024, wb=32, n_queues=2, pool_slots=8192):
    pool = PacketPool(pool_slots, 2048)
    return [Port.make(pool, ring_size=ring, writeback_threshold=wb,
                      n_queues=n_queues, link_gbps=40.0, link_latency_ns=1000)]


def _report_key(rep):
    lat = None if rep.latency is None else rep.latency.as_dict()
    return (rep.offered_gbps, rep.achieved_gbps, rep.sent, rep.received,
            rep.dropped, lat, tuple(sorted(rep.extras.items())))


def _bypass(ports, burst=32, **kw):
    srv = BypassL2FwdServer(ports, burst_size=burst, n_lcores=1, **kw)
    srv.attach_clock(SimClock())
    return srv


# -- config-reachable reasons: engine-parity pair runs -------------------------
#
# Each case is a factory returning (loadgen, server, sched); the test runs it
# once per engine on fresh state and demands the exact reason plus identical
# reports.

def _case_pipeline():
    ports = _ports()
    srv = PipelineServer(ports[0])
    srv.attach_clock(SimClock())
    return LoadGen(ports), srv, None


def _case_kernel():
    ports = _ports()
    srv = KernelStackServer(ports)
    srv.attach_clock(SimClock())
    return LoadGen(ports), srv, None


def _case_serving_prefill():
    import repro.serving as serving
    ports = _ports()
    srv = serving.PrefillServer(ports[0])
    srv.attach_clock(SimClock())
    return LoadGen(ports), srv, None


def _case_serving_balancer():
    import repro.serving as serving
    ports = _ports()
    srv = serving.BalancerServer(ports[0])
    srv.attach_clock(SimClock())
    return LoadGen(ports), srv, None


def _case_dca_accumulate():
    ports = _ports()
    srv = _bypass(ports)
    srv.enable_dca_accumulate(200_000)
    return LoadGen(ports), srv, None


def _case_integrity():
    ports = _ports()
    return LoadGen(ports, verify_integrity=True), _bypass(ports), None


def _case_dctcp_cc():
    # a rate controller adapts the emission schedule mid-trial on echo
    # feedback; the epoch planner precomputes the whole schedule up front
    from repro.core import DctcpRateController
    ports = _ports()
    lg = LoadGen(ports)
    lg.attach_cc(DctcpRateController(rate_gbps=5.0, window_ns=100_000,
                                     max_gbps=40.0, max_inflight=8))
    return lg, _bypass(ports), None


def _case_zero_cost():
    ports = _ports()
    srv = BypassL2FwdServer(ports, burst_size=32, n_lcores=1)
    srv.attach_clock(SimClock(), cost=HostCostModel(pmd_poll_cycles=0,
                                                    pmd_per_packet_cycles=0))
    return LoadGen(ports), srv, None


def _case_custom_fn():
    ports = _ports()
    srv = BypassL2FwdServer(ports, burst_size=32, n_lcores=1,
                            process_fn=lambda frame: None)
    srv.attach_clock(SimClock())
    return LoadGen(ports), srv, None


def _case_burst_exceeds_max_tx():
    ports = _ports()
    return LoadGen(ports, max_tx_burst=16), _bypass(ports, burst=64), None


def _case_burst_exceeds_tx_ring():
    ports = _ports(ring=32)
    return LoadGen(ports), _bypass(ports, burst=64), None


def _case_writeback_timers():
    ports = _ports()
    srv = _bypass(ports)
    sched = EventScheduler(srv.clock)
    for ring in ports[0].rx_queues:
        ring.attach_scheduler(sched, timeout_ns=100_000)
    return LoadGen(ports), srv, sched


def _case_writeback_dma():
    # timeout 0 disarms the idle timer so the DMA check is what trips
    ports = _ports()
    srv = _bypass(ports)
    sched = EventScheduler(srv.clock)
    for ring in ports[0].rx_queues:
        ring.attach_scheduler(sched, timeout_ns=0, writeback_dma_ns=500)
    return LoadGen(ports), srv, sched


CONFIG_CASES = [
    ("pipeline", _case_pipeline,
     "server type PipelineServer is not BypassL2FwdServer"),
    ("kernel", _case_kernel,
     "server type KernelStackServer is not BypassL2FwdServer"),
    ("serving-prefill", _case_serving_prefill,
     "server type PrefillServer is not BypassL2FwdServer"),
    ("serving-balancer", _case_serving_balancer,
     "server type BalancerServer is not BypassL2FwdServer"),
    ("custom-fn", _case_custom_fn, "custom packet-processing function"),
    ("dca-accumulate", _case_dca_accumulate, "DCA accumulate mode"),
    ("integrity", _case_integrity, "integrity verification enabled"),
    ("dctcp-cc", _case_dctcp_cc, "DCTCP rate-adaptive loadgen active"),
    ("zero-cost", _case_zero_cost, "zero-cost host model"),
    ("burst-gt-max-tx", _case_burst_exceeds_max_tx,
     "lcore burst exceeds loadgen max_tx_burst (TX would linger)"),
    ("burst-gt-tx-ring", _case_burst_exceeds_tx_ring,
     "lcore burst exceeds TX ring size"),
    ("wb-timers", _case_writeback_timers, "writeback-timeout timers armed"),
    ("wb-dma", _case_writeback_dma, "writeback DMA latency armed"),
]


@pytest.mark.parametrize("name,make,reason",
                         CONFIG_CASES, ids=[c[0] for c in CONFIG_CASES])
def test_reason_fires_and_engines_match(name, make, reason):
    lg, srv, sched = make()
    assert _fallback_reason(lg, srv, sched) == reason

    # engine parity on the same (fresh) config
    lg_e, srv_e, sched_e = make()
    ev = _report_key(lg_e.run_sim(srv_e, PATTERN, duration_s=DUR,
                                  clock=srv_e.clock, sched=sched_e))
    lg_f, srv_f, sched_f = make()
    info = EpochRunInfo()
    ep = _report_key(run_epoch_sim(lg_f, srv_f, PATTERN, duration_s=DUR,
                                   clock=srv_f.clock, sched=sched_f,
                                   info=info))
    assert not info.fastpath
    assert info.fallback_reason == reason
    assert ev == ep


# -- mid-run / degenerate states: direct probes --------------------------------
#
# These reasons guard against *reusing* a warm testbed; no fresh config can
# produce them, so we probe the predicate directly.

def test_no_clock():
    ports = _ports()
    srv = BypassL2FwdServer(ports, burst_size=32, n_lcores=1)  # no clock
    assert _fallback_reason(LoadGen(ports), srv, None) == "no SimClock attached"


def test_pending_queue_deadlines():
    ports = _ports()
    srv = _bypass(ports)
    srv.enable_dca_accumulate(100_000)
    srv._queue_deadline[(0, 0)] = 123  # lcore mid-accumulation
    assert _fallback_reason(LoadGen(ports), srv, None) \
        == "DCA accumulate mode"  # accumulate check dominates...
    srv._dca_wait_ns = None  # ...so strip it to expose the deadline check
    assert _fallback_reason(LoadGen(ports), srv, None) \
        == "pending queue accumulation deadlines"


def test_pending_scheduler_events():
    ports = _ports()
    srv = _bypass(ports)
    sched = EventScheduler(srv.clock)
    sched.schedule_in(1_000, lambda: None)
    assert _fallback_reason(LoadGen(ports), srv, sched) \
        == "pending scheduler events"


def test_no_ports():
    ports = _ports()
    srv = _bypass(ports)
    lg = LoadGen(ports)
    lg.ports = []
    assert _fallback_reason(lg, srv, None) == "no ports"


def test_port_lists_differ():
    ports_a, ports_b = _ports(), _ports()
    srv = _bypass(ports_a)
    assert _fallback_reason(LoadGen(ports_b), srv, None) \
        == "server and loadgen port lists differ"


def test_rx_ring_not_idle():
    ports = _ports(wb=1)
    srv = _bypass(ports)
    ports[0].rx_queues[0].nic_deliver(0, 100)  # published, unharvested
    assert _fallback_reason(LoadGen(ports), srv, None) == "RX ring not idle"


def test_rx_ring_not_idle_includes_dma_flight():
    ports = _ports(wb=2)
    srv = _bypass(ports)
    sched = EventScheduler(srv.clock)
    ring = ports[0].rx_queues[0]
    ring.attach_scheduler(sched, timeout_ns=0, writeback_dma_ns=700)
    ring.nic_deliver(0, 100)
    ring.nic_deliver(1, 100)           # threshold crossing starts the DMA
    assert ring._dma_pending == 2
    ring._sched = None                 # mask the armed-DMA check itself
    ring._dma_ns = 0
    assert _fallback_reason(LoadGen(ports), srv, None) == "RX ring not idle"


def test_tx_ring_not_idle():
    ports = _ports()
    srv = _bypass(ports)
    slot = ports[0].pool.alloc()
    assert ports[0].tx_queues[0].post(slot, 100)
    assert _fallback_reason(LoadGen(ports), srv, None) == "TX ring not idle"


def test_clean_bypass_config_has_no_reason():
    ports = _ports()
    srv = _bypass(ports)
    assert _fallback_reason(LoadGen(ports), srv, None) is None
    assert _fallback_reason(LoadGen(ports), srv,
                            EventScheduler(srv.clock)) is None


# -- topology-level reason: partitioned execution ------------------------------
#
# PR 8 adds one reason the per-host predicate can never see: a topology run
# under a partition mode executes domain-by-domain, and the epoch fast path
# only exists inside the shared event loop.  ``run_topology_experiment`` is
# the layer that knows, so it stamps the info struct itself.

def test_partitioned_reason_is_distinct_and_stamped():
    from repro.core.fastpath import PARTITIONED_REASON
    from repro.exp import (LinkConfig, NodeConfig, StackConfig, SwitchConfig,
                           TopologyConfig, TrafficConfig,
                           run_topology_experiment)

    assert PARTITIONED_REASON not in [c[2] for c in CONFIG_CASES]
    cfg = TopologyConfig(
        name="taxonomy-partitioned",
        nodes=(NodeConfig(name="srv",
                          stack=StackConfig(kind="bypass", burst_size=32)),),
        n_clients=2,
        switch=SwitchConfig(link=LinkConfig(gbps=40.0, latency_ns=1000)),
        traffic=TrafficConfig(mode="open_loop", rate_gbps=2.0,
                              duration_s=0.0002, packet_size=512, seed=7,
                              sim_time=True, engine="epoch"),
    ).with_partition("partitioned")
    info = EpochRunInfo()
    rep = run_topology_experiment(cfg, info=info)
    assert not info.fastpath
    assert info.fallback_reason == PARTITIONED_REASON
    # refusal, not mis-simulation: bit-identical to the shared-clock run
    shared = run_topology_experiment(cfg.with_partition("shared-clock"))
    assert rep.to_dict() == shared.to_dict()


# -- the taxonomy is CLOSED (PR 9 satellite) -----------------------------------
#
# Both info dataclasses validate every ``fallback_reason`` assignment against
# a closed reason list, so a typo'd or ad-hoc reason fails loudly at the
# assignment site instead of silently forking the taxonomy these tests and
# the sweep tooling key on.

def test_every_documented_epoch_reason_is_in_the_closed_enum():
    from repro.core.fastpath import validate_epoch_fallback_reason
    for _name, _make, reason in CONFIG_CASES:
        validate_epoch_fallback_reason(reason)  # must not raise
    for reason in (
            "no SimClock attached",
            "pending queue accumulation deadlines",
            "pending scheduler events",
            "no ports",
            "server and loadgen port lists differ",
            "RX ring not idle",
            "TX ring not idle",
            "RX ring would fill (overflow writeback/drop regime)",
            "packet pool would exhaust",
            "planning failed: ValueError('boom')",
            "server type PrefillServer is not BypassL2FwdServer",
            "partitioned domain execution",
            None):
        validate_epoch_fallback_reason(reason)


def test_epoch_info_rejects_unknown_reason():
    info = EpochRunInfo()
    with pytest.raises(ValueError, match="closed"):
        info.fallback_reason = "RX ring nearly full"  # typo'd variant
    with pytest.raises(ValueError, match="closed"):
        EpochRunInfo(fallback_reason="made-up reason")
    info.fallback_reason = "RX ring not idle"  # exact member: fine
    info.fallback_reason = None


def test_partition_info_rejects_unknown_reason():
    from repro.core import PartitionRunInfo
    info = PartitionRunInfo()
    with pytest.raises(ValueError, match="closed"):
        info.fallback_reason = "partition disabled"
    with pytest.raises(ValueError, match="closed"):
        PartitionRunInfo(fallback_reason="nope")
    info.fallback_reason = (
        "serving topology: balancer reads live cross-domain state")
    info.fallback_reason = None


def test_partition_fallback_reasons_cover_the_policy_layer():
    """Every string ``repro.exp.topology.partition_fallback_reason`` can
    produce must validate against the closed partition taxonomy."""
    from repro.core import validate_partition_fallback_reason
    for reason in (
            "serving topology: balancer reads live cross-domain state",
            "zero-latency links leave no conservative lookahead window",
            "node 'srv': zero-cost PMD model needs the shared loop's "
            "every-round polling",
            "node 'srv': zero-cost kernel model needs the shared loop's "
            "every-round polling",
            "node 'srv': stack kind 'pipeline' not proven "
            "partition-equivalent",
            "AQM policy 'ecn' not proven partition-equivalent",
            "AQM policy 'red' not proven partition-equivalent",
            "DCTCP rate-adaptive clients adapt on cross-domain echo feedback",
            "multi-switch trunk fabric not proven partition-equivalent",
            None):
        validate_partition_fallback_reason(reason)
    with pytest.raises(ValueError, match="closed"):
        validate_partition_fallback_reason("node srv is weird")


# -- PR 10 partition reasons: triggering configs + refusal parity --------------
#
# Each new fabric/loadgen feature is conservatively excluded from partitioned
# execution until proven equivalent.  The contract per reason: the policy
# layer names it, the run stamps it, and the "partitioned" run is the
# shared-clock run bit-for-bit (refusal, never mis-simulation).

def _pr10_topology(**kw):
    from repro.exp import (LinkConfig, NodeConfig, PoolConfig, SwitchConfig,
                           TopologyConfig, TrafficConfig)
    switch_kw = {k: kw.pop(k) for k in ("pipeline", "trunk") if k in kw}
    traffic_kw = {k: kw.pop(k) for k in ("cc_mode",) if k in kw}
    return TopologyConfig(
        name="taxonomy-pr10",
        nodes=(NodeConfig(name="srv", pool=PoolConfig(n_slots=8192)),),
        n_clients=2,
        switch=SwitchConfig(egress_capacity=16,
                            link=LinkConfig(gbps=10.0, latency_ns=1000),
                            **switch_kw),
        traffic=TrafficConfig(mode="open_loop", rate_gbps=2.0,
                              duration_s=0.0002, packet_size=512, seed=7,
                              cc_window_ns=100_000, cc_max_inflight=8,
                              **traffic_kw),
        target="srv", **kw)


def _pr10_cases():
    from repro.exp import AqmConfig, LinkConfig, PipelineConfig
    ecn = PipelineConfig(aqm=AqmConfig(kind="ecn", min_thresh=2,
                                       max_thresh=8, max_p=0.2, seed=1))
    red = PipelineConfig(aqm=AqmConfig(kind="red", min_thresh=2,
                                       max_thresh=8, max_p=0.2, seed=1))
    return [
        ("aqm-ecn", _pr10_topology(pipeline=ecn),
         "AQM policy 'ecn' not proven partition-equivalent"),
        ("aqm-red", _pr10_topology(pipeline=red),
         "AQM policy 'red' not proven partition-equivalent"),
        ("dctcp", _pr10_topology(cc_mode="dctcp"),
         "DCTCP rate-adaptive clients adapt on cross-domain echo feedback"),
        ("trunk", _pr10_topology(trunk=LinkConfig(gbps=40.0,
                                                  latency_ns=2000)),
         "multi-switch trunk fabric not proven partition-equivalent"),
    ]


@pytest.mark.parametrize("name,cfg,reason",
                         _pr10_cases(), ids=[c[0] for c in _pr10_cases()])
def test_pr10_partition_reasons_fire_and_refusal_is_bit_identical(
        name, cfg, reason):
    from repro.core import PartitionRunInfo
    from repro.exp import run_topology_experiment
    from repro.exp.topology import partition_fallback_reason

    assert partition_fallback_reason(cfg) == reason
    info = PartitionRunInfo()
    rep = run_topology_experiment(cfg.with_partition("partitioned"),
                                  partition_info=info)
    assert info.mode_requested == "partitioned"
    assert info.mode_used == "shared-clock"
    assert info.fallback_reason == reason
    shared = run_topology_experiment(cfg.with_partition("shared-clock"))
    assert rep.to_dict() == shared.to_dict()
