"""Virtual-time core: SimClock/EventScheduler/Wire units, analytic emission
schedules (incl. the Poisson-pacing fix), wire semantics in RTTs, virtual-time
core scaling, and the headline determinism guarantees — same seeded
ExperimentConfig → bit-identical RunReport, for all three traffic modes."""
import numpy as np
import pytest

from repro.core import (BypassL2FwdServer, EventScheduler, LoadGen, PacketPool,
                        Port, SimClock, TrafficPattern, Wire,
                        find_max_sustainable_bandwidth)
from repro.core.cost import HostCostModel, ZERO_COST
from repro.exp import (CostConfig, ExperimentConfig, LinkConfig, PoolConfig,
                       PortConfig, StackConfig, TrafficConfig, run_experiment)

ZERO_COST_CFG = CostConfig(interrupt_cycles=0, syscall_cycles=0,
                           per_packet_kernel_cycles=0, pmd_poll_cycles=0,
                           pmd_per_packet_cycles=0)


# -- clock / scheduler / wire units -------------------------------------------

def test_simclock_monotonic():
    c = SimClock()
    assert c.advance_to(100) == 100
    assert c.advance_to(50) == 100  # never backward
    assert c.advance(25) == 125
    with pytest.raises(ValueError):
        c.advance(-1)


def test_event_scheduler_fifo_tiebreak_and_order():
    sched = EventScheduler()
    fired = []
    sched.schedule_at(20, lambda: fired.append("b"))
    sched.schedule_at(10, lambda: fired.append("a"))
    sched.schedule_at(20, lambda: fired.append("c"))  # same time: FIFO
    assert sched.next_time_ns() == 10
    assert sched.run_until(15) == 1
    assert sched.clock.now_ns == 15
    sched.run_all()
    assert fired == ["a", "b", "c"]
    assert sched.clock.now_ns == 20


def test_event_scheduler_cancel_token():
    """Timer primitives: schedule returns a token; cancel prevents firing,
    reports whether the event was still pending, and keeps len() live-only."""
    sched = EventScheduler()
    fired = []
    t1 = sched.schedule_at(10, lambda: fired.append("a"))
    t2 = sched.schedule_at(20, lambda: fired.append("b"))
    t3 = sched.schedule_in(30, lambda: fired.append("c"))
    assert len(sched) == 3
    assert sched.cancel(t2) is True
    assert sched.cancel(t2) is False  # already cancelled
    assert len(sched) == 2
    sched.run_all()
    assert fired == ["a", "c"]
    assert sched.cancel(t1) is False  # already fired
    assert sched.cancel(t3) is False
    assert len(sched) == 0


def test_event_scheduler_cancelled_head_never_fires():
    """A cancelled earliest event must not gate next_time_ns or run_until
    (a tombstoned head used to make run_until fire events beyond t_ns)."""
    sched = EventScheduler()
    fired = []
    tok = sched.schedule_at(5, lambda: fired.append("dead"))
    sched.schedule_at(50, lambda: fired.append("live"))
    sched.cancel(tok)
    assert sched.next_time_ns() == 50
    assert sched.run_until(10) == 0  # the 50ns event must NOT fire early
    assert fired == []
    assert sched.run_until(60) == 1
    assert fired == ["live"]


def test_event_scheduler_cancel_churn_compacts():
    """Arm/cancel churn (per-packet writeback timers) must not grow the heap
    unboundedly: tombstones are compacted once they dominate."""
    sched = EventScheduler()
    for i in range(10_000):
        sched.cancel(sched.schedule_at(1_000_000 + i, lambda: None))
    assert len(sched) == 0
    assert len(sched._heap) <= 64


def test_wire_serialization_and_fifo_queueing():
    w = Wire(gbps=10.0, latency_ns=500)  # 10 Gbps: 1250B == 1000 ns
    assert w.serialization_ns(1250) == 1000
    a1 = w.transmit(0, 1250)
    a2 = w.transmit(0, 1250)  # queues behind the first frame
    assert a1 == 1500
    assert a2 == 2500
    burst = w.transmit_burst(0, np.array([1250, 1250], dtype=np.int32))
    assert list(burst) == [3500, 4500]  # continues behind the queue
    ideal = Wire(gbps=0.0, latency_ns=0)
    assert ideal.transmit(7, 9000) == 7


def test_wire_transmit_burst_empty_burst():
    """Regression: an empty burst used to raise IndexError (ends[-1]) on a
    rate-limited wire; it must return an empty array and leave the wire
    untouched."""
    w = Wire(gbps=10.0, latency_ns=500)
    w.transmit(0, 1250)  # wire busy until 1000
    out = w.transmit_burst(100, [])
    assert out.dtype == np.int64 and len(out) == 0
    assert w.busy_until_ns == 1000
    ideal = Wire(gbps=0.0, latency_ns=0)
    assert len(ideal.transmit_burst(0, np.empty(0, dtype=np.int32))) == 0


# -- analytic emission schedules ----------------------------------------------

def test_uniform_schedule_exact_spacing():
    p = TrafficPattern(rate_gbps=1.0, packet_size=1518, kind="uniform")
    times, sizes = p.emission_schedule(1_000_000)
    # 1 Gbps / 1518B -> 12144 ns gap, 82 packets in 1 ms
    assert len(times) == int(1e6 / 12144.0)
    assert (np.diff(times) == 12144).all()
    assert (sizes == 1518).all()


def test_poisson_schedule_is_a_real_poisson_process():
    """Pre-drawn exponential inter-arrivals: monotone, correct mean rate,
    exponential spread — the seed's per-iteration rng.poisson(cumulative)
    re-draw had none of these properties."""
    p = TrafficPattern(rate_gbps=1.0, packet_size=1518, kind="poisson", seed=5)
    dur = 50_000_000  # 50 ms -> ~4117 expected arrivals
    times, _ = p.emission_schedule(dur)
    gaps = np.diff(times).astype(np.float64)
    assert (gaps >= 0).all()
    expected = dur / 12144.0
    assert abs(len(times) - expected) / expected < 0.1
    # exponential: std ≈ mean (CoV ~1); uniform pacing would give CoV ~0
    assert 0.8 < gaps.std() / gaps.mean() < 1.2
    # reproducible from the seed
    t2, _ = p.emission_schedule(dur)
    assert np.array_equal(times, t2)


def test_bursty_schedule_back_to_back_trains():
    p = TrafficPattern(rate_gbps=1.0, packet_size=512, kind="bursty",
                       burst_len=16)
    times, _ = p.emission_schedule(2_000_000)
    starts, counts = np.unique(times, return_counts=True)
    assert (counts == 16).all()
    assert len(starts) >= 2


def test_trace_schedule_replays_within_duration():
    trace = [(i * 1000, 128 + i) for i in range(50)]
    p = TrafficPattern(trace=trace)
    times, sizes = p.emission_schedule(10_000)
    assert len(times) == 10
    assert list(sizes) == [128 + i for i in range(10)]


def test_trace_schedule_sorts_out_of_order_entries():
    """Regression: an out-of-order trace used to pass through unsorted,
    violating the documented "times non-decreasing" contract and corrupting
    run_sim's event loop and run's searchsorted credit."""
    p = TrafficPattern(trace=[(5000, 128), (1000, 256), (1000, 300), (0, 64)])
    times, sizes = p.emission_schedule(10_000)
    assert list(times) == [0, 1000, 1000, 5000]
    # stable: equal-time entries keep their input order
    assert list(sizes) == [64, 256, 300, 128]


def test_trace_schedule_rejects_negative_offsets():
    with pytest.raises(ValueError, match=">= 0"):
        TrafficPattern(trace=[(-1, 64)]).emission_schedule(10_000)


# -- virtual-time runs --------------------------------------------------------

def _sim_setup(link_gbps=100.0, latency_ns=1000, cost=ZERO_COST, ring=1024,
               n_queues=1, pool_slots=16384):
    pool = PacketPool(pool_slots, 1518)
    ports = [Port.make(pool, ring_size=ring, n_queues=n_queues,
                       link_gbps=link_gbps, link_latency_ns=latency_ns)]
    server = BypassL2FwdServer(ports, burst_size=64)
    clock = SimClock()
    server.attach_clock(clock, cost)
    return server, ports, clock


def test_100gbps_simulates_from_virtual_time():
    """Acceptance: 100 Gbps of offered load is simulable on any host, with
    achieved_gbps computed from virtual (not host) time."""
    server, ports, clock = _sim_setup(link_gbps=400.0)
    lg = LoadGen(ports)
    rep = lg.run_sim(server, TrafficPattern(rate_gbps=100.0, packet_size=1518),
                     duration_s=0.0002, clock=clock)
    assert rep.sent == 1646  # floor(200us * 100Gbps / 8 / 1518)
    assert rep.dropped == 0
    assert abs(rep.achieved_gbps - 100.0) / 100.0 < 0.05
    assert rep.extras["sim_time"] == 1.0
    # the whole virtual 200 us elapsed, regardless of how fast the host ran
    assert rep.extras["virtual_elapsed_ns"] >= 200_000


def test_link_latency_and_serialization_floor_the_rtt():
    server, ports, clock = _sim_setup(link_gbps=10.0, latency_ns=5_000)
    lg = LoadGen(ports)
    rep = lg.run_sim(server, TrafficPattern(rate_gbps=0.5, packet_size=1250),
                     duration_s=0.001, clock=clock)
    # two crossings of (1000ns serialization + 5000ns propagation)
    assert rep.latency.min_ns >= 2 * (1000 + 5000)
    assert rep.received > 0 and rep.dropped == 0


def test_wire_saturation_caps_offered_load():
    """Offering 40 Gbps into a 10 Gbps wire: the wire itself is the
    bottleneck; everything that fits arrives late but the server keeps up."""
    server, ports, clock = _sim_setup(link_gbps=10.0, ring=4096,
                                      pool_slots=65536)
    lg = LoadGen(ports)
    rep = lg.run_sim(server, TrafficPattern(rate_gbps=40.0, packet_size=1518),
                     duration_s=0.0005, clock=clock)
    assert rep.achieved_gbps < 12.0  # line rate, not offered rate
    assert rep.latency.p99_ns > rep.latency.min_ns  # queueing built up


def test_virtual_time_core_scaling():
    """The Fig. 3(a) core axis actually scales in virtual time (per-lcore
    busy-time is parallel), even on a 1-core GIL-bound host."""
    msbs = {}
    for nq in (1, 2, 4):
        def mk(nq=nq):
            server, ports, _ = _sim_setup(link_gbps=400.0,
                                          cost=HostCostModel(), n_queues=nq,
                                          pool_slots=32768)
            return server, ports
        msbs[nq], _ = find_max_sustainable_bandwidth(
            mk, trial_s=0.001, refine_iters=2, start_gbps=8.0)
    assert msbs[2] > 1.7 * msbs[1]
    assert msbs[4] > 3.0 * msbs[1]


def test_sim_drops_accounted_exactly():
    class DeadServer:  # never polls: everything beyond ring+pool drops
        def poll_once(self):
            return 0

    pool = PacketPool(64, 1518)
    ports = [Port.make(pool, ring_size=8, writeback_threshold=8,
                       link_gbps=100.0)]
    lg = LoadGen(ports)
    rep = lg.run_sim(DeadServer(), TrafficPattern(rate_gbps=5.0,
                                                  packet_size=1518),
                     duration_s=0.001)
    assert rep.sent > 0
    assert rep.dropped > 0
    assert rep.received + rep.dropped == rep.sent


# -- the determinism acceptance: config + seed -> bit-identical report --------

def _report_fingerprint(rep):
    return (
        rep.sent, rep.received, rep.dropped, rep.offered_gbps,
        rep.achieved_gbps, rep.achieved_mpps,
        None if rep.latency is None else tuple(sorted(
            rep.latency.as_dict().items())),
        tuple(tuple(sorted(b.items())) for b in rep.histogram),
        tuple(sorted(rep.extras.items())),
    )


def _cfg(mode: str, kind: str = "poisson") -> ExperimentConfig:
    return ExperimentConfig(
        name=f"determinism-{mode}",
        pool=PoolConfig(n_slots=8192),
        ports=(PortConfig(n_queues=2, ring_size=512,
                          link=LinkConfig(gbps=100.0, latency_ns=1000)),),
        stack=StackConfig(kind="bypass", burst_size=32),
        traffic=TrafficConfig(mode=mode, rate_gbps=5.0, kind=kind,
                              packet_size=512, duration_s=0.001, seed=11,
                              n_packets=300, window=64, payload_seed=2,
                              start_gbps=1.0, trial_s=0.0005, refine_iters=2),
    )


@pytest.mark.parametrize("mode,kind", [("open_loop", "uniform"),
                                       ("open_loop", "poisson"),
                                       ("open_loop", "bursty"),
                                       ("closed_loop", "uniform"),
                                       ("msb", "uniform")])
def test_seeded_config_reports_are_bit_identical(mode, kind):
    a = _report_fingerprint(run_experiment(_cfg(mode, kind)))
    b = _report_fingerprint(run_experiment(_cfg(mode, kind)))
    assert a == b


def test_lcore_threads_refuse_virtual_time():
    """Threads pace on the host clock; starting them on a clocked stack
    would silently corrupt cost accounting, so it must raise."""
    server, ports, clock = _sim_setup()
    with pytest.raises(RuntimeError, match="sim_time"):
        server.start_lcore_threads()


def test_kernel_stack_deterministic_in_sim():
    cfg = ExperimentConfig(
        ports=(PortConfig(ring_size=512),),
        stack=StackConfig(kind="kernel"),
        traffic=TrafficConfig(mode="open_loop", rate_gbps=1.0,
                              packet_size=1518, duration_s=0.002, seed=3))
    a = _report_fingerprint(run_experiment(cfg))
    b = _report_fingerprint(run_experiment(cfg))
    assert a == b


def test_bypass_beats_kernel_in_virtual_time():
    """The paper's headline ratio, now measured deterministically: bypass
    MSB lands ~5-6x over the kernel stack (Fig. 3(a), 1 port)."""
    def msb_of(kind):
        cfg = ExperimentConfig(
            stack=StackConfig(kind=kind),
            traffic=TrafficConfig(mode="msb", trial_s=0.002, refine_iters=3,
                                  start_gbps=0.5))
        return run_experiment(cfg).extras["msb_gbps"]
    b, k = msb_of("bypass"), msb_of("kernel")
    assert b > 3.0 * k
    assert k > 0
