"""repro.exp: config round-tripping, the Testbed builder, the stack
registry, and run_experiment conservation across all three stacks."""
import json

import pytest

from repro.core import (BypassL2FwdServer, EthDevState, KernelStackServer,
                        PipelineServer, SimClock)
from repro.exp import (CostConfig, ExperimentConfig, LinkConfig, PoolConfig,
                       PortConfig, RssConfig, StackConfig, TrafficConfig,
                       Testbed, make_server_factory, register_stack,
                       run_experiment, run_testbed, stack_kinds)

ZERO_COST = CostConfig(interrupt_cycles=0, syscall_cycles=0,
                       per_packet_kernel_cycles=0)


def _full_config() -> ExperimentConfig:
    """Non-default values in every field that supports them."""
    return ExperimentConfig(
        name="roundtrip",
        pool=PoolConfig(n_slots=4096, slot_size=1024),
        ports=(PortConfig(n_queues=4, ring_size=512, writeback_threshold=None,
                          rss=RssConfig(table_size=64, key_hex="ab" * 40),
                          link=LinkConfig(gbps=25.0, latency_ns=350)),
               PortConfig(n_queues=2)),
        stack=StackConfig(kind="kernel", burst_size=32, n_lcores=2,
                          per_lcore_bursts=(8, 16), sockbuf_budget=32,
                          cost=CostConfig(cpu_ghz=3.0, interrupt_cycles=4000,
                                          pmd_per_packet_cycles=900)),
        traffic=TrafficConfig(mode="closed_loop", n_packets=500, window=64,
                              payload_seed=7, verify_integrity=True,
                              packet_size=300, sim_time=False))


# -- config layer -------------------------------------------------------------

def test_config_round_trip():
    """Acceptance: ExperimentConfig.from_dict(cfg.to_dict()) == cfg."""
    for cfg in (ExperimentConfig(), _full_config()):
        assert ExperimentConfig.from_dict(cfg.to_dict()) == cfg


def test_config_survives_json():
    cfg = _full_config()
    assert ExperimentConfig.from_dict(
        json.loads(json.dumps(cfg.to_dict()))) == cfg


def test_config_validation():
    with pytest.raises(ValueError):
        TrafficConfig(mode="warp")
    with pytest.raises(ValueError):
        PortConfig(n_queues=0)
    with pytest.raises(ValueError):
        ExperimentConfig(ports=())
    with pytest.raises(ValueError):
        ExperimentConfig(stack=StackConfig(kind="pipeline"),
                         ports=(PortConfig(), PortConfig()))


def test_with_helpers_return_new_frozen_configs():
    cfg = ExperimentConfig()
    c2 = cfg.with_traffic(rate_gbps=2.0).with_stack(burst_size=128)
    assert cfg.traffic.rate_gbps == 1.0  # original untouched
    assert c2.traffic.rate_gbps == 2.0
    assert c2.stack.burst_size == 128
    c3 = c2.with_ports(n_queues=4)
    assert all(p.n_queues == 4 for p in c3.ports)


def test_cost_config_maps_to_host_cost_model():
    m = CostConfig(cpu_ghz=3.0, interrupt_cycles=1).to_host_cost_model()
    assert m.cpu_ghz == 3.0 and m.interrupt_cycles == 1
    assert CostConfig.from_host_cost_model(m) == CostConfig(
        cpu_ghz=3.0, interrupt_cycles=1)


# -- testbed builder ----------------------------------------------------------

def test_testbed_builds_started_devices_per_config():
    cfg = ExperimentConfig(
        pool=PoolConfig(n_slots=2048),
        ports=(PortConfig(n_queues=2, ring_size=128),
               PortConfig(n_queues=1, ring_size=64)),
        stack=StackConfig(kind="bypass"))
    tb = Testbed.build(cfg)
    assert len(tb.devs) == 2
    assert all(d.state is EthDevState.STARTED for d in tb.devs)
    assert tb.devs[0].n_queues == 2 and tb.devs[1].n_queues == 1
    assert tb.devs[0].rx_queues[0].size == 128
    assert tb.devs[1].rx_queues[0].size == 64
    assert isinstance(tb.server, BypassL2FwdServer)
    assert tb.pool.n_slots == 2048


def test_stack_registry_selects_server_class():
    mk = lambda kind, cost=None: Testbed.build(ExperimentConfig(
        stack=StackConfig(kind=kind, cost=cost))).server
    assert isinstance(mk("bypass"), BypassL2FwdServer)
    assert isinstance(mk("pipeline"), PipelineServer)
    assert isinstance(mk("kernel", ZERO_COST), KernelStackServer)
    assert {"bypass", "kernel", "pipeline"} <= set(stack_kinds())


def test_register_stack_extension_point():
    calls = []

    @register_stack("test-custom")
    def _build(cfg, devs):
        calls.append(cfg.kind)
        return BypassL2FwdServer(list(devs), burst_size=cfg.burst_size)

    try:
        cfg = ExperimentConfig(stack=StackConfig(kind="test-custom"))
        assert ExperimentConfig.from_dict(cfg.to_dict()) == cfg
        tb = Testbed.build(cfg)
        assert isinstance(tb.server, BypassL2FwdServer)
        assert calls == ["test-custom"]
    finally:
        from repro.exp import testbed
        testbed._STACKS.pop("test-custom", None)


def test_unknown_stack_kind_raises_at_build_time():
    cfg = ExperimentConfig(stack=StackConfig(kind="no-such-stack"))
    with pytest.raises(ValueError, match="unknown stack kind"):
        Testbed.build(cfg)


# -- run_experiment -----------------------------------------------------------

def _closed_loop(kind: str, **stack_kw) -> ExperimentConfig:
    return ExperimentConfig(
        name=f"t-{kind}",
        pool=PoolConfig(n_slots=4096),
        ports=(PortConfig(n_queues=2, ring_size=256),),
        stack=StackConfig(kind=kind, burst_size=32,
                          cost=ZERO_COST if kind == "kernel" else None,
                          **stack_kw),
        traffic=TrafficConfig(mode="closed_loop", n_packets=400,
                              packet_size=256, verify_integrity=True,
                              payload_seed=3))


@pytest.mark.parametrize("kind", ["bypass", "pipeline", "kernel"])
def test_run_experiment_conserves_packets(kind):
    rep = run_experiment(_closed_loop(kind))
    assert rep.received == 400
    assert rep.dropped == 0
    assert rep.extras["integrity_errors"] == 0


def test_run_experiment_is_deterministic_from_config():
    """Same config → byte-identical per-queue stats, twice."""
    def once():
        tb = Testbed.build(_closed_loop("bypass"))
        run_testbed(tb)
        return {k: (v.rx_packets, v.tx_packets, v.rx_bytes)
                for k, v in tb.server.per_queue_stats().items()}
    assert once() == once()


def test_run_experiment_msb_mode():
    cfg = ExperimentConfig(
        traffic=TrafficConfig(mode="msb", trial_s=0.002, refine_iters=1,
                              start_gbps=0.1))
    rep = run_experiment(cfg)
    assert rep.extras["msb_gbps"] > 0
    assert rep.extras["msb_trials"] >= 1


def test_sim_time_default_builds_clocked_testbed():
    tb = Testbed.build(ExperimentConfig())
    assert isinstance(tb.clock, SimClock)
    assert tb.server.clock is tb.clock
    # links flow config -> EthDev -> engine
    assert tb.devs[0].link_gbps == 100.0
    assert tb.devs[0].link_latency_ns == 1_000
    # wall-clock mode opts out
    tb_wall = Testbed.build(ExperimentConfig(
        traffic=TrafficConfig(sim_time=False)))
    assert tb_wall.clock is None
    assert tb_wall.server.clock is None


def test_make_server_factory_fresh_state():
    f = make_server_factory(_closed_loop("bypass"))
    s1, d1 = f()
    s2, d2 = f()
    assert s1 is not s2
    assert d1[0] is not d2[0]
    assert d1[0].pool is not d2[0].pool


def test_run_testbed_rejects_msb():
    cfg = ExperimentConfig(traffic=TrafficConfig(mode="msb"))
    with pytest.raises(ValueError):
        run_testbed(Testbed.build(cfg))
