"""Sim-time DCA descriptor path (paper §3.1.4 / §5.2, Fig. 4 end-to-end).

The tentpole guarantee: sweeping ``DcaConfig.burst_size`` /
``writeback_threshold`` through the standard ``run_experiment`` path moves
*measured RTT percentiles* — not just the standalone queue-occupancy proxy —
because the descriptor rings publish completions via threshold crossings and
scheduler-driven writeback-timeout events, and the bypass PMD accumulates a
full burst before forwarding (give-up deadline bounded by the same timeout).
Everything stays bit-identical for the same config + seed, including under
``run_topology_experiment``.
"""
import json

import numpy as np
import pytest

from repro.core import (BurstPlan, BypassL2FwdServer, EventScheduler,
                        PacketPool, Port, SimClock)
from repro.core.descriptor import RxDescriptorRing
from repro.exp import (DcaConfig, ExperimentConfig, LinkConfig, NodeConfig,
                       PoolConfig, PortConfig, StackConfig, SwitchConfig,
                       TopologyConfig, TrafficConfig, run_experiment,
                       run_topology_experiment)


# -- ring-level writeback timeout (the ITR analogue) ---------------------------

def test_writeback_timeout_flushes_idle_cache():
    """A completion entering an empty descriptor cache arms the idle timer;
    with no threshold crossing, the timer publishes it ``timeout_ns`` later
    as a scheduler event."""
    sched = EventScheduler(SimClock())
    ring = RxDescriptorRing(64, writeback_threshold=32)
    ring.attach_scheduler(sched, timeout_ns=5_000)
    ring.nic_deliver(0, 100)
    ring.nic_deliver(1, 100)
    assert ring.done_count == 0 and len(sched) == 1
    assert sched.next_time_ns() == 5_000
    sched.run_until(5_000)
    assert sched.clock.now_ns == 5_000
    assert ring.done_count == 2
    assert ring.timeout_flushes == 1
    assert ring.writeback_sizes == [2]


def test_threshold_crossing_cancels_the_timer():
    """A threshold writeback empties the cache and cancels the pending idle
    timer — no spurious (empty) timeout flush is recorded later."""
    sched = EventScheduler(SimClock())
    ring = RxDescriptorRing(64, writeback_threshold=4)
    ring.attach_scheduler(sched, timeout_ns=5_000)
    for i in range(4):
        ring.nic_deliver(i, 100)
    assert ring.done_count == 4
    assert len(sched) == 0  # timer cancelled by the threshold writeback
    sched.run_until(50_000)
    assert ring.timeout_flushes == 0
    assert ring.writebacks == 1


def test_timer_rearms_per_idle_period():
    sched = EventScheduler(SimClock())
    ring = RxDescriptorRing(64, writeback_threshold=32)
    ring.attach_scheduler(sched, timeout_ns=1_000)
    ring.nic_deliver(0, 64)
    sched.run_until(1_000)
    assert ring.timeout_flushes == 1
    ring.nic_deliver(1, 64)  # new idle period: a fresh timer
    assert len(sched) == 1
    sched.run_until(2_000)
    assert ring.timeout_flushes == 2
    assert ring.writeback_sizes == [1, 1]


# -- BurstPlan attach-time validation (satellite bugfix) -----------------------

def test_burst_plan_length_must_match_lcores():
    """A 3-entry per_lcore tuple on a 4-lcore stack is a misconfiguration:
    the old modulo wrap silently recycled entry 0 for lcore 3."""
    pool = PacketPool(1024, 256)
    ports = [Port.make(pool, ring_size=64, n_queues=4)]
    with pytest.raises(ValueError, match="per_lcore"):
        BypassL2FwdServer(ports, n_lcores=4, plan=BurstPlan(per_lcore=(8, 16, 32)))
    # exact length still works
    srv = BypassL2FwdServer(ports, n_lcores=4,
                            plan=BurstPlan(per_lcore=(8, 16, 32, 64)))
    assert [lc.burst_size for lc in srv.lcores] == [8, 16, 32, 64]
    # burst_for keeps the documented modulo fallback for direct callers
    assert BurstPlan(per_lcore=(8, 16, 32)).burst_for(3) == 8


# -- DcaConfig plumbing --------------------------------------------------------

def test_dca_config_round_trips_exactly():
    dca = DcaConfig(burst_size=1024, writeback_threshold=None,
                    writeback_timeout_ns=123_456, per_lcore_bursts=(4, 1024))
    assert DcaConfig.from_dict(dca.to_dict()) == dca
    cfg = ExperimentConfig(name="dca", dca=dca)
    assert ExperimentConfig.from_dict(cfg.to_dict()) == cfg
    via_json = ExperimentConfig.from_dict(json.loads(json.dumps(cfg.to_dict())))
    assert via_json == cfg
    node = NodeConfig(name="n", dca=DcaConfig(burst_size=64))
    assert NodeConfig.from_dict(node.to_dict()) == node
    topo = TopologyConfig(nodes=(node,))
    assert TopologyConfig.from_dict(topo.to_dict()) == topo


def test_dca_config_requires_sim_time():
    with pytest.raises(ValueError, match="sim_time"):
        ExperimentConfig(dca=DcaConfig(),
                         traffic=TrafficConfig(sim_time=False))


def test_dca_threshold_must_fit_the_ring():
    with pytest.raises(ValueError, match="ring_size"):
        ExperimentConfig(ports=(PortConfig(ring_size=256),),
                         dca=DcaConfig(writeback_threshold=512))
    with pytest.raises(ValueError, match="ring_size"):
        NodeConfig(port=PortConfig(ring_size=256),
                   dca=DcaConfig(writeback_threshold=512))


def test_dca_burst_must_fit_the_ring():
    """A burst the ring can never hold would degenerate every forward into
    a timeout wait — rejected at config time, including per-lcore bursts."""
    with pytest.raises(ValueError, match="accumulate"):
        ExperimentConfig(ports=(PortConfig(ring_size=256),),
                         dca=DcaConfig(burst_size=512))
    with pytest.raises(ValueError, match="accumulate"):
        NodeConfig(port=PortConfig(ring_size=256),
                   dca=DcaConfig(burst_size=32, per_lcore_bursts=(32, 512)))


def test_dca_timeout_must_be_positive():
    """timeout 0 would mean 'never flush' at the NIC timer but 'give up
    immediately' at the PMD — one knob, opposite semantics — so it is
    rejected: the timeout is the model's latency bound and must exist."""
    with pytest.raises(ValueError, match="writeback_timeout_ns"):
        DcaConfig(writeback_timeout_ns=0)


# -- end-to-end: burst size moves measured RTT percentiles (Fig. 4) ------------

def _single_host_cfg(burst: int, threshold=32, dma_ns=0,
                     kind="bypass") -> ExperimentConfig:
    return ExperimentConfig(
        name=f"dca-b{burst}",
        ports=(PortConfig(n_queues=1, ring_size=2048),),
        stack=StackConfig(kind=kind, n_lcores=1),
        traffic=TrafficConfig(mode="open_loop", rate_gbps=10.0,
                              packet_size=1518, duration_s=0.002, seed=3),
        dca=DcaConfig(burst_size=burst, writeback_threshold=threshold,
                      writeback_timeout_ns=200_000,
                      writeback_dma_ns=dma_ns))


def test_burst_size_moves_measured_rtt_percentiles():
    """Acceptance: p99 at burst 1024 > p99 at burst 32 at the same offered
    rate, through the standard run_experiment path; no packets are lost
    (the accumulation give-up deadline forwards the tail)."""
    r32 = run_experiment(_single_host_cfg(32))
    r1024 = run_experiment(_single_host_cfg(1024))
    for rep in (r32, r1024):
        assert rep.received == rep.sent > 1000
    assert r1024.latency.p99_ns > 2 * r32.latency.p99_ns
    assert r1024.latency.median_ns > r32.latency.median_ns


def test_writeback_threshold_moves_measured_rtt_percentiles():
    """The §3.1.4 knob itself: a coarse writeback threshold delays PMD
    visibility and fattens the measured tail at a fixed processing burst."""
    fine = run_experiment(_single_host_cfg(32, threshold=32))
    coarse = run_experiment(_single_host_cfg(32, threshold=1024))
    assert coarse.latency.p99_ns > fine.latency.p99_ns
    assert coarse.extras["p0q0_wb_size_max"] > fine.extras["p0q0_wb_size_max"]


def test_timeout_bounds_worst_case_latency_and_run_quiesces():
    """The writeback timeout is the latency backstop: with burst 1024 and a
    train that ends mid-burst, every packet still completes, the timer
    records its flushes, and the worst RTT stays within a few timeouts
    (NIC-side flush + PMD give-up) instead of hanging unboundedly."""
    timeout = 200_000
    rep = run_experiment(_single_host_cfg(1024))
    assert rep.received == rep.sent
    assert rep.extras["p0q0_timeout_flushes"] >= 1
    assert rep.latency.max_ns < 3 * timeout


def test_dca_reports_bit_identical_and_telemetry_present():
    a = run_experiment(_single_host_cfg(1024))
    b = run_experiment(_single_host_cfg(1024))
    assert a.summary() == b.summary()
    assert a.latency.as_dict() == b.latency.as_dict()
    for key in ("p0q0_writebacks", "p0q0_wb_size_mean", "p0q0_wb_size_max",
                "p0q0_timeout_flushes"):
        assert key in a.extras
    assert a.extras["p0q0_writebacks"] > 0
    assert a.extras["p0q0_wb_size_mean"] > 0


def test_dca_msb_mode_timers_fire_without_explicit_sched():
    """MSB trials build fresh testbeds behind a factory and call run_sim
    without a scheduler argument — the loadgen must discover the ports'
    attached EventScheduler or idle caches would strand packets as drops."""
    cfg = ExperimentConfig(
        name="dca-msb",
        ports=(PortConfig(ring_size=2048),),
        stack=StackConfig(kind="bypass", n_lcores=1),
        traffic=TrafficConfig(mode="msb", packet_size=1518, start_gbps=1.0,
                              max_gbps=8.0, trial_s=0.001, refine_iters=2),
        dca=DcaConfig(burst_size=32, writeback_threshold=32,
                      writeback_timeout_ns=100_000))
    rep = run_experiment(cfg)
    assert rep.extras["msb_gbps"] > 0


# -- satellite: accumulate-then-forward on the pipeline stack ------------------

def test_pipeline_dca_accumulate_moves_rtt_percentiles():
    """The Fig. 4 accumulation semantics are stack-generic: the pipeline
    RX stage holds partial bursts behind the same give-up deadline the
    bypass PMD uses, so sweeping the DCA burst moves measured percentiles
    through run_experiment with kind='pipeline' too — and the deadline
    still forwards the end-of-train tail (no losses)."""
    r32 = run_experiment(_single_host_cfg(32, kind="pipeline"))
    r1024 = run_experiment(_single_host_cfg(1024, kind="pipeline"))
    for rep in (r32, r1024):
        assert rep.received == rep.sent > 1000
    assert r1024.latency.p99_ns > 2 * r32.latency.p99_ns
    assert r1024.latency.median_ns > r32.latency.median_ns
    again = run_experiment(_single_host_cfg(1024, kind="pipeline"))
    assert again.summary() == r1024.summary()


# -- satellite: writeback DMA latency ------------------------------------------

def test_writeback_dma_defers_publication_by_exactly_dma_ns():
    """With ``writeback_dma_ns`` armed, a threshold crossing *starts* a DMA:
    descriptors become PMD-visible ``dma_ns`` later as a scheduler event.
    At 0 (the default) the crossing publishes synchronously — the legacy
    behaviour, with no scheduler traffic at all."""
    sched = EventScheduler(SimClock())
    legacy = RxDescriptorRing(64, writeback_threshold=4)
    legacy.attach_scheduler(sched, timeout_ns=5_000)  # dma defaults to 0
    for i in range(4):
        legacy.nic_deliver(i, 100)
    assert legacy.done_count == 4 and len(sched) == 0

    ring = RxDescriptorRing(64, writeback_threshold=4)
    ring.attach_scheduler(sched, timeout_ns=5_000, writeback_dma_ns=700)
    for i in range(4):
        ring.nic_deliver(i, 100)
    assert ring.done_count == 0          # in DMA flight, not yet visible
    assert sched.next_time_ns() == 700
    sched.run_until(700)
    assert ring.done_count == 4


def test_writeback_dma_config_round_trips_and_validates():
    dca = DcaConfig(burst_size=64, writeback_dma_ns=750)
    assert DcaConfig.from_dict(dca.to_dict()) == dca
    cfg = ExperimentConfig(name="dma", dca=dca)
    via_json = ExperimentConfig.from_dict(json.loads(json.dumps(cfg.to_dict())))
    assert via_json == cfg
    with pytest.raises(ValueError, match="writeback_dma_ns"):
        DcaConfig(writeback_dma_ns=-1)
    with pytest.raises(ValueError, match="writeback_dma_ns"):
        RxDescriptorRing(64).attach_scheduler(
            EventScheduler(SimClock()), timeout_ns=1_000, writeback_dma_ns=-5)


def test_writeback_dma_latency_shifts_measured_percentiles():
    """A non-zero DMA latency sits on every completion's critical path, so
    it shifts the whole measured RTT distribution upward at the same offered
    rate — and the run still quiesces loss-free and deterministically."""
    base = run_experiment(_single_host_cfg(32, dma_ns=0))
    dma = run_experiment(_single_host_cfg(32, dma_ns=20_000))
    assert dma.received == dma.sent == base.sent
    assert dma.latency.median_ns > base.latency.median_ns
    assert dma.latency.p99_ns > base.latency.p99_ns
    again = run_experiment(_single_host_cfg(32, dma_ns=20_000))
    assert again.summary() == dma.summary()
    assert again.latency.as_dict() == dma.latency.as_dict()


# -- topology: the same knobs under run_topology_experiment --------------------

def _topo_cfg(burst: int) -> TopologyConfig:
    return TopologyConfig(
        name=f"dca-topo-{burst}",
        nodes=(NodeConfig(name="server", port=PortConfig(ring_size=2048),
                          stack=StackConfig(kind="bypass"),
                          dca=DcaConfig(burst_size=burst,
                                        writeback_threshold=32,
                                        writeback_timeout_ns=200_000)),),
        n_clients=2,
        client_pool=PoolConfig(n_slots=4096),
        switch=SwitchConfig(egress_capacity=256,
                            link=LinkConfig(gbps=100.0, latency_ns=1000)),
        traffic=TrafficConfig(mode="open_loop", rate_gbps=5.0,
                              packet_size=1518, duration_s=0.002, seed=11))


def test_topology_dca_burst_moves_rtt_and_stays_deterministic():
    # NOTE: long enough that the end-of-train tail (which waits out the
    # give-up deadline under EVERY burst size) stays below the p99 cutoff
    # for burst 32; the signal measured is steady-state accumulation.
    r32 = run_topology_experiment(_topo_cfg(32))
    r1024 = run_topology_experiment(_topo_cfg(1024))
    assert r32.received == r32.sent > 1000
    assert r1024.received == r1024.sent
    assert r1024.latency.p99_ns > 2 * r32.latency.p99_ns
    assert r1024.latency.median_ns > 2 * r32.latency.median_ns
    again = run_topology_experiment(_topo_cfg(1024))
    assert again.summary() == r1024.summary()
    assert r1024.extras["n0_p0q0_writebacks"] > 0
    assert r1024.extras["n0_p0q0_timeout_flushes"] >= 1
