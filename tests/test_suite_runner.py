"""Parallel sweep runner + content-derived seeding (PR 8 satellites).

The determinism contract: a suite's merged JSON artifact is a pure function
of its trial definitions — submission order, worker count, and cache state
must all be invisible in the bytes.  That only holds because every RNG seed
derives from config *content* (``repro.exp.seeding``), so these tests pin
the two layers together.
"""
import json
import random

import pytest

from benchmarks import suite
from benchmarks.common import experiment_config
from benchmarks.run import needs_csv_header, select_sections
from repro.exp import (TrafficConfig, config_fingerprint, derive_seed)
from repro.exp.seeding import scrub_execution_keys


def _trials(n_rates=2):
    base = experiment_config(
        "bypass",
        traffic=TrafficConfig(mode="open_loop", rate_gbps=1.0,
                              duration_s=0.0001, packet_size=256,
                              sim_time=True),
        name="mini").to_dict()
    return suite.expand_grid("mini", "experiment", base, [
        ("traffic.rate_gbps", [0.5, 1.0][:n_rates]),
        ("traffic.packet_size", [256, 512]),
    ])


def _dumps(merged):
    return json.dumps(merged, sort_keys=True)


# -- runner determinism --------------------------------------------------------

def test_shuffled_submission_is_byte_identical():
    """Satellite regression: submitting trials in a shuffled order yields a
    byte-identical merged artifact (ordering lives in trial definitions, and
    nothing wall-clock-dependent leaks in)."""
    trials = _trials()
    ref, _ = suite.run_suite(trials)
    for seed in (1, 2):
        order = list(range(len(trials)))
        random.Random(seed).shuffle(order)
        shuffled, _ = suite.run_suite(trials, submit_order=order)
        assert _dumps(shuffled) == _dumps(ref)


def test_worker_pool_is_byte_identical():
    trials = _trials()
    serial, _ = suite.run_suite(trials, workers=1)
    parallel, t = suite.run_suite(trials, workers=2)
    assert _dumps(parallel) == _dumps(serial)
    assert t["workers"] == 2 and t["n_trials"] == len(trials)


def test_cache_round_trip(tmp_path):
    trials = _trials()
    cold, t_cold = suite.run_suite(trials, cache_dir=str(tmp_path))
    warm, t_warm = suite.run_suite(trials, cache_dir=str(tmp_path))
    assert t_cold["n_cache_hits"] == 0
    assert t_warm["n_cache_hits"] == len(trials)
    assert _dumps(warm) == _dumps(cold)


def test_cache_key_tracks_config_content(tmp_path):
    t1 = _trials()[0]
    bumped = suite.Trial(name=t1.name, kind=t1.kind,
                         config={**t1.config,
                                 "traffic": {**t1.config["traffic"],
                                             "seed": 999}})
    assert suite.trial_key(t1) != suite.trial_key(bumped)
    assert suite.trial_key(t1) == suite.trial_key(
        suite.Trial(name="other-name-same-config", kind=t1.kind,
                    config=json.loads(json.dumps(t1.config))))


def test_write_suite_json_is_stable(tmp_path):
    trials = _trials()
    merged, _ = suite.run_suite(trials)
    p1, p2 = tmp_path / "a.json", tmp_path / "b.json"
    suite.write_suite_json(str(p1), merged)
    order = list(range(len(trials)))
    random.Random(9).shuffle(order)
    merged2, _ = suite.run_suite(trials, submit_order=order)
    suite.write_suite_json(str(p2), merged2)
    assert p1.read_bytes() == p2.read_bytes()


def test_grid_expansion_shapes_and_errors():
    trials = _trials()
    assert [t.name for t in trials] == [
        "mini/rate_gbps=0.5,packet_size=256",
        "mini/rate_gbps=0.5,packet_size=512",
        "mini/rate_gbps=1.0,packet_size=256",
        "mini/rate_gbps=1.0,packet_size=512",
    ]
    assert all(t.config["name"] == t.name for t in trials)
    assert trials[1].config["traffic"]["packet_size"] == 512
    with pytest.raises(KeyError):
        suite.expand_grid("bad", "experiment", trials[0].config,
                          [("traffic.no_such_knob", [1])])
    with pytest.raises(ValueError):
        suite.expand_grid("bad", "nonsense-kind", trials[0].config, [])
    with pytest.raises(ValueError):
        suite.run_suite(trials, submit_order=[0, 0, 1, 2])


def test_replicates_reseed_stably():
    t = _trials()[0]
    reps = suite.with_replicates([t], 3)
    assert [r.name for r in reps] == [f"{t.name}@r{i}" for i in range(3)]
    seeds = [r.config["traffic"]["seed"] for r in reps]
    assert len(set(seeds)) == 3
    # derived, not positional: same trial content → same replicate seeds
    again = suite.with_replicates([t], 3)
    assert [r.config["traffic"]["seed"] for r in again] == seeds


# -- seeding -------------------------------------------------------------------

def test_fingerprint_scrubs_execution_only_knobs():
    cfg = {"name": "a", "partition": "partitioned", "partition_workers": 4,
           "traffic": {"seed": 7, "engine": "epoch", "rate_gbps": 1.0},
           "nodes": [{"name": "srv"}]}
    scrubbed = scrub_execution_keys(cfg)
    assert "partition" not in scrubbed and "name" not in scrubbed
    assert "engine" not in scrubbed["traffic"]
    assert scrubbed["traffic"]["seed"] == 7  # physics knobs stay
    twin = dict(cfg, name="b", partition="shared-clock", partition_workers=0)
    assert config_fingerprint(cfg) == config_fingerprint(twin)
    assert config_fingerprint(cfg) != config_fingerprint(
        {**cfg, "traffic": {**cfg["traffic"], "seed": 8}})


def test_derive_seed_is_stable_and_decorrelated():
    fp = config_fingerprint({"x": 1})
    assert derive_seed(fp, 0, "client") == derive_seed(fp, 0, "client")
    assert derive_seed(fp, 0, "client") != derive_seed(fp, 1, "client")
    assert derive_seed(fp, 0, "client") != derive_seed(fp, 0, "replicate")
    s = derive_seed(fp, 3, "client")
    assert 0 <= s < 2 ** 63  # numpy and random.Random both accept it


# -- run.py section plumbing (satellite: no stray CSV header) ------------------

SECTIONS = [("fig3a", "csv", None), ("fastpath", "text", None),
            ("parallel", "text", None)]


def test_select_sections():
    assert [s[0] for s in select_sections(SECTIONS, None)] == \
        ["fig3a", "fastpath", "parallel"]
    assert [s[0] for s in select_sections(SECTIONS, "fastpath")] == \
        ["fastpath"]
    assert select_sections(SECTIONS, "nope") == []


def test_csv_header_only_for_csv_sections():
    assert needs_csv_header(select_sections(SECTIONS, None))
    assert needs_csv_header(select_sections(SECTIONS, "fig3a"))
    assert not needs_csv_header(select_sections(SECTIONS, "fastpath"))
    assert not needs_csv_header(select_sections(SECTIONS, "parallel"))
    assert not needs_csv_header([])
