"""Per-kernel validation: Pallas (interpret=True) and chunked-jnp vs ref.py
oracles, swept over shapes and dtypes."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.burst_gather import burst_gather_pallas
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rglru_scan import rglru_scan_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas

RNG = np.random.default_rng(0)


def _qkv(B, S, H, Hkv, Dh, dtype):
    q = jnp.asarray(RNG.normal(size=(B, S, H, Dh)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, S, Hkv, Dh)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, S, Hkv, Dh)), dtype)
    return q, k, v


ATTN_SWEEP = [
    # B, S, H, Hkv, Dh, causal, window
    (1, 128, 2, 1, 64, True, 0),
    (2, 256, 4, 2, 32, True, 0),
    (1, 256, 2, 2, 64, False, 0),     # bidirectional (encoder)
    (1, 384, 2, 1, 32, True, 128),    # sliding window
    (2, 128, 8, 2, 16, True, 0),      # deep GQA group
]


@pytest.mark.parametrize("B,S,H,Hkv,Dh,causal,window", ATTN_SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_pallas_vs_ref(B, S, H, Hkv, Dh, causal, window, dtype):
    q, k, v = _qkv(B, S, H, Hkv, Dh, dtype)
    got = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 blk_q=128, blk_k=128, interpret=True)
    want = ref.mha(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.array(got, np.float32),
                               np.array(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("B,S,H,Hkv,Dh,causal,window", ATTN_SWEEP)
def test_chunked_attention_vs_ref(B, S, H, Hkv, Dh, causal, window):
    q, k, v = _qkv(B, S, H, Hkv, Dh, jnp.float32)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              impl="chunked", q_chunk=64)
    want = ref.mha(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_chunked_attention_ragged_seq():
    """Non-chunk-multiple sequence lengths must pad/unpad correctly."""
    q, k, v = _qkv(1, 100, 2, 1, 16, jnp.float32)
    got = ops.flash_attention(q, k, v, impl="chunked", q_chunk=32)
    want = ref.mha(q, k, v)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,S,H,Hkv,Dh", [(2, 512, 4, 2, 64), (1, 300, 2, 1, 32),
                                          (3, 128, 6, 3, 16)])
def test_decode_attention_pallas_vs_ref(B, S, H, Hkv, Dh):
    q = jnp.asarray(RNG.normal(size=(B, H, Dh)), jnp.float32)
    kc = jnp.asarray(RNG.normal(size=(B, S, Hkv, Dh)), jnp.float32)
    vc = jnp.asarray(RNG.normal(size=(B, S, Hkv, Dh)), jnp.float32)
    cl = jnp.asarray(RNG.integers(1, S + 1, size=(B,)), jnp.int32)
    got = decode_attention_pallas(q, kc, vc, cl, blk_k=128, interpret=True)
    want = ref.decode_attention(q, kc, vc, cl)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,S,W,blk_s,blk_w", [(2, 256, 512, 64, 128),
                                               (1, 128, 256, 128, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_pallas_vs_ref(B, S, W, blk_s, blk_w, dtype):
    x = jnp.asarray(RNG.normal(size=(B, S, W)), dtype)
    al = jnp.asarray(-np.abs(RNG.normal(size=(B, S, W))) * 0.5, jnp.float32)
    y, hl = rglru_scan_pallas(x, al, blk_s=blk_s, blk_w=blk_w, interpret=True)
    want = ref.rglru(x, al)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.array(y, np.float32),
                               np.array(want, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.array(hl, np.float32),
                               np.array(want[:, -1], np.float32),
                               atol=tol, rtol=tol)


def test_rglru_assoc_scan_with_h0():
    """Carried-state path: scan(x[:half]) then scan(x[half:], h0) == scan(x)."""
    B, S, W = 2, 64, 32
    x = jnp.asarray(RNG.normal(size=(B, S, W)), jnp.float32)
    al = jnp.asarray(-np.abs(RNG.normal(size=(B, S, W))) * 0.5, jnp.float32)
    full, _ = ops.rglru_scan(x, al, impl="chunked")
    h1, hf1 = ops.rglru_scan(x[:, :32], al[:, :32], impl="chunked")
    h2, _ = ops.rglru_scan(x[:, 32:], al[:, 32:], h0=hf1, impl="chunked")
    np.testing.assert_allclose(h2, full[:, 32:], atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("B,S,H,P,N,chunk", [(2, 128, 4, 16, 32, 32),
                                             (1, 256, 2, 8, 16, 64)])
def test_ssd_pallas_vs_ref(B, S, H, P, N, chunk):
    x = jnp.asarray(RNG.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(np.abs(RNG.normal(size=(B, S, H))) * 0.3 + 0.01, jnp.float32)
    A = jnp.asarray(-np.abs(RNG.normal(size=(H,))) - 0.1, jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    y, hf = ssd_scan_pallas(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    want = ref.ssd(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(y, want, atol=5e-4, rtol=5e-4)


def test_ssd_chunked_vs_ref_and_state_handoff():
    B, S, H, P, N = 2, 96, 3, 8, 16
    x = jnp.asarray(RNG.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(np.abs(RNG.normal(size=(B, S, H))) * 0.3 + 0.01, jnp.float32)
    A = jnp.asarray(-np.abs(RNG.normal(size=(H,))) - 0.1, jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    y, hf = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=32, impl="chunked")
    want = ref.ssd(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(y, want, atol=5e-4, rtol=5e-4)
    # decode continuation from final state matches a longer ref scan
    y1, h1 = ops.ssd_decode_step(x[:, 0], dt[:, 0], A, Bm[:, 0], Cm[:, 0],
                                 jnp.zeros((B, H, P, N)))
    np.testing.assert_allclose(y1, want[:, 0], atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("n,slot_size,width", [(16, 256, 256), (8, 128, 300),
                                               (32, 64, 32)])
def test_burst_gather_pallas_vs_ref(n, slot_size, width):
    arena = jnp.asarray(RNG.integers(0, 256, size=(64, slot_size)), jnp.uint8)
    slots = jnp.asarray(RNG.permutation(64)[:n], jnp.int32)
    lens = jnp.asarray(RNG.integers(1, slot_size, size=(n,)), jnp.int32)
    got = burst_gather_pallas(arena, slots, lens, width, interpret=True)
    want = ref.burst_gather(arena, slots, lens, width)
    assert (np.array(got) == np.array(want)).all()


def test_attention_grad_paths():
    """Backward through the chunked path stays finite (remat inside scan)."""
    q, k, v = _qkv(1, 64, 2, 1, 16, jnp.float32)
    g = jax.grad(lambda q: ops.flash_attention(
        q, k, v, impl="chunked", q_chunk=32).sum())(q)
    assert np.isfinite(np.array(g)).all()


@pytest.mark.parametrize("B,S,H,Hkv,Dh,chunk",
                         [(2, 128, 4, 2, 16, 32), (1, 96, 6, 3, 8, 32),
                          (1, 100, 2, 1, 8, 16)])
def test_paired_causal_attention_vs_ref(B, S, H, Hkv, Dh, chunk):
    """Exact-flops pair-scheduled causal attention (EXPERIMENTS §Perf iter 6),
    including ragged sequence lengths and GQA."""
    q, k, v = _qkv(B, S, H, Hkv, Dh, jnp.float32)
    got = ops._paired_causal_attention(q, k, v, scale=Dh ** -0.5, chunk=chunk)
    want = ref.mha(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-5)


def test_paired_attention_halves_flops():
    """The pair schedule must lower ~(n+1)/2n of the rectangle's dot flops."""
    import os
    from repro.parallel.hlo_counter import analyze
    q = jax.ShapeDtypeStruct((1, 1024, 2, 16), jnp.float32)
    k = jax.ShapeDtypeStruct((1, 1024, 2, 16), jnp.float32)
    paired = jax.jit(lambda q, k, v: ops.flash_attention(
        q, k, v, causal=True, impl="chunked", q_chunk=128))
    c1 = analyze(paired.lower(q, k, k).compile().as_text())
    os.environ["REPRO_NO_PAIRED"] = "1"
    try:
        full = jax.jit(lambda q, k, v: ops.flash_attention(
            q, k, v, causal=True, impl="chunked", q_chunk=127))
        c2 = analyze(full.lower(q, k, k).compile().as_text())
    finally:
        del os.environ["REPRO_NO_PAIRED"]
    ratio = c1.dot_flops / c2.dot_flops
    assert 0.4 < ratio < 0.65, ratio
