"""HLO counter + partition-spec machinery tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.models import lm, registry
from repro.parallel import hlo_counter
from repro.parallel.axes import single_pod_rules
from repro.parallel.specs import (make_param_specs, param_logical_axes,
                                  sanitize_spec)


def test_hlo_counter_scan_trip_multiplication():
    """A matmul inside a lax.scan of length N must count N× the flops."""
    N, M = 12, 64

    def f(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=N)
        return out

    x = jax.ShapeDtypeStruct((M, M), jnp.float32)
    w = jax.ShapeDtypeStruct((M, M), jnp.float32)
    compiled = jax.jit(f).lower(x, w).compile()
    cost = hlo_counter.analyze(compiled.as_text())
    want = 2.0 * M * M * M * N
    assert abs(cost.dot_flops - want) / want < 0.05, (cost.dot_flops, want)
    assert cost.max_trip == N


def test_hlo_counter_plain_matmul():
    M, K, Nn = 32, 48, 64
    f = lambda a, b: a @ b
    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((M, K), jnp.float32),
        jax.ShapeDtypeStruct((K, Nn), jnp.float32)).compile()
    cost = hlo_counter.analyze(compiled.as_text())
    want = 2.0 * M * K * Nn
    assert abs(cost.dot_flops - want) / want < 0.01


@pytest.mark.parametrize("arch", list(registry.ARCHS))
def test_partition_rules_cover_every_param(arch):
    """Every leaf of every architecture's param tree must match a rule."""
    cfg = registry.get_smoke_config(arch)
    params = jax.eval_shape(
        lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
    axes = param_logical_axes(params)  # raises on uncovered leaf rank > 1
    n = len(jax.tree_util.tree_leaves(params))
    # axes leaves are tuples → count via params structure
    assert n > 0


def test_sanitize_spec_divisibility():
    import numpy as np
    from jax.sharding import Mesh

    from repro.launch.mesh import auto_axis_types_kw
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(devs, ("data", "model"), **auto_axis_types_kw(2))
    # 1-sized axes always divide
    assert sanitize_spec(P("data", None), (8, 4), mesh) == P("data", None)

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    fm = FakeMesh()
    assert sanitize_spec(P("model", "data"), (24, 32), fm) == P(None, "data")
    assert sanitize_spec(P(("data", "model"), None), (256, 8), fm) == \
        P(("data", "model"), None)
    assert sanitize_spec(P(("data", "model"), None), (128, 8), fm) == \
        P(None, None)
    assert sanitize_spec(P("data"), (1,), fm) == P(None)


def test_model_flops_formula():
    from repro.parallel.hlo_analysis import model_flops_for_step
    cfg = registry.get_config("qwen3-1.7b")
    n = cfg.param_count()
    f_train = model_flops_for_step(cfg, "train", 4096, 256)
    assert abs(f_train - 6 * n * 4096 * 256) / f_train < 1e-9
    f_dec = model_flops_for_step(cfg, "decode", 32768, 128)
    assert abs(f_dec - 2 * n * 128) / f_dec < 1e-9
    # MoE uses active params
    moe = registry.get_config("mixtral-8x7b")
    assert moe.active_param_count() < 0.45 * moe.param_count()
