"""PartitionSanitizer: the always-available causality race detector (PR 9).

``tests/test_partition_property.py`` proves the conservative-bound property
only when hypothesis is installed; the sanitizer promotes it into a runtime
check every environment can run.  Contract under test: with sanitization on,
(a) every legal crossing passes and reports stay bit-identical to the
shared-clock loop, (b) any crossing delivered before its link-latency bound,
behind its destination clock, or out of (fire_t, birth) order raises
``CausalityError``, and (c) the ``partition_sanitize`` knob is execution-only
— it must never perturb derived seeds or report content.
"""
import numpy as np
import pytest

from repro.core import CausalityError, PartitionRunInfo, PartitionSanitizer
from repro.core.partition import PartitionEngine
from repro.exp import (LinkConfig, NodeConfig, PoolConfig, PortConfig,
                       StackConfig, SwitchConfig, TopologyConfig,
                       TrafficConfig, run_partitioned_topology,
                       run_topology_experiment)
from repro.exp.seeding import config_fingerprint
from repro.exp.topology import _build_domain


def _topology(latency_ns=1000, link_gbps=100.0, n_clients=2):
    return TopologyConfig(
        name="sanitize",
        nodes=(NodeConfig(name="srv",
                          pool=PoolConfig(n_slots=8192, slot_size=2048),
                          port=PortConfig(n_queues=1, ring_size=512,
                                          writeback_threshold=1),
                          stack=StackConfig(kind="bypass", burst_size=32)),),
        n_clients=n_clients,
        switch=SwitchConfig(egress_capacity=64,
                            link=LinkConfig(gbps=link_gbps,
                                            latency_ns=latency_ns)),
        traffic=TrafficConfig(mode="open_loop", rate_gbps=2.0,
                              duration_s=0.0002, packet_size=256,
                              kind="poisson", seed=7, sim_time=True))


FRAME = np.zeros(64, dtype=np.uint8)


# -- direct invariant checks ---------------------------------------------------

def test_bound_violation_raises():
    san = PartitionSanitizer(latency_ns=1000)
    # born at t=0, fires at 999 < 0 + 0 + 1000: impossible on a 1000ns link
    with pytest.raises(CausalityError, match="conservative bound"):
        san.check((0, 999, (0, 0, 0, 0), "deliver", FRAME))


def test_bound_includes_serialization_term():
    # 64B at 1 Gbps == 512 ns on the wire; latency 1000 → bound 1512
    san = PartitionSanitizer(latency_ns=1000, gbps=1.0)
    with pytest.raises(CausalityError, match="conservative bound"):
        san.check((0, 1511, (0, 0, 0, 0), "deliver", FRAME))
    san.check((0, 1512, (0, 0, 0, 0), "deliver", FRAME))  # exactly legal


def test_fwd_payload_frame_length_is_used():
    # fwd payload is (in_port, frame); the frame's length drives the bound
    san = PartitionSanitizer(latency_ns=100, gbps=1.0)
    with pytest.raises(CausalityError):
        san.check((0, 200, (0, 0, 0, 0), "fwd", (3, FRAME)))
    san.check((0, 612, (0, 0, 0, 0), "fwd", (3, FRAME)))


def test_destination_clock_violation_raises():
    san = PartitionSanitizer(latency_ns=10)
    with pytest.raises(CausalityError, match="destination clock"):
        san.check((0, 50, (0, 0, 0, 0), "deliver", FRAME), dst_clock_ns=60)


def test_out_of_order_delivery_raises():
    san = PartitionSanitizer(latency_ns=10)
    san.check((0, 100, (50, 0, 0, 0), "deliver", FRAME))
    # same destination, strictly smaller (fire_t, birth) key
    with pytest.raises(CausalityError, match="out of order"):
        san.check((0, 90, (40, 0, 0, 0), "deliver", FRAME))


def test_order_is_tracked_per_destination():
    san = PartitionSanitizer(latency_ns=10)
    san.check((0, 100, (50, 0, 0, 0), "deliver", FRAME))
    san.check((1, 90, (40, 0, 0, 0), "deliver", FRAME))  # other dst: fine
    assert san.checked == 2


# -- engine integration --------------------------------------------------------

def test_engine_raises_on_injected_early_crossing():
    """A crossing smuggled into the boundary stream with an impossible
    (birth, fire_t) pair must kill the run, not corrupt it."""
    cfg = _topology()
    delta = cfg.switch.link.latency_ns
    outbox = []
    n_domains = cfg.n_clients + len(cfg.nodes) + 1
    domains = [_build_domain(cfg, i, outbox) for i in range(n_domains)]
    # born far in the virtual future yet firing at t=0: a causality race
    outbox.append((0, 0, (10 ** 15, 0, 0, 0), "deliver", FRAME.copy()))
    eng = PartitionEngine(domains, delta, outbox,
                          sanitizer=PartitionSanitizer(
                              delta, gbps=cfg.switch.link.gbps))
    with pytest.raises(CausalityError):
        eng.run()


def test_engine_without_sanitizer_does_not_check():
    cfg = _topology()
    outbox = []
    n_domains = cfg.n_clients + len(cfg.nodes) + 1
    domains = [_build_domain(cfg, i, outbox) for i in range(n_domains)]
    eng = PartitionEngine(domains, cfg.switch.link.latency_ns, outbox)
    eng.run()  # legal run, no sanitizer: nothing raises
    assert eng.n_windows > 0


def test_parity_holds_with_sanitizer_enabled():
    """The sanitizer observes, never perturbs: reports stay bit-identical to
    the shared-clock loop and every crossing is checked."""
    cfg = _topology()
    base = run_topology_experiment(cfg).to_dict()
    info = PartitionRunInfo()
    got = run_partitioned_topology(
        cfg.with_partition("partitioned", sanitize=True), info=info).to_dict()
    assert info.mode_used == "partitioned", info.fallback_reason
    assert info.n_sanitized > 0
    assert got == base


def test_mp_parity_with_env_flag(monkeypatch):
    monkeypatch.setenv("REPRO_PARTITION_SANITIZE", "1")
    cfg = _topology()
    base = run_topology_experiment(cfg).to_dict()
    info = PartitionRunInfo()
    got = run_partitioned_topology(
        cfg.with_partition("partitioned-mp", workers=2), info=info).to_dict()
    assert info.mode_used == "partitioned-mp", info.fallback_reason
    assert info.n_sanitized > 0
    assert got == base


def test_env_flag_off_values(monkeypatch):
    from repro.exp.topology import _sanitize_enabled
    cfg = _topology()
    monkeypatch.delenv("REPRO_PARTITION_SANITIZE", raising=False)
    assert not _sanitize_enabled(cfg)
    monkeypatch.setenv("REPRO_PARTITION_SANITIZE", "0")
    assert not _sanitize_enabled(cfg)
    monkeypatch.setenv("REPRO_PARTITION_SANITIZE", "1")
    assert _sanitize_enabled(cfg)


# -- execution-only contract ---------------------------------------------------

def test_sanitize_flag_is_execution_only():
    """partition_sanitize must not perturb the config fingerprint (and so no
    derived per-client seed), exactly like partition/partition_workers."""
    cfg = _topology()
    on = cfg.with_partition("partitioned", sanitize=True)
    assert on.partition_sanitize is True
    assert cfg.partition_sanitize is False
    assert (config_fingerprint(cfg.to_dict())
            == config_fingerprint(on.to_dict()))
    # ...and it round-trips through to_dict/from_dict like any other field
    assert TopologyConfig.from_dict(on.to_dict()) == on
