"""End-to-end behaviour tests for the paper's system.

(1) the kernel-bypass claim itself: the bypass stack sustains strictly more
    bandwidth than the kernel stack on identical hardware/budget;
(2) the DCA burst-size use case: large bursts build deeper queues;
(3) the full trainer: bypass-fed training with checkpoint/restart resumes
    deterministically;
(4) dataplane semantics: bypass and kernel feeds deliver identical batches.
"""
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BypassL2FwdServer, KernelStackServer, LoadGen,
                        PacketPool, Port, SimClock, TrafficPattern,
                        run_burst_experiment)
from repro.core.cost import HostCostModel
from repro.core.dataplane import BypassDataplane, KernelStackFeed
from repro.data.pipeline import DataConfig, stream_factory
from repro.models.registry import get_smoke_config
from repro.runtime.trainer import TrainerConfig, TrainerRuntime


def _mk(kind: str, nports: int = 1):
    pool = PacketPool(8192, 1518)
    # ring small enough that a saturated stack overflows it within the run
    # (in virtual time the tail is always fully drained, so a huge ring
    # would just absorb the backlog instead of dropping)
    ports = [Port.make(pool, ring_size=256, link_gbps=100.0,
                       link_latency_ns=1000) for _ in range(nports)]
    if kind == "bypass":
        server = BypassL2FwdServer(ports, burst_size=64)
    else:
        server = KernelStackServer(ports)
    server.attach_clock(SimClock(), HostCostModel())
    return server, ports


def test_bypass_beats_kernel_stack():
    """The paper's headline: same offered load, kernel stack saturates and
    drops while the bypass stack keeps up (or achieves strictly more)."""
    rate = 6.0  # Gbps — above the kernel stack's modeled capacity (~3.7)
    srv_b, ports_b = _mk("bypass")
    rep_b = LoadGen(ports_b).run_sim(srv_b, TrafficPattern(rate_gbps=rate,
                                                           packet_size=1518),
                                     duration_s=0.005)
    srv_k, ports_k = _mk("kernel")
    rep_k = LoadGen(ports_k).run_sim(srv_k, TrafficPattern(rate_gbps=rate,
                                                           packet_size=1518),
                                     duration_s=0.005)
    assert rep_b.achieved_gbps > rep_k.achieved_gbps
    assert rep_b.drop_pct <= rep_k.drop_pct
    assert rep_k.dropped > 0  # the kernel stack really saturated


def test_kernel_stack_does_more_work_per_packet():
    srv_b, ports_b = _mk("bypass")
    LoadGen(ports_b).run_sim(srv_b, TrafficPattern(rate_gbps=0.1,
                                                   packet_size=512),
                             duration_s=0.05)
    srv_k, ports_k = _mk("kernel")
    LoadGen(ports_k).run_sim(srv_k, TrafficPattern(rate_gbps=0.1,
                                                   packet_size=512),
                             duration_s=0.05)
    # bypass: zero copies & allocations; kernel: ≥3 copies per packet,
    # ≥1 syscall per packet (sendto) + batched read()s, ≥2 allocs per packet
    assert srv_k.stats.copies >= 3 * srv_k.stats.rx_packets
    assert srv_k.stats.syscalls >= srv_k.stats.rx_packets
    assert srv_k.stats.allocs >= 2 * srv_k.stats.rx_packets
    assert srv_k.stats.interrupts > 0
    assert srv_b.stats.rx_packets > 0  # and no copy counters even exist


def test_dca_burst_size_queue_pressure():
    """Paper Fig. 4: processing in bursts of 32 keeps the staging queue
    shallow; waiting for the whole 1024-packet train floods it."""
    tr32, d32 = run_burst_experiment(1024, 32)
    tr1024, d1024 = run_burst_experiment(1024, 1024)
    assert tr32.high_water < tr1024.high_water
    assert tr32.mean < tr1024.mean
    assert d32[d32 >= 0].mean() < d1024[d1024 >= 0].mean()


def test_feeds_deliver_identical_batches():
    cfg = get_smoke_config("qwen3-1.7b")
    dcfg = DataConfig(seq_len=16, global_batch=4, seed=9)
    kf = KernelStackFeed(stream_factory(cfg, dcfg, n_steps=3)(0, 1))
    bp = BypassDataplane(stream_factory(cfg, dcfg, n_steps=3), depth=2, ports=1)
    try:
        for _ in range(3):
            a = kf.next_batch()
            b = bp.next_batch()
            for ka in a:
                np.testing.assert_array_equal(np.asarray(a[ka]),
                                              np.asarray(b[ka]))
        assert bp.next_batch() is None  # clean end of stream
    finally:
        bp.stop()


def test_multiport_feed_covers_global_batch():
    cfg = get_smoke_config("qwen3-1.7b")
    dcfg = DataConfig(seq_len=16, global_batch=8, seed=4)
    bp = BypassDataplane(stream_factory(cfg, dcfg, n_steps=2), depth=2, ports=2)
    try:
        seen = [bp.next_batch() for _ in range(4)]  # 2 steps × 2 ports
        assert all(s is not None for s in seen)
        assert all(s["tokens"].shape == (4, 16) for s in seen)  # 8/2 ports
    finally:
        bp.stop()


@pytest.fixture
def no_jax_compilation_cache():
    """The persistent compilation cache aborts XLA:CPU on reloading the
    trainer's donated-buffer executables (jax 0.4.x limitation); compile
    fresh for this test and restore the cache afterwards."""
    old = jax.config.jax_compilation_cache_dir
    jax.config.update("jax_compilation_cache_dir", None)
    yield
    jax.config.update("jax_compilation_cache_dir", old)


@pytest.mark.slow  # wall-clock jax training loop (~10s); nightly/-m slow
def test_trainer_checkpoint_restart_determinism(tmp_path,
                                                no_jax_compilation_cache):
    cfg = get_smoke_config("qwen3-1.7b").replace(param_dtype="float32",
                                                 compute_dtype="float32")
    dcfg = DataConfig(seq_len=32, global_batch=2, seed=5)

    def losses_of(run_steps, ckpt_dir):
        t = TrainerRuntime(cfg, dcfg, TrainerConfig(
            steps=run_steps, ckpt_every=2, ckpt_dir=ckpt_dir, feed="bypass",
            log_every=1))
        t.run()
        return {m["step"]: m["loss"] for m in t.metrics_log}

    d1 = str(tmp_path / "a")
    full = losses_of(6, d1)
    # interrupted run: 4 steps, then resume to 6 in a fresh runtime
    d2 = str(tmp_path / "b")
    losses_of(4, d2)
    resumed = losses_of(6, d2)
    for s in (5, 6):
        assert abs(full[s] - resumed[s]) < 1e-4, \
            f"step {s}: {full[s]} vs {resumed[s]} — restart not deterministic"
