"""Partitioned-parallel topology execution (PR 8 tentpole).

The contract under test is HARD: for every partition-eligible topology
config, the partitioned engines (in-process domains and worker processes)
must produce a RunReport **bit-identical** to the shared-clock loop — same
counters, same latency percentiles, same extras, same histogram buckets.
Configs outside the proven-equivalent set must *refuse* (fall back to the
shared loop with a named reason), never approximate.
"""
import pytest

from repro.core import (PartitionRunInfo, Wire, assign_groups)
from repro.core.partition import DomainScheduler, _deliver_due
from repro.core.simclock import SimClock
from repro.exp import (CostConfig, DcaConfig, LinkConfig, NodeConfig,
                       PoolConfig, PortConfig, StackConfig, SwitchConfig,
                       TopologyConfig, TrafficConfig,
                       partition_fallback_reason, run_partitioned_topology,
                       run_topology_experiment)
from repro.exp.topology import Cluster


def _node(name="srv", kind="bypass", dca=None, n_queues=1, cost=None):
    return NodeConfig(name=name,
                      pool=PoolConfig(n_slots=8192, slot_size=2048),
                      port=PortConfig(n_queues=n_queues, ring_size=512,
                                      writeback_threshold=1),
                      stack=StackConfig(kind=kind, burst_size=32, cost=cost),
                      dca=dca)


def _topology(nodes=None, n_clients=2, rate_gbps=2.0, duration_s=0.0002,
              packet_size=256, kind="poisson", burst_len=1,
              egress_capacity=64, link_gbps=100.0, latency_ns=1000,
              client_targets=None, name="part"):
    return TopologyConfig(
        name=name,
        nodes=tuple(nodes) if nodes else (_node(),),
        n_clients=n_clients,
        client_targets=client_targets,
        switch=SwitchConfig(egress_capacity=egress_capacity,
                            link=LinkConfig(gbps=link_gbps,
                                            latency_ns=latency_ns)),
        traffic=TrafficConfig(mode="open_loop", rate_gbps=rate_gbps,
                              duration_s=duration_s, packet_size=packet_size,
                              kind=kind, burst_len=burst_len, seed=7,
                              sim_time=True))


# every topology shape the repo's test suite exercises, as a parity corpus
PARITY_CASES = {
    "bypass-2c": _topology(),
    "kernel": _topology(nodes=[_node(kind="kernel")], rate_gbps=1.0),
    "incast-drops": _topology(n_clients=6, rate_gbps=6.0, packet_size=512,
                              egress_capacity=8, link_gbps=10.0),
    "bursty-rss": _topology(nodes=[_node(n_queues=2)], kind="bursty",
                            burst_len=4, rate_gbps=3.0),
    "dca": _topology(nodes=[_node(dca=DcaConfig(burst_size=8,
                                                writeback_threshold=8,
                                                writeback_timeout_ns=5000))]),
    "multi-node-targets": _topology(
        nodes=[_node("a"), _node("b", kind="kernel")],
        n_clients=4, client_targets=("a", "b", "a", "b"), rate_gbps=1.5,
        packet_size=300),
    "slow-links": _topology(link_gbps=10.0, latency_ns=5000, rate_gbps=1.0),
}


def _run_pair(cfg, mode):
    base = run_topology_experiment(cfg).to_dict()
    info = PartitionRunInfo()
    got = run_topology_experiment(cfg.with_partition(mode, workers=2),
                                  partition_info=info).to_dict()
    return base, got, info


@pytest.mark.parametrize("case", sorted(PARITY_CASES))
def test_partitioned_bit_identical_to_shared_clock(case):
    """THE tentpole gate: domain-partitioned execution reproduces the shared
    loop's RunReport exactly, for every topology shape in the suite."""
    base, got, info = _run_pair(PARITY_CASES[case], "partitioned")
    assert info.mode_used == "partitioned", info.fallback_reason
    assert info.n_windows > 0
    assert got == base


def test_partitioned_mp_bit_identical_to_shared_clock():
    """Worker processes change nothing: crossings are delivered in
    (fire_time, birth) order regardless of which process minted them when."""
    cfg = PARITY_CASES["multi-node-targets"]
    base, got, info = _run_pair(cfg, "partitioned-mp")
    assert info.mode_used == "partitioned-mp", info.fallback_reason
    assert info.n_workers == 2
    assert got == base


def test_domain_count_does_not_change_results():
    """Satellite: {1, 2, N} execution groups on a 4-node incast produce the
    identical report — grouping is scheduling, not semantics."""
    cfg = _topology(nodes=[_node(f"n{i}") for i in range(4)], n_clients=4,
                    client_targets=("n0", "n1", "n2", "n3"))
    n_domains = cfg.n_clients + len(cfg.nodes) + 1
    runs = [run_partitioned_topology(cfg.with_partition("partitioned"),
                                     n_groups=g).to_dict()
            for g in (1, 2, n_domains)]
    assert runs[0] == runs[1] == runs[2]
    assert runs[0] == run_topology_experiment(cfg).to_dict()


def test_assign_groups_shapes():
    assert assign_groups(5, 1) == [[0, 1, 2, 3, 4]]
    groups = assign_groups(5, 3)
    assert groups[-1] == [4]  # the switch domain rides alone
    assert sorted(d for g in groups for d in g) == [0, 1, 2, 3, 4]
    assert assign_groups(3, 99)[-1] == [2]  # clamped to n_domains


def test_crossings_never_arrive_before_wire_time():
    """Conservative-window invariant, checked on the real crossing trace: a
    frame minted at t can reach its destination domain no earlier than the
    unloaded wire would carry it (serialization + link latency), so a window
    of min(link_latency) can never deliver into a domain's past."""
    cfg = PARITY_CASES["multi-node-targets"]
    trace = []
    run_partitioned_topology(cfg.with_partition("partitioned"), trace=trace)
    assert trace, "run produced no boundary crossings"
    link = cfg.switch.link
    for _dst, fire_t, birth, kind, payload in trace:
        frame = payload[1] if kind == "fwd" else payload
        mint_t = birth[0]
        unloaded = Wire(gbps=link.gbps,
                        latency_ns=link.latency_ns).transmit(mint_t,
                                                             len(frame))
        assert fire_t >= unloaded
        assert fire_t >= mint_t + link.latency_ns


def test_deliver_due_orders_by_fire_time_then_birth():
    a = (0, 100, (50, 0, 1, 0), "fwd", None)
    b = (0, 100, (50, 0, 0, 0), "fwd", None)
    c = (0, 90, (60, 2, 0, 0), "fwd", None)
    late = (0, 500, (50, 0, 0, 1), "fwd", None)
    due, rest = _deliver_due([a, late, b, c], 200)
    assert due == [c, b, a]
    assert rest == [late]


# -- fallback policy -----------------------------------------------------------

def test_zero_latency_link_falls_back():
    cfg = _topology(latency_ns=0).with_partition("partitioned")
    assert "lookahead" in partition_fallback_reason(cfg)
    info = PartitionRunInfo()
    rep = run_topology_experiment(cfg, partition_info=info)
    assert info.mode_used == "shared-clock"
    assert info.fallback_reason is not None
    assert rep.to_dict() == run_topology_experiment(
        cfg.with_partition("shared-clock")).to_dict()


def test_zero_cost_stack_falls_back():
    free = CostConfig(cpu_ghz=2.0, interrupt_cycles=0, syscall_cycles=0,
                      per_packet_kernel_cycles=0, pmd_poll_cycles=0,
                      pmd_per_packet_cycles=0)
    for kind in ("bypass", "kernel"):
        cfg = _topology(nodes=[_node(kind=kind, cost=free)])
        assert "zero-cost" in partition_fallback_reason(cfg)


def test_pipeline_stack_falls_back():
    cfg = _topology(nodes=[_node(kind="pipeline")])
    reason = partition_fallback_reason(cfg)
    assert "pipeline" in reason and "not proven" in reason
    info = PartitionRunInfo()
    rep = run_topology_experiment(cfg.with_partition("partitioned"),
                                  partition_info=info)
    assert info.mode_requested == "partitioned"
    assert info.mode_used == "shared-clock"
    assert rep.to_dict() == run_topology_experiment(cfg).to_dict()


def test_serving_falls_back():
    import repro.serving  # noqa: F401
    from repro.serving import RequestMixConfig, ServingConfig
    s = ServingConfig(mix=RequestMixConfig(prompt_mean_tokens=64,
                                           prompt_dist="fixed",
                                           output_mean_tokens=4,
                                           output_dist="fixed"),
                      qps=10_000.0, kv_bytes_per_token=256,
                      kv_segment_bytes=1024, balancer="lb",
                      prefill=("p0",), decode=("d0",))
    cfg = TopologyConfig(
        name="serving-part",
        nodes=(_node("lb", "balancer"), _node("p0", "prefill"),
               _node("d0", "decode")),
        n_clients=1,
        traffic=TrafficConfig(mode="open_loop", duration_s=0.0005,
                              sim_time=True, seed=3),
        serving=s)
    assert "balancer" in partition_fallback_reason(cfg)


def test_eligible_configs_have_no_reason():
    for case, cfg in PARITY_CASES.items():
        assert partition_fallback_reason(cfg) is None, case


# -- DomainScheduler mechanics -------------------------------------------------

def test_domain_scheduler_orders_by_birth_not_insertion():
    """Two events at one instant run in birth order even when scheduled in
    the opposite order — the property that makes worker scheduling
    invisible."""
    ds = DomainScheduler(SimClock())
    seen = []
    ds.schedule_with_birth(100, (50, 2, 1, 0), lambda: seen.append("late"))
    ds.schedule_with_birth(100, (50, 0, 0, 0), lambda: seen.append("early"))
    ds.run_until(100)
    assert seen == ["early", "late"]
    assert ds.clock.now_ns == 100


def test_domain_scheduler_children_sort_after_parent():
    ds = DomainScheduler(SimClock())
    seen = []

    def parent():
        ds.schedule_at(ds.clock.now_ns, lambda: seen.append("child"))
        seen.append("parent")

    ds.begin_phase(0, 0, 0)
    ds.schedule_at(10, parent)
    ds.schedule_at(10, lambda: seen.append("sibling"))
    ds.run_until(10)
    # the sibling was minted at t=0 (phase context), the child at t=10
    # (inside the parent's execution) — lexicographic birth order IS mint
    # order, so the earlier-born sibling runs before the child
    assert seen == ["parent", "sibling", "child"]


def test_domain_scheduler_phase_counter_persists_within_instant():
    ds = DomainScheduler(SimClock())
    ds.begin_phase(5, 0, 0)
    b1 = ds.mint_birth()
    ds.begin_phase(5, 0, 0)  # re-round at the same instant
    b2 = ds.mint_birth()
    assert b1 == (5, 0, 0, 0) and b2 == (5, 0, 0, 1)
    ds.begin_phase(6, 0, 0)  # new instant resets the counter
    assert ds.mint_birth() == (6, 0, 0, 0)


def test_domain_scheduler_cancel():
    ds = DomainScheduler(SimClock())
    seen = []
    tok = ds.schedule_at(10, lambda: seen.append("dead"))
    ds.schedule_at(10, lambda: seen.append("live"))
    assert ds.cancel(tok)
    assert not ds.cancel(tok)
    assert len(ds) == 1
    ds.run_until(20)
    assert seen == ["live"]
    assert ds.next_time_ns() is None


# -- engine composition (satellite: epoch taxonomy) ----------------------------

def test_partition_records_epoch_fallback_reason():
    """TrafficConfig.engine='epoch' composes with partitioned execution: the
    epoch fast path refuses with the documented reason, the partitioned
    event loop runs, and the report still matches shared-clock exactly."""
    from repro.core import EpochRunInfo, PARTITIONED_REASON
    from dataclasses import replace
    cfg = PARITY_CASES["bypass-2c"]
    cfg_epoch = replace(cfg, traffic=replace(cfg.traffic, engine="epoch"),
                        partition="partitioned")
    info = EpochRunInfo()
    rep = run_topology_experiment(cfg_epoch, info=info)
    assert info.fallback_reason == PARTITIONED_REASON
    assert info.fastpath is False
    assert rep.to_dict() == run_topology_experiment(cfg).to_dict()


def test_partition_knob_does_not_change_seeds():
    """Execution-only knobs are scrubbed from the seed fingerprint: the
    partition mode must not perturb which streams the clients draw."""
    cfg = PARITY_CASES["bypass-2c"]
    c1 = Cluster.build(cfg)
    c2 = Cluster.build(cfg.with_partition("partitioned-mp", workers=8))
    assert [c.seed for c in c1.clients] == [c.seed for c in c2.clients]
