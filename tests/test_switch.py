"""Multi-host Switch/Topology layer: switch units (LPM forwarding, FIFO
egress, bounded drop-tail buffers), TopologyConfig round-tripping, and the
headline scenario guarantees — bit-identical incast RunReports on one shared
SimClock, losses attributed to the switch egress buffer (never the NICs), and
an RTT tail that grows with client count."""
import json

import numpy as np
import pytest

from repro.core import EventScheduler, Switch
from repro.core.packet import MIN_FRAME, write_flow
from repro.exp import (LinkConfig, NodeConfig, PoolConfig, PortConfig,
                       StackConfig, SwitchConfig, TopologyConfig,
                       TrafficConfig, Cluster, run_topology_experiment)


def _frame(dst_ip: int, size: int = 1250) -> np.ndarray:
    buf = np.zeros(max(size, MIN_FRAME), dtype=np.uint8)
    write_flow(buf, 0x0A010001, dst_ip, 1024, 443)
    return buf


# -- switch units -------------------------------------------------------------

def test_switch_longest_prefix_match_routing():
    sw = Switch(3, EventScheduler(), gbps=0.0, latency_ns=0)
    sw.add_route(0x0A010000, 1, prefix_len=16)   # 10.1.0.0/16
    sw.add_route(0x0A010005, 2, prefix_len=32)   # 10.1.0.5/32 wins inside it
    assert sw.lookup(0x0A010005) == 2
    assert sw.lookup(0x0A017777) == 1
    assert sw.lookup(0x0B000001) is None


def test_switch_forwards_with_exact_wire_timing():
    """One frame, port 0 -> port 1: uplink serialization + propagation to
    reach the switch, then the same again on the egress side."""
    sched = EventScheduler()
    sw = Switch(2, sched, gbps=10.0, latency_ns=500)  # 1250B == 1000 ns
    out = []
    sw.attach(1, lambda frame, t: out.append((t, len(frame))))
    sw.add_route(0xC0A80001, 1)
    sw.send(0, _frame(0xC0A80001, 1250), t_ns=0)
    sched.run_all()
    # ingress arrival at 1500; egress serialization ends 2500, lands 3000
    assert out == [(3000, 1250)]
    assert sw.ports[0].rx_frames == 1
    assert sw.ports[1].tx_frames == 1
    assert sw.ports[1].occupancy == 0


def test_switch_unrouted_frames_counted():
    sched = EventScheduler()
    sw = Switch(2, sched, gbps=0.0, latency_ns=0)
    sw.send(0, _frame(0xDEADBEEF), t_ns=0)
    sched.run_all()
    assert sw.unrouted == 1
    assert sw.ports[0].rx_frames == 1
    assert sw.ports[1].tx_frames == 0


def test_switch_bounded_egress_buffer_drops_tail():
    """Two ingress ports converging on one egress at line rate: the egress
    drains at half the aggregate arrival rate, occupancy hits the cap, and
    the excess is dropped at the switch (drop-tail), FIFO preserved."""
    sched = EventScheduler()
    cap = 4
    sw = Switch(3, sched, gbps=10.0, latency_ns=0, egress_capacity=cap)
    out = []
    sw.attach(2, lambda frame, t: out.append(t))
    sw.add_route(0xC0A80001, 2)
    n_each = 10
    for i in range(n_each):  # back-to-back trains on both uplinks
        sw.send(0, _frame(0xC0A80001, 1250), t_ns=0)
        sw.send(1, _frame(0xC0A80001, 1250), t_ns=0)
    sched.run_all()
    port = sw.ports[2]
    assert port.egress_drops > 0
    assert port.egress_enqueued + port.egress_drops == 2 * n_each
    assert len(out) == port.tx_frames == port.egress_enqueued
    assert port.occ_high == cap
    assert port.occupancy == 0
    assert out == sorted(out)  # FIFO egress: non-decreasing arrivals


def test_switch_validates_arguments():
    sched = EventScheduler()
    with pytest.raises(ValueError):
        Switch(0, sched)
    sw = Switch(2, sched)
    with pytest.raises(ValueError):
        sw.add_route(1, 5)
    with pytest.raises(ValueError):
        sw.add_route(1, 0, prefix_len=40)


# -- topology configs ---------------------------------------------------------

def _full_topology() -> TopologyConfig:
    return TopologyConfig(
        name="roundtrip",
        nodes=(NodeConfig(name="a", ip=0xC0A80010,
                          pool=PoolConfig(n_slots=2048),
                          port=PortConfig(n_queues=2, ring_size=512,
                                          writeback_threshold=8),
                          stack=StackConfig(kind="bypass", burst_size=16)),
               NodeConfig(name="b", stack=StackConfig(kind="kernel"))),
        n_clients=3,
        client_pool=PoolConfig(n_slots=1024),
        switch=SwitchConfig(egress_capacity=16,
                            link=LinkConfig(gbps=25.0, latency_ns=600)),
        traffic=TrafficConfig(mode="open_loop", rate_gbps=2.0,
                              packet_size=512, duration_s=0.0002, seed=9),
        target="a")


def test_topology_config_round_trip():
    for cfg in (TopologyConfig(), _full_topology()):
        assert TopologyConfig.from_dict(cfg.to_dict()) == cfg


def test_topology_config_survives_json():
    cfg = _full_topology()
    assert TopologyConfig.from_dict(
        json.loads(json.dumps(cfg.to_dict()))) == cfg


def test_topology_config_validation():
    with pytest.raises(ValueError):
        TopologyConfig(nodes=())
    with pytest.raises(ValueError):
        TopologyConfig(n_clients=0)
    with pytest.raises(ValueError):
        TopologyConfig(nodes=(NodeConfig(name="x"), NodeConfig(name="x")))
    with pytest.raises(ValueError):
        TopologyConfig(target="nope")
    with pytest.raises(ValueError):
        TopologyConfig(traffic=TrafficConfig(mode="closed_loop"))
    with pytest.raises(ValueError):
        TopologyConfig(traffic=TrafficConfig(sim_time=False))
    with pytest.raises(ValueError):
        SwitchConfig(egress_capacity=0)
    with pytest.raises(ValueError):
        TopologyConfig(client_pool=PoolConfig(n_slots=16, slot_size=256),
                       traffic=TrafficConfig(packet_size=512))


# -- scenarios ----------------------------------------------------------------

def _incast(n_clients: int, rate_gbps: float = 3.0,
            duration_s: float = 0.0003, egress_capacity: int = 32,
            verify: bool = False) -> TopologyConfig:
    return TopologyConfig(
        name=f"incast-{n_clients}",
        nodes=(NodeConfig(name="server", pool=PoolConfig(n_slots=16384),
                          port=PortConfig(ring_size=2048,
                                          writeback_threshold=1),
                          stack=StackConfig(kind="bypass", burst_size=64)),),
        n_clients=n_clients,
        switch=SwitchConfig(egress_capacity=egress_capacity,
                            link=LinkConfig(gbps=10.0, latency_ns=1000)),
        traffic=TrafficConfig(mode="open_loop", rate_gbps=rate_gbps,
                              packet_size=1518, duration_s=duration_s,
                              seed=7, verify_integrity=verify))


def _fingerprint(rep):
    return (
        rep.sent, rep.received, rep.dropped, rep.offered_gbps,
        rep.achieved_gbps, rep.achieved_mpps,
        None if rep.latency is None else tuple(sorted(
            rep.latency.as_dict().items())),
        tuple(tuple(sorted(b.items())) for b in rep.histogram),
        tuple(sorted(rep.extras.items())),
    )


def test_forward_path_rtt_floor_and_conservation():
    """1 client -> switch -> server and back: four wire crossings, each
    paying serialization + propagation, floor the RTT; every frame returns."""
    rep = run_topology_experiment(_incast(1, rate_gbps=1.0))
    assert rep.received > 0 and rep.dropped == 0
    ser = int(round(1518 * 8 / 10.0))  # 1214 ns at 10 Gbps
    assert rep.latency.min_ns >= 4 * (ser + 1000)
    assert rep.received + rep.dropped == rep.sent


def test_topology_reports_are_bit_identical():
    """Acceptance: same TopologyConfig + seed -> bit-identical RunReport,
    including an overloaded (dropping) incast."""
    for cfg in (_incast(2), _incast(6)):
        assert _fingerprint(run_topology_experiment(cfg)) == \
            _fingerprint(run_topology_experiment(cfg))


def test_incast_drops_at_switch_egress_not_nics():
    """Acceptance: in an overloaded incast every loss is a switch
    egress-buffer drop; NIC rings and pools stay loss-free."""
    rep = run_topology_experiment(_incast(6))
    assert rep.dropped > 0
    assert rep.extras["sw_p0_egress_drops"] == float(rep.dropped)
    assert rep.extras["sw_p0_occ_high"] == 32.0  # buffer actually filled
    assert rep.extras["n0_imissed"] == 0.0
    assert rep.extras["n0_rx_nombuf"] == 0.0
    assert rep.extras["sw_unrouted"] == 0.0
    assert rep.received + rep.dropped == rep.sent


def test_incast_rtt_tail_grows_with_client_count():
    """Acceptance: the RTT tail is a queueing observable — more clients into
    one egress port means deeper switch queues and a fatter tail."""
    p99 = {}
    for n in (2, 6):
        rep = run_topology_experiment(_incast(n))
        p99[n] = rep.latency.p99_ns
        assert rep.extras["n0_imissed"] == 0.0  # NICs loss-free throughout
    assert p99[6] > 2.0 * p99[2]


def test_incast_integrity_through_the_fabric():
    """Payloads survive pool-to-pool DMA, the echo rewrite, and the trip
    back (checksummed past the flow tuple the server legitimately swaps)."""
    rep = run_topology_experiment(_incast(2, rate_gbps=1.0, verify=True))
    assert rep.received > 0
    assert rep.extras["integrity_errors"] == 0.0


def test_multi_node_topology_routes_to_target():
    """Two nodes on the fabric; only the target sees client traffic, and
    replies still come home (per-client /16 routes)."""
    cfg = TopologyConfig(
        nodes=(NodeConfig(name="a"), NodeConfig(name="b")),
        n_clients=2,
        switch=SwitchConfig(link=LinkConfig(gbps=10.0, latency_ns=500)),
        traffic=TrafficConfig(mode="open_loop", rate_gbps=0.5,
                              packet_size=512, duration_s=0.0002, seed=3),
        target="b")
    rep = run_topology_experiment(cfg)
    assert rep.received == rep.sent > 0
    assert rep.extras["n0_rx_packets"] == 0.0   # node "a" untouched
    assert rep.extras["n1_rx_packets"] == float(rep.sent)


def test_kernel_stack_node_on_the_fabric():
    """The stack registry works per node: an interrupt-driven kernel node
    echoes fabric traffic deterministically."""
    cfg = TopologyConfig(
        nodes=(NodeConfig(name="kserver",
                          port=PortConfig(ring_size=1024,
                                          writeback_threshold=1),
                          stack=StackConfig(kind="kernel")),),
        n_clients=2,
        switch=SwitchConfig(link=LinkConfig(gbps=10.0, latency_ns=500)),
        traffic=TrafficConfig(mode="open_loop", rate_gbps=0.25,
                              packet_size=512, duration_s=0.0003, seed=5))
    a = _fingerprint(run_topology_experiment(cfg))
    b = _fingerprint(run_topology_experiment(cfg))
    assert a == b
    assert a[1] > 0  # received


def test_build_rejects_colliding_resolved_ips():
    """An explicit node ip that lands on another node's auto-assigned
    address must fail loudly at build, not silently shadow its route."""
    cfg = TopologyConfig(
        nodes=(NodeConfig(name="a", ip=0xC0A80002), NodeConfig(name="b")),
        traffic=TrafficConfig(duration_s=0.0001), target="b")
    with pytest.raises(ValueError, match="collide"):
        Cluster.build(cfg)
    cfg2 = TopologyConfig(
        nodes=(NodeConfig(name="a", ip=0x0A010005),),  # inside client 1's /16
        traffic=TrafficConfig(duration_s=0.0001))
    with pytest.raises(ValueError, match="client /16"):
        Cluster.build(cfg2)


def test_run_raises_when_traffic_never_quiesces():
    """A self-addressed forwarding loop must raise, not spin max_rounds and
    return a silently-wrong report."""
    from repro.core.packet import swap_macs_vec

    cluster = Cluster.build(_incast(1, rate_gbps=0.5, duration_s=0.0001))
    # break the echo: macs swap but flow IPs don't, so every reply is still
    # addressed to the server and cycles node -> switch -> node forever
    cluster.nodes[0].server.burst_process_fn = swap_macs_vec
    with pytest.raises(RuntimeError, match="max_rounds"):
        cluster.run(max_rounds=20_000)


def test_cluster_exposes_live_objects():
    """Benchmarks need mid-run access (per-queue stats, switch counters)."""
    cluster = Cluster.build(_incast(2, rate_gbps=0.5, duration_s=0.0001))
    rep = cluster.run()
    assert len(cluster.nodes) == 1 and len(cluster.clients) == 2
    assert cluster.nodes[0].server.stats.rx_packets == rep.received
    assert cluster.switch.n_ports == 3
    assert cluster.clock.now_ns > 0