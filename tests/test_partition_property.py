"""Property tests for partitioned execution (hypothesis).

Randomized topology shapes drive two properties the hand-picked parity
corpus cannot sweep:

* **no early delivery** — epoch-bounded stepping never lands a frame in a
  destination domain before ``emission + serialization + link_latency``
  (the conservative-window soundness condition);
* **parity** — the partitioned report equals the shared-clock report
  bit-for-bit on every drawn config.
"""
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import Wire
from repro.exp import (LinkConfig, NodeConfig, PoolConfig, PortConfig,
                       StackConfig, SwitchConfig, TopologyConfig,
                       TrafficConfig, run_partitioned_topology,
                       run_topology_experiment)


def _topology(n_clients, rate_gbps, packet_size, latency_ns, egress_capacity,
              kind):
    return TopologyConfig(
        name="prop",
        nodes=(NodeConfig(name="srv",
                          pool=PoolConfig(n_slots=8192, slot_size=2048),
                          port=PortConfig(ring_size=512,
                                          writeback_threshold=1),
                          stack=StackConfig(kind=kind, burst_size=32)),),
        n_clients=n_clients,
        switch=SwitchConfig(egress_capacity=egress_capacity,
                            link=LinkConfig(gbps=10.0,
                                            latency_ns=latency_ns)),
        traffic=TrafficConfig(mode="open_loop", rate_gbps=rate_gbps,
                              duration_s=0.0001, packet_size=packet_size,
                              seed=7, sim_time=True))


@settings(max_examples=10, deadline=None)
@given(n_clients=st.integers(1, 3),
       rate_gbps=st.sampled_from([0.5, 2.0, 6.0]),
       packet_size=st.sampled_from([64, 256, 1024]),
       latency_ns=st.sampled_from([1, 500, 2000, 5000]),
       egress_capacity=st.sampled_from([2, 8, 64]),
       kind=st.sampled_from(["bypass", "kernel"]))
def test_no_frame_beats_its_wire(n_clients, rate_gbps, packet_size,
                                 latency_ns, egress_capacity, kind):
    cfg = _topology(n_clients, rate_gbps, packet_size, latency_ns,
                    egress_capacity, kind).with_partition("partitioned")
    trace = []
    rep = run_partitioned_topology(cfg, trace=trace)
    link = cfg.switch.link
    for _dst, fire_t, birth, xkind, payload in trace:
        frame = payload[1] if xkind == "fwd" else payload
        unloaded = Wire(gbps=link.gbps,
                        latency_ns=link.latency_ns).transmit(birth[0],
                                                             len(frame))
        assert fire_t >= unloaded, (
            f"crossing fired at {fire_t} < unloaded wire arrival {unloaded}")
    assert rep.to_dict() == run_topology_experiment(
        cfg.with_partition("shared-clock")).to_dict()
