"""Per-arch smoke tests (reduced configs): forward + one train step on CPU,
asserting output shapes and finiteness; plus prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, synth_tokens
from repro.models import lm, registry
from repro.models.layers import apply_norm, logits_for
from repro.optim import adamw
from repro.runtime.steps import make_train_step

ARCHS = list(registry.ARCHS)


def _batch(cfg, B=2, S=32, seed=0):
    dcfg = DataConfig(seq_len=S, global_batch=B, seed=seed)
    host = synth_tokens(cfg, dcfg, 0, 1, 0)
    return {k: jnp.asarray(v) for k, v in host.items()}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = registry.get_smoke_config(arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, metrics = jax.jit(lambda p, b: lm.train_loss(cfg, p, b))(params, batch)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    assert loss.shape == ()
    # one full optimizer step
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, decay_steps=10)
    opt_state = adamw.init(opt_cfg, params)
    step = jax.jit(make_train_step(cfg, opt_cfg))  # no donation: we compare
    new_params, new_opt, m = step(params, opt_state, batch)
    assert int(new_opt.step) == 1
    assert jnp.isfinite(m["loss"]) and jnp.isfinite(m["grad_norm"])
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert jnp.isfinite(leaf.astype(jnp.float32)).all()
    # params actually changed
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        params, new_params)
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if registry.get_smoke_config(a).has_decode])
def test_prefill_decode_matches_forward(arch):
    cfg = registry.get_smoke_config(arch).replace(
        param_dtype="float32", compute_dtype="float32", capacity_factor=8.0)
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 24
    key = jax.random.PRNGKey(2)
    tok = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    if cfg.frontend == "vision_patches":
        patches = jax.random.normal(key, (B, cfg.n_patches, cfg.d_model)) * 0.02
        pb = {"tokens": tok[:, :S], "patches": patches}
        rb = {"tokens": tok, "patches": patches}
        S_total = S + cfg.n_patches
    else:
        pb, rb = {"tokens": tok[:, :S]}, {"tokens": tok}
        S_total = S
    logits_p, cache = jax.jit(
        lambda p, b: lm.prefill(cfg, p, b, S_total + 8))(params, pb)
    logits_d, _ = jax.jit(
        lambda p, c, t, q: lm.decode_step(cfg, p, c, t, q))(
        params, cache, tok[:, S], jnp.full((B,), S_total, jnp.int32))

    from repro.models.lm import _embed_inputs, backbone
    def full(p):
        x, positions, _ = _embed_inputs(cfg, p, rb)
        h, _ = backbone(cfg).forward_hidden(cfg, p["backbone"], x, positions,
                                            remat=False)
        h = apply_norm(cfg, p["final_norm"], h)
        return (logits_for(cfg, p["embed"], h[:, -2]),
                logits_for(cfg, p["embed"], h[:, -1]))
    ref_p, ref_d = jax.jit(full)(params)
    np.testing.assert_allclose(logits_p, ref_p, atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(logits_d, ref_d, atol=2e-3, rtol=2e-3)


def test_encoder_has_no_decode_cells():
    cfg = registry.get_smoke_config("hubert-xlarge")
    assert not cfg.has_decode
    ok, reason = registry.cell_status(registry.get_config("hubert-xlarge"),
                                      "decode_32k")
    assert not ok and "encoder" in reason


def test_long_context_gating():
    full = registry.get_config("qwen3-1.7b")
    ok, reason = registry.cell_status(full, "long_500k")
    assert not ok and "sub-quadratic" in reason
    for a in ("mamba2-1.3b", "recurrentgemma-9b", "mixtral-8x7b"):
        ok, _ = registry.cell_status(registry.get_config(a), "long_500k")
        assert ok, a


def test_param_counts_match_assignment():
    """Sanity: derived parameter counts are in the right ballpark for the
    named model sizes (within loose factors — configs are from the table)."""
    expect = {
        "qwen3-1.7b": 1.7e9, "granite-8b": 8e9, "phi4-mini-3.8b": 3.8e9,
        "llama3.2-3b": 3.2e9, "internvl2-26b": 26e9, "mixtral-8x7b": 46.7e9,
        "llama4-maverick-400b-a17b": 400e9, "recurrentgemma-9b": 9e9,
        "mamba2-1.3b": 1.3e9, "hubert-xlarge": 1e9,
    }
    for arch, want in expect.items():
        got = registry.get_config(arch).param_count()
        assert 0.5 * want < got < 1.6 * want, (arch, got, want)
    # MoE active params
    l4 = registry.get_config("llama4-maverick-400b-a17b")
    assert 10e9 < l4.active_param_count() < 25e9  # "a17b"


def test_mixtral_moe_routing_statistics():
    """Top-2 routing: every token contributes exactly 2 combine weights that
    sum to 1 (before capacity drops)."""
    from repro.models.moe import _route
    cfg = registry.get_smoke_config("mixtral-8x7b")
    rng = jax.random.PRNGKey(0)
    router = jax.random.normal(rng, (cfg.d_model, cfg.n_experts)) * 0.1
    x = jax.random.normal(rng, (64, cfg.d_model))
    idx, w, aux = _route(cfg, router, x)
    assert idx.shape == (64, 2) and w.shape == (64, 2)
    np.testing.assert_allclose(np.array(w.sum(-1)), 1.0, atol=1e-5)
    assert float(aux) > 0
