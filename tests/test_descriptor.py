"""Descriptor-ring semantics: the paper's §3.1.4 writeback-threshold fix."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.descriptor import RxDescriptorRing, TxDescriptorRing


def test_pathological_default_writeback():
    """writeback_threshold=None reproduces the pre-fix gem5 behaviour: the
    PMD sees nothing until the entire ring is used."""
    ring = RxDescriptorRing(8, writeback_threshold=None)
    for i in range(7):
        assert ring.nic_deliver(i, 100)
        assert ring.poll(8) == [], "nothing visible before full ring"
    assert ring.nic_deliver(7, 100)
    got = ring.poll(8)
    assert [s for s, _ in got] == list(range(8))
    assert ring.writebacks == 1
    assert ring.writeback_sizes == [8]


def test_threshold_writeback_publishes_in_bursts():
    ring = RxDescriptorRing(64, writeback_threshold=4)
    for i in range(10):
        ring.nic_deliver(i, 64)
    # two writebacks of 4; 2 still cached
    assert ring.writeback_sizes == [4, 4]
    got = ring.poll(64)
    assert [s for s, _ in got] == list(range(8))
    ring.flush()
    assert [s for s, _ in ring.poll(64)] == [8, 9]


def test_ring_overflow_drops():
    ring = RxDescriptorRing(4, writeback_threshold=1)
    for i in range(6):
        ring.nic_deliver(i, 10)
    assert ring.delivered == 4
    assert ring.dropped == 2


@given(size=st.sampled_from([4, 8, 16, 32]),
       threshold=st.integers(1, 32),
       n=st.integers(1, 200),
       poll_burst=st.integers(1, 40))
@settings(max_examples=60, deadline=None)
def test_no_loss_no_dup_through_ring(size, threshold, n, poll_burst):
    """Every delivered descriptor is polled exactly once, in order."""
    threshold = min(threshold, size)
    ring = RxDescriptorRing(size, writeback_threshold=threshold)
    sent, received = [], []
    i = 0
    while i < n or ring.in_flight > 0:
        if i < n and ring.nic_deliver(i, 10 + (i % 5)):
            sent.append(i)
        i += 1 if i < n else 0
        ring.flush()
        for s, _l in ring.poll(poll_burst):
            received.append(s)
        if i >= n:
            break
    ring.flush()
    while True:
        batch = ring.poll(poll_burst)
        if not batch:
            break
        received.extend(s for s, _ in batch)
    assert received == sent


def test_vectorized_paths_match_scalar():
    r1 = RxDescriptorRing(16, writeback_threshold=4)
    r2 = RxDescriptorRing(16, writeback_threshold=4)
    slots = np.arange(10, dtype=np.int64)
    lengths = np.full(10, 77, dtype=np.int32)
    for s in range(10):
        r1.nic_deliver(int(slots[s]), 77)
    accepted = r2.nic_deliver_burst(slots, lengths)
    assert accepted == 10
    r1.flush(), r2.flush()
    a = r1.poll(16)
    s2, l2 = r2.poll_burst(16)
    assert [x for x, _ in a] == list(s2)
    assert all(l == 77 for _, l in a) and (l2 == 77).all()


def test_tx_ring_drain():
    tx = TxDescriptorRing(8)
    assert tx.post_burst_vec(np.arange(5), np.full(5, 9, np.int32)) == 5
    s, l = tx.drain_burst(3)
    assert list(s) == [0, 1, 2]
    s, l = tx.drain_burst(10)
    assert list(s) == [3, 4]
    assert tx.transmitted == 5


# -- regression: vectorized writeback must match the per-packet path ----------

def test_deliver_burst_one_writeback_per_threshold_crossing():
    """Regression: a 256-frame burst at threshold 32 is eight 32-descriptor
    DMAs, not one 256-descriptor DMA.  ``writeback_sizes`` is exactly the
    quantity Fig. 4 studies, so the vectorized path must not coarsen it."""
    ring = RxDescriptorRing(512, writeback_threshold=32)
    ring.nic_deliver_burst(np.arange(256, dtype=np.int64),
                           np.full(256, 100, np.int32))
    assert ring.writebacks == 8
    assert ring.writeback_sizes == [32] * 8


def test_deliver_burst_writebacks_match_scalar_deliver():
    """Scalar/vector parity on writeback *events*, not just polled frames."""
    scalar = RxDescriptorRing(512, writeback_threshold=24)
    vector = RxDescriptorRing(512, writeback_threshold=24)
    slots = np.arange(100, dtype=np.int64)
    lengths = np.full(100, 64, np.int32)
    for s in slots:
        scalar.nic_deliver(int(s), 64)
    vector.nic_deliver_burst(slots, lengths)
    assert vector.writebacks == scalar.writebacks
    assert vector.writeback_sizes == scalar.writeback_sizes
    assert vector.done_count == scalar.done_count
    assert vector.delivered_bytes == scalar.delivered_bytes


def test_deliver_burst_residue_flushes_when_ring_fills():
    """Ring-full still publishes the sub-threshold residue (both paths)."""
    ring = RxDescriptorRing(10, writeback_threshold=4)
    ring.nic_deliver_burst(np.arange(10, dtype=np.int64),
                           np.full(10, 50, np.int32))
    # two threshold crossings of 4, then the full ring flushes the 2 left
    assert ring.writeback_sizes == [4, 4, 2]
    assert ring.done_count == 10


# -- regression: TX scalar/vector stats parity ---------------------------------

def test_tx_post_burst_counts_untried_tail_as_rejected():
    """Regression: post_burst used to stop at the first rejected item and
    leave the rest of the burst uncounted, so scalar and vectorized paths
    disagreed on ``rejected`` for the same offered burst."""
    scalar = TxDescriptorRing(4)
    vector = TxDescriptorRing(4)
    items = [(i, 10) for i in range(9)]
    n_scalar = scalar.post_burst(items)
    n_vector = vector.post_burst_vec(np.arange(9, dtype=np.int64),
                                     np.full(9, 10, np.int32))
    assert n_scalar == n_vector == 4
    assert scalar.rejected == vector.rejected == 5
    assert scalar.posted == vector.posted == 4
    assert scalar.posted_bytes == vector.posted_bytes == 40


def test_tx_post_burst_no_rejects_unchanged():
    tx = TxDescriptorRing(8)
    assert tx.post_burst([(i, 5) for i in range(6)]) == 6
    assert tx.rejected == 0


# -- invariant suite -----------------------------------------------------------

def test_rx_wraparound_cursors_past_size():
    """head/tail keep counting past ``size``; slot indices stay correct."""
    ring = RxDescriptorRing(8, writeback_threshold=2)
    polled = []
    for i in range(100):
        assert ring.nic_deliver(i, 10)
        got = ring.poll_burst(8)[0]
        polled.extend(int(s) for s in got)
    ring.flush()
    polled.extend(int(s) for s in ring.poll_burst(8)[0])
    assert polled == list(range(100))
    assert ring.head == ring.tail == 100  # far past size=8
    assert ring.published == 100
    assert ring.in_flight == 0


def test_poll_and_poll_burst_parity_on_partial_writeback():
    """With completions split cache/published, both harvest APIs must see
    exactly the published prefix."""
    a = RxDescriptorRing(32, writeback_threshold=8)
    b = RxDescriptorRing(32, writeback_threshold=8)
    for ring in (a, b):
        for i in range(11):  # one writeback of 8; 3 still cached
            ring.nic_deliver(i, 20)
        assert ring.done_count == 8
    got_a = a.poll(32)
    s_b, l_b = b.poll_burst(32)
    assert [s for s, _ in got_a] == list(s_b) == list(range(8))
    assert [l for _, l in got_a] == list(l_b)
    assert a.tail == b.tail == 8


def test_flush_is_idempotent():
    ring = RxDescriptorRing(16, writeback_threshold=8)
    for i in range(3):
        ring.nic_deliver(i, 10)
    ring.flush()
    assert ring.writebacks == 1 and ring.writeback_sizes == [3]
    ring.flush()  # nothing cached: no extra writeback event is recorded
    ring.flush()
    assert ring.writebacks == 1 and ring.writeback_sizes == [3]


def test_deliver_burst_drop_accounting_mid_burst():
    """A burst that overruns the free descriptors drops exactly the tail and
    conserves counts: delivered + dropped == offered."""
    ring = RxDescriptorRing(8, writeback_threshold=4)
    ring.nic_deliver_burst(np.arange(5, dtype=np.int64), np.full(5, 10, np.int32))
    accepted = ring.nic_deliver_burst(np.arange(100, 106, dtype=np.int64),
                                      np.full(6, 10, np.int32))
    assert accepted == 3
    assert ring.delivered == 8
    assert ring.dropped == 3
    assert ring.delivered + ring.dropped == 11
    # the accepted prefix is intact (order preserved through the overflow)
    ring.flush()
    s, _ = ring.poll_burst(8)
    assert list(s) == [0, 1, 2, 3, 4, 100, 101, 102]


def test_byte_counters_are_int64_safe():
    """Multi-million-packet runs overflow int32 byte sums; counters must
    accumulate exactly (numpy reductions forced to int64)."""
    ring = RxDescriptorRing(4096, writeback_threshold=None)
    tx = TxDescriptorRing(4096)
    big = np.full(4096, 2**31 - 1, np.int32)  # 4096 * (2^31-1) >> int32/uint32
    slots = np.arange(4096, dtype=np.int64)
    assert ring.nic_deliver_burst(slots, big) == 4096
    assert ring.delivered_bytes == 4096 * (2**31 - 1)
    assert tx.post_burst_vec(slots, big) == 4096
    assert tx.posted_bytes == 4096 * (2**31 - 1)
    tx.drain_burst(4096)
    assert tx.transmitted_bytes == 4096 * (2**31 - 1)
