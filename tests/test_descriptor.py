"""Descriptor-ring semantics: the paper's §3.1.4 writeback-threshold fix."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.descriptor import RxDescriptorRing, TxDescriptorRing


def test_pathological_default_writeback():
    """writeback_threshold=None reproduces the pre-fix gem5 behaviour: the
    PMD sees nothing until the entire ring is used."""
    ring = RxDescriptorRing(8, writeback_threshold=None)
    for i in range(7):
        assert ring.nic_deliver(i, 100)
        assert ring.poll(8) == [], "nothing visible before full ring"
    assert ring.nic_deliver(7, 100)
    got = ring.poll(8)
    assert [s for s, _ in got] == list(range(8))
    assert ring.writebacks == 1
    assert ring.writeback_sizes == [8]


def test_threshold_writeback_publishes_in_bursts():
    ring = RxDescriptorRing(64, writeback_threshold=4)
    for i in range(10):
        ring.nic_deliver(i, 64)
    # two writebacks of 4; 2 still cached
    assert ring.writeback_sizes == [4, 4]
    got = ring.poll(64)
    assert [s for s, _ in got] == list(range(8))
    ring.flush()
    assert [s for s, _ in ring.poll(64)] == [8, 9]


def test_ring_overflow_drops():
    ring = RxDescriptorRing(4, writeback_threshold=1)
    for i in range(6):
        ring.nic_deliver(i, 10)
    assert ring.delivered == 4
    assert ring.dropped == 2


@given(size=st.sampled_from([4, 8, 16, 32]),
       threshold=st.integers(1, 32),
       n=st.integers(1, 200),
       poll_burst=st.integers(1, 40))
@settings(max_examples=60, deadline=None)
def test_no_loss_no_dup_through_ring(size, threshold, n, poll_burst):
    """Every delivered descriptor is polled exactly once, in order."""
    threshold = min(threshold, size)
    ring = RxDescriptorRing(size, writeback_threshold=threshold)
    sent, received = [], []
    i = 0
    while i < n or ring.in_flight > 0:
        if i < n and ring.nic_deliver(i, 10 + (i % 5)):
            sent.append(i)
        i += 1 if i < n else 0
        ring.flush()
        for s, _l in ring.poll(poll_burst):
            received.append(s)
        if i >= n:
            break
    ring.flush()
    while True:
        batch = ring.poll(poll_burst)
        if not batch:
            break
        received.extend(s for s, _ in batch)
    assert received == sent


def test_vectorized_paths_match_scalar():
    r1 = RxDescriptorRing(16, writeback_threshold=4)
    r2 = RxDescriptorRing(16, writeback_threshold=4)
    slots = np.arange(10, dtype=np.int64)
    lengths = np.full(10, 77, dtype=np.int32)
    for s in range(10):
        r1.nic_deliver(int(slots[s]), 77)
    accepted = r2.nic_deliver_burst(slots, lengths)
    assert accepted == 10
    r1.flush(), r2.flush()
    a = r1.poll(16)
    s2, l2 = r2.poll_burst(16)
    assert [x for x, _ in a] == list(s2)
    assert all(l == 77 for _, l in a) and (l2 == 77).all()


def test_tx_ring_drain():
    tx = TxDescriptorRing(8)
    assert tx.post_burst_vec(np.arange(5), np.full(5, 9, np.int32)) == 5
    s, l = tx.drain_burst(3)
    assert list(s) == [0, 1, 2]
    s, l = tx.drain_burst(10)
    assert list(s) == [3, 4]
    assert tx.transmitted == 5
