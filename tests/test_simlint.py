"""The determinism linter lints itself honestly (PR 9 tentpole tests).

Per rule SL001–SL007: a known-bad snippet that must fire at the exact
file:line, and a known-clean twin that must stay silent.  Plus the
suppression-comment contract, the baseline workflow, and the CLI exit codes
CI gates on.
"""
import json
import textwrap

from repro.simlint import (SimlintConfig, lint_source, load_baseline,
                           split_new, write_baseline)
from repro.simlint.cli import main

CFG = SimlintConfig()


def _lint(src, path="snippet.py", cfg=CFG):
    return lint_source(path, textwrap.dedent(src), cfg)


def _rules(findings):
    return [f.rule for f in findings]


# -- SL001: wall-clock reads ---------------------------------------------------

def test_sl001_fires_with_line():
    fs = _lint("""\
        import time

        def f():
            t0 = time.perf_counter()
            return t0
        """)
    assert _rules(fs) == ["SL001"]
    assert (fs[0].path, fs[0].line) == ("snippet.py", 4)
    assert "time.perf_counter" in fs[0].message


def test_sl001_sees_through_import_aliases():
    fs = _lint("""\
        from time import perf_counter as pc
        t = pc()
        """)
    assert _rules(fs) == ["SL001"]
    assert fs[0].line == 2


def test_sl001_clean_twin_virtual_clock():
    assert _lint("""\
        def f(clock):
            return clock.now_ns
        """) == []


def test_sl001_allow_glob():
    cfg = SimlintConfig(sl001_allow=("bench/*.py",))
    src = "import time\nt = time.time()\n"
    assert lint_source("bench/timing.py", src, cfg) == []
    assert _rules(lint_source("core/sim.py", src, cfg)) == ["SL001"]


# -- SL002: unseeded RNG -------------------------------------------------------

def test_sl002_global_numpy_and_argless_default_rng():
    fs = _lint("""\
        import numpy as np

        x = np.random.rand(3)
        rng = np.random.default_rng()
        """)
    assert _rules(fs) == ["SL002", "SL002"]
    assert [f.line for f in fs] == [3, 4]


def test_sl002_clean_twin_seeded():
    assert _lint("""\
        import numpy as np

        rng = np.random.default_rng(7)
        x = rng.normal(size=3)
        ss = np.random.SeedSequence(42)
        """) == []


def test_sl002_stdlib_random():
    fs = _lint("""\
        import random

        a = random.random()
        b = random.Random()
        c = random.SystemRandom(1)
        """)
    assert _rules(fs) == ["SL002", "SL002", "SL002"]
    assert _lint("""\
        import random
        r = random.Random(42)
        """) == []


# -- SL003: set iteration near schedulers --------------------------------------

def test_sl003_fires_only_in_scheduler_adjacent_files():
    bad = """\
        # ordering feeds the EventScheduler heap
        for x in {3, 1, 2}:
            print(x)
        """
    fs = _lint(bad)
    assert _rules(fs) == ["SL003"]
    assert fs[0].line == 2
    # identical iteration, no scheduler token in the file: out of scope
    assert _lint(bad.replace("EventScheduler", "nothing")) == []


def test_sl003_set_typed_name_and_sorted_escape():
    fs = _lint("""\
        # DomainScheduler bookkeeping
        live = set()
        for t in live:
            pass
        for t in sorted(live):
            pass
        """)
    assert _rules(fs) == ["SL003"]
    assert fs[0].line == 3  # the sorted() iteration is deterministic


# -- SL004: float accumulation into int64 counters -----------------------------

def test_sl004_fires_on_floaty_rhs():
    fs = _lint("""\
        class Meter:
            def add(self, n):
                self.packets += n / 2
        """)
    assert _rules(fs) == ["SL004"]
    assert fs[0].line == 3
    assert ".packets" in fs[0].message


def test_sl004_clean_twin_int_rhs_and_non_counter():
    assert _lint("""\
        class Meter:
            def add(self, n):
                self.packets += int(n)
                self.mean_ns += n / 2
        """) == []  # .mean_ns is not a declared int64 counter


# -- SL005: config dataclass hygiene -------------------------------------------

def test_sl005_unfrozen_config_dataclass():
    fs = _lint("""\
        from dataclasses import dataclass

        @dataclass
        class FooConfig:
            a: int = 0
        """)
    assert _rules(fs) == ["SL005"]
    assert fs[0].line == 4
    assert "not frozen" in fs[0].message


def test_sl005_mutable_default():
    fs = _lint("""\
        from dataclasses import dataclass

        @dataclass(frozen=True)
        class FooConfig:
            xs: list = []
        """)
    assert _rules(fs) == ["SL005"]
    assert fs[0].line == 5


def test_sl005_clean_twin_and_scope():
    assert _lint("""\
        from dataclasses import dataclass, field

        @dataclass(frozen=True)
        class FooConfig:
            a: int = 0
            xs: tuple = field(default_factory=tuple)

        @dataclass
        class MutableState:
            n: int = 0

        class PlainConfig:
            pass
        """) == []  # non-Config dataclasses / non-dataclass Configs pass


# -- SL006: to_dict/from_dict field coverage -----------------------------------

def test_sl006_omitted_field_both_directions():
    fs = _lint("""\
        from dataclasses import dataclass

        @dataclass(frozen=True)
        class BarConfig:
            a: int = 0
            b: int = 1

            def to_dict(self):
                return {"a": self.a}

            @classmethod
            def from_dict(cls, d):
                return cls(a=d["a"])
        """)
    assert _rules(fs) == ["SL006", "SL006"]
    assert "to_dict omits field(s) b" in fs[0].message
    assert fs[0].line == 8
    assert "from_dict never passes field(s) b" in fs[1].message


def test_sl006_one_sided_pair():
    fs = _lint("""\
        from dataclasses import dataclass

        @dataclass(frozen=True)
        class BazConfig:
            a: int = 0

            def to_dict(self):
                return {"a": self.a}
        """)
    assert _rules(fs) == ["SL006"]
    assert "without from_dict" in fs[0].message


def test_sl006_clean_twins():
    assert _lint("""\
        from dataclasses import dataclass

        @dataclass(frozen=True)
        class OkConfig:
            a: int = 0
            b: int = 1

            def to_dict(self):
                return {"a": self.a, "b": self.b}

            @classmethod
            def from_dict(cls, d):
                return cls(a=d["a"], b=d["b"])

        @dataclass(frozen=True)
        class GenericConfig:
            a: int = 0

            def to_dict(self):
                return _config_to_dict(self)

            @classmethod
            def from_dict(cls, d):
                return cls(**d)
        """) == []  # explicit full coverage, and generic forms, both pass


# -- SL007: process-identity ordering in mp paths ------------------------------

def test_sl007_fires_only_with_mp_import():
    bad = """\
        import multiprocessing
        import os

        def worker(obj):
            pid = os.getpid()
            env = os.environ.get("X")
            raw = os.environ["Y"]
            return id(obj)
        """
    fs = _lint(bad)
    assert _rules(fs) == ["SL007"] * 4
    assert [f.line for f in fs] == [5, 6, 7, 8]
    # same body, no mp import: a plain utility, out of scope
    assert _lint(bad.replace("import multiprocessing", "import os")) == []


# -- suppressions / syntax errors ----------------------------------------------

def test_inline_suppression_is_rule_specific():
    src = ("import time\n"
           "t = time.time()  # simlint: disable=SL001 -- wall-mode timing\n")
    assert lint_source("s.py", src, CFG) == []
    wrong = src.replace("disable=SL001", "disable=SL002")
    assert _rules(lint_source("s.py", wrong, CFG)) == ["SL001"]


def test_syntax_error_is_a_finding_not_a_crash():
    fs = lint_source("s.py", "def broken(:\n", CFG)
    assert _rules(fs) == ["SL000"]


# -- baseline workflow ---------------------------------------------------------

def _tmp_repo(tmp_path, bad_lines):
    (tmp_path / "simlint.toml").write_text(
        '[simlint]\npaths = ["pkg"]\nbaseline = "simlint_baseline.json"\n')
    pkg = tmp_path / "pkg"
    pkg.mkdir()
    (pkg / "mod.py").write_text("import time\n" + "\n".join(bad_lines) + "\n")
    return tmp_path


def test_baseline_absorbs_old_findings_and_gates_new(tmp_path):
    repo = _tmp_repo(tmp_path, ["t0 = time.time()"])
    toml = str(repo / "simlint.toml")
    # accept the current state into the baseline, then the run is clean
    assert main(["--config", toml, "--write-baseline"]) == 0
    assert main(["--config", toml]) == 0
    entries = json.loads((repo / "simlint_baseline.json").read_text())
    assert [(e["rule"], e["text"]) for e in entries] \
        == [("SL001", "t0 = time.time()")]
    # a NEW violation gates even though the old one stays absorbed
    (repo / "pkg" / "mod.py").write_text(
        "import time\nt0 = time.time()\nt1 = time.monotonic()\n")
    assert main(["--config", toml]) == 1


def test_baseline_is_content_addressed_multiset(tmp_path):
    repo = _tmp_repo(tmp_path, ["t0 = time.time()"])
    cfg = SimlintConfig(paths=("pkg",), root=str(repo))
    from repro.simlint import lint_paths
    findings = lint_paths([str(repo / "pkg")], cfg)
    bl_path = str(repo / "simlint_baseline.json")
    write_baseline(bl_path, findings, root=str(repo))
    # the same line moving to another line number stays baselined...
    (repo / "pkg" / "mod.py").write_text(
        "import time\n\n\nt0 = time.time()\n")
    new, old = split_new(lint_paths([str(repo / "pkg")], cfg),
                         load_baseline(bl_path), root=str(repo))
    assert (len(new), len(old)) == (0, 1)
    # ...but a DUPLICATE of a baselined line is a new finding (multiset)
    (repo / "pkg" / "mod.py").write_text(
        "import time\nt0 = time.time()\nt0 = time.time()\n")
    new, old = split_new(lint_paths([str(repo / "pkg")], cfg),
                         load_baseline(bl_path), root=str(repo))
    assert (len(new), len(old)) == (1, 1)


# -- CLI contract --------------------------------------------------------------

def test_cli_exit_codes_and_report_format(tmp_path, capsys):
    repo = _tmp_repo(tmp_path, ["t0 = time.time()"])
    toml = str(repo / "simlint.toml")
    assert main(["--config", toml, "--no-baseline"]) == 1
    out = capsys.readouterr().out
    assert "pkg/mod.py:2:6: SL001" in out
    assert "hint:" in out
    assert "1 new finding(s)" in out
    # fix the violation (inline suppression with a reason) -> exit 0
    (repo / "pkg" / "mod.py").write_text(
        "import time\n"
        "t0 = time.time()  # simlint: disable=SL001 -- bench timing\n")
    assert main(["--config", toml, "--no-baseline"]) == 0
    assert "clean" in capsys.readouterr().out


def test_cli_list_rules(capsys):
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rid in ("SL001", "SL002", "SL003", "SL004", "SL005", "SL006",
                "SL007"):
        assert rid in out


def test_repo_is_clean_under_its_own_config():
    """The acceptance gate, as a test: the repo's configured lint scope has
    zero unsuppressed, unbaselined findings."""
    assert main([]) == 0
