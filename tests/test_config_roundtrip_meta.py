"""Auto-generated config round-trip coverage (PR 9 satellite).

Every frozen ``*Config`` dataclass in :mod:`repro.exp.config` and
:mod:`repro.serving.config` is discovered by reflection — adding a config
class (or a field to one) automatically extends this suite.  The contract
per class: it IS frozen (simlint SL005), ``to_dict`` covers every declared
field exactly, ``from_dict(to_dict())`` reproduces the instance (simlint
SL006), and the dict survives a JSON round-trip — the property every sweep
manifest, checkpointed run, and mp-worker rebuild leans on.
"""
import dataclasses
import inspect
import json

import pytest

import repro.exp.config as exp_config
import repro.serving.config as serving_config
from repro.exp.config import (AqmConfig, CostConfig, DcaConfig,
                              ExperimentConfig, LinkConfig, NodeConfig,
                              PipelineConfig, PoolConfig, PortConfig,
                              RssConfig, StackConfig, SwitchConfig,
                              TopologyConfig, TrafficConfig)
from repro.serving.config import RequestMixConfig, ServingConfig


def _config_classes(mod):
    return sorted(
        (obj for name, obj in vars(mod).items()
         if inspect.isclass(obj) and obj.__module__ == mod.__name__
         and dataclasses.is_dataclass(obj) and name.endswith("Config")),
        key=lambda c: c.__name__)


CONFIG_CLASSES = _config_classes(exp_config) + _config_classes(serving_config)
IDS = [c.__name__ for c in CONFIG_CLASSES]

# one non-default instance per class, so round-trips are exercised on real
# values (not just defaults the from_dict(**{}) path would mask)
SAMPLES = {
    AqmConfig: lambda: AqmConfig(
        kind="ecn", min_thresh=4, max_thresh=12, max_p=0.25, seed=9),
    PipelineConfig: lambda: PipelineConfig(
        aqm=AqmConfig(kind="red", min_thresh=2, max_thresh=6),
        per_port_aqm=(AqmConfig(kind="ecn"), None)),
    CostConfig: lambda: CostConfig(cpu_ghz=3.0, pmd_poll_cycles=99),
    DcaConfig: lambda: DcaConfig(
        burst_size=8, writeback_threshold=8, writeback_timeout_ns=5000,
        writeback_dma_ns=100, per_lcore_bursts=(8,),
        per_queue_writeback_thresholds=(4, None)),
    ExperimentConfig: lambda: ExperimentConfig(
        name="meta", ports=(PortConfig(n_queues=2),),
        stack=StackConfig(kind="kernel", burst_size=16),
        dca=DcaConfig(burst_size=4, writeback_threshold=4)),
    LinkConfig: lambda: LinkConfig(gbps=10.0, latency_ns=5),
    NodeConfig: lambda: NodeConfig(
        name="n0", ip=0x0A000001, pool=PoolConfig(n_slots=128),
        dca=DcaConfig(burst_size=4)),
    PoolConfig: lambda: PoolConfig(n_slots=128, slot_size=4096),
    PortConfig: lambda: PortConfig(
        n_queues=2, ring_size=256, writeback_threshold=None,
        rss=RssConfig(table_size=64), link=LinkConfig(gbps=40.0)),
    RssConfig: lambda: RssConfig(table_size=64, key_hex="ab" * 20),
    StackConfig: lambda: StackConfig(
        kind="kernel", burst_size=16, n_lcores=2, per_lcore_bursts=(16, 8),
        cost=CostConfig(cpu_ghz=2.5)),
    SwitchConfig: lambda: SwitchConfig(
        egress_capacity=8, link=LinkConfig(latency_ns=500),
        pipeline=PipelineConfig(
            aqm=AqmConfig(kind="ecn", min_thresh=4, max_thresh=8)),
        trunk=LinkConfig(gbps=25.0, latency_ns=2000)),
    TrafficConfig: lambda: TrafficConfig(
        mode="open_loop", rate_gbps=2.5, seed=3, payload_seed=1,
        verify_integrity=True, cc_mode="dctcp", cc_window_ns=50_000,
        cc_gain=0.125, cc_min_gbps=0.1, cc_increase_gbps=0.5,
        cc_max_inflight=16),
    TopologyConfig: lambda: TopologyConfig(
        name="meta-topo",
        nodes=(NodeConfig(name="a"), NodeConfig(name="b")),
        n_clients=2, target="a", client_targets=("a", "b"),
        partition="partitioned", partition_workers=2,
        partition_sanitize=True,
        switch=SwitchConfig(trunk=LinkConfig(gbps=50.0)),
        node_switch=(0, 0), client_switch=(1, 0)),
    RequestMixConfig: lambda: RequestMixConfig(
        prompt_mean_tokens=64, prompt_dist="fixed", output_mean_tokens=4),
    ServingConfig: lambda: ServingConfig(
        mix=RequestMixConfig(output_mean_tokens=4),
        balancer="lb0", prefill=("p0",), decode=("d0", "d1"),
        policy="least_loaded", qps=100.0,
        prefill_ns_per_token=10, decode_overhead_ns=1000),
}


def test_every_config_class_has_a_sample():
    """Reflection keeps this suite honest: a new config class must bring a
    non-default sample (and thereby real round-trip coverage) with it."""
    missing = [c.__name__ for c in CONFIG_CLASSES if c not in SAMPLES]
    assert not missing, f"add SAMPLES entries for {missing}"


@pytest.mark.parametrize("cls", CONFIG_CLASSES, ids=IDS)
def test_config_is_frozen(cls):
    assert cls.__dataclass_params__.frozen, \
        f"{cls.__name__} must be @dataclass(frozen=True) (simlint SL005)"


@pytest.mark.parametrize("cls", CONFIG_CLASSES, ids=IDS)
def test_to_dict_covers_every_field(cls):
    inst = SAMPLES[cls]()
    d = inst.to_dict()
    declared = {f.name for f in dataclasses.fields(cls)}
    assert set(d) == declared, \
        f"{cls.__name__}.to_dict keys {set(d)} != fields {declared}"


@pytest.mark.parametrize("cls", CONFIG_CLASSES, ids=IDS)
def test_default_instance_round_trips(cls):
    inst = cls()
    assert cls.from_dict(inst.to_dict()) == inst


@pytest.mark.parametrize("cls", CONFIG_CLASSES, ids=IDS)
def test_sample_round_trips_exactly(cls):
    inst = SAMPLES[cls]()
    again = cls.from_dict(inst.to_dict())
    assert again == inst
    for f in dataclasses.fields(cls):
        assert getattr(again, f.name) == getattr(inst, f.name), f.name


@pytest.mark.parametrize("cls", CONFIG_CLASSES, ids=IDS)
def test_dict_survives_json(cls):
    inst = SAMPLES[cls]()
    wire = json.loads(json.dumps(inst.to_dict()))
    assert cls.from_dict(wire) == inst
