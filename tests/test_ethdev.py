"""EthDev: rte_ethdev lifecycle state machine, burst dataplane gating, and
DPDK-named stats/xstats parity with the legacy Port counters."""
import numpy as np
import pytest

from repro.core import (BypassL2FwdServer, EthConf, EthDev, EthDevError,
                        EthDevState, LoadGen, PacketPool)


def _dev(n_queues=2, ring=64, pool_slots=1024):
    return EthDev.make(PacketPool(pool_slots, 1518), ring_size=ring,
                       n_queues=n_queues)


# -- lifecycle state machine --------------------------------------------------

def test_lifecycle_happy_path():
    dev = EthDev(PacketPool(256, 1518))
    assert dev.state is EthDevState.UNCONFIGURED
    dev.configure(EthConf(n_rx_queues=2, n_tx_queues=2))
    assert dev.state is EthDevState.CONFIGURED
    for q in range(2):
        dev.rx_queue_setup(q, 64)
        dev.tx_queue_setup(q, 64)
    dev.dev_start()
    assert dev.state is EthDevState.STARTED
    dev.dev_stop()
    assert dev.state is EthDevState.STOPPED
    dev.dev_start()  # restart without reconfiguring (DPDK allows it)
    assert dev.state is EthDevState.STARTED


def test_illegal_transitions_raise():
    pool = PacketPool(256, 1518)
    dev = EthDev(pool)
    # dataplane / start / queue setup before configure
    with pytest.raises(EthDevError):
        dev.dev_start()
    with pytest.raises(EthDevError):
        dev.rx_queue_setup(0, 64)
    with pytest.raises(EthDevError):
        dev.rx_burst(0, 32)
    with pytest.raises(EthDevError):
        dev.dev_stop()
    dev.configure(EthConf())
    # start with unset queues
    with pytest.raises(EthDevError):
        dev.dev_start()
    dev.rx_queue_setup(0, 64)
    dev.tx_queue_setup(0, 64)
    dev.dev_start()
    # configure/queue-setup/start while running
    with pytest.raises(EthDevError):
        dev.configure(EthConf())
    with pytest.raises(EthDevError):
        dev.rx_queue_setup(0, 64)
    with pytest.raises(EthDevError):
        dev.tx_queue_setup(0, 64)
    with pytest.raises(EthDevError):
        dev.dev_start()
    # stop twice
    dev.dev_stop()
    with pytest.raises(EthDevError):
        dev.dev_stop()


def test_dataplane_gated_on_started():
    dev = _dev()
    dev.dev_stop()
    with pytest.raises(EthDevError):
        dev.rx_burst(0, 32)
    with pytest.raises(EthDevError):
        dev.tx_burst(0, np.array([0]), np.array([64]))
    with pytest.raises(EthDevError):
        dev.deliver(0, 64)
    with pytest.raises(EthDevError):
        _ = dev.port
    dev.dev_start()
    slots, lengths = dev.rx_burst(0, 32)
    assert len(slots) == 0 and len(lengths) == 0


def test_reconfigure_after_stop_wipes_queues():
    dev = _dev(n_queues=2)
    dev.dev_stop()
    dev.configure(EthConf(n_rx_queues=4, n_tx_queues=4))
    assert dev.state is EthDevState.CONFIGURED
    assert dev.n_queues == 4
    # old queue setups are gone: starting now must fail until re-setup
    with pytest.raises(EthDevError):
        dev.dev_start()
    for q in range(4):
        dev.rx_queue_setup(q, 32)
        dev.tx_queue_setup(q, 32)
    dev.dev_start()
    assert len(dev.rx_queues) == 4


def test_queue_setup_bounds():
    dev = EthDev(PacketPool(256, 1518)).configure(
        EthConf(n_rx_queues=2, n_tx_queues=2))
    with pytest.raises(EthDevError):
        dev.rx_queue_setup(2, 64)      # queue id out of range
    with pytest.raises(EthDevError):
        dev.rx_queue_setup(-1, 64)
    with pytest.raises(EthDevError):
        dev.tx_queue_setup(5, 64)
    with pytest.raises(EthDevError):
        dev.rx_queue_setup(0, 0)       # nb_desc must be >= 1
    dev.rx_queue_setup(1, 64)          # in-range ids are fine
    dev.tx_queue_setup(0, 64)


def test_ethconf_validation():
    with pytest.raises(ValueError):
        EthConf(n_rx_queues=0)
    with pytest.raises(ValueError):
        EthConf(n_rx_queues=2, n_tx_queues=4)


# -- burst dataplane ----------------------------------------------------------

def test_rx_tx_burst_roundtrip():
    """Wire deliver → rx_burst → tx_burst → drain: the DPDK loop by hand."""
    dev = _dev(n_queues=1, ring=64)
    pool = dev.pool
    for i in range(8):
        s = pool.alloc()
        pool.write_packet(s, seq=i, length=128, fill=0)
        assert dev.deliver(s, 128)
    dev.flush_rx()
    slots, lengths = dev.rx_burst(0, 64)
    assert len(slots) == 8
    assert dev.tx_burst(0, slots, lengths) == 8
    drained, dlens = dev.drain_tx_bursts(64)
    assert len(drained) == 8
    assert (np.sort(drained) == np.sort(slots)).all()


def test_counters_persist_across_stop_start():
    dev = _dev(n_queues=1, ring=64)
    pool = dev.pool
    s = pool.alloc()
    pool.write_packet(s, seq=0, length=128, fill=0)
    dev.deliver(s, 128)
    dev.dev_stop()
    dev.dev_start()
    assert dev.stats().ipackets == 1  # hardware counters survive stop/start


def test_queue_resetup_after_stop_takes_effect_on_restart():
    """DPDK semantics: a queue re-setup done while STOPPED replaces the ring
    the dataplane uses after the next dev_start."""
    dev = _dev(n_queues=2, ring=64)
    dev.dev_stop()
    dev.rx_queue_setup(0, 128)
    dev.tx_queue_setup(1, 32)
    dev.dev_start()
    assert dev.rx_queues[0].size == 128
    assert dev.rx_queues[1].size == 64      # untouched queue keeps its ring
    assert dev.tx_queues[1].size == 32


def test_rss_rebalance_persists_across_stop_start():
    dev = _dev(n_queues=4)
    dev.rss.rebalance([2] * 128)
    dev.dev_stop()
    dev.dev_start()
    assert (dev.rss.table == 2).all()


# -- stats / xstats -----------------------------------------------------------

def _run_traffic(n_queues=4, n_packets=1200):
    pool = PacketPool(4096, 1518)
    dev = EthDev.make(pool, ring_size=256, n_queues=n_queues)
    server = BypassL2FwdServer([dev], burst_size=32, n_lcores=n_queues)
    lg = LoadGen([dev])
    lg.run_closed_loop(server, n_packets=n_packets, packet_size=256)
    return dev


def test_xstats_parity_with_legacy_counters():
    """Satellite acceptance: xstats sums equal Port.rx_delivered /
    rx_dropped / tx_posted exactly."""
    dev = _run_traffic()
    xs = dev.xstats()
    port = dev.port
    n_q = dev.n_queues
    assert sum(xs[f"rx_q{q}_packets"] for q in range(n_q)) == port.rx_delivered
    assert sum(xs[f"rx_q{q}_errors"] for q in range(n_q)) == port.rx_dropped
    assert sum(xs[f"tx_q{q}_packets"] for q in range(n_q)) == port.tx_posted
    assert xs["rx_good_packets"] == port.rx_delivered
    assert xs["imissed"] == port.rx_dropped
    assert xs["rx_nombuf"] == dev.pool.alloc_failures


def test_stats_aggregate_block():
    dev = _run_traffic(n_queues=2, n_packets=800)
    st = dev.stats()
    assert st.ipackets == 800
    assert st.opackets == 800
    assert st.ibytes == 800 * 256
    assert st.obytes == 800 * 256
    assert st.imissed == 0 and st.oerrors == 0 and st.rx_nombuf == 0
    assert st.as_dict()["ipackets"] == 800


def test_imissed_counts_ring_overflow_drops():
    """Frames the NIC drops for want of descriptors land in imissed and in
    rx_q*_errors, never in rx_q*_packets."""
    pool = PacketPool(512, 1518)
    dev = EthDev.make(pool, ring_size=8, writeback_threshold=8, n_queues=1)
    delivered = 0
    for i in range(32):  # nobody polls: ring fills at 8
        s = pool.alloc()
        pool.write_packet(s, seq=i, length=128, fill=0)
        if dev.deliver(s, 128):
            delivered += 1
    st = dev.stats()
    assert delivered == 8
    assert st.ipackets == 8
    assert st.imissed == 32 - 8
    xs = dev.xstats()
    assert xs["rx_q0_packets"] == 8 and xs["rx_q0_errors"] == 24


def test_stats_reset():
    dev = _run_traffic(n_queues=2, n_packets=400)
    assert dev.stats().ipackets == 400
    dev.stats_reset()
    st = dev.stats()
    assert st.ipackets == 0 and st.opackets == 0
    assert st.ibytes == 0 and st.obytes == 0
    assert all(v == 0 for v in dev.xstats().values())


def test_rx_nombuf_resets_against_shared_pool_baseline():
    """The mempool is pool-scoped and may be shared; stats_reset restarts
    this device's view of alloc failures."""
    pool = PacketPool(4, 1518)
    dev = EthDev.make(pool, ring_size=8, writeback_threshold=8, n_queues=1)
    for _ in range(6):
        pool.alloc()  # 4 succeed, 2 fail
    assert dev.stats().rx_nombuf == 2
    dev.stats_reset()
    assert dev.stats().rx_nombuf == 0
    pool.alloc()  # one more failure after the reset
    assert dev.stats().rx_nombuf == 1


def test_ethdev_is_dropin_for_port_in_server_and_loadgen():
    """The whole point of the facade: servers + LoadGen take EthDevs."""
    pool = PacketPool(4096, 1518)
    devs = [EthDev.make(pool, ring_size=256, n_queues=2, dev_id=i)
            for i in range(2)]
    server = BypassL2FwdServer(devs, burst_size=32)
    lg = LoadGen(devs, verify_integrity=True)
    rep = lg.run_closed_loop(server, n_packets=600, packet_size=200,
                             rng=np.random.default_rng(0))
    assert rep.received == 600
    assert rep.dropped == 0
    assert rep.extras["integrity_errors"] == 0
    assert sum(d.stats().ipackets for d in devs) == 600
