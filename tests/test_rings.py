"""Property tests for the SPSC ring and packet pool (hypothesis)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.packet import PacketPool
from repro.core.rings import SpscRing


@given(capacity=st.integers(1, 64),
       ops=st.lists(st.one_of(
           st.tuples(st.just("push"), st.integers(0, 1000)),
           st.tuples(st.just("pop"), st.integers(0, 0)),
           st.tuples(st.just("push_burst"), st.integers(1, 20)),
           st.tuples(st.just("pop_burst"), st.integers(1, 20)),
       ), max_size=200))
@settings(max_examples=100, deadline=None)
def test_ring_fifo_and_conservation(capacity, ops):
    """Ring is FIFO, never loses or duplicates accepted items, and respects
    its capacity bound."""
    ring = SpscRing(capacity)
    model = []  # reference FIFO of accepted items
    seq = 0
    for op, arg in ops:
        if op == "push":
            ok = ring.try_push(seq)
            if ok:
                model.append(seq)
            assert ok == (len(model) <= ring.capacity
                          and model and model[-1] == seq) or not ok
            seq += 1
        elif op == "push_burst":
            items = list(range(seq, seq + arg))
            seq += arg
            n = ring.push_burst(items)
            model.extend(items[:n])
        elif op == "pop":
            got = ring.try_pop()
            want = model.pop(0) if model else None
            assert got == want
        else:
            got = ring.pop_burst(arg)
            want = model[:arg]
            del model[:arg]
            assert got == want
        assert len(ring) == len(model)
        assert len(model) <= ring.capacity


@given(n_slots=st.integers(1, 128),
       takes=st.lists(st.integers(1, 50), max_size=30))
@settings(max_examples=50, deadline=None)
def test_pool_conservation(n_slots, takes):
    """alloc/free conserve slots; no slot is handed out twice concurrently."""
    pool = PacketPool(n_slots, 128)
    live = set()
    for t in takes:
        got = pool.alloc_burst(t)
        assert len(got) <= t
        for s in got:
            assert s not in live, "double allocation!"
            live.add(s)
        assert pool.n_free == n_slots - len(live)
        # free half
        back = list(live)[: len(live) // 2]
        for s in back:
            live.discard(s)
        pool.free_burst(back)
        assert pool.n_free == n_slots - len(live)


def test_ring_wraparound():
    ring = SpscRing(4)
    for round_ in range(10):
        assert ring.push_burst([round_ * 10 + i for i in range(4)]) == 4
        assert ring.is_full()
        assert not ring.try_push(999)
        assert ring.pop_burst(4) == [round_ * 10 + i for i in range(4)]
        assert ring.is_empty()
    assert ring.enq_drops == 10


def test_pool_zero_copy_views():
    pool = PacketPool(4, 64)
    s = pool.alloc()
    pool.write_packet(s, seq=7, length=64, fill=3)
    view = pool.view(s)
    view[40] = 99  # mutate through the view
    assert pool.arena[s, 40] == 99, "view must alias the arena (zero copy)"
