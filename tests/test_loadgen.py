"""LoadGen (EtherLoadGen analogue): integrity, drops, latency, MSB search.

Open-loop and MSB tests run in virtual time (deterministic, fast); the
wall-clock pacing path keeps regression coverage under ``-m slow``.
"""
import numpy as np
import pytest

from repro.core import (BypassL2FwdServer, KernelStackServer, LoadGen,
                        PacketPool, Port, SimClock, TrafficPattern,
                        find_max_sustainable_bandwidth)
from repro.core.cost import HostCostModel


def _setup(nports=1, pool_slots=2048, ring=256, wb=32, link_gbps=100.0,
           latency_ns=1000):
    pool = PacketPool(pool_slots, 1518)
    ports = [Port.make(pool, ring_size=ring, writeback_threshold=wb,
                       link_gbps=link_gbps, link_latency_ns=latency_ns)
             for _ in range(nports)]
    return pool, ports


def _sim_server(ports, cost=None, **kw):
    server = BypassL2FwdServer(ports, **kw)
    server.attach_clock(SimClock(), cost or HostCostModel())
    return server


def test_l2fwd_payload_integrity():
    """Paper §4.2: 'We always receive the correct content regardless of the
    packet size and network configuration.'"""
    for size in (64, 200, 512, 1400):
        for nports in (1, 2):
            pool, ports = _setup(nports)
            server = BypassL2FwdServer(ports, burst_size=16)
            lg = LoadGen(ports, verify_integrity=True)
            rep = lg.run_closed_loop(server, n_packets=200, packet_size=size,
                                     rng=np.random.default_rng(size),
                                     clock=SimClock())
            assert rep.received == 200
            assert rep.extras["integrity_errors"] == 0
            assert rep.dropped == 0


def test_kernel_stack_integrity():
    pool, ports = _setup()
    server = KernelStackServer(ports, cost_model=HostCostModel(
        interrupt_cycles=0, syscall_cycles=0, per_packet_kernel_cycles=0))
    lg = LoadGen(ports, verify_integrity=True)
    rep = lg.run_closed_loop(server, n_packets=100, packet_size=300,
                             rng=np.random.default_rng(0), clock=SimClock())
    assert rep.received == 100
    assert rep.extras["integrity_errors"] == 0


def test_seq_and_timestamp_roundtrip():
    pool, ports = _setup()
    server = _sim_server(ports)
    lg = LoadGen(ports)
    rep = lg.run_sim(server, TrafficPattern(rate_gbps=0.05, packet_size=256),
                     duration_s=0.05)
    assert rep.received > 0
    assert rep.latency is not None
    assert rep.latency.min_ns > 0           # timestamps parsed & sane
    assert rep.latency.p99_ns >= rep.latency.median_ns
    assert rep.drop_pct == 0.0


def test_overload_produces_drops():
    """Tiny rings + huge offered rate must drop at the NIC, and the loadgen
    must account every one (sent == received + dropped)."""
    pool = PacketPool(64, 1518)
    ports = [Port.make(pool, ring_size=8, writeback_threshold=8,
                       link_gbps=100.0)]
    # server that never polls: everything beyond ring+pool capacity drops
    class DeadServer:
        def poll_once(self):
            return 0
    lg = LoadGen(ports)
    rep = lg.run_sim(DeadServer(), TrafficPattern(rate_gbps=5.0,
                                                  packet_size=1518),
                     duration_s=0.002)
    assert rep.sent > 0
    assert rep.dropped > 0
    assert rep.received + rep.dropped == rep.sent


def test_msb_search_finds_sustainable_rate():
    def mk():
        pool, ports = _setup(pool_slots=8192, ring=1024, link_gbps=400.0)
        return _sim_server(ports, burst_size=64), ports
    msb, reports = find_max_sustainable_bandwidth(
        mk, trial_s=0.002, refine_iters=2, start_gbps=0.1)
    assert msb > 0
    # the reported MSB trial itself had no drops
    ok_trials = [r for r in reports if r.drop_pct == 0 and r.sent > 0]
    assert ok_trials, "at least one sustainable trial"
    # the reported MSB is an offered rate that was actually probed & sustained
    assert any(r.offered_gbps == pytest.approx(msb) and r.drop_pct == 0
               for r in reports)


def test_msb_first_trial_failure_probes_lo_before_refining():
    """Regression: when the very first ramp trial fails, the search used to
    bisect [start/2, start] without ever validating the lower bound as
    sustainable (and could report 0 or an unprobed rate).  It must probe
    downward first, then refine between validated-good and failing rates.

    The system under test saturates physically: a 5 Gbps wire behind a small
    pool, so offering 8 Gbps backs the pool up into drops while anything at
    or below line rate sustains.
    """
    cost = HostCostModel(interrupt_cycles=0, syscall_cycles=0,
                         per_packet_kernel_cycles=0, pmd_poll_cycles=0,
                         pmd_per_packet_cycles=0)

    def mk():
        pool, ports = _setup(pool_slots=2048, ring=1024, link_gbps=5.0)
        return _sim_server(ports, cost=cost, burst_size=64), ports

    msb, reports = find_max_sustainable_bandwidth(
        mk, trial_s=0.02, refine_iters=2, start_gbps=8.0, max_gbps=64.0)
    assert reports[0].drop_pct > 0, "premise: the first ramp trial fails"
    assert 4.0 <= msb < 8.0
    # every reported-sustainable bound was actually probed
    assert any(r.offered_gbps == pytest.approx(msb) and r.drop_pct == 0
               for r in reports)


def test_msb_nothing_sustainable_returns_zero():
    """A system that drops at every probed rate must report 0, not an
    unvalidated bisection floor."""
    class DeadServer:
        def poll_once(self):
            return 0

    def mk():
        pool = PacketPool(64, 1518)
        ports = [Port.make(pool, ring_size=8, writeback_threshold=8,
                           link_gbps=100.0)]
        return DeadServer(), ports

    msb, reports = find_max_sustainable_bandwidth(
        mk, trial_s=0.005, refine_iters=3, start_gbps=1.0, sim_time=True)
    assert msb == 0.0
    assert all(not (r.drop_pct == 0 and r.sent > 0) for r in reports)


def test_trace_replay():
    pool, ports = _setup()
    server = _sim_server(ports)
    lg = LoadGen(ports)
    trace = [(i * 100_000, 128 + (i % 3) * 64) for i in range(100)]
    rep = lg.run_sim(server, TrafficPattern(trace=trace), duration_s=0.05)
    assert rep.sent == 100
    assert rep.received == 100


def test_bursty_and_poisson_patterns():
    for kind in ("bursty", "poisson"):
        pool, ports = _setup(pool_slots=8192, ring=2048, wb=32)
        server = _sim_server(ports, burst_size=64)
        lg = LoadGen(ports)
        rep = lg.run_sim(server, TrafficPattern(rate_gbps=0.2, packet_size=512,
                                                kind=kind, seed=1),
                         duration_s=0.02)
        assert rep.received > 0
        assert rep.received + rep.dropped == rep.sent


# -- wall-clock pacing regression coverage (-m slow) --------------------------

@pytest.mark.slow
def test_wall_clock_open_loop_still_measures():
    """The retained host-clock mode (sim_time=False analogue): real pacing,
    real RTTs, exact drop accounting."""
    pool, ports = _setup(link_gbps=0.0, latency_ns=0)
    server = BypassL2FwdServer(ports)
    lg = LoadGen(ports)
    rep = lg.run(server, TrafficPattern(rate_gbps=0.05, packet_size=256),
                 duration_s=0.05)
    assert rep.received > 0
    assert rep.latency.min_ns > 0
    assert rep.received + rep.dropped == rep.sent


@pytest.mark.slow
def test_wall_clock_poisson_uses_predrawn_interarrivals():
    """The wall path paces off the same analytic schedule (the Poisson fix
    applies to both modes)."""
    pool, ports = _setup(pool_slots=8192, ring=2048, link_gbps=0.0,
                         latency_ns=0)
    server = BypassL2FwdServer(ports, burst_size=64)
    lg = LoadGen(ports)
    rep = lg.run(server, TrafficPattern(rate_gbps=0.2, packet_size=512,
                                        kind="poisson", seed=1),
                 duration_s=0.05)
    assert rep.received > 0
    assert rep.received + rep.dropped == rep.sent


@pytest.mark.slow
def test_wall_clock_msb_search():
    def mk():
        pool, ports = _setup(pool_slots=8192, ring=1024, link_gbps=0.0,
                             latency_ns=0)
        return BypassL2FwdServer(ports, burst_size=64), ports
    msb, reports = find_max_sustainable_bandwidth(
        mk, trial_s=0.05, refine_iters=2, start_gbps=0.1, sim_time=False)
    assert msb > 0
    assert any(r.drop_pct == 0 and r.sent > 0 for r in reports)
