"""Per-port switch pipeline: AQM stages (RED early-drop / ECN marking),
DCTCP rate adaptation, and the trunk fabric (PR 10).

Three layers of coverage (the hypothesis property suite for the same
surfaces lives in ``test_aqm_property.py``):

* **switch units** — AQM verdict mechanics on a bare :class:`Switch`: the
  certain-drop RED band, CE marking on delivered frames, decision-time
  ``occ_high`` sampling (the satellite bugfix: a policy that refuses frames
  at depth k must still record the demand that reached it), and replayable
  counter-seeded decision streams.
* **topology guarantees** — an unset/drop-tail ``PipelineConfig`` is
  bit-identical to no pipeline at all; ECN+DCTCP runs are bit-identical
  per config + seed; the headline incast contract (ECN cuts egress drops
  >= 10x below drop-tail at the same offered load).
* **trunk fabric** — two-switch topologies expose per-switch extras,
  conserve frames, and an oversubscribed trunk concentrates the loss at
  the trunk egress port.
"""
import numpy as np
import pytest

from repro.core import AqmRed, EventScheduler, Switch, aqm_uniform_u64
from repro.core.packet import MIN_FRAME, read_ce, set_ce, write_flow
from repro.core.partition import _pack_crossings, _unpack_crossings
from repro.exp import (AqmConfig, LinkConfig, NodeConfig, PipelineConfig,
                       PoolConfig, SwitchConfig, TopologyConfig,
                       TrafficConfig, run_topology_experiment)


def _frame(dst_ip: int, size: int = 1250) -> np.ndarray:
    buf = np.zeros(max(size, MIN_FRAME), dtype=np.uint8)
    write_flow(buf, 0x0A010001, dst_ip, 1024, 443)
    return buf


def _switch_with_aqm(kind: str, min_thresh: int, max_thresh: int,
                     max_p: float = 1.0, seed: int = 1,
                     egress_capacity: int = 64):
    sched = EventScheduler()
    sw = Switch(2, sched, gbps=10.0, latency_ns=0,
                egress_capacity=egress_capacity)
    out = []
    sw.attach(1, lambda frame, t: out.append(frame))
    sw.add_route(0xC0A80001, 1)
    sw.set_aqm(1, AqmRed(kind=kind, min_thresh=min_thresh,
                         max_thresh=max_thresh, max_p=max_p, seed=seed))
    return sched, sw, out


# -- switch units -------------------------------------------------------------

def test_red_certain_band_drops_every_frame():
    """min == max == 1: depth (occupancy+1) is always >= max_thresh, so the
    RED curve is pinned at 1.0 and every arrival is an early drop."""
    sched, sw, out = _switch_with_aqm("red", 1, 1)
    for _ in range(10):
        sw.send(0, _frame(0xC0A80001), t_ns=0)
    sched.run_all()
    port = sw.ports[1]
    assert out == []
    assert port.aqm.early_drops == 10
    assert port.egress_drops == 0          # never reached the buffer
    assert port.egress_enqueued == 0


def test_occ_high_sampled_at_decision_time():
    """The satellite bugfix: a RED drop at depth k leaves ``occ_high >= k``
    even though nothing was ever enqueued — demand is recorded when the
    policy looks, not only on enqueue."""
    sched, sw, _out = _switch_with_aqm("red", 1, 1)
    sw.send(0, _frame(0xC0A80001), t_ns=0)
    sched.run_all()
    port = sw.ports[1]
    assert port.occupancy == 0
    assert port.egress_enqueued == 0
    # pre-fix behavior: occ_high stays 0 because enqueue never ran
    assert port.occ_high == 1
    assert sw.extras()["sw_p1_occ_high"] == 1.0


def test_ecn_certain_band_marks_and_delivers_every_frame():
    sched, sw, out = _switch_with_aqm("ecn", 1, 1)
    for _ in range(5):
        sw.send(0, _frame(0xC0A80001), t_ns=0)
    sched.run_all()
    port = sw.ports[1]
    assert len(out) == 5
    assert all(read_ce(f) for f in out)
    assert port.aqm.ecn_marked == 5
    assert port.aqm.early_drops == 0
    ex = sw.extras()
    assert ex["sw_p1_ecn_marked"] == 5.0
    assert ex["sw_p1_aqm_early_drops"] == 0.0


def test_below_min_thresh_is_a_no_op():
    """One frame at a time through a wide-open band: depth 1 < min_thresh,
    probability 0, no marks, no drops — indistinguishable from drop-tail."""
    sched, sw, out = _switch_with_aqm("ecn", 8, 24, max_p=0.5)
    for _ in range(5):
        sw.send(0, _frame(0xC0A80001), t_ns=0)
        sched.run_all()                     # drain: queue never builds
    assert len(out) == 5
    assert not any(read_ce(f) for f in out)
    assert sw.ports[1].aqm.ecn_marked == 0


def test_aqm_decision_stream_is_replayable_from_counters():
    """Counter-seeded decisions: two switches with the same policy config
    drop/pass the identical pattern, and the raw uniform stream is a pure
    function of (seed, port, counter)."""
    def run_once():
        sched, sw, out = _switch_with_aqm("red", 2, 6, max_p=0.5, seed=42,
                                          egress_capacity=4)
        for i in range(40):                 # overlapping arrivals: queue builds
            sw.send(0, _frame(0xC0A80001), t_ns=i * 100)
        sched.run_all()
        p = sw.ports[1]
        return (len(out), p.aqm.early_drops, p.aqm.decisions, p.egress_drops)

    assert run_once() == run_once()
    assert [aqm_uniform_u64(42, 1, k) for k in range(8)] \
        == [aqm_uniform_u64(42, 1, k) for k in range(8)]
    assert aqm_uniform_u64(42, 1, 0) != aqm_uniform_u64(42, 2, 0)
    assert aqm_uniform_u64(42, 1, 0) != aqm_uniform_u64(43, 1, 0)


def test_aqm_config_validation():
    with pytest.raises(ValueError, match="kind"):
        AqmRed(kind="codel", min_thresh=1, max_thresh=2, max_p=0.5, seed=0)
    with pytest.raises(ValueError, match="min_thresh"):
        AqmRed(kind="red", min_thresh=5, max_thresh=2, max_p=0.5, seed=0)
    with pytest.raises(ValueError, match="max_p"):
        AqmRed(kind="red", min_thresh=1, max_thresh=2, max_p=0.0, seed=0)


# -- topology guarantees ------------------------------------------------------

def _incast(pipeline=None, cc="fixed", dur=0.0005, seed=7, trunk=None,
            **topo_kw):
    return TopologyConfig(
        name="aqm-test-incast",
        nodes=(NodeConfig(name="srv", pool=PoolConfig(n_slots=16384)),),
        n_clients=4,
        client_pool=PoolConfig(n_slots=16384),
        switch=SwitchConfig(egress_capacity=16,
                            link=LinkConfig(gbps=10.0, latency_ns=1000),
                            pipeline=pipeline, trunk=trunk),
        traffic=TrafficConfig(mode="open_loop", rate_gbps=4.0,
                              packet_size=1518, duration_s=dur, seed=seed,
                              cc_mode=cc, cc_window_ns=100_000,
                              cc_increase_gbps=0.1, cc_max_inflight=8),
        target="srv", **topo_kw)


def test_drop_tail_pipeline_is_bit_identical_to_no_pipeline():
    """An explicit drop-tail pipeline (and an unset one) must not perturb a
    single bit of the report — the refactor's no-behavior-change contract."""
    plain = run_topology_experiment(_incast(pipeline=None)).to_dict()
    explicit = run_topology_experiment(
        _incast(pipeline=PipelineConfig(aqm=AqmConfig(kind="drop-tail"))))
    assert explicit.to_dict() == plain


def test_ecn_dctcp_run_is_deterministic():
    pipe = PipelineConfig(aqm=AqmConfig(kind="ecn", min_thresh=4,
                                        max_thresh=12, max_p=0.1, seed=1))
    a = run_topology_experiment(_incast(pipeline=pipe, cc="dctcp")).to_dict()
    b = run_topology_experiment(_incast(pipeline=pipe, cc="dctcp")).to_dict()
    assert a == b


def test_ecn_dctcp_cuts_egress_drops_vs_drop_tail():
    """The headline contract, at test scale: same offered load, ECN+DCTCP
    loses >= 10x fewer frames to the egress buffer than drop-tail."""
    dt = run_topology_experiment(_incast(dur=0.002))
    pipe = PipelineConfig(aqm=AqmConfig(kind="ecn", min_thresh=4,
                                        max_thresh=12, max_p=0.1, seed=1))
    ec = run_topology_experiment(_incast(pipeline=pipe, cc="dctcp",
                                         dur=0.002))
    dt_drops = dt.extras["sw_p0_egress_drops"]
    ec_drops = ec.extras["sw_p0_egress_drops"]
    assert dt_drops > 0
    assert ec_drops * 10 <= dt_drops
    assert ec.extras["sw_p0_ecn_marked"] > 0
    # the controller actually adapted: some window cut below the configured
    # rate (the final rate may have recovered all the way to line rate)
    assert ec.extras["g0_cc_windows"] > 0
    assert ec.extras["g0_cc_min_rate_gbps"] < 4.0


def test_red_dctcp_converts_egress_drops_to_early_drops():
    pipe = PipelineConfig(aqm=AqmConfig(kind="red", min_thresh=4,
                                        max_thresh=12, max_p=0.1, seed=1))
    rep = run_topology_experiment(_incast(pipeline=pipe, cc="dctcp",
                                          dur=0.002))
    assert rep.extras["sw_p0_egress_drops"] == 0
    assert rep.extras["sw_p0_aqm_early_drops"] > 0


def test_per_port_aqm_overrides_the_default_policy():
    """Port 0 (the server egress, where the incast queue builds) gets ECN;
    every other port keeps the default drop-tail — only port 0 reports AQM
    extras, and it marks."""
    per_port = (AqmConfig(kind="ecn", min_thresh=4, max_thresh=12,
                          max_p=0.1, seed=1),) + (None,) * 4
    pipe = PipelineConfig(per_port_aqm=per_port)
    rep = run_topology_experiment(_incast(pipeline=pipe, cc="dctcp",
                                          dur=0.002))
    assert rep.extras["sw_p0_ecn_marked"] > 0
    assert "sw_p1_ecn_marked" not in rep.extras


# -- trunk fabric -------------------------------------------------------------

def test_trunk_fabric_runs_and_reports_per_switch_extras():
    """Default placement (nodes on switch 0, clients on switch 1): traffic
    crosses the trunk both ways, both switches report counters, and the
    run is deterministic."""
    cfg = _incast(trunk=LinkConfig(gbps=40.0, latency_ns=2000), dur=0.001)
    rep = run_topology_experiment(cfg)
    assert rep.received > 0
    sw0 = {k for k in rep.extras if k.startswith("sw0_")}
    sw1 = {k for k in rep.extras if k.startswith("sw1_")}
    assert sw0 and sw1
    # switch 0: server local port 0, trunk port 1; switch 1: clients 0-3,
    # trunk port 4.  Requests leave sw1's trunk, land on the server via
    # sw0 port 0; echoes return through sw0's trunk port 1.
    assert rep.extras["sw0_p0_egress_forwarded"] > 0
    assert rep.extras["sw0_p1_egress_forwarded"] > 0
    assert rep.extras["sw1_p4_egress_forwarded"] > 0
    assert rep.to_dict() == run_topology_experiment(cfg).to_dict()


def test_trunk_conserves_frames():
    """Every request forwarded out of switch 1's trunk port either reaches
    the server's egress queue or dies in a counted drop — no frame
    vanishes between the switches."""
    cfg = _incast(trunk=LinkConfig(gbps=40.0, latency_ns=2000), dur=0.001)
    rep = run_topology_experiment(cfg)
    ex = rep.extras
    # sw1 trunk egress feeds sw0's forward pipeline toward server port 0
    fed = ex["sw1_p4_egress_forwarded"]
    assert fed == ex["sw0_p0_egress_forwarded"] + ex["sw0_p0_egress_drops"]
    assert ex["sw0_unrouted"] == 0 and ex["sw1_unrouted"] == 0


def test_oversubscribed_trunk_concentrates_loss_at_the_trunk_port():
    """Trunk slower than the aggregate edge rate: the core, not the server
    edge, is the bottleneck — drops appear at switch 1's trunk egress."""
    cfg = _incast(trunk=LinkConfig(gbps=2.0, latency_ns=2000), dur=0.001)
    rep = run_topology_experiment(cfg)
    assert rep.extras["sw1_p4_egress_drops"] > 0
    assert rep.extras["sw0_p0_egress_drops"] == 0


def test_trunk_port_aqm_marks_at_the_core_bottleneck():
    """Full-length per_port_aqm covers the two trunk pseudo-ports; ECN on
    switch 1's trunk egress marks where the oversubscription bites."""
    n_end = 5                               # 1 node + 4 clients
    per_port = (None,) * n_end + (None,
                                  AqmConfig(kind="ecn", min_thresh=2,
                                            max_thresh=8, max_p=0.2, seed=3))
    cfg = _incast(trunk=LinkConfig(gbps=2.0, latency_ns=2000), dur=0.001,
                  pipeline=PipelineConfig(per_port_aqm=per_port))
    rep = run_topology_experiment(cfg)
    assert rep.extras["sw1_p4_ecn_marked"] > 0


# -- mp crossing packing ------------------------------------------------------

def test_pack_unpack_crossings_roundtrip():
    f1, f2 = _frame(0xC0A80001, 200), _frame(0xC0A80002, 300)
    set_ce(f2)
    crossings = [
        (0, 1000, (900, 0, 1), "fwd", (3, f1)),
        (1, 2000, (1900, 1, 2), "deliver", f2),
        (2, 3000, (2900, 2, 3), "deliver", ("exotic", "payload")),
    ]
    metas, buf = _pack_crossings(crossings)
    back = _unpack_crossings(metas, buf)
    assert len(back) == 3
    d0, d1, d2 = back
    assert d0[:4] == crossings[0][:4] and d0[4][0] == 3
    assert np.array_equal(d0[4][1], f1)
    assert d1[:4] == crossings[1][:4]
    assert np.array_equal(d1[4], f2) and read_ce(d1[4])
    assert d2 == crossings[2]               # exotic payload rides unpacked
    # unpacked frames are writable and private (the ECN stage needs both)
    d0[4][1][12] |= 0x01
    assert read_ce(d0[4][1]) and not read_ce(f1)


def test_pack_crossings_one_contiguous_buffer():
    frames = [_frame(0xC0A80001, 100 + 10 * i) for i in range(4)]
    crossings = [(0, i, (0, 0, i), "deliver", f)
                 for i, f in enumerate(frames)]
    metas, buf = _pack_crossings(crossings)
    assert isinstance(buf, bytes)
    assert len(buf) == sum(len(f) for f in frames)
    assert bytes(b"".join(f.tobytes() for f in frames)) == buf
    assert [m[5] for m in metas] == [
        (sum(len(f) for f in frames[:i]), len(frames[i]))
        for i in range(4)]


