"""Telemetry edge cases: degenerate throughput windows and exhaustive
ServerStats merges — the counters every RunReport is assembled from."""
from dataclasses import dataclass

import numpy as np
import pytest

from repro.core import ServerStats, ThroughputMeter
from repro.core.kernel_stack import KernelStats


# -- ThroughputMeter ----------------------------------------------------------

def test_throughput_meter_degenerate_window_reports_nonzero():
    """Regression: a single completion landing in one terminal flush gives
    start_ns == end_ns; the meter used to report 0 Gbps (as if nothing
    moved).  It must measure over the 1 ns tick floor instead."""
    m = ThroughputMeter()
    m.on_packet(1518, 1_000)
    assert m.packets == 1
    assert m.gbps > 0
    assert m.mpps > 0


def test_throughput_meter_degenerate_merge_counts_window():
    m = ThroughputMeter()
    m.merge_counts(4, 4 * 512, 7_000, 7_000)  # burst at one instant
    assert m.gbps > 0 and m.mpps > 0


def test_throughput_meter_empty_still_zero():
    m = ThroughputMeter()
    assert m.elapsed_s == 0.0
    assert m.gbps == 0.0
    assert m.mpps == 0.0


def test_throughput_meter_normal_window_unchanged():
    m = ThroughputMeter()
    m.on_packet(1000, 0)
    m.on_packet(1000, 1_000_000)  # 2000 B over 1 ms
    assert m.elapsed_s == pytest.approx(1e-3)
    assert m.gbps == pytest.approx(2000 * 8 / 1e9 / 1e-3)
    assert m.mpps == pytest.approx(2 / 1e6 / 1e-3)


def test_throughput_meter_open_window_anchors_start():
    m = ThroughputMeter()
    m.open_window(100)
    m.on_packet(1518, 1_000_100)
    assert m.elapsed_s == pytest.approx(1e-3)


# -- ServerStats.merge_from ---------------------------------------------------

@dataclass
class _FloatStats(ServerStats):
    busy_frac: float = 0.0


@dataclass
class _BadStats(ServerStats):
    note: str = ""


def test_merge_from_is_exhaustive_over_numeric_fields():
    """Regression: merge_from silently dropped any non-int field a stats
    subclass added; float fields must accumulate like ints do."""
    a = _FloatStats(rx_packets=1, busy_frac=0.5)
    b = _FloatStats(rx_packets=2, busy_frac=0.25)
    a.merge_from(b)
    assert a.rx_packets == 3
    assert a.busy_frac == pytest.approx(0.75)


def test_merge_from_fails_loudly_on_unmergeable_field():
    with pytest.raises(TypeError, match="note"):
        _BadStats().merge_from(_BadStats())


def test_merge_from_still_aggregates_kernel_stats_and_buckets():
    a, b = KernelStats(), KernelStats()
    a.record_burst(4)
    b.record_burst(4)
    b.syscalls = 7
    a.merge_from(b)
    assert a.syscalls == 7
    assert a.burst_count == 2
    assert int(a.burst_buckets.sum()) == 2
    assert isinstance(a.burst_buckets, np.ndarray)
