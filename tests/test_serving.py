"""LLM-inference-serving layer (tentpole): config round-trips, deterministic
request generation, the disaggregated prefill/decode cluster end-to-end over
the switched fabric, balancer policies, continuous-batching saturation (p99
TTFT vs offered QPS), the KV-cache elephant flow as an attributable switch
observable, and decode-replica failover."""
import dataclasses
import json

import numpy as np
import pytest

from repro.exp import (LinkConfig, NodeConfig, PoolConfig, PortConfig,
                       StackConfig, SwitchConfig, TopologyConfig,
                       TrafficConfig, run_topology_experiment)
from repro.serving import (MIN_SERVING_FRAME, BalancerServer,
                           RequestGenerator, RequestMixConfig, ServingConfig,
                           build_frame, is_serving_frame, read_header)
from repro.serving.protocol import MSG_REQUEST


# -- builders ------------------------------------------------------------------

def _mix(**kw) -> RequestMixConfig:
    base = dict(prompt_mean_tokens=64, prompt_dist="fixed",
                output_mean_tokens=4, output_dist="fixed")
    base.update(kw)
    return RequestMixConfig(**base)


def _serving(**kw) -> ServingConfig:
    base = dict(mix=_mix(), qps=20_000.0, prefill_ns_per_token=200,
                prefill_overhead_ns=5_000, decode_ns_per_token=300,
                decode_overhead_ns=2_000, kv_bytes_per_token=256,
                kv_segment_bytes=1024, max_batch_tokens=2048,
                max_batch_requests=8)
    base.update(kw)
    return ServingConfig(**base)


def _node(name: str, kind: str) -> NodeConfig:
    return NodeConfig(name=name,
                      pool=PoolConfig(n_slots=4096, slot_size=2048),
                      port=PortConfig(n_queues=2, ring_size=512,
                                      writeback_threshold=1),
                      stack=StackConfig(kind=kind, burst_size=32))


def _topology(serving: ServingConfig, n_clients: int = 2,
              duration_s: float = 0.002, egress_capacity: int = 256,
              link_gbps: float = 100.0, seed: int = 7) -> TopologyConfig:
    return TopologyConfig(
        name="serving",
        nodes=(_node("lb", "balancer"), _node("prefill0", "prefill"),
               _node("prefill1", "prefill"), _node("decode0", "decode"),
               _node("decode1", "decode")),
        n_clients=n_clients,
        client_pool=PoolConfig(n_slots=4096, slot_size=2048),
        switch=SwitchConfig(egress_capacity=egress_capacity,
                            link=LinkConfig(gbps=link_gbps, latency_ns=1000)),
        traffic=TrafficConfig(duration_s=duration_s, seed=seed,
                              mode="open_loop", sim_time=True),
        serving=serving)


def _report_key(rep):
    lat = None if rep.latency is None else rep.latency.as_dict()
    return (rep.sent, rep.received, rep.dropped, lat,
            tuple(sorted(rep.extras.items())))


# -- configs: validation + exact round-trip ------------------------------------

def test_serving_config_round_trips_through_json():
    s = _serving(policy="weighted", prefill_weights=(3, 1),
                 fail_node="decode1", fail_at_s=0.001)
    assert ServingConfig.from_dict(s.to_dict()) == s
    topo = _topology(s)
    assert TopologyConfig.from_dict(topo.to_dict()) == topo
    via_json = TopologyConfig.from_dict(json.loads(json.dumps(topo.to_dict())))
    assert via_json == topo
    # non-serving topologies keep a None field and still round-trip
    plain = TopologyConfig(
        traffic=TrafficConfig(mode="open_loop", duration_s=0.0005))
    assert plain.serving is None
    assert TopologyConfig.from_dict(plain.to_dict()) == plain


def test_serving_config_validation():
    with pytest.raises(ValueError, match="policy"):
        _serving(policy="random")
    with pytest.raises(ValueError, match="unknown model"):
        _mix(model="gpt-17")
    with pytest.raises(ValueError, match="prefill_weights"):
        _serving(policy="weighted", prefill_weights=(1,))
    with pytest.raises(ValueError, match="fail_node"):
        _serving(fail_node="prefill0")
    with pytest.raises(ValueError, match="MIN_SERVING_FRAME"):
        _serving(token_frame_bytes=MIN_SERVING_FRAME - 1)
    with pytest.raises(ValueError, match="overlap"):
        _serving(prefill=("a", "b"), decode=("b", "c"))
    with pytest.raises(ValueError, match="qps"):
        _serving(qps=0.0)


def test_topology_serving_validation():
    s = _serving()
    nodes = (_node("lb", "balancer"), _node("prefill0", "prefill"),
             _node("prefill1", "prefill"), _node("decode0", "decode"),
             _node("decode1", "decode"))
    traffic = TrafficConfig(mode="open_loop", sim_time=True)
    # role name must exist among the nodes
    with pytest.raises(ValueError, match="not a node name"):
        TopologyConfig(nodes=nodes[:-1], traffic=traffic, serving=s)
    # the named node must carry the matching registered stack kind
    bad = nodes[:1] + (_node("prefill0", "bypass"),) + nodes[2:]
    with pytest.raises(ValueError, match="stack kind"):
        TopologyConfig(nodes=bad, traffic=traffic, serving=s)
    # long engine iterations + coarse writeback threshold would strand frames
    coarse = dataclasses.replace(
        nodes[1], port=PortConfig(n_queues=2, ring_size=512,
                                  writeback_threshold=32))
    with pytest.raises(ValueError, match="writeback_threshold"):
        TopologyConfig(nodes=nodes[:1] + (coarse,) + nodes[2:],
                       traffic=traffic, serving=s)


def test_model_derived_cost_figures():
    s = ServingConfig(mix=RequestMixConfig(model="llama3.2-3b"))
    m = s.model_config()
    assert s.resolved_kv_bytes_per_token() == 2 * m.n_layers * m.kv_dim * 2
    assert s.resolved_prefill_ns_per_token() >= 1
    assert s.resolved_decode_overhead_ns() >= 1
    # explicit overrides win
    assert _serving().resolved_prefill_ns_per_token() == 200
    assert _serving().kv_segments(64) == (64 * 256 + 1023) // 1024


# -- request generation --------------------------------------------------------

def test_request_generator_deterministic_and_qps_scaled():
    s = _serving(qps=50_000.0)
    a = RequestGenerator(s, seed=3).generate(2_000_000)
    b = RequestGenerator(s, seed=3).generate(2_000_000)
    for x, y in zip(a, b):
        assert np.array_equal(x, y)
    times, prompts, outputs = a
    # 50k qps over 2 ms of schedule ≈ 100 requests
    assert 80 <= len(times) <= 120
    assert np.all(prompts == 64) and np.all(outputs == 4)
    c = RequestGenerator(s, seed=4).generate(2_000_000)
    assert not np.array_equal(times, c[0])


def test_token_length_distributions_respect_bounds():
    mix = _mix(prompt_dist="lognormal", prompt_cv=1.0,
               prompt_mean_tokens=256, max_prompt_tokens=512,
               output_dist="exponential", output_mean_tokens=8,
               min_output_tokens=2, max_output_tokens=16)
    prompts, outputs = mix.sample(np.random.default_rng(0), 500)
    assert prompts.min() >= 1 and prompts.max() <= 512
    assert outputs.min() >= 2 and outputs.max() <= 16
    assert len(np.unique(prompts)) > 10  # actually a distribution


def test_serving_frame_protocol_round_trip():
    buf = np.zeros(256, dtype=np.uint8)
    build_frame(buf, size=256, seq=9, src_ip=0x0A010000, dst_ip=0xC0A80001,
                stamp_ns=123, msg=MSG_REQUEST, req_id=77, seg=2, seg_count=3,
                prompt_tokens=64, output_tokens=4, aux=0xC0A80004, last=True)
    assert is_serving_frame(buf)
    hdr = read_header(buf)
    assert (hdr.msg, hdr.req_id, hdr.seg, hdr.seg_count) == (MSG_REQUEST, 77,
                                                             2, 3)
    assert (hdr.prompt_tokens, hdr.output_tokens, hdr.aux) == (64, 4,
                                                               0xC0A80004)
    assert hdr.last
    assert not is_serving_frame(np.zeros(256, dtype=np.uint8))


# -- end-to-end over the fabric ------------------------------------------------

def test_serving_cluster_completes_all_requests():
    rep = run_topology_experiment(_topology(_serving()))
    assert rep.sent > 50
    assert rep.received == rep.sent            # every request completes
    assert rep.extras["serving"] == 1.0
    # SLOs recorded in virtual ns: TTFT covers the 2-hop request path +
    # prefill compute; TPOT is the decode iteration cadence
    assert rep.extras["ttft_count"] == rep.sent
    assert rep.extras["ttft_p50_ns"] > 4000    # > 4 wire crossings
    assert rep.extras["tpot_p50_ns"] > 0
    assert rep.extras["ttft_p99_ns"] >= rep.extras["ttft_p50_ns"]
    # request accounting is conserved through every role
    routed = rep.extras["n0_lb_requests_routed"]
    assert routed == rep.sent
    prefill_in = (rep.extras["n1_prefill_requests_in"]
                  + rep.extras["n2_prefill_requests_in"])
    assert prefill_in == rep.sent
    done = (rep.extras["n3_decode_requests_done"]
            + rep.extras["n4_decode_requests_done"])
    assert done == rep.sent                    # all multi-token here
    # the KV elephant flow actually crossed the fabric
    kv = (rep.extras["n1_prefill_kv_segments"]
          + rep.extras["n2_prefill_kv_segments"])
    assert kv == (rep.extras["n3_decode_kv_segments_in"]
                  + rep.extras["n4_decode_kv_segments_in"]) > rep.sent
    # nothing stray, nothing lost at NICs
    for gi in range(2):
        assert rep.extras[f"g{gi}_stray_frames"] == 0.0
    for ni in range(5):
        assert rep.extras[f"n{ni}_imissed"] == 0.0


def test_serving_reports_bit_identical():
    cfg = _topology(_serving())
    a = run_topology_experiment(cfg)
    b = run_topology_experiment(cfg)
    assert _report_key(a) == _report_key(b)


def test_balancer_policies_spread_requests():
    # round_robin: exact 50/50 split
    rep = run_topology_experiment(_topology(_serving(policy="round_robin")))
    assert rep.extras["n0_lb_prefill0_requests"] == \
        rep.extras["n0_lb_prefill1_requests"]
    # weighted 3:1 — smooth WRR holds the ratio at every prefix
    w = run_topology_experiment(
        _topology(_serving(policy="weighted", prefill_weights=(3, 1))))
    r0, r1 = (w.extras["n0_lb_prefill0_requests"],
              w.extras["n0_lb_prefill1_requests"])
    assert r0 + r1 == w.sent
    assert 2.0 <= r0 / max(r1, 1.0) <= 4.0
    # least_loaded keeps both replicas busy and completes everything
    ll = run_topology_experiment(_topology(_serving(policy="least_loaded")))
    assert ll.received == ll.sent
    assert ll.extras["n0_lb_prefill0_requests"] > 0
    assert ll.extras["n0_lb_prefill1_requests"] > 0


def test_least_loaded_prefers_the_idle_replica():
    srv = BalancerServer.__new__(BalancerServer)
    srv.serving = _serving(policy="least_loaded")

    class _Fake:
        def __init__(self, q):
            self.queued_tokens = q

    srv.prefill_servers = [_Fake(500), _Fake(20)]
    assert srv._pick_prefill() == 1


def test_ttft_p99_degrades_monotonically_across_saturation():
    """The continuous-batching acceptance: two prefill replicas at
    2000 ns/token and 64-token prompts sustain ~16k requests/s; sweeping the
    offered QPS across that knee must fatten the TTFT tail monotonically,
    with the saturated point at least 3x the underloaded one (queueing
    delay, not noise)."""
    p99s = []
    for qps in (2_000.0, 8_000.0, 24_000.0):
        s = _serving(qps=qps, prefill_ns_per_token=2_000)
        rep = run_topology_experiment(_topology(s, n_clients=1))
        assert rep.received == rep.sent > 0
        p99s.append(rep.extras["ttft_p99_ns"])
    assert p99s[0] <= p99s[1] <= p99s[2]
    assert p99s[2] > 3 * p99s[0]


def test_kv_elephant_flow_congests_the_decode_egress_port():
    """The KV transfer is an attributable fabric observable: pin a single
    decode replica so both prefills' elephant flows converge 2:1 on one
    egress port, shrink its buffers, and the bursts overrun it — drops land
    on the *switch* decode port (3), the NICs stay clean, and the requests
    whose KV died report incomplete."""
    s = _serving(kv_bytes_per_token=4096,  # 256 KV segments per request
                 decode=("decode0",))
    cfg = _topology(s, n_clients=2, egress_capacity=16, link_gbps=10.0)
    rep = run_topology_experiment(cfg)
    assert rep.extras["sw_p3_egress_drops"] > 0
    assert rep.received < rep.sent           # stranded on lost KV
    for ni in range(5):
        assert rep.extras[f"n{ni}_imissed"] == 0.0
        assert rep.extras[f"n{ni}_rx_nombuf"] == 0.0
    # reassembly on the decode side is visibly stuck, not silently wrong
    assert rep.extras["n3_decode_reasm_pending"] > 0
    # attribution control: same topology and convergence, but mice-sized KV
    # (16 segments/request) with roomy buffers completes loss-free — the
    # drops above are the elephants' doing, not the single-replica routing
    mice = _serving(kv_bytes_per_token=256, decode=("decode0",))
    ok = run_topology_experiment(
        _topology(mice, n_clients=2, egress_capacity=4096, link_gbps=100.0))
    assert ok.received == ok.sent
    assert ok.extras["sw_p3_egress_drops"] == 0.0


def test_decode_replica_failover():
    """Kill decode1 mid-run: requests pinned to it strand (counted on the
    failed node), later requests route around it, and the run still
    quiesces deterministically."""
    # the failure instant must land while decode1 still has requests in
    # flight; that depends on the (content-derived) client seeds, so it is
    # re-tuned whenever the seed derivation changes
    s = _serving(fail_node="decode1", fail_at_s=0.0004)
    cfg = _topology(s, n_clients=2, duration_s=0.002)
    rep = run_topology_experiment(cfg)
    lost = (rep.extras["n4_decode_failed_drops"]
            + rep.extras["n4_decode_stranded_requests"])
    assert lost > 0
    assert rep.received < rep.sent
    # the healthy replica picks up the post-failure traffic, and every
    # completion is accounted to one of the two replicas
    assert rep.extras["n3_decode_requests_done"] > rep.extras[
        "n4_decode_requests_done"]
    assert (rep.extras["n3_decode_requests_done"]
            + rep.extras["n4_decode_requests_done"]) == rep.received
    assert _report_key(run_topology_experiment(cfg)) == _report_key(rep)


def test_extras_collision_guard_rejects_duplicate_keys():
    """Satellite: RunReport extras merging is collision-checked.  Before the
    guard, a component re-exporting an existing key silently overwrote it;
    now the merge raises and names the offender."""
    from repro.exp.topology import _merge_extras
    extras = {"sw_p0_egress_drops": 3.0}
    _merge_extras(extras, {"sw_p1_egress_drops": 0.0}, "switch telemetry")
    assert extras["sw_p1_egress_drops"] == 0.0
    with pytest.raises(ValueError, match="collision.*sw_p0_egress_drops"):
        _merge_extras(extras, {"sw_p0_egress_drops": 9.0}, "rogue component")
    # the existing value is untouched by the failed merge
    assert extras["sw_p0_egress_drops"] == 3.0
