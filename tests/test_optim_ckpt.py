"""Optimizer + checkpoint manager unit tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.optim import adamw


def test_adamw_converges_on_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                            decay_steps=1000, grad_clip=100.0)
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    state = adamw.init(cfg, params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}  # d/dw ||w||^2
        params, state, m = adamw.apply_updates(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_grad_clipping():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) > 100
    total = jnp.sqrt(sum(jnp.sum(x ** 2)
                         for x in jax.tree_util.tree_leaves(clipped)))
    np.testing.assert_allclose(float(total), 1.0, rtol=1e-5)


def test_schedule_shape():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, decay_steps=100,
                            min_lr_frac=0.1)
    lrs = [float(adamw.schedule(cfg, jnp.int32(s))) for s in range(0, 120, 5)]
    assert lrs[0] < lrs[1]                       # warming up
    assert abs(lrs[2] - 1.0) < 0.05              # peak ≈ lr
    assert lrs[-1] <= 0.12                       # decayed to min_lr_frac
    assert all(l >= 0 for l in lrs)


def test_int8_compression_roundtrip():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(256, 64)) * 0.01, jnp.float32)
    q, scale = adamw.compress_int8(g)
    back = adamw.decompress_int8(q, scale)
    assert q.dtype == jnp.int8
    # quantization error bounded by scale/2 per element
    assert float(jnp.abs(back - g).max()) <= float(scale) * 0.51


def test_bf16_params_master_fp32_update():
    cfg = adamw.AdamWConfig(lr=0.01, warmup_steps=1, decay_steps=10)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = adamw.init(cfg, params)
    grads = {"w": jnp.full((4,), 1e-4, jnp.float32)}
    new_params, new_state, _ = adamw.apply_updates(cfg, params, grads, state)
    assert new_params["w"].dtype == jnp.bfloat16
    assert new_state.master["w"].dtype == jnp.float32
    # master moved even though bf16 params may round
    assert float(jnp.abs(new_state.master["w"] - 1.0).max()) > 0


# -- checkpoint manager -------------------------------------------------------

def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.bfloat16)}}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(5, tree, extra={"note": "x"}, block=True)
    like = jax.tree_util.tree_map(jnp.zeros_like, tree)
    restored, step, extra = mgr.restore(None, like)
    assert step == 5 and extra["note"] == "x"
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_gc_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(), block=True)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2
    assert mgr.latest_step() == 4


def test_checkpoint_corruption_detected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(), block=True)
    # flip bytes in one array file
    d = os.path.join(tmp_path, "step_000000001", "arrays")
    f = os.path.join(d, sorted(os.listdir(d))[0])
    raw = bytearray(open(f, "rb").read())
    raw[-1] ^= 0xFF
    open(f, "wb").write(bytes(raw))
    with pytest.raises(IOError):
        mgr.restore(1, _tree())


def test_checkpoint_shape_mismatch(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(), block=True)
    bad = {"a": jnp.zeros((2, 2)), "b": {"c": jnp.zeros((2,), jnp.bfloat16)}}
    with pytest.raises(ValueError):
        mgr.restore(1, bad)
